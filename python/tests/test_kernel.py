"""L1 kernel correctness: Pallas `gf2_decode` vs the pure-jnp oracle and
an independent integer-arithmetic implementation.

Hypothesis sweeps shapes and seeds; `assert_allclose` with zero tolerance
— GF(2) bits and small-integer accumulations are exact in f32.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.gf2_decode import (
    gf2_decode_planes,
    gf2_decode_single,
)
from compile.kernels.ref import (
    decode_matvec_ref,
    gf2_decode_ref,
    sliding_windows,
)


def rand_bits(rng, shape):
    return rng.integers(0, 2, size=shape).astype(np.float32)


# ---------- independent integer oracle ----------


def int_decode(windows, m_t):
    """Bitwise-int GF(2) decode, no matmul: XOR of selected columns."""
    l, k = windows.shape
    n_out = m_t.shape[1]
    out = np.zeros((l, n_out), dtype=np.int64)
    wi = windows.astype(np.int64)
    mi = m_t.astype(np.int64)
    for t in range(l):
        acc = np.zeros(n_out, dtype=np.int64)
        for j in range(k):
            if wi[t, j]:
                acc ^= mi[j]
        out[t] = acc
    return out.astype(np.float32)


# ---------- single-plane kernel ----------


@settings(max_examples=20, deadline=None)
@given(
    l=st.integers(1, 40),
    k=st.integers(1, 24),
    n_out=st.integers(1, 96),
    seed=st.integers(0, 2**31),
)
def test_single_plane_kernel_matches_ref(l, k, n_out, seed):
    rng = np.random.default_rng(seed)
    win = rand_bits(rng, (l, k))
    m_t = rand_bits(rng, (k, n_out))
    got = np.asarray(gf2_decode_single(win, m_t, block_l=16))
    want = np.asarray(gf2_decode_ref(win, m_t))
    assert_allclose(got, want, rtol=0, atol=0)


@settings(max_examples=10, deadline=None)
@given(
    l=st.integers(1, 16),
    k=st.integers(1, 12),
    n_out=st.integers(1, 32),
    seed=st.integers(0, 2**31),
)
def test_ref_matches_integer_xor_oracle(l, k, n_out, seed):
    rng = np.random.default_rng(seed)
    win = rand_bits(rng, (l, k))
    m_t = rand_bits(rng, (k, n_out))
    want = int_decode(win, m_t)
    got = np.asarray(gf2_decode_ref(win, m_t))
    assert_allclose(got, want, rtol=0, atol=0)


def test_kernel_tiling_boundary_cases():
    """Exercise l not divisible by block_l (grid padding)."""
    rng = np.random.default_rng(0)
    for l in [1, 255, 256, 257, 300]:
        win = rand_bits(rng, (l, 24))
        m_t = rand_bits(rng, (24, 80))
        got = np.asarray(gf2_decode_single(win, m_t))
        want = np.asarray(gf2_decode_ref(win, m_t))
        assert_allclose(got, want, rtol=0, atol=0)


# ---------- fused planes kernel ----------


def fused_oracle(windows, m_t, corr, invert):
    """Numpy reimplementation of the fused kernel semantics."""
    n_planes, l, _ = windows.shape
    n_out = m_t.shape[1]
    acc = np.zeros((l, n_out), dtype=np.float32)
    for k in range(n_planes):
        bits = int_decode(windows[k], m_t)
        fixed = np.mod(bits + corr[k] + invert[k], 2.0)
        weight = -128.0 if k == 0 else 2.0 ** (7 - k)
        acc += fixed * weight
    return acc


@settings(max_examples=10, deadline=None)
@given(
    l=st.integers(1, 24),
    k=st.integers(1, 24),
    n_out=st.integers(1, 40),
    seed=st.integers(0, 2**31),
)
def test_fused_planes_kernel(l, k, n_out, seed):
    rng = np.random.default_rng(seed)
    win = rand_bits(rng, (8, l, k))
    m_t = rand_bits(rng, (k, n_out))
    corr = rand_bits(rng, (8, l, n_out))
    inv = rand_bits(rng, (8,))
    got = np.asarray(gf2_decode_planes(win, m_t, corr, inv, block_l=8))
    want = fused_oracle(win, m_t, corr, inv)
    assert_allclose(got, want, rtol=0, atol=0)


def test_fused_planes_value_range():
    """Accumulated two's-complement bytes stay in [-128, 127]."""
    rng = np.random.default_rng(1)
    win = rand_bits(rng, (8, 32, 24))
    m_t = rand_bits(rng, (24, 80))
    corr = np.zeros((8, 32, 80), dtype=np.float32)
    inv = np.zeros(8, dtype=np.float32)
    out = np.asarray(gf2_decode_planes(win, m_t, corr, inv))
    assert out.min() >= -128.0
    assert out.max() <= 127.0
    assert np.all(out == np.round(out))


# ---------- sliding windows ----------


@settings(max_examples=15, deadline=None)
@given(
    l=st.integers(1, 20),
    n_s=st.integers(0, 3),
    n_in=st.integers(1, 8),
    seed=st.integers(0, 2**31),
)
def test_sliding_windows_layout(l, n_s, n_in, seed):
    rng = np.random.default_rng(seed)
    bits = rand_bits(rng, (l + n_s, n_in))
    win = np.asarray(sliding_windows(bits, n_s, l))
    assert win.shape == (l, (n_s + 1) * n_in)
    for t in range(l):
        for s in range(n_s + 1):
            # slot s of window t = stream entry (t + n_s - s)
            seg = win[t, s * n_in : (s + 1) * n_in]
            assert_allclose(seg, bits[t + n_s - s], rtol=0, atol=0)


def test_sliding_windows_preload_zeros():
    """With zero preload, early windows see zero history."""
    l, n_s, n_in = 4, 2, 3
    bits = np.ones((l + n_s, n_in), dtype=np.float32)
    bits[:n_s] = 0.0
    win = np.asarray(sliding_windows(bits, n_s, l))
    # Window 0: slots 1, 2 come from preload → zero.
    assert_allclose(win[0, n_in:], 0.0)
    # Window 2+: all slots from real inputs → one.
    assert_allclose(win[2], 1.0)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

"""L2 model correctness: `decode_matvec` (Pallas path) vs the oracle and
vs directly-constructed ground-truth weights.
"""

import functools

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.ref import decode_matvec_ref
from compile.model import decode_matvec, decode_weights


def make_case(rng, rows, cols, n_in, n_out, n_s, batch):
    n = rows * cols
    l = -(-n // n_out)
    k = (n_s + 1) * n_in
    return {
        "encoded_bits": rng.integers(0, 2, (8, l + n_s, n_in)).astype(
            np.float32
        ),
        "m_t": rng.integers(0, 2, (k, n_out)).astype(np.float32),
        "corr": rng.integers(0, 2, (8, l * n_out)).astype(np.float32),
        "invert": rng.integers(0, 2, (8,)).astype(np.float32),
        "mask": rng.integers(0, 2, (n,)).astype(np.float32),
        "x": rng.normal(size=(batch, cols)).astype(np.float32),
        "scale": np.float32(0.03),
    }


@settings(max_examples=8, deadline=None)
@given(
    rows=st.integers(2, 12),
    cols=st.integers(2, 24),
    n_out=st.integers(4, 40),
    n_s=st.integers(0, 2),
    batch=st.integers(1, 4),
    seed=st.integers(0, 2**31),
)
def test_model_matches_ref(rows, cols, n_out, n_s, batch, seed):
    rng = np.random.default_rng(seed)
    n_in = 8
    case = make_case(rng, rows, cols, n_in, n_out, n_s, batch)
    n = rows * cols
    l = -(-n // n_out)

    (got,) = decode_matvec(
        case["encoded_bits"],
        case["m_t"],
        case["corr"],
        case["invert"],
        case["mask"],
        case["x"],
        case["scale"],
        n_s=n_s,
        rows=rows,
        cols=cols,
    )
    want = decode_matvec_ref(
        case["encoded_bits"],
        case["m_t"],
        _corr_flat(case["corr"], n, l, n_out),
        case["invert"],
        case["mask"],
        case["x"],
        case["scale"],
        n_s=n_s,
    )
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def _corr_flat(corr, n, l, n_out):
    """ref takes corr at flat positions [8, n]; model takes [8, l·n_out]."""
    return corr.reshape(8, l * n_out)[:, :n]


def test_decode_weights_reconstructs_known_bytes():
    """Build streams whose decode is fully known: M⊕ = I-ish rows.

    With n_s = 0 and m_t = identity (n_in = n_out = 8), the decoded
    plane bits equal the encoded bits — so we can write arbitrary bytes
    and check the two's-complement reconstruction against numpy int8.
    """
    rows, cols = 4, 16
    n = rows * cols
    n_in = n_out = 8
    l = n // n_out
    rng = np.random.default_rng(3)
    target = rng.integers(-128, 128, size=n).astype(np.int8)

    # Plane k bit of weight i = bit (7-k) of the byte (MSB-first planes).
    bits = ((target.astype(np.uint8)[None, :] >> (7 - np.arange(8))[:, None]) & 1)
    encoded = bits.reshape(8, l, n_out).astype(np.float32)
    # identity m_t: window j → output j
    m_t = np.eye(8, dtype=np.float32)

    (w,) = decode_weights(
        encoded,
        m_t,
        np.zeros((8, l * n_out), np.float32),
        np.zeros(8, np.float32),
        np.ones(n, np.float32),
        np.float32(1.0),
        n_s=0,
        rows=rows,
        cols=cols,
    )
    assert_allclose(
        np.asarray(w).reshape(-1), target.astype(np.float32), rtol=0, atol=0
    )


def test_mask_zeroes_pruned_weights():
    rng = np.random.default_rng(4)
    rows, cols, n_out, n_s = 4, 8, 10, 1
    case = make_case(rng, rows, cols, 8, n_out, n_s, 1)
    case["mask"] = np.zeros(rows * cols, np.float32)
    (w,) = decode_weights(
        case["encoded_bits"],
        case["m_t"],
        case["corr"],
        case["invert"],
        case["mask"],
        case["scale"],
        n_s=n_s,
        rows=rows,
        cols=cols,
    )
    assert_allclose(np.asarray(w), 0.0)


def test_model_is_jittable_and_stable():
    """jit(decode_matvec) must lower and produce identical values."""
    rng = np.random.default_rng(5)
    rows, cols, n_out, n_s, batch = 8, 16, 20, 2, 3
    case = make_case(rng, rows, cols, 8, n_out, n_s, batch)
    f = functools.partial(decode_matvec, n_s=n_s, rows=rows, cols=cols)
    args = [
        case["encoded_bits"],
        case["m_t"],
        case["corr"],
        case["invert"],
        case["mask"],
        case["x"],
        case["scale"],
    ]
    (eager,) = f(*args)
    (jitted,) = jax.jit(f)(*args)
    assert_allclose(np.asarray(jitted), np.asarray(eager), rtol=1e-6, atol=1e-5)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

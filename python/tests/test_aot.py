"""AOT lowering sanity: HLO text generation + manifest consistency.

These run the lowering in-process (no artifact files needed) and verify
the HLO text has the structure the Rust loader expects.
"""

import pytest

from compile import aot


def test_shapes_are_consistent():
    sh = aot.shapes(8)
    n = aot.ROWS * aot.COLS
    l = -(-n // aot.N_OUT)
    assert sh["encoded_bits"] == (8, l + aot.N_S, aot.N_IN)
    assert sh["m_t"] == ((aot.N_S + 1) * aot.N_IN, aot.N_OUT)
    assert sh["corr"] == (8, l * aot.N_OUT)
    assert sh["x"] == (8, aot.COLS)


@pytest.mark.parametrize("batch", [1, 8])
def test_lower_matvec_produces_hlo_text(batch):
    text = aot.lower_matvec(batch)
    assert "HloModule" in text
    assert "ROOT" in text
    # One f32 output of shape [batch, rows] inside a tuple.
    assert f"f32[{batch},{aot.ROWS}]" in text


def test_lower_weights_produces_hlo_text():
    text = aot.lower_weights()
    assert "HloModule" in text
    assert f"f32[{aot.ROWS},{aot.COLS}]" in text


def test_hlo_has_no_custom_calls():
    """interpret=True Pallas must lower to plain HLO ops — a Mosaic
    custom-call would be unloadable by the CPU PJRT plugin."""
    text = aot.lower_matvec(1)
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

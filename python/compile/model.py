"""L2 JAX model: fixed-to-fixed decode + masked matvec (Algorithm 2).

``decode_matvec`` reconstructs a signed-INT8 layer from its encoded
bit-plane streams through the Pallas kernel (`kernels.gf2_decode`) and
multiplies the (masked, dequantized) weights with a batch of activation
vectors. Lowered once by ``aot.py`` to HLO text per batch size; the Rust
runtime executes the artifacts at request time — Python never touches
the request path.

Input layout (all f32; bit tensors hold 0.0/1.0):
  encoded_bits [8, l+n_s, n_in] — per-plane encoded streams, sign plane
                                   first, first n_s entries = register
                                   preload
  m_t          [K, n_out]        — M⊕ transpose, K = (n_s+1)·n_in
  corr         [8, l·n_out]      — correction bits at decoded positions
                                   (tail padding zeros)
  invert       [8]               — per-plane inverting flags
  mask         [n]               — 1 = unpruned (n = rows·cols)
  x            [batch, cols]     — activations
  scale        []                — INT8 dequantization scale
Output:
  y            [batch, rows]
"""

import jax.numpy as jnp

from compile.kernels.gf2_decode import gf2_decode_planes
from compile.kernels.ref import sliding_windows


def decode_matvec(
    encoded_bits,
    m_t,
    corr,
    invert,
    mask,
    x,
    scale,
    *,
    n_s: int,
    rows: int,
    cols: int,
):
    """Decode an INT8 layer and compute ``y = x · Wᵀ`` (Algorithm 2)."""
    n = rows * cols
    n_planes, stream_len, _ = encoded_bits.shape
    l = stream_len - n_s
    n_out = m_t.shape[1]

    windows = sliding_windows(encoded_bits, n_s, l)
    corr3 = corr.reshape(n_planes, l, n_out)
    signed = gf2_decode_planes(windows, m_t, corr3, invert)
    w = (signed.reshape(-1)[:n] * scale * mask).reshape(rows, cols)
    return (x @ w.T,)


def decode_weights(
    encoded_bits, m_t, corr, invert, mask, scale, *, n_s, rows, cols
):
    """Decode-only variant (returns the dense weight matrix)."""
    n = rows * cols
    n_planes, stream_len, _ = encoded_bits.shape
    l = stream_len - n_s
    n_out = m_t.shape[1]
    windows = sliding_windows(encoded_bits, n_s, l)
    corr3 = corr.reshape(n_planes, l, n_out)
    signed = gf2_decode_planes(windows, m_t, corr3, invert)
    return ((signed.reshape(-1)[:n] * scale * mask).reshape(rows, cols),)

"""L1 Pallas kernel: fused GF(2) decode + correct + invert + accumulate.

The hot spot of Algorithm 2 is reconstructing weight bits from encoded
vectors: for every plane, ``bits = (windows @ M⊕ᵀ) mod 2``. We fuse the
8 INT8 bit-planes into one kernel that also applies the lossless
correction stream (XOR), the inverting flags (XOR), and the
two's-complement accumulation — one kernel invocation turns encoded
streams into dequantized (pre-mask) weight values.

XOR on {0,1} floats is ``(a + b) mod 2``, exact in f32.

TPU mapping (DESIGN.md §3): the matmul is ``[TL, K] @ [K, n_out]`` with
``K ≤ 24``, ``n_out ≤ 96`` — one MXU tile; we tile the long ``l``
dimension into VMEM blocks of ``block_l`` rows via BlockSpec, the
analogue of the paper's "stream blocks through a fixed XOR array". The
grid is 1-D over ``l`` tiles; planes ride in a leading block dimension.
``interpret=True`` is mandatory on CPU (real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile over the block-stream dimension. 256 rows × (24 in + 2·96 out)
# per plane in f32 ≈ 1.6 MB total ≪ 16 MB VMEM; double-bufferable.
DEFAULT_BLOCK_L = 256


def _decode_acc_kernel(
    win_ref, m_ref, corr_ref, inv_ref, out_ref, *, n_planes: int
):
    """One tile: decode all planes, fix errors, accumulate the byte.

    win_ref:  [n_planes, TL, K]     decoder input windows per plane
    m_ref:    [K, n_out]            M⊕ transpose (shared by planes)
    corr_ref: [n_planes, TL, n_out] correction bits (1 = flip)
    inv_ref:  [n_planes, 1]         inverting flags
    out_ref:  [TL, n_out]           accumulated signed byte value
    """
    m = m_ref[...]
    acc = jnp.zeros(out_ref.shape, dtype=jnp.float32)
    for k in range(n_planes):
        raw = jnp.mod(win_ref[k] @ m, 2.0)
        fixed = jnp.mod(raw + corr_ref[k] + inv_ref[k, 0], 2.0)
        weight = -128.0 if k == 0 else 2.0 ** (7 - k)
        acc = acc + fixed * weight
    out_ref[...] = acc


def gf2_decode_planes(
    windows, m_t, corr, invert, block_l: int = DEFAULT_BLOCK_L
):
    """Decode 8 planes losslessly and accumulate to signed byte values.

    windows: [8, l, K] float 0/1 — decoder inputs per plane
    m_t:     [K, n_out] float 0/1
    corr:    [8, l, n_out] float 0/1 — correction bits per plane
    invert:  [8] float 0/1 — per-plane inverting flags
    Returns  [l, n_out] float — signed two's-complement value of each
             decoded byte position (−128 … 127), before mask/scale.
    """
    n_planes, l, k_dim = windows.shape
    n_out = m_t.shape[1]
    assert m_t.shape[0] == k_dim
    assert corr.shape == (n_planes, l, n_out)
    block_l = min(block_l, l)
    grid = (pl.cdiv(l, block_l),)

    return pl.pallas_call(
        functools.partial(_decode_acc_kernel, n_planes=n_planes),
        out_shape=jax.ShapeDtypeStruct((l, n_out), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_planes, block_l, k_dim), lambda i: (0, i, 0)),
            pl.BlockSpec((k_dim, n_out), lambda i: (0, 0)),
            pl.BlockSpec((n_planes, block_l, n_out), lambda i: (0, i, 0)),
            pl.BlockSpec((n_planes, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_l, n_out), lambda i: (i, 0)),
        interpret=True,  # CPU correctness path; Mosaic on real TPUs.
    )(windows, m_t, corr, invert.reshape(n_planes, 1))


def gf2_decode_single(windows, m_t, block_l: int = DEFAULT_BLOCK_L):
    """Single-plane GF(2) decode: ``(windows @ m_t) mod 2``.

    windows: [l, K]; returns [l, n_out] float 0/1. Used by the kernel
    unit tests and by FP32 flows that need raw plane bits.
    """
    l, k_dim = windows.shape
    n_out = m_t.shape[1]
    block_l = min(block_l, l)

    def kernel(win_ref, m_ref, out_ref):
        out_ref[...] = jnp.mod(win_ref[...] @ m_ref[...], 2.0)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((l, n_out), jnp.float32),
        grid=(pl.cdiv(l, block_l),),
        in_specs=[
            pl.BlockSpec((block_l, k_dim), lambda i: (i, 0)),
            pl.BlockSpec((k_dim, n_out), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_l, n_out), lambda i: (i, 0)),
        interpret=True,
    )(windows, m_t)

"""Pure-jnp reference (oracle) for the GF(2) sequential decode.

This is the ground truth the Pallas kernel (`gf2_decode.py`) and the Rust
decoder are validated against. Everything is float 0/1 arithmetic: a
GF(2) mat-vec is an ordinary matmul followed by `mod 2`, which is exact
in f32 for the paper's sizes (row sums ≤ (N_s+1)·N_in ≤ 24 ≪ 2^24).
"""

import jax.numpy as jnp


def sliding_windows(bits, n_s: int, l: int):
    """Build the decoder input windows from an unpacked bit stream.

    bits: [..., l + n_s, n_in] float 0/1 — encoded vectors, stream index
          ascending in time; the first ``n_s`` entries are the shift
          register preload (zeros when produced by the Rust encoder).
    Returns [..., l, (n_s+1)·n_in] where window ``t`` is the concat
    ``(w_t, w_{t-1}, …, w_{t-n_s})`` — slot 0 (current input) first,
    matching the column layout of the Rust ``M⊕``.
    """
    slots = [bits[..., n_s - s : n_s - s + l, :] for s in range(n_s + 1)]
    return jnp.concatenate(slots, axis=-1)


def gf2_decode_ref(windows, m_t):
    """GF(2) decode: ``(windows @ m_t) mod 2``.

    windows: [..., l, K] float 0/1 with K = (n_s+1)·n_in
    m_t:     [K, n_out] float 0/1 — transpose of the Rust row-major M⊕
             (``m_t[j, i] = M[i][j]``).
    Returns [..., l, n_out] float 0/1.
    """
    return jnp.mod(windows @ m_t, 2.0)


def decode_plane_ref(bits, m_t, n_s: int, n_bits: int):
    """Decode one plane end-to-end: windows → GF(2) matmul → flat bits.

    bits: [l + n_s, n_in]; returns [n_bits] (tail padding dropped).
    """
    l = bits.shape[0] - n_s
    out = gf2_decode_ref(sliding_windows(bits, n_s, l), m_t)
    return out.reshape(-1)[:n_bits]


def decode_matvec_ref(
    encoded_bits, m_t, corr, invert, mask, x, scale, n_s: int
):
    """Full INT8 decode + masked matvec — the L2 model's oracle.

    encoded_bits: [8, l + n_s, n_in] — one stream per bit-plane, MSB
                  (sign) plane first.
    m_t:          [K, n_out]
    corr:         [8, n]   correction bits to XOR into decoded planes
    invert:       [8]      per-plane inverting flags (0/1)
    mask:         [n]      1 = unpruned
    x:            [batch, cols]
    scale:        []       INT8 dequantization scale
    Returns [batch, rows] with rows·cols = n.
    """
    n = mask.shape[0]
    batch, cols = x.shape
    rows = n // cols
    l = encoded_bits.shape[1] - n_s

    planes = gf2_decode_ref(
        sliding_windows(encoded_bits, n_s, l), m_t
    ).reshape(8, -1)[:, :n]
    # Lossless correction then optional un-invert: XOR as (a + b) mod 2.
    planes = jnp.mod(planes + corr, 2.0)
    planes = jnp.mod(planes + invert[:, None], 2.0)
    # Two's complement: w = −128·b0 + Σ_{k≥1} 2^(7−k)·b_k.
    weights_q = -128.0 * planes[0]
    for k in range(1, 8):
        weights_q = weights_q + planes[k] * (2.0 ** (7 - k))
    w = (weights_q * scale * mask).reshape(rows, cols)
    return x @ w.T

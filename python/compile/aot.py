"""AOT lowering: JAX model → HLO text artifacts for the Rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts (default serving config — a 256×512 signed-INT8 layer at
S = 0.9, N_in = 8 → N_out = 80, N_s = 2):

  artifacts/decode_matvec_b{1,8,32}.hlo.txt   one per batch size
  artifacts/decode_weights.hlo.txt            decode-only graph
  artifacts/manifest.txt                      shapes for the Rust side
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import decode_matvec, decode_weights

# Default serving geometry — keep in sync with rust examples
# (examples/serve_compressed.rs reads manifest.txt).
ROWS, COLS = 256, 512
N_IN, N_OUT, N_S = 8, 80, 2
N_PLANES = 8
BATCHES = (1, 8, 32)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shapes(batch: int):
    n = ROWS * COLS
    l = -(-n // N_OUT)  # ceil
    k = (N_S + 1) * N_IN
    return {
        "encoded_bits": (N_PLANES, l + N_S, N_IN),
        "m_t": (k, N_OUT),
        "corr": (N_PLANES, l * N_OUT),
        "invert": (N_PLANES,),
        "mask": (n,),
        "x": (batch, COLS),
        "scale": (),
    }


def lower_matvec(batch: int) -> str:
    sh = shapes(batch)
    f = functools.partial(
        decode_matvec, n_s=N_S, rows=ROWS, cols=COLS
    )
    specs = [
        jax.ShapeDtypeStruct(sh[name], jnp.float32)
        for name in [
            "encoded_bits", "m_t", "corr", "invert", "mask", "x", "scale",
        ]
    ]
    return to_hlo_text(jax.jit(f).lower(*specs))


def lower_weights() -> str:
    sh = shapes(1)
    f = functools.partial(
        decode_weights, n_s=N_S, rows=ROWS, cols=COLS
    )
    specs = [
        jax.ShapeDtypeStruct(sh[name], jnp.float32)
        for name in ["encoded_bits", "m_t", "corr", "invert", "mask", "scale"]
    ]
    return to_hlo_text(jax.jit(f).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = [
        f"rows={ROWS}",
        f"cols={COLS}",
        f"n_in={N_IN}",
        f"n_out={N_OUT}",
        f"n_s={N_S}",
        f"n_planes={N_PLANES}",
        f"batches={','.join(str(b) for b in BATCHES)}",
    ]
    for b in BATCHES:
        text = lower_matvec(b)
        path = os.path.join(args.out, f"decode_matvec_b{b}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    text = lower_weights()
    path = os.path.join(args.out, "decode_weights.hlo.txt")
    with open(path, "w") as fh:
        fh.write(text)
    print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.txt"), "w") as fh:
        fh.write("\n".join(manifest) + "\n")
    print("wrote manifest.txt")


if __name__ == "__main__":
    main()

//! Integration: PJRT runtime executing the AOT artifacts.
//!
//! Requires `make artifacts`; tests skip (pass trivially with a notice)
//! when the artifacts directory is absent so `cargo test` works in a
//! fresh checkout.

use f2f::decoder::SequentialDecoder;
use f2f::models::{quantize_i8, LayerSpec, SyntheticLayer, WeightGen};
use f2f::pipeline::{CompressionConfig, Compressor};
use f2f::pruning::PruneMethod;
use f2f::runtime::{Input, Runtime};
use f2f::sparse::DecodedLayer;
use std::path::{Path, PathBuf};

const ROWS: usize = 256;
const COLS: usize = 512;
const N_S: usize = 2;
const N_OUT: usize = 80;

fn artifacts() -> Option<PathBuf> {
    if !f2f::runtime::pjrt_available() {
        eprintln!("built without `pjrt` — skipping PJRT integration test");
        return None;
    }
    // Tests run from the crate root.
    let dir = Path::new("artifacts");
    if dir.join("decode_matvec_b1.hlo.txt").exists() {
        Some(dir.to_path_buf())
    } else {
        eprintln!("artifacts/ not built — skipping PJRT integration test");
        None
    }
}

#[test]
fn pjrt_decode_matvec_matches_native() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let model = rt
        .load_hlo_text(&dir.join("decode_matvec_b1.hlo.txt"))
        .expect("load artifact");

    // Compress the same geometry the artifact was lowered for.
    let spec = LayerSpec { name: "rt".into(), rows: ROWS, cols: COLS };
    let layer = SyntheticLayer::generate(&spec, WeightGen::default(), 3);
    let (q, scale) = quantize_i8(&layer.weights);
    let cfg = CompressionConfig {
        sparsity: 0.9,
        n_s: N_S,
        method: PruneMethod::Magnitude,
        beam: Some(8),
        ..Default::default()
    };
    let (cl, _) =
        Compressor::new(cfg).compress_i8("rt", ROWS, COLS, &q, scale);

    // Marshal inputs (mirrors examples/serve_compressed.rs).
    let n = ROWS * COLS;
    let l = cl.spec.num_blocks(n);
    let stream = l + N_S;
    let mut encoded_bits = vec![0f32; 8 * stream * 8];
    let mut corr = vec![0f32; 8 * l * N_OUT];
    let mut invert = vec![0f32; 8];
    for (p, plane) in cl.planes.iter().enumerate() {
        for (t, &chunk) in plane.encoded.iter().enumerate() {
            for b in 0..8 {
                encoded_bits[(p * stream + t) * 8 + b] =
                    ((chunk >> b) & 1) as f32;
            }
        }
        for pos in plane.correction.positions() {
            corr[p * l * N_OUT + pos] = 1.0;
        }
        invert[p] = plane.inverted as u8 as f32;
    }
    let dec = SequentialDecoder::random(cl.spec, cl.m_seed);
    let k = cl.spec.total_inputs();
    let mut m_t = vec![0f32; k * N_OUT];
    for j in 0..k {
        for i in 0..N_OUT {
            if dec.matrix().get(i, j) {
                m_t[j * N_OUT + i] = 1.0;
            }
        }
    }
    let mask: Vec<f32> =
        (0..n).map(|i| cl.mask.get(i) as u8 as f32).collect();
    let x: Vec<f32> = (0..COLS).map(|i| (i as f32 * 0.017).cos()).collect();

    let out = model
        .run(&[
            Input::F32(&encoded_bits, &[8, stream as i64, 8]),
            Input::F32(&m_t, &[k as i64, N_OUT as i64]),
            Input::F32(&corr, &[8, (l * N_OUT) as i64]),
            Input::F32(&invert, &[8]),
            Input::F32(&mask, &[n as i64]),
            Input::F32(&x, &[1, COLS as i64]),
            Input::F32(&[cl.scale], &[]),
        ])
        .expect("execute");
    let y = &out[0];
    assert_eq!(y.len(), ROWS);

    let native = DecodedLayer::from_compressed(&cl);
    let want = native.gemv(&x);
    for (i, (a, b)) in y.iter().zip(&want).enumerate() {
        assert!(
            (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
            "row {i}: PJRT {a} vs native {b}"
        );
    }
}

#[test]
fn pjrt_decode_weights_is_lossless() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let model = rt
        .load_hlo_text(&dir.join("decode_weights.hlo.txt"))
        .expect("load artifact");

    let spec = LayerSpec { name: "rtw".into(), rows: ROWS, cols: COLS };
    let layer = SyntheticLayer::generate(&spec, WeightGen::default(), 8);
    let (q, scale) = quantize_i8(&layer.weights);
    let cfg = CompressionConfig {
        sparsity: 0.9,
        n_s: N_S,
        beam: Some(8),
        ..Default::default()
    };
    let (cl, _) =
        Compressor::new(cfg).compress_i8("rtw", ROWS, COLS, &q, scale);

    let n = ROWS * COLS;
    let l = cl.spec.num_blocks(n);
    let stream = l + N_S;
    let mut encoded_bits = vec![0f32; 8 * stream * 8];
    let mut corr = vec![0f32; 8 * l * N_OUT];
    let mut invert = vec![0f32; 8];
    for (p, plane) in cl.planes.iter().enumerate() {
        for (t, &chunk) in plane.encoded.iter().enumerate() {
            for b in 0..8 {
                encoded_bits[(p * stream + t) * 8 + b] =
                    ((chunk >> b) & 1) as f32;
            }
        }
        for pos in plane.correction.positions() {
            corr[p * l * N_OUT + pos] = 1.0;
        }
        invert[p] = plane.inverted as u8 as f32;
    }
    let dec = SequentialDecoder::random(cl.spec, cl.m_seed);
    let k = cl.spec.total_inputs();
    let mut m_t = vec![0f32; k * N_OUT];
    for j in 0..k {
        for i in 0..N_OUT {
            if dec.matrix().get(i, j) {
                m_t[j * N_OUT + i] = 1.0;
            }
        }
    }
    let mask: Vec<f32> =
        (0..n).map(|i| cl.mask.get(i) as u8 as f32).collect();

    let out = model
        .run(&[
            Input::F32(&encoded_bits, &[8, stream as i64, 8]),
            Input::F32(&m_t, &[k as i64, N_OUT as i64]),
            Input::F32(&corr, &[8, (l * N_OUT) as i64]),
            Input::F32(&invert, &[8]),
            Input::F32(&mask, &[n as i64]),
            Input::F32(&[cl.scale], &[]),
        ])
        .expect("execute");
    let w = &out[0];
    assert_eq!(w.len(), n);
    // Lossless: every unpruned weight equals the quantized original.
    for i in 0..n {
        let want = if cl.mask.get(i) { q[i] as f32 * scale } else { 0.0 };
        assert!(
            (w[i] - want).abs() <= 1e-5 * (1.0 + want.abs()),
            "weight {i}: PJRT {} vs {}",
            w[i],
            want
        );
    }
}

//! Container format compatibility: the v1 (`F2F1`) path must stay
//! bit-exact through the versioned reader while v2 (`F2F2`) lands, and
//! the two layouts must decode identically.

use f2f::container::{
    read_container, read_layer_at, write_container, write_container_v2,
    Container, ContainerIndex,
};
use f2f::models::{quantize_i8, LayerSpec, SyntheticLayer, WeightGen};
use f2f::pipeline::{CompressionConfig, Compressor};
use f2f::sparse::DecodedLayer;

/// A real 3-layer compressed model (mixed dtypes).
fn compressed_model(seed: u64) -> Container {
    let comp = Compressor::new(CompressionConfig {
        sparsity: 0.8,
        n_s: 1,
        beam: Some(8),
        ..Default::default()
    });
    let mut c = Container::default();
    for (i, (rows, cols)) in
        [(8usize, 40usize), (6, 32), (4, 24)].iter().enumerate()
    {
        let name = format!("l{i}");
        let spec =
            LayerSpec { name: name.clone(), rows: *rows, cols: *cols };
        let layer = SyntheticLayer::generate(
            &spec,
            WeightGen::default(),
            seed + i as u64,
        );
        if i == 0 {
            let (cl, _) =
                comp.compress_f32(&name, *rows, *cols, &layer.weights);
            c.layers.push(cl);
        } else {
            let (q, scale) = quantize_i8(&layer.weights);
            let (cl, _) =
                comp.compress_i8(&name, *rows, *cols, &q, scale);
            c.layers.push(cl);
        }
    }
    c
}

fn decoded_bits(c: &Container) -> Vec<Vec<u32>> {
    c.layers
        .iter()
        .map(|l| {
            DecodedLayer::from_compressed(l)
                .weights
                .iter()
                .map(|w| w.to_bits())
                .collect()
        })
        .collect()
}

#[test]
fn v1_reads_bit_exact_through_versioned_reader() {
    // The satellite guarantee: a container written by the *existing v1
    // writer* read through the new version-dispatching reader decodes to
    // bit-identical weights.
    let c = compressed_model(1);
    let want = decoded_bits(&c);
    let v1_bytes = write_container(&c);
    let back = read_container(&v1_bytes).expect("v1 must stay readable");
    assert_eq!(decoded_bits(&back), want);
}

#[test]
fn v2_decodes_identically_to_v1() {
    let c = compressed_model(2);
    let v1 = read_container(&write_container(&c)).unwrap();
    let v2 = read_container(&write_container_v2(&c)).unwrap();
    assert_eq!(decoded_bits(&v1), decoded_bits(&v2));
}

#[test]
fn v2_random_access_matches_full_parse() {
    let c = compressed_model(3);
    let bytes = write_container_v2(&c);
    let index = ContainerIndex::parse(&bytes).unwrap();
    // Read layers back to front — order independence is the point.
    for name in ["l2", "l0", "l1"] {
        let entry = index.find(name).expect("indexed");
        let layer = read_layer_at(&bytes, entry).unwrap();
        let full = read_container(&bytes).unwrap();
        let want = full
            .layers
            .iter()
            .find(|l| l.name == name)
            .expect("present");
        assert_eq!(
            DecodedLayer::from_compressed(&layer).weights,
            DecodedLayer::from_compressed(want).weights
        );
    }
}

#[test]
fn v2_header_corruption_fails_loudly() {
    let c = compressed_model(4);
    let bytes = write_container_v2(&c);
    // Magic / version / count flips must never parse as a valid model
    // with the same inventory.
    for i in 0..12 {
        let mut b = bytes.clone();
        b[i] ^= 0xFF;
        if let Ok(parsed) = read_container(&b) {
            assert!(
                parsed.layers.len() != c.layers.len()
                    || parsed.layers[0].name != c.layers[0].name,
                "flip at byte {i} silently accepted"
            );
        }
    }
}

#[test]
fn v2_every_truncation_point_fails_cleanly() {
    let c = compressed_model(5);
    let bytes = write_container_v2(&c);
    for cut in (0..bytes.len()).step_by(7) {
        assert!(
            read_container(&bytes[..cut]).is_err(),
            "truncation at {cut} parsed"
        );
    }
    assert!(read_container(&bytes[..bytes.len() - 1]).is_err());
}

//! Failure injection: corrupted containers, truncated streams, bad
//! geometry — the system must fail loudly, never decode garbage
//! silently.

use f2f::container::{read_container, write_container, Container};
use f2f::models::{quantize_i8, LayerSpec, SyntheticLayer, WeightGen};
use f2f::pipeline::{CompressionConfig, Compressor};
use f2f::rng::Rng;
use f2f::sparse::DecodedLayer;

fn sample() -> Container {
    let layer = SyntheticLayer::generate(
        &LayerSpec { name: "fi".into(), rows: 8, cols: 64 },
        WeightGen::default(),
        1,
    );
    let (q, scale) = quantize_i8(&layer.weights);
    let (cl, _) = Compressor::new(CompressionConfig {
        sparsity: 0.8,
        n_s: 1,
        ..Default::default()
    })
    .compress_i8("fi", 8, 64, &q, scale);
    Container { layers: vec![cl] }
}

#[test]
fn bitflips_in_header_are_rejected_or_changed() {
    // Flipping early header bytes must produce a parse error (magic,
    // version, counts) — never a silently different model.
    let bytes = write_container(&sample());
    for i in 0..12 {
        let mut b = bytes.clone();
        b[i] ^= 0xFF;
        // Either the parse fails, or (for name bytes) the layer name
        // differs — the payload may never silently change.
        if let Ok(c) = read_container(&b) {
            let orig = sample();
            assert!(
                c.layers[0].name != orig.layers[0].name
                    || c.layers.len() != orig.layers.len(),
                "flip at byte {i} silently accepted"
            );
        }
    }
}

#[test]
fn every_truncation_point_fails_cleanly() {
    let bytes = write_container(&sample());
    // Exhaustive truncation scan: no panic, always Err.
    for cut in 0..bytes.len() {
        assert!(
            read_container(&bytes[..cut]).is_err(),
            "truncation at {cut} parsed"
        );
    }
}

#[test]
fn mask_corruption_changes_decoded_weights_only_at_masked_positions() {
    // Decoding is mask-gated: flipping a mask bit must only affect that
    // weight.
    let c = sample();
    let layer = &c.layers[0];
    let base = DecodedLayer::from_compressed(layer);
    let mut corrupted = layer.clone();
    // Flip mask bit 5.
    let was = corrupted.mask.get(5);
    corrupted.mask.set(5, !was);
    let out = DecodedLayer::from_compressed(&corrupted);
    for i in 0..base.weights.len() {
        if i == 5 {
            continue;
        }
        assert_eq!(base.weights[i], out.weights[i], "weight {i} moved");
    }
}

#[test]
fn stream_corruption_is_repaired_only_where_correction_says() {
    // Flipping one encoded chunk corrupts a window of blocks; the
    // correction stream was built for the *original* stream, so decode
    // must now mismatch — proving corrections are position-exact, not
    // error-correcting magic.
    let c = sample();
    let layer = &c.layers[0];
    let base = DecodedLayer::from_compressed(layer);
    let mut corrupted = layer.clone();
    corrupted.planes[0].encoded[3] ^= 0x7;
    let out = DecodedLayer::from_compressed(&corrupted);
    assert_ne!(
        base.weights, out.weights,
        "corrupting the stream must change the decode"
    );
}

#[test]
fn zero_weight_layer_compresses_and_roundtrips() {
    let q = vec![0i8; 256];
    let (cl, rep) = Compressor::new(CompressionConfig {
        sparsity: 0.5,
        n_s: 1,
        ..Default::default()
    })
    .compress_i8("z", 4, 64, &q, 1.0);
    // All-zero planes are trivially encodable.
    assert!(rep.efficiency > 99.9);
    let out = DecodedLayer::from_compressed(&cl);
    assert!(out.weights.iter().all(|&w| w == 0.0));
}

#[test]
fn one_by_one_layer_works() {
    // Degenerate geometry: single weight.
    let mut rng = Rng::new(2);
    let q = vec![(rng.below(200) as i16 - 100) as i8; 1];
    let (cl, _) = Compressor::new(CompressionConfig {
        sparsity: 0.0,
        n_s: 2,
        ..Default::default()
    })
    .compress_i8("tiny", 1, 1, &q, 0.5);
    let out = DecodedLayer::from_compressed(&cl);
    assert_eq!(out.weights[0], q[0] as f32 * 0.5);
}

#[test]
fn f32_nan_and_inf_weights_roundtrip_bit_exact() {
    // Bit-plane coding is value-agnostic: NaN/Inf payloads must survive.
    let w = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1.0e-40];
    let (cl, _) = Compressor::new(CompressionConfig {
        sparsity: 0.0,
        n_s: 0,
        ..Default::default()
    })
    .compress_f32("weird", 1, 4, &w);
    let out = DecodedLayer::from_compressed(&cl);
    for i in 0..4 {
        if cl.mask.get(i) {
            assert_eq!(out.weights[i].to_bits(), w[i].to_bits());
        }
    }
}

//! The live operations plane end to end, against real worker
//! processes: polling the stats socket mid-serve returns *merged*
//! per-worker histograms without pausing traffic (outputs stay
//! bit-exact vs an unpolled run), and killing a worker mid-traffic
//! leaves a postmortem artifact — the dead worker's flight-recorded
//! spans plus an exit-cause event in the journal — while the serve
//! completes with zero failed requests after the revive.
#![cfg(unix)]

use f2f::container::{
    split_container, write_container_v2, ContainerIndex, ShardAssignment,
};
use f2f::coordinator::Backend;
use f2f::ipc::{ProcRouter, Supervisor, WorkerSpec};
use f2f::models::{compressed_mlp, MlpConfig};
use f2f::obs::stats::{field, poll_stats, LiveSources, StatsServer, StatsSnapshot};
use f2f::store::{ModelBackend, ModelStore, ReadaheadPolicy, StoreConfig};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

const DIMS: [usize; 5] = [32, 24, 16, 12, 8];

fn model_bytes(seed: u64) -> Vec<u8> {
    let (c, _) = compressed_mlp(&MlpConfig {
        seed,
        sparsity: 0.75,
        ..MlpConfig::new(&DIMS)
    });
    write_container_v2(&c)
}

fn probes(n: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| {
            (0..DIMS[0])
                .map(|j| ((i * j) as f32 * 0.1).sin())
                .collect()
        })
        .collect()
}

fn single_store_outputs(bytes: &[u8], xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let store = Arc::new(
        ModelStore::open_bytes(bytes.to_vec(), StoreConfig::default())
            .unwrap(),
    );
    ModelBackend::sequential(store)
        .unwrap()
        .forward_batch(xs)
        .unwrap()
}

/// A 2-worker deployment with the crash flight recorder enabled:
/// shard files, sockets, and flight sidecars all live in one private
/// temp dir, cleaned up on drop.
struct Deployment {
    dir: PathBuf,
    map: f2f::container::ShardMap,
    index: ContainerIndex,
    sup: Arc<Supervisor>,
}

impl Deployment {
    fn spawn(tag: &str, bytes: &[u8], n_workers: usize) -> Deployment {
        let dir = std::env::temp_dir().join(format!(
            "f2f-liveops-{tag}-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let (map, shard_bytes) =
            split_container(bytes, n_workers, ShardAssignment::ByBytes)
                .unwrap();
        let binary = PathBuf::from(env!("CARGO_BIN_EXE_f2f"));
        let mut specs = Vec::new();
        for (i, b) in shard_bytes.iter().enumerate() {
            let shard_path = dir.join(format!("shard{i}.f2f"));
            std::fs::write(&shard_path, b).unwrap();
            specs.push(
                WorkerSpec::new(
                    &binary,
                    shard_path,
                    dir.join(format!("shard{i}.sock")),
                )
                .with_flight_dir(&dir),
            );
        }
        let sup = Supervisor::spawn(specs).expect("spawn workers");
        let index = ContainerIndex::parse(bytes).unwrap();
        Deployment { dir, map, index, sup }
    }

    fn router(&self) -> ProcRouter {
        ProcRouter::new(
            self.sup.clients().to_vec(),
            &self.map,
            &self.index,
        )
        .unwrap()
        .with_supervisor(self.sup.clone())
        .with_readahead(ReadaheadPolicy::layers(1))
    }

    /// The [`LiveSources`] a multi-process serve wires up: per-worker
    /// store metrics over the wire, worker decode costs folded with
    /// the router-local GEMV costs.
    fn live_sources(
        &self,
        local_costs: Arc<f2f::store::LayerCosts>,
    ) -> LiveSources {
        let c1 = self.sup.clients().to_vec();
        let c2 = self.sup.clients().to_vec();
        LiveSources::new(
            Arc::new(move || {
                c1.iter()
                    .enumerate()
                    .filter_map(|(i, c)| {
                        c.metrics()
                            .ok()
                            .map(|m| (format!("worker {i}"), m))
                    })
                    .collect()
            }),
            Arc::new(move || {
                let mut profile = f2f::shard::CostProfile::default();
                for c in &c2 {
                    if let Ok(p) = c.cost_profile() {
                        for (name, cost) in p.entries() {
                            profile.record(&name, cost);
                        }
                    }
                }
                for (name, cost) in local_costs.snapshot() {
                    profile.record(&name, cost);
                }
                profile.entries()
            }),
        )
    }
}

impl Drop for Deployment {
    fn drop(&mut self) {
        self.sup.shutdown();
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

/// Acceptance: polling the stats socket during a 2-worker serve
/// returns merged per-worker snapshots with nonzero decode and GEMV
/// samples, and the polled serve's outputs are bit-exact vs an
/// unpolled run — polling never pauses or perturbs traffic.
#[test]
fn stats_polling_mid_serve_is_merged_and_bit_exact() {
    f2f::obs::events::set_stderr_mirror(false);
    let bytes = model_bytes(90);
    let xs = probes(6);
    let want = single_store_outputs(&bytes, &xs);
    const PASSES: usize = 3;

    // Reference run, never polled.
    let unpolled: Vec<Vec<Vec<f32>>> = {
        let dep = Deployment::spawn("quiet", &bytes, 2);
        let mut router = dep.router();
        (0..PASSES)
            .map(|_| router.forward_batch(&xs).unwrap())
            .collect()
    };
    for pass in &unpolled {
        assert_eq!(pass, &want, "reference run itself must be exact");
    }

    // Polled run: a stats server over the live deployment, hammered
    // from another thread while the same traffic flows.
    let dep = Deployment::spawn("polled", &bytes, 2);
    let mut router = dep.router();
    let local_costs = router.costs().clone();
    let live = dep.live_sources(local_costs);
    let socket = dep.dir.join("stats.sock");
    let server = StatsServer::start(&socket, live).unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let poller = {
        let stop = stop.clone();
        let socket = socket.clone();
        std::thread::spawn(move || {
            let mut polls = 0u64;
            while !stop.load(Ordering::Acquire) {
                let json =
                    poll_stats(&socket, Duration::from_secs(5))
                        .expect("mid-serve poll failed");
                StatsSnapshot::parse_json(&json)
                    .expect("mid-serve poll returned unparseable stats");
                polls += 1;
                std::thread::sleep(Duration::from_millis(5));
            }
            polls
        })
    };

    for pass in 0..PASSES {
        let got = router.forward_batch(&xs).unwrap();
        assert_eq!(
            got, unpolled[pass],
            "pass {pass}: polled serve diverged from the unpolled run"
        );
    }
    stop.store(true, Ordering::Release);
    let polls = poller.join().unwrap();
    assert!(polls > 0, "the poller never got a snapshot in");

    // The final snapshot merges both workers with live samples.
    let snap = StatsSnapshot::parse_json(
        &poll_stats(&socket, Duration::from_secs(5)).unwrap(),
    )
    .unwrap();
    assert_eq!(snap.pid, std::process::id() as u64);
    assert_eq!(snap.shards.len(), 2, "one entry per worker: {snap:?}");
    let (mut decodes, mut decode_samples) = (0.0, 0.0);
    for (name, fields) in &snap.shards {
        assert!(name.starts_with("worker "), "{name}");
        decodes += field(fields, "decodes");
        decode_samples += field(fields, "decode_samples");
    }
    assert!(decodes > 0.0, "merged decode counters must be live");
    assert!(
        decode_samples > 0.0,
        "merged decode histograms must carry samples"
    );
    assert_eq!(
        snap.layers.len(),
        DIMS.len() - 1,
        "every chain layer reports costs: {snap:?}"
    );
    for (name, fields) in &snap.layers {
        assert!(
            field(fields, "decode_samples") > 0.0,
            "{name}: worker-side decode cost missing"
        );
        assert!(
            field(fields, "gemv_samples") > 0.0,
            "{name}: router-side GEMV cost missing"
        );
    }

    drop(server);
    assert!(!socket.exists(), "stats server removes its socket");
}

/// Acceptance: SIGKILLing a worker mid-traffic produces a postmortem
/// (the worker's flight-recorded spans + attributed exit cause), a
/// `worker_exit` journal event naming the cause, and the serve
/// completes with zero failed requests once the supervisor revives it.
#[test]
fn killed_worker_leaves_postmortem_and_serve_completes_cleanly() {
    use f2f::coordinator::{InferenceServer, ServerConfig};
    f2f::obs::events::set_stderr_mirror(false);
    let bytes = model_bytes(91);
    let xs = probes(4);
    let want = single_store_outputs(&bytes, &xs);
    let dep = Deployment::spawn("kill", &bytes, 2);
    let router = dep.router();
    let server = InferenceServer::start(
        ServerConfig {
            max_batch: 4,
            batch_timeout: Duration::from_millis(1),
            ..Default::default()
        },
        move || Box::new(router),
    )
    .unwrap();

    // Warm traffic so worker 0 has decode spans on record, then give
    // its flight recorder (100 ms cadence) time to checkpoint them.
    for (i, x) in xs.iter().cloned().enumerate() {
        assert_eq!(server.infer(x).unwrap(), want[i], "warm request {i}");
    }
    std::thread::sleep(Duration::from_millis(300));

    let pid = dep.sup.worker_pid(0).expect("worker 0 alive");
    dep.sup.kill_worker(0).unwrap();

    // Traffic after the kill: the supervisor revives the worker on
    // demand and every request still succeeds, bit-exact.
    for (i, x) in xs.iter().cloned().enumerate() {
        assert_eq!(
            server.infer(x).unwrap(),
            want[i],
            "post-kill request {i} diverged"
        );
    }
    let m = server.metrics();
    assert_eq!(m.errors, 0, "zero failed requests across the kill");
    assert_eq!(m.completed, 2 * xs.len() as u64);
    server.shutdown();
    assert!(dep.sup.restarts() >= 1, "supervisor must have revived");

    // The postmortem artifact pair exists and attributes the kill.
    let summary_path = dep.dir.join(format!("postmortem-{pid}.json"));
    let summary = std::fs::read_to_string(&summary_path)
        .expect("postmortem summary must exist after a reap");
    assert!(
        summary.contains("\"cause\": \"signal 9\""),
        "SIGKILL must be attributed: {summary}"
    );
    assert!(summary.contains(&format!("\"pid\": {pid}")), "{summary}");
    assert!(
        dep.dir
            .join(format!("postmortem-{pid}.trace.json"))
            .exists(),
        "trace fragment must ride along"
    );
    // Span recording rides the `obs` feature; with it on, the flight
    // checkpoint must have captured the worker's serving spans.
    #[cfg(feature = "obs")]
    {
        let spans: u64 = summary
            .lines()
            .find_map(|l| {
                l.trim()
                    .strip_prefix("\"spans\": ")?
                    .trim_end_matches(',')
                    .parse()
                    .ok()
            })
            .expect("summary carries a spans count");
        assert!(
            spans >= 1,
            "postmortem must carry the dead worker's spans: {summary}"
        );
    }

    // The journal records the exit with its attributed cause.
    let exit_line = f2f::obs::events::recent(4096)
        .into_iter()
        .find(|l| {
            l.contains("\"kind\":\"worker_exit\"")
                && l.contains("signal 9")
                && l.contains(&format!("\"pid\":{pid}"))
        });
    assert!(
        exit_line.is_some(),
        "journal must carry a worker_exit event attributing signal 9"
    );
}

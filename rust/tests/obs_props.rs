//! Property tests for the observability primitives the live ops
//! plane leans on: [`f2f::obs::HdrLite`] merge algebra (commutative,
//! associative, identical to single-histogram recording), the
//! bucket-resolution quantile contract (every reported percentile is
//! within one power-of-two bucket of the exact sample), and the wire
//! `Metrics` frame's field-count-prefixed histogram encoding
//! (byte-exact round trip; short payloads zero-fill, long payloads
//! ignore extras — the mixed-version contract `f2f top` and the
//! stats socket inherit).

use f2f::obs::{HdrLite, HDR_WIRE_FIELDS};
use f2f::rng::Rng;
use std::time::Duration;

/// A pseudo-random latency sample spanning the full bucket range:
/// mostly microsecond-scale, with zeros and huge outliers mixed in.
fn sample(rng: &mut Rng) -> u64 {
    match rng.next_u64() % 8 {
        0 => 0,
        1 => rng.next_u64() % 16,                  // sub-16 ns
        2..=5 => 1_000 + rng.next_u64() % 100_000, // the body
        6 => rng.next_u64() % 10_000_000_000,      // up to 10 s
        _ => u64::MAX - rng.next_u64() % 1024,     // open-ended bucket
    }
}

fn hist_of(samples: &[u64]) -> HdrLite {
    let mut h = HdrLite::new();
    for &v in samples {
        h.record_ns(v);
    }
    h
}

#[test]
fn merge_is_commutative_and_associative() {
    for seed in 0..32u64 {
        let mut rng = Rng::new(seed);
        let n = 1 + (rng.next_u64() % 200) as usize;
        let a: Vec<u64> = (0..n).map(|_| sample(&mut rng)).collect();
        let b: Vec<u64> =
            (0..n / 2 + 1).map(|_| sample(&mut rng)).collect();
        let c: Vec<u64> = (0..3).map(|_| sample(&mut rng)).collect();
        let (ha, hb, hc) = (hist_of(&a), hist_of(&b), hist_of(&c));

        // a ⊕ b == b ⊕ a
        let mut ab = ha;
        ab.merge(&hb);
        let mut ba = hb;
        ba.merge(&ha);
        assert_eq!(ab, ba, "seed {seed}: merge must be commutative");

        // (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c)
        let mut left = ab;
        left.merge(&hc);
        let mut bc = hb;
        bc.merge(&hc);
        let mut right = ha;
        right.merge(&bc);
        assert_eq!(left, right, "seed {seed}: merge must be associative");

        // …and both equal recording every sample into one histogram —
        // the property that makes cross-shard aggregation exact.
        let mut all: Vec<u64> = a.clone();
        all.extend(&b);
        all.extend(&c);
        assert_eq!(
            left,
            hist_of(&all),
            "seed {seed}: merged == single-histogram recording"
        );
    }
}

#[test]
fn merge_with_empty_is_identity_both_ways() {
    let mut rng = Rng::new(99);
    let samples: Vec<u64> = (0..50).map(|_| sample(&mut rng)).collect();
    let h = hist_of(&samples);
    let mut left = HdrLite::new();
    left.merge(&h);
    assert_eq!(left, h);
    let mut right = h;
    right.merge(&HdrLite::new());
    assert_eq!(right, h);
}

/// Every quantile the histogram reports is within one power-of-two
/// bucket of the exact rank-order sample: `exact <= reported <=
/// 2 * exact` (equal at zero), and exact at both extremes.
#[test]
fn quantiles_are_within_one_bucket_of_exact() {
    for seed in 100..132u64 {
        let mut rng = Rng::new(seed);
        let n = 1 + (rng.next_u64() % 500) as usize;
        let mut samples: Vec<u64> =
            (0..n).map(|_| sample(&mut rng)).collect();
        let h = hist_of(&samples);
        samples.sort_unstable();

        for q in [0.0, 0.01, 0.1, 0.5, 0.9, 0.95, 0.99, 0.999, 1.0] {
            let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
            let exact = samples[rank - 1];
            let got = h.value_at(q);
            assert!(
                got >= exact,
                "seed {seed} q={q}: reported {got} below exact {exact}"
            );
            let bound = exact.saturating_mul(2).max(1);
            assert!(
                got <= bound.min(*samples.last().unwrap_or(&0)).max(exact),
                "seed {seed} q={q}: reported {got} more than one \
                 bucket above exact {exact}"
            );
        }
        assert_eq!(
            h.max(),
            Duration::from_nanos(*samples.last().unwrap()),
            "seed {seed}: max is exact"
        );
        assert_eq!(
            h.min(),
            Duration::from_nanos(samples[0]),
            "seed {seed}: min is exact"
        );
    }
}

/// The wire `Metrics` frame round-trips its histograms byte-exactly,
/// and its `u32 field_count` prefix keeps mixed-version peers talking:
/// a shorter payload (older peer) zero-fills the histogram tail, a
/// longer one (newer peer) is read ignoring the extras.
#[cfg(unix)]
mod metrics_frame {
    use super::*;
    use f2f::ipc::wire::{read_response, send_response, write_frame, Response};
    use f2f::store::StoreMetrics;
    use std::io::Cursor;

    /// Frame header length: magic + version + kind + payload_len.
    const HEADER: usize = 4 + 2 + 1 + 4;

    fn random_metrics(rng: &mut Rng) -> StoreMetrics {
        let mut decode_hist = HdrLite::new();
        let mut gemv_hist = HdrLite::new();
        for _ in 0..(rng.next_u64() % 100) {
            decode_hist.record_ns(sample(rng));
        }
        for _ in 0..(rng.next_u64() % 100) {
            gemv_hist.record_ns(sample(rng));
        }
        StoreMetrics {
            hits: rng.next_u64() % 1_000,
            misses: rng.next_u64() % 1_000,
            decodes: rng.next_u64() % 1_000,
            evictions: rng.next_u64() % 1_000,
            prefetches: rng.next_u64() % 1_000,
            redundant_decodes: rng.next_u64() % 10,
            readahead_skips: rng.next_u64() % 10,
            cached_bytes: (rng.next_u64() % (1 << 30)) as usize,
            cached_layers: (rng.next_u64() % 64) as usize,
            pinned_bytes: (rng.next_u64() % (1 << 20)) as usize,
            decode_ns_total: rng.next_u64() % (1 << 40),
            gemv_ns_total: rng.next_u64() % (1 << 40),
            decode_hist,
            gemv_hist,
        }
    }

    fn frame_of(m: StoreMetrics) -> Vec<u8> {
        let mut buf = Vec::new();
        send_response(&mut buf, &Response::Metrics(m)).unwrap();
        buf
    }

    #[test]
    fn histograms_round_trip_byte_exact() {
        for seed in 7..27u64 {
            let mut rng = Rng::new(seed);
            let m = random_metrics(&mut rng);
            let frame = frame_of(m);
            let got =
                read_response(&mut Cursor::new(&frame)).unwrap();
            let Response::Metrics(sm) = got else {
                panic!("seed {seed}: not a metrics reply")
            };
            assert_eq!(sm, m, "seed {seed}: decoded snapshot diverged");
            // Re-encoding the decoded snapshot reproduces the original
            // frame bit for bit — histograms included.
            assert_eq!(
                frame_of(sm),
                frame,
                "seed {seed}: re-encode must be byte-exact"
            );
        }
    }

    #[test]
    fn short_field_count_zero_fills_the_histograms() {
        let mut rng = Rng::new(42);
        let m = random_metrics(&mut rng);
        let frame = frame_of(m);
        let kind = frame[6];
        // Keep only the 12 scalar counters: an older peer's payload.
        let mut payload = Vec::new();
        payload.extend_from_slice(&12u32.to_le_bytes());
        payload.extend_from_slice(
            &frame[HEADER + 4..HEADER + 4 + 12 * 8],
        );
        let mut short = Vec::new();
        write_frame(&mut short, kind, &payload).unwrap();
        let got = read_response(&mut Cursor::new(&short)).unwrap();
        let Response::Metrics(sm) = got else { panic!("not metrics") };
        assert_eq!(sm.hits, m.hits);
        assert_eq!(sm.gemv_ns_total, m.gemv_ns_total);
        assert!(sm.decode_hist.is_empty(), "missing tail zero-fills");
        assert!(sm.gemv_hist.is_empty(), "missing tail zero-fills");
    }

    #[test]
    fn long_field_count_ignores_the_extras() {
        let mut rng = Rng::new(43);
        let m = random_metrics(&mut rng);
        let frame = frame_of(m);
        let kind = frame[6];
        let n_fields = (12 + 2 * HDR_WIRE_FIELDS) as u32;
        // A newer peer appends four fields this build doesn't know.
        let mut payload = Vec::new();
        payload.extend_from_slice(&(n_fields + 4).to_le_bytes());
        payload.extend_from_slice(&frame[HEADER + 4..]);
        for v in [7u64, 8, 9, 10] {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        let mut long = Vec::new();
        write_frame(&mut long, kind, &payload).unwrap();
        let got = read_response(&mut Cursor::new(&long)).unwrap();
        assert_eq!(
            got,
            Response::Metrics(m),
            "unknown trailing fields must be ignored"
        );
    }

    #[test]
    fn lying_field_count_is_rejected_before_allocation() {
        let mut rng = Rng::new(44);
        let frame = frame_of(random_metrics(&mut rng));
        let kind = frame[6];
        let mut payload = Vec::new();
        payload.extend_from_slice(&u32::MAX.to_le_bytes());
        payload.extend_from_slice(&frame[HEADER + 4..]);
        let mut lying = Vec::new();
        write_frame(&mut lying, kind, &payload).unwrap();
        assert!(
            read_response(&mut Cursor::new(&lying)).is_err(),
            "a field count past the payload is corruption"
        );
    }
}

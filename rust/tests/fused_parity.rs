//! Fused vs materialized bit-exactness, end to end.
//!
//! The fused decode→GEMV path ([`f2f::kernels::FusedLayer`]) promises
//! outputs **bit-identical** to the materialized dense path — same f32
//! accumulation order, pruned terms included as `+0.0` — on every
//! serving tier. This suite pins the contract down at three levels:
//!
//! 1. a property sweep over dtype {F32, I8} × mask density
//!    {0, ~0.1, ~0.9, 1} × widths that are not multiples of 64 (the
//!    row-padded tail words), comparing scalar, word, and fused decode
//!    of the *same* compressed layer bit for bit;
//! 2. a 2-shard in-process serve: `ShardRouter` over fused stores must
//!    match the materialized router and the single-store baseline;
//! 3. a 2-shard multi-process serve: real `f2f shard-worker` children
//!    spawned with `--decode-mode fused`, shipping bit-plane frames
//!    over the wire, routed by `ProcRouter` — same outputs again.

use f2f::container::{
    split_container, write_container_v2, CompressedLayer, Dtype,
    ShardAssignment,
};
use f2f::coordinator::Backend;
use f2f::decoder::SequentialDecoder;
use f2f::gf2::BitVecF2;
use f2f::kernels::{DecodeMode, FusedLayer, KernelKind};
use f2f::models::{
    compressed_mlp, LayerSpec, MlpConfig, SyntheticLayer, WeightGen,
};
use f2f::pipeline::{CompressionConfig, Compressor};
use f2f::shard::ShardRouter;
use f2f::sparse::{decode_plane_with, DecodedLayer};
use f2f::store::{ModelBackend, ModelStore, StoreConfig};
use std::sync::Arc;

/// Compress one synthetic layer at the given dtype and pruning rate.
fn compress(
    rows: usize,
    cols: usize,
    dtype: Dtype,
    sparsity: f64,
    seed: u64,
) -> CompressedLayer {
    let spec = LayerSpec { name: "p".into(), rows, cols };
    let layer = SyntheticLayer::generate(&spec, WeightGen::default(), seed);
    let cfg = CompressionConfig {
        sparsity,
        n_s: 0,
        seed,
        ..Default::default()
    };
    Compressor::new(cfg).compress_layer(&layer, dtype).0
}

fn decoded_planes(cl: &CompressedLayer) -> Vec<BitVecF2> {
    let dec = SequentialDecoder::random(cl.spec, cl.m_seed);
    (0..cl.planes.len())
        .map(|k| decode_plane_with(cl, &dec, k, KernelKind::Word))
        .collect()
}

fn bits_of(ws: &[f32]) -> Vec<u32> {
    ws.iter().map(|w| w.to_bits()).collect()
}

/// The property: for every dtype × mask density × odd width, the
/// scalar kernel, the word kernel, and the fused path produce the same
/// dense weights and the same GEMV output, bit for bit. Densities 0
/// and 1 are forced by overwriting the mask post-compression (the
/// encoder cannot express S = 1.0) — both paths must honor whatever
/// mask the container carries, including the degenerate ones.
#[test]
fn fused_matches_materialized_across_dtypes_densities_and_widths() {
    // (density target, sparsity to compress at, force-mask)
    enum Force {
        None,
        AllPruned,
        AllKept,
    }
    let densities: [(f64, Force); 4] = [
        (0.0, Force::AllPruned),
        (0.1, Force::None), // sparsity 0.9
        (0.9, Force::None), // sparsity 0.1
        (1.0, Force::AllKept),
    ];
    for dtype in [Dtype::F32, Dtype::I8] {
        // Widths off the 64 grid exercise the row-padded tail word;
        // 128 keeps one aligned case in the sweep.
        for (rows, cols) in [(6, 37), (4, 70), (3, 128)] {
            for (density, force) in &densities {
                let sparsity = match force {
                    Force::None => 1.0 - density,
                    _ => 0.5, // any valid rate; mask is replaced below
                };
                let seed = (rows * 1000 + cols) as u64
                    ^ ((*density * 10.0) as u64)
                    ^ dtype.bits() as u64;
                let mut cl = compress(rows, cols, dtype, sparsity, seed);
                let n = cl.n_weights();
                match force {
                    Force::None => {}
                    Force::AllPruned => cl.mask = BitVecF2::zeros(n),
                    Force::AllKept => {
                        let mut m = BitVecF2::zeros(n);
                        for i in 0..n {
                            m.set(i, true);
                        }
                        cl.mask = m;
                    }
                }
                let tag = format!(
                    "{dtype:?} {rows}x{cols} density {density}"
                );

                let scalar = DecodedLayer::from_compressed_with(
                    &cl,
                    KernelKind::Scalar,
                );
                let word = DecodedLayer::from_compressed_with(
                    &cl,
                    KernelKind::Word,
                );
                assert_eq!(
                    bits_of(&scalar.weights),
                    bits_of(&word.weights),
                    "{tag}: scalar vs word kernels"
                );

                let fused =
                    FusedLayer::from_planes(&cl, &decoded_planes(&cl))
                        .expect("well-formed layer");
                assert_eq!(
                    bits_of(&fused.to_dense().weights),
                    bits_of(&word.weights),
                    "{tag}: fused to_dense vs materialized"
                );

                // GEMV parity, including buffer reuse: the same
                // caller-owned buffer across calls (the batch-loop
                // shape `gemv_into` exists for).
                let x: Vec<f32> = (0..cols)
                    .map(|j| ((j as f32) * 0.37 + seed as f32).sin())
                    .collect();
                let want = word.gemv(&x);
                let got = fused.gemv(&x);
                assert_eq!(
                    bits_of(&got),
                    bits_of(&want),
                    "{tag}: fused gemv vs materialized"
                );
                let mut reused = vec![7.0f32; 3];
                fused.gemv_into(&x, &mut reused);
                assert_eq!(bits_of(&reused), bits_of(&want), "{tag}");
                word.gemv_into(&x, &mut reused);
                assert_eq!(bits_of(&reused), bits_of(&want), "{tag}");

                // Degenerate densities really did take effect.
                match force {
                    Force::AllPruned => assert!(
                        word.weights.iter().all(|w| *w == 0.0),
                        "{tag}: all-pruned layer must decode to zeros"
                    ),
                    Force::AllKept => assert_eq!(
                        (0..n).filter(|&i| cl.mask.get(i)).count(),
                        n,
                        "{tag}"
                    ),
                    Force::None => {}
                }
            }
        }
    }
}

/// Widths of the serving-level model: distinct sizes so by-bytes
/// 2-shard balancing is non-trivial, wide enough that `Auto` prices
/// I8 layers fused.
const DIMS: [usize; 4] = [48, 32, 16, 8];

fn model_bytes(seed: u64) -> Vec<u8> {
    let (c, _) = compressed_mlp(&MlpConfig {
        seed,
        sparsity: 0.75,
        ..MlpConfig::new(&DIMS)
    });
    write_container_v2(&c)
}

fn probes(n: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| {
            (0..DIMS[0])
                .map(|j| ((i * j) as f32 * 0.1).sin())
                .collect()
        })
        .collect()
}

fn single_store_outputs(
    bytes: &[u8],
    mode: DecodeMode,
    xs: &[Vec<f32>],
) -> Vec<Vec<f32>> {
    let store = Arc::new(
        ModelStore::open_bytes(
            bytes.to_vec(),
            StoreConfig { decode_mode: mode, ..StoreConfig::default() },
        )
        .unwrap(),
    );
    ModelBackend::sequential(store)
        .unwrap()
        .forward_batch(xs)
        .unwrap()
}

#[test]
fn two_shard_router_serves_fused_bit_exact() {
    let bytes = model_bytes(41);
    let xs = probes(5);
    let want = single_store_outputs(&bytes, DecodeMode::Materialized, &xs);
    // Single store first: every decode mode, one answer.
    for mode in [DecodeMode::Fused, DecodeMode::Auto] {
        assert_eq!(
            single_store_outputs(&bytes, mode, &xs),
            want,
            "{mode:?} single store diverged from materialized"
        );
    }
    let (map, shard_bytes) =
        split_container(&bytes, 2, ShardAssignment::ByBytes).unwrap();
    assert_eq!(shard_bytes.len(), 2);
    for mode in
        [DecodeMode::Materialized, DecodeMode::Fused, DecodeMode::Auto]
    {
        let mut router = ShardRouter::from_bytes(
            &map.to_bytes(),
            shard_bytes.clone(),
            StoreConfig { decode_mode: mode, ..StoreConfig::default() },
        )
        .unwrap();
        assert_eq!(
            router.forward_batch(&xs).unwrap(),
            want,
            "{mode:?} 2-shard router diverged from materialized"
        );
    }
}

/// The multi-process leg: real `f2f shard-worker` children spawned
/// with `--decode-mode fused` serve bit-plane frames over the wire;
/// the `ProcRouter` executes them without ever materializing dense
/// f32 — and the outputs still match the materialized tier exactly.
#[cfg(unix)]
#[test]
fn two_worker_procrouter_serves_fused_bit_exact() {
    use f2f::container::ContainerIndex;
    use f2f::ipc::{ProcRouter, Supervisor, WorkerSpec};
    use std::path::PathBuf;

    let bytes = model_bytes(42);
    let xs = probes(4);
    let want = single_store_outputs(&bytes, DecodeMode::Materialized, &xs);

    let dir = std::env::temp_dir().join(format!(
        "f2f-fused-parity-{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let (map, shard_bytes) =
        split_container(&bytes, 2, ShardAssignment::ByBytes).unwrap();
    let binary = PathBuf::from(env!("CARGO_BIN_EXE_f2f"));
    let index = ContainerIndex::parse(&bytes).unwrap();

    for mode in [DecodeMode::Materialized, DecodeMode::Fused] {
        let mut specs = Vec::new();
        for (i, b) in shard_bytes.iter().enumerate() {
            let shard_path = dir.join(format!("{mode}-shard{i}.f2f"));
            std::fs::write(&shard_path, b).unwrap();
            let mut spec = WorkerSpec::new(
                &binary,
                shard_path,
                dir.join(format!("{mode}-shard{i}.sock")),
            );
            spec.decode_mode = mode;
            specs.push(spec);
        }
        let sup = Supervisor::spawn(specs).expect("spawn workers");
        let mut router =
            ProcRouter::new(sup.clients().to_vec(), &map, &index)
                .unwrap()
                .with_supervisor(sup.clone());
        assert_eq!(
            router.forward_batch(&xs).unwrap(),
            want,
            "{mode:?} worker processes diverged from the \
             materialized single store"
        );
        // A worker restarted mid-tier replays its decode mode, so the
        // revived process serves the same representation bit-exactly.
        sup.kill_worker(0).unwrap();
        assert_eq!(
            router.forward_batch(&xs).unwrap(),
            want,
            "{mode:?} serve across a worker restart"
        );
        sup.shutdown();
    }
    let _ = std::fs::remove_dir_all(&dir);
}

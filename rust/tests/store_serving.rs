//! Integration: a whole compressed multi-layer model served end to end
//! through container v2 + `ModelStore` + `ModelBackend` under a decoded
//! byte budget smaller than the full model (eviction exercised), with
//! outputs matching the serially-decoded native path.

use f2f::container::{write_container_v2, Container};
use f2f::coordinator::{InferenceServer, ServerConfig};
use f2f::models::{compressed_mlp, MlpConfig};
use f2f::rng::Rng;
use f2f::sparse::DecodedLayer;
use f2f::store::{
    DecodePool, ModelBackend, ModelStore, ReadaheadPolicy, StoreConfig,
};
use std::sync::Arc;
use std::time::Duration;

/// Widths of the synthetic MLP: 4 layers, decoded total 4.5 KiB.
const DIMS: [usize; 5] = [32, 24, 16, 12, 8];

fn compressed_model(seed: u64) -> Container {
    compressed_mlp(&MlpConfig {
        seed,
        sparsity: 0.75,
        ..MlpConfig::new(&DIMS)
    })
    .0
}

fn reference_forward(c: &Container, x: &[f32]) -> Vec<f32> {
    let mut a = x.to_vec();
    for (i, l) in c.layers.iter().enumerate() {
        let dec = DecodedLayer::from_compressed(l);
        let mut y = dec.gemv(&a);
        if i + 1 < c.layers.len() {
            for v in &mut y {
                *v = v.max(0.0);
            }
        }
        a = y;
    }
    a
}

#[test]
fn whole_model_serves_under_tight_budget_with_eviction() {
    let model = compressed_model(21);
    let decoded_total: usize =
        model.layers.iter().map(|l| l.n_weights() * 4).sum();
    let bytes = write_container_v2(&model);

    // Budget: under half the decoded model — the LRU must evict while
    // every request still walks all four layers.
    let budget = decoded_total / 2;
    let store = Arc::new(
        ModelStore::open_bytes(
            bytes,
            StoreConfig {
                cache_budget_bytes: budget,
                decode_workers: 2,
                ..StoreConfig::default()
            },
        )
        .unwrap(),
    );
    assert!(store.total_decoded_bytes() == decoded_total);

    let backend = ModelBackend::sequential(store.clone()).unwrap();
    let server = InferenceServer::start(
        ServerConfig {
            max_batch: 8,
            batch_timeout: Duration::from_millis(1),
            ..Default::default()
        },
        move || Box::new(backend),
    )
    .unwrap();

    let mut rng = Rng::new(33);
    for _ in 0..12 {
        let x: Vec<f32> =
            (0..DIMS[0]).map(|_| rng.next_f32() - 0.5).collect();
        let y = server.infer(x.clone()).unwrap();
        let want = reference_forward(&model, &x);
        assert_eq!(y.len(), DIMS[DIMS.len() - 1]);
        for (a, b) in y.iter().zip(&want) {
            assert!(
                (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                "served {a} vs reference {b}"
            );
        }
    }
    let m = server.metrics();
    assert_eq!(m.completed, 12);
    assert_eq!(m.errors, 0);
    server.shutdown();

    store.wait_for_idle();
    let sm = store.metrics();
    assert!(
        sm.evictions > 0,
        "budget {budget} < decoded {decoded_total} must evict"
    );
    assert!(sm.cached_bytes <= budget, "cache respects the budget");
    assert!(sm.decodes > 4, "cold re-decodes under eviction pressure");
    assert_eq!(
        sm.redundant_decodes, 0,
        "in-flight dedup: no decode result may be discarded"
    );
    assert_eq!(sm.pinned_bytes, 0, "all pins released after serving");
}

#[test]
fn generous_budget_decodes_each_layer_once() {
    let model = compressed_model(22);
    let bytes = write_container_v2(&model);
    let store = Arc::new(
        ModelStore::open_bytes(bytes, StoreConfig::default()).unwrap(),
    );
    let backend = ModelBackend::sequential(store.clone()).unwrap();
    backend.prefetch_all().unwrap();
    assert_eq!(store.metrics().decodes, 4);

    let server = InferenceServer::start(
        ServerConfig::default(),
        move || Box::new(backend),
    )
    .unwrap();
    for i in 0..20 {
        let x = vec![0.01 * i as f32; DIMS[0]];
        server.infer(x).unwrap();
    }
    server.shutdown();
    let sm = store.metrics();
    assert_eq!(
        sm.decodes, 4,
        "prefetch + serving must never decode a layer twice"
    );
    assert_eq!(sm.evictions, 0);
    assert!(sm.hits >= 20 * 4, "every layer fetch after warmup is a hit");
}

#[test]
fn sequential_scan_thrash_is_bounded_by_readahead_pinning() {
    // The classic LRU worst case: a chain whose decoded size is one
    // layer over budget, scanned in order, evicts every layer on every
    // pass. The readahead pipeline cannot beat the capacity miss rate,
    // but in-flight dedup plus pin-while-executing must bound the work
    // at one decode per layer per pass — never decode-evict-redecode
    // churn within a pass, never a discarded decode.
    use f2f::coordinator::Backend;

    // 4 layers, 1 KiB decoded each.
    let model = compressed_mlp(&MlpConfig {
        seed: 40,
        sparsity: 0.75,
        ..MlpConfig::uniform(4, 16)
    })
    .0;
    let layers = model.layers.len();
    let layer_bytes = 16 * 16 * 4;
    let budget = layer_bytes * (layers - 1); // budget + 1 layer of model

    let store = Arc::new(ModelStore::from_container(
        model.clone(),
        StoreConfig {
            cache_budget_bytes: budget,
            decode_workers: 2,
            ..StoreConfig::default()
        },
    ));
    let mut backend = ModelBackend::sequential(store.clone())
        .unwrap()
        .with_readahead(ReadaheadPolicy::layers(1));

    let x: Vec<f32> = (0..16).map(|j| (j as f32 * 0.3).sin()).collect();
    let want = reference_forward(&model, &x);
    let passes = 5;
    for _ in 0..passes {
        let ys = backend.forward_batch(&[x.clone()]).unwrap();
        for (a, b) in ys[0].iter().zip(&want) {
            assert!(
                (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                "thrash pass diverged: {a} vs {b}"
            );
        }
    }
    store.wait_for_idle();
    let sm = store.metrics();
    // Bound: one decode per layer per pass, plus at most one wrap
    // readahead per pass that eviction wastes before the next pass
    // reaches it. Without dedup + pinning this would be up to 2x.
    assert!(
        sm.decodes as usize <= (layers + 1) * passes,
        "decodes-per-pass must stay bounded at one per layer \
         (got {} over {passes} passes of {layers} layers)",
        sm.decodes
    );
    assert_eq!(
        sm.redundant_decodes, 0,
        "readahead dedup must never discard a decode"
    );
    assert!(sm.evictions > 0, "budget+1 scan still evicts");
    assert!(sm.cached_bytes <= budget);
    assert_eq!(sm.pinned_bytes, 0);
}

#[test]
fn readahead_auto_serves_bit_exact_vs_fixed_and_off() {
    // The cost-model planner may only change *when* layers warm, never
    // what the chain computes: off / fixed depth-1 / auto must agree
    // bit for bit, pass after pass, while the auto store fills its
    // cost table and starts planning past the depth-1 fallback.
    use f2f::coordinator::Backend;

    let model = compressed_model(24);
    let bytes = write_container_v2(&model);
    let xs: Vec<Vec<f32>> = (0..6)
        .map(|i| {
            (0..DIMS[0]).map(|j| ((i * j) as f32 * 0.1).sin()).collect()
        })
        .collect();
    let mut outs = Vec::new();
    for policy in [
        ReadaheadPolicy::off(),
        ReadaheadPolicy::layers(1),
        ReadaheadPolicy::auto(),
    ] {
        let store = Arc::new(
            ModelStore::open_bytes(
                bytes.clone(),
                StoreConfig {
                    cache_budget_bytes: usize::MAX,
                    decode_workers: 2,
                    ..StoreConfig::default()
                },
            )
            .unwrap(),
        );
        let mut backend = ModelBackend::sequential(store.clone())
            .unwrap()
            .with_readahead(policy);
        let mut passes = Vec::new();
        for _ in 0..3 {
            passes.push(backend.forward_batch(&xs).unwrap());
        }
        assert!(
            passes.windows(2).all(|w| w[0] == w[1]),
            "passes must be identical under one policy"
        );
        store.wait_for_idle();
        let m = store.metrics();
        assert_eq!(m.redundant_decodes, 0);
        if policy.is_auto() {
            // The planner left telemetry behind: every layer's GEMV
            // was stamped once per pass and every decode was timed.
            assert!(m.gemv_ns_total > 0 && m.decode_ns_total > 0);
            for name in store.layer_names() {
                let c = store.costs().get(&name).unwrap();
                assert_eq!(c.gemv_samples, 3, "{name}");
                assert!(c.decode_samples >= 1, "{name}");
            }
        }
        outs.push(passes.pop().unwrap());
    }
    assert_eq!(outs[0], outs[1], "fixed depth-1 must match off");
    assert_eq!(outs[0], outs[2], "auto must match off bit for bit");
}

#[test]
fn readahead_auto_respects_tight_budgets() {
    // Auto under eviction pressure: the budget admission path (not
    // just the planner's fit check) still rules, outputs still match
    // the reference, and the cache never ends a pass over budget.
    use f2f::coordinator::Backend;

    let model = compressed_model(25);
    let decoded_total: usize =
        model.layers.iter().map(|l| l.n_weights() * 4).sum();
    let budget = decoded_total / 2;
    let store = Arc::new(
        ModelStore::open_bytes(
            write_container_v2(&model),
            StoreConfig {
                cache_budget_bytes: budget,
                decode_workers: 2,
                ..StoreConfig::default()
            },
        )
        .unwrap(),
    );
    let mut backend = ModelBackend::sequential(store.clone())
        .unwrap()
        .with_readahead(ReadaheadPolicy::auto());
    let x: Vec<f32> =
        (0..DIMS[0]).map(|j| (j as f32 * 0.2).cos()).collect();
    let want = reference_forward(&model, &x);
    for pass in 0..4 {
        let ys = backend.forward_batch(&[x.clone()]).unwrap();
        for (a, b) in ys[0].iter().zip(&want) {
            assert!(
                (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                "pass {pass}: {a} vs {b}"
            );
        }
    }
    store.wait_for_idle();
    let m = store.metrics();
    assert!(m.cached_bytes <= budget, "budget respected after passes");
    assert_eq!(m.redundant_decodes, 0);
    assert_eq!(m.pinned_bytes, 0);
}

#[test]
fn pooled_decode_equals_serial_on_served_model() {
    let model = compressed_model(23);
    let refs: Vec<&f2f::container::CompressedLayer> =
        model.layers.iter().collect();
    let pooled = DecodePool::new(4).decode_many(&refs);
    for (p, l) in pooled.iter().zip(&model.layers) {
        let s = DecodedLayer::from_compressed(l);
        assert_eq!(p.weights, s.weights, "pool diverged on {}", l.name);
    }
}

#[test]
fn store_rejects_garbage_bytes() {
    assert!(ModelStore::open_bytes(
        b"not a container".to_vec(),
        StoreConfig::default()
    )
    .is_err());
}

//! Property tests (seeded-random, proptest-style) on encoder/decoder
//! invariants across random configurations.

use f2f::correction::CorrectionStream;
use f2f::decoder::{DecoderSpec, SequentialDecoder};
use f2f::encoder::{Encoder, SlicedPlane, ViterbiEncoder};
use f2f::gf2::BitVecF2;
use f2f::rng::Rng;

/// Random small decoder spec + workload.
fn random_case(
    rng: &mut Rng,
) -> (DecoderSpec, BitVecF2, BitVecF2) {
    let n_in = 2 + rng.below(5); // 2..=6
    let n_s = rng.below(3); // 0..=2
    let n_out = n_in + 1 + rng.below(24);
    let spec = DecoderSpec::new(n_in, n_out, n_s);
    let bits = n_out * (2 + rng.below(30));
    let data = BitVecF2::random(bits, rng.next_f64() * 0.8 + 0.1, rng);
    let mask = BitVecF2::random(bits, rng.next_f64() * 0.9, rng);
    (spec, data, mask)
}

/// INVARIANT: decode(encode(x)) differs from x on exactly the reported
/// mismatch positions, and nowhere else among unpruned bits.
#[test]
fn prop_reported_mismatches_are_exact() {
    let mut rng = Rng::new(0xB0B);
    for case in 0..40 {
        let (spec, data, mask) = random_case(&mut rng);
        let dec = SequentialDecoder::random(spec, case);
        let enc = ViterbiEncoder::new(dec.clone());
        let plane = SlicedPlane::new(&data, &mask, spec.n_out);
        let res = enc.encode(&plane);

        let decoded = dec.decode_stream_to_bits(&res.encoded, data.len());
        let mut mismatch_set = res.mismatches.clone();
        mismatch_set.sort_unstable();
        let mut found = Vec::new();
        for i in 0..data.len() {
            if mask.get(i) && decoded.get(i) != data.get(i) {
                found.push(i);
            }
        }
        assert_eq!(found, mismatch_set, "case {case} ({spec:?})");
    }
}

/// INVARIANT: encode → decode → correct reproduces every unpruned bit
/// (lossless end to end), for any p that is a power of two.
#[test]
fn prop_correction_makes_roundtrip_lossless() {
    let mut rng = Rng::new(0xCAFE);
    for case in 0..40 {
        let (spec, data, mask) = random_case(&mut rng);
        let dec = SequentialDecoder::random(spec, case * 7 + 1);
        let enc = ViterbiEncoder::new(dec.clone());
        let plane = SlicedPlane::new(&data, &mask, spec.n_out);
        let res = enc.encode(&plane);

        let p = [64usize, 128, 512][rng.below(3)];
        let cs = CorrectionStream::build(&res.mismatches, data.len(), p);
        let mut decoded =
            dec.decode_stream_to_bits(&res.encoded, data.len());
        cs.apply(&mut decoded);
        for i in 0..data.len() {
            if mask.get(i) {
                assert_eq!(
                    decoded.get(i),
                    data.get(i),
                    "case {case} bit {i} ({spec:?}, p={p})"
                );
            }
        }
    }
}

/// INVARIANT: the DP error count is monotonically non-increasing in N_s
/// when the same M⊕ prefix... (strictly: a larger-N_s decoder is a
/// different code, so we assert the *statistical* version: averaged over
/// cases, higher N_s never does worse by more than noise, and wins
/// overall — the paper's §4 claim.)
#[test]
fn prop_sequential_wins_in_aggregate() {
    let mut rng = Rng::new(0xF00D);
    let mut total = [0usize; 3];
    for case in 0..15 {
        let n_out = 12 + rng.below(20);
        let bits = n_out * 24;
        let data = BitVecF2::random(bits, 0.5, &mut rng);
        let mask = BitVecF2::random(bits, 0.3, &mut rng);
        for n_s in 0..3usize {
            let spec = DecoderSpec::new(4, n_out, n_s);
            let dec = SequentialDecoder::random(spec, case);
            let plane = SlicedPlane::new(&data, &mask, n_out);
            let res = ViterbiEncoder::new(dec).encode(&plane);
            total[n_s] += res.stats.error_bits;
        }
    }
    assert!(
        total[1] < total[0],
        "N_s=1 ({}) should beat N_s=0 ({})",
        total[1],
        total[0]
    );
    assert!(
        total[2] <= total[1],
        "N_s=2 ({}) should not lose to N_s=1 ({})",
        total[2],
        total[1]
    );
}

/// INVARIANT: beam search with any width is never better than exact DP
/// (it explores a subset of the trellis), and a wide beam recovers the
/// exact optimum on these small instances.
#[test]
fn prop_beam_is_bounded_by_exact() {
    let mut rng = Rng::new(0xBEA);
    for case in 0..10 {
        let n_out = 10 + rng.below(12);
        let spec = DecoderSpec::new(4, n_out, 2);
        let bits = n_out * 20;
        let data = BitVecF2::random(bits, 0.5, &mut rng);
        let mask = BitVecF2::random(bits, 0.4, &mut rng);
        let plane = SlicedPlane::new(&data, &mask, n_out);
        let dec = SequentialDecoder::random(spec, case + 100);
        let exact = ViterbiEncoder::new(dec.clone())
            .encode(&plane)
            .stats
            .error_bits;
        for beam in [0u32, 2, 8] {
            let e = ViterbiEncoder::with_beam(dec.clone(), beam)
                .encode(&plane)
                .stats
                .error_bits;
            assert!(e >= exact, "beam {beam} beat exact: {e} < {exact}");
        }
        let wide = ViterbiEncoder::with_beam(dec, 1000)
            .encode(&plane)
            .stats
            .error_bits;
        assert_eq!(wide, exact, "case {case}: wide beam must be exact");
    }
}

/// INVARIANT: container serialization is a bijection on the wire bytes
/// (write → read → write is byte-identical).
#[test]
fn prop_container_write_read_write_fixpoint() {
    use f2f::container::{read_container, write_container};
    use f2f::models::{quantize_i8, LayerSpec, SyntheticLayer, WeightGen};
    use f2f::pipeline::{CompressionConfig, Compressor};

    let mut rng = Rng::new(0x5EED);
    for case in 0..5 {
        let rows = 4 + rng.below(8);
        let cols = 16 * (1 + rng.below(4));
        let layer = SyntheticLayer::generate(
            &LayerSpec { name: format!("c{case}"), rows, cols },
            WeightGen::default(),
            case,
        );
        let (q, scale) = quantize_i8(&layer.weights);
        let cfg = CompressionConfig {
            sparsity: [0.6, 0.8, 0.9][rng.below(3)],
            n_s: rng.below(2),
            seed: case,
            ..Default::default()
        };
        let (cl, _) = Compressor::new(cfg)
            .compress_i8(&format!("c{case}"), rows, cols, &q, scale);
        let c = f2f::container::Container { layers: vec![cl] };
        let b1 = write_container(&c);
        let c2 = read_container(&b1).unwrap();
        let b2 = write_container(&c2);
        assert_eq!(b1, b2, "case {case}");
    }
}

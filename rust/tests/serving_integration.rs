//! Integration: coordinator serving a real compressed layer end to end
//! (native backend — the PJRT path is covered by
//! `runtime_artifacts.rs` + `examples/serve_compressed.rs`).

use f2f::coordinator::{InferenceServer, NativeBackend, ServerConfig};
use f2f::models::{quantize_i8, LayerSpec, SyntheticLayer, WeightGen};
use f2f::pipeline::{CompressionConfig, Compressor};
use f2f::rng::Rng;
use f2f::sparse::DecodedLayer;
use std::time::Duration;

fn compressed_layer() -> (f2f::container::CompressedLayer, Vec<i8>, f32) {
    let spec = LayerSpec { name: "srv".into(), rows: 32, cols: 128 };
    let layer = SyntheticLayer::generate(&spec, WeightGen::default(), 5);
    let (q, scale) = quantize_i8(&layer.weights);
    let cfg = CompressionConfig {
        sparsity: 0.9,
        n_s: 1,
        ..Default::default()
    };
    let (cl, _) =
        Compressor::new(cfg).compress_i8("srv", 32, 128, &q, scale);
    (cl, q, scale)
}

#[test]
fn served_outputs_match_reference() {
    let (cl, q, scale) = compressed_layer();
    let reference = DecodedLayer::from_compressed(&cl);
    // Sanity: the reference itself must be the masked dequantized layer.
    for i in 0..q.len() {
        if cl.mask.get(i) {
            assert_eq!(reference.weights[i], q[i] as f32 * scale);
        }
    }
    let cl2 = cl.clone();
    let server = InferenceServer::start(
        ServerConfig {
            max_batch: 8,
            batch_timeout: Duration::from_millis(1),
            ..Default::default()
        },
        move || Box::new(NativeBackend::new(&cl2)),
    )
    .unwrap();
    let mut rng = Rng::new(9);
    for _ in 0..20 {
        let x: Vec<f32> =
            (0..128).map(|_| rng.next_f32() - 0.5).collect();
        let y = server.infer(x.clone()).unwrap();
        let want = reference.gemv(&x);
        assert_eq!(y.len(), 32);
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5);
        }
    }
    let m = server.metrics();
    assert_eq!(m.completed, 20);
    assert_eq!(m.errors, 0);
    server.shutdown();
}

#[test]
fn concurrent_load_is_batched_and_complete() {
    let (cl, _, _) = compressed_layer();
    let server = InferenceServer::start(
        ServerConfig {
            max_batch: 16,
            batch_timeout: Duration::from_millis(2),
            ..Default::default()
        },
        move || Box::new(NativeBackend::new(&cl)),
    )
    .unwrap();
    let n = 200;
    let handles: Vec<_> = (0..n)
        .map(|i| server.infer_async(vec![i as f32 * 0.01; 128]))
        .collect();
    for h in handles {
        h.recv().unwrap().unwrap();
    }
    let m = server.metrics();
    assert_eq!(m.completed, n as u64);
    assert!(
        (m.batches as usize) < n,
        "expected batching: {} batches for {n} requests",
        m.batches
    );
    assert!(m.p99 >= m.p50);
    server.shutdown();
}

#[test]
fn backpressure_rejects_when_queue_full() {
    // A tiny queue plus a slow backend forces rejections.
    struct Slow;
    impl f2f::coordinator::Backend for Slow {
        fn forward_batch(
            &mut self,
            xs: &[Vec<f32>],
        ) -> anyhow::Result<Vec<Vec<f32>>> {
            std::thread::sleep(Duration::from_millis(20));
            Ok(xs.iter().map(|x| vec![x[0]]).collect())
        }
        fn input_dim(&self) -> usize {
            2
        }
        fn output_dim(&self) -> usize {
            1
        }
    }
    let server = InferenceServer::start(
        ServerConfig {
            max_batch: 4,
            batch_timeout: Duration::from_millis(1),
            queue_capacity: 8,
        },
        || Box::new(Slow),
    )
    .unwrap();
    let handles: Vec<_> =
        (0..64).map(|_| server.infer_async(vec![1.0, 2.0])).collect();
    let (mut ok, mut rejected) = (0, 0);
    for h in handles {
        match h.recv().unwrap() {
            Ok(_) => ok += 1,
            Err(_) => rejected += 1,
        }
    }
    assert!(ok >= 8, "some requests must succeed (ok={ok})");
    assert!(
        rejected > 0,
        "queue of 8 must reject part of a 64-burst (ok={ok})"
    );
    server.shutdown();
}

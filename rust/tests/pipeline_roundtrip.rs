//! Integration: full pipeline losslessness across dtypes, pruning
//! methods and decoder geometries, plus Algorithm 1 ≡ Algorithm 2.

use f2f::container::{read_container, write_container, Container, Dtype};
use f2f::models::{quantize_i8, LayerSpec, SyntheticLayer, WeightGen};
use f2f::pipeline::{CompressionConfig, Compressor};
use f2f::pruning::PruneMethod;
use f2f::rng::Rng;
use f2f::sparse::{decode_gemv, CsrMatrix, DecodedLayer, DenseMatrix};

fn layer(rows: usize, cols: usize, seed: u64) -> SyntheticLayer {
    SyntheticLayer::generate(
        &LayerSpec { name: format!("L{seed}"), rows, cols },
        WeightGen::default(),
        seed,
    )
}

#[test]
fn lossless_across_configs_i8() {
    let mut case = 0u64;
    for &s in &[0.6, 0.9] {
        for n_s in [0usize, 1, 2] {
            for method in [PruneMethod::Random, PruneMethod::Magnitude] {
                case += 1;
                let l = layer(8, 64, case);
                let (q, scale) = quantize_i8(&l.weights);
                let cfg = CompressionConfig {
                    sparsity: s,
                    n_s,
                    method,
                    beam: if n_s >= 2 { Some(8) } else { None },
                    seed: case,
                    ..Default::default()
                };
                let (cl, _) = Compressor::new(cfg)
                    .compress_i8(&l.spec.name, 8, 64, &q, scale);
                let dec = DecodedLayer::from_compressed(&cl);
                for i in 0..q.len() {
                    if cl.mask.get(i) {
                        assert_eq!(
                            dec.weights[i],
                            q[i] as f32 * scale,
                            "case {case} weight {i}"
                        );
                    } else {
                        assert_eq!(dec.weights[i], 0.0);
                    }
                }
            }
        }
    }
}

#[test]
fn lossless_f32_with_inverting() {
    let l = layer(6, 64, 99);
    let cfg = CompressionConfig {
        sparsity: 0.8,
        n_s: 1,
        method: PruneMethod::Magnitude,
        invert: true,
        ..Default::default()
    };
    let (cl, rep) = Compressor::new(cfg).compress_f32(
        &l.spec.name,
        6,
        64,
        &l.weights,
    );
    // FP32 exponent planes are heavily skewed → inverting must fire on
    // at least one plane.
    assert!(
        cl.planes.iter().any(|p| p.inverted),
        "no plane inverted despite exponent skew"
    );
    assert!(rep.efficiency > 50.0);
    let dec = DecodedLayer::from_compressed(&cl);
    for i in 0..l.weights.len() {
        if cl.mask.get(i) {
            assert_eq!(dec.weights[i].to_bits(), l.weights[i].to_bits());
        }
    }
}

#[test]
fn container_file_roundtrip_multi_layer() {
    let layers = vec![layer(8, 32, 1), layer(4, 64, 2)];
    let cfg = CompressionConfig {
        sparsity: 0.7,
        n_s: 1,
        ..Default::default()
    };
    let (container, _) =
        Compressor::new(cfg).compress_model(&layers, Dtype::I8);
    let bytes = write_container(&container);
    let back: Container = read_container(&bytes).unwrap();
    assert_eq!(back.layers.len(), 2);
    for (a, b) in container.layers.iter().zip(&back.layers) {
        let da = DecodedLayer::from_compressed(a);
        let db = DecodedLayer::from_compressed(b);
        assert_eq!(da.weights, db.weights);
    }
    assert_eq!(container.compressed_bits(), back.compressed_bits());
}

/// Algorithm 1 (CSR SpMV on the pruned weights) and Algorithm 2 (decode
/// the fixed-to-fixed stream, masked GEMV) must agree.
#[test]
fn algorithm1_equals_algorithm2() {
    let l = layer(16, 96, 7);
    let (q, scale) = quantize_i8(&l.weights);
    let cfg = CompressionConfig {
        sparsity: 0.85,
        n_s: 1,
        method: PruneMethod::Magnitude,
        ..Default::default()
    };
    let (cl, _) =
        Compressor::new(cfg).compress_i8(&l.spec.name, 16, 96, &q, scale);

    // Algorithm 1 path: build the pruned dense matrix, CSR-ify.
    let pruned: Vec<f32> = (0..q.len())
        .map(|i| {
            if cl.mask.get(i) {
                q[i] as f32 * scale
            } else {
                0.0
            }
        })
        .collect();
    let dense = DenseMatrix::from_vec(16, 96, pruned);
    let csr = CsrMatrix::from_dense(&dense);

    let mut rng = Rng::new(3);
    for _ in 0..5 {
        let x: Vec<f32> =
            (0..96).map(|_| rng.next_f32() - 0.5).collect();
        let y1 = csr.spmv(&x);
        let y2 = decode_gemv(&cl, &x);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-4, "alg1 {a} vs alg2 {b}");
        }
    }
}

/// Compression ratio sanity at the flagship setting: encoded payload is
/// `N_in/N_out` of the original, end to end through the container.
#[test]
fn payload_matches_rate_rule() {
    let l = layer(16, 160, 11);
    let (q, scale) = quantize_i8(&l.weights);
    let cfg = CompressionConfig {
        sparsity: 0.9,
        n_s: 1,
        ..Default::default()
    };
    let (cl, _) =
        Compressor::new(cfg).compress_i8(&l.spec.name, 16, 160, &q, scale);
    let n_bits = 16 * 160 * 8; // total weight bits
    let payload = cl.payload_bits();
    // 8/80 of the original + (l + N_s) rounding per plane.
    let expect = n_bits / 10;
    assert!(
        payload >= expect && payload < expect + 8 * 8 * 2,
        "payload {payload} vs rate-rule {expect}"
    );
}

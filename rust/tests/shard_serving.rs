//! Sharded serving end to end: `split_container` + `ShardRouter` must
//! reproduce the single-store `ModelBackend` bit-exactly across shard
//! counts and assignment strategies, survive per-shard cache budgets
//! behind the batching `InferenceServer`, open shard files from disk
//! (mmap-backed when the feature is on), and reject corrupt shard maps
//! with errors — never panics.

use f2f::container::{
    split_container, split_with_map, write_container_v2,
    ContainerIndex, ShardAssignment, ShardMap,
};
use f2f::coordinator::{Backend, InferenceServer, ServerConfig};
use f2f::models::{compressed_mlp, MlpConfig};
use f2f::shard::{rebalance_map, CostProfile, ShardRouter};
use f2f::store::{ModelBackend, ModelStore, ReadaheadPolicy, StoreConfig};
use std::sync::Arc;
use std::time::Duration;

/// Widths of the synthetic MLP: 4 layers of distinct sizes, so
/// by-bytes balancing differs from round-robin.
const DIMS: [usize; 5] = [32, 24, 16, 12, 8];

fn model_bytes(seed: u64) -> Vec<u8> {
    let (c, _) = compressed_mlp(&MlpConfig {
        seed,
        sparsity: 0.75,
        ..MlpConfig::new(&DIMS)
    });
    write_container_v2(&c)
}

fn probes(n: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| {
            (0..DIMS[0])
                .map(|j| ((i * j) as f32 * 0.1).sin())
                .collect()
        })
        .collect()
}

fn single_store_outputs(bytes: &[u8], xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let store = Arc::new(
        ModelStore::open_bytes(bytes.to_vec(), StoreConfig::default())
            .unwrap(),
    );
    ModelBackend::sequential(store)
        .unwrap()
        .forward_batch(xs)
        .unwrap()
}

#[test]
fn sharded_round_trip_is_bit_exact_for_1_2_4_shards() {
    let bytes = model_bytes(51);
    let xs = probes(5);
    let want = single_store_outputs(&bytes, &xs);
    for n_shards in [1usize, 2, 4] {
        for strategy in
            [ShardAssignment::RoundRobin, ShardAssignment::ByBytes]
        {
            let (map, shard_bytes) =
                split_container(&bytes, n_shards, strategy).unwrap();
            assert_eq!(map.n_shards(), n_shards);
            let mut router = ShardRouter::from_bytes(
                &map.to_bytes(),
                shard_bytes,
                StoreConfig {
                    cache_budget_bytes: usize::MAX,
                    decode_workers: 2,
                    ..StoreConfig::default()
                },
            )
            .unwrap()
            .with_readahead(ReadaheadPolicy::layers(1));
            let got = router.forward_batch(&xs).unwrap();
            assert_eq!(
                got, want,
                "{n_shards} shards ({strategy:?}) must serve outputs \
                 bit-identical to the single store"
            );
            router.wait_for_idle();
            let m = router.metrics();
            assert_eq!(m.per_shard.len(), n_shards);
            assert_eq!(
                m.total.decodes,
                DIMS.len() as u64 - 1,
                "each layer decodes exactly once across all shards"
            );
            assert_eq!(m.total.redundant_decodes, 0);
            assert_eq!(m.total.pinned_bytes, 0);
        }
    }
}

#[test]
fn sharded_auto_readahead_is_bit_exact_for_1_2_4_shards() {
    // The cost-model planner on top of cross-shard readahead: off,
    // fixed depth-1 and auto must all reproduce the single-store
    // outputs bit-exactly through every shard count, across repeated
    // passes (the later ones running with a warmed cost table).
    let bytes = model_bytes(56);
    let xs = probes(5);
    let want = single_store_outputs(&bytes, &xs);
    for n_shards in [1usize, 2, 4] {
        for policy in [
            ReadaheadPolicy::off(),
            ReadaheadPolicy::layers(1),
            ReadaheadPolicy::auto(),
        ] {
            let (map, shard_bytes) =
                split_container(&bytes, n_shards, ShardAssignment::ByBytes)
                    .unwrap();
            let mut router = ShardRouter::from_bytes(
                &map.to_bytes(),
                shard_bytes,
                StoreConfig {
                    cache_budget_bytes: usize::MAX,
                    decode_workers: 2,
                    ..StoreConfig::default()
                },
            )
            .unwrap()
            .with_readahead(policy);
            for pass in 0..3 {
                assert_eq!(
                    router.forward_batch(&xs).unwrap(),
                    want,
                    "{n_shards} shards, {policy}, pass {pass}"
                );
            }
            router.wait_for_idle();
            let m = router.metrics();
            assert_eq!(m.total.redundant_decodes, 0);
            assert!(m.total.gemv_ns_total > 0);
            // The merged cost table covers the whole chain no matter
            // which shard observed each layer.
            assert_eq!(m.costs.len(), DIMS.len() - 1);
        }
    }
}

#[test]
fn rebalance_round_trips_from_observed_costs_to_serving() {
    // The full loop `f2f serve --profile-out` + `f2f rebalance`
    // automate: serve → capture a CostProfile → JSON round trip →
    // rebalance_map → sidecar validation → split_with_map → serve the
    // rebalanced shards bit-exactly.
    let bytes = model_bytes(57);
    let xs = probes(4);
    let want = single_store_outputs(&bytes, &xs);

    let store = Arc::new(
        ModelStore::open_bytes(bytes.clone(), StoreConfig::default())
            .unwrap(),
    );
    let mut backend = ModelBackend::sequential(store.clone()).unwrap();
    backend.forward_batch(&xs).unwrap();
    store.wait_for_idle();
    let profile = CostProfile::from_stores([store.costs()]);
    assert_eq!(profile.len(), DIMS.len() - 1);

    // Wire round trip, exactly what the CLI writes and reads.
    let profile = CostProfile::parse_json(&profile.to_json()).unwrap();
    let index = ContainerIndex::parse(&bytes).unwrap();
    let map = rebalance_map(&index, 2, &profile).unwrap();
    // The emitted sidecar passes the standard corruption validation...
    let map = ShardMap::parse(&map.to_bytes()).unwrap();
    assert_eq!(map.n_shards(), 2);
    // ...and both shards carry real load under the profile.
    let loads = profile.shard_loads(&map);
    assert!(loads.iter().all(|&l| l > 0.0), "no empty shard: {loads:?}");

    let shard_bytes = split_with_map(&bytes, &map).unwrap();
    let mut router = ShardRouter::from_bytes(
        &map.to_bytes(),
        shard_bytes,
        StoreConfig::default(),
    )
    .unwrap()
    .with_readahead(ReadaheadPolicy::auto());
    assert_eq!(
        router.forward_batch(&xs).unwrap(),
        want,
        "rebalanced shards must serve bit-exactly"
    );
    router.wait_for_idle();

    // A stale profile — captured from a *different* (shorter) model —
    // errors instead of panicking.
    let (small, _) = compressed_mlp(&MlpConfig {
        seed: 58,
        sparsity: 0.75,
        ..MlpConfig::new(&[32, 24, 16])
    });
    let small_bytes = write_container_v2(&small);
    let small_store = Arc::new(
        ModelStore::open_bytes(small_bytes, StoreConfig::default())
            .unwrap(),
    );
    let mut small_backend =
        ModelBackend::sequential(small_store.clone()).unwrap();
    small_backend.forward_batch(&probes(2)).unwrap();
    small_store.wait_for_idle();
    let stale = CostProfile::from_stores([small_store.costs()]);
    let err = rebalance_map(&index, 2, &stale).unwrap_err();
    assert!(
        format!("{err}").contains("stale"),
        "stale profile must be called out: {err}"
    );
}

#[test]
fn more_shards_than_layers_still_serves_exactly() {
    let bytes = model_bytes(52);
    let xs = probes(3);
    let want = single_store_outputs(&bytes, &xs);
    let (map, shard_bytes) =
        split_container(&bytes, 6, ShardAssignment::RoundRobin).unwrap();
    let mut router = ShardRouter::from_bytes(
        &map.to_bytes(),
        shard_bytes,
        StoreConfig::default(),
    )
    .unwrap();
    assert_eq!(router.forward_batch(&xs).unwrap(), want);
}

#[test]
fn sharded_server_under_tight_budgets_with_eviction() {
    let bytes = model_bytes(53);
    let want = single_store_outputs(&bytes, &probes(12));
    let (map, shard_bytes) =
        split_container(&bytes, 2, ShardAssignment::RoundRobin).unwrap();
    // Per-shard budget below each shard's decoded share: the LRUs must
    // evict while every request still walks all four layers.
    let stores: Vec<Arc<ModelStore>> = shard_bytes
        .into_iter()
        .map(|b| {
            let store = ModelStore::open_bytes(
                b,
                StoreConfig {
                    cache_budget_bytes: 2048,
                    decode_workers: 2,
                    ..StoreConfig::default()
                },
            )
            .unwrap();
            Arc::new(store)
        })
        .collect();
    let router = ShardRouter::new(stores.clone(), &map)
        .unwrap()
        .with_readahead(ReadaheadPolicy::layers(1));
    let server = InferenceServer::start(
        ServerConfig {
            max_batch: 4,
            batch_timeout: Duration::from_millis(1),
            ..Default::default()
        },
        move || Box::new(router),
    )
    .unwrap();
    for (i, x) in probes(12).into_iter().enumerate() {
        let y = server.infer(x).unwrap();
        assert_eq!(
            y, want[i],
            "request {i} diverged from the single-store reference"
        );
    }
    let m = server.metrics();
    assert_eq!(m.completed, 12);
    assert_eq!(m.errors, 0);
    server.shutdown();
    for s in &stores {
        s.wait_for_idle();
    }
    let evictions: u64 = stores.iter().map(|s| s.metrics().evictions).sum();
    let redundant: u64 =
        stores.iter().map(|s| s.metrics().redundant_decodes).sum();
    assert!(evictions > 0, "tight per-shard budgets must evict");
    assert_eq!(redundant, 0, "cross-shard readahead never double-decodes");
    for s in &stores {
        let sm = s.metrics();
        // Budget respected, modulo the store's keep-one rule (a single
        // layer bigger than the whole budget still serves).
        assert!(
            sm.cached_bytes <= 2048 || sm.cached_layers == 1,
            "per-shard budget violated: {} bytes in {} layers",
            sm.cached_bytes,
            sm.cached_layers
        );
        assert_eq!(sm.pinned_bytes, 0, "all pins released after serving");
    }
}

#[test]
fn shards_open_from_disk_and_serve() {
    let bytes = model_bytes(54);
    let xs = probes(4);
    let want = single_store_outputs(&bytes, &xs);
    let (map, shard_bytes) =
        split_container(&bytes, 2, ShardAssignment::ByBytes).unwrap();

    let dir = std::env::temp_dir();
    let tag = format!("f2f-shard-serving-{}", std::process::id());
    let map_path = dir.join(format!("{tag}.shardmap"));
    std::fs::write(&map_path, map.to_bytes()).unwrap();
    let mut shard_paths = Vec::new();
    for (i, b) in shard_bytes.iter().enumerate() {
        let p = dir.join(format!("{tag}.shard{i}.f2f"));
        std::fs::write(&p, b).unwrap();
        shard_paths.push(p);
    }

    let mut router = ShardRouter::open_paths(
        &map_path,
        &shard_paths,
        StoreConfig::default(),
    )
    .unwrap();
    #[cfg(all(unix, target_pointer_width = "64", feature = "mmap"))]
    for s in router.shards() {
        assert!(
            s.source_mapped(),
            "disk-opened shard stores must be mmap-backed"
        );
    }
    assert_eq!(router.forward_batch(&xs).unwrap(), want);
    router.wait_for_idle();
    drop(router);

    let _ = std::fs::remove_file(&map_path);
    for p in &shard_paths {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn corrupt_shard_maps_error_and_never_panic() {
    let bytes = model_bytes(55);
    let (map, shard_bytes) =
        split_container(&bytes, 2, ShardAssignment::RoundRobin).unwrap();
    let wire = map.to_bytes();

    // Truncation at every byte boundary must fail cleanly.
    for cut in 0..wire.len() {
        assert!(
            ShardMap::parse(&wire[..cut]).is_err(),
            "truncated shard map (cut {cut}) parsed"
        );
    }

    // Shard count forced to zero (offset 8..12 after magic+version).
    let mut zero = wire.clone();
    zero[8..12].copy_from_slice(&0u32.to_le_bytes());
    let err = ShardMap::parse(&zero).unwrap_err();
    assert!(format!("{err}").contains("zero shards"), "{err}");

    // First entry's shard id (after magic+version+counts and the
    // 4-byte-length-prefixed name "fc0") pointed at a missing shard.
    let id_pos = 4 + 4 + 4 + 4 + (4 + 3);
    let mut missing = wire.clone();
    missing[id_pos..id_pos + 4].copy_from_slice(&9u32.to_le_bytes());
    let err = ShardMap::parse(&missing).unwrap_err();
    assert!(format!("{err}").contains("only 2 shards exist"), "{err}");

    // A map that parses but disagrees with the opened stores is a
    // router error, not a panic: 3-shard map over 2 stores.
    let (map3, _) =
        split_container(&bytes, 3, ShardAssignment::RoundRobin).unwrap();
    assert!(ShardRouter::from_bytes(
        &map3.to_bytes(),
        shard_bytes,
        StoreConfig::default()
    )
    .is_err());

    // Byte-flip fuzz: every position forced to adversarial values must
    // parse or reject — never panic.
    for pos in 0..wire.len() {
        for val in [0x00u8, 0x01, 0x7F, 0xFF] {
            if wire[pos] == val {
                continue;
            }
            let mut corrupt = wire.clone();
            corrupt[pos] = val;
            let _ = ShardMap::parse(&corrupt);
        }
    }
}

//! Miri-targeted soundness tests for every decoder that faces bytes
//! from another process or an on-disk artifact.
//!
//! Everything here runs purely in memory — no sockets, no files, no
//! spawned threads, no clocks — so
//! `cargo +nightly miri test --test miri_soundness` finishes in
//! seconds while exercising, under the interpreter's full UB checking,
//! the exact code paths the serving stack runs on untrusted input:
//! IPC frame encode/decode ([`f2f::ipc::wire`]), the v2 container
//! index and `F2F3` shard-map parsers ([`f2f::container`]), and the
//! `CostProfile` JSON reader ([`f2f::shard`]).
//!
//! The regular test suite covers the same parsers through sockets and
//! temp files; those tests are skipped under Miri (isolation forbids
//! the syscalls), which is why this file exists.

use f2f::container::{
    is_shard_map, is_v2, write_container_v2, Container, ContainerIndex,
    ShardMap,
};
use f2f::shard::CostProfile;
use f2f::store::LayerCost;

/// The IPC wire codec only exists on unix (`std::os::unix::net`), but
/// the frame encode/decode under test is pure `Read`/`Write` over
/// in-memory buffers — Miri runs it without socket syscalls.
#[cfg(unix)]
mod wire_frames {
    use f2f::ipc::wire::{
        read_frame, read_request, read_response, send_request,
        send_response, Request, Response, WireError,
    };

    /// Encode one request into an in-memory frame (`Vec<u8>` is
    /// `Write`).
    fn request_frame(req: &Request) -> Vec<u8> {
        let mut buf = Vec::new();
        send_request(&mut buf, req).expect("encode request");
        buf
    }

    /// Encode one response into an in-memory frame.
    fn response_frame(resp: &Response) -> Vec<u8> {
        let mut buf = Vec::new();
        send_response(&mut buf, resp).expect("encode response");
        buf
    }

    #[test]
    fn every_request_variant_roundtrips_in_memory() {
        let reqs = [
            Request::Fetch { layer: "layer0".into(), trace: 7 },
            Request::Prefetch { layer: "blk.3/ffn".into(), trace: 0 },
            Request::Metrics,
            Request::CostProfile,
            Request::TraceDump,
            Request::Shutdown,
        ];
        for req in &reqs {
            let buf = request_frame(req);
            let got = read_request(&mut &buf[..]).expect("decode");
            assert_eq!(&got, req);
        }
    }

    #[test]
    fn response_variants_roundtrip_in_memory() {
        let resps = [
            Response::Layer {
                rows: 2,
                cols: 3,
                weights: vec![0.5, -1.0, 0.0, 3.25, -0.125, 2.0],
            },
            Response::Ack { accepted: true },
            Response::Ack { accepted: false },
            Response::CostProfile { json: "{\"layers\":{}}".into() },
            Response::Err {
                message: "unknown layer \"ghost\"".into(),
            },
            Response::Bye,
        ];
        for resp in &resps {
            let buf = response_frame(resp);
            let got = read_response(&mut &buf[..]).expect("decode");
            assert_eq!(&got, resp);
        }
    }

    #[test]
    fn truncated_frames_error_and_never_panic() {
        let frames = [
            request_frame(&Request::Fetch {
                layer: "w".into(),
                trace: 1,
            }),
            response_frame(&Response::Layer {
                rows: 1,
                cols: 2,
                weights: vec![1.0, 2.0],
            }),
        ];
        for buf in &frames {
            for cut in 0..buf.len() {
                let short = &buf[..cut];
                assert!(
                    read_frame(&mut &short[..]).is_err(),
                    "a {cut}-byte prefix of a {}-byte frame must \
                     not parse",
                    buf.len()
                );
            }
        }
    }

    #[test]
    fn corrupt_frame_headers_are_rejected() {
        // Header layout: magic [0..4], version u16 [4..6], kind [6],
        // payload length u32 [7..11].
        let good = request_frame(&Request::Metrics);

        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xFF;
        assert!(matches!(
            read_frame(&mut &bad_magic[..]),
            Err(WireError::Corrupt(_))
        ));

        let mut bad_version = good.clone();
        bad_version[4] ^= 0xFF;
        assert!(read_frame(&mut &bad_version[..]).is_err());

        // An unknown kind may pass the frame layer but must be
        // rejected as a request.
        let mut bad_kind = good.clone();
        bad_kind[6] = 0xEE;
        assert!(read_request(&mut &bad_kind[..]).is_err());

        // A length field claiming more payload than the stream
        // delivers is truncation, not an allocation of the claimed
        // size.
        let mut lying_len = good;
        lying_len[7] = 40;
        assert!(read_frame(&mut &lying_len[..]).is_err());
    }
}

#[test]
fn cost_profile_json_roundtrips() {
    let mut p = CostProfile::new();
    p.record(
        "blk.0",
        LayerCost {
            decode_ns: 1.5e6,
            gemv_ns: 300.0,
            decode_samples: 4,
            gemv_samples: 2,
        },
    );
    p.record(
        "blk.1",
        LayerCost {
            decode_ns: 2.25e6,
            gemv_ns: 0.0,
            decode_samples: 1,
            gemv_samples: 0,
        },
    );
    let json = p.to_json();
    let back = CostProfile::parse_json(&json).expect("parse own json");
    assert_eq!(back.len(), 2);
    let a = back.get("blk.0").expect("blk.0 present");
    assert_eq!(a.decode_samples, 4);
    assert!((a.decode_ns - 1.5e6).abs() < 1.0, "got {}", a.decode_ns);
}

#[test]
fn truncated_cost_profile_json_errors_and_never_panics() {
    let mut p = CostProfile::new();
    p.record(
        "layer \"quoted\" \\ name",
        LayerCost {
            decode_ns: 9.0e5,
            gemv_ns: 12.5,
            decode_samples: 3,
            gemv_samples: 1,
        },
    );
    let json = p.to_json();
    // Any cut before the closing brace leaves the top-level object
    // unbalanced, so every such prefix must error (and, under Miri,
    // must do so without UB). The layer name here is ASCII, so every
    // byte offset is a char boundary. Cuts inside the trailing
    // newline would be complete documents and are excluded.
    let end = json.trim_end().len();
    for cut in 0..end {
        assert!(
            CostProfile::parse_json(&json[..cut]).is_err(),
            "prefix of length {cut} must not parse"
        );
    }
    assert!(CostProfile::parse_json(&json).is_ok());
}

#[test]
fn adversarial_cost_profile_json_never_panics() {
    let cases = [
        "",
        "{",
        "}",
        "null",
        "[1,2,3]",
        "{\"layers\":}",
        "{\"layers\":{\"a\":1}}",
        "{\"layers\":{\"a\":{\"decode_ns\":\"NaN\"}}}",
        "{\"layers\":{\"a\":{\"decode_ns\":1e309}}}",
        "{\"layers\":{\"a\":{}}, \"layers\":{\"a\":{}}}",
        "{\"layers\":{\"\\u0000\":{}}}",
        "{\"layers\" \u{7f}",
    ];
    for s in cases {
        // Lenient readers may accept some of these; the contract under
        // test is error-or-value, never a panic or UB.
        let _ = CostProfile::parse_json(s);
    }
}

#[test]
fn v2_index_parses_and_rejects_every_truncation() {
    let bytes = write_container_v2(&Container::default());
    assert!(is_v2(&bytes));
    let idx = ContainerIndex::parse(&bytes).expect("parse own bytes");
    assert!(idx.is_empty());
    for cut in 0..bytes.len() {
        assert!(
            ContainerIndex::parse(&bytes[..cut]).is_err(),
            "a {cut}-byte prefix of the v2 header must not parse"
        );
    }
    // Single-byte corruption anywhere in the header must never panic.
    for i in 0..bytes.len() {
        let mut bad = bytes.clone();
        bad[i] ^= 0xFF;
        let _ = ContainerIndex::parse(&bad);
    }
}

#[test]
fn shard_map_roundtrips_and_rejects_corruption() {
    let map = ShardMap::from_assignments(
        2,
        vec![("blk.0".into(), 0), ("blk.1".into(), 1)],
    )
    .expect("valid assignments");
    let bytes = map.to_bytes();
    assert!(is_shard_map(&bytes));
    let back = ShardMap::parse(&bytes).expect("parse own bytes");
    assert_eq!(back.n_shards(), 2);
    assert_eq!(back.shard_of("blk.1"), Some(1));

    for cut in 0..bytes.len() {
        assert!(
            ShardMap::parse(&bytes[..cut]).is_err(),
            "a {cut}-byte prefix of the shard map must not parse"
        );
    }
    for i in 0..bytes.len() {
        let mut bad = bytes.clone();
        bad[i] ^= 0xFF;
        let _ = ShardMap::parse(&bad);
    }

    // Semantic rejects: out-of-range shard id, duplicate layer.
    let out_of_range =
        ShardMap::from_assignments(1, vec![("a".into(), 1)]);
    assert!(out_of_range.is_err());
    let duplicate = ShardMap::from_assignments(
        2,
        vec![("a".into(), 0), ("a".into(), 1)],
    );
    assert!(duplicate.is_err());
}

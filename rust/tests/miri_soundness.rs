//! Miri-targeted soundness tests for every decoder that faces bytes
//! from another process or an on-disk artifact.
//!
//! Everything here runs purely in memory — no sockets, no files, no
//! spawned threads, no clocks — so
//! `cargo +nightly miri test --test miri_soundness` finishes in
//! seconds while exercising, under the interpreter's full UB checking,
//! the exact code paths the serving stack runs on untrusted input:
//! IPC frame encode/decode ([`f2f::ipc::wire`]), the v2 container
//! index and `F2F3` shard-map parsers ([`f2f::container`]), and the
//! `CostProfile` JSON reader ([`f2f::shard`]).
//!
//! The regular test suite covers the same parsers through sockets and
//! temp files; those tests are skipped under Miri (isolation forbids
//! the syscalls), which is why this file exists. The [`word_kernels`]
//! module additionally runs the bit-twiddling hot loops — the 64×64
//! transpose, the block writer, and the fused tail-word decode — under
//! the interpreter, where an out-of-range shift or a stray read past a
//! row's tail word would surface as an error instead of silence.

use f2f::container::{
    is_shard_map, is_v2, write_container_v2, Container, ContainerIndex,
    ShardMap,
};
use f2f::shard::CostProfile;
use f2f::store::LayerCost;

/// The IPC wire codec only exists on unix (`std::os::unix::net`), but
/// the frame encode/decode under test is pure `Read`/`Write` over
/// in-memory buffers — Miri runs it without socket syscalls.
#[cfg(unix)]
mod wire_frames {
    use f2f::ipc::wire::{
        read_frame, read_request, read_response, send_request,
        send_response, Request, Response, WireError,
    };

    /// Encode one request into an in-memory frame (`Vec<u8>` is
    /// `Write`).
    fn request_frame(req: &Request) -> Vec<u8> {
        let mut buf = Vec::new();
        send_request(&mut buf, req).expect("encode request");
        buf
    }

    /// Encode one response into an in-memory frame.
    fn response_frame(resp: &Response) -> Vec<u8> {
        let mut buf = Vec::new();
        send_response(&mut buf, resp).expect("encode response");
        buf
    }

    #[test]
    fn every_request_variant_roundtrips_in_memory() {
        let reqs = [
            Request::Fetch {
                layer: "layer0".into(),
                model: "zoo-a".into(),
                trace: 7,
            },
            Request::Prefetch {
                layer: "blk.3/ffn".into(),
                model: String::new(),
                trace: 0,
            },
            Request::Metrics,
            Request::CostProfile,
            Request::TraceDump,
            Request::Shutdown,
        ];
        for req in &reqs {
            let buf = request_frame(req);
            let got = read_request(&mut &buf[..]).expect("decode");
            assert_eq!(&got, req);
        }
    }

    #[test]
    fn response_variants_roundtrip_in_memory() {
        let resps = [
            Response::Layer {
                rows: 2,
                cols: 3,
                weights: vec![0.5, -1.0, 0.0, 3.25, -0.125, 2.0],
            },
            // Fused bit-plane frame: 2×70 I8 → 2 words/row, 8 planes.
            Response::FusedLayer {
                rows: 2,
                cols: 70,
                dtype: f2f::container::Dtype::I8,
                scale: 0.125,
                planes: (0..8 * 2 * 2).map(|i| i as u64 * 0x9E37).collect(),
                mask: vec![u64::MAX; 2 * 2],
            },
            Response::Ack { accepted: true },
            Response::Ack { accepted: false },
            Response::CostProfile { json: "{\"layers\":{}}".into() },
            Response::Err {
                message: "unknown layer \"ghost\"".into(),
            },
            Response::Bye,
        ];
        for resp in &resps {
            let buf = response_frame(resp);
            let got = read_response(&mut &buf[..]).expect("decode");
            assert_eq!(&got, resp);
        }
    }

    #[test]
    fn fused_frames_reject_truncation_and_corruption_in_memory() {
        let buf = response_frame(&Response::FusedLayer {
            rows: 1,
            cols: 3,
            dtype: f2f::container::Dtype::I8,
            scale: 1.0,
            planes: vec![0b101; 8],
            mask: vec![0b111],
        });
        for cut in 0..buf.len() {
            assert!(
                read_response(&mut &buf[..cut]).is_err(),
                "a {cut}-byte prefix of a fused frame must not parse"
            );
        }
        // Single-byte corruption anywhere (geometry, dtype, words)
        // must produce error-or-value, never a panic or UB — a lying
        // rows/cols field in particular must not drive an allocation
        // or a word read past the payload.
        for i in 0..buf.len() {
            let mut bad = buf.clone();
            bad[i] ^= 0xFF;
            let _ = read_response(&mut &bad[..]);
        }
    }

    #[test]
    fn truncated_frames_error_and_never_panic() {
        let frames = [
            request_frame(&Request::Fetch {
                layer: "w".into(),
                model: String::new(),
                trace: 1,
            }),
            response_frame(&Response::Layer {
                rows: 1,
                cols: 2,
                weights: vec![1.0, 2.0],
            }),
        ];
        for buf in &frames {
            for cut in 0..buf.len() {
                let short = &buf[..cut];
                assert!(
                    read_frame(&mut &short[..]).is_err(),
                    "a {cut}-byte prefix of a {}-byte frame must \
                     not parse",
                    buf.len()
                );
            }
        }
    }

    #[test]
    fn corrupt_frame_headers_are_rejected() {
        // Header layout: magic [0..4], version u16 [4..6], kind [6],
        // payload length u32 [7..11].
        let good = request_frame(&Request::Metrics);

        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xFF;
        assert!(matches!(
            read_frame(&mut &bad_magic[..]),
            Err(WireError::Corrupt(_))
        ));

        let mut bad_version = good.clone();
        bad_version[4] ^= 0xFF;
        assert!(read_frame(&mut &bad_version[..]).is_err());

        // An unknown kind may pass the frame layer but must be
        // rejected as a request.
        let mut bad_kind = good.clone();
        bad_kind[6] = 0xEE;
        assert!(read_request(&mut &bad_kind[..]).is_err());

        // A length field claiming more payload than the stream
        // delivers is truncation, not an allocation of the claimed
        // size.
        let mut lying_len = good;
        lying_len[7] = 40;
        assert!(read_frame(&mut &lying_len[..]).is_err());
    }
}

/// The word-parallel kernel hot loops, pure in memory: shift networks
/// and tail-word handling are exactly where UB (out-of-range shifts,
/// reads past a padded row) likes to hide.
mod word_kernels {
    use f2f::container::Dtype;
    use f2f::kernels::{transpose64, BlockWriter, FusedLayer};
    use f2f::rng::Rng;

    #[test]
    fn transpose64_moves_every_bit_and_is_an_involution() {
        let mut rng = Rng::new(11);
        let orig: [u64; 64] = std::array::from_fn(|_| rng.next_u64());
        let mut a = orig;
        transpose64(&mut a);
        for r in 0..64 {
            for c in 0..64 {
                assert_eq!(
                    (a[c] >> r) & 1,
                    (orig[r] >> c) & 1,
                    "bit ({r},{c})"
                );
            }
        }
        transpose64(&mut a);
        assert_eq!(a, orig, "transpose twice is identity");
    }

    #[test]
    fn block_writer_matches_a_per_bit_reference_across_tails() {
        let mut rng = Rng::new(12);
        // Widths straddling the word boundaries (63/64/65) and the
        // two-word spill (127/128), against short and unaligned
        // target lengths.
        for width in [1usize, 7, 63, 64, 65, 100, 127, 128] {
            for n_bits in [1usize, 64, 70, 130] {
                let blocks: Vec<u128> = (0..n_bits.div_ceil(width) + 1)
                    .map(|_| {
                        (rng.next_u64() as u128) << 64
                            | rng.next_u64() as u128
                    })
                    .collect();
                let mut w = BlockWriter::new(n_bits);
                for &b in &blocks {
                    w.push(b, width);
                }
                let v = w.finish();
                let mut cursor = 0usize;
                let mut expected = vec![false; n_bits];
                for &b in &blocks {
                    for i in 0..width {
                        if cursor < n_bits {
                            expected[cursor] = (b >> i) & 1 == 1;
                            cursor += 1;
                        }
                    }
                }
                for (i, want) in expected.iter().enumerate() {
                    assert_eq!(
                        v.get(i),
                        *want,
                        "width={width} n_bits={n_bits} bit {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn fused_tail_words_decode_gemv_and_ignore_hostile_padding() {
        // 3×70 I8: 2 words/row, the second covering only bits 0..5.
        // Bits 6..63 of every tail word are garbage the decode must
        // never read — the involution of the row-padded layout.
        let (rows, cols, n_w) = (3usize, 70usize, 8usize);
        let wpr = cols.div_ceil(64);
        let mut rng = Rng::new(13);
        let planes: Vec<u64> =
            (0..n_w * rows * wpr).map(|_| rng.next_u64()).collect();
        let mask: Vec<u64> =
            (0..rows * wpr).map(|_| rng.next_u64()).collect();
        let scale = -0.25f32; // negative: pruned must be +0.0, not −0.0
        let fused = FusedLayer::from_raw(
            rows,
            cols,
            Dtype::I8,
            scale,
            planes.clone(),
            mask.clone(),
        )
        .expect("word counts match the geometry");

        // Independent per-bit reference straight off the raw words.
        let stride = rows * wpr;
        let bit = |words: &[u64], base: usize, c: usize| {
            (words[base + c / 64] >> (c % 64)) & 1
        };
        let mut want = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                let row = r * wpr;
                if bit(&mask, row, c) == 1 {
                    let mut byte = 0u8;
                    for k in 0..n_w {
                        byte |= (bit(&planes, k * stride + row, c)
                            as u8)
                            << (n_w - 1 - k);
                    }
                    want.push(byte as i8 as f32 * scale);
                } else {
                    want.push(0.0);
                }
            }
        }
        let got = fused.to_dense();
        assert_eq!((got.rows, got.cols), (rows, cols));
        let bits = |ws: &[f32]| {
            ws.iter().map(|w| w.to_bits()).collect::<Vec<_>>()
        };
        assert_eq!(bits(&got.weights), bits(&want));

        // GEMV parity with the dense reference, same op order.
        let x: Vec<f32> =
            (0..cols).map(|j| (j as f32).sin()).collect();
        assert_eq!(bits(&fused.gemv(&x)), bits(&got.gemv(&x)));

        // Stray tail-word bits really are dead: flipping them must
        // change nothing.
        let mut hostile_planes = planes;
        let mut hostile_mask = mask;
        for r in 0..rows {
            for k in 0..n_w {
                hostile_planes[k * stride + r * wpr + 1] ^=
                    !0u64 << (cols - 64);
            }
            hostile_mask[r * wpr + 1] ^= !0u64 << (cols - 64);
        }
        let hostile = FusedLayer::from_raw(
            rows,
            cols,
            Dtype::I8,
            scale,
            hostile_planes,
            hostile_mask,
        )
        .expect("same geometry");
        assert_eq!(bits(&hostile.to_dense().weights), bits(&want));
    }
}

#[test]
fn cost_profile_json_roundtrips() {
    let mut p = CostProfile::new();
    p.record(
        "blk.0",
        LayerCost {
            decode_ns: 1.5e6,
            gemv_ns: 300.0,
            decode_samples: 4,
            gemv_samples: 2,
        },
    );
    p.record(
        "blk.1",
        LayerCost {
            decode_ns: 2.25e6,
            gemv_ns: 0.0,
            decode_samples: 1,
            gemv_samples: 0,
        },
    );
    let json = p.to_json();
    let back = CostProfile::parse_json(&json).expect("parse own json");
    assert_eq!(back.len(), 2);
    let a = back.get("blk.0").expect("blk.0 present");
    assert_eq!(a.decode_samples, 4);
    assert!((a.decode_ns - 1.5e6).abs() < 1.0, "got {}", a.decode_ns);
}

#[test]
fn truncated_cost_profile_json_errors_and_never_panics() {
    let mut p = CostProfile::new();
    p.record(
        "layer \"quoted\" \\ name",
        LayerCost {
            decode_ns: 9.0e5,
            gemv_ns: 12.5,
            decode_samples: 3,
            gemv_samples: 1,
        },
    );
    let json = p.to_json();
    // Any cut before the closing brace leaves the top-level object
    // unbalanced, so every such prefix must error (and, under Miri,
    // must do so without UB). The layer name here is ASCII, so every
    // byte offset is a char boundary. Cuts inside the trailing
    // newline would be complete documents and are excluded.
    let end = json.trim_end().len();
    for cut in 0..end {
        assert!(
            CostProfile::parse_json(&json[..cut]).is_err(),
            "prefix of length {cut} must not parse"
        );
    }
    assert!(CostProfile::parse_json(&json).is_ok());
}

#[test]
fn adversarial_cost_profile_json_never_panics() {
    let cases = [
        "",
        "{",
        "}",
        "null",
        "[1,2,3]",
        "{\"layers\":}",
        "{\"layers\":{\"a\":1}}",
        "{\"layers\":{\"a\":{\"decode_ns\":\"NaN\"}}}",
        "{\"layers\":{\"a\":{\"decode_ns\":1e309}}}",
        "{\"layers\":{\"a\":{}}, \"layers\":{\"a\":{}}}",
        "{\"layers\":{\"\\u0000\":{}}}",
        "{\"layers\" \u{7f}",
    ];
    for s in cases {
        // Lenient readers may accept some of these; the contract under
        // test is error-or-value, never a panic or UB.
        let _ = CostProfile::parse_json(s);
    }
}

#[test]
fn v2_index_parses_and_rejects_every_truncation() {
    let bytes = write_container_v2(&Container::default());
    assert!(is_v2(&bytes));
    let idx = ContainerIndex::parse(&bytes).expect("parse own bytes");
    assert!(idx.is_empty());
    for cut in 0..bytes.len() {
        assert!(
            ContainerIndex::parse(&bytes[..cut]).is_err(),
            "a {cut}-byte prefix of the v2 header must not parse"
        );
    }
    // Single-byte corruption anywhere in the header must never panic.
    for i in 0..bytes.len() {
        let mut bad = bytes.clone();
        bad[i] ^= 0xFF;
        let _ = ContainerIndex::parse(&bad);
    }
}

#[test]
fn shard_map_roundtrips_and_rejects_corruption() {
    let map = ShardMap::from_assignments(
        2,
        vec![("blk.0".into(), 0), ("blk.1".into(), 1)],
    )
    .expect("valid assignments");
    let bytes = map.to_bytes();
    assert!(is_shard_map(&bytes));
    let back = ShardMap::parse(&bytes).expect("parse own bytes");
    assert_eq!(back.n_shards(), 2);
    assert_eq!(back.shard_of("blk.1"), Some(1));

    for cut in 0..bytes.len() {
        assert!(
            ShardMap::parse(&bytes[..cut]).is_err(),
            "a {cut}-byte prefix of the shard map must not parse"
        );
    }
    for i in 0..bytes.len() {
        let mut bad = bytes.clone();
        bad[i] ^= 0xFF;
        let _ = ShardMap::parse(&bad);
    }

    // Semantic rejects: out-of-range shard id, duplicate layer.
    let out_of_range =
        ShardMap::from_assignments(1, vec![("a".into(), 1)]);
    assert!(out_of_range.is_err());
    let duplicate = ShardMap::from_assignments(
        2,
        vec![("a".into(), 0), ("a".into(), 1)],
    );
    assert!(duplicate.is_err());
}

//! Multi-process sharded serving end to end: real `f2f shard-worker`
//! child processes (spawned from the test binary's `CARGO_BIN_EXE_f2f`)
//! behind a supervisor, routed by a `ProcRouter`. Serving through
//! 1/2/4 worker processes must be bit-exact vs the single-store
//! `ModelBackend` *and* the in-process `ShardRouter`; a killed worker
//! must be restarted by the supervisor with its shard assignment
//! replayed while the serve completes correctly; corrupt frames on
//! the wire must produce errors on both sides — never a panic, never a
//! dead worker; and (with the `obs` feature) every request's trace id
//! must stitch one connected timeline across the process boundary.
#![cfg(unix)]

use f2f::container::{split_container, write_container_v2, ContainerIndex, ShardAssignment};
use f2f::coordinator::Backend;
use f2f::ipc::{wire, IpcShardStore, ProcRouter, Supervisor, WorkerSpec};
use f2f::models::{compressed_mlp, MlpConfig};
use f2f::shard::ShardRouter;
use f2f::store::{cost_sidecar_path, ModelBackend, ModelStore, ReadaheadPolicy, StoreConfig};
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

/// Widths of the synthetic MLP: 4 layers of distinct sizes so
/// by-bytes balancing is non-trivial.
const DIMS: [usize; 5] = [32, 24, 16, 12, 8];

fn model_bytes(seed: u64) -> Vec<u8> {
    let (c, _) = compressed_mlp(&MlpConfig {
        seed,
        sparsity: 0.75,
        ..MlpConfig::new(&DIMS)
    });
    write_container_v2(&c)
}

fn probes(n: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| {
            (0..DIMS[0])
                .map(|j| ((i * j) as f32 * 0.1).sin())
                .collect()
        })
        .collect()
}

fn single_store_outputs(bytes: &[u8], xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let store = Arc::new(
        ModelStore::open_bytes(bytes.to_vec(), StoreConfig::default())
            .unwrap(),
    );
    ModelBackend::sequential(store)
        .unwrap()
        .forward_batch(xs)
        .unwrap()
}

/// A spawned multi-process deployment: shard files + sockets in a
/// private temp dir, workers supervised, cleaned up on drop.
struct Deployment {
    dir: PathBuf,
    map: f2f::container::ShardMap,
    index: ContainerIndex,
    sup: Arc<Supervisor>,
}

impl Deployment {
    fn spawn(tag: &str, bytes: &[u8], n_workers: usize) -> Deployment {
        let dir = std::env::temp_dir().join(format!(
            "f2f-ipc-test-{tag}-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let (map, shard_bytes) =
            split_container(bytes, n_workers, ShardAssignment::ByBytes)
                .unwrap();
        let binary = PathBuf::from(env!("CARGO_BIN_EXE_f2f"));
        let mut specs = Vec::new();
        for (i, b) in shard_bytes.iter().enumerate() {
            let shard_path = dir.join(format!("shard{i}.f2f"));
            std::fs::write(&shard_path, b).unwrap();
            specs.push(WorkerSpec::new(
                &binary,
                shard_path,
                dir.join(format!("shard{i}.sock")),
            ));
        }
        let sup = Supervisor::spawn(specs).expect("spawn workers");
        let index = ContainerIndex::parse(bytes).unwrap();
        Deployment { dir, map, index, sup }
    }

    fn router(&self) -> ProcRouter {
        ProcRouter::new(
            self.sup.clients().to_vec(),
            &self.map,
            &self.index,
        )
        .unwrap()
        .with_supervisor(self.sup.clone())
        .with_readahead(ReadaheadPolicy::layers(1))
    }
}

impl Drop for Deployment {
    fn drop(&mut self) {
        self.sup.shutdown();
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

#[test]
fn multiproc_serving_is_bit_exact_for_1_2_4_workers() {
    let bytes = model_bytes(80);
    let xs = probes(5);
    let want = single_store_outputs(&bytes, &xs);
    for n_workers in [1usize, 2, 4] {
        let dep = Deployment::spawn(
            &format!("bitexact{n_workers}"),
            &bytes,
            n_workers,
        );
        // Cross-check against the in-process shard router over the
        // *same* partition: three serving tiers, one answer.
        let shard_bytes =
            f2f::container::split_with_map(&bytes, &dep.map).unwrap();
        let mut inproc = ShardRouter::from_bytes(
            &dep.map.to_bytes(),
            shard_bytes,
            StoreConfig::default(),
        )
        .unwrap();
        assert_eq!(
            inproc.forward_batch(&xs).unwrap(),
            want,
            "{n_workers}: in-process router diverged from the single \
             store"
        );

        let mut router = dep.router();
        assert_eq!(router.input_dim(), DIMS[0]);
        assert_eq!(router.output_dim(), *DIMS.last().unwrap());
        let got = router.forward_batch(&xs).unwrap();
        assert_eq!(
            got, want,
            "{n_workers} worker processes must serve outputs \
             bit-identical to the single store"
        );

        // Metrics aggregate over the wire: every layer decoded
        // exactly once across all workers (readahead dedups).
        let m = router.metrics().unwrap();
        assert_eq!(m.per_shard.len(), n_workers);
        assert_eq!(m.total.decodes, DIMS.len() as u64 - 1);
        assert_eq!(m.total.redundant_decodes, 0);
        // The merged cost profile covers the whole chain: decode
        // observed worker-side, GEMV router-side.
        for (name, cost) in &m.costs {
            assert!(cost.decode_samples > 0, "{name}");
            assert!(cost.gemv_samples > 0, "{name}");
        }
        assert_eq!(m.costs.len(), DIMS.len() - 1);
    }
}

#[test]
fn killed_worker_is_restarted_and_the_serve_completes() {
    let bytes = model_bytes(81);
    let xs = probes(4);
    let want = single_store_outputs(&bytes, &xs);
    let dep = Deployment::spawn("restart", &bytes, 2);
    let mut router = dep.router();
    assert_eq!(router.forward_batch(&xs).unwrap(), want, "healthy pass");
    assert_eq!(dep.sup.restarts(), 0);
    let pid_before = dep.sup.worker_pid(0).expect("worker 0 alive");

    // Kill worker 0 outright (SIGKILL, no cleanup) — the next pass
    // must transparently revive it with the same shard assignment and
    // still produce bit-exact outputs.
    dep.sup.kill_worker(0).unwrap();
    assert_eq!(
        router.forward_batch(&xs).unwrap(),
        want,
        "serve must complete correctly across a worker restart"
    );
    assert!(dep.sup.restarts() >= 1, "supervisor must have restarted");
    let pid_after = dep.sup.worker_pid(0).expect("worker 0 respawned");
    assert_ne!(pid_before, pid_after, "a fresh process took over");

    // The replayed assignment serves worker 0's own layers and still
    // rejects foreign ones remotely (alive, not just reachable).
    let shard0_layer = dep.map.layers_of(0).next().unwrap().to_string();
    let client = &dep.sup.clients()[0];
    assert!(client.fetch(&shard0_layer).is_ok());
    let foreign = dep.map.layers_of(1).next().unwrap().to_string();
    let err = client.fetch(&foreign).unwrap_err();
    assert!(!err.is_transport(), "foreign layer is a remote error");

    // And a second kill during ongoing traffic also recovers.
    dep.sup.kill_worker(1).unwrap();
    assert_eq!(router.forward_batch(&xs).unwrap(), want);
    assert!(dep.sup.restarts() >= 2);
}

#[test]
fn worker_restart_warms_from_the_cost_sidecar() {
    // The cost-model lifecycle across processes: a sidecar written
    // next to the shard file pre-warms the respawned worker's table,
    // so its profile reports decode estimates before any traffic.
    let bytes = model_bytes(82);
    let dep = Deployment::spawn("sidecar", &bytes, 2);
    let shard0_layer = dep.map.layers_of(0).next().unwrap().to_string();
    let mut profile = f2f::shard::CostProfile::new();
    profile.record(
        &shard0_layer,
        f2f::store::LayerCost {
            decode_ns: 12_345.0,
            decode_samples: 4,
            ..Default::default()
        },
    );
    std::fs::write(
        cost_sidecar_path(&dep.dir.join("shard0.f2f")),
        profile.to_json(),
    )
    .unwrap();
    dep.sup.kill_worker(0).unwrap();
    dep.sup.revive(0).unwrap();
    let warmed = dep.sup.clients()[0].cost_profile().unwrap();
    assert_eq!(
        warmed.get(&shard0_layer).map(|c| c.decode_ns),
        Some(12_345.0),
        "respawned worker must auto-load the shard's cost sidecar"
    );
}

#[test]
fn corrupt_frames_error_on_both_sides_and_never_kill_the_worker() {
    let bytes = model_bytes(83);
    let dep = Deployment::spawn("fuzz", &bytes, 1);
    let socket = dep.sup.clients()[0].socket_path().to_path_buf();
    let first_layer = dep.map.layers_of(0).next().unwrap().to_string();

    // Raw garbage on a fresh connection: the worker answers with an
    // error frame (or closes) and keeps serving.
    for garbage in [
        b"not a frame at all............".as_slice(),
        &[0xFFu8; 64],
        &[0x00u8; 11], // zeroed header: bad magic
    ] {
        let mut stream = UnixStream::connect(&socket).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        use std::io::Write;
        stream.write_all(garbage).unwrap();
        let _ = wire::read_response(&mut stream); // err frame or EOF
        drop(stream);
        let client = IpcShardStore::connect(&socket);
        assert!(
            client.fetch(&first_layer).is_ok(),
            "worker must survive garbage frames"
        );
    }

    // A well-formed header with an unknown kind: an error frame, and
    // the *same* worker still serves afterwards.
    let mut stream = UnixStream::connect(&socket).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    wire::write_frame(&mut stream, 0x42, b"mystery").unwrap();
    match wire::read_response(&mut stream) {
        Ok(wire::Response::Err { .. }) | Err(_) => {}
        Ok(other) => panic!("expected an error frame, got {other:?}"),
    }
    let client = IpcShardStore::connect(&socket);
    assert!(client.ping(), "worker alive after unknown request kind");

    // Truncated-frame fuzz against the pure decoders (no socket):
    // every prefix of every message must error, never panic.
    let mut frame = Vec::new();
    wire::send_request(
        &mut frame,
        &wire::Request::Fetch {
            layer: first_layer,
            model: String::new(),
            trace: 1,
        },
    )
    .unwrap();
    for cut in 0..frame.len() {
        let _ =
            wire::read_request(&mut std::io::Cursor::new(&frame[..cut]));
    }
}

#[test]
fn multiproc_serves_behind_the_inference_server() {
    // The full production shape: supervisor + ProcRouter behind the
    // batching InferenceServer, mixed traffic, bit-exact replies.
    use f2f::coordinator::{InferenceServer, ServerConfig};
    let bytes = model_bytes(84);
    let xs = probes(8);
    let want = single_store_outputs(&bytes, &xs);
    let dep = Deployment::spawn("server", &bytes, 2);
    let router = dep.router();
    let server = InferenceServer::start(
        ServerConfig {
            max_batch: 4,
            batch_timeout: Duration::from_millis(1),
            ..Default::default()
        },
        move || Box::new(router),
    )
    .unwrap();
    for (i, x) in xs.into_iter().enumerate() {
        assert_eq!(
            server.infer(x).unwrap(),
            want[i],
            "request {i} diverged"
        );
    }
    let m = server.metrics();
    assert_eq!(m.completed, 8);
    assert_eq!(m.errors, 0);
    server.shutdown();
}

/// Satellite of the tracing tentpole: a 2-worker serve must produce
/// one *connected* trace per request — router-side GEMV and
/// `ipc_fetch` spans plus worker-side cache/decode spans, all sharing
/// the request's trace id, with no orphaned traces in any worker lane.
#[cfg(feature = "obs")]
#[test]
fn traces_stitch_across_process_boundaries() {
    use f2f::obs::{self, SpanKind};

    let bytes = model_bytes(86);
    let xs = probes(3);
    let dep = Deployment::spawn("trace", &bytes, 2);
    let mut router = dep.router();
    // One forward pass per request, each pinned to its own trace —
    // exactly what the inference server does per batch leader.
    let mut trace_ids = Vec::new();
    for x in &xs {
        let tr = obs::mint_trace();
        let _g = obs::with_trace(tr);
        router.forward_batch(std::slice::from_ref(x)).unwrap();
        trace_ids.push(tr);
    }
    let n_layers = DIMS.len() - 1;

    // Router side: every request trace carries one GEMV span and one
    // IPC fetch round trip per chain layer.
    let local = obs::snapshot();
    for &tr in &trace_ids {
        for (kind, what) in [
            (SpanKind::Gemv, "gemv"),
            (SpanKind::IpcFetch, "ipc fetch"),
        ] {
            let n = local
                .iter()
                .filter(|e| e.trace_id == tr && e.kind == kind)
                .count();
            assert_eq!(
                n, n_layers,
                "trace {tr:#x}: one {what} span per layer"
            );
        }
    }

    // Worker side: each lane is a real separate process, its spans
    // stitch to our request traces, and nothing is orphaned.
    let mut pids = vec![std::process::id()];
    let mut worker_events = Vec::new();
    for (i, client) in dep.sup.clients().iter().enumerate() {
        let (pid, events) = client.trace_events().unwrap();
        assert!(
            !pids.contains(&pid),
            "worker {i} must be its own process (pid {pid})"
        );
        pids.push(pid);
        assert!(!events.is_empty(), "worker {i} recorded no spans");
        for e in &events {
            assert!(
                e.trace_id == obs::TRACE_NONE
                    || trace_ids.contains(&e.trace_id),
                "worker {i} span {:?} is orphaned: trace {:#x} \
                 belongs to no request",
                e.kind,
                e.trace_id
            );
        }
        worker_events.extend(events);
    }
    // Every request reached the workers under its own id (the first
    // as decodes/misses, later ones at least as cache hits) …
    for &tr in &trace_ids {
        assert!(
            worker_events.iter().any(|e| e.trace_id == tr),
            "trace {tr:#x} never appeared in any worker lane"
        );
    }
    // … and each layer's one decode landed in exactly one lane.
    let decodes = worker_events
        .iter()
        .filter(|e| e.kind == SpanKind::Decode)
        .count();
    assert_eq!(decodes, n_layers, "one decode span per chain layer");

    // A killed-and-revived worker comes back with a fresh, empty
    // recorder, answers dumps cleanly, and resumes stitched tracing.
    dep.sup.kill_worker(0).unwrap();
    dep.sup.revive(0).unwrap();
    let (new_pid, events) =
        dep.sup.clients()[0].trace_events().unwrap();
    assert!(!pids.contains(&new_pid), "revived worker is a fresh pid");
    assert!(
        events.is_empty(),
        "a fresh worker has no spans before traffic"
    );
    let tr = obs::mint_trace();
    {
        let _g = obs::with_trace(tr);
        router.forward_batch(&xs[..1]).unwrap();
    }
    let (_, events) = dep.sup.clients()[0].trace_events().unwrap();
    assert!(
        events.iter().any(|e| e.trace_id == tr),
        "revived worker must stitch new requests into their traces"
    );
}

#[test]
fn supervisor_shutdown_stops_workers_and_removes_sockets() {
    let bytes = model_bytes(85);
    let dep = Deployment::spawn("shutdown", &bytes, 2);
    let sockets: Vec<PathBuf> = dep
        .sup
        .clients()
        .iter()
        .map(|c| c.socket_path().to_path_buf())
        .collect();
    for s in &sockets {
        assert!(s.exists(), "socket {} up before shutdown", s.display());
    }
    dep.sup.shutdown();
    for s in &sockets {
        assert!(!s.exists(), "socket {} removed", s.display());
    }
    // Clients degrade to transport errors once the tier is down.
    assert!(!dep.sup.clients()[0].ping());
}

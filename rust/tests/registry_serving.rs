//! Multi-tenant registry serving end to end: N models with layer-kind
//! chains behind one `ModelRegistry`, sharing decode workers and one
//! global byte budget. Interleaved cross-tenant traffic must stay
//! bit-exact vs serving each model alone — under a budget small
//! enough to force cross-model eviction — with zero redundant decodes
//! and nothing pinned at rest; the same zoo behind the batching
//! `InferenceServer` must complete concurrent per-tenant bursts with
//! zero errors; and the zoo served through real shard-worker
//! processes (the `--shard-procs` path) must match the in-process
//! answers across a worker kill/revive.
//!
//! The store's budget/pinning invariants (`check_invariants`) assert
//! on every cache transition in debug builds, so the interleaved
//! passes here double as an invariant stress under multiple tenants.

use f2f::container::{write_container_v3, Dtype};
use f2f::coordinator::Backend;
use f2f::models::{
    compressed_mlp, tiny_transformer_layers, transformer_chain,
    transformer_layers, MlpConfig, SyntheticLayer, WeightGen,
};
use f2f::pipeline::{CompressionConfig, Compressor};
use f2f::pruning::PruneMethod;
use f2f::registry::{CompiledChain, ModelRegistry, ZooModel};
use f2f::store::StoreConfig;

/// A Transformer tenant: the canonical attention + FFN table at test
/// scale, compressed with its chain riding in a v3 container. (The
/// full 512-d `transformer_layers()` table builds the *same* chain —
/// see `the_real_transformer_table_compiles_into_an_executable_chain`
/// below — it is only too large to compress per test run.)
fn transformer_model(id: &str, d_model: usize, d_ff: usize) -> ZooModel {
    let specs = tiny_transformer_layers(2, d_model, d_ff);
    let chain = transformer_chain(id, &specs).unwrap();
    let layers: Vec<SyntheticLayer> = specs
        .iter()
        .map(|s| SyntheticLayer::generate(s, WeightGen::default(), 0x7A))
        .collect();
    let cfg = CompressionConfig {
        sparsity: 0.85,
        n_s: 0,
        method: PruneMethod::Magnitude,
        beam: None,
        ..Default::default()
    };
    let (container, _) =
        Compressor::new(cfg).compress_model(&layers, Dtype::I8);
    let bytes = write_container_v3(&container, &[chain]);
    ZooModel::from_bytes(id, &bytes).unwrap()
}

/// An MLP tenant with no explicit chain — served as the implicit
/// uniform gemv+relu ladder, like every pre-zoo container.
fn mlp_model(id: &str, dims: &[usize], seed: u64) -> ZooModel {
    let (c, _) = compressed_mlp(&MlpConfig {
        seed,
        sparsity: 0.75,
        ..MlpConfig::new(dims)
    });
    ZooModel::new(id, c)
}

fn probes(dim: usize, n: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| {
            (0..dim)
                .map(|j| (((i * dim + j) as f32) * 0.23).sin())
                .collect()
        })
        .collect()
}

fn unbounded() -> StoreConfig {
    StoreConfig {
        cache_budget_bytes: usize::MAX,
        decode_workers: 2,
        ..Default::default()
    }
}

/// Reference outputs: the tenant served from its own registry with
/// nothing else contending for the budget.
fn serve_alone(model: ZooModel, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let id = model.id.clone();
    let zoo = [model];
    let mut reg = ModelRegistry::new(&zoo, unbounded()).unwrap();
    reg.forward_model_batch(&id, xs).unwrap()
}

#[test]
fn interleaved_tenants_stay_bit_exact_under_cross_model_eviction() {
    let make_tx = || transformer_model("tx", 16, 32);
    let make_a = || mlp_model("mlp-a", &[24, 20, 16, 12], 31);
    let make_b = || mlp_model("mlp-b", &[12, 10, 8], 32);
    let tx_xs = probes(16, 4);
    let a_xs = probes(24, 4);
    let b_xs = probes(12, 4);
    let want_tx = serve_alone(make_tx(), &tx_xs);
    let want_a = serve_alone(make_a(), &a_xs);
    let want_b = serve_alone(make_b(), &b_xs);

    // Measure the combined decoded working set, then rebuild the zoo
    // under a budget well below it.
    let zoo = [make_tx(), make_a(), make_b()];
    let reg = ModelRegistry::new(&zoo, unbounded()).unwrap();
    let combined: usize = reg
        .stores()
        .iter()
        .map(|s| s.total_decoded_bytes())
        .sum();
    drop(reg);

    let budget = combined * 3 / 5;
    let zoo = [make_tx(), make_a(), make_b()];
    let mut reg = ModelRegistry::new(
        &zoo,
        StoreConfig {
            cache_budget_bytes: budget,
            decode_workers: 2,
            ..Default::default()
        },
    )
    .unwrap();
    for round in 0..3 {
        assert_eq!(
            reg.forward_model_batch("tx", &tx_xs).unwrap(),
            want_tx,
            "tx diverged under contention (round {round})"
        );
        assert_eq!(
            reg.forward_model_batch("mlp-a", &a_xs).unwrap(),
            want_a,
            "mlp-a diverged under contention (round {round})"
        );
        assert_eq!(
            reg.forward_model_batch("mlp-b", &b_xs).unwrap(),
            want_b,
            "mlp-b diverged under contention (round {round})"
        );
    }
    reg.wait_for_idle();
    let m = reg.store_metrics().unwrap();
    assert_eq!(
        m.redundant_decodes, 0,
        "in-flight dedup must hold across tenants: {m:?}"
    );
    assert!(
        m.evictions > 0,
        "budget {budget} of {combined} must force cross-model \
         eviction: {m:?}"
    );
    assert!(
        m.cached_bytes <= budget,
        "cache over budget: {} > {budget}",
        m.cached_bytes
    );
    assert_eq!(m.pinned_bytes, 0, "nothing pinned at rest: {m:?}");
}

#[test]
fn concurrent_tenant_bursts_behind_the_server_complete_exactly() {
    use f2f::coordinator::{InferenceServer, ServerConfig};
    use std::time::Duration;

    let make_tx = || transformer_model("tx", 16, 32);
    let make_mlp = || mlp_model("mlp", &[24, 20, 16, 12], 31);
    let tx_xs = probes(16, 4);
    let mlp_xs = probes(24, 4);
    let want_tx = serve_alone(make_tx(), &tx_xs);
    let want_mlp = serve_alone(make_mlp(), &mlp_xs);

    let zoo = [make_tx(), make_mlp()];
    let reg = ModelRegistry::new(&zoo, unbounded()).unwrap();
    let combined: usize = reg
        .stores()
        .iter()
        .map(|s| s.total_decoded_bytes())
        .sum();
    drop(reg);
    let zoo = [make_tx(), make_mlp()];
    let reg = ModelRegistry::new(
        &zoo,
        StoreConfig {
            cache_budget_bytes: combined * 3 / 5,
            decode_workers: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let server = InferenceServer::start(
        ServerConfig {
            max_batch: 4,
            batch_timeout: Duration::from_millis(1),
            ..Default::default()
        },
        move || Box::new(reg),
    )
    .unwrap();

    // 24 in-flight requests alternating tenants: batches stay
    // model-pure, both tenants' pinned layers must survive the
    // other's bursts mid-execution.
    let mut pending = Vec::new();
    for r in 0..24usize {
        let (id, xs, want) = if r % 2 == 0 {
            ("tx", &tx_xs, &want_tx)
        } else {
            ("mlp", &mlp_xs, &want_mlp)
        };
        let k = (r / 2) % xs.len();
        pending.push((
            server.infer_model_async(id, xs[k].clone()),
            want[k].clone(),
            id,
            r,
        ));
    }
    for (rx, want, id, r) in pending {
        assert_eq!(
            rx.recv().unwrap().unwrap(),
            want,
            "{id} request {r} diverged"
        );
    }
    let m = server.metrics();
    assert_eq!(m.completed, 24);
    assert_eq!(m.errors, 0);
    for id in ["tx", "mlp"] {
        let pm = server.model_metrics(id).unwrap();
        assert_eq!(pm.completed, 12, "{id} per-model window");
        assert_eq!(pm.errors, 0, "{id} per-model window");
    }
    server.shutdown();
}

#[test]
fn the_real_transformer_table_compiles_into_an_executable_chain() {
    // The acceptance shape: Transformer-base (Vaswani et al.), real
    // `transformer_layers()` dims, attention + FFN kind records. The
    // chain compiles into an executable plan without decoding a byte.
    let specs = transformer_layers();
    let chain = transformer_chain("transformer-base", &specs).unwrap();
    let compiled = CompiledChain::compile(
        &chain,
        |name| name.to_string(),
        |name| {
            specs
                .iter()
                .find(|s| s.name == name)
                .map(|s| (s.rows, s.cols))
        },
    )
    .unwrap();
    assert_eq!(compiled.input_dim(), 512);
    assert_eq!(compiled.output_dim(), 512);
    // 6 enc × (att + ffn1 + ffn2) + 6 dec × (2 att + 2 ffn).
    assert_eq!(compiled.n_steps(), 6 * 3 + 6 * 4);
    assert_eq!(compiled.layers().len(), specs.len());
}

#[cfg(unix)]
mod multiproc {
    use super::*;
    use f2f::container::{
        split_container, write_container_v2, ShardAssignment,
    };
    use f2f::ipc::{Supervisor, WorkerSpec};
    use f2f::registry::merge_zoo;
    use std::path::PathBuf;

    #[test]
    fn zoo_over_worker_processes_matches_in_process_serving() {
        let make_tx = || transformer_model("tx", 16, 32);
        let make_mlp = || mlp_model("mlp", &[24, 20, 16, 12], 31);
        let tx_xs = probes(16, 3);
        let mlp_xs = probes(24, 3);

        let zoo = [make_tx(), make_mlp()];
        let mut inproc = ModelRegistry::new(&zoo, unbounded()).unwrap();
        let want_tx = inproc.forward_model_batch("tx", &tx_xs).unwrap();
        let want_mlp =
            inproc.forward_model_batch("mlp", &mlp_xs).unwrap();
        drop(inproc);

        // The `serve --models --shard-procs` deployment shape: merge
        // the zoo into one scoped container, shard it across 2 real
        // worker processes (a shard can hold layers of both tenants),
        // and route fetches by model-scoped name over the wire.
        let dir = std::env::temp_dir().join(format!(
            "f2f-registry-ipc-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let merged = merge_zoo(&zoo).unwrap();
        let bytes = write_container_v2(&merged.container);
        let (map, shard_bytes) =
            split_container(&bytes, 2, ShardAssignment::ByBytes)
                .unwrap();
        let binary = PathBuf::from(env!("CARGO_BIN_EXE_f2f"));
        let mut specs = Vec::new();
        for (i, b) in shard_bytes.iter().enumerate() {
            let shard_path = dir.join(format!("shard{i}.f2f"));
            std::fs::write(&shard_path, b).unwrap();
            specs.push(WorkerSpec::new(
                &binary,
                shard_path,
                dir.join(format!("shard{i}.sock")),
            ));
        }
        let sup = Supervisor::spawn(specs).unwrap();
        let mut reg =
            ModelRegistry::over_ipc(&zoo, &map, sup.clients().to_vec())
                .unwrap()
                .with_supervisor(sup.clone());
        assert_eq!(
            reg.forward_model_batch("tx", &tx_xs).unwrap(),
            want_tx,
            "tx over worker processes diverged from in-process"
        );
        assert_eq!(
            reg.forward_model_batch("mlp", &mlp_xs).unwrap(),
            want_mlp,
            "mlp over worker processes diverged from in-process"
        );

        // A worker killed mid-zoo is revived with its cross-tenant
        // shard intact; both tenants keep serving bit-exact.
        sup.kill_worker(0).unwrap();
        assert_eq!(
            reg.forward_model_batch("mlp", &mlp_xs).unwrap(),
            want_mlp,
            "mlp must survive a worker restart"
        );
        assert_eq!(
            reg.forward_model_batch("tx", &tx_xs).unwrap(),
            want_tx,
            "tx must survive a worker restart"
        );
        assert!(sup.restarts() >= 1, "supervisor must have restarted");

        sup.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }
}

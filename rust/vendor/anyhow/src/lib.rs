//! Minimal, dependency-free shim of the `anyhow` 1.x API surface used by
//! the `f2f` crate, so the workspace builds fully offline.
//!
//! Provides [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] macros and
//! the [`Context`] extension trait. Error values carry a flattened
//! context/source chain of messages: `Display` prints the outermost
//! message, `{:#}` joins the chain with `": "` (matching anyhow), and
//! `Debug` prints the chain as a `Caused by:` list.

use std::fmt;

/// A flattened error: context messages first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root-cause message (innermost).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    /// Attach a context message to the error.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Attach a lazily-built context message to the error.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "root cause")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Error::from(io_err()).context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root cause");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_option() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
        let v: Option<u32> = Some(3);
        assert_eq!(v.with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn bail_and_anyhow_macros() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("bad value {}", 7);
            }
            Ok(1)
        }
        assert_eq!(format!("{}", f(true).unwrap_err()), "bad value 7");
        assert_eq!(f(false).unwrap(), 1);
    }

    #[test]
    fn chain_is_preserved() {
        let e = Error::from(io_err()).context("mid").context("top");
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["top", "mid", "root cause"]);
        assert_eq!(e.root_cause(), "root cause");
    }
}

//! Encoder throughput: the offline cost the paper bounds at
//! `O(l · 2^{N_in(N_s+1)})`. Reports blocks/s and bits/s per
//! configuration, plus the beam speedup (EXPERIMENTS.md §Perf tracks
//! these numbers across optimization iterations).

use f2f::bench_util::{bench_with_result, black_box};
use f2f::decoder::{DecoderSpec, SequentialDecoder};
use f2f::encoder::{Encoder, SlicedPlane, ViterbiEncoder};
use f2f::gf2::BitVecF2;
use f2f::rng::Rng;
use std::time::Duration;

fn workload(bits: usize, s: f64, seed: u64) -> (BitVecF2, BitVecF2) {
    let mut rng = Rng::new(seed);
    (
        BitVecF2::random(bits, 0.5, &mut rng),
        BitVecF2::random(bits, 1.0 - s, &mut rng),
    )
}

fn main() {
    println!("== encode benchmarks (single core) ==");
    let budget = Duration::from_secs(2);

    // N_s = 0: exhaustive per-block search.
    {
        let spec = DecoderSpec::for_sparsity(8, 0.9, 0);
        let (data, mask) = workload(80_000, 0.9, 1);
        let plane = SlicedPlane::new(&data, &mask, spec.n_out);
        let dec = SequentialDecoder::random(spec, 7);
        let enc = ViterbiEncoder::new(dec);
        let r = bench_with_result("viterbi ns0 S=0.9 80k bits", 1, budget, 50, || {
            enc.encode(black_box(&plane))
        });
        println!(
            "  -> {:.1} Mbit/s",
            80_000.0 / r.mean.as_secs_f64() / 1e6
        );
    }

    // N_s = 1.
    {
        let spec = DecoderSpec::for_sparsity(8, 0.9, 1);
        let (data, mask) = workload(80_000, 0.9, 2);
        let plane = SlicedPlane::new(&data, &mask, spec.n_out);
        let enc = ViterbiEncoder::new(SequentialDecoder::random(spec, 7));
        let r = bench_with_result("viterbi ns1 S=0.9 80k bits", 1, budget, 50, || {
            enc.encode(black_box(&plane))
        });
        println!(
            "  -> {:.1} Mbit/s",
            80_000.0 / r.mean.as_secs_f64() / 1e6
        );
    }

    // N_s = 2 exact vs beam — the §Perf headline.
    for (label, beam, bits) in [
        ("viterbi ns2 exact S=0.9", None, 24_000usize),
        ("viterbi ns2 beam=16 S=0.9", Some(16u32), 24_000),
        ("viterbi ns2 beam=8  S=0.9", Some(8), 24_000),
        ("viterbi ns2 beam=4  S=0.9", Some(4), 24_000),
    ] {
        let spec = DecoderSpec::for_sparsity(8, 0.9, 2);
        let (data, mask) = workload(bits, 0.9, 3);
        let plane = SlicedPlane::new(&data, &mask, spec.n_out);
        let dec = SequentialDecoder::random(spec, 7);
        let enc = match beam {
            None => ViterbiEncoder::new(dec),
            Some(b) => ViterbiEncoder::with_beam(dec, b),
        };
        let r = bench_with_result(
            &format!("{label} {bits} bits"),
            0,
            Duration::from_secs(3),
            10,
            || enc.encode(black_box(&plane)),
        );
        let blocks = plane.num_blocks() as f64;
        println!(
            "  -> {:.0} blocks/s, {:.2} Mbit/s, E = {:.2}%",
            blocks / r.mean.as_secs_f64(),
            bits as f64 / r.mean.as_secs_f64() / 1e6,
            enc.encode(&plane).efficiency(),
        );
    }

    // Exact DP per-candidate rate (the popcount-bound inner loop).
    {
        let spec = DecoderSpec::for_sparsity(8, 0.9, 2);
        let (data, mask) = workload(8_000, 0.9, 4);
        let plane = SlicedPlane::new(&data, &mask, spec.n_out);
        let enc = ViterbiEncoder::new(SequentialDecoder::random(spec, 7));
        let r = bench_with_result(
            "viterbi ns2 exact 8k bits (candidate rate)",
            0,
            Duration::from_secs(3),
            10,
            || enc.encode(black_box(&plane)),
        );
        let cands = plane.num_blocks() as f64 * (1u64 << 24) as f64;
        println!(
            "  -> {:.2}e9 candidate evals/s",
            cands / r.mean.as_secs_f64() / 1e9
        );
    }
}

//! Decoder throughput: the online path. In hardware this is one cycle
//! per block; in software the table decode should be memory-bound.
//! Target (DESIGN.md §7): ≥ 1 Gbit/s reconstructed single-thread.

use f2f::bench_util::{bench_with_result, black_box};
use f2f::decoder::{DecoderSpec, SequentialDecoder};
use f2f::rng::Rng;
use std::time::Duration;

fn main() {
    println!("== decode benchmarks ==");
    let budget = Duration::from_secs(2);
    for (n_s, n_out) in [(0usize, 80usize), (1, 80), (2, 80), (2, 26)] {
        let spec = DecoderSpec::new(8, n_out, n_s);
        let dec = SequentialDecoder::random(spec, 1);
        let l = 125_000; // 10 Mbit at N_out = 80
        let mut rng = Rng::new(2);
        let encoded: Vec<u32> = (0..l + n_s)
            .map(|_| rng.below(256) as u32)
            .collect();
        let r = bench_with_result(
            &format!("decode_stream ns{n_s} N_out={n_out} l={l}"),
            1,
            budget,
            50,
            || dec.decode_stream(black_box(&encoded)),
        );
        let bits = (l * n_out) as f64;
        println!(
            "  -> {:.2} Gbit/s reconstructed",
            bits / r.mean.as_secs_f64() / 1e9
        );
    }

    // decode straight into a flat bit-plane (includes packing).
    {
        let spec = DecoderSpec::new(8, 80, 2);
        let dec = SequentialDecoder::random(spec, 1);
        let n_bits = 1_000_000;
        let l = spec.num_blocks(n_bits);
        let mut rng = Rng::new(3);
        let encoded: Vec<u32> = (0..l + 2)
            .map(|_| rng.below(256) as u32)
            .collect();
        let r = bench_with_result(
            "decode_stream_to_bits 1 Mbit",
            1,
            budget,
            50,
            || dec.decode_stream_to_bits(black_box(&encoded), n_bits),
        );
        println!(
            "  -> {:.2} Gbit/s into packed plane",
            n_bits as f64 / r.mean.as_secs_f64() / 1e9
        );
    }
}

//! Figure S.10's timing study as a bench target: CSR SpMM vs dense GEMM
//! vs the fixed-to-fixed decode-then-GEMV path.

use f2f::bench_util::{bench_with_result, black_box};
use f2f::rng::Rng;
use f2f::sparse::{gemm, CsrMatrix, DenseMatrix};
use std::time::Duration;

fn main() {
    println!("== spmv/spmm benchmarks (Fig. S.10 shape) ==");
    let n = 1024;
    let budget = Duration::from_secs(2);
    let mut rng = Rng::new(1);
    for &s in &[0.7f64, 0.9, 0.95] {
        let a = DenseMatrix::random_sparse(n, n, s, &mut rng);
        let csr = CsrMatrix::from_dense(&a);
        for &k in &[1usize, 8, 32] {
            let b = DenseMatrix::random_sparse(n, k, 0.0, &mut rng);
            let rd = bench_with_result(
                &format!("dense gemm {n}x{n} k={k} (S={s})"),
                1,
                budget,
                20,
                || gemm(black_box(&a), black_box(&b)),
            );
            let rs = bench_with_result(
                &format!("csr   spmm {n}x{n} k={k} (S={s})"),
                1,
                budget,
                20,
                || csr.spmm(black_box(&b)),
            );
            println!(
                "  -> csr/dense time ratio = {:.3} (<1 means CSR wins)",
                rs.mean.as_secs_f64() / rd.mean.as_secs_f64()
            );
        }
    }

    // Algorithm 2 amortization: decode once, then GEMV many times.
    {
        use f2f::models::{quantize_i8, LayerSpec, SyntheticLayer, WeightGen};
        use f2f::pipeline::{CompressionConfig, Compressor};
        use f2f::sparse::DecodedLayer;
        let spec =
            LayerSpec { name: "b".into(), rows: 256, cols: 1024 };
        let layer =
            SyntheticLayer::generate(&spec, WeightGen::default(), 2);
        let (q, scale) = quantize_i8(&layer.weights);
        let (cl, _) = Compressor::new(CompressionConfig {
            sparsity: 0.9,
            n_s: 1,
            ..Default::default()
        })
        .compress_i8("b", 256, 1024, &q, scale);

        let rd = bench_with_result(
            "decode 256x1024 INT8 layer (one-time)",
            1,
            budget,
            20,
            || DecodedLayer::from_compressed(black_box(&cl)),
        );
        let decoded = DecodedLayer::from_compressed(&cl);
        let x: Vec<f32> = (0..1024).map(|i| (i as f32).sin()).collect();
        let rg = bench_with_result(
            "gemv on decoded layer (per request)",
            10,
            budget,
            10_000,
            || decoded.gemv(black_box(&x)),
        );
        println!(
            "  -> decode amortizes over {:.0} requests",
            rd.mean.as_secs_f64() / rg.mean.as_secs_f64()
        );
    }
}

//! Whole-pipeline benchmarks: compress + decompress a layer end to end,
//! and the container codec.

use f2f::bench_util::{bench_with_result, black_box};
use f2f::container::{read_container, write_container, Container};
use f2f::models::{quantize_i8, LayerSpec, SyntheticLayer, WeightGen};
use f2f::pipeline::{CompressionConfig, Compressor};
use f2f::sparse::DecodedLayer;
use std::time::Duration;

fn main() {
    println!("== pipeline benchmarks ==");
    let budget = Duration::from_secs(3);
    let spec = LayerSpec { name: "p".into(), rows: 32, cols: 512 };
    let layer = SyntheticLayer::generate(&spec, WeightGen::default(), 1);
    let (q, scale) = quantize_i8(&layer.weights);

    for (label, n_s, beam) in [
        ("compress i8 32x512 ns0", 0usize, None),
        ("compress i8 32x512 ns1", 1, None),
        ("compress i8 32x512 ns2 beam8", 2, Some(8u32)),
    ] {
        let cfg = CompressionConfig {
            sparsity: 0.9,
            n_s,
            beam,
            ..Default::default()
        };
        let c = Compressor::new(cfg);
        let r = bench_with_result(label, 0, budget, 20, || {
            c.compress_i8("p", 32, 512, black_box(&q), scale)
        });
        let bits = (32 * 512 * 8) as f64;
        println!(
            "  -> {:.2} Mbit/s compressed",
            bits / r.mean.as_secs_f64() / 1e6
        );
    }

    // Decompression (the serving-startup cost).
    let cfg = CompressionConfig {
        sparsity: 0.9,
        n_s: 2,
        beam: Some(8),
        ..Default::default()
    };
    let (cl, _) =
        Compressor::new(cfg).compress_i8("p", 32, 512, &q, scale);
    let r = bench_with_result("decompress i8 32x512", 1, budget, 200, || {
        DecodedLayer::from_compressed(black_box(&cl))
    });
    println!(
        "  -> {:.2} Mbit/s decompressed",
        (32.0 * 512.0 * 8.0) / r.mean.as_secs_f64() / 1e6
    );

    // Container codec.
    let container = Container { layers: vec![cl] };
    let bytes = write_container(&container);
    bench_with_result("container write", 1, budget, 2000, || {
        write_container(black_box(&container))
    });
    bench_with_result("container read", 1, budget, 2000, || {
        read_container(black_box(&bytes)).unwrap()
    });
    println!("  container size: {} bytes", bytes.len());
}

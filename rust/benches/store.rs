//! Model-store benchmarks: serial vs pooled decode throughput, cold vs
//! warm serve latency through the `ModelStore`/`ModelBackend` path, the
//! readahead pipeline (decode of layer `i+1` overlapping layer `i`'s
//! GEMV) against the decode-on-miss serial baseline, the cost-model
//! `auto` readahead planner against the fixed depth-1 pipeline (with
//! the per-layer decode/GEMV telemetry it plans from), and the sharded
//! cold serve (the same model behind 1/2/4 stores through a
//! `ShardRouter`), the span-recording overhead of the `obs` layer
//! on the warm path (runtime kill switch on vs off, `obs_overhead_pct`,
//! target <3%), and the live stats socket's cost on the same warm path
//! (`stats_poll_overhead_pct`: a 10 Hz `f2f top`-shaped poller against
//! the unpolled serve), the scalar vs word-parallel decode kernels
//! (`decode_kernel_scalar` / `decode_kernel_word`), and the fused
//! bit-plane serve against the materialized baseline
//! (`serve_cold_fused` / `serve_warm_fused`, `speedup_vs_materialized`),
//! and the model zoo: N tenants interleaved through one shared-budget
//! `ModelRegistry` (`serve_zoo_{2,4}_models`) with the shared LRU
//! pitted against the same total bytes statically partitioned per
//! tenant (`hit_rate_shared_vs_partitioned`).
//! Emits machine-readable `BENCH_store.json` next to the human output
//! to keep the perf trajectory moving.

use f2f::bench_util::{bench_with_result, black_box, timed_pass, JsonReport};
use f2f::container::{
    split_container, write_container_v2, CompressedLayer, Container,
    ShardAssignment,
};
use f2f::coordinator::Backend;
use f2f::kernels::{DecodeMode, KernelKind};
use f2f::models::{compressed_mlp, MlpConfig};
use f2f::shard::ShardRouter;
use f2f::sparse::DecodedLayer;
use f2f::store::{
    DecodePool, ModelBackend, ModelStore, ReadaheadPolicy, StoreConfig,
};
use std::sync::Arc;
use std::time::Duration;

const LAYERS: usize = 4;
const WIDTH: usize = 256;

fn build_model() -> Container {
    compressed_mlp(&MlpConfig {
        seed: 77,
        ..MlpConfig::uniform(LAYERS, WIDTH)
    })
    .0
}

fn main() {
    println!("== model store benchmarks ==");
    let budget = Duration::from_secs(2);
    let mut json = JsonReport::new("store: decode pool + LRU serving");

    let t0 = std::time::Instant::now();
    let model = build_model();
    println!(
        "model: {LAYERS} layers of {WIDTH}x{WIDTH} INT8 (compressed in {:?})",
        t0.elapsed()
    );
    let refs: Vec<&CompressedLayer> = model.layers.iter().collect();
    let decoded_bits = (LAYERS * WIDTH * WIDTH * 8) as f64;

    // --- serial vs pooled decode ---
    let serial = bench_with_result(
        "decode serial (from_compressed per layer)",
        1,
        budget,
        50,
        || {
            refs.iter()
                .map(|l| DecodedLayer::from_compressed(l))
                .collect::<Vec<_>>()
        },
    );
    json.add("decode_serial", &serial);
    json.metric(
        "decode_serial",
        "gbit_per_s",
        decoded_bits / serial.mean.as_secs_f64() / 1e9,
    );

    let mut best_pooled = serial;
    for workers in [2usize, 4, 8] {
        let pool = DecodePool::new(workers);
        let r = bench_with_result(
            &format!("decode pooled workers={workers}"),
            1,
            budget,
            50,
            || pool.decode_many(black_box(&refs)),
        );
        let case = format!("decode_pooled_w{workers}");
        json.add(&case, &r);
        json.metric(
            &case,
            "gbit_per_s",
            decoded_bits / r.mean.as_secs_f64() / 1e9,
        );
        json.metric(
            &case,
            "speedup_vs_serial",
            serial.mean.as_secs_f64() / r.mean.as_secs_f64(),
        );
        if r.mean < best_pooled.mean {
            best_pooled = r;
        }
    }
    println!(
        "  -> best pooled speedup {:.2}x over serial",
        serial.mean.as_secs_f64() / best_pooled.mean.as_secs_f64()
    );

    // --- decode kernels: scalar per-bit loop vs word-parallel ---
    // Same end-to-end decode (GF(2) planes + corrections + reassembly),
    // explicit kernel choice on each side; the default path is whatever
    // `F2F_KERNEL` selects, so this series keeps both spellings honest.
    let kern_scalar = bench_with_result(
        "decode kernel scalar (per-bit decode + reassembly)",
        1,
        budget,
        50,
        || {
            refs.iter()
                .map(|l| {
                    DecodedLayer::from_compressed_with(
                        l,
                        KernelKind::Scalar,
                    )
                })
                .collect::<Vec<_>>()
        },
    );
    json.add("decode_kernel_scalar", &kern_scalar);
    json.metric(
        "decode_kernel_scalar",
        "gbit_per_s",
        decoded_bits / kern_scalar.mean.as_secs_f64() / 1e9,
    );
    let kern_word = bench_with_result(
        "decode kernel word (u64 blocks + 64x64 transpose)",
        1,
        budget,
        50,
        || {
            refs.iter()
                .map(|l| {
                    DecodedLayer::from_compressed_with(
                        l,
                        KernelKind::Word,
                    )
                })
                .collect::<Vec<_>>()
        },
    );
    json.add("decode_kernel_word", &kern_word);
    json.metric(
        "decode_kernel_word",
        "gbit_per_s",
        decoded_bits / kern_word.mean.as_secs_f64() / 1e9,
    );
    json.metric(
        "decode_kernel_word",
        "speedup_vs_scalar",
        kern_scalar.mean.as_secs_f64() / kern_word.mean.as_secs_f64(),
    );
    println!(
        "  -> word-parallel decode kernel {:.2}x over scalar",
        kern_scalar.mean.as_secs_f64() / kern_word.mean.as_secs_f64()
    );

    // --- cold vs warm serve through the store ---
    let bytes = write_container_v2(&model);
    let x: Vec<f32> = (0..WIDTH).map(|i| (i as f32 * 0.01).sin()).collect();

    let cold = bench_with_result(
        "serve cold (fresh store, decode on miss)",
        1,
        budget,
        50,
        || {
            let store = Arc::new(
                ModelStore::open_bytes(
                    bytes.clone(),
                    StoreConfig::default(),
                )
                .expect("open store"),
            );
            let mut backend = ModelBackend::sequential(store)
                .expect("backend")
                .with_readahead(ReadaheadPolicy::off());
            backend
                .forward_batch(std::slice::from_ref(&x))
                .expect("serve")
        },
    );
    json.add("serve_cold", &cold);

    // --- fused cold serve: bit-plane GEMV, dense f32 never built ---
    // Identical request shape to `serve_cold`; the store caches
    // `FusedLayer`s and the backend executes y = W·x straight off the
    // planes. The cold win is skipping the transpose/reassembly and
    // touching ~n_w/32 of the dense bytes.
    let cold_fused = bench_with_result(
        "serve cold fused (decode-mode fused, no dense materialize)",
        1,
        budget,
        50,
        || {
            let store = Arc::new(
                ModelStore::open_bytes(
                    bytes.clone(),
                    StoreConfig {
                        decode_mode: DecodeMode::Fused,
                        ..StoreConfig::default()
                    },
                )
                .expect("open store"),
            );
            let mut backend = ModelBackend::sequential(store)
                .expect("backend")
                .with_readahead(ReadaheadPolicy::off());
            backend
                .forward_batch(std::slice::from_ref(&x))
                .expect("serve")
        },
    );
    json.add("serve_cold_fused", &cold_fused);
    json.metric(
        "serve_cold_fused",
        "speedup_vs_materialized",
        cold.mean.as_secs_f64() / cold_fused.mean.as_secs_f64(),
    );
    println!(
        "  -> fused cold serve {:.2}x vs materialized",
        cold.mean.as_secs_f64() / cold_fused.mean.as_secs_f64()
    );

    // --- cold serve, readahead pipeline vs decode-on-miss serial ---
    // A small batch gives each layer's GEMV phase enough weight for the
    // next layer's background decode to overlap with.
    let batch: Vec<Vec<f32>> = (0..8)
        .map(|i| {
            (0..WIDTH)
                .map(|j| ((i * WIDTH + j) as f32 * 0.01).sin())
                .collect()
        })
        .collect();
    let cold_serial = bench_with_result(
        "serve cold serial (1 decode worker, no readahead)",
        1,
        budget,
        50,
        || {
            let store = Arc::new(
                ModelStore::open_bytes(
                    bytes.clone(),
                    StoreConfig {
                        cache_budget_bytes: usize::MAX,
                        decode_workers: 1,
                        ..StoreConfig::default()
                    },
                )
                .expect("open store"),
            );
            let mut backend = ModelBackend::sequential(store)
                .expect("backend")
                .with_readahead(ReadaheadPolicy::off());
            backend.forward_batch(black_box(&batch)).expect("serve")
        },
    );
    json.add("serve_cold_serial", &cold_serial);
    // Same worker count as the readahead series, readahead off: the
    // honest control that isolates the overlap win from plain
    // decode-worker parallelism.
    let cold_parallel = bench_with_result(
        "serve cold parallel (host workers, no readahead)",
        1,
        budget,
        50,
        || {
            let store = Arc::new(
                ModelStore::open_bytes(
                    bytes.clone(),
                    StoreConfig::default(),
                )
                .expect("open store"),
            );
            let mut backend = ModelBackend::sequential(store)
                .expect("backend")
                .with_readahead(ReadaheadPolicy::off());
            backend.forward_batch(black_box(&batch)).expect("serve")
        },
    );
    json.add("serve_cold_parallel", &cold_parallel);
    let cold_readahead = bench_with_result(
        "serve cold readahead (decode i+1 overlaps GEMV of i)",
        1,
        budget,
        50,
        || {
            let store = Arc::new(
                ModelStore::open_bytes(
                    bytes.clone(),
                    StoreConfig::default(),
                )
                .expect("open store"),
            );
            let mut backend = ModelBackend::sequential(store)
                .expect("backend")
                .with_readahead(ReadaheadPolicy::layers(1));
            backend.forward_batch(black_box(&batch)).expect("serve")
        },
    );
    json.add("serve_cold_readahead", &cold_readahead);
    json.metric(
        "serve_cold_readahead",
        "speedup_vs_serial",
        cold_serial.mean.as_secs_f64() / cold_readahead.mean.as_secs_f64(),
    );
    json.metric(
        "serve_cold_readahead",
        "speedup_vs_parallel_miss",
        cold_parallel.mean.as_secs_f64()
            / cold_readahead.mean.as_secs_f64(),
    );
    println!(
        "  -> readahead cold serve {:.2}x over decode-on-miss serial, \
         {:.2}x over same-width decode-on-miss",
        cold_serial.mean.as_secs_f64() / cold_readahead.mean.as_secs_f64(),
        cold_parallel.mean.as_secs_f64()
            / cold_readahead.mean.as_secs_f64()
    );

    // --- auto readahead planner vs fixed depth-1 ---
    // One untimed warmup pass fills a cost table (per-layer decode and
    // GEMV EWMAs); each timed iteration then serves a *cold* store
    // seeded with that profile — the production shape, where the cost
    // model outlives any one store — so the planner runs warm instead
    // of in its depth-1 fallback.
    let cost_snapshot = {
        let store = Arc::new(
            ModelStore::open_bytes(bytes.clone(), StoreConfig::default())
                .expect("open store"),
        );
        let mut backend = ModelBackend::sequential(store.clone())
            .expect("backend")
            .with_readahead(ReadaheadPolicy::layers(1));
        let (_, warm_pass) =
            timed_pass(&mut backend, &batch).expect("warmup pass");
        store.wait_for_idle();
        println!("  (cost-model warmup pass: {warm_pass:?})");
        store.costs().snapshot()
    };
    for (name, c) in &cost_snapshot {
        json.metric("layer_costs", &format!("{name}.decode_ns"), c.decode_ns);
        json.metric("layer_costs", &format!("{name}.gemv_ns"), c.gemv_ns);
    }
    let cold_auto = bench_with_result(
        "serve cold readahead auto (cost-model planner)",
        1,
        budget,
        50,
        || {
            let store = Arc::new(
                ModelStore::open_bytes(
                    bytes.clone(),
                    StoreConfig::default(),
                )
                .expect("open store"),
            );
            store.seed_costs(cost_snapshot.iter().cloned());
            let mut backend = ModelBackend::sequential(store)
                .expect("backend")
                .with_readahead(ReadaheadPolicy::auto());
            backend.forward_batch(black_box(&batch)).expect("serve")
        },
    );
    json.add("serve_cold_readahead_auto", &cold_auto);
    json.metric(
        "serve_cold_readahead_auto",
        "speedup_vs_fixed_depth1",
        cold_readahead.mean.as_secs_f64() / cold_auto.mean.as_secs_f64(),
    );
    println!(
        "  -> auto-planned cold serve {:.2}x vs fixed depth-1",
        cold_readahead.mean.as_secs_f64() / cold_auto.mean.as_secs_f64()
    );

    // --- sharded cold serve: the same model behind 1/2/4 stores ---
    // Baseline is the single-store readahead pipeline above (same
    // batch, same policy): `speedup_vs_single_store` isolates what the
    // multi-store router adds (per-shard decode services warming in
    // parallel) from what readahead already bought.
    let mut inproc_sharded: Vec<(usize, Duration)> = Vec::new();
    for n_shards in [1usize, 2, 4] {
        let (map, shard_bytes) =
            split_container(&bytes, n_shards, ShardAssignment::ByBytes)
                .expect("split container");
        let r = bench_with_result(
            &format!("serve cold sharded ({n_shards} shards, readahead on)"),
            1,
            budget,
            50,
            || {
                let stores: Vec<Arc<ModelStore>> = shard_bytes
                    .iter()
                    .map(|b| {
                        Arc::new(
                            ModelStore::open_bytes(
                                b.clone(),
                                StoreConfig::default(),
                            )
                            .expect("open shard"),
                        )
                    })
                    .collect();
                let mut router = ShardRouter::new(stores, &map)
                    .expect("router")
                    .with_readahead(ReadaheadPolicy::layers(1));
                router.forward_batch(black_box(&batch)).expect("serve")
            },
        );
        let case = format!("serve_cold_sharded_s{n_shards}");
        json.add(&case, &r);
        json.metric(
            &case,
            "speedup_vs_single_store",
            cold_readahead.mean.as_secs_f64() / r.mean.as_secs_f64(),
        );
        println!(
            "  -> {n_shards}-shard cold serve {:.2}x vs single store",
            cold_readahead.mean.as_secs_f64() / r.mean.as_secs_f64()
        );
        inproc_sharded.push((n_shards, r.mean));
    }

    #[cfg(unix)]
    bench_multiproc(&mut json, &bytes, &batch, &inproc_sharded);
    #[cfg(not(unix))]
    let _ = &inproc_sharded;

    let store = Arc::new(
        ModelStore::open_bytes(bytes.clone(), StoreConfig::default())
            .expect("open store"),
    );
    let mut backend = ModelBackend::sequential(store.clone())
        .expect("backend")
        .with_readahead(ReadaheadPolicy::off());
    backend.prefetch_all().expect("prefetch");
    let warm = bench_with_result(
        "serve warm (cached decoded layers)",
        1,
        budget,
        200,
        || {
            backend
                .forward_batch(black_box(std::slice::from_ref(&x)))
                .expect("serve")
        },
    );
    json.add("serve_warm", &warm);
    json.metric(
        "serve_warm",
        "cold_over_warm",
        cold.mean.as_secs_f64() / warm.mean.as_secs_f64(),
    );
    let m = store.metrics();
    println!(
        "  -> warm cache: hits={} misses={} (cold/warm = {:.1}x)",
        m.hits,
        m.misses,
        cold.mean.as_secs_f64() / warm.mean.as_secs_f64()
    );

    // --- fused warm serve: the steady-state GEMV trade ---
    // Cache fully warm on both sides, so this isolates the per-request
    // cost of the bit-plane GEMV (n_w plane passes + mask pass) against
    // the dense unit-stride multiply it replaces. The fused side pays
    // more FLOP-shaped work per element but reads ~n_w/32 of the bytes;
    // which side wins is memory-bound vs compute-bound, so the ratio is
    // tracked rather than asserted.
    let fused_store = Arc::new(
        ModelStore::open_bytes(
            bytes.clone(),
            StoreConfig {
                decode_mode: DecodeMode::Fused,
                ..StoreConfig::default()
            },
        )
        .expect("open store"),
    );
    let mut fused_backend = ModelBackend::sequential(fused_store)
        .expect("backend")
        .with_readahead(ReadaheadPolicy::off());
    fused_backend.prefetch_all().expect("prefetch");
    let warm_fused = bench_with_result(
        "serve warm fused (cached bit-plane layers)",
        1,
        budget,
        200,
        || {
            fused_backend
                .forward_batch(black_box(std::slice::from_ref(&x)))
                .expect("serve")
        },
    );
    json.add("serve_warm_fused", &warm_fused);
    json.metric(
        "serve_warm_fused",
        "speedup_vs_materialized",
        warm.mean.as_secs_f64() / warm_fused.mean.as_secs_f64(),
    );
    println!(
        "  -> fused warm serve {:.2}x vs materialized",
        warm.mean.as_secs_f64() / warm_fused.mean.as_secs_f64()
    );

    // --- observability overhead: runtime kill switch on vs off ---
    // The warm serve above ran with span recording on (the default);
    // the same backend re-measured with the recorder disabled isolates
    // what the per-layer spans and cache events cost on the hot path.
    // Target: <3% mean overhead — the recorder is a fixed ring of
    // try_lock slots, no allocation, relaxed atomics. (The
    // compiled-out path is covered by the `--no-default-features` CI
    // leg; this measures the shipping default.)
    f2f::obs::set_enabled(false);
    let warm_obs_off = bench_with_result(
        "serve warm (span recording disabled)",
        1,
        budget,
        200,
        || {
            backend
                .forward_batch(black_box(std::slice::from_ref(&x)))
                .expect("serve")
        },
    );
    f2f::obs::set_enabled(true);
    let obs_overhead_pct = (warm.mean.as_secs_f64()
        / warm_obs_off.mean.as_secs_f64()
        - 1.0)
        * 100.0;
    json.add("serve_warm_obs_off", &warm_obs_off);
    json.metric("serve_warm", "obs_overhead_pct", obs_overhead_pct);
    println!(
        "  -> span recording overhead {obs_overhead_pct:.2}% on the \
         warm path (target <3%)"
    );

    // --- stats socket overhead: warm serve with a live 10 Hz poller ---
    // The same warm backend re-measured while an `f2f top`-shaped
    // client polls the stats socket at 10 Hz: the whole live ops plane
    // (socket accept, snapshot closures walking the store metrics,
    // JSON render) billed against the serving hot path.
    #[cfg(unix)]
    {
        use f2f::obs::stats::{poll_stats, LiveSources, StatsServer};
        use std::sync::atomic::{AtomicBool, Ordering};

        let socket = std::env::temp_dir()
            .join(format!("f2f-bench-stats-{}.sock", std::process::id()));
        let live = {
            let s1 = store.clone();
            let s2 = store.clone();
            LiveSources::new(
                Arc::new(move || {
                    vec![("store".to_string(), s1.metrics())]
                }),
                Arc::new(move || s2.costs().snapshot()),
            )
        };
        let server =
            StatsServer::start(&socket, live).expect("stats server");
        let stop = Arc::new(AtomicBool::new(false));
        let poller = {
            let stop = stop.clone();
            let socket = socket.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    let _ =
                        poll_stats(&socket, Duration::from_secs(1));
                    std::thread::sleep(Duration::from_millis(100));
                }
            })
        };
        let warm_polled = bench_with_result(
            "serve warm (stats socket polled at 10 Hz)",
            1,
            budget,
            200,
            || {
                backend
                    .forward_batch(black_box(std::slice::from_ref(&x)))
                    .expect("serve")
            },
        );
        stop.store(true, Ordering::Release);
        let _ = poller.join();
        drop(server);
        let stats_poll_overhead_pct = (warm_polled.mean.as_secs_f64()
            / warm.mean.as_secs_f64()
            - 1.0)
            * 100.0;
        json.add("serve_warm_stats_polled", &warm_polled);
        json.metric(
            "serve_warm",
            "stats_poll_overhead_pct",
            stats_poll_overhead_pct,
        );
        println!(
            "  -> live stats polling overhead \
             {stats_poll_overhead_pct:.2}% on the warm path"
        );
    }

    // --- budgeted serve: eviction-heavy traffic, production policy ---
    let tight = WIDTH * WIDTH * 4 * 2; // two of four layers fit
    let store = Arc::new(
        ModelStore::open_bytes(
            bytes,
            StoreConfig {
                cache_budget_bytes: tight,
                decode_workers: 0,
                ..StoreConfig::default()
            },
        )
        .expect("open store"),
    );
    let mut backend = ModelBackend::sequential(store.clone())
        .expect("backend")
        .with_readahead(ReadaheadPolicy::layers(1));
    let budgeted = bench_with_result(
        "serve budgeted (cache holds 2/4 layers, readahead on)",
        1,
        budget,
        50,
        || {
            backend
                .forward_batch(black_box(std::slice::from_ref(&x)))
                .expect("serve")
        },
    );
    json.add("serve_budgeted", &budgeted);
    store.wait_for_idle();
    let m = store.metrics();
    json.metric("serve_budgeted", "evictions", m.evictions as f64);
    json.metric(
        "serve_budgeted",
        "redundant_decodes",
        m.redundant_decodes as f64,
    );
    println!(
        "  -> budgeted cache: decodes={} evictions={} prefetches={} \
         skips={} redundant={}",
        m.decodes, m.evictions, m.prefetches, m.readahead_skips,
        m.redundant_decodes
    );

    // --- model zoo: N tenants behind one shared-budget registry ---
    // Each tenant is a 3-layer 128-wide MLP; the interleaved load is
    // skewed (tenant 0 takes three requests per round, the rest one)
    // and the shared budget holds half the combined decoded bytes, so
    // every round works the cross-model LRU. The hit-rate series pins
    // the zoo's core claim: one shared budget beats the same total
    // bytes statically partitioned per tenant, because the shared LRU
    // reassigns the cold tenants' slack to the hot one.
    {
        use f2f::registry::{ModelRegistry, ZooModel};

        const ZOO_LAYERS: usize = 3;
        const ZOO_WIDTH: usize = 128;
        let build_zoo = |n: usize| -> Vec<ZooModel> {
            (0..n)
                .map(|i| {
                    let (container, _) = compressed_mlp(&MlpConfig {
                        seed: 100 + i as u64,
                        name_prefix: format!("t{i}/fc"),
                        ..MlpConfig::uniform(ZOO_LAYERS, ZOO_WIDTH)
                    });
                    ZooModel::new(format!("t{i}"), container)
                })
                .collect()
        };
        // Per round: tenant 0 three times, every other tenant once.
        let schedule = |ids: &[String]| -> Vec<String> {
            let mut seq = Vec::new();
            for _ in 0..6 {
                for _ in 0..3 {
                    seq.push(ids[0].clone());
                }
                for id in &ids[1..] {
                    seq.push(id.clone());
                }
            }
            seq
        };
        let zx: Vec<Vec<f32>> = (0..2)
            .map(|i| {
                (0..ZOO_WIDTH)
                    .map(|j| ((i * ZOO_WIDTH + j) as f32 * 0.01).sin())
                    .collect()
            })
            .collect();
        let per_tenant_bytes = ZOO_LAYERS * ZOO_WIDTH * ZOO_WIDTH * 4;

        for n_models in [2usize, 4] {
            let zoo = build_zoo(n_models);
            let ids: Vec<String> =
                zoo.iter().map(|m| m.id.clone()).collect();
            let seq = schedule(&ids);
            let byte_budget = per_tenant_bytes * n_models / 2;
            let r = bench_with_result(
                &format!(
                    "serve zoo ({n_models} tenants, shared budget, \
                     skewed interleave)"
                ),
                1,
                budget,
                12,
                || {
                    let mut reg = ModelRegistry::new(
                        &zoo,
                        StoreConfig {
                            cache_budget_bytes: byte_budget,
                            ..StoreConfig::default()
                        },
                    )
                    .expect("registry")
                    .with_readahead(ReadaheadPolicy::layers(1));
                    for id in &seq {
                        black_box(
                            reg.forward_model_batch(id, black_box(&zx))
                                .expect("zoo serve"),
                        );
                    }
                    reg.wait_for_idle();
                },
            );
            json.add(&format!("serve_zoo_{n_models}_models"), &r);
        }

        // Hit rate under the same workload and the same total bytes:
        // one shared-budget registry vs one registry per tenant, each
        // capped at its static 1/N slice. A slice below a tenant's
        // full chain thrashes LRU on the cyclic layer walk, so the
        // partitioned rate can bottom out near zero — the ratio's
        // denominator is floored to keep the metric finite.
        let n_models = 4usize;
        let zoo = build_zoo(n_models);
        let ids: Vec<String> = zoo.iter().map(|m| m.id.clone()).collect();
        let seq = schedule(&ids);
        let total_budget = per_tenant_bytes * n_models / 2;

        let shared_rate = {
            let mut reg = ModelRegistry::new(
                &zoo,
                StoreConfig {
                    cache_budget_bytes: total_budget,
                    ..StoreConfig::default()
                },
            )
            .expect("registry")
            .with_readahead(ReadaheadPolicy::layers(1));
            for id in &seq {
                reg.forward_model_batch(id, &zx).expect("zoo serve");
            }
            reg.wait_for_idle();
            let m = reg.store_metrics().expect("zoo metrics");
            m.hits as f64 / (m.hits + m.misses).max(1) as f64
        };
        let partitioned_rate = {
            let mut regs: Vec<ModelRegistry> = zoo
                .iter()
                .map(|m| {
                    ModelRegistry::new(
                        std::slice::from_ref(m),
                        StoreConfig {
                            cache_budget_bytes: total_budget / n_models,
                            ..StoreConfig::default()
                        },
                    )
                    .expect("solo registry")
                    .with_readahead(ReadaheadPolicy::layers(1))
                })
                .collect();
            for id in &seq {
                let i = ids
                    .iter()
                    .position(|x| x == id)
                    .expect("known tenant");
                regs[i]
                    .forward_model_batch(id, &zx)
                    .expect("solo serve");
            }
            let (mut hits, mut misses) = (0u64, 0u64);
            for reg in &regs {
                reg.wait_for_idle();
                let m = reg.store_metrics().expect("solo metrics");
                hits += m.hits;
                misses += m.misses;
            }
            hits as f64 / (hits + misses).max(1) as f64
        };
        json.metric(
            "serve_zoo_4_models",
            "hit_rate_shared",
            shared_rate,
        );
        json.metric(
            "serve_zoo_4_models",
            "hit_rate_partitioned",
            partitioned_rate,
        );
        json.metric(
            "serve_zoo_4_models",
            "hit_rate_shared_vs_partitioned",
            shared_rate / partitioned_rate.max(0.01),
        );
        println!(
            "  -> zoo hit rate: shared {:.1}% vs partitioned {:.1}% \
             (same total bytes, skewed tenants)",
            shared_rate * 100.0,
            partitioned_rate * 100.0
        );
    }

    json.write("BENCH_store.json").expect("write BENCH_store.json");
    println!("wrote BENCH_store.json");
}

/// Cold multi-process serve: spawn N supervised `f2f shard-worker`
/// processes, route one cold batch over IPC, shut the tier down —
/// the full lifecycle a short-lived deployment pays, timed per
/// iteration. `speedup_vs_inproc_router` pins the fork + socket +
/// weight-transfer overhead against the in-process shard router on
/// the *same* partition, so the IPC tax stays visible in the perf
/// trajectory (values below 1.0 are expected and are the point).
#[cfg(unix)]
fn bench_multiproc(
    json: &mut JsonReport,
    bytes: &[u8],
    batch: &[Vec<f32>],
    inproc: &[(usize, Duration)],
) {
    use f2f::ipc::{ProcRouter, Supervisor, WorkerSpec};
    use std::path::PathBuf;

    let dir = std::env::temp_dir()
        .join(format!("f2f-bench-multiproc-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench workdir");
    let binary = PathBuf::from(env!("CARGO_BIN_EXE_f2f"));
    let index =
        f2f::container::ContainerIndex::parse(bytes).expect("index");
    for n_workers in [1usize, 2, 4] {
        let (map, shard_bytes) =
            split_container(bytes, n_workers, ShardAssignment::ByBytes)
                .expect("split container");
        let mut specs = Vec::new();
        for (i, b) in shard_bytes.iter().enumerate() {
            let shard_path =
                dir.join(format!("s{n_workers}-shard{i}.f2f"));
            std::fs::write(&shard_path, b).expect("write shard");
            specs.push(WorkerSpec::new(
                &binary,
                shard_path,
                dir.join(format!("s{n_workers}-shard{i}.sock")),
            ));
        }
        let r = bench_with_result(
            &format!(
                "serve cold multiproc ({n_workers} workers, \
                 spawn+serve+stop)"
            ),
            1,
            Duration::from_secs(2),
            12,
            || {
                let sup = Supervisor::spawn(specs.clone())
                    .expect("spawn workers");
                let mut router = ProcRouter::new(
                    sup.clients().to_vec(),
                    &map,
                    &index,
                )
                .expect("router")
                .with_readahead(ReadaheadPolicy::layers(1))
                .with_supervisor(sup.clone());
                let ys = router
                    .forward_batch(black_box(batch))
                    .expect("serve");
                sup.shutdown();
                ys
            },
        );
        let case = format!("serve_cold_multiproc_s{n_workers}");
        json.add(&case, &r);
        if let Some((_, base)) =
            inproc.iter().find(|(n, _)| *n == n_workers)
        {
            let speedup = base.as_secs_f64() / r.mean.as_secs_f64();
            json.metric(&case, "speedup_vs_inproc_router", speedup);
            println!(
                "  -> {n_workers}-worker multiproc cold serve \
                 {speedup:.2}x vs in-proc router (fork + IPC tax)"
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

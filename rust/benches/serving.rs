//! Serving benchmarks: coordinator throughput/latency vs batch size —
//! the L3 perf target (batching ≥ 4× the batch=1 throughput).

use f2f::coordinator::{InferenceServer, NativeBackend, ServerConfig};
use f2f::models::{quantize_i8, LayerSpec, SyntheticLayer, WeightGen};
use f2f::pipeline::{CompressionConfig, Compressor};
use f2f::rng::Rng;
use std::time::{Duration, Instant};

fn main() {
    println!("== serving benchmarks ==");
    let spec = LayerSpec { name: "s".into(), rows: 256, cols: 512 };
    let layer = SyntheticLayer::generate(&spec, WeightGen::default(), 1);
    let (q, scale) = quantize_i8(&layer.weights);
    let (cl, _) = Compressor::new(CompressionConfig {
        sparsity: 0.9,
        n_s: 1,
        ..Default::default()
    })
    .compress_i8("s", 256, 512, &q, scale);

    let requests = 4000;
    for max_batch in [1usize, 4, 16, 64] {
        let cl2 = cl.clone();
        let server = InferenceServer::start(
            ServerConfig {
                max_batch,
                batch_timeout: Duration::from_micros(500),
                queue_capacity: 1 << 14,
            },
            move || Box::new(NativeBackend::new(&cl2)),
        )
        .unwrap();
        let mut rng = Rng::new(2);
        let xs: Vec<Vec<f32>> = (0..64)
            .map(|_| (0..512).map(|_| rng.next_f32() - 0.5).collect())
            .collect();
        let t0 = Instant::now();
        let pending: Vec<_> = (0..requests)
            .map(|i| server.infer_async(xs[i % 64].clone()))
            .collect();
        for p in pending {
            p.recv().unwrap().unwrap();
        }
        let dt = t0.elapsed();
        let m = server.metrics();
        println!(
            "max_batch={max_batch:<3} {:>8.0} req/s  mean_batch={:<5.1} p50={:?} p99={:?}",
            requests as f64 / dt.as_secs_f64(),
            m.mean_batch_size(),
            m.p50,
            m.p99,
        );
        server.shutdown();
    }
}

//! `f2f` — CLI for the fixed-to-fixed compression library.
//!
//! Subcommands:
//!
//! * `f2f repro <id> [...]` — regenerate a paper table/figure (see
//!   DESIGN.md §5 for ids: fig1 fig4a fig4b fig4c fig8 fig9 table1
//!   table2 table3 s4 s5 s10 s12 s13 entropy beamcheck all).
//! * `f2f compress --model <transformer|resnet50> [...]` — compress a
//!   synthetic model to a container file (indexed v2 by default; pass
//!   `--v1` for the legacy layout) and report per-layer stats.
//! * `f2f inspect <container>` — print a container's inventory (v1/v2).
//! * `f2f serve [...]` — compress a multi-layer model, serve it through
//!   the model store (`--cache-kb <n>` decoded-weight budget,
//!   `--decode-threads <n>` decode-service width, `--layers`, `--width`,
//!   `--readahead on|off|<depth>` async warm-ahead) and run a
//!   self-driven load test.
//! * `f2f hw --s <S> --nin <N> --ns <N>` — Appendix G hardware cost.

use anyhow::{bail, Result};
use f2f::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("repro") => f2f::repro::run(args),
        Some("compress") => cmd_compress(args),
        Some("inspect") => cmd_inspect(args),
        Some("serve") => cmd_serve(args),
        Some("hw") => cmd_hw(args),
        _ => {
            eprintln!(
                "usage: f2f <repro|compress|inspect|serve|hw> [options]\n\
                 try: f2f repro table1 --bits 100000"
            );
            Ok(())
        }
    }
}

fn cmd_compress(args: &Args) -> Result<()> {
    use f2f::container::Dtype;
    use f2f::models::{resnet50_layers, transformer_layers, SyntheticLayer, WeightGen};
    use f2f::pipeline::{CompressionConfig, Compressor};
    use f2f::pruning::PruneMethod;

    let model = args.get_str("model", "transformer");
    let sparsity: f64 = args.get("s", 0.9)?;
    let n_s: usize = args.get("ns", 2)?;
    let max_w: usize = args.get("weights", 8192)?;
    let n_layers: usize = args.get("layers", 4)?;
    let seed: u64 = args.get("seed", 0xF2F)?;
    let beam: i64 = args.get("beam", 8)?;
    let out = args.get_str("out", "model.f2f");
    let dtype = match args.get_str("dtype", "i8").as_str() {
        "i8" => Dtype::I8,
        "f32" => Dtype::F32,
        d => bail!("unknown dtype {d}"),
    };

    let specs = match model.as_str() {
        "transformer" => transformer_layers(),
        "resnet50" => resnet50_layers(),
        m => bail!("unknown model {m}"),
    };
    let layers: Vec<SyntheticLayer> = specs
        .iter()
        .step_by((specs.len() / n_layers).max(1))
        .take(n_layers)
        .map(|s| {
            SyntheticLayer::generate(s, WeightGen::default(), seed)
                .truncated(max_w)
        })
        .collect();

    let cfg = CompressionConfig {
        sparsity,
        n_s,
        method: PruneMethod::Magnitude,
        invert: dtype == Dtype::F32,
        seed,
        beam: if beam < 0 { None } else { Some(beam as u32) },
        ..Default::default()
    };
    let compressor = Compressor::new(cfg);
    let t0 = std::time::Instant::now();
    let (container, reports) = compressor.compress_model(&layers, dtype);
    let dt = t0.elapsed();

    let mut table = f2f::report::Table::new(
        &format!("compress {model} S={sparsity} N_s={n_s} ({dt:?})"),
        &["layer", "weights", "E%", "mem_reduction%", "coeff_var"],
    );
    for r in &reports {
        table.row(vec![
            r.name.clone(),
            r.n_weights.to_string(),
            format!("{:.2}", r.efficiency),
            format!("{:.2}", r.memory_reduction),
            format!("{:.3}", r.coeff_var),
        ]);
    }
    print!("{}", table.render());
    println!(
        "total: {} -> {} bits ({:.2}% reduction)",
        container.original_bits(),
        container.compressed_bits(),
        container.memory_reduction()
    );
    let bytes = if args.flag("v1") {
        f2f::container::write_container(&container)
    } else {
        f2f::container::write_container_v2(&container)
    };
    std::fs::write(&out, bytes)?;
    println!(
        "wrote {out} ({})",
        if args.flag("v1") { "legacy v1" } else { "indexed v2" }
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let path = args.pos(1)?;
    let bytes = std::fs::read(path)?;
    let layout = if bytes.len() >= 4 && &bytes[..4] == b"F2F2" {
        "v2 indexed"
    } else {
        "v1"
    };
    let c = f2f::container::read_container(&bytes)?;
    let mut table = f2f::report::Table::new(
        &format!("{path} ({} bytes, {layout})", bytes.len()),
        &["layer", "shape", "dtype", "spec", "planes", "mem_reduction%"],
    );
    for l in &c.layers {
        table.row(vec![
            l.name.clone(),
            format!("{}x{}", l.rows, l.cols),
            format!("{:?}", l.dtype),
            format!(
                "N_in={} N_out={} N_s={}",
                l.spec.n_in, l.spec.n_out, l.spec.n_s
            ),
            l.planes.len().to_string(),
            format!("{:.2}", l.memory_reduction()),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use f2f::container::Container;
    use f2f::coordinator::{InferenceServer, ServerConfig};
    use f2f::models::{quantize_i8, LayerSpec, SyntheticLayer, WeightGen};
    use f2f::pipeline::{CompressionConfig, Compressor};
    use f2f::pruning::PruneMethod;
    use f2f::store::{
        ModelBackend, ModelStore, ReadaheadPolicy, StoreConfig,
    };
    use std::sync::Arc;

    let requests: usize = args.get("requests", 2000)?;
    let max_batch: usize = args.get("batch", 16)?;
    let seed: u64 = args.get("seed", 7)?;
    let n_layers: usize = args.get("layers", 4)?;
    let width: usize = args.get("width", 256)?;
    // Decoded-weight cache budget; 0 = unbounded. Set it below the
    // model's decoded size to exercise decode-on-miss / evict-cold.
    let cache_kb: usize = args.get("cache-kb", 0)?;
    // Decode service width; 0 = size to the host.
    let decode_threads: usize = args.get("decode-threads", 0)?;
    // Warm layer i+1 while layer i executes: on | off | <depth>.
    let readahead: ReadaheadPolicy =
        args.get_str("readahead", "on").parse()?;

    // Compress a multi-layer MLP-shaped model into an indexed container.
    let compressor = Compressor::new(CompressionConfig {
        sparsity: 0.9,
        n_s: 1,
        method: PruneMethod::Magnitude,
        beam: Some(8),
        seed,
        ..Default::default()
    });
    let t0 = std::time::Instant::now();
    let mut container = Container::default();
    for i in 0..n_layers {
        let name = format!("mlp/fc{i}");
        let spec =
            LayerSpec { name: name.clone(), rows: width, cols: width };
        let layer = SyntheticLayer::generate(
            &spec,
            WeightGen::default(),
            seed.wrapping_add(i as u64),
        );
        let (q, scale) = quantize_i8(&layer.weights);
        let (cl, rep) =
            compressor.compress_i8(&name, width, width, &q, scale);
        println!(
            "compressed {name} ({width}x{width}): E={:.2}% \
             mem_reduction={:.2}%",
            rep.efficiency, rep.memory_reduction
        );
        container.layers.push(cl);
    }
    println!("model compressed in {:?}", t0.elapsed());
    let bytes = f2f::container::write_container_v2(&container);

    let budget = if cache_kb == 0 { usize::MAX } else { cache_kb << 10 };
    let store = Arc::new(ModelStore::open_bytes(
        bytes,
        StoreConfig {
            cache_budget_bytes: budget,
            decode_workers: decode_threads,
        },
    )?);
    println!(
        "store: {} layers, decoded size {} KiB, budget {}, {} decode \
         workers, readahead depth {}",
        n_layers,
        store.total_decoded_bytes() >> 10,
        if budget == usize::MAX {
            "unbounded".to_string()
        } else {
            format!("{} KiB", budget >> 10)
        },
        store.decode_workers(),
        readahead.depth,
    );

    let backend =
        ModelBackend::sequential(store.clone())?.with_readahead(readahead);
    let server = InferenceServer::start(
        ServerConfig { max_batch, ..Default::default() },
        move || Box::new(backend),
    );
    let mut rng = f2f::rng::Rng::new(seed);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for _ in 0..requests {
        let x: Vec<f32> =
            (0..width).map(|_| rng.next_f32() - 0.5).collect();
        pending.push(server.infer_async(x));
    }
    for p in pending {
        p.recv()??;
    }
    let dt = t0.elapsed();
    let m = server.metrics();
    println!(
        "{requests} requests in {dt:?} ({:.0} req/s), batches={} mean_batch={:.1}",
        requests as f64 / dt.as_secs_f64(),
        m.batches,
        m.mean_batch_size()
    );
    println!("latency p50={:?} p95={:?} p99={:?}", m.p50, m.p95, m.p99);
    let sm = store.metrics();
    println!(
        "store: hits={} misses={} decodes={} evictions={} cached={} KiB \
         ({} layers)",
        sm.hits,
        sm.misses,
        sm.decodes,
        sm.evictions,
        sm.cached_bytes >> 10,
        sm.cached_layers,
    );
    println!(
        "readahead: prefetches={} skips={} redundant_decodes={}",
        sm.prefetches, sm.readahead_skips, sm.redundant_decodes,
    );
    server.shutdown();
    Ok(())
}

fn cmd_hw(args: &Args) -> Result<()> {
    use f2f::decoder::{DecoderSpec, SequentialDecoder};
    let s: f64 = args.get("s", 0.9)?;
    let n_in: usize = args.get("nin", 8)?;
    let n_s: usize = args.get("ns", 2)?;
    let spec = DecoderSpec::for_sparsity(n_in, s, n_s);
    let dec = SequentialDecoder::random(spec, 0);
    let c = dec.hardware_cost();
    println!(
        "decoder spec: N_in={} N_out={} N_s={}",
        spec.n_in, spec.n_out, spec.n_s
    );
    println!(
        "xor gates:           {} (estimate {})",
        c.xor_gates, c.xor_gates_estimate
    );
    println!("transistors:         {}", c.transistors);
    println!("register bits:       {}", c.register_bits);
    println!("latency (cycles):    {}", c.latency_cycles);
    println!("throughput (b/cyc):  {}", c.throughput_bits_per_cycle);
    println!(
        "transistors/output bit: {:.1}",
        c.transistors_per_output_bit()
    );
    Ok(())
}

//! `f2f` — CLI for the fixed-to-fixed compression library.
//!
//! Subcommands:
//!
//! * `f2f repro <id> [...]` — regenerate a paper table/figure (see
//!   DESIGN.md §5 for ids: fig1 fig4a fig4b fig4c fig8 fig9 table1
//!   table2 table3 s4 s5 s10 s12 s13 entropy beamcheck all).
//! * `f2f compress --model <transformer|resnet50> [...]` — compress a
//!   synthetic model to a container file (indexed v2 by default; pass
//!   `--v1` for the legacy layout) and report per-layer stats. With
//!   `--chain` compress a *full* tiny chain-valid layer table (no
//!   subsampling/truncation — chain geometry must survive; `--model`
//!   additionally accepts `mlp`, a uniform gemv+relu ladder sized by
//!   `--width`/`--layers`) and write the v3 layout with the
//!   executable chain recorded (`--blocks`, `--d-model`, `--d-ff`,
//!   `--id <model-id>`), ready for `serve --models` and
//!   [`f2f::registry`].
//! * `f2f inspect <container>` — print a container's inventory
//!   (v1/v2/v3; v3 also lists the recorded chains).
//! * `f2f shard <container> --shards <n> [--by-bytes] [--out prefix]` —
//!   split a v2 container into per-shard v2 files plus the `F2F3`
//!   shard-map sidecar.
//! * `f2f rebalance <container> --profile <json> [--shards <n>]
//!   [--out prefix]` — re-split a v2 container on *observed* per-layer
//!   decode cost (a `CostProfile` JSON exported by
//!   `serve --profile-out`), rewriting the per-shard files and the
//!   `F2F3` sidecar.
//! * `f2f serve [...]` — compress a multi-layer model, serve it through
//!   the model store (`--cache-kb <n>` decoded-weight budget,
//!   `--decode-threads <n>` decode-service width, `--layers`, `--width`,
//!   `--readahead on|off|<depth>|auto` async warm-ahead — `auto` plans
//!   depth from observed costs — `--decode-mode
//!   materialized|fused|auto` pick how stores cache decoded layers
//!   (dense f32, bit-plane-resident fused GEMV, or per-layer
//!   whichever is smaller — see [`f2f::kernels`]), `--shards <n>`
//!   split across a multi-store shard router, `--shard-procs <n>`
//!   split across that many supervised *worker processes* routed over
//!   unix-socket IPC, `--models <id=path,...>` serve N pre-compressed
//!   containers as a model zoo through one shared-budget
//!   [`f2f::registry::ModelRegistry`] instead of compressing a
//!   synthetic MLP — combines with `--shards` / `--shard-procs`, the
//!   load interleaves tenants (batches stay model-pure), and the
//!   stats socket / `f2f top` grow per-model rows,
//!   `--timing` print the per-layer cost table plus the request /
//!   batch / decode / GEMV latency histograms, `--profile-out [path]`
//!   export it as `CostProfile` JSON — bare `--profile-out` writes the
//!   `<container>.costs.json` sidecar `ModelStore::open_path`
//!   auto-loads — `--trace-out <path>` export the run's spans as a
//!   Chrome trace (one pid lane per process; load in chrome://tracing
//!   or Perfetto), `--metrics-out <path>` export the unified metrics
//!   registry as JSON, `--stats-socket <path>` serve live stats on a
//!   dedicated unix socket while serving (poll it with `f2f top`),
//!   `--events-out <path>` persist the structured event journal as
//!   JSONL, `--quiet` stop mirroring warn/error events to stderr,
//!   `--duration-s <n>` keep replaying the load until the wall-clock
//!   budget is spent — how CI holds a serve open to poll and kill it
//!   mid-flight) and run a self-driven load test. `--trace-out` /
//!   `--metrics-out` are also checkpointed incrementally (atomic
//!   tmp+rename every 500 ms) so a crashed serve still leaves fresh
//!   artifacts.
//! * `f2f top <stats-socket> [--interval-ms <n>] [--once]` — poll a
//!   serve's `--stats-socket` and render a refreshing per-shard /
//!   per-layer table (hit rate, decode/GEMV quantiles, queue depth,
//!   evictions, readahead skips). `--once` prints the raw stats JSON
//!   document and exits — the machine-readable mode CI asserts on.
//! * `f2f shard-worker <shard.f2f2> --socket <path> [--cache-kb <n>]
//!   [--decode-threads <n>] [--decode-mode <mode>]
//!   [--flight-dir <dir>]` — serve one shard file over a unix socket:
//!   the child-process entrypoint `serve --shard-procs` spawns (unix
//!   only). With `--flight-dir`
//!   the worker keeps a crash flight sidecar checkpointed for the
//!   supervisor's postmortem.
//! * `f2f hw --s <S> --nin <N> --ns <N>` — Appendix G hardware cost.
//! * `f2f lint [--root <dir>] [--file <path> [--as <relpath>]]` — run
//!   the repo-native invariant linter (see [`f2f::analysis`]) over
//!   `rust/src`, or over one file as if it lived at `<relpath>` (how CI
//!   drives the must-fail fixture corpus). Exits non-zero on findings.

use anyhow::{bail, Result};
use f2f::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("repro") => f2f::repro::run(args),
        Some("compress") => cmd_compress(args),
        Some("inspect") => cmd_inspect(args),
        Some("shard") => cmd_shard(args),
        Some("rebalance") => cmd_rebalance(args),
        Some("serve") => cmd_serve(args),
        Some("top") => cmd_top(args),
        Some("shard-worker") => cmd_shard_worker(args),
        Some("hw") => cmd_hw(args),
        Some("lint") => cmd_lint(args),
        _ => {
            eprintln!(
                "usage: f2f <repro|compress|inspect|shard|rebalance|\
                 serve|top|shard-worker|hw|lint> [options]\n\
                 try: f2f repro table1 --bits 100000"
            );
            Ok(())
        }
    }
}

fn cmd_compress(args: &Args) -> Result<()> {
    use f2f::container::Dtype;
    use f2f::models::{resnet50_layers, transformer_layers, SyntheticLayer, WeightGen};
    use f2f::pipeline::{CompressionConfig, Compressor};
    use f2f::pruning::PruneMethod;

    if args.flag("chain") {
        return cmd_compress_chain(args);
    }

    let model = args.get_str("model", "transformer");
    let sparsity: f64 = args.get("s", 0.9)?;
    let n_s: usize = args.get("ns", 2)?;
    let max_w: usize = args.get("weights", 8192)?;
    let n_layers: usize = args.get("layers", 4)?;
    let seed: u64 = args.get("seed", 0xF2F)?;
    let beam: i64 = args.get("beam", 8)?;
    let out = args.get_str("out", "model.f2f");
    let dtype = match args.get_str("dtype", "i8").as_str() {
        "i8" => Dtype::I8,
        "f32" => Dtype::F32,
        d => bail!("unknown dtype {d}"),
    };

    let specs = match model.as_str() {
        "transformer" => transformer_layers(),
        "resnet50" => resnet50_layers(),
        m => bail!("unknown model {m}"),
    };
    let layers: Vec<SyntheticLayer> = specs
        .iter()
        .step_by((specs.len() / n_layers).max(1))
        .take(n_layers)
        .map(|s| {
            SyntheticLayer::generate(s, WeightGen::default(), seed)
                .truncated(max_w)
        })
        .collect();

    let cfg = CompressionConfig {
        sparsity,
        n_s,
        method: PruneMethod::Magnitude,
        invert: dtype == Dtype::F32,
        seed,
        beam: if beam < 0 { None } else { Some(beam as u32) },
        ..Default::default()
    };
    let compressor = Compressor::new(cfg);
    let t0 = std::time::Instant::now();
    let (container, reports) = compressor.compress_model(&layers, dtype);
    let dt = t0.elapsed();

    let mut table = f2f::report::Table::new(
        &format!("compress {model} S={sparsity} N_s={n_s} ({dt:?})"),
        &["layer", "weights", "E%", "mem_reduction%", "coeff_var"],
    );
    for r in &reports {
        table.row(vec![
            r.name.clone(),
            r.n_weights.to_string(),
            format!("{:.2}", r.efficiency),
            format!("{:.2}", r.memory_reduction),
            format!("{:.3}", r.coeff_var),
        ]);
    }
    print!("{}", table.render());
    println!(
        "total: {} -> {} bits ({:.2}% reduction)",
        container.original_bits(),
        container.compressed_bits(),
        container.memory_reduction()
    );
    let bytes = if args.flag("v1") {
        f2f::container::write_container(&container)
    } else {
        f2f::container::write_container_v2(&container)
    };
    std::fs::write(&out, bytes)?;
    println!(
        "wrote {out} ({})",
        if args.flag("v1") { "legacy v1" } else { "indexed v2" }
    );
    Ok(())
}

/// `compress --chain`: compress a *full* tiny chain-valid layer table
/// — the plain compress path subsamples (`step_by`) and truncates
/// layers, which breaks attention/conv geometry — and write the v3
/// container with the executable [`f2f::container::ChainSpec`]
/// recorded, ready for `serve --models` and the registry.
fn cmd_compress_chain(args: &Args) -> Result<()> {
    use f2f::container::Dtype;
    use f2f::models::{
        resnet_chain, tiny_resnet_layers, tiny_transformer_layers,
        transformer_chain, SyntheticLayer, WeightGen,
    };
    use f2f::pipeline::{CompressionConfig, Compressor};
    use f2f::pruning::PruneMethod;

    let model = args.get_str("model", "transformer");
    let sparsity: f64 = args.get("s", 0.9)?;
    let n_s: usize = args.get("ns", 1)?;
    let seed: u64 = args.get("seed", 0xF2F)?;
    let beam: i64 = args.get("beam", 8)?;
    let out = args.get_str("out", "model.f2f");
    let id = args.get_str("id", &model);
    let blocks: usize = args.get("blocks", 2)?;

    let (specs, chain) = match model.as_str() {
        "transformer" => {
            let d_model: usize = args.get("d-model", 32)?;
            let d_ff: usize = args.get("d-ff", d_model * 2)?;
            let specs =
                tiny_transformer_layers(blocks, d_model, d_ff);
            let chain = transformer_chain(id.as_str(), &specs)?;
            (specs, chain)
        }
        "resnet50" | "resnet" => {
            // One bottleneck per stage, widths doubling per stage —
            // the tiny analogue of the ResNet-50 ladder.
            let widths: Vec<(usize, usize)> =
                (0..blocks.max(1)).map(|g| (4 << g, 16 << g)).collect();
            let specs = tiny_resnet_layers(&widths);
            let chain = resnet_chain(id.as_str(), &specs)?;
            (specs, chain)
        }
        "mlp" => {
            // The uniform gemv+relu ladder as an explicit chain — the
            // chain-valid MLP tenant for zoo deployments.
            let width: usize = args.get("width", 32)?;
            let n_layers: usize = args.get("layers", 3)?;
            let specs: Vec<f2f::models::LayerSpec> = (0..n_layers)
                .map(|i| f2f::models::LayerSpec {
                    name: format!("mlp/fc{i}"),
                    rows: width,
                    cols: width,
                })
                .collect();
            let names: Vec<String> =
                specs.iter().map(|s| s.name.clone()).collect();
            let chain =
                f2f::container::ChainSpec::uniform(id.as_str(), &names);
            (specs, chain)
        }
        m => bail!("--chain supports transformer|resnet50|mlp, not {m}"),
    };

    let layers: Vec<SyntheticLayer> = specs
        .iter()
        .map(|s| SyntheticLayer::generate(s, WeightGen::default(), seed))
        .collect();
    let cfg = CompressionConfig {
        sparsity,
        n_s,
        method: PruneMethod::Magnitude,
        seed,
        beam: if beam < 0 { None } else { Some(beam as u32) },
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let (container, reports) =
        Compressor::new(cfg).compress_model(&layers, Dtype::I8);
    let dt = t0.elapsed();

    let mut table = f2f::report::Table::new(
        &format!(
            "compress --chain {model} S={sparsity} N_s={n_s} ({dt:?})"
        ),
        &["layer", "shape", "E%", "mem_reduction%"],
    );
    for (r, s) in reports.iter().zip(&specs) {
        table.row(vec![
            r.name.clone(),
            format!("{}x{}", s.rows, s.cols),
            format!("{:.2}", r.efficiency),
            format!("{:.2}", r.memory_reduction),
        ]);
    }
    print!("{}", table.render());
    let n_chain_layers = chain.layer_names().len();
    let n_steps = chain.steps.len();
    let bytes =
        f2f::container::write_container_v3(&container, &[chain]);
    std::fs::write(&out, bytes)?;
    println!(
        "wrote {out} (v3, chain {id:?}: {n_steps} steps over \
         {n_chain_layers} layers) — serve it with \
         `f2f serve --models {id}={out}`"
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let path = args.pos(1)?;
    let bytes = std::fs::read(path)?;
    let version = if bytes.len() >= 8 && &bytes[..4] == b"F2F2" {
        u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]])
    } else {
        1
    };
    let layout = match version {
        1 => "v1",
        3 => "v3 indexed+chains",
        _ => "v2 indexed",
    };
    let c = f2f::container::read_container(&bytes)?;
    let mut table = f2f::report::Table::new(
        &format!("{path} ({} bytes, {layout})", bytes.len()),
        &["layer", "shape", "dtype", "spec", "planes", "mem_reduction%"],
    );
    for l in &c.layers {
        table.row(vec![
            l.name.clone(),
            format!("{}x{}", l.rows, l.cols),
            format!("{:?}", l.dtype),
            format!(
                "N_in={} N_out={} N_s={}",
                l.spec.n_in, l.spec.n_out, l.spec.n_s
            ),
            l.planes.len().to_string(),
            format!("{:.2}", l.memory_reduction()),
        ]);
    }
    print!("{}", table.render());
    if version >= 3 {
        let index = f2f::container::ContainerIndex::parse(&bytes)?;
        for chain in index.chains() {
            println!(
                "chain {:?}: {} steps over {} layers",
                chain.model,
                chain.steps.len(),
                chain.layer_names().len()
            );
        }
    }
    Ok(())
}

fn cmd_shard(args: &Args) -> Result<()> {
    use f2f::container::{split_container, ShardAssignment};

    let path = args.pos(1)?;
    let n_shards: usize = args.get("shards", 2)?;
    let strategy = if args.flag("by-bytes") {
        ShardAssignment::ByBytes
    } else {
        ShardAssignment::RoundRobin
    };
    let out = args.get_str("out", path);
    let bytes = std::fs::read(path)?;
    let (map, shards) = split_container(&bytes, n_shards, strategy)?;

    let mut table = f2f::report::Table::new(
        &format!("{path} -> {n_shards} shards ({strategy:?})"),
        &["shard", "file", "layers", "bytes"],
    );
    for (i, shard_bytes) in shards.iter().enumerate() {
        let shard_path = format!("{out}.shard{i}.f2f");
        std::fs::write(&shard_path, shard_bytes)?;
        let layers: Vec<&str> = map.layers_of(i).collect();
        table.row(vec![
            i.to_string(),
            shard_path,
            layers.join(","),
            shard_bytes.len().to_string(),
        ]);
    }
    print!("{}", table.render());
    let map_path = format!("{out}.shardmap");
    std::fs::write(&map_path, map.to_bytes())?;
    println!(
        "wrote {map_path} ({} layers across {n_shards} shards)",
        map.len()
    );
    Ok(())
}

fn cmd_rebalance(args: &Args) -> Result<()> {
    use f2f::container::{split_with_map, ContainerIndex, ShardMap};
    use f2f::shard::{rebalance_map, CostProfile};

    let path = args.pos(1)?;
    let profile_path = args.get_str("profile", "");
    if profile_path.is_empty() {
        bail!("rebalance needs --profile <json> (export one with \
               `f2f serve --profile-out <path>`)");
    }
    let n_shards: usize = args.get("shards", 2)?;
    let out = args.get_str("out", path);

    let bytes = std::fs::read(path)?;
    let index = ContainerIndex::parse(&bytes)?;
    let profile =
        CostProfile::parse_json(&std::fs::read_to_string(&profile_path)?)?;
    let map = rebalance_map(&index, n_shards, &profile)?;
    // Round-trip through the wire form so the emitted sidecar passes
    // exactly the validation every consumer applies.
    let map = ShardMap::parse(&map.to_bytes())?;
    let shards = split_with_map(&bytes, &map)?;
    let loads = profile.shard_loads(&map);

    let mut table = f2f::report::Table::new(
        &format!(
            "{path} -> {n_shards} shards (observed decode cost, \
             profile {profile_path})"
        ),
        &["shard", "file", "layers", "bytes", "predicted_decode_ms"],
    );
    for (i, shard_bytes) in shards.iter().enumerate() {
        let shard_path = format!("{out}.shard{i}.f2f");
        std::fs::write(&shard_path, shard_bytes)?;
        let layers: Vec<&str> = map.layers_of(i).collect();
        table.row(vec![
            i.to_string(),
            shard_path,
            layers.join(","),
            shard_bytes.len().to_string(),
            format!("{:.3}", loads[i] / 1e6),
        ]);
    }
    print!("{}", table.render());
    let map_path = format!("{out}.shardmap");
    std::fs::write(&map_path, map.to_bytes())?;
    println!(
        "wrote {map_path} ({} layers across {n_shards} shards, \
         rebalanced on observed decode time)",
        map.len()
    );
    Ok(())
}

/// Child-process entrypoint for `serve --shard-procs`: serve one
/// shard file over a unix socket until a wire `Shutdown` arrives.
/// Silent on success — the supervisor owns the operator-facing
/// output.
#[cfg(unix)]
fn cmd_shard_worker(args: &Args) -> Result<()> {
    use f2f::store::StoreConfig;

    let shard = args.pos(1)?;
    let socket = args.get_str("socket", "");
    if socket.is_empty() {
        bail!("shard-worker needs --socket <path>");
    }
    let cache_kb: usize = args.get("cache-kb", 0)?;
    let decode_threads: usize = args.get("decode-threads", 0)?;
    let decode_mode: f2f::kernels::DecodeMode =
        args.get_str("decode-mode", "materialized").parse()
            .map_err(|e: String| anyhow::anyhow!(e))?;
    let flight_dir = args.get_str("flight-dir", "");
    let flight = if flight_dir.is_empty() {
        None
    } else {
        Some(std::path::PathBuf::from(&flight_dir))
    };
    let budget = if cache_kb == 0 { usize::MAX } else { cache_kb << 10 };
    f2f::ipc::run_worker(
        std::path::Path::new(shard),
        std::path::Path::new(&socket),
        StoreConfig {
            cache_budget_bytes: budget,
            decode_workers: decode_threads,
            decode_mode,
        },
        flight.as_deref(),
    )
}

#[cfg(not(unix))]
fn cmd_shard_worker(_args: &Args) -> Result<()> {
    bail!("shard-worker requires unix domain sockets (unix only)");
}

/// `f2f top <stats-socket>`: poll a serving process's live-stats
/// socket and render the refreshing operations table. `--once` prints
/// the raw stats JSON document and exits (the machine-readable mode
/// CI asserts on); otherwise the view refreshes every
/// `--interval-ms` until the serve goes away (which ends the loop
/// with the connect error).
#[cfg(unix)]
fn cmd_top(args: &Args) -> Result<()> {
    use f2f::obs::stats::{poll_stats, StatsSnapshot};
    use std::time::Duration;

    let socket = args.pos(1)?.to_string();
    let socket = std::path::Path::new(&socket);
    let interval_ms: u64 = args.get("interval-ms", 1000)?;
    let timeout = Duration::from_secs(5);
    if args.flag("once") {
        print!("{}", poll_stats(socket, timeout)?);
        return Ok(());
    }
    loop {
        let snap =
            StatsSnapshot::parse_json(&poll_stats(socket, timeout)?)?;
        // ANSI clear + home: redraw in place like `top`.
        print!("\x1b[2J\x1b[H{}", snap.render());
        use std::io::Write as _;
        let _ = std::io::stdout().flush();
        std::thread::sleep(Duration::from_millis(interval_ms.max(100)));
    }
}

#[cfg(not(unix))]
fn cmd_top(_args: &Args) -> Result<()> {
    bail!("top requires unix domain sockets (unix only)");
}

fn cmd_serve(args: &Args) -> Result<()> {
    use f2f::container::{write_sharded, ShardAssignment};
    use f2f::coordinator::{InferenceServer, ServerConfig};
    use f2f::models::{compressed_mlp, MlpConfig};
    use f2f::shard::{CostProfile, ShardRouter};
    use f2f::store::{
        ModelBackend, ModelStore, ReadaheadPolicy, StoreConfig,
        StoreMetrics,
    };
    use std::sync::Arc;

    let requests: usize = args.get("requests", 2000)?;
    let max_batch: usize = args.get("batch", 16)?;
    let seed: u64 = args.get("seed", 7)?;
    let n_layers: usize = args.get("layers", 4)?;
    let width: usize = args.get("width", 256)?;
    // Decoded-weight cache budget (per store); 0 = unbounded. Set it
    // below the model's decoded size to exercise decode-on-miss /
    // evict-cold.
    let cache_kb: usize = args.get("cache-kb", 0)?;
    // Decode service width (per store); 0 = size to the host.
    let decode_threads: usize = args.get("decode-threads", 0)?;
    // Warm layer i+1 while layer i executes: on | off | <depth>, or
    // `auto` — plan depth per layer from the observed cost table.
    let readahead: ReadaheadPolicy =
        args.get_str("readahead", "on").parse()?;
    // How stores cache decoded layers: dense f32 (`materialized`),
    // bit-plane-resident with the GEMV fused over the planes
    // (`fused`), or per layer whichever is smaller (`auto`).
    let decode_mode: f2f::kernels::DecodeMode =
        args.get_str("decode-mode", "materialized").parse()
            .map_err(|e: String| anyhow::anyhow!(e))?;
    // Split the model across this many stores behind a shard router.
    let n_shards: usize = args.get("shards", 1)?;
    // Split the model across this many supervised worker *processes*
    // routed over unix-socket IPC (0 = in-process serving).
    let shard_procs: usize = args.get("shard-procs", 0)?;
    // Print the per-layer observed cost table (what `auto` sees).
    let show_timing = args.flag("timing");
    // Export the observed costs as CostProfile JSON (the input to
    // `f2f rebalance`). A bare `--profile-out` defaults to the
    // `<container>.costs.json` sidecar that `ModelStore::open_path`
    // auto-loads, so the planner survives restarts.
    let profile_out_explicit = args.get_str("profile-out", "");
    let profile_out_requested =
        args.flag("profile-out") || !profile_out_explicit.is_empty();
    // Export the run's recorded spans ([`f2f::obs`]) as a Chrome
    // trace. Multi-process serving stitches one pid lane per worker,
    // connected to the router lane by shared request trace ids.
    let trace_out = args.get_str("trace-out", "");
    // Export the unified metrics registry: server counters and
    // request/batch histograms, per-store cache counters and
    // decode/GEMV histograms, per-layer observed costs.
    let metrics_out = args.get_str("metrics-out", "");
    // Live operations plane: serve stats on a dedicated unix socket
    // while serving (`f2f top` polls it), persist the structured
    // event journal, silence its stderr mirror, and optionally keep
    // the load running for a wall-clock budget so there is a live
    // process to poll.
    let stats_socket = args.get_str("stats-socket", "");
    let events_out = args.get_str("events-out", "");
    let duration_s: u64 = args.get("duration-s", 0)?;
    if args.flag("quiet") {
        f2f::obs::events::set_stderr_mirror(false);
    }
    if !events_out.is_empty() {
        let path = std::path::Path::new(&events_out);
        // The sink may live inside a workdir that is only created
        // further down (multi-process serving) — make the parent now.
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        f2f::obs::events::set_sink_path(path)?;
        println!("event journal: {events_out} (JSONL, incremental)");
    }

    // `--models` switches serve into the zoo path: N pre-compressed
    // containers behind one shared-budget registry, instead of
    // compressing a synthetic MLP here.
    let models_spec = args.get_str("models", "");
    if !models_spec.is_empty() {
        return serve_zoo(args, &models_spec);
    }

    // Compress a multi-layer MLP-shaped model into an indexed container.
    let t0 = std::time::Instant::now();
    let (container, reports) = compressed_mlp(&MlpConfig {
        seed,
        name_prefix: "mlp/fc".into(),
        ..MlpConfig::uniform(n_layers, width)
    });
    for rep in &reports {
        println!(
            "compressed {} ({width}x{width}): E={:.2}% \
             mem_reduction={:.2}%",
            rep.name, rep.efficiency, rep.memory_reduction
        );
    }
    println!("model compressed in {:?}", t0.elapsed());

    if shard_procs > 0 {
        #[cfg(unix)]
        return serve_multiproc(
            &container,
            &MultiprocOpts {
                shard_procs,
                requests,
                max_batch,
                seed,
                width,
                cache_kb,
                decode_threads,
                decode_mode,
                readahead,
                show_timing,
                profile_out_explicit,
                profile_out_requested,
                trace_out,
                metrics_out,
                stats_socket,
                duration_s,
                workdir: args.get_str("workdir", ""),
            },
        );
        #[cfg(not(unix))]
        bail!("--shard-procs requires unix domain sockets (unix only)");
    }

    let budget = if cache_kb == 0 { usize::MAX } else { cache_kb << 10 };
    let store_config = StoreConfig {
        cache_budget_bytes: budget,
        decode_workers: decode_threads,
        decode_mode,
    };
    let budget_label = if budget == usize::MAX {
        "unbounded".to_string()
    } else {
        format!("{} KiB", budget >> 10)
    };

    // Resolved export path for this in-process serve: an explicit
    // `--profile-out <path>` wins; a bare flag targets the sidecar of
    // the default `f2f compress` output (`model.f2f.costs.json`).
    // Consumers of that convention are `open_path` callers — spawned
    // shard workers and anything serving the compressed file from
    // disk; this in-memory serve loop itself always cold-starts.
    let profile_out = if !profile_out_explicit.is_empty() {
        profile_out_explicit.clone()
    } else if profile_out_requested {
        f2f::store::cost_sidecar_path(std::path::Path::new(
            "model.f2f",
        ))
        .display()
        .to_string()
    } else {
        String::new()
    };
    let write_profile = |profile: &CostProfile| -> Result<()> {
        if !profile_out.is_empty() {
            std::fs::write(&profile_out, profile.to_json())?;
            println!(
                "wrote {profile_out} ({} layers) — feed it to \
                 `f2f rebalance --profile {profile_out}`",
                profile.len()
            );
        }
        Ok(())
    };

    if n_shards <= 1 {
        let bytes = f2f::container::write_container_v2(&container);
        let store = Arc::new(ModelStore::open_bytes(bytes, store_config)?);
        println!(
            "store: {} layers, decoded size {} KiB, budget \
             {budget_label}, {} decode workers, readahead {}, \
             decode-mode {decode_mode}",
            n_layers,
            store.total_decoded_bytes() >> 10,
            store.decode_workers(),
            readahead,
        );
        let backend = ModelBackend::sequential(store.clone())?
            .with_readahead(readahead);
        let server = InferenceServer::start(
            ServerConfig { max_batch, ..Default::default() },
            move || Box::new(backend),
        )?;
        let live = {
            let s1 = store.clone();
            let s2 = store.clone();
            let metrics = server.metrics_handle();
            let inflight = server.inflight_handle();
            let capacity = server.queue_capacity();
            f2f::obs::stats::LiveSources::new(
                Arc::new(move || {
                    vec![("store".to_string(), s1.metrics())]
                }),
                Arc::new(move || s2.costs().snapshot()),
            )
            .with_server(Arc::new(move || metrics.snapshot()))
            .with_queue(Arc::new(move || {
                (
                    inflight.load(std::sync::atomic::Ordering::Relaxed),
                    capacity,
                )
            }))
        };
        let ops =
            start_ops_plane(&stats_socket, &trace_out, &metrics_out, &live)?;
        run_load_for(&server, requests, width, seed, duration_s)?;
        // Let trailing readahead decodes land so the printed counters
        // are stable run to run.
        store.wait_for_idle();
        print_store_metrics("store", &store.metrics());
        if show_timing {
            print_cost_table("store", &store.costs().snapshot());
        }
        write_profile(&CostProfile::from_stores([store.costs()]))?;
        let snap = server.metrics();
        drop(ops);
        server.shutdown();
        export_observability(
            &trace_out,
            &metrics_out,
            show_timing,
            &snap,
            &[("store".to_string(), store.metrics())],
            &store.costs().snapshot(),
            Vec::new(),
        );
    } else {
        let (map, shard_bytes) =
            write_sharded(&container, n_shards, ShardAssignment::ByBytes)?;
        let stores = shard_bytes
            .into_iter()
            .map(|b| ModelStore::open_bytes(b, store_config).map(Arc::new))
            .collect::<Result<Vec<_>>>()?;
        for (i, s) in stores.iter().enumerate() {
            let layers: Vec<&str> = map.layers_of(i).collect();
            println!(
                "shard {i}: layers [{}], decoded size {} KiB, budget \
                 {budget_label}, {} decode workers",
                layers.join(","),
                s.total_decoded_bytes() >> 10,
                s.decode_workers(),
            );
        }
        let router = ShardRouter::new(stores.clone(), &map)?
            .with_readahead(readahead);
        let server = InferenceServer::start(
            ServerConfig { max_batch, ..Default::default() },
            move || Box::new(router),
        )?;
        let live = {
            let s1 = stores.clone();
            let s2 = stores.clone();
            let metrics = server.metrics_handle();
            let inflight = server.inflight_handle();
            let capacity = server.queue_capacity();
            f2f::obs::stats::LiveSources::new(
                Arc::new(move || {
                    s1.iter()
                        .enumerate()
                        .map(|(i, s)| (format!("shard {i}"), s.metrics()))
                        .collect()
                }),
                Arc::new(move || {
                    CostProfile::from_stores(s2.iter().map(|s| s.costs()))
                        .entries()
                }),
            )
            .with_server(Arc::new(move || metrics.snapshot()))
            .with_queue(Arc::new(move || {
                (
                    inflight.load(std::sync::atomic::Ordering::Relaxed),
                    capacity,
                )
            }))
        };
        let ops =
            start_ops_plane(&stats_socket, &trace_out, &metrics_out, &live)?;
        run_load_for(&server, requests, width, seed, duration_s)?;
        // Let trailing cross-shard readahead decodes land so the
        // printed counters are stable run to run.
        for s in &stores {
            s.wait_for_idle();
        }
        let mut total = StoreMetrics::default();
        let mut shard_metrics = Vec::new();
        for (i, s) in stores.iter().enumerate() {
            let sm = s.metrics();
            print_store_metrics(&format!("shard {i}"), &sm);
            total.merge(&sm);
            shard_metrics.push((format!("shard {i}"), sm));
        }
        print_store_metrics("all shards", &total);
        let profile =
            CostProfile::from_stores(stores.iter().map(|s| s.costs()));
        if show_timing {
            print_cost_table("all shards", &profile.entries());
        }
        write_profile(&profile)?;
        let snap = server.metrics();
        drop(ops);
        server.shutdown();
        export_observability(
            &trace_out,
            &metrics_out,
            show_timing,
            &snap,
            &shard_metrics,
            &profile.entries(),
            Vec::new(),
        );
    }
    Ok(())
}

/// The live operations plane for one serve: the optional stats
/// socket, the regression watchdog, and the incremental exporter
/// that keeps `--trace-out` / `--metrics-out` fresh (atomic
/// tmp+rename every 500 ms). Dropping it stops all three.
struct OpsPlane {
    #[cfg(unix)]
    _stats: Option<f2f::obs::stats::StatsServer>,
    _watchdog: f2f::obs::watchdog::Watchdog,
    flush_stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
    flusher: Option<std::thread::JoinHandle<()>>,
}

impl Drop for OpsPlane {
    fn drop(&mut self) {
        self.flush_stop
            .store(true, std::sync::atomic::Ordering::Release);
        if let Some(t) = self.flusher.take() {
            let _ = t.join();
        }
    }
}

/// How often the incremental exporter checkpoints `--trace-out` /
/// `--metrics-out` while serving.
const FLUSH_INTERVAL: std::time::Duration =
    std::time::Duration::from_millis(500);

fn start_ops_plane(
    stats_socket: &str,
    trace_out: &str,
    metrics_out: &str,
    live: &f2f::obs::stats::LiveSources,
) -> Result<OpsPlane> {
    #[cfg(unix)]
    let stats = if stats_socket.is_empty() {
        None
    } else {
        let path = std::path::Path::new(stats_socket);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let server =
            f2f::obs::stats::StatsServer::start(path, live.clone())?;
        println!(
            "stats socket: {stats_socket} \
             (try `f2f top {stats_socket}`)"
        );
        Some(server)
    };
    #[cfg(not(unix))]
    if !stats_socket.is_empty() {
        bail!("--stats-socket requires unix domain sockets (unix only)");
    }
    let watchdog = {
        let live = live.clone();
        f2f::obs::watchdog::Watchdog::start(
            f2f::obs::watchdog::WatchdogConfig::default(),
            move || live.watchdog_sample(),
        )
    };
    let flush_stop = std::sync::Arc::new(
        std::sync::atomic::AtomicBool::new(false),
    );
    let flusher = if trace_out.is_empty() && metrics_out.is_empty() {
        None
    } else {
        let stop = flush_stop.clone();
        let live = live.clone();
        let trace_out = trace_out.to_string();
        let metrics_out = metrics_out.to_string();
        std::thread::Builder::new()
            .name("f2f-flush".into())
            .spawn(move || {
                let tick = std::time::Duration::from_millis(10);
                let mut since = std::time::Duration::ZERO;
                while !stop
                    .load(std::sync::atomic::Ordering::Acquire)
                {
                    std::thread::sleep(tick);
                    since += tick;
                    if since < FLUSH_INTERVAL {
                        continue;
                    }
                    since = std::time::Duration::ZERO;
                    flush_exports(&trace_out, &metrics_out, &live);
                }
            })
            .ok()
    };
    Ok(OpsPlane {
        #[cfg(unix)]
        _stats: stats,
        _watchdog: watchdog,
        flush_stop,
        flusher,
    })
}

/// One incremental export checkpoint: rewrite `--trace-out` (this
/// process's lane only; worker lanes are stitched in at teardown)
/// and `--metrics-out` atomically, so a crashed serve still leaves
/// artifacts no staler than [`FLUSH_INTERVAL`]. Failures are silent
/// here — the final teardown export reports them.
fn flush_exports(
    trace_out: &str,
    metrics_out: &str,
    live: &f2f::obs::stats::LiveSources,
) {
    if !trace_out.is_empty() {
        let lanes = vec![f2f::obs::ProcessLane {
            pid: std::process::id(),
            name: "server".to_string(),
            events: f2f::obs::snapshot(),
        }];
        let _ = f2f::obs::write_atomic(
            std::path::Path::new(trace_out),
            f2f::obs::chrome_trace(&lanes).as_bytes(),
        );
    }
    if !metrics_out.is_empty() {
        if let Some(snap) = live.server_snapshot() {
            let json = build_metrics_report(
                &snap,
                &live.stores(),
                &live.costs(),
            )
            .to_json();
            let _ = f2f::obs::write_atomic(
                std::path::Path::new(metrics_out),
                json.as_bytes(),
            );
        }
    }
}

/// [`run_load`], then keep replaying it until `duration_s` of wall
/// clock has passed (0 = one pass — the default). CI uses the budget
/// to hold a serve open while it polls the stats socket and kills a
/// worker mid-flight.
fn run_load_for(
    server: &f2f::coordinator::InferenceServer,
    requests: usize,
    width: usize,
    seed: u64,
    duration_s: u64,
) -> Result<()> {
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs(duration_s);
    run_load(server, requests, width, seed)?;
    let mut round = 1u64;
    while std::time::Instant::now() < deadline {
        run_load(server, requests, width, seed.wrapping_add(round))?;
        round += 1;
    }
    Ok(())
}

/// Parse `--models id=path,…` (bare `path` entries take the file stem
/// as id) and load each container as a zoo tenant. v3 containers
/// bring their recorded chain; v1/v2 serve as the uniform gemv+relu
/// ladder.
fn load_zoo(spec: &str) -> Result<Vec<f2f::registry::ZooModel>> {
    let mut zoo = Vec::new();
    for part in spec.split(',').filter(|p| !p.is_empty()) {
        let (id, path) = match part.split_once('=') {
            Some((id, path)) => (id.to_string(), path),
            None => {
                let stem = std::path::Path::new(part)
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .unwrap_or(part);
                (stem.to_string(), part)
            }
        };
        zoo.push(f2f::registry::ZooModel::from_path(id, path)?);
    }
    if zoo.is_empty() {
        bail!("--models needs at least one id=path entry");
    }
    Ok(zoo)
}

/// `serve --models`: the zoo serving path. One shared-budget
/// [`f2f::registry::ModelRegistry`] executes every tenant's chain
/// over the same store set (single store, or `--shards` in-process
/// shards), the load interleaves tenants request by request (batches
/// stay model-pure), and the ops plane gains per-model stats.
fn serve_zoo(args: &Args, spec: &str) -> Result<()> {
    use f2f::container::ShardAssignment;
    use f2f::coordinator::{InferenceServer, ServerConfig};
    use f2f::obs::stats::{LiveSources, ModelLiveStats};
    use f2f::registry::{ModelRegistry, MODEL_SEP};
    use f2f::shard::CostProfile;
    use f2f::store::{ReadaheadPolicy, StoreConfig, StoreMetrics};
    use std::sync::Arc;

    let requests: usize = args.get("requests", 2000)?;
    let max_batch: usize = args.get("batch", 16)?;
    let seed: u64 = args.get("seed", 7)?;
    let cache_kb: usize = args.get("cache-kb", 0)?;
    let decode_threads: usize = args.get("decode-threads", 0)?;
    let readahead: ReadaheadPolicy =
        args.get_str("readahead", "on").parse()?;
    let decode_mode: f2f::kernels::DecodeMode =
        args.get_str("decode-mode", "materialized")
            .parse()
            .map_err(|e: String| anyhow::anyhow!(e))?;
    let n_shards: usize = args.get("shards", 1)?;
    let shard_procs: usize = args.get("shard-procs", 0)?;
    let show_timing = args.flag("timing");
    let trace_out = args.get_str("trace-out", "");
    let metrics_out = args.get_str("metrics-out", "");
    let stats_socket = args.get_str("stats-socket", "");
    let duration_s: u64 = args.get("duration-s", 0)?;

    let zoo = load_zoo(spec)?;
    let ids: Vec<String> = zoo.iter().map(|m| m.id.clone()).collect();
    let budget = if cache_kb == 0 { usize::MAX } else { cache_kb << 10 };
    let store_config = StoreConfig {
        cache_budget_bytes: budget,
        decode_workers: decode_threads,
        decode_mode,
    };

    if shard_procs > 0 {
        #[cfg(unix)]
        return serve_zoo_multiproc(args, zoo, shard_procs, store_config);
        #[cfg(not(unix))]
        bail!("--shard-procs requires unix domain sockets (unix only)");
    }

    let registry = if n_shards <= 1 {
        ModelRegistry::new(&zoo, store_config)?
    } else {
        ModelRegistry::new_sharded(
            &zoo,
            n_shards,
            ShardAssignment::ByBytes,
            store_config,
        )?
    }
    .with_readahead(readahead);
    let stores = registry.stores().to_vec();
    let budget_label = if budget == usize::MAX {
        "unbounded".to_string()
    } else {
        format!("{} KiB", budget >> 10)
    };
    println!(
        "zoo: {} models over {} shared store(s), budget {budget_label} \
         per store, readahead {readahead}, decode-mode {decode_mode}",
        ids.len(),
        stores.len(),
    );
    let mut chain_counts: Vec<(String, u64)> = Vec::new();
    for id in &ids {
        let Some(chain) = registry.chain(id) else { continue };
        println!(
            "model {id}: {} steps over {} layers, {} -> {}",
            chain.n_steps(),
            chain.layers().len(),
            chain.input_dim(),
            chain.output_dim(),
        );
        chain_counts.push((id.clone(), chain.layers().len() as u64));
    }

    let server = InferenceServer::start(
        ServerConfig { max_batch, ..Default::default() },
        move || Box::new(registry),
    )?;
    let live = {
        let s1 = stores.clone();
        let s2 = stores.clone();
        let s3 = stores.clone();
        let metrics = server.metrics_handle();
        let inflight = server.inflight_handle();
        let capacity = server.queue_capacity();
        let handles = server.model_metrics_handles();
        LiveSources::new(
            Arc::new(move || {
                let n = s1.len();
                s1.iter()
                    .enumerate()
                    .map(|(i, s)| {
                        let name = if n == 1 {
                            "store".to_string()
                        } else {
                            format!("shard {i}")
                        };
                        (name, s.metrics())
                    })
                    .collect()
            }),
            Arc::new(move || {
                CostProfile::from_stores(s2.iter().map(|s| s.costs()))
                    .entries()
            }),
        )
        .with_server(Arc::new(move || metrics.snapshot()))
        .with_queue(Arc::new(move || {
            (
                inflight.load(std::sync::atomic::Ordering::Relaxed),
                capacity,
            )
        }))
        .with_models(Arc::new(move || {
            handles
                .iter()
                .map(|(id, m)| {
                    let snap = m.snapshot();
                    let prefix = format!("{id}{MODEL_SEP}");
                    let mut cached_layers = 0u64;
                    let mut cached_bytes = 0u64;
                    for s in &s3 {
                        for (name, b) in s.cached_entries() {
                            if name.starts_with(&prefix) {
                                cached_layers += 1;
                                cached_bytes += b as u64;
                            }
                        }
                    }
                    let chain_layers = chain_counts
                        .iter()
                        .find(|(cid, _)| cid == id)
                        .map(|&(_, n)| n)
                        .unwrap_or(0);
                    (
                        id.clone(),
                        ModelLiveStats {
                            completed: snap.completed,
                            errors: snap.errors,
                            p50: snap.p50,
                            p99: snap.p99,
                            mean_batch_size: snap.mean_batch_size(),
                            chain_layers,
                            cached_layers,
                            cached_bytes,
                        },
                    )
                })
                .collect()
        }))
    };
    let ops =
        start_ops_plane(&stats_socket, &trace_out, &metrics_out, &live)?;
    run_zoo_load(&server, &ids, requests, seed, duration_s)?;
    // Let trailing cross-tenant readahead decodes land so the printed
    // counters are stable run to run.
    for s in &stores {
        s.wait_for_idle();
    }
    let mut total = StoreMetrics::default();
    let mut store_metrics = Vec::new();
    for (i, s) in stores.iter().enumerate() {
        let name = if stores.len() == 1 {
            "store".to_string()
        } else {
            format!("shard {i}")
        };
        let sm = s.metrics();
        print_store_metrics(&name, &sm);
        total.merge(&sm);
        store_metrics.push((name, sm));
    }
    if stores.len() > 1 {
        print_store_metrics("all shards", &total);
    }
    let profile =
        CostProfile::from_stores(stores.iter().map(|s| s.costs()));
    for id in &ids {
        if let Some(m) = server.model_metrics(id) {
            println!(
                "model {id}: completed={} errors={} p50={:?} p99={:?} \
                 mean_batch={:.1}",
                m.completed,
                m.errors,
                m.p50,
                m.p99,
                m.mean_batch_size(),
            );
        }
        if show_timing {
            let prefix = format!("{id}{MODEL_SEP}");
            let costs: Vec<_> = profile
                .entries()
                .into_iter()
                .filter_map(|(name, c)| {
                    name.strip_prefix(&prefix)
                        .map(|bare| (bare.to_string(), c))
                })
                .collect();
            print_cost_table(&format!("model {id}"), &costs);
        }
    }
    let snap = server.metrics();
    drop(ops);
    server.shutdown();
    export_observability(
        &trace_out,
        &metrics_out,
        show_timing,
        &snap,
        &store_metrics,
        &profile.entries(),
        Vec::new(),
    );
    Ok(())
}

/// `serve --models --shard-procs N`: shard the *merged* zoo container
/// across N supervised worker processes and serve every tenant
/// through [`f2f::registry::ModelRegistry::over_ipc`] — fetches ride
/// model-scoped wire frames, one shard can hold layers of several
/// tenants, and a killed worker heals through the supervisor's revive
/// path mid-zoo.
#[cfg(unix)]
fn serve_zoo_multiproc(
    args: &Args,
    zoo: Vec<f2f::registry::ZooModel>,
    shard_procs: usize,
    store_config: f2f::store::StoreConfig,
) -> Result<()> {
    use f2f::container::{
        split_container, write_container_v2, ShardAssignment,
    };
    use f2f::coordinator::{InferenceServer, ServerConfig};
    use f2f::ipc::{ProcRouter, Supervisor, WorkerSpec};
    use f2f::obs::stats::{LiveSources, ModelLiveStats};
    use f2f::registry::{merge_zoo, ModelRegistry, MODEL_SEP};
    use f2f::store::StoreMetrics;
    use std::sync::Arc;

    let requests: usize = args.get("requests", 2000)?;
    let max_batch: usize = args.get("batch", 16)?;
    let seed: u64 = args.get("seed", 7)?;
    let readahead: f2f::store::ReadaheadPolicy =
        args.get_str("readahead", "on").parse()?;
    let show_timing = args.flag("timing");
    let trace_out = args.get_str("trace-out", "");
    let metrics_out = args.get_str("metrics-out", "");
    let stats_socket = args.get_str("stats-socket", "");
    let duration_s: u64 = args.get("duration-s", 0)?;
    let workdir_arg = args.get_str("workdir", "");

    let ids: Vec<String> = zoo.iter().map(|m| m.id.clone()).collect();
    let merged = merge_zoo(&zoo)?;
    let bytes = write_container_v2(&merged.container);

    let (workdir, ephemeral) = if workdir_arg.is_empty() {
        (
            std::env::temp_dir().join(format!(
                "f2f-serve-zoo-{}",
                std::process::id()
            )),
            true,
        )
    } else {
        (std::path::PathBuf::from(&workdir_arg), false)
    };
    std::fs::create_dir_all(&workdir)?;
    std::fs::write(workdir.join("zoo.f2f"), &bytes)?;
    let (map, shard_bytes) =
        split_container(&bytes, shard_procs, ShardAssignment::ByBytes)?;
    std::fs::write(workdir.join("zoo.shardmap"), map.to_bytes())?;

    let binary = std::env::current_exe()?;
    let mut specs = Vec::new();
    for (i, b) in shard_bytes.iter().enumerate() {
        let shard_path = workdir.join(format!("zoo.shard{i}.f2f"));
        std::fs::write(&shard_path, b)?;
        specs.push(WorkerSpec {
            binary: binary.clone(),
            shard_path,
            socket_path: workdir.join(format!("shard{i}.sock")),
            cache_kb: if store_config.cache_budget_bytes == usize::MAX {
                0
            } else {
                store_config.cache_budget_bytes >> 10
            },
            decode_threads: store_config.decode_workers,
            decode_mode: store_config.decode_mode,
            flight_dir: Some(workdir.clone()),
        });
    }
    let sup = Supervisor::spawn(specs)?;
    println!(
        "zoo: {} models across {} shard workers (merged container, \
         cross-tenant shards)",
        ids.len(),
        sup.n_workers(),
    );
    for i in 0..sup.n_workers() {
        let layers: Vec<&str> = map.layers_of(i).collect();
        println!("worker {i}: layers [{}]", layers.join(","));
    }

    let registry =
        ModelRegistry::over_ipc(&zoo, &map, sup.clients().to_vec())?
            .with_supervisor(sup.clone())
            .with_readahead(readahead);
    let local_costs = registry.costs().clone();
    let mut chain_counts: Vec<(String, u64)> = Vec::new();
    for id in &ids {
        if let Some(chain) = registry.chain(id) {
            println!(
                "model {id}: {} steps over {} layers, {} -> {}",
                chain.n_steps(),
                chain.layers().len(),
                chain.input_dim(),
                chain.output_dim(),
            );
            chain_counts
                .push((id.clone(), chain.layers().len() as u64));
        }
    }
    let clients: Vec<_> = sup.clients().to_vec();
    let server = InferenceServer::start(
        ServerConfig { max_batch, ..Default::default() },
        move || Box::new(registry),
    )?;
    let live = {
        let c1 = clients.clone();
        let c2 = clients.clone();
        let local = local_costs.clone();
        let metrics = server.metrics_handle();
        let inflight = server.inflight_handle();
        let capacity = server.queue_capacity();
        let handles = server.model_metrics_handles();
        LiveSources::new(
            Arc::new(move || {
                c1.iter()
                    .enumerate()
                    .filter_map(|(i, c)| {
                        c.metrics()
                            .ok()
                            .map(|m| (format!("worker {i}"), m))
                    })
                    .collect()
            }),
            Arc::new(move || {
                let mut profile = f2f::shard::CostProfile::default();
                for c in &c2 {
                    if let Ok(p) = c.cost_profile() {
                        for (name, cost) in p.entries() {
                            profile.record(&name, cost);
                        }
                    }
                }
                for (name, cost) in local.snapshot() {
                    profile.record(&name, cost);
                }
                profile.entries()
            }),
        )
        .with_server(Arc::new(move || metrics.snapshot()))
        .with_queue(Arc::new(move || {
            (
                inflight.load(std::sync::atomic::Ordering::Relaxed),
                capacity,
            )
        }))
        .with_models(Arc::new(move || {
            handles
                .iter()
                .map(|(id, m)| {
                    let snap = m.snapshot();
                    let chain_layers = chain_counts
                        .iter()
                        .find(|(cid, _)| cid == id)
                        .map(|&(_, n)| n)
                        .unwrap_or(0);
                    (
                        id.clone(),
                        ModelLiveStats {
                            completed: snap.completed,
                            errors: snap.errors,
                            p50: snap.p50,
                            p99: snap.p99,
                            mean_batch_size: snap.mean_batch_size(),
                            chain_layers,
                            // Residency lives in the workers; the
                            // per-worker shard rows carry it.
                            cached_layers: 0,
                            cached_bytes: 0,
                        },
                    )
                })
                .collect()
        }))
    };
    let ops =
        start_ops_plane(&stats_socket, &trace_out, &metrics_out, &live)?;
    run_zoo_load(&server, &ids, requests, seed, duration_s)?;
    let model_snaps: Vec<(String, f2f::coordinator::MetricsSnapshot)> =
        ids.iter()
            .filter_map(|id| {
                server.model_metrics(id).map(|m| (id.clone(), m))
            })
            .collect();
    let server_snap = server.metrics();
    drop(ops);
    server.shutdown();

    let mut total = StoreMetrics::default();
    let mut worker_metrics = Vec::new();
    for (i, client) in clients.iter().enumerate() {
        match client.metrics() {
            Ok(m) => {
                print_store_metrics(&format!("worker {i}"), &m);
                total.merge(&m);
                worker_metrics.push((format!("worker {i}"), m));
            }
            Err(e) => println!("worker {i}: metrics unavailable ({e})"),
        }
    }
    print_store_metrics("all workers", &total);
    println!("supervisor: {} worker restarts", sup.restarts());
    for (id, m) in &model_snaps {
        println!(
            "model {id}: completed={} errors={} p50={:?} p99={:?} \
             mean_batch={:.1}",
            m.completed,
            m.errors,
            m.p50,
            m.p99,
            m.mean_batch_size(),
        );
    }
    // Teardown reporting degrades per-worker, exactly like the
    // single-model multiproc path.
    let profile =
        match ProcRouter::merged_profile(&clients, &local_costs) {
            Ok(p) => Some(p),
            Err(e) => {
                println!("cost profile unavailable ({e:#})");
                None
            }
        };
    if let Some(profile) = &profile {
        if show_timing {
            for id in &ids {
                let prefix = format!("{id}{MODEL_SEP}");
                let costs: Vec<_> = profile
                    .entries()
                    .into_iter()
                    .filter_map(|(name, c)| {
                        name.strip_prefix(&prefix)
                            .map(|bare| (bare.to_string(), c))
                    })
                    .collect();
                print_cost_table(&format!("model {id}"), &costs);
            }
        }
    }
    export_observability(
        &trace_out,
        &metrics_out,
        show_timing,
        &server_snap,
        &worker_metrics,
        &profile.as_ref().map(|p| p.entries()).unwrap_or_default(),
        Vec::new(),
    );
    sup.shutdown();
    if ephemeral {
        let _ = std::fs::remove_dir_all(&workdir);
    } else {
        println!(
            "kept workdir {} (merged zoo container + shards + map)",
            workdir.display()
        );
    }
    Ok(())
}

/// Interleave `requests` across the zoo's tenants round-robin —
/// model-pure batches, cross-model cache pressure — then keep
/// replaying until the `--duration-s` wall-clock budget is spent.
fn run_zoo_load(
    server: &f2f::coordinator::InferenceServer,
    ids: &[String],
    requests: usize,
    seed: u64,
    duration_s: u64,
) -> Result<()> {
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs(duration_s);
    run_zoo_round(server, ids, requests, seed)?;
    let mut round = 1u64;
    while std::time::Instant::now() < deadline {
        run_zoo_round(server, ids, requests, seed.wrapping_add(round))?;
        round += 1;
    }
    Ok(())
}

/// One interleaved pass: request `r` goes to tenant `r % N`, sized to
/// that tenant's input width.
fn run_zoo_round(
    server: &f2f::coordinator::InferenceServer,
    ids: &[String],
    requests: usize,
    seed: u64,
) -> Result<()> {
    let dims = ids
        .iter()
        .map(|id| {
            server.model_input_dim(id).ok_or_else(|| {
                anyhow::anyhow!("server has no model {id:?}")
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let mut rng = f2f::rng::Rng::new(seed);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for r in 0..requests {
        let i = r % ids.len();
        let x: Vec<f32> =
            (0..dims[i]).map(|_| rng.next_f32() - 0.5).collect();
        pending.push(server.infer_model_async(&ids[i], x));
    }
    for p in pending {
        p.recv()??;
    }
    let dt = t0.elapsed();
    let m = server.metrics();
    println!(
        "{requests} requests across {} models in {dt:?} \
         ({:.0} req/s), batches={} mean_batch={:.1}",
        ids.len(),
        requests as f64 / dt.as_secs_f64(),
        m.batches,
        m.mean_batch_size()
    );
    Ok(())
}

fn print_store_metrics(label: &str, sm: &f2f::store::StoreMetrics) {
    println!(
        "{label}: hits={} misses={} decodes={} evictions={} \
         cached={} KiB ({} layers)",
        sm.hits,
        sm.misses,
        sm.decodes,
        sm.evictions,
        sm.cached_bytes >> 10,
        sm.cached_layers,
    );
    println!(
        "{label} readahead: prefetches={} skips={} \
         redundant_decodes={}",
        sm.prefetches, sm.readahead_skips, sm.redundant_decodes,
    );
}

/// Per-layer observed cost table (`--timing`): exactly the telemetry
/// the auto readahead planner reads.
fn print_cost_table(
    label: &str,
    costs: &[(String, f2f::store::LayerCost)],
) {
    let mut table = f2f::report::Table::new(
        &format!("{label}: per-layer observed costs (EWMA)"),
        &[
            "layer",
            "decode_us",
            "decode_samples",
            "gemv_us_per_item",
            "gemv_samples",
        ],
    );
    for (name, c) in costs {
        table.row(vec![
            name.clone(),
            format!("{:.1}", c.decode_ns / 1e3),
            c.decode_samples.to_string(),
            format!("{:.2}", c.gemv_ns / 1e3),
            c.gemv_samples.to_string(),
        ]);
    }
    print!("{}", table.render());
}

/// The observability tail shared by every serve path (single-store,
/// sharded, multi-process): the `--timing` histogram summary, the
/// `--metrics-out` registry, and the `--trace-out` Chrome trace. All
/// of it is teardown reporting, so failures print and degrade instead
/// of turning a completed serve into a nonzero exit.
fn export_observability(
    trace_out: &str,
    metrics_out: &str,
    show_timing: bool,
    server: &f2f::coordinator::MetricsSnapshot,
    stores: &[(String, f2f::store::StoreMetrics)],
    costs: &[(String, f2f::store::LayerCost)],
    worker_lanes: Vec<f2f::obs::ProcessLane>,
) {
    if show_timing {
        print_latency_histograms(server, stores);
    }
    if !metrics_out.is_empty() {
        let json = build_metrics_report(server, stores, costs).to_json();
        // Self-check before writing: the registry must stay readable
        // by the same hand-rolled JSON reader `f2f rebalance` uses.
        match f2f::shard::CostProfile::parse_json(&json) {
            Ok(_) => match std::fs::write(metrics_out, &json) {
                Ok(()) => println!(
                    "wrote {metrics_out} (unified metrics registry)"
                ),
                Err(e) => {
                    println!("could not write {metrics_out}: {e}")
                }
            },
            Err(e) => println!(
                "metrics registry failed its own round-trip check, \
                 not written: {e:#}"
            ),
        }
    }
    if !trace_out.is_empty() {
        let mut lanes = vec![f2f::obs::ProcessLane {
            pid: std::process::id(),
            name: "server".to_string(),
            events: f2f::obs::snapshot(),
        }];
        lanes.extend(worker_lanes);
        let n_spans: usize = lanes.iter().map(|l| l.events.len()).sum();
        match std::fs::write(trace_out, f2f::obs::chrome_trace(&lanes))
        {
            Ok(()) => println!(
                "wrote {trace_out} ({n_spans} spans across {} process \
                 lanes) — load it in chrome://tracing or Perfetto",
                lanes.len()
            ),
            Err(e) => println!("could not write {trace_out}: {e}"),
        }
    }
}

/// `--timing` histogram summary: request/batch latency from the
/// server plus decode/GEMV phase latency per store, log-bucketed
/// quantiles (see [`f2f::obs::HdrLite`]).
fn print_latency_histograms(
    server: &f2f::coordinator::MetricsSnapshot,
    stores: &[(String, f2f::store::StoreMetrics)],
) {
    let mut series: Vec<(String, f2f::obs::HdrLite)> = vec![
        ("request".to_string(), server.latency),
        ("batch".to_string(), server.batch_time),
    ];
    for (label, sm) in stores {
        series.push((format!("{label} decode"), sm.decode_hist));
        series.push((format!("{label} gemv"), sm.gemv_hist));
    }
    let mut table = f2f::report::Table::new(
        "latency histograms (log-bucketed)",
        &["series", "count", "p50", "p95", "p99", "max"],
    );
    for (name, h) in &series {
        table.row(vec![
            name.clone(),
            h.count().to_string(),
            format!("{:?}", h.percentile(0.50)),
            format!("{:?}", h.percentile(0.95)),
            format!("{:?}", h.percentile(0.99)),
            format!("{:?}", h.max()),
        ]);
    }
    print!("{}", table.render());
}

/// Quantile + count metrics of one histogram under `case`.
fn hist_metrics(
    rep: &mut f2f::bench_util::JsonReport,
    case: &str,
    prefix: &str,
    h: &f2f::obs::HdrLite,
) {
    rep.metric(case, &format!("{prefix}_count"), h.count() as f64);
    for (q, tag) in [(0.50, "p50"), (0.95, "p95"), (0.99, "p99")] {
        rep.metric(
            case,
            &format!("{prefix}_{tag}_s"),
            h.percentile(q).as_secs_f64(),
        );
    }
    rep.metric(case, &format!("{prefix}_max_s"), h.max().as_secs_f64());
}

/// The `--metrics-out` registry: one JSON object unifying the serving
/// tier's telemetry — server counters with request/batch histogram
/// quantiles, per-store cache counters with decode/GEMV histogram
/// quantiles, and per-layer observed costs (the same numbers
/// `--profile-out` exports, here under `layer/<name>` cases).
fn build_metrics_report(
    server: &f2f::coordinator::MetricsSnapshot,
    stores: &[(String, f2f::store::StoreMetrics)],
    costs: &[(String, f2f::store::LayerCost)],
) -> f2f::bench_util::JsonReport {
    let mut rep =
        f2f::bench_util::JsonReport::new("f2f serve metrics");
    rep.metric("server", "completed", server.completed as f64);
    rep.metric("server", "batches", server.batches as f64);
    rep.metric("server", "errors", server.errors as f64);
    rep.metric("server", "mean_batch_size", server.mean_batch_size());
    hist_metrics(&mut rep, "server", "request", &server.latency);
    hist_metrics(&mut rep, "server", "batch", &server.batch_time);
    for (label, sm) in stores {
        for (key, v) in [
            ("hits", sm.hits),
            ("misses", sm.misses),
            ("decodes", sm.decodes),
            ("evictions", sm.evictions),
            ("prefetches", sm.prefetches),
            ("redundant_decodes", sm.redundant_decodes),
            ("readahead_skips", sm.readahead_skips),
            ("cached_bytes", sm.cached_bytes as u64),
            ("cached_layers", sm.cached_layers as u64),
        ] {
            rep.metric(label, key, v as f64);
        }
        hist_metrics(&mut rep, label, "decode", &sm.decode_hist);
        hist_metrics(&mut rep, label, "gemv", &sm.gemv_hist);
    }
    for (name, c) in costs {
        let case = format!("layer/{name}");
        rep.metric(&case, "decode_ns", c.decode_ns);
        rep.metric(&case, "decode_samples", c.decode_samples as f64);
        rep.metric(&case, "gemv_ns", c.gemv_ns);
        rep.metric(&case, "gemv_samples", c.gemv_samples as f64);
    }
    rep
}

/// Knobs of the multi-process serve path, bundled so the branch in
/// [`cmd_serve`] stays readable.
#[cfg(unix)]
struct MultiprocOpts {
    shard_procs: usize,
    requests: usize,
    max_batch: usize,
    seed: u64,
    width: usize,
    cache_kb: usize,
    decode_threads: usize,
    decode_mode: f2f::kernels::DecodeMode,
    readahead: f2f::store::ReadaheadPolicy,
    show_timing: bool,
    profile_out_explicit: String,
    profile_out_requested: bool,
    trace_out: String,
    metrics_out: String,
    stats_socket: String,
    duration_s: u64,
    /// Where shard files, map, and sidecars land. Empty = an
    /// ephemeral temp dir removed on exit; explicit = kept, so the
    /// artifacts (including the per-shard cost sidecars that warm
    /// restarted workers) survive for the next serve.
    workdir: String,
}

/// `serve --shard-procs N`: split the compressed model into N shard
/// files, spawn one supervised `f2f shard-worker` process per shard,
/// and serve through a [`f2f::ipc::ProcRouter`] behind the batching
/// server — the multi-process serving tier, end to end.
#[cfg(unix)]
fn serve_multiproc(
    container: &f2f::container::Container,
    opts: &MultiprocOpts,
) -> Result<()> {
    use f2f::container::{
        split_container, write_container_v2, ContainerIndex,
        ShardAssignment,
    };
    use f2f::coordinator::{InferenceServer, ServerConfig};
    use f2f::ipc::{ProcRouter, Supervisor, WorkerSpec};
    use f2f::store::{cost_sidecar_path, StoreMetrics};
    use std::sync::Arc;

    let (workdir, ephemeral) = if opts.workdir.is_empty() {
        (
            std::env::temp_dir().join(format!(
                "f2f-serve-procs-{}",
                std::process::id()
            )),
            true,
        )
    } else {
        (std::path::PathBuf::from(&opts.workdir), false)
    };
    std::fs::create_dir_all(&workdir)?;
    let bytes = write_container_v2(container);
    let model_path = workdir.join("model.f2f");
    std::fs::write(&model_path, &bytes)?;
    let (map, shard_bytes) = split_container(
        &bytes,
        opts.shard_procs,
        ShardAssignment::ByBytes,
    )?;
    std::fs::write(workdir.join("model.shardmap"), map.to_bytes())?;

    let binary = std::env::current_exe()?;
    let mut specs = Vec::new();
    let mut shard_paths = Vec::new();
    for (i, b) in shard_bytes.iter().enumerate() {
        let shard_path = workdir.join(format!("model.shard{i}.f2f"));
        std::fs::write(&shard_path, b)?;
        specs.push(WorkerSpec {
            binary: binary.clone(),
            shard_path: shard_path.clone(),
            socket_path: workdir.join(format!("shard{i}.sock")),
            cache_kb: opts.cache_kb,
            decode_threads: opts.decode_threads,
            decode_mode: opts.decode_mode,
            // Crash flight recorder sidecars land next to the shards;
            // the supervisor turns them into postmortems on reap.
            flight_dir: Some(workdir.clone()),
        });
        shard_paths.push(shard_path);
    }
    let sup = Supervisor::spawn(specs)?;
    let budget_label = if opts.cache_kb == 0 {
        "unbounded".to_string()
    } else {
        format!("{} KiB", opts.cache_kb)
    };
    println!(
        "spawned {} shard workers (cache {budget_label}/worker, \
         readahead {}, decode-mode {}):",
        sup.n_workers(),
        opts.readahead,
        opts.decode_mode,
    );
    for i in 0..sup.n_workers() {
        let layers: Vec<&str> = map.layers_of(i).collect();
        println!(
            "worker {i}: pid {}, layers [{}], socket {}",
            sup.worker_pid(i)
                .map(|p| p.to_string())
                .unwrap_or_else(|| "?".into()),
            layers.join(","),
            workdir.join(format!("shard{i}.sock")).display(),
        );
    }

    let index = ContainerIndex::parse(&bytes)?;
    let router =
        ProcRouter::new(sup.clients().to_vec(), &map, &index)?
            .with_readahead(opts.readahead)
            .with_supervisor(sup.clone());
    // Keep a handle on the router-local GEMV telemetry: the router
    // itself moves behind the server.
    let local_costs = router.costs().clone();
    let clients: Vec<_> = sup.clients().to_vec();
    let server = InferenceServer::start(
        ServerConfig {
            max_batch: opts.max_batch,
            ..Default::default()
        },
        move || Box::new(router),
    )?;
    // Live sources poll the workers over the same IPC clients the
    // router serves with; the per-client mutex serializes a poll
    // against in-flight fetches, so polling never changes results —
    // it only interleaves. A worker mid-restart is skipped rather
    // than failing the whole snapshot.
    let live = {
        let c1 = clients.clone();
        let c2 = clients.clone();
        let local = local_costs.clone();
        let metrics = server.metrics_handle();
        let inflight = server.inflight_handle();
        let capacity = server.queue_capacity();
        f2f::obs::stats::LiveSources::new(
            Arc::new(move || {
                c1.iter()
                    .enumerate()
                    .filter_map(|(i, c)| {
                        c.metrics()
                            .ok()
                            .map(|m| (format!("worker {i}"), m))
                    })
                    .collect()
            }),
            Arc::new(move || {
                let mut profile = f2f::shard::CostProfile::default();
                for c in &c2 {
                    if let Ok(p) = c.cost_profile() {
                        for (name, cost) in p.entries() {
                            profile.record(&name, cost);
                        }
                    }
                }
                for (name, cost) in local.snapshot() {
                    profile.record(&name, cost);
                }
                profile.entries()
            }),
        )
        .with_server(Arc::new(move || metrics.snapshot()))
        .with_queue(Arc::new(move || {
            (
                inflight.load(std::sync::atomic::Ordering::Relaxed),
                capacity,
            )
        }))
    };
    let ops = start_ops_plane(
        &opts.stats_socket,
        &opts.trace_out,
        &opts.metrics_out,
        &live,
    )?;
    run_load_for(
        &server,
        opts.requests,
        opts.width,
        opts.seed,
        opts.duration_s,
    )?;
    let server_snap = server.metrics();
    drop(ops);
    server.shutdown();

    // Aggregate worker metrics over the wire — the counters a
    // single-process serve prints, now gathered across processes.
    let mut total = StoreMetrics::default();
    let mut worker_metrics = Vec::new();
    for (i, client) in clients.iter().enumerate() {
        match client.metrics() {
            Ok(m) => {
                print_store_metrics(&format!("worker {i}"), &m);
                total.merge(&m);
                worker_metrics.push((format!("worker {i}"), m));
            }
            Err(e) => println!("worker {i}: metrics unavailable ({e})"),
        }
    }
    print_store_metrics("all workers", &total);
    println!("supervisor: {} worker restarts", sup.restarts());

    // Pull every worker's span lane for the cross-process trace: the
    // shared request trace ids are what connect a worker's decode
    // spans to this process's GEMV and ipc_fetch spans.
    let mut worker_lanes = Vec::new();
    if !opts.trace_out.is_empty() {
        for (i, client) in clients.iter().enumerate() {
            match client.trace_events() {
                Ok((pid, events)) => {
                    worker_lanes.push(f2f::obs::ProcessLane {
                        pid,
                        name: format!("worker {i}"),
                        events,
                    })
                }
                Err(e) => {
                    println!("worker {i}: trace unavailable ({e})")
                }
            }
        }
    }

    // The profile merge is teardown reporting, like the metrics loop
    // above: a worker that died *after* serving completed must not
    // turn a successful serve into a nonzero exit (or skip the
    // workdir cleanup below) — degrade per-worker instead.
    let profile = match ProcRouter::merged_profile(
        &clients,
        &local_costs,
    ) {
        Ok(profile) => Some(profile),
        Err(e) => {
            println!("cost profile unavailable ({e:#})");
            None
        }
    };
    if let Some(profile) = &profile {
        if opts.show_timing {
            print_cost_table("all workers", &profile.entries());
        }
        // `--profile-out <path>` exports there; a bare
        // `--profile-out` targets the container's auto-loaded
        // sidecar — but never inside an ephemeral workdir (it is
        // deleted on exit, which would silently discard the profile
        // right after advertising its path). Without `--workdir`,
        // the bare flag falls back to the cwd sidecar of the default
        // `f2f compress` output.
        let profile_out = if !opts.profile_out_explicit.is_empty() {
            opts.profile_out_explicit.clone()
        } else if opts.profile_out_requested && !ephemeral {
            cost_sidecar_path(&model_path).display().to_string()
        } else if opts.profile_out_requested {
            cost_sidecar_path(std::path::Path::new("model.f2f"))
                .display()
                .to_string()
        } else {
            String::new()
        };
        if !profile_out.is_empty() {
            match std::fs::write(&profile_out, profile.to_json()) {
                Ok(()) => println!(
                    "wrote {profile_out} ({} layers) — feed it to \
                     `f2f rebalance --profile {profile_out}`",
                    profile.len()
                ),
                Err(e) => println!(
                    "could not write {profile_out}: {e}"
                ),
            }
        }
    }
    export_observability(
        &opts.trace_out,
        &opts.metrics_out,
        opts.show_timing,
        &server_snap,
        &worker_metrics,
        &profile.as_ref().map(|p| p.entries()).unwrap_or_default(),
        worker_lanes,
    );

    // Per-shard sidecars: a worker respawned over these files (this
    // run or the next, in a kept workdir) opens with a warm planner.
    for (i, (client, shard_path)) in
        clients.iter().zip(&shard_paths).enumerate()
    {
        if let Ok(p) = client.cost_profile() {
            let sidecar = cost_sidecar_path(shard_path);
            if std::fs::write(&sidecar, p.to_json()).is_ok()
                && !ephemeral
            {
                println!(
                    "wrote {} (warm planner for worker {i} restarts)",
                    sidecar.display()
                );
            }
        }
    }
    sup.shutdown();
    if ephemeral {
        let _ = std::fs::remove_dir_all(&workdir);
    } else {
        println!(
            "kept workdir {} (shards + map + cost sidecars)",
            workdir.display()
        );
    }
    Ok(())
}

/// Fire `requests` random vectors at the server and report throughput
/// plus latency percentiles (shared by the single-store and sharded
/// serve paths).
fn run_load(
    server: &f2f::coordinator::InferenceServer,
    requests: usize,
    width: usize,
    seed: u64,
) -> Result<()> {
    let mut rng = f2f::rng::Rng::new(seed);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for _ in 0..requests {
        let x: Vec<f32> =
            (0..width).map(|_| rng.next_f32() - 0.5).collect();
        pending.push(server.infer_async(x));
    }
    for p in pending {
        p.recv()??;
    }
    let dt = t0.elapsed();
    let m = server.metrics();
    println!(
        "{requests} requests in {dt:?} ({:.0} req/s), batches={} \
         mean_batch={:.1}",
        requests as f64 / dt.as_secs_f64(),
        m.batches,
        m.mean_batch_size()
    );
    println!("latency p50={:?} p95={:?} p99={:?}", m.p50, m.p95, m.p99);
    Ok(())
}

fn cmd_hw(args: &Args) -> Result<()> {
    use f2f::decoder::{DecoderSpec, SequentialDecoder};
    let s: f64 = args.get("s", 0.9)?;
    let n_in: usize = args.get("nin", 8)?;
    let n_s: usize = args.get("ns", 2)?;
    let spec = DecoderSpec::for_sparsity(n_in, s, n_s);
    let dec = SequentialDecoder::random(spec, 0);
    let c = dec.hardware_cost();
    println!(
        "decoder spec: N_in={} N_out={} N_s={}",
        spec.n_in, spec.n_out, spec.n_s
    );
    println!(
        "xor gates:           {} (estimate {})",
        c.xor_gates, c.xor_gates_estimate
    );
    println!("transistors:         {}", c.transistors);
    println!("register bits:       {}", c.register_bits);
    println!("latency (cycles):    {}", c.latency_cycles);
    println!("throughput (b/cyc):  {}", c.throughput_bits_per_cycle);
    println!(
        "transistors/output bit: {:.1}",
        c.transistors_per_output_bit()
    );
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    use anyhow::Context as _;
    use f2f::analysis::{lint_source, render, run_lint};
    use std::path::PathBuf;

    // Single-file mode: lint one file as if it lived at the given
    // `rust/src`-relative path, so every scoped rule applies. CI uses
    // this to run the must-fail fixture corpus.
    let file = args.get_str("file", "");
    if !file.is_empty() {
        let rel = args.get_str("as", &file);
        let src = std::fs::read_to_string(&file)
            .with_context(|| format!("reading {file}"))?;
        let findings = lint_source(&rel, &src);
        print!("{}", render(&findings));
        if !findings.is_empty() {
            bail!("lint: {} finding(s) in {file}", findings.len());
        }
        println!("lint: {file} clean (as {rel})");
        return Ok(());
    }

    let root_arg = args.get_str("root", "");
    let root = if root_arg.is_empty() {
        discover_repo_root()?
    } else {
        PathBuf::from(root_arg)
    };
    let findings = run_lint(&root)?;
    print!("{}", render(&findings));
    if !findings.is_empty() {
        bail!("lint: {} finding(s)", findings.len());
    }
    let src_root = root.join("rust").join("src");
    println!("lint: {} clean", src_root.display());
    Ok(())
}

/// Find the repo root (the directory holding `rust/src`): walk up from
/// the current directory — works from the repo root and from `rust/` —
/// then fall back to the source tree this binary was built from.
fn discover_repo_root() -> Result<std::path::PathBuf> {
    let mut dir = std::env::current_dir()?;
    loop {
        if dir.join("rust").join("src").is_dir() {
            return Ok(dir);
        }
        if !dir.pop() {
            break;
        }
    }
    let built = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    if built.join("rust").join("src").is_dir() {
        return Ok(built.to_path_buf());
    }
    bail!("cannot locate rust/src; pass --root <dir>")
}

//! The inverting technique (§5.1, Figure 9).
//!
//! A random XOR-gate decoder finds zero outputs "for free" (the all-zero
//! input always decodes to the all-zero block), so encoding efficiency
//! rises when unpruned weight bits skew toward zero. FP32 exponent planes
//! skew heavily (Figure S.12); when a plane's unpruned bits hold *more
//! ones than zeros*, flipping the whole plane (and remembering one flag
//! bit) converts the skew into the favourable direction. The paper
//! applies this for `N_s ∈ {0, 1}`, where the gain is noticeable; INT8
//! planes are near-balanced so inverting is a no-op ("N/A" in Table 2).

use crate::gf2::BitVecF2;

/// Outcome of the inverting decision for one plane.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InvertDecision {
    /// Whether the plane should be flipped before encoding.
    pub apply: bool,
    /// Zero-ratio of the unpruned bits before flipping.
    pub zero_ratio: f64,
}

/// Decide whether to invert: flip when the ratio of zeros among
/// *unpruned* bits is below 50%.
pub fn decide_invert(plane: &BitVecF2, mask: &BitVecF2) -> InvertDecision {
    assert_eq!(plane.len(), mask.len());
    let mut zeros = 0usize;
    let mut total = 0usize;
    for i in 0..plane.len() {
        if mask.get(i) {
            total += 1;
            if !plane.get(i) {
                zeros += 1;
            }
        }
    }
    let zero_ratio =
        if total == 0 { 1.0 } else { zeros as f64 / total as f64 };
    InvertDecision { apply: zero_ratio < 0.5, zero_ratio }
}

/// Apply the decision: returns a (possibly flipped) copy plus the flag to
/// store alongside the encoded stream.
pub fn maybe_invert(
    plane: &BitVecF2,
    mask: &BitVecF2,
) -> (BitVecF2, bool) {
    let d = decide_invert(plane, mask);
    if d.apply {
        let mut p = plane.clone();
        p.invert();
        (p, true)
    } else {
        (plane.clone(), false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn skewed_to_ones_gets_inverted() {
        let mut rng = Rng::new(1);
        let plane = BitVecF2::random(1000, 0.9, &mut rng); // 90% ones
        let mask = BitVecF2::random(1000, 0.5, &mut rng);
        let d = decide_invert(&plane, &mask);
        assert!(d.apply);
        assert!(d.zero_ratio < 0.2);
    }

    #[test]
    fn skewed_to_zeros_left_alone() {
        let mut rng = Rng::new(2);
        let plane = BitVecF2::random(1000, 0.1, &mut rng);
        let mask = BitVecF2::random(1000, 0.5, &mut rng);
        assert!(!decide_invert(&plane, &mask).apply);
    }

    #[test]
    fn decision_uses_only_unpruned_bits() {
        // Plane: ones where pruned, zeros where unpruned → no invert.
        let n = 100;
        let mask = BitVecF2::from_iter_bits((0..n).map(|i| i % 2 == 0));
        let plane = BitVecF2::from_iter_bits((0..n).map(|i| i % 2 == 1));
        let d = decide_invert(&plane, &mask);
        assert!(!d.apply);
        assert_eq!(d.zero_ratio, 1.0);
    }

    #[test]
    fn maybe_invert_roundtrip() {
        let mut rng = Rng::new(3);
        let plane = BitVecF2::random(500, 0.8, &mut rng);
        let mask = BitVecF2::random(500, 0.5, &mut rng);
        let (flipped, flag) = maybe_invert(&plane, &mask);
        assert!(flag);
        let mut back = flipped;
        back.invert();
        assert_eq!(back, plane);
    }

    #[test]
    fn empty_mask_means_no_invert() {
        let plane = BitVecF2::random(100, 0.9, &mut Rng::new(4));
        let mask = BitVecF2::zeros(100);
        assert!(!decide_invert(&plane, &mask).apply);
    }
}

//! Bit-plane grouping of FP32 / signed-INT8 tensors.

use crate::gf2::BitVecF2;

/// A tensor decomposed into `n_w` bit-planes (plane 0 = MSB/sign).
#[derive(Debug, Clone)]
pub struct BitPlanes {
    planes: Vec<BitVecF2>,
    n_weights: usize,
}

impl BitPlanes {
    /// Decompose FP32 weights into 32 planes. Plane `k` holds IEEE-754
    /// bit `31 − k` of each weight (so plane 0 = sign, planes 1–8 =
    /// exponent, planes 9–31 = mantissa — Figure S.12's indexing shifted
    /// to 0-based).
    pub fn from_f32(weights: &[f32]) -> Self {
        let n = weights.len();
        let mut planes = vec![BitVecF2::zeros(n); 32];
        for (i, &w) in weights.iter().enumerate() {
            let bits = w.to_bits();
            for (k, plane) in planes.iter_mut().enumerate() {
                if (bits >> (31 - k)) & 1 == 1 {
                    plane.set(i, true);
                }
            }
        }
        BitPlanes { planes, n_weights: n }
    }

    /// Decompose signed INT8 weights into 8 planes (plane 0 = sign bit of
    /// the two's-complement byte).
    pub fn from_i8(weights: &[i8]) -> Self {
        let n = weights.len();
        let mut planes = vec![BitVecF2::zeros(n); 8];
        for (i, &w) in weights.iter().enumerate() {
            let bits = w as u8;
            for (k, plane) in planes.iter_mut().enumerate() {
                if (bits >> (7 - k)) & 1 == 1 {
                    plane.set(i, true);
                }
            }
        }
        BitPlanes { planes, n_weights: n }
    }

    /// Number of planes (`n_w`: 32 for FP32, 8 for INT8).
    pub fn n_planes(&self) -> usize {
        self.planes.len()
    }

    /// Number of weights per plane.
    pub fn n_weights(&self) -> usize {
        self.n_weights
    }

    /// Plane `k` (0 = MSB).
    pub fn plane(&self, k: usize) -> &BitVecF2 {
        &self.planes[k]
    }

    /// Mutable plane access (inverting, reconstruction-time correction).
    pub fn plane_mut(&mut self, k: usize) -> &mut BitVecF2 {
        &mut self.planes[k]
    }

    /// Iterate planes MSB-first.
    pub fn iter(&self) -> impl Iterator<Item = &BitVecF2> {
        self.planes.iter()
    }

    /// Reassemble FP32 weights (requires 32 planes).
    pub fn to_f32(&self) -> Vec<f32> {
        assert_eq!(self.planes.len(), 32);
        (0..self.n_weights)
            .map(|i| {
                let mut bits = 0u32;
                for (k, plane) in self.planes.iter().enumerate() {
                    if plane.get(i) {
                        bits |= 1 << (31 - k);
                    }
                }
                f32::from_bits(bits)
            })
            .collect()
    }

    /// Reassemble signed INT8 weights (requires 8 planes).
    pub fn to_i8(&self) -> Vec<i8> {
        assert_eq!(self.planes.len(), 8);
        (0..self.n_weights)
            .map(|i| {
                let mut bits = 0u8;
                for (k, plane) in self.planes.iter().enumerate() {
                    if plane.get(i) {
                        bits |= 1 << (7 - k);
                    }
                }
                bits as i8
            })
            .collect()
    }

    /// Zero-ratio of each plane's *unpruned* bits under `mask` —
    /// the statistic plotted in Figure S.12.
    pub fn zero_ratios(&self, mask: &BitVecF2) -> Vec<f64> {
        self.planes
            .iter()
            .map(|p| {
                let mut zeros = 0usize;
                let mut total = 0usize;
                for i in 0..self.n_weights {
                    if mask.get(i) {
                        total += 1;
                        if !p.get(i) {
                            zeros += 1;
                        }
                    }
                }
                if total == 0 {
                    1.0
                } else {
                    zeros as f64 / total as f64
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn f32_roundtrip_exact() {
        let mut rng = Rng::new(1);
        let w: Vec<f32> = (0..257)
            .map(|_| (rng.normal() * 0.05) as f32)
            .collect();
        let planes = BitPlanes::from_f32(&w);
        assert_eq!(planes.n_planes(), 32);
        let back = planes.to_f32();
        assert_eq!(
            w.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            back.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn i8_roundtrip_exact() {
        let w: Vec<i8> = (-128..=127).collect();
        let planes = BitPlanes::from_i8(&w);
        assert_eq!(planes.n_planes(), 8);
        assert_eq!(planes.to_i8(), w);
    }

    #[test]
    fn plane0_is_sign_bit() {
        let w = vec![-1.0f32, 2.0, -3.0, 4.0];
        let planes = BitPlanes::from_f32(&w);
        let signs: Vec<bool> = planes.plane(0).iter().collect();
        assert_eq!(signs, vec![true, false, true, false]);
    }

    #[test]
    fn i8_plane0_is_sign_bit() {
        let w = vec![-5i8, 5, -100, 100];
        let planes = BitPlanes::from_i8(&w);
        let signs: Vec<bool> = planes.plane(0).iter().collect();
        assert_eq!(signs, vec![true, false, true, false]);
    }

    #[test]
    fn exponent_planes_are_skewed_for_small_gaussian_weights() {
        // Weight-decayed DNN weights are ≪ 1, so high exponent bits have
        // strongly skewed 0/1 ratios (Fig. S.12's observation).
        let mut rng = Rng::new(2);
        let w: Vec<f32> = (0..4096)
            .map(|_| (rng.normal() * 0.05) as f32)
            .collect();
        let planes = BitPlanes::from_f32(&w);
        let mask = BitVecF2::from_bools(&vec![true; w.len()]);
        let zr = planes.zero_ratios(&mask);
        // Exponent MSB (plane 1): |w| < 2 ⇒ exponent < 128 ⇒ bit is 0.
        assert!(zr[1] > 0.99, "plane1 zero-ratio {}", zr[1]);
        // Next exponent bits ~all ones for 2^-64 < |w| < 1.
        assert!(zr[2] < 0.01, "plane2 zero-ratio {}", zr[2]);
        // Deep mantissa bits are ~uniform.
        assert!((zr[28] - 0.5).abs() < 0.05, "plane28 zero-ratio {}", zr[28]);
    }

    #[test]
    fn zero_ratio_respects_mask() {
        let w = vec![-1.0f32, 1.0, -1.0, 1.0];
        let planes = BitPlanes::from_f32(&w);
        // Only positions 0 and 2 unpruned → sign plane all ones → ratio 0.
        let mask = BitVecF2::from_bools(&[true, false, true, false]);
        let zr = planes.zero_ratios(&mask);
        assert_eq!(zr[0], 0.0);
    }
}

//! Weight manipulation: bit-plane grouping, flattening, slicing (§4,
//! Figure 6) and the inverting technique (§5.1).
//!
//! A tensor in an `n_w`-bit number format is split into `n_w` binary
//! planes: plane `k` concatenates the `k`-th bit of every weight. Bit
//! indices follow the paper's Figure S.12 convention — **k = 0 is the
//! sign/most-significant bit**, `k = n_w − 1` the least-significant
//! mantissa bit. Every plane shares the layer's pruning mask.
//!
//! Planes are encoded independently; the inverting technique flips an
//! entire plane when unpruned bits contain fewer zeros than ones, because
//! a random XOR decoder has a slight bias toward producing zeros from
//! sparse inputs (Figure 9).

mod bitplane;
mod invert;

pub use bitplane::BitPlanes;
pub use invert::{decide_invert, maybe_invert, InvertDecision};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf2::BitVecF2;
    use crate::rng::Rng;

    #[test]
    fn module_reexports_work() {
        let mut rng = Rng::new(1);
        let w: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let planes = BitPlanes::from_f32(&w);
        let mask = BitVecF2::from_iter_bits((0..64).map(|i| i % 2 == 0));
        let d = decide_invert(planes.plane(0), &mask);
        let _ = d.apply;
    }
}

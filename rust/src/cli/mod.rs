//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, and positional arguments, with typed
//! accessors and defaults. Enough for the `f2f` binary's subcommands.

use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// Parsed arguments: positionals + `--key [value]` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                match iter.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = iter.next().unwrap();
                        out.options.insert(key.to_string(), v);
                    }
                    _ => out.flags.push(key.to_string()),
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Boolean flag (`--csv`).
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// String option with default.
    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.options
            .get(name)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Typed option with default.
    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name}: cannot parse {v:?}")),
        }
    }

    /// Required positional at index `i`.
    pub fn pos(&self, i: usize) -> Result<&str> {
        self.positional
            .get(i)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("missing positional argument {i}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("repro table1 --bits 100000 --csv");
        assert_eq!(a.pos(0).unwrap(), "repro");
        assert_eq!(a.pos(1).unwrap(), "table1");
        assert_eq!(a.get("bits", 0usize).unwrap(), 100_000);
        assert!(a.flag("csv"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("x");
        assert_eq!(a.get("seed", 42u64).unwrap(), 42);
        assert_eq!(a.get_str("out", "art"), "art");
    }

    #[test]
    fn bad_parse_is_error() {
        let a = parse("x --bits abc");
        assert!(a.get("bits", 0usize).is_err());
    }

    #[test]
    fn missing_positional_is_error() {
        let a = parse("only");
        assert!(a.pos(1).is_err());
    }
}

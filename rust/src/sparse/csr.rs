//! Compressed Sparse Row — the fixed-to-variable baseline (Algorithm 1).
//!
//! `dat/col` store the nonzeros row-contiguously, `row_ptr[i]..row_ptr[i+1]`
//! brackets row `i`. The SpMV inner loop `y_i += dat[j] · x[col[j]]` makes
//! a data-dependent gather on `x` — the irregular access pattern the
//! paper's Figure 1(b) blames for bandwidth loss.

use super::DenseMatrix;

/// CSR matrix (f32 values).
#[derive(Debug, Clone)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
    pub dat: Vec<f32>,
}

impl CsrMatrix {
    /// Compress a dense matrix (zeros are pruned entries).
    pub fn from_dense(a: &DenseMatrix) -> Self {
        let mut row_ptr = Vec::with_capacity(a.rows + 1);
        let mut col_idx = Vec::new();
        let mut dat = Vec::new();
        row_ptr.push(0);
        for r in 0..a.rows {
            for (c, &v) in a.row(r).iter().enumerate() {
                if v != 0.0 {
                    col_idx.push(c as u32);
                    dat.push(v);
                }
            }
            row_ptr.push(dat.len());
        }
        CsrMatrix { rows: a.rows, cols: a.cols, row_ptr, col_idx, dat }
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.dat.len()
    }

    /// Storage bits: values (32b) + column indices (32b) + row pointers.
    /// The fixed-to-variable representation the paper compares against.
    pub fn storage_bits(&self) -> usize {
        self.dat.len() * 32
            + self.col_idx.len() * 32
            + self.row_ptr.len() * 32
    }

    /// Algorithm 1: SpMV with irregular, data-dependent access.
    /// `x.len()` must equal `cols` (validated by serving callers).
    pub fn spmv(&self, x: &[f32]) -> Vec<f32> {
        debug_assert_eq!(self.cols, x.len());
        let mut y = vec![0.0f32; self.rows];
        for i in 0..self.rows {
            let mut acc = 0.0f32;
            for j in self.row_ptr[i]..self.row_ptr[i + 1] {
                acc += self.dat[j] * x[self.col_idx[j] as usize];
            }
            y[i] = acc;
        }
        y
    }

    /// SpMM against a dense `cols × k` matrix (Fig. S.10's workload).
    /// `b.rows` must equal `cols`.
    pub fn spmm(&self, b: &DenseMatrix) -> DenseMatrix {
        debug_assert_eq!(self.cols, b.rows);
        let k = b.cols;
        let mut y = DenseMatrix::zeros(self.rows, k);
        for i in 0..self.rows {
            let yrow = &mut y.data[i * k..(i + 1) * k];
            for j in self.row_ptr[i]..self.row_ptr[i + 1] {
                let v = self.dat[j];
                let brow = b.row(self.col_idx[j] as usize);
                for c in 0..k {
                    yrow[c] += v * brow[c];
                }
            }
        }
        y
    }

    /// Per-row nonzero counts — the variable record lengths that break
    /// fixed-burst memory access (Appendix A's `n_b` random variable).
    pub fn row_lengths(&self) -> Vec<usize> {
        self.row_ptr.windows(2).map(|w| w[1] - w[0]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::sparse::{gemm, gemv};

    #[test]
    fn from_dense_roundtrip_structure() {
        let a = DenseMatrix::from_vec(
            2,
            3,
            vec![1.0, 0.0, 2.0, 0.0, 0.0, 3.0],
        );
        let c = CsrMatrix::from_dense(&a);
        assert_eq!(c.nnz(), 3);
        assert_eq!(c.row_ptr, vec![0, 2, 3]);
        assert_eq!(c.col_idx, vec![0, 2, 2]);
        assert_eq!(c.dat, vec![1.0, 2.0, 3.0]);
        assert_eq!(c.row_lengths(), vec![2, 1]);
    }

    #[test]
    fn spmv_matches_dense() {
        let mut rng = Rng::new(4);
        let a = DenseMatrix::random_sparse(64, 96, 0.9, &mut rng);
        let c = CsrMatrix::from_dense(&a);
        let x: Vec<f32> = (0..96).map(|_| rng.next_f32()).collect();
        let yd = gemv(&a, &x);
        let yc = c.spmv(&x);
        for (p, q) in yd.iter().zip(&yc) {
            assert!((p - q).abs() < 1e-4);
        }
    }

    #[test]
    fn spmm_matches_dense_gemm() {
        let mut rng = Rng::new(5);
        let a = DenseMatrix::random_sparse(32, 48, 0.7, &mut rng);
        let b = DenseMatrix::random_sparse(48, 4, 0.0, &mut rng);
        let c = CsrMatrix::from_dense(&a);
        let y1 = gemm(&a, &b);
        let y2 = c.spmm(&b);
        for (p, q) in y1.data.iter().zip(&y2.data) {
            assert!((p - q).abs() < 1e-3);
        }
    }

    #[test]
    fn storage_shrinks_with_sparsity_but_has_overhead() {
        let mut rng = Rng::new(6);
        let dense_bits = 256 * 256 * 32;
        let a50 = DenseMatrix::random_sparse(256, 256, 0.5, &mut rng);
        let a95 = DenseMatrix::random_sparse(256, 256, 0.95, &mut rng);
        let s50 = CsrMatrix::from_dense(&a50).storage_bits();
        let s95 = CsrMatrix::from_dense(&a95).storage_bits();
        assert!(s95 < s50);
        // At 50% sparsity CSR is ~as large as dense (2× per nnz).
        assert!(s50 as f64 > 0.9 * dense_bits as f64);
    }
}

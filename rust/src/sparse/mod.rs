//! Sparse/dense matrix kernels: the CSR baseline (Algorithm 1), dense
//! GEMM/GEMV, and the paper's decode-then-multiply path (Algorithm 2).
//!
//! These back two artifacts:
//! * Appendix B / Figure S.10 — CSR SpMM vs dense GEMM timing (the paper's
//!   motivation: CSR can be *slower* than dense below a sparsity
//!   threshold, especially at small batch `k`);
//! * Algorithm 1 vs Algorithm 2 equivalence — decoding the fixed-to-fixed
//!   stream and multiplying with zero-skipping must produce the same `y`
//!   as CSR SpMV.

mod csr;
mod dense;
mod f2f_mv;

pub use csr::CsrMatrix;
pub use dense::{gemm, gemv, DenseMatrix};
pub use f2f_mv::{
    assemble_with, decode_gemv, decode_plane_with, DecodedLayer,
};
pub(crate) use f2f_mv::{assemble, decode_plane};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn csr_spmv_equals_dense_gemv() {
        let mut rng = Rng::new(1);
        let (m, n) = (37, 53);
        let dense = DenseMatrix::random_sparse(m, n, 0.8, &mut rng);
        let csr = CsrMatrix::from_dense(&dense);
        let x: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
        let y_dense = gemv(&dense, &x);
        let y_csr = csr.spmv(&x);
        for (a, b) in y_dense.iter().zip(&y_csr) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}

//! Algorithm 2: SpMV using fixed-to-fixed encoded weights.
//!
//! `W_i ← w_i^e × M⊕` over GF(2) (regular, fixed-size accesses), then
//! `y = W · x` with the mask zeroing pruned positions. Decoded planes are
//! corrected (lossless) and reassembled into the original number format;
//! pruned weights decode to arbitrary bits (the paper: "pruned weights
//! are filled by random values during weight decoding") and are nulled by
//! the mask before the multiply.
//!
//! Reassembly dispatches on [`KernelKind`]: the default word-parallel
//! path assembles 64 weights per iteration through the bit-matrix
//! transpose in [`crate::kernels`]; `F2F_KERNEL=scalar` forces the
//! per-bit reference loops kept here (also the baseline
//! `benches/store.rs` times the word kernels against). Both produce
//! bit-identical weights.

use crate::container::{CompressedLayer, Dtype};
use crate::decoder::SequentialDecoder;
use crate::gf2::BitVecF2;
use crate::kernels::{reassemble_f32_words, reassemble_i8_words, KernelKind};
#[cfg(test)]
use crate::weights::BitPlanes;

/// A layer reconstructed from its fixed-to-fixed streams.
#[derive(Debug, Clone)]
pub struct DecodedLayer {
    pub rows: usize,
    pub cols: usize,
    /// Dense row-major weights, zeros at pruned positions.
    pub weights: Vec<f32>,
}

/// Decode one bit-plane of a compressed layer: decode-stream →
/// correction → invert. The per-plane work item of the decode path,
/// shared with [`crate::store::DecodePool`]'s parallel workers.
pub(crate) fn decode_plane(
    layer: &CompressedLayer,
    dec: &SequentialDecoder,
    k: usize,
) -> BitVecF2 {
    decode_plane_with(layer, dec, k, KernelKind::active())
}

/// [`decode_plane`] with an explicit kernel choice (benches time the
/// scalar and word block writers against each other through this).
pub fn decode_plane_with(
    layer: &CompressedLayer,
    dec: &SequentialDecoder,
    k: usize,
    kind: KernelKind,
) -> BitVecF2 {
    let p = &layer.planes[k];
    let mut bits =
        dec.decode_stream_to_bits_with(&p.encoded, layer.n_weights(), kind);
    p.correction.apply(&mut bits);
    if p.inverted {
        bits.invert();
    }
    bits
}

/// Reassemble decoded bit-planes into the dense f32 layer (mask-gated,
/// dtype-dispatched). Shared with [`crate::store::DecodePool`].
/// Fallible: a plane count or length that disagrees with the layer's
/// dtype/shape (a malformed container) is an error, never a panic —
/// this is reached from the serving path.
pub(crate) fn assemble(
    layer: &CompressedLayer,
    planes: &[BitVecF2],
) -> Result<DecodedLayer, String> {
    assemble_with(layer, planes, KernelKind::active())
}

/// [`assemble`] with an explicit kernel choice.
pub fn assemble_with(
    layer: &CompressedLayer,
    planes: &[BitVecF2],
    kind: KernelKind,
) -> Result<DecodedLayer, String> {
    let n = layer.n_weights();
    let n_w = layer.dtype.bits();
    if planes.len() != n_w {
        return Err(format!(
            "layer {:?}: {} planes for dtype {:?} (want {n_w})",
            layer.name,
            planes.len(),
            layer.dtype
        ));
    }
    if layer.mask.len() != n {
        return Err(format!(
            "layer {:?}: mask has {} bits for {n} weights",
            layer.name,
            layer.mask.len()
        ));
    }
    for (k, p) in planes.iter().enumerate() {
        if p.len() != n {
            return Err(format!(
                "layer {:?}: plane {k} has {} bits for {n} weights",
                layer.name,
                p.len()
            ));
        }
    }
    let weights = match (layer.dtype, kind) {
        (Dtype::F32, KernelKind::Word) => {
            reassemble_f32_words(planes, &layer.mask, n)
        }
        (Dtype::I8, KernelKind::Word) => {
            reassemble_i8_words(planes, &layer.mask, n, layer.scale)
        }
        (Dtype::F32, KernelKind::Scalar) => {
            reassemble_f32(planes, &layer.mask, n)
        }
        (Dtype::I8, KernelKind::Scalar) => {
            reassemble_i8(planes, &layer.mask, n, layer.scale)
        }
    };
    Ok(DecodedLayer { rows: layer.rows, cols: layer.cols, weights })
}

impl DecodedLayer {
    /// Decode + correct + reassemble a compressed layer. Lossless: the
    /// unpruned weights are bit-exact.
    pub fn from_compressed(layer: &CompressedLayer) -> Self {
        Self::from_compressed_with(layer, KernelKind::active())
    }

    /// [`DecodedLayer::from_compressed`] with an explicit kernel choice.
    pub fn from_compressed_with(
        layer: &CompressedLayer,
        kind: KernelKind,
    ) -> Self {
        let dec = SequentialDecoder::random(layer.spec, layer.m_seed);
        let planes: Vec<BitVecF2> = (0..layer.planes.len())
            .map(|k| decode_plane_with(layer, &dec, k, kind))
            .collect();
        // lint: allow(no-unwrap) -- plane count/length vs dtype is validated at container parse; serving decodes go through the fallible `assemble` in the store pool instead
        assemble_with(layer, &planes, kind).expect("parse-validated layer")
    }

    /// Decoded dense size in bytes (what this layer costs in a
    /// [`crate::store::ModelStore`] cache).
    pub fn decoded_bytes(&self) -> usize {
        self.weights.len() * std::mem::size_of::<f32>()
    }

    /// `y = W · x` (Algorithm 2's multiply; pruned entries are already
    /// zero so no gather is needed — every access is unit-stride).
    pub fn gemv(&self, x: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.gemv_into(x, &mut out);
        out
    }

    /// [`DecodedLayer::gemv`] into a caller-owned buffer (cleared and
    /// refilled), so batch loops reuse allocations instead of
    /// reallocating every layer × item. Shapes are validated at the
    /// serving boundary (`validate_chain` / `forward_batch`); a
    /// mismatched `x` truncates the dot product rather than panicking.
    pub fn gemv_into(&self, x: &[f32], out: &mut Vec<f32>) {
        debug_assert_eq!(self.cols, x.len());
        out.clear();
        out.reserve(self.rows);
        for r in 0..self.rows {
            out.push(
                self.weights[r * self.cols..(r + 1) * self.cols]
                    .iter()
                    .zip(x)
                    .map(|(&w, &xv)| w * xv)
                    .sum(),
            );
        }
    }
}

/// One-call Algorithm 2: decode a compressed layer and multiply.
pub fn decode_gemv(layer: &CompressedLayer, x: &[f32]) -> Vec<f32> {
    DecodedLayer::from_compressed(layer).gemv(x)
}

/// Per-bit f32 reassembly — the scalar reference kernel.
fn reassemble_f32(planes: &[BitVecF2], mask: &BitVecF2, n: usize) -> Vec<f32> {
    debug_assert_eq!(planes.len(), 32);
    (0..n)
        .map(|i| {
            if !mask.get(i) {
                return 0.0;
            }
            let mut bits = 0u32;
            for (k, p) in planes.iter().enumerate() {
                if p.get(i) {
                    bits |= 1 << (31 - k);
                }
            }
            f32::from_bits(bits)
        })
        .collect()
}

/// Per-bit i8 reassembly — the scalar reference kernel.
fn reassemble_i8(
    planes: &[BitVecF2],
    mask: &BitVecF2,
    n: usize,
    scale: f32,
) -> Vec<f32> {
    debug_assert_eq!(planes.len(), 8);
    (0..n)
        .map(|i| {
            if !mask.get(i) {
                return 0.0;
            }
            let mut bits = 0u8;
            for (k, p) in planes.iter().enumerate() {
                if p.get(i) {
                    bits |= 1 << (7 - k);
                }
            }
            (bits as i8) as f32 * scale
        })
        .collect()
}

// Integration tests with real compressed layers live in
// `rust/tests/pipeline_roundtrip.rs` (they need the pipeline to build
// containers) and `rust/tests/fused_parity.rs` (kernel/mode parity);
// unit tests here exercise the reassembly helpers.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn reassemble_f32_respects_mask() {
        let w = vec![1.5f32, -2.25, 0.75, 3.0];
        let planes_src = BitPlanes::from_f32(&w);
        let planes: Vec<BitVecF2> =
            (0..32).map(|k| planes_src.plane(k).clone()).collect();
        let mask = BitVecF2::from_bools(&[true, false, true, false]);
        let out = reassemble_f32(&planes, &mask, 4);
        assert_eq!(out, vec![1.5, 0.0, 0.75, 0.0]);
        // The word kernel agrees bit for bit.
        assert_eq!(reassemble_f32_words(&planes, &mask, 4), out);
    }

    #[test]
    fn reassemble_i8_scales() {
        let w = vec![10i8, -20, 127, -128];
        let planes_src = BitPlanes::from_i8(&w);
        let planes: Vec<BitVecF2> =
            (0..8).map(|k| planes_src.plane(k).clone()).collect();
        let mask = BitVecF2::from_bools(&[true, true, true, true]);
        let out = reassemble_i8(&planes, &mask, 4, 0.5);
        assert_eq!(out, vec![5.0, -10.0, 63.5, -64.0]);
        assert_eq!(reassemble_i8_words(&planes, &mask, 4, 0.5), out);
    }

    #[test]
    fn scalar_and_word_kernels_agree_across_tail_widths() {
        let mut rng = Rng::new(8);
        for n in [1usize, 63, 64, 65, 129, 200] {
            let w: Vec<f32> =
                (0..n).map(|_| rng.normal() as f32).collect();
            let planes_src = BitPlanes::from_f32(&w);
            let planes: Vec<BitVecF2> =
                (0..32).map(|k| planes_src.plane(k).clone()).collect();
            let mask = BitVecF2::from_iter_bits(
                (0..n).map(|_| rng.bernoulli(0.6)),
            );
            let scalar = reassemble_f32(&planes, &mask, n);
            let word = reassemble_f32_words(&planes, &mask, n);
            assert_eq!(scalar.len(), word.len());
            for (s, wd) in scalar.iter().zip(&word) {
                assert_eq!(s.to_bits(), wd.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn assemble_rejects_wrong_plane_count() {
        let mut rng = Rng::new(9);
        let dense =
            crate::sparse::DenseMatrix::random_sparse(4, 8, 0.5, &mut rng);
        let cfg = crate::pipeline::CompressionConfig {
            sparsity: 0.5,
            n_s: 0,
            ..Default::default()
        };
        let (cl, _) = crate::pipeline::Compressor::new(cfg)
            .compress_f32("t", 4, 8, &dense.data);
        let dec = SequentialDecoder::random(cl.spec, cl.m_seed);
        let planes: Vec<BitVecF2> = (0..cl.planes.len())
            .map(|k| decode_plane(&cl, &dec, k))
            .collect();
        assert!(assemble(&cl, &planes).is_ok());
        assert!(assemble(&cl, &planes[..31]).is_err());
        let mut bad = planes;
        bad[0] = BitVecF2::zeros(3);
        assert!(assemble(&cl, &bad).is_err());
    }

    #[test]
    fn gemv_on_decoded_layer() {
        let mut rng = Rng::new(1);
        let weights: Vec<f32> =
            (0..12).map(|_| rng.normal() as f32).collect();
        let layer =
            DecodedLayer { rows: 3, cols: 4, weights: weights.clone() };
        let x = vec![1.0, 2.0, -1.0, 0.5];
        let y = layer.gemv(&x);
        for r in 0..3 {
            let expect: f32 = (0..4)
                .map(|c| weights[r * 4 + c] * x[c])
                .sum();
            assert!((y[r] - expect).abs() < 1e-5);
        }
        // gemv_into reuses its buffer and matches bit for bit.
        let mut buf = vec![0.0f32; 17];
        layer.gemv_into(&x, &mut buf);
        assert_eq!(buf.len(), 3);
        for (a, b) in y.iter().zip(&buf) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

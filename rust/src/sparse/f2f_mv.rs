//! Algorithm 2: SpMV using fixed-to-fixed encoded weights.
//!
//! `W_i ← w_i^e × M⊕` over GF(2) (regular, fixed-size accesses), then
//! `y = W · x` with the mask zeroing pruned positions. Decoded planes are
//! corrected (lossless) and reassembled into the original number format;
//! pruned weights decode to arbitrary bits (the paper: "pruned weights
//! are filled by random values during weight decoding") and are nulled by
//! the mask before the multiply.

use crate::container::{CompressedLayer, Dtype};
use crate::decoder::SequentialDecoder;
use crate::gf2::BitVecF2;
#[cfg(test)]
use crate::weights::BitPlanes;

/// A layer reconstructed from its fixed-to-fixed streams.
#[derive(Debug, Clone)]
pub struct DecodedLayer {
    pub rows: usize,
    pub cols: usize,
    /// Dense row-major weights, zeros at pruned positions.
    pub weights: Vec<f32>,
}

/// Decode one bit-plane of a compressed layer: decode-stream →
/// correction → invert. The per-plane work item of the decode path,
/// shared with [`crate::store::DecodePool`]'s parallel workers.
pub(crate) fn decode_plane(
    layer: &CompressedLayer,
    dec: &SequentialDecoder,
    k: usize,
) -> BitVecF2 {
    let p = &layer.planes[k];
    let mut bits = dec.decode_stream_to_bits(&p.encoded, layer.n_weights());
    p.correction.apply(&mut bits);
    if p.inverted {
        bits.invert();
    }
    bits
}

/// Reassemble decoded bit-planes into the dense f32 layer (mask-gated,
/// dtype-dispatched). Shared with [`crate::store::DecodePool`].
pub(crate) fn assemble(
    layer: &CompressedLayer,
    planes: &[BitVecF2],
) -> DecodedLayer {
    let n = layer.n_weights();
    let weights = match layer.dtype {
        Dtype::F32 => reassemble_f32(planes, &layer.mask, n),
        Dtype::I8 => reassemble_i8(planes, &layer.mask, n, layer.scale),
    };
    DecodedLayer { rows: layer.rows, cols: layer.cols, weights }
}

impl DecodedLayer {
    /// Decode + correct + reassemble a compressed layer. Lossless: the
    /// unpruned weights are bit-exact.
    pub fn from_compressed(layer: &CompressedLayer) -> Self {
        let dec = SequentialDecoder::random(layer.spec, layer.m_seed);
        let planes: Vec<BitVecF2> = (0..layer.planes.len())
            .map(|k| decode_plane(layer, &dec, k))
            .collect();
        assemble(layer, &planes)
    }

    /// Decoded dense size in bytes (what this layer costs in a
    /// [`crate::store::ModelStore`] cache).
    pub fn decoded_bytes(&self) -> usize {
        self.weights.len() * std::mem::size_of::<f32>()
    }

    /// `y = W · x` (Algorithm 2's multiply; pruned entries are already
    /// zero so no gather is needed — every access is unit-stride).
    pub fn gemv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|r| {
                self.weights[r * self.cols..(r + 1) * self.cols]
                    .iter()
                    .zip(x)
                    .map(|(&w, &xv)| w * xv)
                    .sum()
            })
            .collect()
    }
}

/// One-call Algorithm 2: decode a compressed layer and multiply.
pub fn decode_gemv(layer: &CompressedLayer, x: &[f32]) -> Vec<f32> {
    DecodedLayer::from_compressed(layer).gemv(x)
}

fn reassemble_f32(planes: &[BitVecF2], mask: &BitVecF2, n: usize) -> Vec<f32> {
    assert_eq!(planes.len(), 32);
    (0..n)
        .map(|i| {
            if !mask.get(i) {
                return 0.0;
            }
            let mut bits = 0u32;
            for (k, p) in planes.iter().enumerate() {
                if p.get(i) {
                    bits |= 1 << (31 - k);
                }
            }
            f32::from_bits(bits)
        })
        .collect()
}

fn reassemble_i8(
    planes: &[BitVecF2],
    mask: &BitVecF2,
    n: usize,
    scale: f32,
) -> Vec<f32> {
    assert_eq!(planes.len(), 8);
    (0..n)
        .map(|i| {
            if !mask.get(i) {
                return 0.0;
            }
            let mut bits = 0u8;
            for (k, p) in planes.iter().enumerate() {
                if p.get(i) {
                    bits |= 1 << (7 - k);
                }
            }
            (bits as i8) as f32 * scale
        })
        .collect()
}

// Integration tests with real compressed layers live in
// `rust/tests/pipeline_roundtrip.rs` (they need the pipeline to build
// containers); unit tests here exercise the reassembly helpers.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn reassemble_f32_respects_mask() {
        let w = vec![1.5f32, -2.25, 0.75, 3.0];
        let planes_src = BitPlanes::from_f32(&w);
        let planes: Vec<BitVecF2> =
            (0..32).map(|k| planes_src.plane(k).clone()).collect();
        let mask = BitVecF2::from_bools(&[true, false, true, false]);
        let out = reassemble_f32(&planes, &mask, 4);
        assert_eq!(out, vec![1.5, 0.0, 0.75, 0.0]);
    }

    #[test]
    fn reassemble_i8_scales() {
        let w = vec![10i8, -20, 127, -128];
        let planes_src = BitPlanes::from_i8(&w);
        let planes: Vec<BitVecF2> =
            (0..8).map(|k| planes_src.plane(k).clone()).collect();
        let mask = BitVecF2::from_bools(&[true, true, true, true]);
        let out = reassemble_i8(&planes, &mask, 4, 0.5);
        assert_eq!(out, vec![5.0, -10.0, 63.5, -64.0]);
    }

    #[test]
    fn gemv_on_decoded_layer() {
        let mut rng = Rng::new(1);
        let weights: Vec<f32> =
            (0..12).map(|_| rng.normal() as f32).collect();
        let layer =
            DecodedLayer { rows: 3, cols: 4, weights: weights.clone() };
        let x = vec![1.0, 2.0, -1.0, 0.5];
        let y = layer.gemv(&x);
        for r in 0..3 {
            let expect: f32 = (0..4)
                .map(|c| weights[r * 4 + c] * x[c])
                .sum();
            assert!((y[r] - expect).abs() < 1e-5);
        }
    }
}

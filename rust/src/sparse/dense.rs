//! Row-major dense matrix + GEMV/GEMM baselines.
//!
//! Deliberately straightforward loops (unit-stride inner loop, no
//! blocking): the Figure S.10 comparison is about *relative* cost of
//! irregular CSR access vs regular dense access, which survives any
//! uniform constant factor.

use crate::rng::Rng;

/// Row-major `rows × cols` f32 matrix.
#[derive(Debug, Clone)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl DenseMatrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DenseMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// From a row-major buffer of exactly `rows · cols` values.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        debug_assert_eq!(data.len(), rows * cols);
        DenseMatrix { rows, cols, data }
    }

    /// Gaussian entries with a `sparsity` fraction set to exactly zero.
    pub fn random_sparse(
        rows: usize,
        cols: usize,
        sparsity: f64,
        rng: &mut Rng,
    ) -> Self {
        let data = (0..rows * cols)
            .map(|_| {
                if rng.bernoulli(sparsity) {
                    0.0
                } else {
                    rng.normal() as f32
                }
            })
            .collect();
        DenseMatrix { rows, cols, data }
    }

    /// Element access.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    /// Row slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Number of exact zeros (the pruned count).
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0.0).count()
    }
}

/// Dense mat-vec `y = A·x` (`x.len()` must equal `a.cols`).
pub fn gemv(a: &DenseMatrix, x: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.cols, x.len());
    (0..a.rows)
        .map(|r| {
            a.row(r)
                .iter()
                .zip(x)
                .map(|(&w, &xv)| w * xv)
                .sum::<f32>()
        })
        .collect()
}

/// Dense mat-mat `Y = A·B` where `B` is `cols × k` (column-major layout
/// `b[j*k + col]`? no — row-major `cols × k`). Output row-major
/// `rows × k`. This is the `(2048×2048)·(2048×k)` shape of Fig. S.10.
pub fn gemm(a: &DenseMatrix, b: &DenseMatrix) -> DenseMatrix {
    debug_assert_eq!(a.cols, b.rows);
    let mut y = DenseMatrix::zeros(a.rows, b.cols);
    for r in 0..a.rows {
        let arow = a.row(r);
        let yrow = &mut y.data[r * b.cols..(r + 1) * b.cols];
        // Deliberately no zero-skipping: the dense baseline pays for
        // every element, as a dense GEMM kernel would.
        for (j, &av) in arow.iter().enumerate() {
            let brow = b.row(j);
            for c in 0..b.cols {
                yrow[c] += av * brow[c];
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemv_known_values() {
        let a = DenseMatrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let y = gemv(&a, &[1., 0., -1.]);
        assert_eq!(y, vec![-2., -2.]);
    }

    #[test]
    fn gemm_matches_gemv_per_column() {
        let mut rng = Rng::new(2);
        let a = DenseMatrix::random_sparse(8, 12, 0.5, &mut rng);
        let b = DenseMatrix::random_sparse(12, 3, 0.0, &mut rng);
        let y = gemm(&a, &b);
        for c in 0..3 {
            let col: Vec<f32> = (0..12).map(|r| b.get(r, c)).collect();
            let yc = gemv(&a, &col);
            for r in 0..8 {
                assert!((y.get(r, c) - yc[r]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn random_sparse_hits_target() {
        let mut rng = Rng::new(3);
        let a = DenseMatrix::random_sparse(100, 100, 0.9, &mut rng);
        let density = a.nnz() as f64 / 10_000.0;
        assert!((density - 0.1).abs() < 0.02);
    }
}

//! Fundamental compression limits — Appendix D.
//!
//! A block of `n_b` bits with `n_u` unpruned bits (positions vary, pruned
//! bits are don't-cares) is mapped to a *symbol*: a full `n_b`-bit
//! assignment consistent with the unpruned bits. A symbol set is valid
//! when **every** (position-set, value) combination has at least one
//! consistent symbol — i.e. the projection of the set onto any `n_u`
//! coordinates covers all `2^{n_u}` patterns (a surjective / covering
//! array). The entropy of the induced symbol distribution (with the
//! assignment chosen to skew probabilities) lower-bounds the bits a
//! fixed-to-variable scheme needs; a fixed-to-fixed scheme needs
//! `⌈log2 |symbols|⌉` bits.
//!
//! The paper's worked examples (`n_b = 4`): `n_u = 1` → 2 symbols, H = 1;
//! `n_u = 2` → 5 symbols, H ≈ 2.28; `n_u = 3` → 8 symbols, H = 3. We
//! reproduce these by exhaustive search.

/// Shannon entropy (bits) of a discrete distribution.
pub fn shannon_entropy(probs: &[f64]) -> f64 {
    probs
        .iter()
        .filter(|&&p| p > 0.0)
        .map(|&p| -p * p.log2())
        .sum()
}

/// Result of the minimal-symbol-set search.
#[derive(Debug, Clone)]
pub struct SymbolSet {
    /// Block width `n_b`.
    pub n_b: usize,
    /// Unpruned bits per block `n_u`.
    pub n_u: usize,
    /// A minimal valid symbol set (bit-packed `n_b`-bit values).
    pub symbols: Vec<u32>,
    /// Minimal achievable entropy over assignments for this set (bits).
    pub entropy: f64,
    /// Bits needed by a fixed-to-fixed scheme: `⌈log2 |symbols|⌉`.
    pub f2f_bits: usize,
}

/// Does `symbols` cover every (`n_u` positions, values) combination?
pub fn covers(symbols: &[u32], n_b: usize, n_u: usize) -> bool {
    for positions in combinations(n_b, n_u) {
        // Collect projections of all symbols onto these positions.
        let mut seen = vec![false; 1 << n_u];
        for &s in symbols {
            let mut proj = 0u32;
            for (k, &p) in positions.iter().enumerate() {
                proj |= ((s >> p) & 1) << k;
            }
            seen[proj as usize] = true;
        }
        if !seen.iter().all(|&x| x) {
            return false;
        }
    }
    true
}

/// All `n_u`-subsets of `0..n_b`.
fn combinations(n_b: usize, n_u: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    let mut cur = Vec::with_capacity(n_u);
    fn rec(
        start: usize,
        n_b: usize,
        n_u: usize,
        cur: &mut Vec<usize>,
        out: &mut Vec<Vec<usize>>,
    ) {
        if cur.len() == n_u {
            out.push(cur.clone());
            return;
        }
        for i in start..n_b {
            cur.push(i);
            rec(i + 1, n_b, n_u, cur, out);
            cur.pop();
        }
    }
    rec(0, n_b, n_u, &mut cur, &mut out);
    out
}

/// Minimal entropy achievable by assigning each masked block to a
/// consistent symbol, skewing the distribution as much as possible
/// (assign greedily by symbol priority; try all priority orders for
/// small sets).
fn min_entropy_for_set(symbols: &[u32], n_b: usize, n_u: usize) -> f64 {
    // Enumerate all masked blocks: (position set, values).
    let blocks: Vec<(Vec<usize>, u32)> = combinations(n_b, n_u)
        .into_iter()
        .flat_map(|pos| {
            (0..(1u32 << n_u)).map(move |v| (pos.clone(), v))
        })
        .collect();
    let consistent = |s: u32, pos: &[usize], v: u32| -> bool {
        pos.iter()
            .enumerate()
            .all(|(k, &p)| ((s >> p) & 1) == ((v >> k) & 1))
    };

    let k = symbols.len();
    let mut order: Vec<usize> = (0..k).collect();
    let mut best = f64::INFINITY;
    if k > 6 {
        // For larger sets the assignment is (nearly) forced — e.g. a
        // covering 8-set for (n_b=4, n_u=3) is bijective, so greedy with
        // any order yields the same distribution. Use identity order.
        let mut counts = vec![0usize; k];
        for (pos, v) in &blocks {
            for i in 0..k {
                if consistent(symbols[i], pos, *v) {
                    counts[i] += 1;
                    break;
                }
            }
        }
        let total: usize = counts.iter().sum();
        let probs: Vec<f64> =
            counts.iter().map(|&c| c as f64 / total as f64).collect();
        return shannon_entropy(&probs);
    }
    permute(&mut order, 0, &mut |perm: &[usize]| {
        let mut counts = vec![0usize; k];
        for (pos, v) in &blocks {
            for &i in perm {
                if consistent(symbols[i], pos, *v) {
                    counts[i] += 1;
                    break;
                }
            }
        }
        let total: usize = counts.iter().sum();
        let probs: Vec<f64> = counts
            .iter()
            .map(|&c| c as f64 / total as f64)
            .collect();
        let h = shannon_entropy(&probs);
        if h < best {
            best = h;
        }
    });
    best
}

fn permute(xs: &mut Vec<usize>, i: usize, f: &mut impl FnMut(&[usize])) {
    if i == xs.len() {
        f(xs);
        return;
    }
    for j in i..xs.len() {
        xs.swap(i, j);
        permute(xs, i + 1, f);
        xs.swap(i, j);
    }
}

/// Exhaustive search for a minimal covering symbol set (small `n_b`
/// only; the paper's Appendix D uses `n_b = 4`). Returns the first
/// minimal set found together with its minimal entropy.
pub fn min_symbol_set(n_b: usize, n_u: usize) -> SymbolSet {
    assert!(n_b <= 5, "exhaustive search only for tiny n_b");
    assert!(n_u >= 1 && n_u <= n_b);
    let universe: Vec<u32> = (0..(1u32 << n_b)).collect();
    for k in 1..=universe.len() {
        let mut found: Option<Vec<u32>> = None;
        let mut best_h = f64::INFINITY;
        subsets_of_size(&universe, k, &mut |set: &[u32]| {
            if covers(set, n_b, n_u) {
                let h = min_entropy_for_set(set, n_b, n_u);
                if h < best_h {
                    best_h = h;
                    found = Some(set.to_vec());
                }
            }
        });
        if let Some(symbols) = found {
            return SymbolSet {
                n_b,
                n_u,
                f2f_bits: (usize::BITS
                    - (symbols.len() - 1).leading_zeros())
                    as usize,
                symbols,
                entropy: best_h,
            };
        }
    }
    unreachable!("full universe always covers");
}

fn subsets_of_size(
    universe: &[u32],
    k: usize,
    f: &mut impl FnMut(&[u32]),
) {
    let mut cur = Vec::with_capacity(k);
    fn rec(
        universe: &[u32],
        start: usize,
        k: usize,
        cur: &mut Vec<u32>,
        f: &mut impl FnMut(&[u32]),
    ) {
        if cur.len() == k {
            f(cur);
            return;
        }
        // Prune: not enough elements left.
        if universe.len() - start < k - cur.len() {
            return;
        }
        for i in start..universe.len() {
            cur.push(universe[i]);
            rec(universe, i + 1, k, cur, f);
            cur.pop();
        }
    }
    rec(universe, 0, k, &mut cur, f);
}

/// Maximum compression ratio by entropy: `n_b / H` (the bound a
/// fixed-to-variable scheme can approach; the paper's §2 rate target
/// `n_b / n_u` is the `H → n_u` limit).
pub fn max_compression_ratio(n_b: usize, entropy: f64) -> f64 {
    n_b as f64 / entropy
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_of_uniform() {
        assert!((shannon_entropy(&[0.5, 0.5]) - 1.0).abs() < 1e-12);
        assert!((shannon_entropy(&[0.25; 4]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn appendix_d_nu1_two_symbols_h1() {
        let r = min_symbol_set(4, 1);
        assert_eq!(r.symbols.len(), 2);
        assert!((r.entropy - 1.0).abs() < 1e-9, "H = {}", r.entropy);
        assert_eq!(r.f2f_bits, 1);
        // The canonical pair {0000, 1111} must be a valid cover.
        assert!(covers(&[0b0000, 0b1111], 4, 1));
        // Complementary pairs in general:
        assert!(covers(&[0b0010, 0b1101], 4, 1));
        assert!(covers(&[0b1010, 0b0101], 4, 1));
    }

    #[test]
    fn appendix_d_nu2_five_symbols() {
        let r = min_symbol_set(4, 2);
        assert_eq!(r.symbols.len(), 5, "paper: minimum 5 symbols");
        assert_eq!(r.f2f_bits, 3, "fixed-to-fixed needs 3 bits");
        // Paper's example distribution 6/24,6/24,5/24,4/24,3/24 → H≈2.28;
        // our searched set must do at least as well.
        assert!(
            r.entropy <= 2.2855 + 1e-6,
            "H = {} should be ≤ 2.2855",
            r.entropy
        );
        assert!(r.entropy > 2.0);
    }

    #[test]
    fn appendix_d_paper_example_set_validates() {
        // P(0000), P(1110), P(0101), P(1001), P(0011) from Appendix D.
        let set = [0b0000u32, 0b0111, 0b1010, 0b1001, 0b1100];
        // (bit k of our packing = position k+1 in the paper's left-to-
        // right string notation; the set above is the paper's example
        // transcribed LSB-first.)
        assert!(covers(&set, 4, 2));
        let h =
            shannon_entropy(&[6.0 / 24.0, 6.0 / 24.0, 5.0 / 24.0, 4.0 / 24.0, 3.0 / 24.0]);
        assert!((h - 2.28).abs() < 0.01, "paper quotes H ≈ 2.28, got {h}");
    }

    #[test]
    fn appendix_d_nu3_eight_symbols() {
        let r = min_symbol_set(4, 3);
        assert_eq!(r.symbols.len(), 8, "paper: minimum 8 symbols");
        assert_eq!(r.f2f_bits, 3, "compressible into 3 bits");
        // H within [3, slightly above 3] — paper: "H can be equal to or
        // slightly higher than n_u".
        assert!(r.entropy >= 3.0 - 1e-9 && r.entropy < 3.3, "H={}", r.entropy);
    }

    #[test]
    fn covering_fails_for_too_small_sets() {
        assert!(!covers(&[0b0000], 4, 1));
        assert!(!covers(&[0b0000, 0b1110], 4, 1)); // position 0 never 1... bit3
        assert!(!covers(&[0b0000, 0b1111, 0b0101, 0b1010], 4, 2));
    }

    #[test]
    fn max_compression_ratio_examples() {
        // n_u = 1: ratio = 4 / 1 = 4×.
        assert!((max_compression_ratio(4, 1.0) - 4.0).abs() < 1e-12);
    }
}

//! Bit-exact correction stream codec (Figure S.11).

use super::log2_ceil;
use crate::gf2::BitVecF2;

/// Default correction vector length — the paper's `p = 512`
/// (`N_c = log2 512 + 1 = 10`).
pub const DEFAULT_P: usize = 512;

/// An encoded correction stream for one plane.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CorrectionStream {
    /// Flag bits, one per `p`-vector.
    flags: BitVecF2,
    /// Error-location payload: for each flagged vector, a run of
    /// `(log2 p)`-bit positions each followed by a continuation bit.
    payload: BitVecF2,
    /// Vector length `p`.
    p: usize,
    /// Plane length in bits.
    n_bits: usize,
    /// Number of recorded errors.
    n_errors: usize,
}

impl CorrectionStream {
    /// Build a stream from sorted, deduplicated flat error positions.
    pub fn build(mismatches: &[usize], n_bits: usize, p: usize) -> Self {
        assert!(p.is_power_of_two(), "p must be a power of two");
        debug_assert!(mismatches.windows(2).all(|w| w[0] < w[1]));
        let k = n_bits.div_ceil(p);
        let pos_bits = log2_ceil(p);
        let mut flags = BitVecF2::zeros(k);
        // Worst case payload size; trimmed below.
        let mut payload_bits: Vec<bool> = Vec::new();
        let mut i = 0usize;
        for v in 0..k {
            let lo = v * p;
            let hi = lo + p;
            let start = i;
            while i < mismatches.len() && mismatches[i] < hi {
                assert!(mismatches[i] >= lo);
                i += 1;
            }
            if i > start {
                flags.set(v, true);
                for (j, &pos) in mismatches[start..i].iter().enumerate() {
                    let rel = pos - lo;
                    for b in (0..pos_bits).rev() {
                        payload_bits.push((rel >> b) & 1 == 1);
                    }
                    // Continuation bit: 1 = another error follows.
                    payload_bits.push(j + 1 < i - start);
                }
            }
        }
        CorrectionStream {
            flags,
            payload: BitVecF2::from_bools(&payload_bits),
            p,
            n_bits,
            n_errors: mismatches.len(),
        }
    }

    /// Apply corrections: flip the recorded positions in `plane`.
    pub fn apply(&self, plane: &mut BitVecF2) {
        assert_eq!(plane.len(), self.n_bits);
        for pos in self.positions() {
            plane.flip(pos);
        }
    }

    /// Decode the flat error positions back out of the stream.
    pub fn positions(&self) -> Vec<usize> {
        let pos_bits = log2_ceil(self.p);
        let mut out = Vec::with_capacity(self.n_errors);
        let mut cursor = 0usize;
        for v in 0..self.flags.len() {
            if !self.flags.get(v) {
                continue;
            }
            loop {
                let mut rel = 0usize;
                for _ in 0..pos_bits {
                    rel = (rel << 1) | (self.payload.get(cursor) as usize);
                    cursor += 1;
                }
                out.push(v * self.p + rel);
                let more = self.payload.get(cursor);
                cursor += 1;
                if !more {
                    break;
                }
            }
        }
        out
    }

    /// Total stream size in bits: flags + payload (the last two terms of
    /// Eq. 7).
    pub fn size_bits(&self) -> usize {
        self.flags.len() + self.payload.len()
    }

    /// Number of corrected bits.
    pub fn n_errors(&self) -> usize {
        self.n_errors
    }

    /// Vector length `p`.
    pub fn p(&self) -> usize {
        self.p
    }

    /// Serialize to words for the container format.
    pub fn to_words(&self) -> (Vec<u64>, usize, Vec<u64>, usize) {
        (
            self.flags.words().to_vec(),
            self.flags.len(),
            self.payload.words().to_vec(),
            self.payload.len(),
        )
    }

    /// Rebuild from serialized parts.
    pub fn from_words(
        flags: (Vec<u64>, usize),
        payload: (Vec<u64>, usize),
        p: usize,
        n_bits: usize,
        n_errors: usize,
    ) -> Self {
        CorrectionStream {
            flags: BitVecF2::from_words(flags.0, flags.1),
            payload: BitVecF2::from_words(payload.0, payload.1),
            p,
            n_bits,
            n_errors,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn roundtrip_positions() {
        let mism = vec![0, 5, 511, 512, 1000, 4095];
        let cs = CorrectionStream::build(&mism, 4096, 512);
        assert_eq!(cs.positions(), mism);
        assert_eq!(cs.n_errors(), 6);
    }

    #[test]
    fn empty_stream_is_flags_only() {
        let cs = CorrectionStream::build(&[], 4096, 512);
        assert_eq!(cs.positions(), Vec::<usize>::new());
        assert_eq!(cs.size_bits(), 8); // ⌈4096/512⌉ flag bits, no payload
    }

    #[test]
    fn size_matches_eq7_terms() {
        // 3 errors in distinct vectors, p = 512 → each costs 10 bits.
        let mism = vec![10, 600, 1500];
        let cs = CorrectionStream::build(&mism, 4096, 512);
        assert_eq!(cs.size_bits(), 8 + 3 * 10);
    }

    #[test]
    fn multiple_errors_same_vector_share_flag() {
        let mism = vec![1, 2, 3];
        let cs = CorrectionStream::build(&mism, 1024, 512);
        // 2 flags + 3×10 payload bits.
        assert_eq!(cs.size_bits(), 2 + 30);
        assert_eq!(cs.positions(), mism);
    }

    #[test]
    fn apply_fixes_a_corrupted_plane() {
        let mut rng = Rng::new(1);
        let original = BitVecF2::random(8192, 0.5, &mut rng);
        let mut corrupted = original.clone();
        let mut mism: Vec<usize> = (0..40).map(|_| rng.below(8192)).collect();
        mism.sort_unstable();
        mism.dedup();
        for &m in &mism {
            corrupted.flip(m);
        }
        let cs = CorrectionStream::build(&mism, 8192, 512);
        cs.apply(&mut corrupted);
        assert_eq!(corrupted, original);
    }

    #[test]
    fn serialization_roundtrip() {
        let mism = vec![3, 700, 701, 2047];
        let cs = CorrectionStream::build(&mism, 2048, 256);
        let (fw, fl, pw, pl) = cs.to_words();
        let back = CorrectionStream::from_words(
            (fw, fl),
            (pw, pl),
            256,
            2048,
            4,
        );
        assert_eq!(back, cs);
        assert_eq!(back.positions(), mism);
    }

    #[test]
    fn random_roundtrip_stress() {
        let mut rng = Rng::new(9);
        for _ in 0..20 {
            let n_bits = 512 + rng.below(20_000);
            let n_err = rng.below(200);
            let mut mism: Vec<usize> =
                (0..n_err).map(|_| rng.below(n_bits)).collect();
            mism.sort_unstable();
            mism.dedup();
            let cs = CorrectionStream::build(&mism, n_bits, 512);
            assert_eq!(cs.positions(), mism);
        }
    }
}

//! Lossless correction stream — Appendix F.
//!
//! The random-number-generator decoder cannot match every unpruned bit
//! (`E < 100%`); a separate correction stream records where to flip the
//! decoded output so the overall scheme is lossless. The decoded plane is
//! re-sliced into `k = ⌈mn/p⌉` vectors of `p` bits; the stream stores
//!
//! 1. one **flag bit** per `p`-vector (1 ⟺ that vector has ≥ 1 error);
//! 2. for each error, `log2 p` bits of in-vector position plus **one
//!    continuation bit** ('1' = another error follows in the same
//!    vector).
//!
//! Total compressed size (Eq. 7):
//! `N_in·⌈mn/N_out⌉ + ⌈mn/p⌉ + (log2 p + 1)·#errors`, and with
//! `N_c = log2 p + 1` the paper's memory-saving closed form (Eq. 2) is
//! `1 − (1−S)(1 + (1−E)·N_c)`.

mod format;

pub use format::{CorrectionStream, DEFAULT_P};

/// Eq. 2: memory saving (fraction, not %) for pruning rate `s`, encoding
/// efficiency `e` (0..=1) and `n_c` correction bits per unmatched bit.
/// Approaches `s` as `e → 1`.
pub fn memory_save_eq2(s: f64, e: f64, n_c: f64) -> f64 {
    1.0 - (1.0 - s) * (1.0 + (1.0 - e) * n_c)
}

/// Eq. 7: exact compressed size in bits for an `mn`-bit plane.
pub fn compressed_bits_eq7(
    mn: usize,
    n_in: usize,
    n_out: usize,
    p: usize,
    unmatched: usize,
) -> usize {
    let payload = n_in * mn.div_ceil(n_out);
    let flags = mn.div_ceil(p);
    let corrections = (log2_ceil(p) + 1) * unmatched;
    payload + flags + corrections
}

/// ⌈log2 p⌉ (p ≥ 1).
pub(crate) fn log2_ceil(p: usize) -> usize {
    assert!(p >= 1);
    (usize::BITS - (p - 1).leading_zeros()) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log2_ceil_values() {
        assert_eq!(log2_ceil(1), 0);
        assert_eq!(log2_ceil(2), 1);
        assert_eq!(log2_ceil(512), 9);
        assert_eq!(log2_ceil(513), 10);
    }

    #[test]
    fn eq2_limits() {
        // E = 1 → memory save = S exactly.
        assert!((memory_save_eq2(0.9, 1.0, 10.0) - 0.9).abs() < 1e-12);
        // E = 0, N_c = 10 → save = 1 − (1−S)·11: can go negative (worse
        // than dense) as the paper notes for poor generators.
        let v = memory_save_eq2(0.5, 0.0, 10.0);
        assert!((v - (1.0 - 0.5 * 11.0)).abs() < 1e-12);
    }

    #[test]
    fn eq7_accounting_with_paper_p512() {
        // p = 512 → log2 p + 1 = 10 = the paper's "N_c is around 10".
        let mn = 1_000_000;
        let bits = compressed_bits_eq7(mn, 8, 80, 512, 100);
        assert_eq!(bits, 8 * 12_500 + 1954 + 10 * 100);
    }

    #[test]
    fn eq7_matches_eq2_asymptotically() {
        // For large mn, Eq. 7 / mn ≈ (1−S)(1 + (1−E)·N_c) + flag overhead.
        let mn = 10_000_000usize;
        let s = 0.9;
        let e = 0.98;
        let n_in = 8;
        let n_out = 80;
        let unpruned = (mn as f64 * (1.0 - s)) as usize;
        let unmatched = (unpruned as f64 * (1.0 - e)) as usize;
        let eq7 = compressed_bits_eq7(mn, n_in, n_out, 512, unmatched)
            as f64
            / mn as f64;
        let eq2 = 1.0 - memory_save_eq2(s, e, 10.0);
        // flag bits add 1/512 ≈ 0.002
        assert!(
            (eq7 - eq2 - 1.0 / 512.0).abs() < 1e-3,
            "eq7 {eq7} eq2 {eq2}"
        );
    }
}

//! The XOR-gate connectivity matrix `M⊕`.
//!
//! `M⊕ ∈ {0,1}^{N_out × N_cols}` where `N_cols = (N_s+1)·N_in`. Row `i`
//! lists which input bits feed output XOR gate `i`: if row 2 is
//! `[1 0 1 1]`, then `w₂ = x₁ ⊕ x₃ ⊕ x₄` (§3.1). The paper fills each
//! element randomly with 0/1 (a random linear code) and, among a pool of
//! random candidates, keeps the matrix with the highest measured encoding
//! efficiency (§5.1 "Setup").
//!
//! We store the matrix column-major as `N_out`-bit [`Block`]s: decoding is
//! then "XOR together the columns selected by the set input bits", which
//! is both the hardware semantics and the fast software path.

use super::{low_mask, Block};
use crate::rng::Rng;

/// Binary matrix for the XOR-gate decoder, column-major bit-packed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct XorMatrix {
    /// `cols[j]` holds column `j`; bit `i` set ⟺ `M[i][j] = 1`.
    cols: Vec<Block>,
    n_out: usize,
    /// Seed used for generation, kept so containers can re-derive the
    /// matrix instead of storing it (`None` for hand-built matrices).
    seed: Option<u64>,
}

impl XorMatrix {
    /// Random matrix: every element i.i.d. Bernoulli(1/2), the paper's
    /// design rule. Deterministic in `seed`.
    pub fn random(n_out: usize, n_cols: usize, seed: u64) -> Self {
        assert!(n_out >= 1 && n_out <= 128, "N_out must be in 1..=128");
        let mut rng = Rng::new(seed);
        let mask = low_mask(n_out);
        let cols = (0..n_cols)
            .map(|_| {
                let lo = rng.next_u64() as u128;
                let hi = (rng.next_u64() as u128) << 64;
                (hi | lo) & mask
            })
            .collect();
        XorMatrix { cols, n_out, seed: Some(seed) }
    }

    /// Build from explicit rows (`rows[i][j] = M[i][j]`).
    pub fn from_rows(rows: &[Vec<bool>]) -> Self {
        let n_out = rows.len();
        assert!(n_out >= 1 && n_out <= 128);
        let n_cols = rows[0].len();
        assert!(rows.iter().all(|r| r.len() == n_cols));
        let mut cols = vec![0 as Block; n_cols];
        for (i, row) in rows.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                if v {
                    cols[j] |= 1 << i;
                }
            }
        }
        XorMatrix { cols, n_out, seed: None }
    }

    /// Output bits per block.
    #[inline]
    pub fn n_out(&self) -> usize {
        self.n_out
    }

    /// Total input columns (`(N_s+1)·N_in`).
    #[inline]
    pub fn n_cols(&self) -> usize {
        self.cols.len()
    }

    /// Generation seed, if the matrix was randomly generated.
    pub fn seed(&self) -> Option<u64> {
        self.seed
    }

    /// Column `j` as a bit-packed block.
    #[inline]
    pub fn col(&self, j: usize) -> Block {
        self.cols[j]
    }

    /// Decode: `M⊕ · x` over GF(2), where bit `j` of `x` selects column
    /// `j`. `x` must fit in 64 bits (the paper's `(N_s+1)·N_in ≤ 26`).
    #[inline]
    pub fn decode(&self, x: u64) -> Block {
        let mut acc: Block = 0;
        let mut rem = x & low_mask(self.n_cols().min(64)) as u128 as u64;
        while rem != 0 {
            let j = rem.trailing_zeros() as usize;
            acc ^= self.cols[j];
            rem &= rem - 1;
        }
        acc
    }

    /// Element access (row `i`, column `j`).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> bool {
        (self.cols[j] >> i) & 1 == 1
    }

    /// Number of XOR gates a hardware realization needs:
    /// `Σ_i max(popcount(row_i) − 1, 0)` (each row of `k` taps is a
    /// `k−1`-gate XOR tree). Appendix G approximates this as
    /// `N_out·N_cols/2` for random fill; we compute it exactly.
    pub fn xor_gate_count(&self) -> usize {
        (0..self.n_out)
            .map(|i| {
                let taps =
                    self.cols.iter().filter(|c| (*c >> i) & 1 == 1).count();
                taps.saturating_sub(1)
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_is_linear() {
        let m = XorMatrix::random(16, 8, 42);
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let a = rng.next_u64() & 0xFF;
            let b = rng.next_u64() & 0xFF;
            // Linearity over GF(2): M(a ⊕ b) = M(a) ⊕ M(b)
            assert_eq!(m.decode(a ^ b), m.decode(a) ^ m.decode(b));
        }
        assert_eq!(m.decode(0), 0);
    }

    #[test]
    fn decode_matches_row_wise_definition() {
        // Paper's example: row [1 0 1 1] ⇒ w = x1 ⊕ x3 ⊕ x4.
        let rows = vec![
            vec![true, false, true, true],
            vec![false, true, false, false],
        ];
        let m = XorMatrix::from_rows(&rows);
        // x = (1, 1, 1, 0) LSB-first → 0b0111
        let out = m.decode(0b0111);
        // row0: x1⊕x3⊕x4 = 1⊕1⊕0 = 0 ; row1: x2 = 1
        assert_eq!(out & 1, 0);
        assert_eq!((out >> 1) & 1, 1);
    }

    #[test]
    fn decode_single_bit_selects_column() {
        let m = XorMatrix::random(32, 16, 3);
        for j in 0..16 {
            assert_eq!(m.decode(1 << j), m.col(j));
        }
    }

    #[test]
    fn random_is_deterministic_in_seed() {
        let a = XorMatrix::random(80, 24, 5);
        let b = XorMatrix::random(80, 24, 5);
        assert_eq!(a, b);
        let c = XorMatrix::random(80, 24, 6);
        assert_ne!(a, c);
    }

    #[test]
    fn random_density_is_half() {
        let m = XorMatrix::random(128, 64, 11);
        let ones: u32 = (0..64).map(|j| m.col(j).count_ones()).sum();
        let density = ones as f64 / (128.0 * 64.0);
        assert!((density - 0.5).abs() < 0.03, "{density}");
    }

    #[test]
    fn gate_count_matches_appendix_g_estimate() {
        let m = XorMatrix::random(96, 24, 9);
        let approx = 96 * 24 / 2;
        let exact = m.xor_gate_count();
        // Exact count is Σ(taps−1) = total_ones − rows_with_taps ≈ N/2 − N_out.
        assert!(
            (exact as i64 - (approx as i64 - 96)).abs() < 200,
            "exact={exact} approx={approx}"
        );
    }
}

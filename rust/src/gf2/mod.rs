//! GF(2) primitives: bit-packed vectors, the XOR-gate matrix `M⊕`, and
//! table-accelerated decoding.
//!
//! The paper's decoder is a linear map over the two-element Galois field:
//! an output block `w ∈ {0,1}^{N_out}` is `M⊕ · x` where
//! `x ∈ {0,1}^{(N_s+1)·N_in}` is the concatenation of the current encoded
//! vector with the `N_s` shift-register copies of previous ones. Addition
//! over GF(2) is XOR, so `M⊕ · x` is the XOR of the columns of `M⊕`
//! selected by the set bits of `x`.
//!
//! Everything here is bit-packed:
//!
//! * a whole block (`N_out ≤ 128` covers every configuration in the paper,
//!   which uses `N_out ≤ 96`) lives in one [`Block`] (`u128`);
//! * flattened bit-planes live in a [`BitVecF2`] (`Vec<u64>` words);
//! * decoding uses per-input-byte lookup tables ([`tables::ChunkTables`]),
//!   reducing a GF(2) mat-vec to a handful of table lookups and XORs —
//!   this is the software analogue of the paper's single-cycle XOR array.

mod bitvec;
mod matrix;
mod tables;

pub use bitvec::BitVecF2;
pub use matrix::XorMatrix;
pub use tables::ChunkTables;

/// One decoded/encoded block, bit `i` in the LSB-first position `1 << i`.
/// `N_out ≤ 128`.
pub type Block = u128;

/// Mask with the low `n` bits set (`n ≤ 128`).
#[inline]
pub fn low_mask(n: usize) -> Block {
    debug_assert!(n <= 128);
    if n == 128 {
        !0
    } else {
        (1u128 << n) - 1
    }
}

/// Number of mismatching *unpruned* bits between `a` and `b` under `mask`
/// (mask bit set = position is unpruned and must match).
#[inline]
pub fn masked_hamming(a: Block, b: Block, mask: Block) -> u32 {
    ((a ^ b) & mask).count_ones()
}

/// Parity (XOR-reduction) of `x & y` — the GF(2) inner product of two
/// bit-packed vectors.
#[inline]
pub fn dot_f2(x: u64, y: u64) -> u8 {
    ((x & y).count_ones() & 1) as u8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_mask_values() {
        assert_eq!(low_mask(0), 0);
        assert_eq!(low_mask(1), 1);
        assert_eq!(low_mask(8), 0xFF);
        assert_eq!(low_mask(128), !0u128);
    }

    #[test]
    fn masked_hamming_counts_only_masked_positions() {
        let a = 0b1010u128;
        let b = 0b0110u128;
        // differ at bits 2 and 3... a^b = 1100
        assert_eq!(masked_hamming(a, b, 0b1111), 2);
        assert_eq!(masked_hamming(a, b, 0b0100), 1);
        assert_eq!(masked_hamming(a, b, 0b0011), 0);
        assert_eq!(masked_hamming(a, b, 0), 0);
    }

    #[test]
    fn dot_f2_is_parity_of_and() {
        assert_eq!(dot_f2(0b101, 0b100), 1);
        assert_eq!(dot_f2(0b101, 0b101), 0);
        assert_eq!(dot_f2(0, 0xFFFF_FFFF_FFFF_FFFF), 0);
    }
}

//! Chunked lookup tables for fast decoding.
//!
//! The sequential decoder's input at time `t` is the concatenation
//! `w_t^e ⌢ w_{t-1}^e ⌢ … ⌢ w_{t-N_s}^e` of `N_s+1` chunks of `N_in` bits.
//! Because decoding is linear over GF(2), the output splits per chunk:
//!
//! ```text
//! M⊕ · (c₀ ⌢ c₁ ⌢ … ⌢ c_{N_s}) = T₀[c₀] ⊕ T₁[c₁] ⊕ … ⊕ T_{N_s}[c_{N_s}]
//! ```
//!
//! where `T_s[v]` precomputes the XOR of slot-`s` columns selected by `v`.
//! A decode becomes `N_s+1` table lookups + XORs, and — crucially for the
//! Viterbi encoder — candidate outputs for all `2^{N_in}` transitions from
//! a state can be enumerated by varying a single table index.

use super::{Block, XorMatrix};

/// Per-slot decode tables: `tables[s][v] = M⊕ · (v placed in slot s)`.
#[derive(Debug, Clone)]
pub struct ChunkTables {
    tables: Vec<Vec<Block>>,
    n_in: usize,
    n_out: usize,
}

impl ChunkTables {
    /// Build tables from a matrix whose columns are laid out as
    /// `n_slots` slots of `n_in` bits: slot `s` covers columns
    /// `[s·n_in, (s+1)·n_in)`.
    ///
    /// Each table is built in `O(2^{N_in})` by a Gray-code-free dynamic
    /// expansion: `T[v] = T[v & (v-1)] ^ col(lowest set bit)`.
    pub fn new(m: &XorMatrix, n_in: usize, n_slots: usize) -> Self {
        assert_eq!(m.n_cols(), n_in * n_slots, "matrix/slot shape mismatch");
        assert!(n_in <= 24, "table size 2^{n_in} too large");
        let size = 1usize << n_in;
        let mut tables = Vec::with_capacity(n_slots);
        for s in 0..n_slots {
            let base = s * n_in;
            let mut t = vec![0 as Block; size];
            for v in 1..size {
                let low = v.trailing_zeros() as usize;
                t[v] = t[v & (v - 1)] ^ m.col(base + low);
            }
            tables.push(t);
        }
        ChunkTables { tables, n_in, n_out: m.n_out() }
    }

    /// Encoded-vector width `N_in`.
    #[inline]
    pub fn n_in(&self) -> usize {
        self.n_in
    }

    /// Output width `N_out`.
    #[inline]
    pub fn n_out(&self) -> usize {
        self.n_out
    }

    /// Number of slots (`N_s + 1`).
    #[inline]
    pub fn n_slots(&self) -> usize {
        self.tables.len()
    }

    /// Contribution of chunk value `v` in slot `s`.
    #[inline]
    pub fn slot(&self, s: usize, v: usize) -> Block {
        self.tables[s][v]
    }

    /// Full table for one slot (hot loops index it directly).
    #[inline]
    pub fn slot_table(&self, s: usize) -> &[Block] {
        &self.tables[s]
    }

    /// Decode from per-slot chunk values (slot 0 = current input `w_t^e`,
    /// slot `s` = input from `s` steps ago).
    #[inline]
    pub fn decode_chunks(&self, chunks: &[usize]) -> Block {
        debug_assert_eq!(chunks.len(), self.tables.len());
        let mut acc: Block = 0;
        for (s, &v) in chunks.iter().enumerate() {
            acc ^= self.tables[s][v];
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn tables_match_direct_decode() {
        let n_in = 6;
        let n_slots = 3;
        let m = XorMatrix::random(40, n_in * n_slots, 77);
        let t = ChunkTables::new(&m, n_in, n_slots);
        let mut rng = Rng::new(5);
        for _ in 0..500 {
            let c0 = rng.below(1 << n_in);
            let c1 = rng.below(1 << n_in);
            let c2 = rng.below(1 << n_in);
            let x = (c0 as u64)
                | ((c1 as u64) << n_in)
                | ((c2 as u64) << (2 * n_in));
            assert_eq!(t.decode_chunks(&[c0, c1, c2]), m.decode(x));
        }
    }

    #[test]
    fn single_slot_table_equals_matrix_decode() {
        let m = XorMatrix::random(16, 8, 1);
        let t = ChunkTables::new(&m, 8, 1);
        for v in 0..256usize {
            assert_eq!(t.slot(0, v), m.decode(v as u64));
        }
    }

    #[test]
    fn zero_chunks_decode_to_zero() {
        let m = XorMatrix::random(80, 24, 2);
        let t = ChunkTables::new(&m, 8, 3);
        assert_eq!(t.decode_chunks(&[0, 0, 0]), 0);
    }
}

//! Arbitrary-length bit-packed vector over GF(2).
//!
//! Used for flattened weight bit-planes and pruning masks: a layer of
//! `m·n` weights becomes `n_w` bit-planes of `m·n` bits each (§4 "weight
//! manipulation"). Bits are stored LSB-first inside `u64` words, index 0
//! first.

use super::{low_mask, Block};

/// Bit-packed vector of bits over GF(2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitVecF2 {
    words: Vec<u64>,
    len: usize,
}

impl BitVecF2 {
    /// All-zero vector of `len` bits.
    pub fn zeros(len: usize) -> Self {
        BitVecF2 { words: vec![0; len.div_ceil(64)], len }
    }

    /// Build from a bool slice.
    pub fn from_bools(bits: &[bool]) -> Self {
        let mut v = BitVecF2::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b {
                v.set(i, true);
            }
        }
        v
    }

    /// Build from an iterator of bools with known length.
    pub fn from_iter_bits<I: IntoIterator<Item = bool>>(iter: I) -> Self {
        let bits: Vec<bool> = iter.into_iter().collect();
        Self::from_bools(&bits)
    }

    /// Random vector where each bit is 1 with probability `p_one`.
    pub fn random(len: usize, p_one: f64, rng: &mut crate::rng::Rng) -> Self {
        let mut v = BitVecF2::zeros(len);
        if (p_one - 0.5).abs() < 1e-12 {
            // Fast path: fill words directly.
            for w in v.words.iter_mut() {
                *w = rng.next_u64();
            }
            v.trim();
        } else {
            for i in 0..len {
                if rng.bernoulli(p_one) {
                    v.set(i, true);
                }
            }
        }
        v
    }

    /// Number of bits.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if the vector holds no bits.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Get bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Set bit `i`.
    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let (w, b) = (i / 64, i % 64);
        if v {
            self.words[w] |= 1 << b;
        } else {
            self.words[w] &= !(1 << b);
        }
    }

    /// Flip bit `i`.
    #[inline]
    pub fn flip(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] ^= 1 << (i % 64);
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Number of clear bits.
    pub fn count_zeros(&self) -> usize {
        self.len - self.count_ones()
    }

    /// Ratio of zero bits (the paper's "ratio of zeros", input to the
    /// inverting decision).
    pub fn zero_ratio(&self) -> f64 {
        if self.len == 0 {
            return 1.0;
        }
        self.count_zeros() as f64 / self.len as f64
    }

    /// Invert every bit in place (the paper's inverting technique).
    pub fn invert(&mut self) {
        for w in self.words.iter_mut() {
            *w = !*w;
        }
        self.trim();
    }

    /// XOR with another vector of equal length.
    pub fn xor_with(&mut self, other: &BitVecF2) {
        assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a ^= b;
        }
    }

    /// Extract `width ≤ 128` bits starting at bit offset `start` into a
    /// [`Block`]. Bits past `len` read as zero (blocks at the tail of a
    /// sliced plane are implicitly zero-padded, matching the paper's
    /// `l = ⌈mn / N_out⌉` slicing).
    pub fn block(&self, start: usize, width: usize) -> Block {
        debug_assert!(width <= 128);
        let mut out: Block = 0;
        let mut got = 0usize;
        while got < width {
            let i = start + got;
            if i >= self.len {
                break;
            }
            let (w, b) = (i / 64, i % 64);
            let avail = 64 - b;
            let take = avail.min(width - got);
            let chunk = (self.words[w] >> b) as u128 & low_mask(take) as Block as u128;
            out |= (chunk as Block) << got;
            got += take;
        }
        out & low_mask(width)
    }

    /// Write `width ≤ 128` bits of `val` at bit offset `start` (bits past
    /// `len` are dropped).
    pub fn set_block(&mut self, start: usize, width: usize, val: Block) {
        debug_assert!(width <= 128);
        for i in 0..width {
            let idx = start + i;
            if idx >= self.len {
                break;
            }
            self.set(idx, (val >> i) & 1 == 1);
        }
    }

    /// Iterate bits as bools.
    pub fn iter(&self) -> impl Iterator<Item = bool> + '_ {
        (0..self.len).map(move |i| self.get(i))
    }

    /// Raw words (LSB-first packing), for serialization.
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Rebuild from raw words + length.
    pub fn from_words(words: Vec<u64>, len: usize) -> Self {
        assert!(words.len() == len.div_ceil(64));
        let mut v = BitVecF2 { words, len };
        v.trim();
        v
    }

    /// Zero any bits beyond `len` in the last word.
    fn trim(&mut self) {
        let extra = self.len % 64;
        if extra != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << extra) - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn set_get_roundtrip() {
        let mut v = BitVecF2::zeros(130);
        v.set(0, true);
        v.set(63, true);
        v.set(64, true);
        v.set(129, true);
        assert!(v.get(0) && v.get(63) && v.get(64) && v.get(129));
        assert!(!v.get(1) && !v.get(65));
        assert_eq!(v.count_ones(), 4);
    }

    #[test]
    fn block_crosses_word_boundary() {
        let mut v = BitVecF2::zeros(200);
        // Set bits 60..70.
        for i in 60..70 {
            v.set(i, true);
        }
        let b = v.block(58, 16);
        // bits 2..12 of the block should be set.
        assert_eq!(b, 0b0000_1111_1111_1100);
    }

    #[test]
    fn block_tail_zero_padded() {
        let mut v = BitVecF2::zeros(10);
        v.set(9, true);
        let b = v.block(8, 8);
        assert_eq!(b, 0b10); // bit 9 lands at offset 1; rest zero
    }

    #[test]
    fn set_block_roundtrip() {
        let mut v = BitVecF2::zeros(300);
        v.set_block(100, 80, 0xDEAD_BEEF_CAFE_1234_5678u128 & super::low_mask(80));
        assert_eq!(v.block(100, 80), 0xDEAD_BEEF_CAFE_1234_5678u128 & super::low_mask(80));
    }

    #[test]
    fn invert_flips_exactly_len_bits() {
        let mut v = BitVecF2::zeros(70);
        v.set(3, true);
        v.invert();
        assert_eq!(v.count_ones(), 69);
        assert!(!v.get(3));
        // trim keeps word padding clean
        assert_eq!(v.words()[1] >> 6, 0);
    }

    #[test]
    fn zero_ratio() {
        let mut v = BitVecF2::zeros(100);
        for i in 0..25 {
            v.set(i, true);
        }
        assert!((v.zero_ratio() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn random_half_density() {
        let mut rng = Rng::new(1);
        let v = BitVecF2::random(100_000, 0.5, &mut rng);
        let ones = v.count_ones() as f64 / 100_000.0;
        assert!((ones - 0.5).abs() < 0.01, "{ones}");
    }

    #[test]
    fn random_biased_density() {
        let mut rng = Rng::new(2);
        let v = BitVecF2::random(100_000, 0.1, &mut rng);
        let ones = v.count_ones() as f64 / 100_000.0;
        assert!((ones - 0.1).abs() < 0.01, "{ones}");
    }

    #[test]
    fn words_roundtrip() {
        let mut rng = Rng::new(3);
        let v = BitVecF2::random(777, 0.5, &mut rng);
        let w = BitVecF2::from_words(v.words().to_vec(), 777);
        assert_eq!(v, w);
    }

    #[test]
    fn xor_with_self_is_zero() {
        let mut rng = Rng::new(4);
        let mut v = BitVecF2::random(500, 0.5, &mut rng);
        let w = v.clone();
        v.xor_with(&w);
        assert_eq!(v.count_ones(), 0);
    }
}

//! Repo-native static analysis: the `f2f lint` invariant checker.
//!
//! The serving stack's contract — corrupt input *errors*, it never
//! panics; a panicking worker degrades, it never cascades — is easy to
//! promise in a PR description and easy to regress one `.unwrap()` at
//! a time. With no external linting crates available offline, this
//! module enforces the contract with a hand-rolled token-level scanner
//! ([`lexer`]) and a small set of scoped rules ([`rules`]):
//!
//! | rule | scope | meaning |
//! |------|-------|---------|
//! | `no-unwrap` | `ipc/ container/ store/ shard/ coordinator/ sparse/ kernels/` | no `.unwrap()` / `.expect()` outside tests |
//! | `no-panic` | same | no `panic!` / `assert!` / `unreachable!` / `todo!` (`debug_assert*` is fine) |
//! | `lock-poison` | same | no `.lock().unwrap()`: use [`crate::sync`] or handle poisoning |
//! | `no-index` | wire/container/JSON parser files | no unchecked `x[i]` on adversarial input |
//! | `safety-comment` | all of `rust/src/` | every `unsafe` carries a `// SAFETY:` comment |
//! | `bad-allow` | all | malformed escape-hatch comments are themselves findings |
//!
//! Code under `#[test]` / `#[cfg(test)]` is exempt from every rule.
//! Justified exceptions use the escape hatch, which must name the rule
//! *and* carry a reason:
//!
//! ```text
//! // lint: allow(no-index) -- chunks_exact(4) yields 4-byte slices
//! ```
//!
//! Run it as `f2f lint` (CI does, on every push); the linter itself is
//! regression-tested against the must-fail fixture corpus in
//! `analysis/fixtures/` (non-`.rs` extensions, so the repo walk skips
//! them).

pub mod lexer;
pub mod rules;

pub use rules::{lint_source, Finding, Rule};

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Lint every `.rs` file under `<repo_root>/rust/src`, returning all
/// findings (empty means the repo is clean). File order — and so
/// finding order — is deterministic.
pub fn run_lint(repo_root: &Path) -> Result<Vec<Finding>> {
    let src_root = repo_root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs(&src_root, &PathBuf::new(), &mut files)
        .with_context(|| {
            format!("walking {}", src_root.display())
        })?;
    files.sort();
    let mut findings = Vec::new();
    for rel in files {
        let path = src_root.join(&rel);
        let src = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {}", path.display())
        })?;
        let rel = rel.to_string_lossy().replace('\\', "/");
        findings.extend(lint_source(&rel, &src));
    }
    Ok(findings)
}

fn collect_rs(
    root: &Path,
    rel: &Path,
    out: &mut Vec<PathBuf>,
) -> Result<()> {
    for entry in std::fs::read_dir(root.join(rel))? {
        let entry = entry?;
        let sub = rel.join(entry.file_name());
        if entry.file_type()?.is_dir() {
            collect_rs(root, &sub, out)?;
        } else if sub.extension().is_some_and(|e| e == "rs") {
            out.push(sub);
        }
    }
    Ok(())
}

/// Render findings one per line, `file:line: rule — message`, with
/// paths relative to the repo root (clickable in most terminals).
pub fn render(findings: &[Finding]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    for f in findings {
        let _ = writeln!(
            out,
            "rust/src/{}:{}: {} — {}",
            f.file,
            f.line,
            f.rule.name(),
            f.message
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Lint a fixture as if it lived at a serving-path parser file, so
    /// every rule scope is active.
    fn lint_fixture(src: &str) -> Vec<Finding> {
        lint_source("container/serde.rs", src)
    }

    fn rules_of(findings: &[Finding]) -> Vec<Rule> {
        findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn fixture_no_unwrap_fails() {
        let f =
            lint_fixture(include_str!("fixtures/no_unwrap.fixture"));
        assert_eq!(rules_of(&f), [Rule::NoUnwrap, Rule::NoUnwrap]);
    }

    #[test]
    fn fixture_no_panic_fails() {
        let f = lint_fixture(include_str!("fixtures/no_panic.fixture"));
        assert_eq!(rules_of(&f), [Rule::NoPanic, Rule::NoPanic]);
    }

    #[test]
    fn fixture_no_index_fails() {
        let f = lint_fixture(include_str!("fixtures/no_index.fixture"));
        assert_eq!(rules_of(&f), [Rule::NoIndex, Rule::NoIndex]);
    }

    #[test]
    fn fixture_safety_comment_fails() {
        let f = lint_fixture(include_str!(
            "fixtures/safety_comment.fixture"
        ));
        assert_eq!(rules_of(&f), [Rule::SafetyComment]);
    }

    #[test]
    fn fixture_lock_poison_fails_once_not_twice() {
        // `.lock().unwrap()` is one lock-poison finding; the trailing
        // unwrap must not be double-reported as no-unwrap.
        let f =
            lint_fixture(include_str!("fixtures/lock_poison.fixture"));
        assert_eq!(rules_of(&f), [Rule::LockPoison, Rule::LockPoison]);
    }

    #[test]
    fn fixture_bad_allow_fails_and_suppresses_nothing() {
        let f =
            lint_fixture(include_str!("fixtures/bad_allow.fixture"));
        let rules = rules_of(&f);
        assert_eq!(
            rules.iter().filter(|r| **r == Rule::BadAllow).count(),
            2,
            "{f:?}"
        );
        // The unwraps under the malformed allows still count.
        assert_eq!(
            rules.iter().filter(|r| **r == Rule::NoUnwrap).count(),
            2,
            "{f:?}"
        );
    }

    #[test]
    fn fixture_allow_ok_passes() {
        let f = lint_fixture(include_str!("fixtures/allow_ok.fixture"));
        assert!(f.is_empty(), "{}", render(&f));
    }

    #[test]
    fn fixture_test_mod_skip_passes() {
        let f = lint_fixture(include_str!(
            "fixtures/test_mod_skip.fixture"
        ));
        assert!(f.is_empty(), "{}", render(&f));
    }

    #[test]
    fn fixture_tricky_lexer_passes() {
        let f =
            lint_fixture(include_str!("fixtures/tricky_lexer.fixture"));
        assert!(f.is_empty(), "{}", render(&f));
    }

    #[test]
    fn scopes_limit_rules_to_their_directories() {
        // The same unwrap is a finding on the serving path, silent in
        // an offline module (encoder math may panic on programmer
        // error), and indexing is only policed in parser files.
        let src = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        assert_eq!(lint_source("store/pool.rs", src).len(), 1);
        assert_eq!(lint_source("encoder/viterbi.rs", src).len(), 0);
        let idx = "pub fn g(b: &[u8]) -> u8 { b[0] }\n";
        assert_eq!(lint_source("ipc/wire.rs", idx).len(), 1);
        assert_eq!(lint_source("store/pool.rs", idx).len(), 0);
    }

    #[test]
    fn allow_covers_its_own_line_and_the_next() {
        let trailing = "pub fn f(x: Option<u32>) -> u32 {\n\
             x.unwrap() // lint: allow(no-unwrap) -- fixture\n\
             }\n";
        assert!(lint_source("store/a.rs", trailing).is_empty());
        let too_far = "pub fn f(x: Option<u32>) -> u32 {\n\
             // lint: allow(no-unwrap) -- fixture\n\
             let y = x;\n\
             y.unwrap()\n\
             }\n";
        assert_eq!(lint_source("store/a.rs", too_far).len(), 1);
    }

    #[test]
    fn allow_is_rule_specific() {
        let src = "pub fn f(x: Option<u32>) -> u32 {\n\
             // lint: allow(no-panic) -- wrong rule for this line\n\
             x.unwrap()\n\
             }\n";
        let f = lint_source("store/a.rs", src);
        assert_eq!(rules_of(&f), [Rule::NoUnwrap]);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // walks the real source tree
    fn repo_is_lint_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let findings = run_lint(root).expect("lint walk");
        assert!(
            findings.is_empty(),
            "f2f lint found {} violation(s):\n{}",
            findings.len(),
            render(&findings)
        );
    }
}

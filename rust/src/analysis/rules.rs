//! The lint rules: token-level invariant checks over one source file.
//!
//! Each rule is scoped (see [`super`] for the full catalog): the
//! panic-freedom rules apply to the serving-critical directories, the
//! indexing rule to the adversarial-input parser files, and the
//! `SAFETY:` rule to every file. Code under a `#[test]` / `#[cfg(test)]`
//! attribute is exempt from all rules — tests are *supposed* to
//! unwrap, panic and index freely.

use super::lexer::{lex, Comment, Tok, Token};

/// Directories (relative to `rust/src/`) on the serving path, where a
/// panic is an availability bug: one poisoned mutex or unwound worker
/// must degrade to an error response, never take the process down.
const SERVING_DIRS: [&str; 8] = [
    "ipc/",
    "container/",
    "store/",
    "shard/",
    "coordinator/",
    "sparse/",
    "kernels/",
    "registry/",
];

/// Files that parse adversarial bytes (wire frames, container records,
/// external JSON). Unchecked indexing is forbidden here outright:
/// every access must be `get`-shaped or justified with an allow.
const PARSER_FILES: [&str; 5] = [
    "ipc/wire.rs",
    "container/serde.rs",
    "container/v2.rs",
    "container/shard.rs",
    "shard/rebalance.rs",
];

/// Macros that abort the current thread. `debug_assert*` is exempt by
/// construction (different identifier): debug-only invariant checks
/// are encouraged, release panics are not.
const PANIC_MACROS: [&str; 7] = [
    "panic",
    "unreachable",
    "todo",
    "unimplemented",
    "assert",
    "assert_eq",
    "assert_ne",
];

/// Keywords that may legally precede `[` without forming an index
/// expression (`let [a, b] = …`, `&mut [0; 4]`, `impl [T]`, …).
const INDEX_KEYWORDS: [&str; 22] = [
    "as", "await", "box", "break", "const", "dyn", "else", "if", "impl",
    "in", "let", "match", "move", "mut", "pub", "ref", "return",
    "static", "type", "union", "where", "yield",
];

/// One lint rule. `name()` is the spelling used in findings and in the
/// `// lint: allow(<rule>) -- <reason>` escape hatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// `.unwrap()` / `.expect()` in a serving-critical module.
    NoUnwrap,
    /// `panic!` / `assert!` / `unreachable!` / … in a serving-critical
    /// module.
    NoPanic,
    /// Unchecked `x[i]` indexing in a parser file.
    NoIndex,
    /// An `unsafe` block or impl with no `// SAFETY:` comment within
    /// the three preceding lines.
    SafetyComment,
    /// `.lock().unwrap()` (or `.wait(..).unwrap()`) — re-panics on a
    /// mutex poisoned by an earlier panic, cascading one failure into
    /// every later request. Use [`crate::sync::lock_unpoisoned`] /
    /// [`crate::sync::wait_unpoisoned`] or handle the `PoisonError`.
    LockPoison,
    /// A malformed `// lint: allow(...)` comment: unknown rule, or a
    /// missing `-- <reason>` justification. Never allowable itself.
    BadAllow,
}

impl Rule {
    /// The rule's spelling in findings and allow comments.
    pub fn name(self) -> &'static str {
        match self {
            Rule::NoUnwrap => "no-unwrap",
            Rule::NoPanic => "no-panic",
            Rule::NoIndex => "no-index",
            Rule::SafetyComment => "safety-comment",
            Rule::LockPoison => "lock-poison",
            Rule::BadAllow => "bad-allow",
        }
    }

    fn from_name(s: &str) -> Option<Rule> {
        match s {
            "no-unwrap" => Some(Rule::NoUnwrap),
            "no-panic" => Some(Rule::NoPanic),
            "no-index" => Some(Rule::NoIndex),
            "safety-comment" => Some(Rule::SafetyComment),
            "lock-poison" => Some(Rule::LockPoison),
            _ => None,
        }
    }
}

/// One lint violation: file (relative to `rust/src/`), 1-based line,
/// rule, and a human-oriented message.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: Rule,
    pub message: String,
}

struct Allow {
    rule: Rule,
    from: u32,
    to: u32,
}

/// Lint one file's source. `rel_path` is the path relative to
/// `rust/src/` with `/` separators — it selects which rule scopes
/// apply (see the module docs).
pub fn lint_source(rel_path: &str, src: &str) -> Vec<Finding> {
    let (tokens, comments) = lex(src);
    let skipped = test_spans(&tokens);
    let (allows, mut findings) = parse_allows(rel_path, &comments);

    let serving = SERVING_DIRS.iter().any(|d| rel_path.starts_with(d));
    let parser_file = PARSER_FILES.contains(&rel_path);
    let mut push = |line: u32, rule: Rule, message: String| {
        findings.push(Finding {
            file: rel_path.to_string(),
            line,
            rule,
            message,
        });
    };

    // lock-poison runs first so the trailing `.unwrap` it consumes is
    // not double-reported by no-unwrap.
    let mut lock_unwraps = Vec::new();
    if serving {
        for i in 0..tokens.len() {
            if skipped[i] || !ident_in(&tokens, i, &["lock", "wait"]) {
                continue;
            }
            if i == 0 || !is_punct(&tokens, i - 1, '.') {
                continue;
            }
            let Some(close) = matching_paren(&tokens, i + 1) else {
                continue;
            };
            if is_punct(&tokens, close + 1, '.')
                && ident_in(&tokens, close + 2, &["unwrap", "expect"])
                && is_punct(&tokens, close + 3, '(')
            {
                lock_unwraps.push(close + 2);
                let name = ident_text(&tokens, i);
                push(
                    tokens[i].line,
                    Rule::LockPoison,
                    format!(
                        "`.{name}(..)` result unwrapped: panics if the \
                         mutex was poisoned by an earlier panic; use \
                         crate::sync::{{lock,wait}}_unpoisoned or \
                         handle the PoisonError"
                    ),
                );
            }
        }
    }

    if serving {
        for i in 0..tokens.len() {
            if skipped[i] || lock_unwraps.contains(&i) {
                continue;
            }
            if ident_in(&tokens, i, &["unwrap", "expect"])
                && i > 0
                && is_punct(&tokens, i - 1, '.')
                && is_punct(&tokens, i + 1, '(')
            {
                let name = ident_text(&tokens, i);
                push(
                    tokens[i].line,
                    Rule::NoUnwrap,
                    format!(
                        "`.{name}()` in a serving-critical module: \
                         return an error instead of panicking"
                    ),
                );
            }
            if ident_in(&tokens, i, &PANIC_MACROS)
                && is_punct(&tokens, i + 1, '!')
            {
                let name = ident_text(&tokens, i);
                push(
                    tokens[i].line,
                    Rule::NoPanic,
                    format!(
                        "`{name}!` in a serving-critical module: \
                         return an error (or use debug_assert! for \
                         debug-only invariants)"
                    ),
                );
            }
        }
    }

    if parser_file {
        for i in 1..tokens.len() {
            if skipped[i] || !is_punct(&tokens, i, '[') {
                continue;
            }
            let indexes = match &tokens[i - 1].tok {
                Tok::Ident(name) => {
                    !INDEX_KEYWORDS.contains(&name.as_str())
                }
                Tok::Punct(')' | ']' | '?') => true,
                _ => false,
            };
            if indexes {
                push(
                    tokens[i].line,
                    Rule::NoIndex,
                    "unchecked indexing in a parser: corrupt input \
                     must error, never panic — use get()/get_mut() \
                     or split_at_checked-style access"
                        .to_string(),
                );
            }
        }
    }

    for i in 0..tokens.len() {
        if skipped[i] || !ident_in(&tokens, i, &["unsafe"]) {
            continue;
        }
        let line = tokens[i].line;
        let documented = comments.iter().any(|c| {
            c.text.contains("SAFETY:")
                && c.end_line <= line
                && c.end_line + 3 >= line
        });
        if !documented {
            push(
                line,
                Rule::SafetyComment,
                "`unsafe` without a `// SAFETY:` comment on the \
                 preceding lines stating why the preconditions hold"
                    .to_string(),
            );
        }
    }

    findings.retain(|f| {
        f.rule == Rule::BadAllow
            || !allows.iter().any(|a| {
                a.rule == f.rule && a.from <= f.line && f.line <= a.to
            })
    });
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}

/// Collect `// lint: allow(<rule>) -- <reason>` comments. A valid
/// allow suppresses its rule on the comment's own lines and the line
/// immediately after (so both trailing and preceding placement work);
/// a malformed one suppresses nothing and is itself a finding.
fn parse_allows(
    rel_path: &str,
    comments: &[Comment],
) -> (Vec<Allow>, Vec<Finding>) {
    let mut allows = Vec::new();
    let mut findings = Vec::new();
    for c in comments {
        let Some(body) = c.text.trim_start().strip_prefix("lint:")
        else {
            continue;
        };
        match parse_allow_body(body.trim()) {
            Ok(rule) => allows.push(Allow {
                rule,
                from: c.line,
                to: c.end_line + 1,
            }),
            Err(why) => findings.push(Finding {
                file: rel_path.to_string(),
                line: c.line,
                rule: Rule::BadAllow,
                message: why,
            }),
        }
    }
    (allows, findings)
}

fn parse_allow_body(body: &str) -> Result<Rule, String> {
    let Some(rest) = body.strip_prefix("allow(") else {
        return Err(
            "expected `allow(<rule>) -- <reason>` after `lint:`"
                .to_string(),
        );
    };
    let Some((rule_name, rest)) = rest.split_once(')') else {
        return Err("unclosed `allow(`".to_string());
    };
    let rule_name = rule_name.trim();
    let Some(rule) = Rule::from_name(rule_name) else {
        return Err(format!("unknown lint rule `{rule_name}`"));
    };
    let Some(reason) = rest.trim_start().strip_prefix("--") else {
        return Err(format!(
            "allow({rule_name}) is missing its `-- <reason>` \
             justification"
        ));
    };
    if reason.trim().is_empty() {
        return Err(format!(
            "allow({rule_name}) has an empty justification"
        ));
    }
    Ok(rule)
}

/// Token indices covered by a test attribute: `#[test]`, `#[cfg(test)]`
/// (and compositions like `#[cfg_attr(miri, ignore)] #[test]`) mark the
/// following item — attribute through the item's matching `}` (or a
/// `;` for item-less forms) — as exempt from every rule.
fn test_spans(tokens: &[Token]) -> Vec<bool> {
    let mut skip = vec![false; tokens.len()];
    let mut i = 0usize;
    while i < tokens.len() {
        if !(is_punct(tokens, i, '#') && is_punct(tokens, i + 1, '[')) {
            i += 1;
            continue;
        }
        // Scan the attribute for the `test` identifier.
        let mut j = i + 2;
        let mut depth = 1usize;
        let mut is_test = false;
        while j < tokens.len() && depth > 0 {
            match &tokens[j].tok {
                Tok::Punct('[') => depth += 1,
                Tok::Punct(']') => depth -= 1,
                Tok::Ident(name) if name == "test" => is_test = true,
                _ => {}
            }
            j += 1;
        }
        if !is_test {
            i = j;
            continue;
        }
        // Skip to the end of the annotated item: the matching close
        // brace of its body, or a `;` reached before any brace.
        let mut k = j;
        let mut braces = 0usize;
        let mut entered = false;
        while k < tokens.len() {
            match &tokens[k].tok {
                Tok::Punct('{') => {
                    braces += 1;
                    entered = true;
                }
                Tok::Punct('}') => {
                    braces = braces.saturating_sub(1);
                    if entered && braces == 0 {
                        k += 1;
                        break;
                    }
                }
                Tok::Punct(';') if !entered => {
                    k += 1;
                    break;
                }
                _ => {}
            }
            k += 1;
        }
        for s in skip.iter_mut().take(k).skip(i) {
            *s = true;
        }
        i = k;
    }
    skip
}

fn is_punct(tokens: &[Token], i: usize, c: char) -> bool {
    matches!(tokens.get(i), Some(t) if t.tok == Tok::Punct(c))
}

fn ident_in(tokens: &[Token], i: usize, names: &[&str]) -> bool {
    match tokens.get(i).map(|t| &t.tok) {
        Some(Tok::Ident(name)) => names.contains(&name.as_str()),
        _ => false,
    }
}

fn ident_text(tokens: &[Token], i: usize) -> &str {
    match tokens.get(i).map(|t| &t.tok) {
        Some(Tok::Ident(name)) => name,
        _ => "",
    }
}

/// With `tokens[open]` expected to be `(`, the index of its matching
/// `)`.
fn matching_paren(tokens: &[Token], open: usize) -> Option<usize> {
    if !is_punct(tokens, open, '(') {
        return None;
    }
    let mut depth = 0usize;
    for (k, t) in tokens.iter().enumerate().skip(open) {
        match t.tok {
            Tok::Punct('(') => depth += 1,
            Tok::Punct(')') => {
                depth -= 1;
                if depth == 0 {
                    return Some(k);
                }
            }
            _ => {}
        }
    }
    None
}

//! A minimal token-level lexer for Rust source — just enough fidelity
//! for the invariant rules in [`super::rules`].
//!
//! This is deliberately *not* a parser: the lint rules only need a
//! faithful token stream (so `.unwrap()` inside a string literal or a
//! comment never counts) plus the comment list with line spans (so
//! `// SAFETY:` and `// lint: allow(...)` comments can be matched to
//! the code they annotate). Handled: line and nested block comments,
//! cooked / raw / byte strings, char literals vs. lifetimes, numeric
//! literals (including `1.0` vs. `0..n` ranges), and identifiers.
//! Known simplification: a non-ASCII char literal lexes as a lifetime
//! plus a stray quote — the repo's sources are ASCII, and the failure
//! mode is a false *positive* a human reviews, never a silent miss.

/// One lexed token kind. Punctuation stays byte-per-byte (`::` is two
/// `Punct(':')` tokens) — the rules only ever match single characters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    /// An identifier or keyword.
    Ident(String),
    /// A lifetime such as `'a` — kept distinct from [`Tok::Ident`] so
    /// `&'a [u8]` never looks like an indexing expression.
    Lifetime,
    /// Any literal: string, raw string, byte string, char, or number.
    Literal,
    /// A single punctuation character.
    Punct(char),
}

/// A token with the 1-based source line it starts on.
#[derive(Debug, Clone)]
pub struct Token {
    pub line: u32,
    pub tok: Tok,
}

/// A comment with its line span (block comments may span lines) and
/// its text — everything after the `//` / between `/*` `*/`.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    pub end_line: u32,
    pub text: String,
}

/// Lex `src` into its token stream and comment list.
pub fn lex(src: &str) -> (Vec<Token>, Vec<Comment>) {
    Lexer::new(src).run()
}

struct Lexer<'a> {
    b: &'a [u8],
    i: usize,
    line: u32,
    tokens: Vec<Token>,
    comments: Vec<Comment>,
}

impl<'a> Lexer<'a> {
    fn new(src: &'a str) -> Self {
        Lexer {
            b: src.as_bytes(),
            i: 0,
            line: 1,
            tokens: Vec::new(),
            comments: Vec::new(),
        }
    }

    fn peek(&self, ahead: usize) -> u8 {
        self.b.get(self.i + ahead).copied().unwrap_or(0)
    }

    fn bump(&mut self) -> u8 {
        let c = self.peek(0);
        self.i += 1;
        if c == b'\n' {
            self.line += 1;
        }
        c
    }

    fn text_since(&self, start: usize, end: usize) -> String {
        let end = end.max(start);
        String::from_utf8_lossy(&self.b[start..end]).into_owned()
    }

    fn run(mut self) -> (Vec<Token>, Vec<Comment>) {
        while self.i < self.b.len() {
            let c = self.peek(0);
            match c {
                b' ' | b'\t' | b'\r' | b'\n' => {
                    self.bump();
                }
                b'/' if self.peek(1) == b'/' => self.line_comment(),
                b'/' if self.peek(1) == b'*' => self.block_comment(),
                b'"' => {
                    let line = self.line;
                    self.bump();
                    self.string_body(false, 0, line);
                }
                b'\'' => self.quote(),
                b'r' | b'b' if self.literal_prefix() => {
                    self.prefixed_literal()
                }
                _ if c == b'_' || c.is_ascii_alphabetic() => self.ident(),
                _ if c.is_ascii_digit() => self.number(),
                _ if c.is_ascii() => {
                    let line = self.line;
                    self.bump();
                    self.tokens.push(Token {
                        line,
                        tok: Tok::Punct(c as char),
                    });
                }
                // Non-ASCII outside a string or comment: opaque bytes
                // (valid Rust only allows them in unicode idents, which
                // this repo does not use).
                _ => {
                    self.bump();
                }
            }
        }
        (self.tokens, self.comments)
    }

    /// Does the `r` / `b` at the cursor start a literal (raw string,
    /// byte string, byte char) rather than an identifier? `r#ident`
    /// raw identifiers answer no and lex as plain tokens.
    fn literal_prefix(&self) -> bool {
        match self.peek(0) {
            b'r' => match self.peek(1) {
                b'"' => true,
                b'#' => {
                    let mut k = 1;
                    while self.peek(k) == b'#' {
                        k += 1;
                    }
                    self.peek(k) == b'"'
                }
                _ => false,
            },
            b'b' => match self.peek(1) {
                b'"' | b'\'' => true,
                b'r' => {
                    let mut k = 2;
                    while self.peek(k) == b'#' {
                        k += 1;
                    }
                    self.peek(k) == b'"'
                }
                _ => false,
            },
            _ => false,
        }
    }

    fn prefixed_literal(&mut self) {
        let line = self.line;
        if self.peek(0) == b'b' && self.peek(1) == b'\'' {
            self.bump(); // b
            self.char_literal(line);
            return;
        }
        let mut raw = false;
        while matches!(self.peek(0), b'b' | b'r') {
            if self.peek(0) == b'r' {
                raw = true;
            }
            self.bump();
        }
        let mut hashes = 0usize;
        while self.peek(0) == b'#' {
            hashes += 1;
            self.bump();
        }
        self.bump(); // opening quote
        self.string_body(raw, hashes, line);
    }

    /// Body of a string literal whose opening quote was consumed. In a
    /// raw string escapes are inert and the closing quote must be
    /// followed by `hashes` `#`s.
    fn string_body(&mut self, raw: bool, hashes: usize, line: u32) {
        while self.i < self.b.len() {
            let c = self.bump();
            if c == b'\\' && !raw {
                self.bump();
            } else if c == b'"' {
                let mut k = 0;
                while k < hashes && self.peek(0) == b'#' {
                    self.bump();
                    k += 1;
                }
                if k == hashes {
                    break;
                }
            }
        }
        self.tokens.push(Token { line, tok: Tok::Literal });
    }

    /// A `'`: char literal (`'x'`, `'\n'`) or lifetime (`'a`).
    fn quote(&mut self) {
        let line = self.line;
        if self.peek(1) == b'\\'
            || (self.peek(2) == b'\'' && self.peek(1) != b'\'')
        {
            self.char_literal(line);
        } else {
            self.bump(); // '
            while self.peek(0) == b'_'
                || self.peek(0).is_ascii_alphanumeric()
            {
                self.bump();
            }
            self.tokens.push(Token { line, tok: Tok::Lifetime });
        }
    }

    /// A char literal with the cursor on its opening quote.
    fn char_literal(&mut self, line: u32) {
        self.bump(); // opening quote
        while self.i < self.b.len() {
            let c = self.bump();
            if c == b'\\' {
                self.bump();
            } else if c == b'\'' {
                break;
            }
        }
        self.tokens.push(Token { line, tok: Tok::Literal });
    }

    fn ident(&mut self) {
        let line = self.line;
        let start = self.i;
        while self.peek(0) == b'_' || self.peek(0).is_ascii_alphanumeric()
        {
            self.bump();
        }
        let text = self.text_since(start, self.i);
        self.tokens.push(Token { line, tok: Tok::Ident(text) });
    }

    /// A numeric literal. The `.` is consumed only when a digit
    /// follows, so `0..n` stays two range dots and `1.0.abs()` stops
    /// before the method call.
    fn number(&mut self) {
        let line = self.line;
        while self.peek(0) == b'_'
            || self.peek(0).is_ascii_alphanumeric()
            || (self.peek(0) == b'.' && self.peek(1).is_ascii_digit())
        {
            self.bump();
        }
        self.tokens.push(Token { line, tok: Tok::Literal });
    }

    fn line_comment(&mut self) {
        let line = self.line;
        self.bump();
        self.bump(); // //
        let start = self.i;
        while self.i < self.b.len() && self.peek(0) != b'\n' {
            self.bump();
        }
        let text = self.text_since(start, self.i);
        self.comments.push(Comment { line, end_line: line, text });
    }

    fn block_comment(&mut self) {
        let line = self.line;
        self.bump();
        self.bump(); // /*
        let start = self.i;
        let mut depth = 1usize;
        while self.i < self.b.len() && depth > 0 {
            if self.peek(0) == b'/' && self.peek(1) == b'*' {
                depth += 1;
                self.bump();
                self.bump();
            } else if self.peek(0) == b'*' && self.peek(1) == b'/' {
                depth -= 1;
                self.bump();
                self.bump();
            } else {
                self.bump();
            }
        }
        let text = self.text_since(start, self.i.saturating_sub(2));
        self.comments.push(Comment {
            line,
            end_line: self.line,
            text,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(src: &str) -> Vec<Tok> {
        lex(src).0.into_iter().map(|t| t.tok).collect()
    }

    fn id(s: &str) -> Tok {
        Tok::Ident(s.to_string())
    }

    #[test]
    fn method_call_chain() {
        assert_eq!(
            toks("x.unwrap()"),
            vec![
                id("x"),
                Tok::Punct('.'),
                id("unwrap"),
                Tok::Punct('('),
                Tok::Punct(')'),
            ]
        );
    }

    #[test]
    fn strings_and_comments_hide_their_contents() {
        let src = r##"
            // a comment with .unwrap() in it
            let a = "string with .unwrap() and x[0]";
            let b = r#"raw with panic!("no")"#;
            /* block /* nested */ with .expect("x") */
            let c = b"bytes .unwrap()";
        "##;
        let (tokens, comments) = lex(src);
        assert!(tokens.iter().all(|t| t.tok != id("unwrap")));
        assert!(tokens.iter().all(|t| t.tok != id("panic")));
        assert_eq!(comments.len(), 2);
        assert!(comments[0].text.contains("unwrap"));
        assert!(comments[1].text.contains("nested"));
    }

    #[test]
    fn lifetimes_are_not_identifiers() {
        let t = toks("fn f<'a>(x: &'a [u8]) -> &'a [u8] { x }");
        assert!(t.contains(&Tok::Lifetime));
        // The `[` after the lifetime follows a Lifetime token, not an
        // identifier — the property the no-index rule relies on.
        let i = t.iter().position(|x| *x == Tok::Lifetime).unwrap();
        assert_eq!(t[i + 1], Tok::Punct('['));
    }

    #[test]
    fn char_literals_versus_lifetimes() {
        assert_eq!(toks("'x'"), vec![Tok::Literal]);
        assert_eq!(toks(r"'\''"), vec![Tok::Literal]);
        assert_eq!(toks("'_'"), vec![Tok::Literal]);
        assert_eq!(toks("'static"), vec![Tok::Lifetime]);
        assert_eq!(toks("b'z'"), vec![Tok::Literal]);
    }

    #[test]
    fn numbers_keep_range_dots() {
        assert_eq!(
            toks("0..n"),
            vec![
                Tok::Literal,
                Tok::Punct('.'),
                Tok::Punct('.'),
                id("n"),
            ]
        );
        assert_eq!(toks("1.5e3"), vec![Tok::Literal]);
        assert_eq!(toks("0xFF_u32"), vec![Tok::Literal]);
        assert_eq!(
            toks("1.0.abs()"),
            vec![
                Tok::Literal,
                Tok::Punct('.'),
                id("abs"),
                Tok::Punct('('),
                Tok::Punct(')'),
            ]
        );
    }

    #[test]
    fn block_comment_spans_lines() {
        let (_, comments) = lex("/* one\ntwo\nthree */ fn f() {}");
        assert_eq!(comments.len(), 1);
        assert_eq!(comments[0].line, 1);
        assert_eq!(comments[0].end_line, 3);
    }

    #[test]
    fn raw_strings_with_hashes_terminate_correctly() {
        let src = r###"let s = r##"inner "# quote"## ; done"###;
        let t = toks(src);
        assert_eq!(
            t,
            vec![
                id("let"),
                id("s"),
                Tok::Punct('='),
                Tok::Literal,
                Tok::Punct(';'),
                id("done"),
            ]
        );
    }
}

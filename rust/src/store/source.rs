//! Record source: where a store's compressed container bytes live.
//!
//! [`crate::store::ModelStore`] used to hold the whole serialized
//! container in an eagerly-loaded `Vec<u8>`. For a sharded serving tier
//! that is waste twice over: every shard store pays resident memory for
//! records it never decodes, and startup reads the full file front to
//! back. A [`RecordSource`] abstracts "bytes the record reader can
//! slice":
//!
//! * **Owned bytes** — the in-memory path (`open_bytes`, tests,
//!   benches). Always available.
//! * **Memory-mapped file** (`mmap` feature, unix) —
//!   [`RecordSource::open`] maps the container read-only and the OS
//!   pages in only the records decode actually touches, which for one
//!   shard is just its own slice of the layer index.
//!
//! The mapping is implemented against raw `mmap(2)`/`munmap(2)` with a
//! local extern declaration (no external crate, so the build stays
//! fully offline). The extern signature assumes LP64 (`off_t` = `i64`),
//! so the mapped path is additionally gated on
//! `target_pointer_width = "64"`; builds without the feature, non-unix
//! targets, and non-LP64 targets all transparently fall back to reading
//! the file into owned bytes — nothing above this module ever branches
//! on the feature.

use anyhow::{Context, Result};
use std::path::Path;

/// Container bytes behind a uniform read-only slice view.
pub struct RecordSource(Repr);

enum Repr {
    Bytes(Vec<u8>),
    #[cfg(all(unix, target_pointer_width = "64", feature = "mmap"))]
    Mapped(mapped::MmapRegion),
}

impl RecordSource {
    /// Wrap owned in-memory bytes.
    pub fn from_bytes(bytes: Vec<u8>) -> Self {
        RecordSource(Repr::Bytes(bytes))
    }

    /// Open a file: memory-mapped when the `mmap` feature is enabled on
    /// unix; otherwise (or for empty files, or if the mapping fails)
    /// read eagerly into owned bytes.
    pub fn open(path: &Path) -> Result<Self> {
        #[cfg(all(unix, target_pointer_width = "64", feature = "mmap"))]
        if let Ok(Some(region)) = mapped::MmapRegion::map_file(path) {
            return Ok(RecordSource(Repr::Mapped(region)));
        }
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Ok(RecordSource(Repr::Bytes(bytes)))
    }

    /// The full byte view (record readers slice into this).
    pub fn as_slice(&self) -> &[u8] {
        match &self.0 {
            Repr::Bytes(b) => b,
            #[cfg(all(unix, target_pointer_width = "64", feature = "mmap"))]
            Repr::Mapped(m) => m.as_slice(),
        }
    }

    /// Length in bytes.
    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    /// True when no bytes are held.
    pub fn is_empty(&self) -> bool {
        self.as_slice().is_empty()
    }

    /// True when the bytes are a live file mapping (paged in on demand)
    /// rather than an owned in-memory copy.
    pub fn is_mapped(&self) -> bool {
        match &self.0 {
            Repr::Bytes(_) => false,
            #[cfg(all(unix, target_pointer_width = "64", feature = "mmap"))]
            Repr::Mapped(_) => true,
        }
    }
}

#[cfg(all(unix, target_pointer_width = "64", feature = "mmap"))]
mod mapped {
    use anyhow::{bail, Result};
    use std::os::unix::io::AsRawFd;
    use std::path::Path;
    use std::ptr::NonNull;

    /// Minimal libc surface, declared locally so no crate is needed.
    mod sys {
        use std::os::raw::{c_int, c_void};

        pub const PROT_READ: c_int = 1;
        pub const MAP_PRIVATE: c_int = 2;

        extern "C" {
            /// `off_t` declared as `i64`: correct on every LP64 unix
            /// target this crate builds for.
            pub fn mmap(
                addr: *mut c_void,
                len: usize,
                prot: c_int,
                flags: c_int,
                fd: c_int,
                offset: i64,
            ) -> *mut c_void;
            pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        }
    }

    /// A read-only private mapping of a whole file.
    ///
    /// The backing file must not be truncated while the region is alive
    /// (the usual mmap caveat: reads through a shrunk mapping fault).
    /// Model containers are immutable artifacts, so the store's
    /// contract — open, serve, drop — never rewrites them in place.
    pub struct MmapRegion {
        ptr: NonNull<u8>,
        len: usize,
    }

    // SAFETY: the region is `PROT_READ`/`MAP_PRIVATE` and never written
    // through for its whole lifetime, so shared references may cross
    // threads freely; the pointer is exclusively owned until `Drop`.
    unsafe impl Send for MmapRegion {}
    // SAFETY: read-only for its whole lifetime (see `Send` above), so
    // concurrent shared reads through `&MmapRegion` never race a write.
    unsafe impl Sync for MmapRegion {}

    impl MmapRegion {
        /// Map `path` read-only. Returns `Ok(None)` for an empty file
        /// (zero-length mappings are invalid; the caller keeps owned
        /// empty bytes instead).
        pub fn map_file(path: &Path) -> Result<Option<MmapRegion>> {
            let file = std::fs::File::open(path)?;
            let len = usize::try_from(file.metadata()?.len())?;
            if len == 0 {
                return Ok(None);
            }
            // SAFETY: a fresh read-only private mapping of `len` bytes
            // of an open fd; the fd may close right after — the mapping
            // stays valid until `munmap`.
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            // MAP_FAILED is (void*)-1.
            if ptr as usize == usize::MAX {
                bail!("mmap of {} failed", path.display());
            }
            let Some(ptr) = NonNull::new(ptr as *mut u8) else {
                bail!("mmap of {} returned null", path.display());
            };
            Ok(Some(MmapRegion { ptr, len }))
        }

        /// The mapped bytes.
        pub fn as_slice(&self) -> &[u8] {
            // SAFETY: `ptr` is a live `len`-byte read-only mapping for
            // as long as `self` exists.
            unsafe {
                std::slice::from_raw_parts(self.ptr.as_ptr(), self.len)
            }
        }
    }

    impl Drop for MmapRegion {
        fn drop(&mut self) {
            // SAFETY: unmapping the exact region this value mapped;
            // no slice of it can outlive `self` (lifetime-tied).
            unsafe {
                let _ = sys::munmap(
                    self.ptr.as_ptr().cast(),
                    self.len,
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_file(tag: &str, bytes: &[u8]) -> std::path::PathBuf {
        let path = std::env::temp_dir()
            .join(format!("f2f-source-{tag}-{}", std::process::id()));
        std::fs::write(&path, bytes).expect("write temp file");
        path
    }

    #[test]
    fn owned_bytes_view() {
        let s = RecordSource::from_bytes(vec![1, 2, 3]);
        assert_eq!(s.as_slice(), &[1, 2, 3]);
        assert_eq!(s.len(), 3);
        assert!(!s.is_empty());
        assert!(!s.is_mapped());
    }

    #[test]
    fn open_reads_file_contents() {
        let want: Vec<u8> = (0..200u8).collect();
        let path = temp_file("contents", &want);
        let s = RecordSource::open(&path).unwrap();
        assert_eq!(s.as_slice(), &want[..]);
        #[cfg(all(unix, target_pointer_width = "64", feature = "mmap"))]
        assert!(s.is_mapped(), "unix + mmap feature must map files");
        drop(s);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn empty_file_falls_back_to_owned_bytes() {
        let path = temp_file("empty", &[]);
        let s = RecordSource::open(&path).unwrap();
        assert!(s.is_empty());
        assert!(!s.is_mapped(), "zero-length files cannot be mapped");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_file_is_an_error() {
        let path = std::env::temp_dir().join("f2f-source-missing-nope");
        assert!(RecordSource::open(&path).is_err());
    }
}

//! Readahead policy: warm layer `i+1` while layer `i`'s GEMV runs.
//!
//! The paper's fixed-to-fixed format exists so irregular-sparsity
//! weights decode through a highly regular, parallel structure; a
//! serving path that only decodes layer `i+1` *after* layer `i`'s GEMV
//! finishes serializes that parallelism away. The policy here is the
//! scheduling half of the fix: while layer `i` executes, the layers it
//! names are warmed asynchronously through
//! [`ModelStore::prefetch_async`](super::ModelStore::prefetch_async),
//! which dedups against in-flight decodes and skips layers that cannot
//! fit in the budget alongside the pinned working set.

use anyhow::anyhow;

/// How far ahead of the executing layer the store should warm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReadaheadPolicy {
    /// Number of layers ahead to warm (0 = readahead off).
    pub depth: usize,
}

impl Default for ReadaheadPolicy {
    /// Warm one layer ahead — decode of `i+1` overlaps `i`'s GEMV.
    fn default() -> Self {
        ReadaheadPolicy::layers(1)
    }
}

impl ReadaheadPolicy {
    /// Readahead disabled: decode strictly on miss.
    pub fn off() -> Self {
        ReadaheadPolicy { depth: 0 }
    }

    /// Warm `depth` layers ahead of the executing one.
    pub fn layers(depth: usize) -> Self {
        ReadaheadPolicy { depth }
    }

    /// True when any readahead is issued.
    pub fn enabled(&self) -> bool {
        self.depth > 0
    }

    /// Chain indices to warm when layer `i` of a `len`-layer chain
    /// starts executing. Wraps at the chain end so the next request's
    /// first layers warm during the tail of this one; never names `i`
    /// itself (depth is clamped to `len - 1`).
    pub fn targets(self, i: usize, len: usize) -> impl Iterator<Item = usize> {
        let depth = if len == 0 { 0 } else { self.depth.min(len - 1) };
        (1..=depth).map(move |d| (i + d) % len)
    }
}

impl std::str::FromStr for ReadaheadPolicy {
    type Err = anyhow::Error;

    /// Parse the CLI form: `on` (depth 1), `off`, or a depth number.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "on" => Ok(ReadaheadPolicy::layers(1)),
            "off" => Ok(ReadaheadPolicy::off()),
            n => n.parse::<usize>().map(ReadaheadPolicy::layers).map_err(
                |_| anyhow!("--readahead: expected on|off|<depth>, got {n:?}"),
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn targets(p: ReadaheadPolicy, i: usize, len: usize) -> Vec<usize> {
        p.targets(i, len).collect()
    }

    #[test]
    fn depth_one_warms_next_and_wraps() {
        let p = ReadaheadPolicy::default();
        assert_eq!(p.depth, 1);
        assert!(p.enabled());
        assert_eq!(targets(p, 0, 4), vec![1]);
        assert_eq!(targets(p, 2, 4), vec![3]);
        assert_eq!(targets(p, 3, 4), vec![0], "wraps at the chain end");
    }

    #[test]
    fn off_names_nothing() {
        let p = ReadaheadPolicy::off();
        assert!(!p.enabled());
        assert_eq!(targets(p, 0, 4), Vec::<usize>::new());
    }

    #[test]
    fn deep_readahead_clamps_to_chain() {
        let p = ReadaheadPolicy::layers(2);
        assert_eq!(targets(p, 1, 4), vec![2, 3]);
        assert_eq!(targets(p, 3, 4), vec![0, 1]);
        // Depth beyond the chain never names the executing layer.
        let p = ReadaheadPolicy::layers(10);
        assert_eq!(targets(p, 1, 3), vec![2, 0]);
        // Degenerate chains.
        assert_eq!(targets(p, 0, 1), Vec::<usize>::new());
        assert_eq!(targets(p, 0, 0), Vec::<usize>::new());
    }

    #[test]
    fn parses_cli_forms() {
        assert_eq!(
            "on".parse::<ReadaheadPolicy>().unwrap(),
            ReadaheadPolicy::layers(1)
        );
        assert_eq!(
            "off".parse::<ReadaheadPolicy>().unwrap(),
            ReadaheadPolicy::off()
        );
        assert_eq!(
            "3".parse::<ReadaheadPolicy>().unwrap(),
            ReadaheadPolicy::layers(3)
        );
        assert!("sideways".parse::<ReadaheadPolicy>().is_err());
    }
}

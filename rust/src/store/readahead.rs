//! Readahead planning: which layers to warm while layer `i` executes.
//!
//! The paper's fixed-to-fixed format exists so irregular-sparsity
//! weights decode through a highly regular, parallel structure; a
//! serving path that only decodes layer `i+1` *after* layer `i`'s GEMV
//! finishes serializes that parallelism away. The policy here is the
//! scheduling half of the fix: while layer `i` executes, the layers it
//! names are warmed asynchronously through
//! [`ModelStore::prefetch_async`](super::ModelStore::prefetch_async),
//! which dedups against in-flight decodes and skips layers that cannot
//! fit in the budget alongside the pinned working set.
//!
//! Two policies exist:
//!
//! * [`ReadaheadPolicy::Fixed`] — warm a constant number of layers
//!   ahead (0 = off). Simple, predictable, and blind: a depth that
//!   overlaps perfectly on one layer stalls or over-warms on another,
//!   because decode time varies with mask density and correction count
//!   while the GEMV window varies with geometry and batch size.
//! * [`ReadaheadPolicy::Auto`] — a cost-model-driven planner. Per
//!   executing layer it picks the largest depth `k` whose *predicted*
//!   cumulative decode cost (EWMA, [`super::LayerCosts`]) fits inside
//!   the layer's *predicted* GEMV window and whose decoded bytes fit
//!   the owning store's budget, falling back to depth-1 until the
//!   estimates warm. Warming deeper than the window can hide wastes
//!   decode workers; shallower leaves stalls — `Auto` tracks the
//!   crossover per layer, per batch size, as the EWMAs drift.
//!
//! The planner decides *how deep*; admission control in the store
//! (budget + pinned set + in-flight dedup) remains the final
//! gatekeeper, so a plan can only ever warm, never evict the working
//! set. Both halves of that decision are traced: the forward chain
//! records a `readahead_plan` instant when it issues a plan, and the
//! store records a `readahead_skip` instant when admission declines a
//! warm (see [`crate::obs::SpanKind`]) — so a trace shows not just
//! what was warmed but what the planner *tried* and lost to budget.

use anyhow::anyhow;

/// Default depth ceiling for [`ReadaheadPolicy::Auto`]: even a fully
/// warmed cost model never plans past this many layers ahead (decode
/// parallelism flattens and deep warms mostly fight the LRU).
pub const DEFAULT_AUTO_MAX_DEPTH: usize = 4;

/// How far ahead of the executing layer the store should warm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadaheadPolicy {
    /// Warm a fixed number of layers ahead (0 = readahead off).
    Fixed(usize),
    /// Plan depth per layer from observed costs, at most `max_depth`.
    Auto {
        /// Hard ceiling on the planned depth.
        max_depth: usize,
    },
}

/// One readahead candidate as the [`ReadaheadPolicy::Auto`] planner
/// sees it, in distance order (`candidates[d-1]` is the layer `d`
/// ahead of the executing one).
#[derive(Debug, Clone, Copy)]
pub struct ReadaheadCandidate {
    /// Predicted decode cost in ns: `None` until the EWMA has a sample
    /// (an already-cached target is `Some(0.0)` — warming it is free).
    pub decode_ns: Option<f64>,
    /// Whether the target's decoded bytes fit its store's budget on
    /// top of what the plan has already committed.
    pub fits_budget: bool,
}

impl Default for ReadaheadPolicy {
    /// Warm one layer ahead — decode of `i+1` overlaps `i`'s GEMV.
    fn default() -> Self {
        ReadaheadPolicy::layers(1)
    }
}

impl ReadaheadPolicy {
    /// Readahead disabled: decode strictly on miss.
    pub fn off() -> Self {
        ReadaheadPolicy::Fixed(0)
    }

    /// Warm `depth` layers ahead of the executing one.
    pub fn layers(depth: usize) -> Self {
        ReadaheadPolicy::Fixed(depth)
    }

    /// Cost-model-driven depth with the default ceiling.
    pub fn auto() -> Self {
        ReadaheadPolicy::Auto { max_depth: DEFAULT_AUTO_MAX_DEPTH }
    }

    /// True when any readahead may be issued (`Auto` with a zero
    /// ceiling is just as off as `Fixed(0)`).
    pub fn enabled(&self) -> bool {
        self.max_depth() > 0
    }

    /// True for the cost-model-driven planner.
    pub fn is_auto(&self) -> bool {
        matches!(self, ReadaheadPolicy::Auto { .. })
    }

    /// The deepest warm this policy can ever issue.
    pub fn max_depth(&self) -> usize {
        match *self {
            ReadaheadPolicy::Fixed(depth) => depth,
            ReadaheadPolicy::Auto { max_depth } => max_depth,
        }
    }

    /// Decide the warm depth for one executing layer.
    ///
    /// `gemv_window_ns` is the predicted GEMV time of the executing
    /// layer over the whole batch (`None` until its EWMA warms);
    /// `candidates[d-1]` describes the layer `d` ahead. `Fixed` ignores
    /// the inputs and returns its depth (clamped to the candidate
    /// count); `Auto` extends the plan while the cumulative predicted
    /// decode cost stays inside the window and each target fits its
    /// budget, stopping at the first unwarmed target — and never
    /// returns less than 1 (the depth-1 fallback keeps the pipeline's
    /// floor behavior identical to `Fixed(1)` while estimates warm).
    pub fn plan(
        &self,
        gemv_window_ns: Option<f64>,
        candidates: &[ReadaheadCandidate],
    ) -> usize {
        match *self {
            ReadaheadPolicy::Fixed(depth) => depth.min(candidates.len()),
            ReadaheadPolicy::Auto { max_depth } => {
                let cap = max_depth.min(candidates.len());
                if cap == 0 {
                    return 0;
                }
                let Some(window) = gemv_window_ns else {
                    return 1; // executing layer unwarmed: floor depth
                };
                let mut spent = 0.0f64;
                let mut k = 0;
                for c in &candidates[..cap] {
                    let Some(cost) = c.decode_ns else { break };
                    if !c.fits_budget || spent + cost > window {
                        break;
                    }
                    spent += cost;
                    k += 1;
                }
                k.max(1)
            }
        }
    }
}

/// Chain indices `1..=depth` ahead of layer `i` in a `len`-layer
/// chain, wrapping at the chain end so the next request's first layers
/// warm during the tail of this one; never names `i` itself (depth is
/// clamped to `len - 1`).
pub(crate) fn wrapped_targets(
    i: usize,
    len: usize,
    depth: usize,
) -> impl Iterator<Item = usize> {
    let depth = if len == 0 { 0 } else { depth.min(len - 1) };
    (1..=depth).map(move |d| (i + d) % len)
}

impl std::str::FromStr for ReadaheadPolicy {
    type Err = anyhow::Error;

    /// Parse the CLI form: `on` (depth 1), `off`, a fixed depth
    /// number, or `auto` (cost-model planner).
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "on" => Ok(ReadaheadPolicy::layers(1)),
            "off" => Ok(ReadaheadPolicy::off()),
            "auto" => Ok(ReadaheadPolicy::auto()),
            n => n.parse::<usize>().map(ReadaheadPolicy::layers).map_err(
                |_| {
                    anyhow!(
                        "--readahead: expected on|off|<depth>|auto, \
                         got {n:?}"
                    )
                },
            ),
        }
    }
}

impl std::fmt::Display for ReadaheadPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            ReadaheadPolicy::Fixed(0) => write!(f, "off"),
            ReadaheadPolicy::Fixed(depth) => write!(f, "{depth}"),
            ReadaheadPolicy::Auto { max_depth } => {
                write!(f, "auto(<={max_depth})")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn targets(i: usize, len: usize, depth: usize) -> Vec<usize> {
        wrapped_targets(i, len, depth).collect()
    }

    fn warm(decode_ns: f64) -> ReadaheadCandidate {
        ReadaheadCandidate { decode_ns: Some(decode_ns), fits_budget: true }
    }

    fn cold() -> ReadaheadCandidate {
        ReadaheadCandidate { decode_ns: None, fits_budget: true }
    }

    #[test]
    fn depth_one_warms_next_and_wraps() {
        let p = ReadaheadPolicy::default();
        assert_eq!(p, ReadaheadPolicy::Fixed(1));
        assert!(p.enabled());
        assert!(!p.is_auto());
        assert_eq!(targets(0, 4, p.max_depth()), vec![1]);
        assert_eq!(targets(2, 4, p.max_depth()), vec![3]);
        assert_eq!(targets(3, 4, p.max_depth()), vec![0], "wraps at end");
    }

    #[test]
    fn off_names_nothing() {
        let p = ReadaheadPolicy::off();
        assert!(!p.enabled());
        assert_eq!(p.max_depth(), 0);
        assert_eq!(p.plan(Some(1e9), &[warm(1.0)]), 0);
        assert_eq!(targets(0, 4, 0), Vec::<usize>::new());
    }

    #[test]
    fn deep_readahead_clamps_to_chain() {
        assert_eq!(targets(1, 4, 2), vec![2, 3]);
        assert_eq!(targets(3, 4, 2), vec![0, 1]);
        // Depth beyond the chain never names the executing layer.
        assert_eq!(targets(1, 3, 10), vec![2, 0]);
        // Degenerate chains.
        assert_eq!(targets(0, 1, 10), Vec::<usize>::new());
        assert_eq!(targets(0, 0, 10), Vec::<usize>::new());
    }

    #[test]
    fn fixed_plan_ignores_costs() {
        let p = ReadaheadPolicy::layers(2);
        assert_eq!(p.plan(None, &[cold(), cold(), cold()]), 2);
        assert_eq!(p.plan(Some(0.0), &[warm(1e12)]), 1, "clamps to len");
    }

    #[test]
    fn auto_falls_back_to_depth_one_until_warm() {
        let p = ReadaheadPolicy::auto();
        assert!(p.is_auto() && p.enabled());
        // Executing layer's window unknown: floor depth 1.
        assert_eq!(p.plan(None, &[warm(10.0), warm(10.0)]), 1);
        // First target unwarmed: still floor depth 1.
        assert_eq!(p.plan(Some(100.0), &[cold(), warm(1.0)]), 1);
        // No candidates at all (single-layer chain): nothing to warm.
        assert_eq!(p.plan(Some(100.0), &[]), 0);
    }

    #[test]
    fn auto_extends_while_decode_fits_the_window() {
        let p = ReadaheadPolicy::auto();
        // Window 100ns, decodes 40+40+40: third overflows.
        let c = [warm(40.0), warm(40.0), warm(40.0)];
        assert_eq!(p.plan(Some(100.0), &c), 2);
        // A roomier window takes all three.
        assert_eq!(p.plan(Some(1000.0), &c), 3);
        // A tiny window still floors at 1 (Fixed(1) parity).
        assert_eq!(p.plan(Some(1.0), &c), 1);
        // An unwarmed target stops the extension, not the floor.
        let c = [warm(40.0), cold(), warm(40.0)];
        assert_eq!(p.plan(Some(1000.0), &c), 1);
        // Already-cached targets report 0ns and extend for free.
        let c = [warm(0.0), warm(0.0), warm(90.0)];
        assert_eq!(p.plan(Some(100.0), &c), 3);
    }

    #[test]
    fn auto_respects_budget_and_max_depth() {
        let p = ReadaheadPolicy::Auto { max_depth: 2 };
        let over = ReadaheadCandidate {
            decode_ns: Some(1.0),
            fits_budget: false,
        };
        // Budget-blocked target stops the extension.
        assert_eq!(p.plan(Some(1e9), &[warm(1.0), over, warm(1.0)]), 1);
        // max_depth caps even when everything fits.
        assert_eq!(
            p.plan(Some(1e9), &[warm(1.0), warm(1.0), warm(1.0)]),
            2
        );
        assert_eq!(p.max_depth(), 2);
        // A zero ceiling is as off as Fixed(0).
        let zero = ReadaheadPolicy::Auto { max_depth: 0 };
        assert!(!zero.enabled());
        assert_eq!(zero.plan(Some(1e9), &[warm(1.0)]), 0);
    }

    #[test]
    fn parses_cli_forms() {
        assert_eq!(
            "on".parse::<ReadaheadPolicy>().unwrap(),
            ReadaheadPolicy::layers(1)
        );
        assert_eq!(
            "off".parse::<ReadaheadPolicy>().unwrap(),
            ReadaheadPolicy::off()
        );
        assert_eq!(
            "3".parse::<ReadaheadPolicy>().unwrap(),
            ReadaheadPolicy::layers(3)
        );
        assert_eq!(
            "auto".parse::<ReadaheadPolicy>().unwrap(),
            ReadaheadPolicy::Auto { max_depth: DEFAULT_AUTO_MAX_DEPTH }
        );
        assert!("sideways".parse::<ReadaheadPolicy>().is_err());
    }

    #[test]
    fn displays_cli_round_trip_forms() {
        assert_eq!(ReadaheadPolicy::off().to_string(), "off");
        assert_eq!(ReadaheadPolicy::layers(3).to_string(), "3");
        assert_eq!(
            ReadaheadPolicy::auto().to_string(),
            format!("auto(<={DEFAULT_AUTO_MAX_DEPTH})")
        );
    }
}

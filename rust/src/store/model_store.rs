//! Byte-budgeted model store: decode-on-miss, evict-cold, warm-ahead.
//!
//! Holds a compressed model (ideally an indexed v2 container, so a miss
//! parses exactly one layer record) plus an LRU cache of decoded layers
//! bounded by `cache_budget_bytes` of dense f32 weights. Models whose
//! decoded size exceeds the budget still serve: a miss decodes through
//! the persistent [`DecodeService`], installs, and evicts the coldest
//! layers until the budget holds again.
//!
//! The store is a concurrent subsystem, not just a cache:
//!
//! * **In-flight dedup** — every decode is registered before it starts;
//!   a `get` racing a readahead (or another `get`) for the same layer
//!   waits on the registered decode instead of starting a second one,
//!   so `redundant_decodes` stays 0 by construction.
//! * **Async readahead** — [`ModelStore::prefetch_async`] queues a
//!   decode on the background service and returns immediately; the
//!   finishing worker installs the layer into the cache. This is how
//!   layer `i+1` decodes while layer `i`'s GEMV runs.
//! * **Pin-while-executing** — [`ModelStore::get_pinned`] returns a
//!   [`PinnedLayer`] guard; pinned entries are never chosen as eviction
//!   victims, so a readahead install can never evict the layer that is
//!   currently executing its GEMV. `prefetch_async` also declines
//!   layers that cannot fit in the budget alongside the pinned working
//!   set (`readahead_skips`).
//! * **Parse off the hot path** — the compressed-record parse of a miss
//!   or readahead runs as the decode task's first worker job
//!   ([`DecodeService::decode_parse_then`]), so the serving thread pays
//!   one queue push per warm, independent of record size.
//! * **Record source** — the container bytes sit behind a
//!   [`RecordSource`]: owned bytes ([`ModelStore::open_bytes`]) or a
//!   read-only mmap ([`ModelStore::open_path`], `mmap` feature), under
//!   which only the records this store decodes are ever paged in — the
//!   substrate for running one shard of a split model per store.

use super::pool::{DecodeOutcome, DecodeService};
use super::source::RecordSource;
use super::timing::{LayerCost, LayerCosts};
use crate::obs::{self, HdrLite};
use crate::container::{
    read_container, read_layer_at, CompressedLayer, Container,
    ContainerIndex,
};
use crate::kernels::{DecodeMode, ExecLayer};
use crate::sync::{lock_unpoisoned, wait_unpoisoned};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};

/// Path of the cost-profile sidecar auto-loaded next to a container:
/// `<container>.costs.json`. Written by `f2f serve --profile-out`
/// (which defaults to this path) and read back by
/// [`ModelStore::open_path`], so a restarted store — or a spawned
/// shard worker — starts with a warm readahead planner instead of the
/// depth-1 fallback.
pub fn cost_sidecar_path(container: &Path) -> PathBuf {
    let mut os = container.as_os_str().to_os_string();
    os.push(".costs.json");
    PathBuf::from(os)
}

/// Store knobs.
#[derive(Debug, Clone, Copy)]
pub struct StoreConfig {
    /// Decoded-weight cache budget in bytes (`usize::MAX` = unbounded).
    pub cache_budget_bytes: usize,
    /// Persistent decode-service worker threads (0 = size to the host).
    pub decode_workers: usize,
    /// Representation decoded layers take in cache: dense f32
    /// (`Materialized`), bit-plane resident (`Fused`), or per-layer
    /// whichever is smaller (`Auto`). Everything byte-budgeted —
    /// admission, install, eviction, readahead planning — prices
    /// layers under this mode.
    pub decode_mode: DecodeMode,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig {
            cache_budget_bytes: usize::MAX,
            decode_workers: 0,
            decode_mode: DecodeMode::Materialized,
        }
    }
}

/// Cache / decode counters (monotonic since open).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreMetrics {
    /// `get`/`prefetch` calls served from cache.
    pub hits: u64,
    /// `get`/`prefetch` calls that could not be served from cache
    /// (waiting on an in-flight decode also counts as a miss).
    pub misses: u64,
    /// Layers decoded and installed into the cache.
    pub decodes: u64,
    /// Layers evicted to respect the budget.
    pub evictions: u64,
    /// Async readahead decodes issued via `prefetch_async`.
    pub prefetches: u64,
    /// Decodes whose result was discarded because the layer was already
    /// cached when they finished. In-flight dedup keeps this at 0.
    pub redundant_decodes: u64,
    /// Readaheads declined because the layer cannot fit in the budget
    /// alongside the currently pinned working set.
    pub readahead_skips: u64,
    /// Decoded bytes currently cached.
    pub cached_bytes: usize,
    /// Layers currently cached.
    pub cached_layers: usize,
    /// Decoded bytes currently pinned by executing layers.
    pub pinned_bytes: usize,
    /// Total wall nanoseconds spent decoding (submit→install), summed
    /// over every completed decode (see [`LayerCosts`]).
    pub decode_ns_total: u64,
    /// Total wall nanoseconds of GEMV phases recorded against this
    /// store's layers by the forward chain.
    pub gemv_ns_total: u64,
    /// Distribution of decode submit→install wall times (every sample
    /// behind `decode_ns_total`, log-bucketed and mergeable).
    pub decode_hist: HdrLite,
    /// Distribution of per-layer GEMV phase wall times.
    pub gemv_hist: HdrLite,
}

impl StoreMetrics {
    /// Accumulate another store's counters into this snapshot — how a
    /// [`crate::shard::ShardRouter`] folds its per-shard stores into
    /// one aggregate view.
    pub fn merge(&mut self, other: &StoreMetrics) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.decodes += other.decodes;
        self.evictions += other.evictions;
        self.prefetches += other.prefetches;
        self.redundant_decodes += other.redundant_decodes;
        self.readahead_skips += other.readahead_skips;
        self.cached_bytes += other.cached_bytes;
        self.cached_layers += other.cached_layers;
        self.pinned_bytes += other.pinned_bytes;
        self.decode_ns_total += other.decode_ns_total;
        self.gemv_ns_total += other.gemv_ns_total;
        self.decode_hist.merge(&other.decode_hist);
        self.gemv_hist.merge(&other.gemv_hist);
    }
}

/// Where the compressed records come from.
enum Source {
    /// Indexed v2 bytes behind a [`RecordSource`] (owned bytes or a
    /// read-only mmap): a miss parses exactly one layer record, and
    /// under an mmap only the touched records ever page in.
    Indexed { source: RecordSource, index: ContainerIndex },
    /// Pre-parsed layers (v1 files or in-memory containers), shared
    /// with decode jobs by refcount rather than deep copy.
    Parsed { layers: Vec<Arc<CompressedLayer>> },
}

struct CacheEntry {
    layer: Arc<ExecLayer>,
    bytes: usize,
    last_used: u64,
    /// Active [`PinnedLayer`] guards; a pinned entry is never evicted.
    pins: usize,
}

/// A decode that has been registered but not yet installed. Waiters
/// block on the condvar; the installing worker completes it with a
/// [`DecodeOutcome`] (errors travel as strings so every waiter shares
/// them — `anyhow::Error` is not `Clone`).
#[derive(Default)]
struct InFlight {
    done: Mutex<Option<DecodeOutcome>>,
    cv: Condvar,
}

impl InFlight {
    fn complete(&self, result: DecodeOutcome) {
        *lock_unpoisoned(&self.done) = Some(result);
        self.cv.notify_all();
    }

    fn wait(&self) -> DecodeOutcome {
        let mut done = lock_unpoisoned(&self.done);
        loop {
            if let Some(r) = done.as_ref() {
                return r.clone();
            }
            done = wait_unpoisoned(&self.cv, done);
        }
    }
}

#[derive(Default)]
struct CacheState {
    entries: HashMap<String, CacheEntry>,
    in_flight: HashMap<String, Arc<InFlight>>,
    clock: u64,
    cached_bytes: usize,
    pinned_bytes: usize,
    /// Decoded bytes of registered-but-uninstalled decodes; readahead
    /// admission counts these so depth ≥ 2 warms cannot be admitted
    /// past the budget and evict each other before use.
    in_flight_bytes: usize,
    hits: u64,
    misses: u64,
    decodes: u64,
    evictions: u64,
    prefetches: u64,
    redundant_decodes: u64,
    readahead_skips: u64,
}

impl CacheState {
    /// Debug-build audit of the cache's core invariants, run after
    /// every mutation under the state lock: the byte counters must
    /// equal what the entries actually hold. Compiled out of release
    /// builds.
    #[cfg(debug_assertions)]
    fn check_invariants(&self) {
        let cached: usize = self.entries.values().map(|e| e.bytes).sum();
        debug_assert_eq!(
            self.cached_bytes, cached,
            "cached_bytes diverged from the sum of resident entries"
        );
        let pinned: usize = self
            .entries
            .values()
            .filter(|e| e.pins > 0)
            .map(|e| e.bytes)
            .sum();
        debug_assert_eq!(
            self.pinned_bytes, pinned,
            "pinned_bytes diverged from the sum of pinned entries"
        );
    }

    #[cfg(not(debug_assertions))]
    fn check_invariants(&self) {}
}

/// Shared core: the compressed source plus the cache state. Completion
/// callbacks running on decode workers hold their own `Arc` of this, so
/// installs outlive any particular caller.
struct StoreInner {
    source: Source,
    budget: usize,
    /// Representation decoded layers take (see [`StoreConfig`]).
    mode: DecodeMode,
    state: Mutex<CacheState>,
    /// Per-layer timing telemetry: decode EWMA stamped on install (the
    /// worker-side callback), GEMV EWMA stamped by the forward chain.
    costs: LayerCosts,
    /// Signalled whenever an in-flight registration is removed, so
    /// [`ModelStore::wait_for_idle`] can block instead of polling.
    idle: Condvar,
}

impl StoreInner {
    /// Parse (or refcount-share) the compressed record for `name`.
    /// Runs on a decode worker (the parse stage of
    /// [`ModelStore::start_decode`]), never on the serving thread.
    fn compressed_layer(&self, name: &str) -> Result<Arc<CompressedLayer>> {
        match &self.source {
            Source::Indexed { source, index } => {
                let Some(entry) = index.find(name) else {
                    bail!("layer {name:?} not in container index");
                };
                read_layer_at(source.as_slice(), entry).map(Arc::new)
            }
            Source::Parsed { layers } => {
                let Some(compressed) =
                    layers.iter().find(|l| l.name == name)
                else {
                    bail!("layer {name:?} not in container");
                };
                Ok(compressed.clone())
            }
        }
    }

    /// Decoded (dense f32) size of a layer, from the index only.
    fn layer_decoded_bytes(&self, name: &str) -> Option<usize> {
        match &self.source {
            Source::Indexed { index, .. } => {
                index.find(name).map(|e| e.decoded_bytes())
            }
            Source::Parsed { layers } => layers
                .iter()
                .find(|l| l.name == name)
                .map(|l| l.n_weights() * std::mem::size_of::<f32>()),
        }
    }

    /// Resident bytes the layer will charge the cache budget under this
    /// store's decode mode — what admission must reserve before the
    /// decode runs, and what [`ExecLayer::planned_bytes`] reports after.
    fn layer_planned_bytes(&self, name: &str) -> Option<usize> {
        match &self.source {
            Source::Indexed { index, .. } => index.find(name).map(|e| {
                self.mode.planned_bytes(e.rows, e.cols, e.dtype.bits())
            }),
            Source::Parsed { layers } => {
                layers.iter().find(|l| l.name == name).map(|l| {
                    self.mode.planned_bytes(l.rows, l.cols, l.dtype.bits())
                })
            }
        }
    }

    /// Install a finished decode, then release its waiters. Runs on the
    /// decode worker that finished the layer's last plane.
    fn install(
        &self,
        name: &str,
        decoded: Arc<ExecLayer>,
        flight: &InFlight,
    ) {
        let bytes = decoded.planned_bytes();
        let result = {
            let mut guard = lock_unpoisoned(&self.state);
            let st = &mut *guard;
            st.clock += 1;
            let clock = st.clock;
            if st.in_flight.remove(name).is_some() {
                st.in_flight_bytes =
                    st.in_flight_bytes.saturating_sub(bytes);
            }
            let installed = if let Some(e) = st.entries.get_mut(name) {
                // Someone installed this layer while we decoded. With
                // in-flight dedup this path is unreachable; count it so
                // a regression is visible in metrics.
                e.last_used = clock;
                st.redundant_decodes += 1;
                e.layer.clone()
            } else {
                st.decodes += 1;
                st.cached_bytes += bytes;
                st.entries.insert(
                    name.to_string(),
                    CacheEntry {
                        layer: decoded.clone(),
                        bytes,
                        last_used: clock,
                        pins: 0,
                    },
                );
                self.evict_over_budget(st, Some(name));
                decoded
            };
            st.check_invariants();
            installed
        };
        self.idle.notify_all();
        flight.complete(Ok(result));
    }

    /// A decode failed (unparseable record, or a worker job panicked on
    /// malformed data): release every waiter with the error and clear
    /// the registration so a later fetch can retry from scratch.
    fn abort(&self, name: &str, msg: String, flight: &InFlight) {
        {
            let mut guard = lock_unpoisoned(&self.state);
            let st = &mut *guard;
            if st.in_flight.remove(name).is_some() {
                let need = self.layer_planned_bytes(name).unwrap_or(0);
                st.in_flight_bytes =
                    st.in_flight_bytes.saturating_sub(need);
            }
            st.check_invariants();
        }
        self.idle.notify_all();
        flight.complete(Err(msg));
    }

    /// Evict least-recently-used entries until the budget holds. The
    /// just-inserted `keep` layer (if any), all pinned layers, and the
    /// last remaining entry are never evicted — a single layer bigger
    /// than the whole budget must still serve (and stay resident
    /// between batches, not re-decode every pass), and a layer mid-GEMV
    /// must never vanish under readahead install pressure.
    fn evict_over_budget(&self, st: &mut CacheState, keep: Option<&str>) {
        while st.cached_bytes > self.budget && st.entries.len() > 1 {
            let victim = st
                .entries
                .iter()
                .filter(|(n, e)| {
                    Some(n.as_str()) != keep && e.pins == 0
                })
                .min_by_key(|(_, e)| e.last_used)
                .map(|(n, _)| n.clone());
            let Some(victim) = victim else { break };
            if let Some(e) = st.entries.remove(&victim) {
                debug_assert_eq!(
                    e.pins, 0,
                    "evicted {victim:?} while it was pinned"
                );
                st.cached_bytes -= e.bytes;
                st.evictions += 1;
                obs::event(obs::SpanKind::Evict, &victim);
                // Ops-plane visibility: evictions under pressure are
                // exactly what `f2f top` watchers grep for. The
                // journal's rate limiter bounds the cost under churn.
                obs::events::info(
                    "evict",
                    &format!("evicted layer {victim}"),
                    &[(
                        "bytes",
                        obs::events::Value::U64(e.bytes as u64),
                    )],
                );
            }
        }
        st.check_invariants();
    }
}

/// A decoded layer held hot for the duration of a use (e.g. one layer's
/// GEMVs over a batch). Dropping the guard unpins.
pub struct PinnedLayer {
    inner: Arc<StoreInner>,
    name: String,
    layer: Arc<ExecLayer>,
    /// Whether this guard actually took a pin on the cache entry (the
    /// eviction-window race can hand out an unpinned guard); only a
    /// taken pin may be released on drop.
    pinned: bool,
}

impl PinnedLayer {
    /// The pinned decoded layer.
    pub fn layer(&self) -> &Arc<ExecLayer> {
        &self.layer
    }

    /// The layer's name.
    pub fn name(&self) -> &str {
        &self.name
    }
}

impl std::ops::Deref for PinnedLayer {
    type Target = ExecLayer;

    fn deref(&self) -> &ExecLayer {
        &self.layer
    }
}

impl Drop for PinnedLayer {
    fn drop(&mut self) {
        if !self.pinned {
            // This guard never took a pin; decrementing here would
            // steal a pin another caller still holds.
            return;
        }
        let mut guard = lock_unpoisoned(&self.inner.state);
        let st = &mut *guard;
        let mut released = false;
        if let Some(e) = st.entries.get_mut(&self.name) {
            if e.pins > 0 {
                e.pins -= 1;
                if e.pins == 0 {
                    st.pinned_bytes -= e.bytes;
                    released = true;
                }
            }
        }
        if released {
            // Budget overshoot tolerated while the layer executed is
            // repaid the moment its last pin releases — the cache may
            // not sit over budget between batches.
            self.inner.evict_over_budget(st, None);
        }
        st.check_invariants();
    }
}

/// How a fetch resolves under the state lock.
enum Fetch {
    Hit(Arc<ExecLayer>),
    Wait(Arc<InFlight>),
    Decode(Arc<InFlight>),
}

/// A compressed model ready to serve under a decoded-byte budget.
pub struct ModelStore {
    inner: Arc<StoreInner>,
    service: DecodeService,
}

impl ModelStore {
    /// Open serialized container bytes (v2 stays indexed — random
    /// access per miss; v1 is parsed eagerly but still decodes lazily).
    pub fn open_bytes(bytes: Vec<u8>, config: StoreConfig) -> Result<Self> {
        Self::open_record_source(RecordSource::from_bytes(bytes), config)
    }

    /// Open a container file. With the `mmap` feature (unix) the file
    /// is memory-mapped read-only, so only the layer records this store
    /// actually decodes are ever paged in — the natural fit for one
    /// shard of a split model. Without the feature the file is read
    /// eagerly; behavior is identical either way.
    ///
    /// If a `<container>.costs.json` sidecar sits next to the file
    /// (see [`cost_sidecar_path`]), the cost table is pre-warmed from
    /// it, so the `Auto` readahead planner survives restarts — and
    /// respawned shard workers come up planning instead of falling
    /// back to depth 1.
    pub fn open_path(
        path: impl AsRef<Path>,
        config: StoreConfig,
    ) -> Result<Self> {
        let store = Self::open_record_source(
            RecordSource::open(path.as_ref())?,
            config,
        )?;
        store.load_cost_sidecar(&cost_sidecar_path(path.as_ref()));
        Ok(store)
    }

    /// Best-effort sidecar seed: only layers this store actually holds
    /// are warmed (a model-wide profile next to a shard file seeds
    /// just that shard's entries, so merged views never double-count
    /// foreign layers). A missing sidecar is the normal case; a
    /// malformed one is reported to stderr and ignored — a stale
    /// profile must never stop a store from opening.
    fn load_cost_sidecar(&self, sidecar: &Path) {
        let Ok(json) = std::fs::read_to_string(sidecar) else {
            return;
        };
        match crate::shard::CostProfile::parse_json(&json) {
            Ok(profile) => {
                for (name, cost) in profile.entries() {
                    if self.layer_decoded_bytes(&name).is_some() {
                        self.inner.costs.seed(&name, cost);
                    }
                }
            }
            Err(e) => {
                obs::events::warn(
                    "cost_sidecar_malformed",
                    &format!(
                        "ignoring malformed cost sidecar {}: {e:#}",
                        sidecar.display()
                    ),
                    &[],
                );
            }
        }
    }

    fn open_record_source(
        source: RecordSource,
        config: StoreConfig,
    ) -> Result<Self> {
        let source = if crate::container::is_v2(source.as_slice()) {
            let index = ContainerIndex::parse(source.as_slice())?;
            Source::Indexed { source, index }
        } else {
            let c = read_container(source.as_slice())?;
            Source::Parsed {
                layers: c.layers.into_iter().map(Arc::new).collect(),
            }
        };
        Ok(Self::from_source(source, config))
    }

    /// Wrap an in-memory container (no serialization round-trip).
    pub fn from_container(c: Container, config: StoreConfig) -> Self {
        Self::from_source(
            Source::Parsed {
                layers: c.layers.into_iter().map(Arc::new).collect(),
            },
            config,
        )
    }

    fn from_source(source: Source, config: StoreConfig) -> Self {
        let service = if config.decode_workers == 0 {
            DecodeService::default_for_host()
        } else {
            DecodeService::new(config.decode_workers)
        };
        ModelStore {
            inner: Arc::new(StoreInner {
                source,
                budget: config.cache_budget_bytes,
                mode: config.decode_mode,
                state: Mutex::new(CacheState::default()),
                costs: LayerCosts::new(),
                idle: Condvar::new(),
            }),
            service,
        }
    }

    /// Layer names in container order (the natural forward chain).
    pub fn layer_names(&self) -> Vec<String> {
        match &self.inner.source {
            Source::Indexed { index, .. } => {
                index.entries().iter().map(|e| e.name.clone()).collect()
            }
            Source::Parsed { layers } => {
                layers.iter().map(|l| l.name.clone()).collect()
            }
        }
    }

    /// `(rows, cols)` of a layer, without decoding it.
    pub fn layer_dims(&self, name: &str) -> Option<(usize, usize)> {
        match &self.inner.source {
            Source::Indexed { index, .. } => {
                index.find(name).map(|e| (e.rows, e.cols))
            }
            Source::Parsed { layers } => layers
                .iter()
                .find(|l| l.name == name)
                .map(|l| (l.rows, l.cols)),
        }
    }

    /// Decoded (dense f32) size of one layer in bytes, without decoding.
    pub fn layer_decoded_bytes(&self, name: &str) -> Option<usize> {
        self.inner.layer_decoded_bytes(name)
    }

    /// Resident bytes one layer will charge the cache budget under this
    /// store's decode mode, without decoding — what readahead planning
    /// and `prefetch_all` budget walks must price with (a fused I8
    /// layer charges ~9/32 of its dense size).
    pub fn layer_planned_bytes(&self, name: &str) -> Option<usize> {
        self.inner.layer_planned_bytes(name)
    }

    /// The decode mode this store caches layers under.
    pub fn decode_mode(&self) -> DecodeMode {
        self.inner.mode
    }

    /// Total decoded size of the whole model in bytes.
    pub fn total_decoded_bytes(&self) -> usize {
        match &self.inner.source {
            Source::Indexed { index, .. } => index.total_decoded_bytes(),
            Source::Parsed { layers } => layers
                .iter()
                .map(|l| l.n_weights() * std::mem::size_of::<f32>())
                .sum(),
        }
    }

    /// Cache budget in bytes.
    pub fn budget_bytes(&self) -> usize {
        self.inner.budget
    }

    /// Bytes the store cannot currently give up: pinned entries plus
    /// registered-but-uninstalled decodes. Readahead *planning* seeds
    /// its committed-bytes ledger with this, so a plan drawn up by one
    /// tenant of a shared store counts every other tenant's executing
    /// and in-flight layers against the budget — not just its own.
    pub fn committed_bytes(&self) -> usize {
        let st = lock_unpoisoned(&self.inner.state);
        st.pinned_bytes.saturating_add(st.in_flight_bytes)
    }

    /// True when the compressed records live behind a file mapping
    /// (paged in on demand) rather than owned in-memory bytes.
    pub fn source_mapped(&self) -> bool {
        matches!(
            &self.inner.source,
            Source::Indexed { source, .. } if source.is_mapped()
        )
    }

    /// True if `name` is currently decoded in cache (does not touch
    /// recency).
    pub fn is_cached(&self, name: &str) -> bool {
        lock_unpoisoned(&self.inner.state).entries.contains_key(name)
    }

    /// `(name, resident bytes)` of every currently cached layer, in no
    /// particular order; does not touch recency. The registry's
    /// per-model cache views filter this by their `{model}::` prefix.
    pub fn cached_entries(&self) -> Vec<(String, usize)> {
        lock_unpoisoned(&self.inner.state)
            .entries
            .iter()
            .map(|(name, e)| (name.clone(), e.bytes))
            .collect()
    }

    /// Fetch a decoded layer (in this store's decode-mode
    /// representation): cache hit bumps recency; miss joins the
    /// in-flight decode if one is running, else starts one on the
    /// background service and waits for its install.
    pub fn get(&self, name: &str) -> Result<Arc<ExecLayer>> {
        match self.lookup(name) {
            Fetch::Hit(layer) => Ok(layer),
            Fetch::Wait(flight) => {
                flight.wait().map_err(|e| anyhow!("{e}"))
            }
            Fetch::Decode(flight) => {
                self.start_decode(name, flight.clone());
                flight.wait().map_err(|e| anyhow!("{e}"))
            }
        }
    }

    /// Fetch a layer and pin it for the duration of the returned guard:
    /// while pinned it is never an eviction victim, so background
    /// readahead installs cannot evict the layer mid-execution.
    pub fn get_pinned(&self, name: &str) -> Result<PinnedLayer> {
        let layer = self.get(name)?;
        let mut guard = lock_unpoisoned(&self.inner.state);
        let st = &mut *guard;
        st.clock += 1;
        let clock = st.clock;
        let pinned = if let Some(e) = st.entries.get_mut(name) {
            e.last_used = clock;
            e.pins += 1;
            if e.pins == 1 {
                st.pinned_bytes += e.bytes;
            }
            true
        } else if st.in_flight.contains_key(name) {
            // Evicted in the window since `get` returned, and another
            // caller has already registered a fresh decode: let that
            // install own the cache slot rather than race it with a
            // reinstatement (keeps `redundant_decodes` at 0). The Arc
            // we hold still serves this batch; only residency differs.
            false
        } else {
            // Evicted in the window since `get` returned: reinstate it
            // pinned — it is about to execute, the hottest possible use.
            let bytes = layer.planned_bytes();
            st.cached_bytes += bytes;
            st.pinned_bytes += bytes;
            st.entries.insert(
                name.to_string(),
                CacheEntry {
                    layer: layer.clone(),
                    bytes,
                    last_used: clock,
                    pins: 1,
                },
            );
            self.inner.evict_over_budget(st, Some(name));
            true
        };
        st.check_invariants();
        drop(guard);
        Ok(PinnedLayer {
            inner: self.inner.clone(),
            name: name.to_string(),
            layer,
            pinned,
        })
    }

    /// Warm a layer into cache ahead of traffic, blocking until decoded.
    pub fn prefetch(&self, name: &str) -> Result<()> {
        self.get(name).map(|_| ())
    }

    /// Warm a layer *asynchronously*: queue a decode on the background
    /// service and return immediately. Returns `true` when the layer is
    /// already warm, already decoding, or a decode was started; `false`
    /// when the readahead was declined (unknown layer, or it cannot fit
    /// in the budget alongside the pinned working set).
    pub fn prefetch_async(&self, name: &str) -> bool {
        let flight = {
            let mut guard = lock_unpoisoned(&self.inner.state);
            let st = &mut *guard;
            if st.entries.contains_key(name)
                || st.in_flight.contains_key(name)
            {
                return true; // warm or already decoding: dedup
            }
            let Some(need) = self.inner.layer_planned_bytes(name) else {
                return false; // unknown layer: a blocking get reports it
            };
            // Admission: the layer must fit in the budget alongside the
            // pinned working set *and* every decode already in flight —
            // otherwise deep readahead admits warms that evict each
            // other before use.
            let committed =
                st.pinned_bytes.saturating_add(st.in_flight_bytes);
            if need.saturating_add(committed) > self.inner.budget {
                st.readahead_skips += 1;
                obs::event(obs::SpanKind::ReadaheadSkip, name);
                return false;
            }
            st.prefetches += 1;
            let flight = Arc::new(InFlight::default());
            st.in_flight.insert(name.to_string(), flight.clone());
            st.in_flight_bytes = st.in_flight_bytes.saturating_add(need);
            flight
        };
        self.start_decode(name, flight);
        true
    }

    /// Register-then-submit: the caller must already hold the in-flight
    /// registration for `name` (see [`Self::lookup`] /
    /// [`Self::prefetch_async`]). The compressed-record parse runs on a
    /// decode worker too (not here), so submitting — a readahead from
    /// the serving thread, in particular — costs one queue push
    /// regardless of how large the layer record is.
    fn start_decode(&self, name: &str, flight: Arc<InFlight>) {
        let parse_inner = self.inner.clone();
        let parse_key = name.to_string();
        let inner = self.inner.clone();
        let key = name.to_string();
        let _handle = self.service.decode_parse_then(
            move || {
                parse_inner
                    .compressed_layer(&parse_key)
                    .map_err(|e| format!("{e:#}"))
            },
            self.inner.mode,
            move |outcome, took| match outcome {
                Ok(decoded) => {
                    // Submit→install wall time, stamped by the service:
                    // the latency the auto readahead planner must hide.
                    inner.costs.record_decode(&key, took);
                    inner.install(&key, decoded, &flight);
                }
                Err(msg) => inner.abort(&key, msg, &flight),
            },
        );
    }

    fn lookup(&self, name: &str) -> Fetch {
        let mut guard = lock_unpoisoned(&self.inner.state);
        let st = &mut *guard;
        st.clock += 1;
        let clock = st.clock;
        if let Some(e) = st.entries.get_mut(name) {
            e.last_used = clock;
            st.hits += 1;
            obs::event(obs::SpanKind::CacheHit, name);
            return Fetch::Hit(e.layer.clone());
        }
        st.misses += 1;
        obs::event(obs::SpanKind::CacheMiss, name);
        if let Some(flight) = st.in_flight.get(name) {
            Fetch::Wait(flight.clone())
        } else {
            let flight = Arc::new(InFlight::default());
            st.in_flight.insert(name.to_string(), flight.clone());
            st.in_flight_bytes = st.in_flight_bytes.saturating_add(
                self.inner.layer_planned_bytes(name).unwrap_or(0),
            );
            Fetch::Decode(flight)
        }
    }

    /// Block until no decode is in flight (test / drain aid).
    pub fn wait_for_idle(&self) {
        let mut st = lock_unpoisoned(&self.inner.state);
        while !st.in_flight.is_empty() {
            st = wait_unpoisoned(&self.inner.idle, st);
        }
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> StoreMetrics {
        let st = lock_unpoisoned(&self.inner.state);
        StoreMetrics {
            hits: st.hits,
            misses: st.misses,
            decodes: st.decodes,
            evictions: st.evictions,
            prefetches: st.prefetches,
            redundant_decodes: st.redundant_decodes,
            readahead_skips: st.readahead_skips,
            cached_bytes: st.cached_bytes,
            cached_layers: st.entries.len(),
            pinned_bytes: st.pinned_bytes,
            decode_ns_total: self.inner.costs.decode_ns_total(),
            gemv_ns_total: self.inner.costs.gemv_ns_total(),
            decode_hist: self.inner.costs.decode_hist(),
            gemv_hist: self.inner.costs.gemv_hist(),
        }
    }

    /// Per-layer timing telemetry: decode (submit→install) and GEMV
    /// EWMAs recorded while this store serves. The auto readahead
    /// planner reads estimates here; `f2f rebalance` consumes a
    /// serialized snapshot ([`crate::shard::CostProfile`]).
    pub fn costs(&self) -> &LayerCosts {
        &self.inner.costs
    }

    /// Pre-warm the cost table from previously captured entries (e.g.
    /// a [`crate::shard::CostProfile`] saved by an earlier run), so the
    /// auto readahead planner starts with estimates instead of the
    /// depth-1 fallback.
    pub fn seed_costs<I>(&self, entries: I)
    where
        I: IntoIterator<Item = (String, LayerCost)>,
    {
        for (name, cost) in entries {
            self.inner.costs.seed(&name, cost);
        }
    }

    /// Decode service width (for logs).
    pub fn decode_workers(&self) -> usize {
        self.service.workers()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::write_container_v2;
    use crate::sparse::DecodedLayer;
    use crate::store::test_model as model;

    fn layer_bytes(dims: &[usize], i: usize) -> usize {
        dims[i + 1] * dims[i] * 4
    }

    #[test]
    fn get_matches_serial_decode() {
        let c = model(&[16, 12, 8], 1);
        let want: Vec<Vec<f32>> = c
            .layers
            .iter()
            .map(|l| DecodedLayer::from_compressed(l).weights)
            .collect();
        let bytes = write_container_v2(&c);
        let store =
            ModelStore::open_bytes(bytes, StoreConfig::default()).unwrap();
        assert_eq!(store.layer_names(), vec!["fc0", "fc1"]);
        assert_eq!(store.layer_dims("fc1"), Some((8, 12)));
        assert_eq!(store.layer_decoded_bytes("fc0"), Some(12 * 16 * 4));
        for (i, name) in ["fc0", "fc1"].iter().enumerate() {
            assert_eq!(store.get(name).unwrap().dense_weights(), want[i]);
        }
        // Misses on unknown layers error, clean up, and keep erroring.
        assert!(store.get("nope").is_err());
        assert!(store.get("nope").is_err());
    }

    #[test]
    fn open_path_serves_from_disk() {
        let c = model(&[16, 12], 36);
        let want = DecodedLayer::from_compressed(&c.layers[0]).weights;
        let path = std::env::temp_dir().join(format!(
            "f2f-store-open-path-{}.f2f",
            std::process::id()
        ));
        std::fs::write(&path, write_container_v2(&c)).unwrap();
        let store =
            ModelStore::open_path(&path, StoreConfig::default()).unwrap();
        #[cfg(all(unix, target_pointer_width = "64", feature = "mmap"))]
        assert!(
            store.source_mapped(),
            "unix + mmap feature must map container files"
        );
        assert_eq!(store.get("fc0").unwrap().dense_weights(), want);
        drop(store);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn v1_bytes_also_open() {
        let c = model(&[16, 12], 2);
        let want = DecodedLayer::from_compressed(&c.layers[0]).weights;
        let bytes = crate::container::write_container(&c);
        let store =
            ModelStore::open_bytes(bytes, StoreConfig::default()).unwrap();
        assert_eq!(store.get("fc0").unwrap().dense_weights(), want);
    }

    #[test]
    fn lru_evicts_coldest_under_tight_budget() {
        let dims = [16usize, 16, 16, 16];
        let c = model(&dims, 3);
        // Budget: exactly two decoded layers.
        let budget = layer_bytes(&dims, 0) * 2;
        let store = ModelStore::from_container(
            c,
            StoreConfig {
                cache_budget_bytes: budget,
                decode_workers: 1,
                ..StoreConfig::default()
            },
        );
        store.get("fc0").unwrap();
        store.get("fc1").unwrap();
        assert!(store.is_cached("fc0") && store.is_cached("fc1"));
        // Touch fc0 so fc1 is the coldest, then insert fc2.
        store.get("fc0").unwrap();
        store.get("fc2").unwrap();
        assert!(store.is_cached("fc0"), "recently-used survives");
        assert!(!store.is_cached("fc1"), "coldest evicted");
        assert!(store.is_cached("fc2"));
        let m = store.metrics();
        assert_eq!(m.evictions, 1);
        assert_eq!(m.cached_layers, 2);
        assert_eq!(m.cached_bytes, budget);
    }

    #[test]
    fn hit_and_miss_metrics() {
        let c = model(&[16, 12, 8], 4);
        let store = ModelStore::from_container(c, StoreConfig::default());
        store.get("fc0").unwrap();
        store.get("fc0").unwrap();
        store.get("fc1").unwrap();
        store.get("fc0").unwrap();
        let m = store.metrics();
        assert_eq!(m.misses, 2);
        assert_eq!(m.hits, 2);
        assert_eq!(m.decodes, 2);
        assert_eq!(m.evictions, 0);
        assert_eq!(m.cached_layers, 2);
        assert_eq!(m.redundant_decodes, 0);
    }

    #[test]
    fn prefetch_then_infer_decodes_once() {
        let c = model(&[16, 12], 5);
        let store = ModelStore::from_container(c, StoreConfig::default());
        store.prefetch("fc0").unwrap();
        assert!(store.is_cached("fc0"));
        let m = store.metrics();
        assert_eq!(m.decodes, 1);
        // Serving path: repeated gets never decode again.
        for _ in 0..5 {
            store.get("fc0").unwrap();
        }
        let m = store.metrics();
        assert_eq!(m.decodes, 1, "prefetch + gets must decode exactly once");
        assert_eq!(m.hits, 5);
    }

    #[test]
    fn oversized_layer_still_serves() {
        let c = model(&[16, 12], 6);
        let store = ModelStore::from_container(
            c,
            StoreConfig {
                cache_budget_bytes: 8,
                decode_workers: 1,
                ..StoreConfig::default()
            },
        );
        let l = store.get("fc0").unwrap();
        assert_eq!(l.rows() * l.cols(), 12 * 16);
        // Bigger than budget but it is the only entry: kept.
        assert!(store.is_cached("fc0"));
    }

    #[test]
    fn concurrent_gets_decode_once() {
        let c = model(&[16, 12], 30);
        let store = Arc::new(ModelStore::from_container(
            c,
            StoreConfig::default(),
        ));
        let barrier = Arc::new(std::sync::Barrier::new(2));
        let threads: Vec<_> = (0..2)
            .map(|_| {
                let store = store.clone();
                let barrier = barrier.clone();
                std::thread::spawn(move || {
                    barrier.wait();
                    store.get("fc0").unwrap().dense_weights()
                })
            })
            .collect();
        let results: Vec<Vec<f32>> = threads
            .into_iter()
            .map(|t| t.join().expect("getter thread"))
            .collect();
        assert_eq!(results[0], results[1]);
        let m = store.metrics();
        assert_eq!(m.decodes, 1, "in-flight dedup must decode once");
        assert_eq!(m.redundant_decodes, 0);
        assert_eq!(m.hits + m.misses, 2);
    }

    #[test]
    fn prefetch_async_installs_and_dedups() {
        let c = model(&[16, 12], 33);
        let store = ModelStore::from_container(c, StoreConfig::default());
        assert!(store.prefetch_async("fc0"));
        assert!(store.prefetch_async("fc0"), "warm/in-flight is a no-op");
        store.wait_for_idle();
        assert!(store.is_cached("fc0"));
        let m = store.metrics();
        assert_eq!(m.decodes, 1);
        assert_eq!(m.prefetches, 1);
        assert_eq!(m.redundant_decodes, 0);
        // Async warming is not caller traffic: no hit/miss accounting.
        assert_eq!(m.hits + m.misses, 0);
        let l = store.get("fc0").unwrap();
        assert_eq!(l.rows() * l.cols(), 12 * 16);
        assert_eq!(store.metrics().hits, 1);
    }

    #[test]
    fn pinned_layer_survives_install_pressure() {
        let dims = [16usize, 16, 16, 16];
        let c = model(&dims, 31);
        let budget = layer_bytes(&dims, 0) * 2; // two layers fit
        let store = ModelStore::from_container(
            c,
            StoreConfig {
                cache_budget_bytes: budget,
                decode_workers: 1,
                ..StoreConfig::default()
            },
        );
        let pinned = store.get_pinned("fc0").unwrap();
        assert_eq!(pinned.rows() * pinned.cols(), 16 * 16);
        // Warm fc1 (fits beside the pin), then fc2: its install must
        // evict fc1 — never the pinned fc0, although fc0 is LRU-oldest.
        assert!(store.prefetch_async("fc1"));
        store.wait_for_idle();
        assert!(store.prefetch_async("fc2"));
        store.wait_for_idle();
        assert!(store.is_cached("fc0"), "pinned layer never evicted");
        assert!(!store.is_cached("fc1"), "unpinned LRU evicted instead");
        assert!(store.is_cached("fc2"));
        assert_eq!(store.metrics().pinned_bytes, layer_bytes(&dims, 0));
        drop(pinned);
        assert_eq!(store.metrics().pinned_bytes, 0);
        // Unpinned again: the next install may evict fc0 normally.
        store.get("fc1").unwrap();
        assert!(!store.is_cached("fc0"), "oldest unpinned layer evicts");
    }

    #[test]
    fn panicking_decode_surfaces_as_error_not_hang() {
        // A malformed plane makes the decode job panic; the store must
        // turn that into an error for every waiter (never a hang, never
        // a dead worker) and keep serving other layers.
        let mut c = model(&[16, 12, 8], 34);
        c.layers[0].planes[0].encoded[0] = u32::MAX;
        let store = ModelStore::from_container(
            c,
            StoreConfig {
                cache_budget_bytes: usize::MAX,
                decode_workers: 1,
                ..StoreConfig::default()
            },
        );
        assert!(store.get("fc0").is_err(), "decode panic must surface");
        store.wait_for_idle();
        assert!(!store.is_cached("fc0"));
        // The single worker survived: the healthy layer still decodes.
        assert!(store.get("fc1").is_ok());
        assert!(store.is_cached("fc1"));
    }

    #[test]
    fn pin_overshoot_is_repaid_on_unpin() {
        let dims = [16usize, 16, 16];
        let c = model(&dims, 35);
        let budget = layer_bytes(&dims, 0); // exactly one layer
        let store = ModelStore::from_container(
            c,
            StoreConfig {
                cache_budget_bytes: budget,
                decode_workers: 1,
                ..StoreConfig::default()
            },
        );
        let pin = store.get_pinned("fc0").unwrap();
        // A demand fetch while fc0 is pinned finds no eviction victim:
        // the budget is overshot rather than evicting mid-GEMV...
        store.get("fc1").unwrap();
        let m = store.metrics();
        assert_eq!(m.cached_bytes, budget * 2, "overshoot while pinned");
        assert_eq!(m.evictions, 0);
        // ...and repaid the moment the last pin releases.
        drop(pin);
        let m = store.metrics();
        assert_eq!(m.cached_bytes, budget);
        assert!(!store.is_cached("fc0"), "stale layer evicted to repay");
        assert!(store.is_cached("fc1"));
        assert_eq!(m.pinned_bytes, 0);
    }

    #[test]
    fn readahead_skipped_when_it_cannot_fit_beside_pins() {
        let dims = [16usize, 16, 16];
        let c = model(&dims, 32);
        let budget = layer_bytes(&dims, 0); // exactly one layer
        let store = ModelStore::from_container(
            c,
            StoreConfig {
                cache_budget_bytes: budget,
                decode_workers: 1,
                ..StoreConfig::default()
            },
        );
        let _pin = store.get_pinned("fc0").unwrap();
        assert!(
            !store.prefetch_async("fc1"),
            "fc1 cannot fit beside the pin"
        );
        let m = store.metrics();
        assert_eq!(m.readahead_skips, 1);
        assert_eq!(m.prefetches, 0);
        assert!(store.is_cached("fc0") && !store.is_cached("fc1"));
        // Unknown layers are declined too (a blocking get reports them).
        assert!(!store.prefetch_async("ghost"));
    }

    #[test]
    fn fused_mode_shrinks_cache_footprint_and_stays_bit_exact() {
        // One wide I8 layer (8 × 64): bit-plane residency costs
        // (8+1)·8·1·8 = 576 bytes vs 2048 dense — the budget, the
        // metrics, and the planned sizing must all price the fused
        // representation, and the weights must stay bit-exact.
        let c = model(&[64, 8], 41);
        let want = DecodedLayer::from_compressed(&c.layers[0]).weights;
        let store = ModelStore::from_container(
            c,
            StoreConfig {
                decode_mode: DecodeMode::Fused,
                ..StoreConfig::default()
            },
        );
        let planned = store.layer_planned_bytes("fc0").unwrap();
        assert_eq!(planned, crate::kernels::fused_bytes(8, 64, 8));
        assert!(planned < store.layer_decoded_bytes("fc0").unwrap());
        let l = store.get("fc0").unwrap();
        assert!(l.is_fused());
        assert_eq!(l.planned_bytes(), planned, "admission == install");
        assert_eq!(l.dense_weights(), want);
        let m = store.metrics();
        assert_eq!(m.cached_bytes, planned);
        // Materialized stores price the same layer dense.
        let c = model(&[64, 8], 41);
        let dense_store =
            ModelStore::from_container(c, StoreConfig::default());
        assert_eq!(
            dense_store.layer_planned_bytes("fc0"),
            dense_store.layer_decoded_bytes("fc0")
        );
        assert!(!dense_store.get("fc0").unwrap().is_fused());
    }

    #[test]
    fn metrics_merge_sums_every_field() {
        // Direct coverage of the aggregation the shard router relies
        // on — every field, including the timing totals and the
        // latency histograms, must sum.
        let mut ha = HdrLite::new();
        ha.record_ns(11);
        let mut hb = HdrLite::new();
        hb.record_ns(1100);
        let mut hab = ha;
        hab.merge(&hb);
        let a = StoreMetrics {
            hits: 1,
            misses: 2,
            decodes: 3,
            evictions: 4,
            prefetches: 5,
            redundant_decodes: 6,
            readahead_skips: 7,
            cached_bytes: 8,
            cached_layers: 9,
            pinned_bytes: 10,
            decode_ns_total: 11,
            gemv_ns_total: 12,
            decode_hist: ha,
            gemv_hist: ha,
        };
        let b = StoreMetrics {
            hits: 100,
            misses: 200,
            decodes: 300,
            evictions: 400,
            prefetches: 500,
            redundant_decodes: 600,
            readahead_skips: 700,
            cached_bytes: 800,
            cached_layers: 900,
            pinned_bytes: 1000,
            decode_ns_total: 1100,
            gemv_ns_total: 1200,
            decode_hist: hb,
            gemv_hist: hb,
        };
        let mut merged = a;
        merged.merge(&b);
        assert_eq!(
            merged,
            StoreMetrics {
                hits: 101,
                misses: 202,
                decodes: 303,
                evictions: 404,
                prefetches: 505,
                redundant_decodes: 606,
                readahead_skips: 707,
                cached_bytes: 808,
                cached_layers: 909,
                pinned_bytes: 1010,
                decode_ns_total: 1111,
                gemv_ns_total: 1212,
                decode_hist: hab,
                gemv_hist: hab,
            }
        );
        // Merging the identity changes nothing.
        let mut same = a;
        same.merge(&StoreMetrics::default());
        assert_eq!(same, a);
    }

    #[test]
    fn decode_timing_is_recorded_on_install() {
        let c = model(&[16, 12, 8], 37);
        let store = ModelStore::from_container(c, StoreConfig::default());
        assert!(store.costs().get("fc0").is_none(), "cold table");
        store.get("fc0").unwrap();
        store.get("fc1").unwrap();
        let c0 = store.costs().get("fc0").unwrap();
        assert_eq!(c0.decode_samples, 1);
        assert!(c0.decode_estimate().unwrap() > 0.0);
        assert_eq!(c0.gemv_samples, 0, "no GEMV ran through the store");
        let m = store.metrics();
        assert!(m.decode_ns_total > 0);
        assert_eq!(m.gemv_ns_total, 0);
        assert_eq!(m.decode_hist.count(), 2, "one sample per decode");
        assert!(m.gemv_hist.is_empty());
        // A cache hit records no new decode sample.
        store.get("fc0").unwrap();
        assert_eq!(store.costs().get("fc0").unwrap().decode_samples, 1);
    }

    #[test]
    fn open_path_auto_loads_the_cost_sidecar() {
        // A profile saved next to the container warms the planner on
        // reopen — but only for layers this store actually holds, so
        // a model-wide profile next to a *shard* file seeds just that
        // shard's entries.
        let c = model(&[16, 12, 8], 39);
        let path = std::env::temp_dir().join(format!(
            "f2f-store-sidecar-{}.f2f",
            std::process::id()
        ));
        std::fs::write(&path, write_container_v2(&c)).unwrap();
        let sidecar = cost_sidecar_path(&path);
        assert_eq!(
            sidecar.file_name().unwrap().to_str().unwrap(),
            format!(
                "f2f-store-sidecar-{}.f2f.costs.json",
                std::process::id()
            )
        );
        let mut profile = crate::shard::CostProfile::new();
        profile.record(
            "fc0",
            LayerCost {
                decode_ns: 420.0,
                decode_samples: 3,
                ..Default::default()
            },
        );
        profile.record(
            "not-in-this-store",
            LayerCost {
                decode_ns: 1.0,
                decode_samples: 1,
                ..Default::default()
            },
        );
        std::fs::write(&sidecar, profile.to_json()).unwrap();
        let store =
            ModelStore::open_path(&path, StoreConfig::default()).unwrap();
        assert_eq!(
            store.costs().get("fc0").unwrap().decode_estimate(),
            Some(420.0),
            "sidecar must pre-warm the planner"
        );
        assert!(
            store.costs().get("not-in-this-store").is_none(),
            "foreign layers are never seeded"
        );
        assert_eq!(store.metrics().decode_ns_total, 0);

        // A corrupt sidecar is ignored — opening must still succeed.
        std::fs::write(&sidecar, b"{definitely not json").unwrap();
        let store =
            ModelStore::open_path(&path, StoreConfig::default()).unwrap();
        assert!(store.costs().get("fc0").is_none());
        assert!(store.get("fc0").is_ok());

        let _ = std::fs::remove_file(&sidecar);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn seeded_costs_prewarm_without_touching_totals() {
        let c = model(&[16, 12], 38);
        let store = ModelStore::from_container(c, StoreConfig::default());
        store.seed_costs(vec![(
            "fc0".to_string(),
            LayerCost {
                decode_ns: 750.0,
                decode_samples: 2,
                ..Default::default()
            },
        )]);
        assert_eq!(
            store.costs().get("fc0").unwrap().decode_estimate(),
            Some(750.0)
        );
        assert_eq!(store.metrics().decode_ns_total, 0);
    }
}

//! Byte-budgeted model store: decode-on-miss, evict-cold.
//!
//! Holds a compressed model (ideally an indexed v2 container, so a miss
//! parses exactly one layer record) plus an LRU cache of decoded layers
//! bounded by `cache_budget_bytes` of dense f32 weights. Models whose
//! decoded size exceeds the budget still serve: a miss decodes through
//! the [`DecodePool`], inserts, and evicts the coldest layers until the
//! budget holds again. [`ModelStore::prefetch`] warms a layer ahead of
//! traffic without handing the caller the weights.

use super::DecodePool;
use crate::container::{
    read_container, read_layer_at, CompressedLayer, Container,
    ContainerIndex,
};
use crate::sparse::DecodedLayer;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Store knobs.
#[derive(Debug, Clone, Copy)]
pub struct StoreConfig {
    /// Decoded-weight cache budget in bytes (`usize::MAX` = unbounded).
    pub cache_budget_bytes: usize,
    /// Worker threads for the decode pool (0 = size to the host).
    pub decode_workers: usize,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig { cache_budget_bytes: usize::MAX, decode_workers: 0 }
    }
}

/// Cache / decode counters (monotonic since open).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreMetrics {
    /// `get`/`prefetch` calls served from cache.
    pub hits: u64,
    /// Calls that had to decode.
    pub misses: u64,
    /// Layers decoded (== misses unless a concurrent get raced).
    pub decodes: u64,
    /// Layers evicted to respect the budget.
    pub evictions: u64,
    /// Decoded bytes currently cached.
    pub cached_bytes: usize,
    /// Layers currently cached.
    pub cached_layers: usize,
}

/// Where the compressed records come from.
enum Source {
    /// Indexed v2 bytes: a miss parses exactly one layer record.
    Indexed { bytes: Vec<u8>, index: ContainerIndex },
    /// Pre-parsed layers (v1 files or in-memory containers).
    Parsed { layers: Vec<CompressedLayer> },
}

struct CacheEntry {
    layer: Arc<DecodedLayer>,
    bytes: usize,
    last_used: u64,
}

#[derive(Default)]
struct CacheState {
    entries: HashMap<String, CacheEntry>,
    clock: u64,
    cached_bytes: usize,
    hits: u64,
    misses: u64,
    decodes: u64,
    evictions: u64,
}

/// A compressed model ready to serve under a decoded-byte budget.
pub struct ModelStore {
    source: Source,
    pool: DecodePool,
    budget: usize,
    state: Mutex<CacheState>,
}

impl ModelStore {
    /// Open serialized container bytes (v2 stays indexed — random
    /// access per miss; v1 is parsed eagerly but still decodes lazily).
    pub fn open_bytes(bytes: Vec<u8>, config: StoreConfig) -> Result<Self> {
        let source = if crate::container::is_v2(&bytes) {
            let index = ContainerIndex::parse(&bytes)?;
            Source::Indexed { bytes, index }
        } else {
            let c = read_container(&bytes)?;
            Source::Parsed { layers: c.layers }
        };
        Ok(Self::from_source(source, config))
    }

    /// Wrap an in-memory container (no serialization round-trip).
    pub fn from_container(c: Container, config: StoreConfig) -> Self {
        Self::from_source(Source::Parsed { layers: c.layers }, config)
    }

    fn from_source(source: Source, config: StoreConfig) -> Self {
        let pool = if config.decode_workers == 0 {
            DecodePool::default_for_host()
        } else {
            DecodePool::new(config.decode_workers)
        };
        ModelStore {
            source,
            pool,
            budget: config.cache_budget_bytes,
            state: Mutex::new(CacheState::default()),
        }
    }

    /// Layer names in container order (the natural forward chain).
    pub fn layer_names(&self) -> Vec<String> {
        match &self.source {
            Source::Indexed { index, .. } => {
                index.entries().iter().map(|e| e.name.clone()).collect()
            }
            Source::Parsed { layers } => {
                layers.iter().map(|l| l.name.clone()).collect()
            }
        }
    }

    /// `(rows, cols)` of a layer, without decoding it.
    pub fn layer_dims(&self, name: &str) -> Option<(usize, usize)> {
        match &self.source {
            Source::Indexed { index, .. } => {
                index.find(name).map(|e| (e.rows, e.cols))
            }
            Source::Parsed { layers } => layers
                .iter()
                .find(|l| l.name == name)
                .map(|l| (l.rows, l.cols)),
        }
    }

    /// Total decoded size of the whole model in bytes.
    pub fn total_decoded_bytes(&self) -> usize {
        match &self.source {
            Source::Indexed { index, .. } => index.total_decoded_bytes(),
            Source::Parsed { layers } => layers
                .iter()
                .map(|l| l.n_weights() * std::mem::size_of::<f32>())
                .sum(),
        }
    }

    /// Cache budget in bytes.
    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// True if `name` is currently decoded in cache (does not touch
    /// recency).
    pub fn is_cached(&self, name: &str) -> bool {
        self.state.lock().unwrap().entries.contains_key(name)
    }

    /// Fetch a decoded layer: cache hit bumps recency; miss decodes via
    /// the pool, inserts, and evicts cold layers down to the budget.
    pub fn get(&self, name: &str) -> Result<Arc<DecodedLayer>> {
        {
            let mut guard = self.state.lock().unwrap();
            let st = &mut *guard;
            st.clock += 1;
            let clock = st.clock;
            if let Some(e) = st.entries.get_mut(name) {
                e.last_used = clock;
                st.hits += 1;
                return Ok(e.layer.clone());
            }
            st.misses += 1;
        }
        // Decode outside the lock so other layers keep serving.
        let decoded = Arc::new(self.decode_miss(name)?);
        let bytes = decoded.decoded_bytes();

        let mut guard = self.state.lock().unwrap();
        let st = &mut *guard;
        st.clock += 1;
        let clock = st.clock;
        if let Some(e) = st.entries.get_mut(name) {
            // A concurrent get decoded it first; keep that copy.
            e.last_used = clock;
            return Ok(e.layer.clone());
        }
        st.decodes += 1;
        st.cached_bytes += bytes;
        st.entries.insert(
            name.to_string(),
            CacheEntry { layer: decoded.clone(), bytes, last_used: clock },
        );
        self.evict_over_budget(st, name);
        Ok(decoded)
    }

    /// Warm a layer into cache ahead of traffic.
    pub fn prefetch(&self, name: &str) -> Result<()> {
        self.get(name).map(|_| ())
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> StoreMetrics {
        let st = self.state.lock().unwrap();
        StoreMetrics {
            hits: st.hits,
            misses: st.misses,
            decodes: st.decodes,
            evictions: st.evictions,
            cached_bytes: st.cached_bytes,
            cached_layers: st.entries.len(),
        }
    }

    /// Decode pool width (for logs).
    pub fn decode_workers(&self) -> usize {
        self.pool.workers()
    }

    fn decode_miss(&self, name: &str) -> Result<DecodedLayer> {
        match &self.source {
            Source::Indexed { bytes, index } => {
                let Some(entry) = index.find(name) else {
                    bail!("layer {name:?} not in container index");
                };
                let compressed = read_layer_at(bytes, entry)?;
                Ok(self.pool.decode(&compressed))
            }
            Source::Parsed { layers } => {
                let Some(compressed) =
                    layers.iter().find(|l| l.name == name)
                else {
                    bail!("layer {name:?} not in container");
                };
                Ok(self.pool.decode(compressed))
            }
        }
    }

    /// Evict least-recently-used entries until the budget holds. The
    /// just-inserted `keep` layer is never evicted — a single layer
    /// bigger than the whole budget must still serve.
    fn evict_over_budget(&self, st: &mut CacheState, keep: &str) {
        while st.cached_bytes > self.budget && st.entries.len() > 1 {
            let victim = st
                .entries
                .iter()
                .filter(|(n, _)| n.as_str() != keep)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(n, _)| n.clone());
            let Some(victim) = victim else { break };
            if let Some(e) = st.entries.remove(&victim) {
                st.cached_bytes -= e.bytes;
                st.evictions += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::write_container_v2;
    use crate::store::test_model as model;

    fn layer_bytes(dims: &[usize], i: usize) -> usize {
        dims[i + 1] * dims[i] * 4
    }

    #[test]
    fn get_matches_serial_decode() {
        let c = model(&[16, 12, 8], 1);
        let want: Vec<Vec<f32>> = c
            .layers
            .iter()
            .map(|l| DecodedLayer::from_compressed(l).weights)
            .collect();
        let bytes = write_container_v2(&c);
        let store =
            ModelStore::open_bytes(bytes, StoreConfig::default()).unwrap();
        assert_eq!(store.layer_names(), vec!["fc0", "fc1"]);
        assert_eq!(store.layer_dims("fc1"), Some((8, 12)));
        for (i, name) in ["fc0", "fc1"].iter().enumerate() {
            assert_eq!(store.get(name).unwrap().weights, want[i]);
        }
        assert!(store.get("nope").is_err());
    }

    #[test]
    fn v1_bytes_also_open() {
        let c = model(&[16, 12], 2);
        let want = DecodedLayer::from_compressed(&c.layers[0]).weights;
        let bytes = crate::container::write_container(&c);
        let store =
            ModelStore::open_bytes(bytes, StoreConfig::default()).unwrap();
        assert_eq!(store.get("fc0").unwrap().weights, want);
    }

    #[test]
    fn lru_evicts_coldest_under_tight_budget() {
        let dims = [16usize, 16, 16, 16];
        let c = model(&dims, 3);
        // Budget: exactly two decoded layers.
        let budget = layer_bytes(&dims, 0) * 2;
        let store = ModelStore::from_container(
            c,
            StoreConfig { cache_budget_bytes: budget, decode_workers: 1 },
        );
        store.get("fc0").unwrap();
        store.get("fc1").unwrap();
        assert!(store.is_cached("fc0") && store.is_cached("fc1"));
        // Touch fc0 so fc1 is the coldest, then insert fc2.
        store.get("fc0").unwrap();
        store.get("fc2").unwrap();
        assert!(store.is_cached("fc0"), "recently-used survives");
        assert!(!store.is_cached("fc1"), "coldest evicted");
        assert!(store.is_cached("fc2"));
        let m = store.metrics();
        assert_eq!(m.evictions, 1);
        assert_eq!(m.cached_layers, 2);
        assert_eq!(m.cached_bytes, budget);
    }

    #[test]
    fn hit_and_miss_metrics() {
        let c = model(&[16, 12, 8], 4);
        let store = ModelStore::from_container(c, StoreConfig::default());
        store.get("fc0").unwrap();
        store.get("fc0").unwrap();
        store.get("fc1").unwrap();
        store.get("fc0").unwrap();
        let m = store.metrics();
        assert_eq!(m.misses, 2);
        assert_eq!(m.hits, 2);
        assert_eq!(m.decodes, 2);
        assert_eq!(m.evictions, 0);
        assert_eq!(m.cached_layers, 2);
    }

    #[test]
    fn prefetch_then_infer_decodes_once() {
        let c = model(&[16, 12], 5);
        let store = ModelStore::from_container(c, StoreConfig::default());
        store.prefetch("fc0").unwrap();
        assert!(store.is_cached("fc0"));
        let m = store.metrics();
        assert_eq!(m.decodes, 1);
        // Serving path: repeated gets never decode again.
        for _ in 0..5 {
            store.get("fc0").unwrap();
        }
        let m = store.metrics();
        assert_eq!(m.decodes, 1, "prefetch + gets must decode exactly once");
        assert_eq!(m.hits, 5);
    }

    #[test]
    fn oversized_layer_still_serves() {
        let c = model(&[16, 12], 6);
        let store = ModelStore::from_container(
            c,
            StoreConfig { cache_budget_bytes: 8, decode_workers: 1 },
        );
        let l = store.get("fc0").unwrap();
        assert_eq!(l.rows * l.cols, 12 * 16);
        // Bigger than budget but it is the only entry: kept.
        assert!(store.is_cached("fc0"));
    }
}

//! Per-layer timing telemetry: the cost model under adaptive serving.
//!
//! The paper's fixed-to-fixed format keeps the *shape* of every layer's
//! compressed record regular, but the *cost* of decoding one is not
//! uniform: it scales with mask density, plane count and correction
//! length, and the GEMV it feeds scales with the layer's geometry and
//! the batch in flight. Scheduling decisions that pretend those costs
//! are equal (a fixed readahead depth, byte-balanced shards) leave
//! overlap on the table. [`LayerCosts`] is the measurement layer those
//! schedulers consume:
//!
//! * [`LayerCosts::record_decode`] — stamped by the model store when a
//!   decode completes, covering submit→install on the background
//!   service (queue wait included: that is the latency a warm must
//!   actually hide).
//! * [`LayerCosts::record_gemv`] — stamped by the forward chain around
//!   each layer's GEMV phase, normalized per batch item so estimates
//!   compose across batch sizes.
//!
//! Estimates are exponentially-weighted moving averages (EWMA), so they
//! track drift (cache pressure, CPU contention) without a sample
//! history, and the table is lock-cheap: one short-critical-section
//! mutex over a small name-keyed map, plus relaxed atomic totals for
//! the metrics surface. Consumers: the `Auto` readahead planner
//! ([`super::ReadaheadPolicy`]) sizes depth-`k` warming against these
//! estimates, and [`crate::shard::CostProfile`] serializes a snapshot
//! so `f2f rebalance` can re-shard on observed decode cost.

use crate::obs::HdrLite;
use crate::sync::lock_unpoisoned;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Default EWMA smoothing factor: new samples carry 25% weight, so an
/// estimate re-centers within a handful of passes without jittering on
/// a single noisy one.
pub const DEFAULT_EWMA_ALPHA: f64 = 0.25;

/// Cap on the sample counts carried in a [`LayerCost`]. The EWMA
/// estimates themselves already decay (every new sample carries
/// `alpha` weight, so a one-off contention spike fades geometrically),
/// but the *counts* used for sample-weighted [`LayerCost::merge`] used
/// to grow without bound — a long-lived table, or a seeded profile
/// carrying a spike, would dominate every future merge no matter how
/// stale its observations were, steering `ReadaheadPolicy::Auto`
/// forever. Counts now saturate here, bounding any one side's merge
/// weight while leaving warm/unwarmed detection intact.
pub const MAX_COST_SAMPLES: u64 = 64;

/// Observed cost of one layer: EWMA nanoseconds per decode
/// (submit→install) and per single GEMV, with sample counts (an
/// estimate with zero samples is *unwarmed*, not free).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LayerCost {
    /// EWMA of submit→install decode time, ns (0 until sampled).
    pub decode_ns: f64,
    /// EWMA of one GEMV over this layer, ns per batch item.
    pub gemv_ns: f64,
    /// Decode samples folded into `decode_ns`.
    pub decode_samples: u64,
    /// GEMV samples folded into `gemv_ns`.
    pub gemv_samples: u64,
}

impl LayerCost {
    /// Predicted decode cost, or `None` until at least one observation.
    pub fn decode_estimate(&self) -> Option<f64> {
        (self.decode_samples > 0).then_some(self.decode_ns)
    }

    /// Predicted per-item GEMV cost, or `None` until observed.
    pub fn gemv_estimate(&self) -> Option<f64> {
        (self.gemv_samples > 0).then_some(self.gemv_ns)
    }

    /// Fold another observation set into this one, sample-weighted —
    /// how per-shard tables merge into one model-wide view. Each
    /// side's weight (and the resulting count) is capped at
    /// [`MAX_COST_SAMPLES`], so no history — however long, however
    /// stale — can outvote fresh observations indefinitely.
    pub fn merge(&mut self, other: &LayerCost) {
        fn blend(a: f64, an: u64, b: f64, bn: u64) -> f64 {
            let (an, bn) = (
                an.min(MAX_COST_SAMPLES) as f64,
                bn.min(MAX_COST_SAMPLES) as f64,
            );
            if an + bn == 0.0 {
                0.0
            } else {
                (a * an + b * bn) / (an + bn)
            }
        }
        self.decode_ns = blend(
            self.decode_ns,
            self.decode_samples,
            other.decode_ns,
            other.decode_samples,
        );
        self.gemv_ns = blend(
            self.gemv_ns,
            self.gemv_samples,
            other.gemv_ns,
            other.gemv_samples,
        );
        self.decode_samples = self
            .decode_samples
            .saturating_add(other.decode_samples)
            .min(MAX_COST_SAMPLES);
        self.gemv_samples = self
            .gemv_samples
            .saturating_add(other.gemv_samples)
            .min(MAX_COST_SAMPLES);
    }
}

/// Concurrent per-layer cost table: EWMA estimates keyed by layer name,
/// plus monotonic wall-time totals for the metrics surface. One table
/// per [`super::ModelStore`]; recording is a short lock hold on the
/// serving/worker path, reading is a snapshot copy.
#[derive(Debug)]
pub struct LayerCosts {
    alpha: f64,
    table: Mutex<BTreeMap<String, LayerCost>>,
    decode_ns_total: AtomicU64,
    gemv_ns_total: AtomicU64,
    // Distribution counterparts of the EWMA point estimates: every
    // recorded decode / GEMV phase also lands in a mergeable
    // log-bucketed histogram, the per-layer-granularity feed of the
    // metrics registry (`StoreMetrics::{decode_hist, gemv_hist}`).
    decode_hist: Mutex<HdrLite>,
    gemv_hist: Mutex<HdrLite>,
}

impl Default for LayerCosts {
    fn default() -> Self {
        LayerCosts::new()
    }
}

impl LayerCosts {
    /// A table with the default smoothing factor.
    pub fn new() -> Self {
        LayerCosts::with_alpha(DEFAULT_EWMA_ALPHA)
    }

    /// A table with a custom EWMA `alpha` (clamped into `(0, 1]`).
    pub fn with_alpha(alpha: f64) -> Self {
        let alpha = if alpha.is_finite() {
            alpha.clamp(f64::EPSILON, 1.0)
        } else {
            DEFAULT_EWMA_ALPHA
        };
        LayerCosts {
            alpha,
            table: Mutex::new(BTreeMap::new()),
            decode_ns_total: AtomicU64::new(0),
            gemv_ns_total: AtomicU64::new(0),
            decode_hist: Mutex::new(HdrLite::new()),
            gemv_hist: Mutex::new(HdrLite::new()),
        }
    }

    /// Record one completed decode of `name` (submit→install wall time).
    pub fn record_decode(&self, name: &str, took: Duration) {
        let ns = saturating_ns(took);
        {
            let mut t = lock_unpoisoned(&self.table);
            let e = t.entry(name.to_string()).or_default();
            e.decode_ns = self.ewma(e.decode_ns, e.decode_samples, ns as f64);
            e.decode_samples =
                (e.decode_samples + 1).min(MAX_COST_SAMPLES);
        }
        lock_unpoisoned(&self.decode_hist).record_ns(ns);
        self.decode_ns_total.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record one GEMV phase of `name`: `took` covers `items` batch
    /// items, the EWMA tracks the per-item cost (estimates must compose
    /// across batch sizes). A zero-item phase records nothing.
    pub fn record_gemv(&self, name: &str, took: Duration, items: usize) {
        if items == 0 {
            return;
        }
        let ns = saturating_ns(took);
        let per_item = ns as f64 / items as f64;
        {
            let mut t = lock_unpoisoned(&self.table);
            let e = t.entry(name.to_string()).or_default();
            e.gemv_ns = self.ewma(e.gemv_ns, e.gemv_samples, per_item);
            e.gemv_samples = (e.gemv_samples + 1).min(MAX_COST_SAMPLES);
        }
        lock_unpoisoned(&self.gemv_hist).record_ns(ns);
        self.gemv_ns_total.fetch_add(ns, Ordering::Relaxed);
    }

    /// Pre-warm `name` with an externally captured cost (e.g. a saved
    /// `CostProfile` from an earlier run), sample-weighted against
    /// anything already observed. Totals are untouched: they count only
    /// this table's own wall time.
    pub fn seed(&self, name: &str, cost: LayerCost) {
        let mut t = lock_unpoisoned(&self.table);
        t.entry(name.to_string()).or_default().merge(&cost);
    }

    /// This layer's current estimates, if any observation exists.
    pub fn get(&self, name: &str) -> Option<LayerCost> {
        lock_unpoisoned(&self.table).get(name).copied()
    }

    /// Name-ordered copy of the whole table.
    pub fn snapshot(&self) -> Vec<(String, LayerCost)> {
        lock_unpoisoned(&self.table)
            .iter()
            .map(|(n, c)| (n.clone(), *c))
            .collect()
    }

    /// Distribution of recorded decode times (submit→install, raw ns
    /// per decode) — a copy, mergeable across tables.
    pub fn decode_hist(&self) -> HdrLite {
        *lock_unpoisoned(&self.decode_hist)
    }

    /// Distribution of recorded GEMV phase times (raw ns per phase,
    /// *not* per item — the EWMA tracks the per-item normalization).
    pub fn gemv_hist(&self) -> HdrLite {
        *lock_unpoisoned(&self.gemv_hist)
    }

    /// Total wall nanoseconds spent decoding (submit→install), summed
    /// over every recorded decode.
    pub fn decode_ns_total(&self) -> u64 {
        self.decode_ns_total.load(Ordering::Relaxed)
    }

    /// Total wall nanoseconds spent in recorded GEMV phases.
    pub fn gemv_ns_total(&self) -> u64 {
        self.gemv_ns_total.load(Ordering::Relaxed)
    }

    fn ewma(&self, prev: f64, prev_samples: u64, x: f64) -> f64 {
        if prev_samples == 0 {
            x
        } else {
            self.alpha * x + (1.0 - self.alpha) * prev
        }
    }
}

fn saturating_ns(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_sample_sets_estimate_then_ewma_blends() {
        let costs = LayerCosts::with_alpha(0.5);
        assert!(costs.get("fc0").is_none());
        costs.record_decode("fc0", Duration::from_nanos(1000));
        let c = costs.get("fc0").unwrap();
        assert_eq!(c.decode_estimate(), Some(1000.0));
        assert_eq!(c.decode_samples, 1);
        assert_eq!(c.gemv_estimate(), None, "gemv still unwarmed");
        // Second sample: 0.5 * 2000 + 0.5 * 1000.
        costs.record_decode("fc0", Duration::from_nanos(2000));
        let c = costs.get("fc0").unwrap();
        assert_eq!(c.decode_estimate(), Some(1500.0));
        assert_eq!(c.decode_samples, 2);
        assert_eq!(costs.decode_ns_total(), 3000);
    }

    #[test]
    fn gemv_normalizes_per_item_and_totals_raw() {
        let costs = LayerCosts::with_alpha(1.0);
        costs.record_gemv("fc0", Duration::from_nanos(8000), 8);
        let c = costs.get("fc0").unwrap();
        assert_eq!(c.gemv_estimate(), Some(1000.0), "per-item EWMA");
        assert_eq!(c.gemv_samples, 1);
        assert_eq!(costs.gemv_ns_total(), 8000, "totals keep raw time");
        // Zero-item phases record nothing.
        costs.record_gemv("fc0", Duration::from_nanos(999), 0);
        assert_eq!(costs.get("fc0").unwrap().gemv_samples, 1);
    }

    #[test]
    fn histograms_track_recorded_distributions() {
        let costs = LayerCosts::new();
        assert!(costs.decode_hist().is_empty());
        assert!(costs.gemv_hist().is_empty());
        costs.record_decode("fc0", Duration::from_nanos(1_000));
        costs.record_decode("fc1", Duration::from_micros(50));
        costs.record_gemv("fc0", Duration::from_nanos(8_000), 8);
        let d = costs.decode_hist();
        assert_eq!(d.count(), 2);
        assert_eq!(d.max(), Duration::from_micros(50));
        let g = costs.gemv_hist();
        assert_eq!(g.count(), 1);
        assert_eq!(
            g.percentile(0.99),
            Duration::from_nanos(8_000),
            "histogram keeps the raw phase time, not the per-item EWMA"
        );
        // Seeding pre-warms estimates only, never the distributions.
        costs.seed(
            "fc2",
            LayerCost {
                decode_ns: 500.0,
                decode_samples: 4,
                ..Default::default()
            },
        );
        assert_eq!(costs.decode_hist().count(), 2);
    }

    #[test]
    fn merge_is_sample_weighted() {
        let mut a = LayerCost {
            decode_ns: 100.0,
            decode_samples: 3,
            gemv_ns: 10.0,
            gemv_samples: 1,
        };
        let b = LayerCost {
            decode_ns: 200.0,
            decode_samples: 1,
            gemv_ns: 0.0,
            gemv_samples: 0,
        };
        a.merge(&b);
        assert_eq!(a.decode_ns, 125.0);
        assert_eq!(a.decode_samples, 4);
        assert_eq!(a.gemv_ns, 10.0, "zero-sample side must not dilute");
        assert_eq!(a.gemv_samples, 1);
        // Merging into a default entry adopts the other side wholesale.
        let mut fresh = LayerCost::default();
        fresh.merge(&a);
        assert_eq!(fresh, a);
    }

    #[test]
    fn snapshot_is_name_ordered_and_seed_prewarms() {
        let costs = LayerCosts::new();
        costs.record_decode("fc1", Duration::from_nanos(10));
        costs.record_decode("fc0", Duration::from_nanos(20));
        let snap = costs.snapshot();
        assert_eq!(
            snap.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            vec!["fc0", "fc1"]
        );
        costs.seed(
            "fc2",
            LayerCost {
                decode_ns: 500.0,
                decode_samples: 4,
                ..Default::default()
            },
        );
        assert_eq!(
            costs.get("fc2").unwrap().decode_estimate(),
            Some(500.0),
            "seeded layers start warm"
        );
        assert_eq!(costs.decode_ns_total(), 30, "seeding never inflates totals");
    }

    #[test]
    fn contention_spike_decays_below_the_planning_threshold() {
        // A one-off spike (cache contention, CPU steal) must not
        // steer the Auto planner forever: with the default alpha the
        // estimate re-centers geometrically, dropping below twice the
        // baseline within a bounded number of normal observations.
        let costs = LayerCosts::new(); // DEFAULT_EWMA_ALPHA
        let baseline = Duration::from_nanos(1_000);
        for _ in 0..4 {
            costs.record_decode("fc0", baseline);
        }
        costs.record_decode("fc0", Duration::from_nanos(1_000_000));
        let spiked =
            costs.get("fc0").unwrap().decode_estimate().unwrap();
        assert!(spiked > 200_000.0, "spike visible at first: {spiked}");
        let mut recovered_after = None;
        for n in 1..=24 {
            costs.record_decode("fc0", baseline);
            let est =
                costs.get("fc0").unwrap().decode_estimate().unwrap();
            if est < 2_000.0 {
                recovered_after = Some(n);
                break;
            }
        }
        let n = recovered_after
            .expect("spike must decay below 2x baseline within 24 obs");
        assert!(n <= 24, "recovered after {n} observations");
    }

    #[test]
    fn sample_counts_saturate_and_cap_merge_weight() {
        // Recording past the cap keeps counting at the cap…
        let costs = LayerCosts::with_alpha(0.5);
        for _ in 0..(MAX_COST_SAMPLES + 16) {
            costs.record_decode("fc0", Duration::from_nanos(100));
        }
        assert_eq!(
            costs.get("fc0").unwrap().decode_samples,
            MAX_COST_SAMPLES
        );
        // …and a merge can never be outvoted by an inflated history:
        // a (possibly hand-written) profile claiming 10× the cap still
        // weighs in at the cap, so fresh observations keep half the
        // vote instead of 1/11th.
        let mut stale = LayerCost {
            decode_ns: 1_000_000.0,
            decode_samples: MAX_COST_SAMPLES * 10,
            ..Default::default()
        };
        let fresh = LayerCost {
            decode_ns: 1_000.0,
            decode_samples: MAX_COST_SAMPLES,
            ..Default::default()
        };
        stale.merge(&fresh);
        assert_eq!(stale.decode_ns, (1_000_000.0 + 1_000.0) / 2.0);
        assert_eq!(stale.decode_samples, MAX_COST_SAMPLES);
    }

    #[test]
    fn degenerate_alpha_is_clamped() {
        for bad in [0.0, -1.0, 2.0, f64::NAN, f64::INFINITY] {
            let costs = LayerCosts::with_alpha(bad);
            costs.record_decode("x", Duration::from_nanos(100));
            costs.record_decode("x", Duration::from_nanos(300));
            let est = costs.get("x").unwrap().decode_estimate().unwrap();
            assert!(
                est.is_finite() && est >= 100.0 && est <= 300.0,
                "alpha {bad} produced estimate {est}"
            );
        }
    }
}

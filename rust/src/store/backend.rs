//! Multi-layer serving backend over a [`ModelStore`].
//!
//! Replaces the single-layer-only `NativeBackend` story: a forward pass
//! chains GEMVs through every layer of the compressed model (ReLU between
//! hidden layers, identity on the output layer), fetching each layer's
//! decoded weights from the store as it goes. Under a tight cache budget
//! the store decodes-on-miss and evicts cold layers, so models larger
//! than the decoded-weight budget still serve.
//!
//! The forward pass is readahead-driven: while layer `i` executes, the
//! layers named by the [`ReadaheadPolicy`] (by default, `i+1`, wrapping
//! at the chain end) are warmed asynchronously, so their decode
//! overlaps layer `i`'s GEMVs instead of following them. The executing
//! layer is fetched *pinned* — a readahead install can never evict the
//! layer mid-GEMV, and readahead admission counts the pinned bytes.

use super::readahead::wrapped_targets;
use super::{ModelStore, ReadaheadCandidate, ReadaheadPolicy};
use crate::coordinator::Backend;
use crate::obs;
use anyhow::{bail, Context, Result};
use std::sync::Arc;
use std::time::Instant;

/// Validate a forward chain's dimensions (`rows(Lᵢ) == cols(Lᵢ₊₁)`)
/// and return `(input_dim, output_dim)`. Shared by [`ModelBackend`]
/// and the multi-store [`crate::shard::ShardRouter`].
pub(crate) fn validate_chain(
    names: &[&str],
    dims: &[(usize, usize)],
) -> Result<(usize, usize)> {
    debug_assert_eq!(names.len(), dims.len());
    for (i, w) in dims.windows(2).enumerate() {
        let ((rows_a, _), (_, cols_b)) = (w[0], w[1]);
        if rows_a != cols_b {
            bail!(
                "chain mismatch: {} outputs {rows_a} but {} expects \
                 {cols_b}",
                names[i],
                names[i + 1]
            );
        }
    }
    Ok((dims[0].1, dims[dims.len() - 1].0))
}

/// THE serving inner loop: `links[i]` is the store owning layer `i`
/// plus the layer's name. Per layer: one *pinned* fetch (every request
/// in the batch reuses the Arc, the LRU sees layer-granular traffic,
/// and a readahead install can never evict the executing layer), then
/// the readahead plan's targets warm asynchronously *on their own
/// store* while this layer's GEMVs run, ReLU between hidden layers.
/// The GEMV phase is stamped into the store's [`super::LayerCosts`]
/// (per batch item), closing the telemetry loop the `Auto` planner
/// reads — readahead never changes outputs, only warming, so every
/// policy serves bit-identical results.
///
/// The single-store [`ModelBackend`] and the multi-store
/// [`crate::shard::ShardRouter`] both run exactly this function —
/// which is what makes their outputs bit-identical by construction.
pub(crate) fn forward_chain(
    links: &[(&ModelStore, &str)],
    readahead: ReadaheadPolicy,
    xs: &[Vec<f32>],
) -> Result<Vec<Vec<f32>>> {
    let mut acts: Vec<Vec<f32>> = xs.to_vec();
    let Some(last) = links.len().checked_sub(1) else {
        return Ok(acts); // empty chain: constructors reject this
    };
    // One scratch output reused across every layer × batch item: each
    // GEMV fills it, then it swaps with the activation — zero per-call
    // allocation once it has grown to the widest layer of the chain.
    let mut scratch: Vec<f32> = Vec::new();
    for (i, (store, name)) in links.iter().enumerate() {
        let layer = store
            .get_pinned(name)
            .with_context(|| format!("fetching layer {name:?}"))?;
        // Warm upcoming layers *while this one executes*: their decode
        // overlaps the GEMVs below, and — because the pin is already
        // held — readahead admission correctly accounts for the
        // executing layer's bytes.
        let depth = planned_depth(readahead, links, i, acts.len());
        if depth > 0 {
            obs::event(obs::SpanKind::ReadaheadPlan, name);
        }
        for t in wrapped_targets(i, links.len(), depth) {
            let (ahead_store, ahead_name) = links[t];
            ahead_store.prefetch_async(ahead_name);
        }
        let gemv_start = Instant::now();
        for a in acts.iter_mut() {
            layer.gemv_into(a, &mut scratch);
            if i < last {
                for v in &mut scratch {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            std::mem::swap(a, &mut scratch);
        }
        let gemv_took = gemv_start.elapsed();
        obs::span(obs::SpanKind::Gemv, name, gemv_took);
        store.costs().record_gemv(name, gemv_took, acts.len());
    }
    Ok(acts)
}

/// Decide how deep layer `i`'s readahead warms. `Fixed` answers
/// immediately; `Auto` assembles the planner's inputs from the
/// telemetry at hand — the executing layer's predicted GEMV window
/// (per-item EWMA × batch) and, per candidate target in distance
/// order, the predicted decode cost from *its own* store's table
/// (zero for already-cached targets) plus a budget-fit check that
/// tracks the bytes the plan has committed per store, seeded with the
/// store's whole committed set — every tenant's pinned and in-flight
/// bytes, not just the executing layer's own pin, so concurrent
/// chains sharing one store don't each plan as if they owned the full
/// budget. The store's admission control remains the final
/// gatekeeper; the plan only decides how far to try.
pub(crate) fn planned_depth(
    policy: ReadaheadPolicy,
    links: &[(&ModelStore, &str)],
    i: usize,
    batch_items: usize,
) -> usize {
    let len = links.len();
    let cap = policy.max_depth().min(len.saturating_sub(1));
    if cap == 0 {
        return 0;
    }
    if !policy.is_auto() {
        // Deliberate short-circuit, duplicating plan()'s one-line
        // Fixed clamp: building the candidate list costs per-target
        // store lookups, which a fixed depth never needs.
        return cap;
    }
    let (store, name) = links[i];
    let window = store
        .costs()
        .get(name)
        .and_then(|c| c.gemv_estimate())
        .map(|per_item| per_item * batch_items as f64);
    // Seed with everything the store is already holding for anyone —
    // other tenants' pins and in-flight decodes included. The old
    // seeding (just this layer's planned bytes) let every concurrent
    // chain plan against the full budget at once.
    let mut committed: Vec<(&ModelStore, usize)> =
        vec![(store, store.committed_bytes())];
    let mut candidates = Vec::with_capacity(cap);
    for d in 1..=cap {
        let (ahead_store, ahead_name) = links[(i + d) % len];
        let cached = ahead_store.is_cached(ahead_name);
        let decode_ns = if cached {
            Some(0.0) // warming a resident layer is a dedup no-op
        } else {
            ahead_store
                .costs()
                .get(ahead_name)
                .and_then(|c| c.decode_estimate())
        };
        let need = if cached {
            0
        } else {
            ahead_store.layer_planned_bytes(ahead_name).unwrap_or(0)
        };
        let used = committed
            .iter_mut()
            .find(|(s, _)| std::ptr::eq(*s, ahead_store));
        let fits_budget = match used {
            Some((_, u)) => {
                let fits =
                    u.saturating_add(need) <= ahead_store.budget_bytes();
                if fits {
                    *u = u.saturating_add(need);
                }
                fits
            }
            None => {
                committed.push((ahead_store, need));
                need <= ahead_store.budget_bytes()
            }
        };
        candidates.push(ReadaheadCandidate { decode_ns, fits_budget });
    }
    policy.plan(window, &candidates)
}

/// A sequential GEMV chain (`x → L₀ → ReLU → L₁ → … → L_{n−1}`) served
/// from a [`ModelStore`]; implements the coordinator's [`Backend`].
pub struct ModelBackend {
    store: Arc<ModelStore>,
    chain: Vec<String>,
    readahead: ReadaheadPolicy,
    input_dim: usize,
    output_dim: usize,
}

impl ModelBackend {
    /// Build a backend running `chain` in order, with the default
    /// one-layer-ahead [`ReadaheadPolicy`]. Validates that every layer
    /// exists and consecutive dimensions line up
    /// (`rows(Lᵢ) == cols(Lᵢ₊₁)`) using the index only — nothing is
    /// decoded here.
    pub fn new(store: Arc<ModelStore>, chain: Vec<String>) -> Result<Self> {
        if chain.is_empty() {
            bail!("model chain is empty");
        }
        let mut dims = Vec::with_capacity(chain.len());
        for name in &chain {
            let Some(d) = store.layer_dims(name) else {
                bail!("layer {name:?} not in the model store");
            };
            dims.push(d);
        }
        let names: Vec<&str> = chain.iter().map(String::as_str).collect();
        let (input_dim, output_dim) = validate_chain(&names, &dims)?;
        Ok(ModelBackend {
            input_dim,
            output_dim,
            store,
            chain,
            readahead: ReadaheadPolicy::default(),
        })
    }

    /// Chain every layer of the store in container order.
    pub fn sequential(store: Arc<ModelStore>) -> Result<Self> {
        let chain = store.layer_names();
        Self::new(store, chain)
    }

    /// Replace the readahead policy (builder style).
    pub fn with_readahead(mut self, policy: ReadaheadPolicy) -> Self {
        self.readahead = policy;
        self
    }

    /// Replace the readahead policy in place.
    pub fn set_readahead(&mut self, policy: ReadaheadPolicy) {
        self.readahead = policy;
    }

    /// The active readahead policy.
    pub fn readahead(&self) -> ReadaheadPolicy {
        self.readahead
    }

    /// The underlying store (e.g. to read cache metrics).
    pub fn store(&self) -> &Arc<ModelStore> {
        &self.store
    }

    /// Layer names in forward order.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }

    /// Warm the *front* of the chain: layers are fetched in forward
    /// order but only while they fit in the budget together, so under a
    /// tight budget the first layers — the ones traffic needs first —
    /// are hot when it arrives. (Warming the whole chain would let the
    /// LRU evict exactly those early layers just before traffic.) The
    /// first layer is always warmed, budget or not.
    pub fn prefetch_all(&self) -> Result<()> {
        let budget = self.store.budget_bytes();
        let mut used = 0usize;
        for (i, name) in self.chain.iter().enumerate() {
            let bytes = self.store.layer_planned_bytes(name).unwrap_or(0);
            if i > 0 && used.saturating_add(bytes) > budget {
                break;
            }
            used = used.saturating_add(bytes);
            self.store.prefetch(name)?;
        }
        Ok(())
    }
}

impl Backend for ModelBackend {
    fn forward_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        // Callers entering outside a server-minted trace (examples,
        // benches, direct use) still get a connected timeline.
        let _trace = obs::ensure_trace();
        let links: Vec<(&ModelStore, &str)> = self
            .chain
            .iter()
            .map(|name| (self.store.as_ref(), name.as_str()))
            .collect();
        forward_chain(&links, self.readahead, xs)
    }

    fn input_dim(&self) -> usize {
        self.input_dim
    }

    fn output_dim(&self) -> usize {
        self.output_dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::Container;
    use crate::sparse::DecodedLayer;
    use crate::store::{test_model as model, StoreConfig};

    /// Reference forward pass from serially-decoded layers.
    fn reference(c: &Container, x: &[f32]) -> Vec<f32> {
        let mut a = x.to_vec();
        for (i, l) in c.layers.iter().enumerate() {
            let dec = DecodedLayer::from_compressed(l);
            let mut y = dec.gemv(&a);
            if i + 1 < c.layers.len() {
                for v in &mut y {
                    *v = v.max(0.0);
                }
            }
            a = y;
        }
        a
    }

    #[test]
    fn forward_matches_reference_chain() {
        let c = model(&[20, 16, 12, 8], 7);
        let store = Arc::new(ModelStore::from_container(
            c.clone(),
            StoreConfig::default(),
        ));
        let mut b = ModelBackend::sequential(store).unwrap();
        assert_eq!(b.input_dim(), 20);
        assert_eq!(b.output_dim(), 8);
        assert_eq!(b.chain().join(","), "fc0,fc1,fc2");
        let xs: Vec<Vec<f32>> = (0..3)
            .map(|i| (0..20).map(|j| ((i * j) as f32 * 0.1).sin()).collect())
            .collect();
        let ys = b.forward_batch(&xs).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            let want = reference(&c, x);
            assert_eq!(y.len(), 8);
            for (a, w) in y.iter().zip(&want) {
                assert!((a - w).abs() < 1e-4, "{a} vs {w}");
            }
        }
    }

    #[test]
    fn readahead_off_matches_readahead_on() {
        let c = model(&[20, 16, 12, 8], 17);
        let x: Vec<f32> = (0..20).map(|j| (j as f32 * 0.2).cos()).collect();
        let mut outs = Vec::new();
        for policy in [
            ReadaheadPolicy::off(),
            ReadaheadPolicy::layers(2),
            ReadaheadPolicy::auto(),
        ] {
            let store = Arc::new(ModelStore::from_container(
                c.clone(),
                StoreConfig::default(),
            ));
            let mut b = ModelBackend::sequential(store.clone())
                .unwrap()
                .with_readahead(policy);
            assert_eq!(b.readahead(), policy);
            // Two passes: the second runs auto with a warmed cost
            // model, so the planner path beyond the depth-1 fallback
            // is exercised too.
            let first = b.forward_batch(&[x.clone()]).unwrap();
            let second = b.forward_batch(&[x.clone()]).unwrap();
            assert_eq!(first, second, "{policy}: passes must agree");
            outs.push(first);
            store.wait_for_idle();
            assert_eq!(store.metrics().redundant_decodes, 0);
        }
        assert_eq!(outs[0], outs[1], "policy must not change outputs");
        assert_eq!(outs[0], outs[2], "auto must not change outputs");
    }

    #[test]
    fn forward_records_gemv_and_decode_telemetry() {
        let c = model(&[20, 16, 12], 18);
        let store = Arc::new(ModelStore::from_container(
            c,
            StoreConfig::default(),
        ));
        let mut b = ModelBackend::sequential(store.clone()).unwrap();
        let xs: Vec<Vec<f32>> =
            (0..3).map(|_| vec![0.25; 20]).collect();
        b.forward_batch(&xs).unwrap();
        store.wait_for_idle();
        for name in ["fc0", "fc1"] {
            let cost = store.costs().get(name).unwrap();
            assert_eq!(cost.gemv_samples, 1, "{name}");
            assert!(cost.gemv_estimate().is_some(), "{name}");
            assert_eq!(cost.decode_samples, 1, "{name}");
        }
        let m = store.metrics();
        assert!(m.gemv_ns_total > 0);
        assert!(m.decode_ns_total > 0);
    }

    #[test]
    fn auto_readahead_plans_deeper_once_costs_warm() {
        // Seed a cost model where decode is far cheaper than the GEMV
        // window: the planner must warm the whole remaining chain, and
        // the store must show multi-layer prefetches during the pass.
        let c = model(&[20, 16, 12, 8], 19);
        let store = Arc::new(ModelStore::from_container(
            c,
            StoreConfig::default(),
        ));
        store.seed_costs(store.layer_names().into_iter().map(|n| {
            (
                n,
                crate::store::LayerCost {
                    decode_ns: 1.0,
                    decode_samples: 8,
                    gemv_ns: 1_000_000.0,
                    gemv_samples: 8,
                },
            )
        }));
        let mut b = ModelBackend::sequential(store.clone())
            .unwrap()
            .with_readahead(ReadaheadPolicy::auto());
        b.forward_batch(&[vec![0.5; 20]]).unwrap();
        store.wait_for_idle();
        let m = store.metrics();
        // Layer 0 alone should have warmed fc1 and fc2 (depth 2 of a
        // 3-layer chain); later layers' warms dedup against residents.
        assert!(
            m.prefetches >= 2,
            "warm cost model must plan past depth 1 (prefetches={})",
            m.prefetches
        );
        assert_eq!(m.redundant_decodes, 0);
    }

    #[test]
    fn decode_modes_serve_bit_identical_chains() {
        // The whole point of `DecodeMode`: representation is invisible
        // to callers. Auto over these I8 layers picks fused for wide
        // layers and materialized for narrow ones — the mix must still
        // be bit-exact with the all-dense baseline.
        use crate::kernels::DecodeMode;
        let c = model(&[20, 16, 12, 8], 21);
        let xs: Vec<Vec<f32>> = (0..2)
            .map(|i| {
                (0..20).map(|j| ((i + j) as f32 * 0.3).sin()).collect()
            })
            .collect();
        let mut outs = Vec::new();
        for mode in [
            DecodeMode::Materialized,
            DecodeMode::Fused,
            DecodeMode::Auto,
        ] {
            let store = Arc::new(ModelStore::from_container(
                c.clone(),
                StoreConfig {
                    decode_mode: mode,
                    ..StoreConfig::default()
                },
            ));
            assert_eq!(store.decode_mode(), mode);
            let mut b = ModelBackend::sequential(store.clone()).unwrap();
            outs.push(b.forward_batch(&xs).unwrap());
            store.wait_for_idle();
        }
        assert_eq!(outs[0], outs[1], "fused must be bit-exact");
        assert_eq!(outs[0], outs[2], "auto must be bit-exact");
    }

    #[test]
    fn rejects_incompatible_chain() {
        let c = model(&[20, 16, 12], 8);
        let store = Arc::new(ModelStore::from_container(
            c,
            StoreConfig::default(),
        ));
        // Reversed order: fc1 outputs 12 but fc0 expects 20.
        let err = ModelBackend::new(
            store.clone(),
            vec!["fc1".into(), "fc0".into()],
        )
        .unwrap_err();
        assert!(format!("{err}").contains("chain mismatch"));
        let err = ModelBackend::new(store.clone(), vec![]).unwrap_err();
        assert!(format!("{err}").contains("empty"));
        let err =
            ModelBackend::new(store, vec!["ghost".into()]).unwrap_err();
        assert!(format!("{err}").contains("ghost"));
    }

    #[test]
    fn prefetch_all_warms_chain() {
        let c = model(&[16, 12, 8], 9);
        let store = Arc::new(ModelStore::from_container(
            c,
            StoreConfig::default(),
        ));
        let b = ModelBackend::sequential(store.clone()).unwrap();
        b.prefetch_all().unwrap();
        assert!(store.is_cached("fc0") && store.is_cached("fc1"));
        let m = store.metrics();
        assert_eq!(m.decodes, 2);
    }

    #[test]
    fn prefetch_all_keeps_early_layers_hot_under_tight_budget() {
        // Regression: the old prefetch_all warmed the whole chain in
        // forward order, so a tight budget evicted the *early* layers
        // right before traffic arrived — the opposite of its contract.
        let dims = [16usize, 16, 16, 16, 16];
        let c = model(&dims, 10);
        let budget = 16 * 16 * 4 * 2; // two of four layers fit
        let store = Arc::new(ModelStore::from_container(
            c,
            StoreConfig {
                cache_budget_bytes: budget,
                decode_workers: 1,
                ..StoreConfig::default()
            },
        ));
        let b = ModelBackend::sequential(store.clone()).unwrap();
        b.prefetch_all().unwrap();
        assert!(store.is_cached("fc0"), "first layer must be hot");
        assert!(store.is_cached("fc1"));
        assert!(!store.is_cached("fc2"), "beyond-budget layers skipped");
        assert!(!store.is_cached("fc3"));
        let m = store.metrics();
        assert_eq!(m.decodes, 2, "no wasted decode-then-evict churn");
        assert_eq!(m.evictions, 0);
    }
}

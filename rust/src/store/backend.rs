//! Multi-layer serving backend over a [`ModelStore`].
//!
//! Replaces the single-layer-only `NativeBackend` story: a forward pass
//! chains GEMVs through every layer of the compressed model (ReLU between
//! hidden layers, identity on the output layer), fetching each layer's
//! decoded weights from the store as it goes. Under a tight cache budget
//! the store decodes-on-miss and evicts cold layers, so models larger
//! than the decoded-weight budget still serve.

use super::ModelStore;
use crate::coordinator::Backend;
use anyhow::{bail, Result};
use std::sync::Arc;

/// A sequential GEMV chain (`x → L₀ → ReLU → L₁ → … → L_{n−1}`) served
/// from a [`ModelStore`]; implements the coordinator's [`Backend`].
pub struct ModelBackend {
    store: Arc<ModelStore>,
    chain: Vec<String>,
    input_dim: usize,
    output_dim: usize,
}

impl ModelBackend {
    /// Build a backend running `chain` in order. Validates that every
    /// layer exists and consecutive dimensions line up
    /// (`rows(Lᵢ) == cols(Lᵢ₊₁)`) using the index only — nothing is
    /// decoded here.
    pub fn new(store: Arc<ModelStore>, chain: Vec<String>) -> Result<Self> {
        if chain.is_empty() {
            bail!("model chain is empty");
        }
        let mut dims = Vec::with_capacity(chain.len());
        for name in &chain {
            let Some(d) = store.layer_dims(name) else {
                bail!("layer {name:?} not in the model store");
            };
            dims.push(d);
        }
        for (i, w) in dims.windows(2).enumerate() {
            let ((rows_a, _), (_, cols_b)) = (w[0], w[1]);
            if rows_a != cols_b {
                bail!(
                    "chain mismatch: {} outputs {rows_a} but {} expects \
                     {cols_b}",
                    chain[i],
                    chain[i + 1]
                );
            }
        }
        Ok(ModelBackend {
            input_dim: dims[0].1,
            output_dim: dims[dims.len() - 1].0,
            store,
            chain,
        })
    }

    /// Chain every layer of the store in container order.
    pub fn sequential(store: Arc<ModelStore>) -> Result<Self> {
        let chain = store.layer_names();
        Self::new(store, chain)
    }

    /// The underlying store (e.g. to read cache metrics).
    pub fn store(&self) -> &Arc<ModelStore> {
        &self.store
    }

    /// Layer names in forward order.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }

    /// Warm the whole chain (first layers first, so under a tight budget
    /// the *early* layers are hot when traffic arrives).
    pub fn prefetch_all(&self) -> Result<()> {
        for name in &self.chain {
            self.store.prefetch(name)?;
        }
        Ok(())
    }
}

impl Backend for ModelBackend {
    fn forward_batch(&mut self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let mut acts: Vec<Vec<f32>> = xs.to_vec();
        let last = self.chain.len() - 1;
        for (i, name) in self.chain.iter().enumerate() {
            // One fetch per layer per batch: every request in the batch
            // reuses the Arc, and the LRU sees layer-granular traffic.
            let layer = self
                .store
                .get(name)
                .expect("validated layer must decode");
            for a in acts.iter_mut() {
                let mut y = layer.gemv(a);
                if i < last {
                    for v in &mut y {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
                *a = y;
            }
        }
        acts
    }

    fn input_dim(&self) -> usize {
        self.input_dim
    }

    fn output_dim(&self) -> usize {
        self.output_dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::Container;
    use crate::sparse::DecodedLayer;
    use crate::store::{test_model as model, StoreConfig};

    /// Reference forward pass from serially-decoded layers.
    fn reference(c: &Container, x: &[f32]) -> Vec<f32> {
        let mut a = x.to_vec();
        for (i, l) in c.layers.iter().enumerate() {
            let dec = DecodedLayer::from_compressed(l);
            let mut y = dec.gemv(&a);
            if i + 1 < c.layers.len() {
                for v in &mut y {
                    *v = v.max(0.0);
                }
            }
            a = y;
        }
        a
    }

    #[test]
    fn forward_matches_reference_chain() {
        let c = model(&[20, 16, 12, 8], 7);
        let store = Arc::new(ModelStore::from_container(
            c.clone(),
            StoreConfig::default(),
        ));
        let mut b = ModelBackend::sequential(store).unwrap();
        assert_eq!(b.input_dim(), 20);
        assert_eq!(b.output_dim(), 8);
        assert_eq!(b.chain().join(","), "fc0,fc1,fc2");
        let xs: Vec<Vec<f32>> = (0..3)
            .map(|i| (0..20).map(|j| ((i * j) as f32 * 0.1).sin()).collect())
            .collect();
        let ys = b.forward_batch(&xs);
        for (x, y) in xs.iter().zip(&ys) {
            let want = reference(&c, x);
            assert_eq!(y.len(), 8);
            for (a, w) in y.iter().zip(&want) {
                assert!((a - w).abs() < 1e-4, "{a} vs {w}");
            }
        }
    }

    #[test]
    fn rejects_incompatible_chain() {
        let c = model(&[20, 16, 12], 8);
        let store = Arc::new(ModelStore::from_container(
            c,
            StoreConfig::default(),
        ));
        // Reversed order: fc1 outputs 12 but fc0 expects 20.
        let err = ModelBackend::new(
            store.clone(),
            vec!["fc1".into(), "fc0".into()],
        )
        .unwrap_err();
        assert!(format!("{err}").contains("chain mismatch"));
        let err = ModelBackend::new(store.clone(), vec![]).unwrap_err();
        assert!(format!("{err}").contains("empty"));
        let err =
            ModelBackend::new(store, vec!["ghost".into()]).unwrap_err();
        assert!(format!("{err}").contains("ghost"));
    }

    #[test]
    fn prefetch_all_warms_chain() {
        let c = model(&[16, 12, 8], 9);
        let store = Arc::new(ModelStore::from_container(
            c,
            StoreConfig::default(),
        ));
        let b = ModelBackend::sequential(store.clone()).unwrap();
        b.prefetch_all().unwrap();
        assert!(store.is_cached("fc0") && store.is_cached("fc1"));
        let m = store.metrics();
        assert_eq!(m.decodes, 2);
    }
}

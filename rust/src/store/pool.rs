//! Parallel streaming decode: per-plane work items over a worker pool.
//!
//! `DecodedLayer::from_compressed` walks a layer's planes on one thread.
//! Planes are independent GF(2) streams, though — the paper's hardware
//! decoder exploits exactly this with one XOR network per plane — so the
//! software path can too. [`DecodePool`] flattens `(layer, plane)` pairs
//! into a work queue, drains it from `workers` scoped `std::thread`s
//! (dynamic stealing via an atomic cursor, so a 32-plane FP32 layer next
//! to an 8-plane INT8 layer balances), then reassembles each layer's
//! planes into dense weights in a second parallel phase.

use crate::container::{CompressedLayer, Container};
use crate::decoder::SequentialDecoder;
use crate::gf2::BitVecF2;
use crate::sparse::{assemble, decode_plane, DecodedLayer};
use std::sync::atomic::{AtomicUsize, Ordering};

/// A configurable-width parallel decoder for compressed layers.
#[derive(Debug, Clone)]
pub struct DecodePool {
    workers: usize,
}

impl DecodePool {
    /// A pool with `workers` decode threads (clamped to ≥ 1).
    pub fn new(workers: usize) -> Self {
        DecodePool { workers: workers.max(1) }
    }

    /// A pool sized to the machine (`available_parallelism`, capped at 8
    /// — plane decode is memory-bound and scaling flattens beyond that).
    pub fn default_for_host() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        DecodePool::new(n.min(8))
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Decode one layer, its planes spread across the pool.
    pub fn decode(&self, layer: &CompressedLayer) -> DecodedLayer {
        self.decode_many(&[layer]).pop().expect("one layer in, one out")
    }

    /// Decode a batch of layers; all `(layer, plane)` pairs share one
    /// work queue. Returns decoded layers in input order.
    pub fn decode_many(
        &self,
        layers: &[&CompressedLayer],
    ) -> Vec<DecodedLayer> {
        if layers.is_empty() {
            return Vec::new();
        }
        let decoders: Vec<SequentialDecoder> = layers
            .iter()
            .map(|l| SequentialDecoder::random(l.spec, l.m_seed))
            .collect();
        let items: Vec<(usize, usize)> = layers
            .iter()
            .enumerate()
            .flat_map(|(li, l)| (0..l.planes.len()).map(move |k| (li, k)))
            .collect();

        // Serial fast path: no thread setup for a single worker.
        if self.workers == 1 || items.len() <= 1 {
            let mut planes: Vec<Vec<BitVecF2>> =
                layers.iter().map(|_| Vec::new()).collect();
            for &(li, k) in &items {
                planes[li].push(decode_plane(layers[li], &decoders[li], k));
            }
            return layers
                .iter()
                .zip(&planes)
                .map(|(l, p)| assemble(l, p))
                .collect();
        }

        // Phase 1: decode planes (dynamic work stealing). Threads are
        // scoped per call — simple and borrow-friendly; spawn cost is
        // amortized by plane decode time, and never more threads than
        // work items.
        let spawn = self.workers.min(items.len());
        let cursor = AtomicUsize::new(0);
        let worker_outputs: Vec<Vec<(usize, BitVecF2)>> =
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..spawn)
                    .map(|_| {
                        let cursor = &cursor;
                        let items = &items;
                        let decoders = &decoders;
                        s.spawn(move || {
                            let mut out = Vec::new();
                            loop {
                                let i =
                                    cursor.fetch_add(1, Ordering::Relaxed);
                                if i >= items.len() {
                                    break;
                                }
                                let (li, k) = items[i];
                                let bits = decode_plane(
                                    layers[li],
                                    &decoders[li],
                                    k,
                                );
                                out.push((i, bits));
                            }
                            out
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("decode worker panicked"))
                    .collect()
            });

        // Collect planes back into per-layer, plane-ordered slots.
        let mut planes: Vec<Vec<Option<BitVecF2>>> = layers
            .iter()
            .map(|l| vec![None; l.planes.len()])
            .collect();
        for (i, bits) in worker_outputs.into_iter().flatten() {
            let (li, k) = items[i];
            planes[li][k] = Some(bits);
        }
        let planes: Vec<Vec<BitVecF2>> = planes
            .into_iter()
            .map(|ps| {
                ps.into_iter()
                    .map(|p| p.expect("every plane decoded"))
                    .collect()
            })
            .collect();

        // Phase 2: reassemble layers in parallel (independent per layer).
        let cursor = AtomicUsize::new(0);
        let assembled: Vec<Vec<(usize, DecodedLayer)>> =
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..self.workers.min(layers.len()))
                    .map(|_| {
                        let cursor = &cursor;
                        let planes = &planes;
                        s.spawn(move || {
                            let mut out = Vec::new();
                            loop {
                                let li =
                                    cursor.fetch_add(1, Ordering::Relaxed);
                                if li >= layers.len() {
                                    break;
                                }
                                out.push((
                                    li,
                                    assemble(layers[li], &planes[li]),
                                ));
                            }
                            out
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("assemble worker panicked"))
                    .collect()
            });
        let mut result: Vec<Option<DecodedLayer>> =
            layers.iter().map(|_| None).collect();
        for (li, dl) in assembled.into_iter().flatten() {
            result[li] = Some(dl);
        }
        result
            .into_iter()
            .map(|d| d.expect("every layer assembled"))
            .collect()
    }

    /// Decode every layer of a container.
    pub fn decode_container(&self, c: &Container) -> Vec<DecodedLayer> {
        let refs: Vec<&CompressedLayer> = c.layers.iter().collect();
        self.decode_many(&refs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{quantize_i8, LayerSpec, SyntheticLayer, WeightGen};
    use crate::pipeline::{CompressionConfig, Compressor};

    fn compress(name: &str, rows: usize, cols: usize, seed: u64) -> CompressedLayer {
        let spec = LayerSpec { name: name.into(), rows, cols };
        let layer = SyntheticLayer::generate(&spec, WeightGen::default(), seed);
        let (q, scale) = quantize_i8(&layer.weights);
        let cfg = CompressionConfig {
            sparsity: 0.75,
            n_s: 0,
            ..Default::default()
        };
        let (cl, _) =
            Compressor::new(cfg).compress_i8(name, rows, cols, &q, scale);
        cl
    }

    #[test]
    fn pooled_decode_matches_serial() {
        let layers =
            vec![compress("a", 8, 32, 1), compress("b", 6, 24, 2)];
        let refs: Vec<&CompressedLayer> = layers.iter().collect();
        for workers in [1, 2, 4, 7] {
            let pool = DecodePool::new(workers);
            let pooled = pool.decode_many(&refs);
            assert_eq!(pooled.len(), layers.len());
            for (p, l) in pooled.iter().zip(&layers) {
                let serial = DecodedLayer::from_compressed(l);
                assert_eq!(p.rows, serial.rows);
                assert_eq!(p.cols, serial.cols);
                assert_eq!(
                    p.weights, serial.weights,
                    "workers={workers} diverged on {}",
                    l.name
                );
            }
        }
    }

    #[test]
    fn single_layer_decode_matches_serial() {
        let cl = compress("solo", 8, 40, 3);
        let pool = DecodePool::new(3);
        let pooled = pool.decode(&cl);
        let serial = DecodedLayer::from_compressed(&cl);
        assert_eq!(pooled.weights, serial.weights);
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        assert_eq!(DecodePool::new(0).workers(), 1);
        assert!(DecodePool::default_for_host().workers() >= 1);
    }

    #[test]
    fn empty_batch_is_empty() {
        assert!(DecodePool::new(4).decode_many(&[]).is_empty());
    }
}

//! Parallel streaming decode: per-plane work items over worker threads.
//!
//! `DecodedLayer::from_compressed` walks a layer's planes on one thread.
//! Planes are independent GF(2) streams, though — the paper's hardware
//! decoder exploits exactly this with one XOR network per plane — so the
//! software path can too. Two engines share that plane-granular split:
//!
//! * [`DecodePool`] — synchronous batch decode over *scoped* threads
//!   spawned per call (dynamic stealing via an atomic cursor). Right for
//!   one-shot bulk decodes (benches, offline tools).
//! * [`DecodeService`] — a *persistent* pool of worker threads with an
//!   async submit/wait interface. The serving hot path uses this one:
//!   submitting a layer costs a queue push (no thread spawn), a
//!   [`DecodeHandle`] waits for the result, and an optional completion
//!   callback lets the model store install decoded layers into its cache
//!   the moment the last plane lands — the mechanism behind readahead
//!   (decode of layer `i+1` overlapping layer `i`'s GEMV). Via
//!   [`DecodeService::decode_parse_then`] even the compressed-record
//!   *parse* runs as the task's first worker job, so a readahead submit
//!   costs the caller one queue push regardless of record size.

use crate::container::{CompressedLayer, Container};
use crate::decoder::SequentialDecoder;
use crate::gf2::BitVecF2;
use crate::kernels::{assemble_exec, DecodeMode, ExecLayer};
use crate::obs;
use crate::sparse::{assemble, decode_plane, DecodedLayer};
use crate::sync::{lock_unpoisoned, wait_unpoisoned};
use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A configurable-width parallel decoder for compressed layers.
#[derive(Debug, Clone)]
pub struct DecodePool {
    workers: usize,
}

impl DecodePool {
    /// A pool with `workers` decode threads (clamped to ≥ 1).
    pub fn new(workers: usize) -> Self {
        DecodePool { workers: workers.max(1) }
    }

    /// A pool sized to the machine (`available_parallelism`, capped at 8
    /// — plane decode is memory-bound and scaling flattens beyond that).
    pub fn default_for_host() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        DecodePool::new(n.min(8))
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Decode one layer, its planes spread across the pool.
    pub fn decode(&self, layer: &CompressedLayer) -> DecodedLayer {
        // lint: allow(no-unwrap) -- decode_many returns one output per input
        self.decode_many(&[layer]).pop().expect("one layer in, one out")
    }

    /// Decode a batch of layers; all `(layer, plane)` pairs share one
    /// work queue. Returns decoded layers in input order.
    pub fn decode_many(
        &self,
        layers: &[&CompressedLayer],
    ) -> Vec<DecodedLayer> {
        if layers.is_empty() {
            return Vec::new();
        }
        let decoders: Vec<SequentialDecoder> = layers
            .iter()
            .map(|l| SequentialDecoder::random(l.spec, l.m_seed))
            .collect();
        let items: Vec<(usize, usize)> = layers
            .iter()
            .enumerate()
            .flat_map(|(li, l)| (0..l.planes.len()).map(move |k| (li, k)))
            .collect();

        // Serial fast path: no thread setup for a single worker.
        if self.workers == 1 || items.len() <= 1 {
            let mut planes: Vec<Vec<BitVecF2>> =
                layers.iter().map(|_| Vec::new()).collect();
            for &(li, k) in &items {
                planes[li].push(decode_plane(layers[li], &decoders[li], k));
            }
            return layers
                .iter()
                .zip(&planes)
                // lint: allow(no-unwrap) -- sync batch engine over caller-built layers: plane slots are sized from each layer's own plane list, the one shape `assemble` can reject
                .map(|(l, p)| assemble(l, p).expect("planes match layer"))
                .collect();
        }

        // Phase 1: decode planes (dynamic work stealing). Threads are
        // scoped per call — simple and borrow-friendly; spawn cost is
        // amortized by plane decode time, and never more threads than
        // work items.
        let spawn = self.workers.min(items.len());
        let cursor = AtomicUsize::new(0);
        let worker_outputs: Vec<Vec<(usize, BitVecF2)>> =
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..spawn)
                    .map(|_| {
                        let cursor = &cursor;
                        let items = &items;
                        let decoders = &decoders;
                        s.spawn(move || {
                            let mut out = Vec::new();
                            loop {
                                let i =
                                    cursor.fetch_add(1, Ordering::Relaxed);
                                if i >= items.len() {
                                    break;
                                }
                                let (li, k) = items[i];
                                let bits = decode_plane(
                                    layers[li],
                                    &decoders[li],
                                    k,
                                );
                                out.push((i, bits));
                            }
                            out
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    // lint: allow(no-unwrap) -- sync batch engine: a scoped worker's panic re-raises on the caller, no shared service state to poison
                    .map(|h| h.join().expect("decode worker panicked"))
                    .collect()
            });

        // Collect planes back into per-layer, plane-ordered slots.
        let mut planes: Vec<Vec<Option<BitVecF2>>> = layers
            .iter()
            .map(|l| vec![None; l.planes.len()])
            .collect();
        for (i, bits) in worker_outputs.into_iter().flatten() {
            let (li, k) = items[i];
            planes[li][k] = Some(bits);
        }
        let planes: Vec<Vec<BitVecF2>> = planes
            .into_iter()
            .map(|ps| {
                ps.into_iter()
                    // lint: allow(no-unwrap) -- every slot was filled above or the join already re-raised
                    .map(|p| p.expect("every plane decoded"))
                    .collect()
            })
            .collect();

        // Phase 2: reassemble layers in parallel (independent per layer).
        let cursor = AtomicUsize::new(0);
        let assembled: Vec<Vec<(usize, DecodedLayer)>> =
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..self.workers.min(layers.len()))
                    .map(|_| {
                        let cursor = &cursor;
                        let planes = &planes;
                        s.spawn(move || {
                            let mut out = Vec::new();
                            loop {
                                let li =
                                    cursor.fetch_add(1, Ordering::Relaxed);
                                if li >= layers.len() {
                                    break;
                                }
                                out.push((
                                    li,
                                    // lint: allow(no-unwrap) -- plane slots are sized from each layer's own plane list, the one shape `assemble` can reject
                                    assemble(layers[li], &planes[li])
                                        .expect("planes match layer"),
                                ));
                            }
                            out
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    // lint: allow(no-unwrap) -- sync batch engine: a scoped worker's panic re-raises on the caller, no shared service state to poison
                    .map(|h| h.join().expect("assemble worker panicked"))
                    .collect()
            });
        let mut result: Vec<Option<DecodedLayer>> =
            layers.iter().map(|_| None).collect();
        for (li, dl) in assembled.into_iter().flatten() {
            result[li] = Some(dl);
        }
        result
            .into_iter()
            // lint: allow(no-unwrap) -- one slot per input layer was filled above or the join already re-raised
            .map(|d| d.expect("every layer assembled"))
            .collect()
    }

    /// Decode every layer of a container.
    pub fn decode_container(&self, c: &Container) -> Vec<DecodedLayer> {
        let refs: Vec<&CompressedLayer> = c.layers.iter().collect();
        self.decode_many(&refs)
    }
}

/// A queued unit of background work (one plane decode, or the assembly
/// of a plane-less layer).
type Job = Box<dyn FnOnce() + Send + 'static>;

/// How a layer decode ended: the assembled layer (in whichever
/// representation the task's [`DecodeMode`] picked), or the failure
/// message — a panic, or a shape mismatch the fallible assembly caught
/// (`String`, so every waiter can share it).
pub type DecodeOutcome = std::result::Result<Arc<ExecLayer>, String>;

/// Completion callback invoked by the finishing worker with the
/// outcome and the task's submit→completion wall time — the latency a
/// readahead must actually hide (queue wait included), which is what
/// the store's cost telemetry records.
type OnDone = Box<dyn FnOnce(DecodeOutcome, Duration) + Send + 'static>;

struct ServiceState {
    queue: VecDeque<Job>,
    shutdown: bool,
}

struct ServiceShared {
    state: Mutex<ServiceState>,
    cv: Condvar,
    /// Set when no worker thread could be spawned at construction: jobs
    /// then run inline on the submitting thread — degraded, but every
    /// decode still completes and every waiter still wakes.
    inline: AtomicBool,
}

/// One in-flight layer decode: plane slots filled by workers, assembled
/// by whichever worker finishes last. A panic in any job (malformed
/// plane data) completes the task with an error instead of hanging its
/// waiters or killing the worker.
///
/// The compressed layer itself may arrive in two ways: pre-parsed at
/// submit time ([`DecodeService::decode_async_then`]), or produced by a
/// *parse stage* that runs as the task's first worker job
/// ([`DecodeService::decode_parse_then`]) — so the submitting thread
/// never pays the record parse. [`LayerTask::begin`] arms the task with
/// the layer in both cases, always before any plane job can run.
struct LayerTask {
    /// When the task was submitted; completion stamps the elapsed wall
    /// time into the callback.
    submitted: Instant,
    /// Representation the final assembly produces (resolved per layer
    /// geometry when `Auto`).
    mode: DecodeMode,
    /// Trace id active on the submitting thread, so the decode span a
    /// readahead kicks off attributes to the request that planned it
    /// even though it completes on a worker thread.
    trace: u64,
    /// Set once by [`LayerTask::begin`] before any plane job runs.
    layer: std::sync::OnceLock<Arc<CompressedLayer>>,
    /// Built lazily by the first worker job (tables are up to
    /// `(N_s+1)·2^N_in` entries — too heavy for the submitting thread).
    decoder: std::sync::OnceLock<SequentialDecoder>,
    planes: Mutex<Vec<Option<BitVecF2>>>,
    remaining: AtomicUsize,
    done: Mutex<Option<DecodeOutcome>>,
    cv: Condvar,
    on_done: Mutex<Option<OnDone>>,
}

impl LayerTask {
    fn new(mode: DecodeMode, on_done: Option<OnDone>) -> Self {
        LayerTask {
            submitted: Instant::now(),
            mode,
            trace: obs::current_trace(),
            layer: std::sync::OnceLock::new(),
            decoder: std::sync::OnceLock::new(),
            planes: Mutex::new(Vec::new()),
            remaining: AtomicUsize::new(0),
            done: Mutex::new(None),
            cv: Condvar::new(),
            on_done: Mutex::new(on_done),
        }
    }

    /// Arm the task with its parsed layer. Must be called exactly once,
    /// strictly before any plane job is queued; returns the plane count.
    fn begin(&self, layer: Arc<CompressedLayer>) -> usize {
        let n_planes = layer.planes.len();
        *lock_unpoisoned(&self.planes) = vec![None; n_planes];
        // A plane-less layer still runs one (assembly-only) job.
        self.remaining.store(n_planes.max(1), Ordering::Release);
        let armed = self.layer.set(layer).is_ok();
        debug_assert!(armed, "LayerTask::begin called twice");
        n_planes
    }

    fn layer_name(&self) -> String {
        self.layer
            .get()
            .map(|l| l.name.clone())
            .unwrap_or_default()
    }

    fn run_plane(&self, k: usize) {
        if lock_unpoisoned(&self.done).is_some() {
            // A sibling plane already failed the task: don't burn the
            // worker on dead work that can never be assembled.
            return;
        }
        // Arm-before-queue is the task's contract (`begin` runs before
        // any plane job exists). If it is ever broken, fail the task
        // instead of panicking the worker.
        let Some(layer) = self.layer.get() else {
            self.complete(Err("plane job ran before begin".to_string()));
            return;
        };
        // No lock is held during the decode, so a panic cannot poison
        // shared state; it becomes this task's error outcome.
        let decoded = catch_unwind(AssertUnwindSafe(|| {
            let decoder = self.decoder.get_or_init(|| {
                SequentialDecoder::random(layer.spec, layer.m_seed)
            });
            decode_plane(layer, decoder, k)
        }));
        match decoded {
            Ok(bits) => {
                if let Some(slot) = lock_unpoisoned(&self.planes).get_mut(k) {
                    *slot = Some(bits);
                }
                // Only successful planes decrement, so `finish` runs
                // iff every slot is filled.
                if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                    self.finish();
                }
            }
            Err(_) => self.complete(Err(format!(
                "decode of layer {:?} plane {k} panicked \
                 (malformed plane data?)",
                self.layer_name()
            ))),
        }
    }

    fn finish(&self) {
        let Some(layer) = self.layer.get() else {
            self.complete(Err("assembly ran before begin".to_string()));
            return;
        };
        let assembled = catch_unwind(AssertUnwindSafe(|| {
            let planes: Option<Vec<BitVecF2>> = {
                let mut slots = lock_unpoisoned(&self.planes);
                slots.iter_mut().map(|p| p.take()).collect()
            };
            planes.map(|planes| assemble_exec(layer, &planes, self.mode))
        }));
        match assembled {
            Ok(Some(Ok(layer))) => self.complete(Ok(Arc::new(layer))),
            Ok(Some(Err(msg))) => self.complete(Err(format!(
                "assembly of layer {:?} rejected: {msg}",
                self.layer_name()
            ))),
            Ok(None) => self.complete(Err(format!(
                "assembly of layer {:?} missing a decoded plane",
                self.layer_name()
            ))),
            Err(_) => self.complete(Err(format!(
                "assembly of layer {:?} panicked (malformed layer?)",
                self.layer_name()
            ))),
        }
    }

    /// Publish the outcome (first writer wins), wake waiters, then run
    /// the completion callback outside every lock.
    fn complete(&self, outcome: DecodeOutcome) {
        let cb = {
            let mut done = lock_unpoisoned(&self.done);
            if done.is_some() {
                return;
            }
            *done = Some(outcome.clone());
            lock_unpoisoned(&self.on_done).take()
        };
        self.cv.notify_all();
        // First writer only (the early return above): one decode span
        // per task, covering submit→install (queue wait included).
        let took = self.submitted.elapsed();
        obs::span_for(
            self.trace,
            obs::SpanKind::Decode,
            &self.layer_name(),
            took,
        );
        if let Some(cb) = cb {
            cb(outcome, took);
        }
    }

    fn wait(&self) -> DecodeOutcome {
        let mut done = lock_unpoisoned(&self.done);
        loop {
            if let Some(d) = done.as_ref() {
                return d.clone();
            }
            done = wait_unpoisoned(&self.cv, done);
        }
    }
}

/// Waitable handle to a layer decode submitted to a [`DecodeService`].
pub struct DecodeHandle {
    task: Arc<LayerTask>,
}

impl DecodeHandle {
    /// Block until the layer is fully decoded and assembled. A decode
    /// job that panicked surfaces here as an error, not a hang.
    pub fn wait(&self) -> Result<Arc<ExecLayer>> {
        self.task.wait().map_err(|e| anyhow!("{e}"))
    }

    /// True once the outcome is available without blocking.
    pub fn is_done(&self) -> bool {
        lock_unpoisoned(&self.task.done).is_some()
    }
}

/// Persistent background decode workers with async submit/wait handles.
///
/// Unlike [`DecodePool`], which spawns scoped threads on every call, the
/// service keeps `workers` long-lived threads draining one shared queue
/// of plane-granular jobs. Submitting a decode never blocks and never
/// spawns: the caller gets a [`DecodeHandle`] back immediately, so a
/// readahead can warm layer `i+1` while layer `i`'s GEMV runs on the
/// caller's thread. Plane jobs of concurrently submitted layers
/// interleave, so two cold layers decode together instead of in turn.
///
/// Dropping the service drains queued jobs (no in-flight decode is
/// abandoned), then joins the workers.
pub struct DecodeService {
    shared: Arc<ServiceShared>,
    threads: Vec<JoinHandle<()>>,
}

impl DecodeService {
    /// A service with `workers` persistent threads (clamped to ≥ 1).
    ///
    /// Spawn failure (thread exhaustion) degrades rather than panics:
    /// the service runs with however many workers came up, and with
    /// zero it switches to decoding inline on the submitting thread.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new(ServiceShared {
            state: Mutex::new(ServiceState {
                queue: VecDeque::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
            inline: AtomicBool::new(false),
        });
        let threads: Vec<JoinHandle<()>> = (0..workers)
            .filter_map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("f2f-decode-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .map_err(|e| {
                        crate::obs::events::warn(
                            "decode_worker_spawn_failed",
                            &format!("spawn decode worker {i}: {e}"),
                            &[],
                        );
                    })
                    .ok()
            })
            .collect();
        if threads.is_empty() {
            crate::obs::events::warn(
                "decode_inline_degraded",
                "no decode worker threads available; decoding inline \
                 on submitting threads",
                &[],
            );
            shared.inline.store(true, Ordering::Release);
        }
        DecodeService { shared, threads }
    }

    /// A service sized like [`DecodePool::default_for_host`].
    pub fn default_for_host() -> Self {
        DecodeService::new(DecodePool::default_for_host().workers())
    }

    /// Number of persistent worker threads.
    pub fn workers(&self) -> usize {
        self.threads.len()
    }

    /// Queue a decode; the handle's [`DecodeHandle::wait`] blocks until
    /// all planes are decoded and assembled (to the default
    /// materialized representation). Takes an `Arc` so callers holding
    /// pre-parsed layers share them with the workers instead of
    /// deep-copying plane streams on every miss.
    pub fn decode_async(&self, layer: Arc<CompressedLayer>) -> DecodeHandle {
        self.decode_async_then(layer, |_, _| {})
    }

    /// Queue a decode and run `on_done` (on the finishing worker) with
    /// the outcome — the assembled layer, or the error of a job that
    /// panicked — plus the task's submit→completion wall time (queue
    /// wait included: the latency a warm must hide, which the store's
    /// cost telemetry records). The callback fires exactly once, after
    /// the outcome has been published to the handle.
    pub fn decode_async_then<F>(
        &self,
        layer: Arc<CompressedLayer>,
        on_done: F,
    ) -> DecodeHandle
    where
        F: FnOnce(DecodeOutcome, Duration) + Send + 'static,
    {
        let task = Arc::new(LayerTask::new(
            DecodeMode::Materialized,
            Some(Box::new(on_done)),
        ));
        let n_planes = task.begin(layer);
        spawn_plane_jobs(&self.shared, &task, n_planes);
        DecodeHandle { task }
    }

    /// Queue a decode whose compressed record is *parsed on a worker*:
    /// `parse` runs as the task's first background job, then the plane
    /// jobs fan out from there. The submitting thread pays one queue
    /// push, never the record parse — for a serving thread issuing
    /// readahead this keeps the overlap window intact even for very
    /// large layer records. A `parse` error (or panic) becomes the
    /// task's outcome, exactly like a plane-decode failure. `mode`
    /// picks the representation the final assembly produces (`Auto`
    /// resolves per the parsed layer's geometry).
    pub fn decode_parse_then<P, F>(
        &self,
        parse: P,
        mode: DecodeMode,
        on_done: F,
    ) -> DecodeHandle
    where
        P: FnOnce() -> std::result::Result<Arc<CompressedLayer>, String>
            + Send
            + 'static,
        F: FnOnce(DecodeOutcome, Duration) + Send + 'static,
    {
        let task = Arc::new(LayerTask::new(mode, Some(Box::new(on_done))));
        let t = task.clone();
        let shared = self.shared.clone();
        self.submit(Box::new(move || {
            match catch_unwind(AssertUnwindSafe(parse)) {
                Err(_) => t.complete(Err(
                    "compressed-record parse panicked".to_string(),
                )),
                Ok(Err(msg)) => t.complete(Err(msg)),
                Ok(Ok(layer)) => {
                    let n_planes = t.begin(layer);
                    spawn_plane_jobs(&shared, &t, n_planes);
                }
            }
        }));
        DecodeHandle { task }
    }

    fn submit(&self, job: Job) {
        submit_job(&self.shared, job);
    }
}

/// Queue the plane jobs (or the assembly-only job) of an armed task.
fn spawn_plane_jobs(
    shared: &Arc<ServiceShared>,
    task: &Arc<LayerTask>,
    n_planes: usize,
) {
    if n_planes == 0 {
        let t = task.clone();
        submit_job(shared, Box::new(move || t.finish()));
    } else {
        for k in 0..n_planes {
            let t = task.clone();
            submit_job(shared, Box::new(move || t.run_plane(k)));
        }
    }
}

/// Push one job and wake a worker (also callable from *inside* a worker
/// job — the parse stage queues its plane jobs this way; during drain
/// the submitting worker itself keeps popping until the queue is empty,
/// so mid-shutdown submissions still run).
fn submit_job(shared: &Arc<ServiceShared>, job: Job) {
    if shared.inline.load(Ordering::Acquire) {
        // Degraded mode (no worker threads came up): run the job on
        // the submitting thread. `LayerTask` already converts decode
        // panics into error outcomes; the guard here keeps a panicking
        // completion callback from unwinding into the submitter.
        let _ = catch_unwind(AssertUnwindSafe(job));
        return;
    }
    {
        let mut st = lock_unpoisoned(&shared.state);
        st.queue.push_back(job);
    }
    shared.cv.notify_one();
}

impl Drop for DecodeService {
    fn drop(&mut self) {
        {
            let mut st = lock_unpoisoned(&self.shared.state);
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn worker_loop(shared: &ServiceShared) {
    loop {
        let job = {
            let mut st = lock_unpoisoned(&shared.state);
            loop {
                if let Some(j) = st.queue.pop_front() {
                    break j;
                }
                if st.shutdown {
                    return;
                }
                st = wait_unpoisoned(&shared.cv, st);
            }
        };
        // Belt and braces: `LayerTask` already converts decode panics
        // into error outcomes; this keeps the worker itself alive even
        // if a completion callback panics.
        let _ = catch_unwind(AssertUnwindSafe(job));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{quantize_i8, LayerSpec, SyntheticLayer, WeightGen};
    use crate::pipeline::{CompressionConfig, Compressor};

    fn compress(name: &str, rows: usize, cols: usize, seed: u64) -> CompressedLayer {
        let spec = LayerSpec { name: name.into(), rows, cols };
        let layer = SyntheticLayer::generate(&spec, WeightGen::default(), seed);
        let (q, scale) = quantize_i8(&layer.weights);
        let cfg = CompressionConfig {
            sparsity: 0.75,
            n_s: 0,
            ..Default::default()
        };
        let (cl, _) =
            Compressor::new(cfg).compress_i8(name, rows, cols, &q, scale);
        cl
    }

    #[test]
    fn pooled_decode_matches_serial() {
        let layers =
            vec![compress("a", 8, 32, 1), compress("b", 6, 24, 2)];
        let refs: Vec<&CompressedLayer> = layers.iter().collect();
        for workers in [1, 2, 4, 7] {
            let pool = DecodePool::new(workers);
            let pooled = pool.decode_many(&refs);
            assert_eq!(pooled.len(), layers.len());
            for (p, l) in pooled.iter().zip(&layers) {
                let serial = DecodedLayer::from_compressed(l);
                assert_eq!(p.rows, serial.rows);
                assert_eq!(p.cols, serial.cols);
                assert_eq!(
                    p.weights, serial.weights,
                    "workers={workers} diverged on {}",
                    l.name
                );
            }
        }
    }

    #[test]
    fn single_layer_decode_matches_serial() {
        let cl = compress("solo", 8, 40, 3);
        let pool = DecodePool::new(3);
        let pooled = pool.decode(&cl);
        let serial = DecodedLayer::from_compressed(&cl);
        assert_eq!(pooled.weights, serial.weights);
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        assert_eq!(DecodePool::new(0).workers(), 1);
        assert!(DecodePool::default_for_host().workers() >= 1);
    }

    #[test]
    fn empty_batch_is_empty() {
        assert!(DecodePool::new(4).decode_many(&[]).is_empty());
    }

    #[test]
    fn service_decode_matches_serial() {
        let cl = compress("svc", 8, 40, 9);
        let serial = DecodedLayer::from_compressed(&cl);
        for workers in [1usize, 2, 4] {
            let svc = DecodeService::new(workers);
            let h = svc.decode_async(Arc::new(cl.clone()));
            let decoded = h.wait().unwrap();
            assert_eq!(
                decoded.dense_weights(),
                serial.weights,
                "service workers={workers} diverged"
            );
            assert!(h.is_done());
        }
    }

    #[test]
    fn service_overlapping_submissions_all_complete() {
        let layers: Vec<CompressedLayer> = (0..6)
            .map(|i| compress(&format!("l{i}"), 6, 24, 10 + i as u64))
            .collect();
        let svc = DecodeService::new(3);
        let handles: Vec<DecodeHandle> = layers
            .iter()
            .map(|l| svc.decode_async(Arc::new(l.clone())))
            .collect();
        for (h, l) in handles.iter().zip(&layers) {
            let serial = DecodedLayer::from_compressed(l);
            assert_eq!(
                h.wait().unwrap().dense_weights(),
                serial.weights,
                "{}",
                l.name
            );
        }
    }

    #[test]
    fn service_completion_callback_fires_once() {
        let cl = compress("cb", 8, 32, 20);
        let svc = DecodeService::new(2);
        let fired = Arc::new(AtomicUsize::new(0));
        let f2 = fired.clone();
        let h =
            svc.decode_async_then(Arc::new(cl.clone()), move |outcome, _| {
                let decoded = outcome.expect("well-formed layer decodes");
                assert_eq!(decoded.rows() * decoded.cols(), 8 * 32);
                f2.fetch_add(1, Ordering::SeqCst);
            });
        h.wait().unwrap();
        assert_eq!(fired.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn completion_callback_stamps_submit_to_install_time() {
        // Wait on the callback itself (not the handle): the outcome is
        // published to waiters *before* the callback runs, so blocking
        // on h.wait() alone would race the stamp.
        let cl = compress("stamp", 8, 32, 21);
        let svc = DecodeService::new(2);
        let (tx, rx) = std::sync::mpsc::channel();
        let t0 = Instant::now();
        let h = svc.decode_async_then(Arc::new(cl), move |outcome, took| {
            outcome.expect("well-formed layer decodes");
            tx.send(took).expect("receiver alive");
        });
        let took = rx.recv().expect("callback fired");
        let wall = t0.elapsed();
        assert!(
            took <= wall,
            "stamped {took:?} cannot exceed the submit→recv wall {wall:?}"
        );
        h.wait().unwrap();
    }

    #[test]
    fn panicking_decode_fails_the_handle_instead_of_hanging() {
        // Malform one plane so its decode job panics (a chunk value far
        // beyond the 2^N_in table range): the panic must surface as
        // this layer's error outcome — never a hung waiter, never a
        // dead worker.
        let mut bad = compress("boom", 8, 32, 50);
        bad.planes[0].encoded[0] = u32::MAX;
        let svc = DecodeService::new(2);
        let err = svc.decode_async(Arc::new(bad)).wait();
        assert!(err.is_err(), "panicked decode must report an error");
        // The workers survived: a well-formed decode still succeeds.
        let ok = compress("fine", 8, 32, 51);
        let want = DecodedLayer::from_compressed(&ok);
        let got = svc.decode_async(Arc::new(ok)).wait().unwrap();
        assert_eq!(got.dense_weights(), want.weights);
    }

    #[test]
    fn fused_and_auto_modes_decode_through_the_service() {
        // I8 layers resolve Auto → Fused; either way the assembled
        // representation must stay bit-exact with the dense decode.
        let cl = compress("fused", 8, 70, 52);
        let want = DecodedLayer::from_compressed(&cl);
        let svc = DecodeService::new(2);
        for mode in [DecodeMode::Fused, DecodeMode::Auto] {
            let l = Arc::new(cl.clone());
            let got = svc
                .decode_parse_then(move || Ok(l), mode, |_, _| {})
                .wait()
                .unwrap();
            assert!(got.is_fused(), "{mode} should keep bit-planes resident");
            assert_eq!(got.dense_weights(), want.weights, "{mode}");
        }
    }

    #[test]
    fn parse_stage_runs_on_a_worker_thread() {
        let cl = compress("lazy", 8, 32, 40);
        let want = DecodedLayer::from_compressed(&cl);
        let svc = DecodeService::new(2);
        let submitter = std::thread::current().id();
        let parse_thread =
            Arc::new(Mutex::new(None::<std::thread::ThreadId>));
        let pt = parse_thread.clone();
        let h = svc.decode_parse_then(
            move || {
                *pt.lock().unwrap() = Some(std::thread::current().id());
                Ok(Arc::new(cl))
            },
            DecodeMode::Materialized,
            |_, _| {},
        );
        let decoded = h.wait().unwrap();
        assert_eq!(decoded.dense_weights(), want.weights);
        let ran_on = parse_thread.lock().unwrap().expect("parse ran");
        assert_ne!(
            ran_on, submitter,
            "the record parse must run on a decode worker, \
             not the submitting thread"
        );
    }

    #[test]
    fn parse_stage_errors_and_panics_fail_the_handle() {
        let svc = DecodeService::new(1);
        let err = svc
            .decode_parse_then(
                || Err("record rotted".into()),
                DecodeMode::Materialized,
                |_, _| {},
            )
            .wait()
            .unwrap_err();
        assert!(format!("{err}").contains("record rotted"));
        let err = svc
            .decode_parse_then(
                || panic!("hostile bytes"),
                DecodeMode::Materialized,
                |_, _| {},
            )
            .wait()
            .unwrap_err();
        assert!(format!("{err}").contains("parse panicked"));
        // The worker survived both failures.
        let ok = compress("after", 8, 32, 41);
        let want = DecodedLayer::from_compressed(&ok);
        let got = svc.decode_async(Arc::new(ok)).wait().unwrap();
        assert_eq!(got.dense_weights(), want.weights);
    }

    #[test]
    fn service_drop_drains_queued_jobs() {
        // Submit then drop immediately: the callback must still fire for
        // every queued layer (no abandoned decode).
        let done = Arc::new(AtomicUsize::new(0));
        {
            let svc = DecodeService::new(1);
            for i in 0..4 {
                let cl = compress(&format!("d{i}"), 6, 24, 30 + i as u64);
                let d2 = done.clone();
                svc.decode_async_then(Arc::new(cl), move |_, _| {
                    d2.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop joins after draining
        assert_eq!(done.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn service_clamps_workers() {
        assert_eq!(DecodeService::new(0).workers(), 1);
        assert!(DecodeService::default_for_host().workers() >= 1);
    }

    #[test]
    fn poisoned_service_mutex_does_not_cascade() {
        // Poison the service's queue mutex from a panicking thread —
        // the cascade this module used to exhibit: one panicking holder
        // turned every later submit/worker `.lock().unwrap()` into its
        // own panic, killing the whole service.
        let svc = DecodeService::new(2);
        let shared = svc.shared.clone();
        let _ = std::thread::spawn(move || {
            let _guard = shared.state.lock().unwrap();
            panic!("poison the service mutex");
        })
        .join();
        assert!(
            svc.shared.state.lock().is_err(),
            "the mutex should actually be poisoned"
        );
        // Submitting and completing decodes still works.
        let cl = compress("poisoned", 8, 32, 61);
        let want = DecodedLayer::from_compressed(&cl);
        let got = svc.decode_async(Arc::new(cl)).wait().unwrap();
        assert_eq!(got.dense_weights(), want.weights);
    }

    #[test]
    fn inline_fallback_decodes_without_worker_threads() {
        // Construct the degraded (zero-worker) shape directly — spawn
        // failure is not reproducible on demand — and check the service
        // still completes decodes, inline on the submitting thread.
        let svc = DecodeService {
            shared: Arc::new(ServiceShared {
                state: Mutex::new(ServiceState {
                    queue: VecDeque::new(),
                    shutdown: false,
                }),
                cv: Condvar::new(),
                inline: AtomicBool::new(true),
            }),
            threads: Vec::new(),
        };
        assert_eq!(svc.workers(), 0);
        let cl = compress("inline", 8, 32, 62);
        let want = DecodedLayer::from_compressed(&cl);
        let h = svc.decode_async(Arc::new(cl));
        assert!(h.is_done(), "inline decode completes at submit time");
        assert_eq!(h.wait().unwrap().dense_weights(), want.weights);
        // The parse-stage path also runs inline, including its
        // recursive plane-job submissions.
        let cl = compress("inline2", 6, 24, 63);
        let want = DecodedLayer::from_compressed(&cl);
        let got = svc
            .decode_parse_then(
                move || Ok(Arc::new(cl)),
                DecodeMode::Materialized,
                |_, _| {},
            )
            .wait()
            .unwrap();
        assert_eq!(got.dense_weights(), want.weights);
    }
}

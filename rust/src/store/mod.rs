//! Model store + streaming decode engine: the layer between
//! [`crate::container`] and [`crate::coordinator`].
//!
//! The paper's fixed-to-fixed encoding exists so sparse weights keep a
//! *regular* memory layout and the memory path stays fast; this module is
//! the serving-side counterpart. A compressed model (indexed container
//! v2) is held in memory in compressed form; decoded layers materialize
//! on demand and *ahead of* demand:
//!
//! * [`DecodeService`] — persistent background decode workers with
//!   async submit/wait handles, one `(layer, bit-plane)` job at a time
//!   (decode-stream → correction → invert, assembled by the finishing
//!   worker). The serving hot path never spawns a thread.
//! * [`DecodePool`] — the synchronous scoped-thread batch decoder, for
//!   one-shot bulk decodes (benches, offline tools).
//! * [`ModelStore`] — byte-budgeted LRU cache of decoded layers as a
//!   concurrent subsystem: in-flight decode dedup (a get and a
//!   readahead never double-decode), async
//!   [`ModelStore::prefetch_async`] warming, and pin-while-executing
//!   ([`ModelStore::get_pinned`] → [`PinnedLayer`]) so installs never
//!   evict a layer mid-GEMV. Models larger than the decoded budget
//!   serve by decode-on-miss / evict-cold. Layers cache as
//!   [`crate::kernels::ExecLayer`]s in the representation the store's
//!   [`crate::kernels::DecodeMode`] picks — dense f32, or bit-plane
//!   resident executing the GEMV fused — with every budget decision
//!   priced in that representation.
//! * [`LayerCosts`] — per-layer timing telemetry: EWMA decode
//!   (submit→install) and GEMV costs, recorded at the source (the
//!   decode service stamps completions, the forward chain stamps each
//!   layer's GEMV phase). The cost model everything below consumes.
//! * [`ReadaheadPolicy`] — which layers to warm while layer `i`
//!   executes: a fixed depth (default: `i+1`, wrapping at the chain
//!   end), or `Auto` — a planner that sizes depth-`k` warming so the
//!   predicted decode cost fits the executing layer's predicted GEMV
//!   window and the store budget.
//! * [`ModelBackend`] — a readahead-driven multi-layer forward pass
//!   (sequential GEMV chain, ReLU between hidden layers) that plugs
//!   into the coordinator's [`crate::coordinator::InferenceServer`].
//! * [`RecordSource`] — where the compressed bytes live: owned memory,
//!   or (with the `mmap` feature) a read-only file mapping that pages
//!   in only the records this store decodes. One store per shard of a
//!   [`crate::container::ShardMap`]-split model is the intended
//!   deployment; [`crate::shard::ShardRouter`] chains them, and
//!   [`crate::shard::CostProfile`] serializes each store's cost table
//!   so `f2f rebalance` can re-shard on observed decode time.

mod backend;
mod model_store;
mod pool;
mod readahead;
mod source;
mod timing;

pub use backend::ModelBackend;
pub(crate) use backend::{forward_chain, planned_depth, validate_chain};
pub use model_store::{
    cost_sidecar_path, ModelStore, PinnedLayer, StoreConfig,
    StoreMetrics,
};
pub(crate) use readahead::wrapped_targets;
pub use pool::{DecodeHandle, DecodeOutcome, DecodePool, DecodeService};
pub use readahead::{
    ReadaheadCandidate, ReadaheadPolicy, DEFAULT_AUTO_MAX_DEPTH,
};
pub use source::RecordSource;
pub use timing::{
    LayerCost, LayerCosts, DEFAULT_EWMA_ALPHA, MAX_COST_SAMPLES,
};

/// Build a small compressed INT8 layer chain (`dims[i+1] × dims[i]`,
/// named `fc0..`) — shared scaffolding for the store unit tests, a thin
/// preset over [`crate::models::compressed_mlp`].
#[cfg(test)]
pub(crate) fn test_model(
    dims: &[usize],
    seed: u64,
) -> crate::container::Container {
    crate::models::compressed_mlp(&crate::models::MlpConfig {
        seed,
        sparsity: 0.75,
        n_s: 0,
        beam: None,
        ..crate::models::MlpConfig::new(dims)
    })
    .0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::write_container_v2;
    use std::sync::Arc;

    #[test]
    fn store_backend_pool_compose() {
        // Smoke test across the three pieces; deeper coverage lives in
        // the submodules and `rust/tests/store_serving.rs`.
        let c = test_model(&[16, 12, 8], 40);
        let bytes = write_container_v2(&c);
        let store = Arc::new(
            ModelStore::open_bytes(
                bytes,
                StoreConfig {
                    cache_budget_bytes: usize::MAX,
                    decode_workers: 2,
                    ..StoreConfig::default()
                },
            )
            .unwrap(),
        );
        assert_eq!(store.decode_workers(), 2);
        assert_eq!(store.total_decoded_bytes(), (12 * 16 + 8 * 12) * 4);
        let mut backend = ModelBackend::sequential(store.clone()).unwrap();
        use crate::coordinator::Backend;
        let ys = backend.forward_batch(&[vec![0.5; 16]]).unwrap();
        assert_eq!(ys.len(), 1);
        assert_eq!(ys[0].len(), 8);
        store.wait_for_idle();
        assert!(store.metrics().decodes == 2);
        assert_eq!(store.metrics().redundant_decodes, 0);
    }
}

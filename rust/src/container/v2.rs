//! Container **v2**: the indexed layout (magic `F2F2`).
//!
//! v1 must be parsed front-to-back, so serving one layer of a big model
//! costs a full-file parse. v2 prefixes the same per-layer records with a
//! layer-offset index:
//!
//! ```text
//! "F2F2" | u32 version=2 | u32 n_layers
//! n_layers × { name, u32 rows, u32 cols, u8 dtype, u32 n_planes,
//!              u64 offset, u64 len }          // the index
//! n_layers × <layer record>                   // v1-identical records
//! ```
//!
//! Offsets are absolute file offsets; records are contiguous and in index
//! order, so the index doubles as an integrity check (no gaps, no
//! trailing bytes). Any layer is addressable in `O(index)` without
//! touching the other records — the enabling property for the
//! [`crate::store::ModelStore`] streaming-decode path.

use super::chain::ChainSpec;
use super::serde::{
    dtype_code, dtype_from_code, read_layer, write_layer, Reader, Writer,
};
use super::{CompressedLayer, Container, Dtype};
use anyhow::{bail, Result};

pub(super) const MAGIC_V2: &[u8; 4] = b"F2F2";

/// Hard cap on one layer's decoded (dense f32) size: 1 TiB. Anything
/// larger in an index or record is corruption or an attack, not a
/// model. Shared with the record reader so v1 layers get the same
/// protection as v2 index entries.
pub(super) const MAX_LAYER_DECODED_BYTES: u64 = 1 << 40;

/// Index entry: where one layer's record lives and its summary geometry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerEntry {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub dtype: Dtype,
    pub n_planes: usize,
    /// Absolute byte offset of the layer record.
    pub offset: usize,
    /// Byte length of the layer record.
    pub len: usize,
}

impl LayerEntry {
    /// Weight count. Plain multiplication is safe: [`ContainerIndex::parse`]
    /// rejects geometry whose decoded size would overflow `usize`.
    pub fn n_weights(&self) -> usize {
        self.rows * self.cols
    }

    /// Decoded (dense f32) size in bytes — what a cache entry costs.
    pub fn decoded_bytes(&self) -> usize {
        self.n_weights() * std::mem::size_of::<f32>()
    }
}

/// Parsed v2/v3 index: layer directory without any payload parsing,
/// plus the chains section when the container carries one (v3).
#[derive(Debug, Clone)]
pub struct ContainerIndex {
    entries: Vec<LayerEntry>,
    chains: Vec<ChainSpec>,
}

impl ContainerIndex {
    /// Parse the index of a v2/v3 container. Validates magic, version,
    /// bounds and contiguity of the records (and, for v3, the chains
    /// section); does not touch payloads.
    pub fn parse(bytes: &[u8]) -> Result<ContainerIndex> {
        let mut r = Reader::new(bytes);
        if r.take(4)? != MAGIC_V2 {
            bail!("bad magic: not an F2F v2 container");
        }
        let version = r.u32()?;
        if version != 2 && version != 3 {
            bail!("unsupported v2 container version {version}");
        }
        let n_layers = r.u32()? as usize;
        // Never pre-reserve attacker-controlled sizes.
        let mut entries: Vec<LayerEntry> =
            Vec::with_capacity(n_layers.min(1024));
        for li in 0..n_layers {
            let name = match String::from_utf8(r.bytes()?) {
                Ok(n) => n,
                Err(_) => bail!("index entry {li}: name not utf8"),
            };
            let rows = r.u32()? as usize;
            let cols = r.u32()? as usize;
            let dtype = dtype_from_code(r.u8()?)?;
            let n_planes = r.u32()? as usize;
            let offset = r.u64()? as usize;
            let len = r.u64()? as usize;
            // `rows`/`cols` are untrusted: `n_weights`/`decoded_bytes`
            // arithmetic downstream must never overflow `usize` (panic
            // in debug, silent wraparound corrupting cache-budget
            // accounting in release). Checked multiplication here, and
            // absurd geometry is rejected outright, so plain `*` is
            // safe everywhere after a successful parse.
            let decoded = (rows as u64)
                .checked_mul(cols as u64)
                .and_then(|n| n.checked_mul(4));
            let sane = matches!(
                decoded,
                Some(d)
                    if d <= MAX_LAYER_DECODED_BYTES
                        && usize::try_from(d).is_ok()
            );
            if !sane {
                bail!(
                    "index entry {li} ({name}): absurd geometry \
                     {rows}x{cols} (decoded size overflows or exceeds \
                     {MAX_LAYER_DECODED_BYTES} bytes)"
                );
            }
            let end = offset
                .checked_add(len)
                .filter(|&e| e <= bytes.len());
            if end.is_none() {
                bail!(
                    "index entry {li} ({name}): record [{offset}, +{len}) \
                     out of bounds ({} bytes)",
                    bytes.len()
                );
            }
            entries.push(LayerEntry {
                name,
                rows,
                cols,
                dtype,
                n_planes,
                offset,
                len,
            });
        }
        // v3 inserts the chains section between index and records; it
        // must be consumed here so the contiguity check below starts
        // at the first record. Chains reference index entries by name,
        // so structural validation runs against the entries just read.
        let chains = if version >= 3 {
            let chains = super::chain::read_chains(&mut r)?;
            for chain in &chains {
                chain.validate(|name| {
                    entries.iter().any(|e| e.name == name)
                })?;
            }
            let mut models: Vec<&str> =
                chains.iter().map(|c| c.model.as_str()).collect();
            models.sort_unstable();
            models.dedup();
            if models.len() != chains.len() {
                bail!("duplicate model id in chains section");
            }
            chains
        } else {
            Vec::new()
        };
        // Records must be contiguous: first right after the index (and
        // chains section, for v3), each next at the previous end, last
        // ending at EOF. This catches both truncation and trailing
        // garbage.
        let mut expect = r.pos;
        for (li, e) in entries.iter().enumerate() {
            if e.offset != expect {
                bail!(
                    "index entry {li}: record at {} but expected {expect}",
                    e.offset
                );
            }
            expect += e.len;
        }
        if expect != bytes.len() {
            bail!(
                "container length {} != indexed payload end {expect}",
                bytes.len()
            );
        }
        // The whole-model decoded size must also stay addressable, so
        // `total_decoded_bytes` can sum with plain arithmetic.
        let mut total: u64 = 0;
        for e in &entries {
            total = match total.checked_add(e.decoded_bytes() as u64) {
                Some(t) if usize::try_from(t).is_ok() => t,
                _ => bail!(
                    "index: total decoded size overflows ({} layers)",
                    entries.len()
                ),
            };
        }
        Ok(ContainerIndex { entries, chains })
    }

    /// All entries, in container order.
    pub fn entries(&self) -> &[LayerEntry] {
        &self.entries
    }

    /// Number of layers.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the container holds no layers.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Look an entry up by layer name.
    pub fn find(&self, name: &str) -> Option<&LayerEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Total decoded (dense f32) size of every layer in bytes.
    pub fn total_decoded_bytes(&self) -> usize {
        self.entries.iter().map(|e| e.decoded_bytes()).sum()
    }

    /// The chains section (empty for v1/v2 containers — callers fall
    /// back to [`ChainSpec::uniform`] over [`Self::entries`]).
    pub fn chains(&self) -> &[ChainSpec] {
        &self.chains
    }

    /// Look a chain up by model id.
    pub fn chain_for(&self, model: &str) -> Option<&ChainSpec> {
        self.chains.iter().find(|c| c.model == model)
    }
}

/// Serialize a container in the indexed v2 layout.
pub fn write_container_v2(c: &Container) -> Vec<u8> {
    write_indexed(c, None)
}

/// Serialize a container in the v3 layout: the v2 index plus a chains
/// section recording the executable structure ([`ChainSpec`]).
pub fn write_container_v3(c: &Container, chains: &[ChainSpec]) -> Vec<u8> {
    write_indexed(c, Some(chains))
}

fn write_indexed(c: &Container, chains: Option<&[ChainSpec]>) -> Vec<u8> {
    // Serialize every record first so offsets are known.
    let records: Vec<Vec<u8>> = c
        .layers
        .iter()
        .map(|l| {
            let mut w = Writer::new();
            write_layer(&mut w, l);
            w.buf
        })
        .collect();
    let chain_bytes: Option<Vec<u8>> = chains.map(|chains| {
        let mut w = Writer::new();
        super::chain::write_chains(&mut w, chains);
        w.buf
    });
    let index_size: usize = 4 + 4 + 4
        + c.layers
            .iter()
            .map(|l| 4 + l.name.len() + 4 + 4 + 1 + 4 + 8 + 8)
            .sum::<usize>()
        + chain_bytes.as_ref().map_or(0, Vec::len);
    let payload: usize = records.iter().map(Vec::len).sum();

    let mut w = Writer::new();
    w.buf.reserve(index_size + payload);
    w.buf.extend_from_slice(MAGIC_V2);
    w.u32(if chain_bytes.is_some() { 3 } else { 2 });
    w.u32(c.layers.len() as u32);
    let mut offset = index_size;
    for (layer, rec) in c.layers.iter().zip(&records) {
        w.bytes(layer.name.as_bytes());
        w.u32(layer.rows as u32);
        w.u32(layer.cols as u32);
        w.u8(dtype_code(layer.dtype));
        w.u32(layer.planes.len() as u32);
        w.u64(offset as u64);
        w.u64(rec.len() as u64);
        offset += rec.len();
    }
    if let Some(chain_bytes) = &chain_bytes {
        w.buf.extend_from_slice(chain_bytes);
    }
    debug_assert_eq!(w.buf.len(), index_size);
    for rec in &records {
        w.buf.extend_from_slice(rec);
    }
    w.buf
}

/// Parse a single layer record addressed by an index entry, without
/// touching any other byte of the container.
pub fn read_layer_at(
    bytes: &[u8],
    entry: &LayerEntry,
) -> Result<CompressedLayer> {
    let record = entry
        .offset
        .checked_add(entry.len)
        .and_then(|end| bytes.get(entry.offset..end));
    let Some(record) = record else {
        bail!(
            "layer {}: record [{}, +{}) out of bounds",
            entry.name,
            entry.offset,
            entry.len
        );
    };
    let mut r = Reader::new(record);
    let layer = read_layer(&mut r)?;
    if r.pos != entry.len {
        bail!(
            "layer {}: {} trailing bytes in record",
            entry.name,
            entry.len - r.pos
        );
    }
    if layer.name != entry.name {
        bail!(
            "index/record name mismatch: {:?} vs {:?}",
            entry.name,
            layer.name
        );
    }
    if layer.rows != entry.rows
        || layer.cols != entry.cols
        || layer.dtype != entry.dtype
        || layer.planes.len() != entry.n_planes
    {
        bail!(
            "index/record geometry mismatch for layer {}: index says \
             {}x{} {:?} ({} planes), record says {}x{} {:?} ({} planes)",
            entry.name,
            entry.rows,
            entry.cols,
            entry.dtype,
            entry.n_planes,
            layer.rows,
            layer.cols,
            layer.dtype,
            layer.planes.len()
        );
    }
    Ok(layer)
}

/// True when `bytes` carry the v2 (`F2F2`) magic.
pub fn is_v2(bytes: &[u8]) -> bool {
    bytes.get(..4) == Some(MAGIC_V2.as_slice())
}

/// Parse a whole v2 container eagerly (the [`read_container`] fallback
/// for callers that want every layer).
///
/// [`read_container`]: super::read_container
pub(super) fn read_container_v2(bytes: &[u8]) -> Result<Container> {
    let index = ContainerIndex::parse(bytes)?;
    let layers = index
        .entries()
        .iter()
        .map(|e| read_layer_at(bytes, e))
        .collect::<Result<Vec<_>>>()?;
    Ok(Container { layers })
}

#[cfg(test)]
mod tests {
    use super::super::serde::{assert_layers_eq, sample_container};
    use super::super::{read_container, write_container};
    use super::*;

    #[test]
    fn v2_roundtrip_exact() {
        let c = sample_container(11);
        let bytes = write_container_v2(&c);
        let back = read_container(&bytes).unwrap();
        assert_layers_eq(&c, &back);
    }

    #[test]
    fn index_matches_layers_without_payload_parse() {
        let c = sample_container(12);
        let bytes = write_container_v2(&c);
        let idx = ContainerIndex::parse(&bytes).unwrap();
        assert_eq!(idx.len(), c.layers.len());
        for (e, l) in idx.entries().iter().zip(&c.layers) {
            assert_eq!(e.name, l.name);
            assert_eq!(e.rows, l.rows);
            assert_eq!(e.cols, l.cols);
            assert_eq!(e.dtype, l.dtype);
            assert_eq!(e.n_planes, l.planes.len());
        }
        assert_eq!(
            idx.total_decoded_bytes(),
            c.layers.iter().map(|l| l.n_weights() * 4).sum::<usize>()
        );
    }

    #[test]
    fn random_access_reads_one_layer() {
        let c = sample_container(13);
        let bytes = write_container_v2(&c);
        let idx = ContainerIndex::parse(&bytes).unwrap();
        let e = idx.find("layer2").expect("layer2 indexed");
        let layer = read_layer_at(&bytes, e).unwrap();
        assert_eq!(layer.name, "layer2");
        assert_eq!(layer.rows, c.layers[2].rows);
        assert_eq!(layer.planes, c.layers[2].planes);
        assert!(idx.find("nope").is_none());
    }

    #[test]
    fn v1_still_reads_through_versioned_reader() {
        let c = sample_container(14);
        let v1 = write_container(&c);
        let back = read_container(&v1).unwrap();
        assert_layers_eq(&c, &back);
    }

    #[test]
    fn rejects_truncation_and_garbage() {
        let c = sample_container(15);
        let bytes = write_container_v2(&c);
        for cut in [3usize, 8, 20, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                read_container(&bytes[..cut]).is_err(),
                "cut at {cut} should fail"
            );
        }
        let mut garbage = bytes.clone();
        garbage.push(0);
        assert!(read_container(&garbage).is_err());
    }

    #[test]
    fn rejects_index_out_of_bounds() {
        let c = sample_container(16);
        let mut bytes = write_container_v2(&c);
        // First entry's offset field sits after magic+version+count and
        // the name record (4-byte len + "layer0") + rows/cols/dtype/planes.
        let off_pos = 4 + 4 + 4 + (4 + 6) + 4 + 4 + 1 + 4;
        bytes[off_pos..off_pos + 8]
            .copy_from_slice(&(u64::MAX / 2).to_le_bytes());
        assert!(ContainerIndex::parse(&bytes).is_err());
    }

    #[test]
    fn rejects_index_geometry_mismatch() {
        let c = sample_container(17);
        let mut bytes = write_container_v2(&c);
        // Corrupt entry 0's rows field (right after the name record).
        let rows_pos = 4 + 4 + 4 + (4 + 6);
        let rows = u32::from_le_bytes(
            bytes[rows_pos..rows_pos + 4].try_into().unwrap(),
        );
        bytes[rows_pos..rows_pos + 4]
            .copy_from_slice(&(rows + 1).to_le_bytes());
        // The index itself still parses (payload untouched) but the
        // record read must reject the lie instead of serving wrong dims.
        let idx = ContainerIndex::parse(&bytes).unwrap();
        let err = read_layer_at(&bytes, &idx.entries()[0]).unwrap_err();
        assert!(format!("{err}").contains("geometry mismatch"));
        assert!(read_container(&bytes).is_err());
    }

    #[test]
    fn rejects_geometry_whose_decoded_size_overflows() {
        let c = sample_container(19);
        let template = write_container_v2(&c);
        // Entry 0's rows field sits after magic+version+count and the
        // name record (4-byte len + "layer0"); cols follows rows.
        let rows_pos = 4 + 4 + 4 + (4 + 6);
        // u32::MAX × u32::MAX × 4 overflows u64: must be rejected.
        let mut bytes = template.clone();
        bytes[rows_pos..rows_pos + 4]
            .copy_from_slice(&u32::MAX.to_le_bytes());
        bytes[rows_pos + 4..rows_pos + 8]
            .copy_from_slice(&u32::MAX.to_le_bytes());
        let err = ContainerIndex::parse(&bytes).unwrap_err();
        assert!(format!("{err}").contains("absurd geometry"), "{err}");
        // 2^20 × 2^20 × 4 = 4 TiB: no overflow, but absurd — rejected.
        let mut bytes = template.clone();
        bytes[rows_pos..rows_pos + 4]
            .copy_from_slice(&(1u32 << 20).to_le_bytes());
        bytes[rows_pos + 4..rows_pos + 8]
            .copy_from_slice(&(1u32 << 20).to_le_bytes());
        let err = ContainerIndex::parse(&bytes).unwrap_err();
        assert!(format!("{err}").contains("absurd geometry"), "{err}");
    }

    #[test]
    fn fuzzed_index_corruption_never_panics() {
        // Fuzz-style sweep: every byte of the index region forced to a
        // handful of adversarial values. Parsing must reject or succeed
        // cleanly — never panic, overflow, or over-allocate.
        let c = sample_container(20);
        let bytes = write_container_v2(&c);
        let index_end = ContainerIndex::parse(&bytes).unwrap().entries()[0]
            .offset;
        for pos in 0..index_end {
            for val in [0x00u8, 0x01, 0x7F, 0xFF] {
                if bytes[pos] == val {
                    continue;
                }
                let mut corrupt = bytes.clone();
                corrupt[pos] = val;
                let _ = ContainerIndex::parse(&corrupt);
                let _ = read_container(&corrupt);
            }
        }
    }

    #[test]
    fn is_v2_detects_magic() {
        let c = sample_container(18);
        assert!(is_v2(&write_container_v2(&c)));
        assert!(!is_v2(&write_container(&c)));
        assert!(!is_v2(b"F2"));
    }

    #[test]
    fn v3_round_trips_chains_and_layers() {
        let c = sample_container(21);
        let chains = vec![ChainSpec::uniform(
            "m",
            &["layer0", "layer1", "layer2"],
        )];
        let bytes = write_container_v3(&c, &chains);
        assert!(is_v2(&bytes), "v3 keeps the F2F2 magic");
        let idx = ContainerIndex::parse(&bytes).unwrap();
        assert_eq!(idx.chains(), chains.as_slice());
        assert!(idx.chain_for("m").is_some());
        assert!(idx.chain_for("ghost").is_none());
        // Records stay addressable and eager reads still work.
        let e = idx.find("layer1").unwrap();
        let layer = read_layer_at(&bytes, e).unwrap();
        assert_eq!(layer.name, "layer1");
        let back = read_container(&bytes).unwrap();
        assert_layers_eq(&c, &back);
    }

    #[test]
    fn v2_containers_parse_with_no_chains() {
        let c = sample_container(22);
        let idx =
            ContainerIndex::parse(&write_container_v2(&c)).unwrap();
        assert!(idx.chains().is_empty());
    }

    #[test]
    fn v3_rejects_chains_referencing_missing_layers() {
        let c = sample_container(23);
        let chains = vec![ChainSpec::uniform("m", &["layer0", "ghost"])];
        let bytes = write_container_v3(&c, &chains);
        let err = ContainerIndex::parse(&bytes).unwrap_err();
        assert!(format!("{err}").contains("not in the container"), "{err}");
    }

    #[test]
    fn v3_rejects_duplicate_model_ids() {
        let c = sample_container(24);
        let chains = vec![
            ChainSpec::uniform("m", &["layer0"]),
            ChainSpec::uniform("m", &["layer1"]),
        ];
        let bytes = write_container_v3(&c, &chains);
        let err = ContainerIndex::parse(&bytes).unwrap_err();
        assert!(format!("{err}").contains("duplicate model id"), "{err}");
    }

    #[test]
    fn v3_fuzzed_header_corruption_never_panics() {
        let c = sample_container(25);
        let chains = vec![ChainSpec::uniform(
            "m",
            &["layer0", "layer1", "layer2"],
        )];
        let bytes = write_container_v3(&c, &chains);
        let index_end =
            ContainerIndex::parse(&bytes).unwrap().entries()[0].offset;
        for pos in 0..index_end {
            for val in [0x00u8, 0x01, 0x7F, 0xFF] {
                if bytes[pos] == val {
                    continue;
                }
                let mut corrupt = bytes.clone();
                corrupt[pos] = val;
                let _ = ContainerIndex::parse(&corrupt);
                let _ = read_container(&corrupt);
            }
        }
        for cut in [8usize, 20, bytes.len() / 2, bytes.len() - 1] {
            assert!(read_container(&bytes[..cut]).is_err());
        }
    }
}

//! Layer-kind chains: the executable structure of a container.
//!
//! v1/v2 containers carry only a flat layer list; every serving tier
//! walked it as an implicit uniform GEMV+ReLU ladder. Real models are
//! not ladders: a Transformer block is four attention matmuls feeding
//! a residual add and a two-matmul FFN, a ResNet bottleneck is three
//! convs (as GEMM over im2col patches) plus a skip link. A
//! [`ChainSpec`] records that structure *next to the weights*, so a
//! compressed container round-trips into something executable instead
//! of a naming convention.
//!
//! The container **v3** layout (same `F2F2` magic, version field 3)
//! inserts a chains section between the layer index and the records:
//!
//! ```text
//! "F2F2" | u32 version=3 | u32 n_layers
//! n_layers × <index entry>                    // unchanged from v2
//! u32 n_chains
//! n_chains × { model_id, u32 n_steps, n_steps × <step> }
//! n_layers × <layer record>                   // unchanged from v2
//! ```
//!
//! Each step names the layers it consumes ([`StepKind`]), where its
//! input comes from ([`StepInput`]) and an optional residual source
//! ([`Residual`]). Step execution order is fixed: matmul(s), then the
//! residual add, then the activation — the post-add ReLU of ResNet
//! and the pre-LN-style `x + f(x)` of Transformer sublayers both fit.
//! Old v2 containers keep parsing (no chains section → callers treat
//! the layer list as one implicit [`ChainSpec::uniform`] gemv+relu
//! chain, bit-identical to the historic behavior).

use super::serde::{Reader, Writer};
use anyhow::{bail, Result};

/// Sanity caps: corrupt counts must be rejected before allocation.
const MAX_CHAINS: usize = 4096;
const MAX_STEPS: usize = 1 << 20;

const INPUT_PREV: u32 = 0xFFFF_FFFF;
const INPUT_CHAIN: u32 = 0xFFFF_FFFE;
const RESID_NONE: u32 = 0xFFFF_FFFF;
const RESID_CHAIN: u32 = 0xFFFF_FFFE;
const RESID_OWN_INPUT: u32 = 0xFFFF_FFFD;
/// Step indices at or above the sentinel range are unrepresentable.
const MAX_STEP_REF: u32 = 0xFFFF_FFF0;

/// Elementwise nonlinearity applied after a step's matmul + residual.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    None,
    Relu,
    /// tanh-approximation GELU (Hendrycks & Gimpel 2016).
    Gelu,
}

impl Activation {
    fn code(self) -> u8 {
        match self {
            Activation::None => 0,
            Activation::Relu => 1,
            Activation::Gelu => 2,
        }
    }

    fn from_code(c: u8) -> Result<Activation> {
        match c {
            0 => Ok(Activation::None),
            1 => Ok(Activation::Relu),
            2 => Ok(Activation::Gelu),
            c => bail!("unknown activation code {c}"),
        }
    }

    /// Apply in place.
    pub fn apply(self, xs: &mut [f32]) {
        match self {
            Activation::None => {}
            Activation::Relu => {
                for v in xs.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            Activation::Gelu => {
                for v in xs.iter_mut() {
                    let x = *v;
                    let c = 0.797_884_56_f32; // sqrt(2/π)
                    let t = (c * (x + 0.044_715 * x * x * x)).tanh();
                    *v = 0.5 * x * (1.0 + t);
                }
            }
        }
    }
}

/// Where a step reads its input vector from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepInput {
    /// The previous step's output (the chain input for step 0).
    Prev,
    /// The chain's input vector.
    ChainInput,
    /// An earlier step's output (strictly `< `this step's index).
    Step(usize),
}

/// Where a step's residual add reads from (added to the matmul output
/// before the activation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residual {
    None,
    /// The chain's input vector.
    ChainInput,
    /// This step's own (resolved) input — the classic `x + f(x)`.
    OwnInput,
    /// An earlier step's output.
    Step(usize),
}

/// What one step computes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StepKind {
    /// One dense matmul: `y = W·x`.
    Gemv { layer: String },
    /// One attention sublayer at sequence length 1: all four
    /// projections run (`q = Wq·x`, `k = Wk·x`, `v = Wv·x`), the
    /// single attention score softmaxes to 1, and `y = Wo·v`.
    Attention { q: String, k: String, v: String, output: String },
    /// Conv-as-GEMM over an im2col patch: the layer is
    /// `out_ch × (kh·kw·in_ch)`; an incoming `in_ch` channel vector
    /// is tiled `kh·kw` times (1×1-feature-map im2col semantics) to
    /// form the patch.
    Conv {
        layer: String,
        kh: usize,
        kw: usize,
        in_ch: usize,
        out_ch: usize,
    },
}

impl StepKind {
    fn tag(&self) -> u8 {
        match self {
            StepKind::Gemv { .. } => 0,
            StepKind::Attention { .. } => 1,
            StepKind::Conv { .. } => 2,
        }
    }

    /// Names of the layers this step fetches, in execution order.
    pub fn layer_names(&self) -> Vec<&str> {
        match self {
            StepKind::Gemv { layer } => vec![layer],
            StepKind::Attention { q, k, v, output } => {
                vec![q, k, v, output]
            }
            StepKind::Conv { layer, .. } => vec![layer],
        }
    }
}

/// One step of an executable chain.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChainStep {
    pub kind: StepKind,
    pub input: StepInput,
    pub residual: Residual,
    pub activation: Activation,
}

impl ChainStep {
    /// A plain `y = relu-or-not(W·x)` step on the running activation.
    pub fn gemv(layer: impl Into<String>, activation: Activation) -> Self {
        ChainStep {
            kind: StepKind::Gemv { layer: layer.into() },
            input: StepInput::Prev,
            residual: Residual::None,
            activation,
        }
    }
}

/// The executable structure of one model in a container: an ordered
/// step list over the container's layers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChainSpec {
    /// Model id this chain belongs to (empty in single-model
    /// containers; never contains `"::"` — the registry's name
    /// separator).
    pub model: String,
    pub steps: Vec<ChainStep>,
}

impl ChainSpec {
    /// The implicit chain of a chainless (v1/v2) container: one Gemv
    /// step per layer, ReLU between layers, none after the last —
    /// exactly the ladder the historic serving path executed.
    pub fn uniform<S: AsRef<str>>(
        model: impl Into<String>,
        layers: &[S],
    ) -> ChainSpec {
        let last = layers.len().saturating_sub(1);
        let steps = layers
            .iter()
            .enumerate()
            .map(|(i, name)| {
                ChainStep::gemv(
                    name.as_ref(),
                    if i < last {
                        Activation::Relu
                    } else {
                        Activation::None
                    },
                )
            })
            .collect();
        ChainSpec { model: model.into(), steps }
    }

    /// Every layer name the chain fetches, in execution order
    /// (attention steps contribute four).
    pub fn layer_names(&self) -> Vec<&str> {
        self.steps
            .iter()
            .flat_map(|s| s.kind.layer_names())
            .collect()
    }

    /// Structural validation: every referenced layer exists (per
    /// `exists`), every step/residual reference points strictly
    /// earlier, and the chain is non-empty.
    pub fn validate(&self, exists: impl Fn(&str) -> bool) -> Result<()> {
        if self.steps.is_empty() {
            bail!("chain {:?} has no steps", self.model);
        }
        for (i, step) in self.steps.iter().enumerate() {
            for name in step.kind.layer_names() {
                if !exists(name) {
                    bail!(
                        "chain {:?} step {i}: layer {name:?} is not in \
                         the container",
                        self.model
                    );
                }
            }
            if let StepInput::Step(s) = step.input {
                if s >= i {
                    bail!(
                        "chain {:?} step {i}: input references step {s} \
                         (must be strictly earlier)",
                        self.model
                    );
                }
            }
            if let Residual::Step(s) = step.residual {
                if s >= i {
                    bail!(
                        "chain {:?} step {i}: residual references step \
                         {s} (must be strictly earlier)",
                        self.model
                    );
                }
            }
            if let StepKind::Conv { layer: _, kh, kw, in_ch, out_ch } =
                &step.kind
            {
                let patch = kh
                    .checked_mul(*kw)
                    .and_then(|k| k.checked_mul(*in_ch));
                if *kh == 0
                    || *kw == 0
                    || *in_ch == 0
                    || *out_ch == 0
                    || patch.is_none()
                {
                    bail!(
                        "chain {:?} step {i}: degenerate conv geometry \
                         {kh}x{kw}x{in_ch}->{out_ch}",
                        self.model
                    );
                }
            }
        }
        Ok(())
    }
}

fn write_input(w: &mut Writer, input: StepInput) {
    w.u32(match input {
        StepInput::Prev => INPUT_PREV,
        StepInput::ChainInput => INPUT_CHAIN,
        StepInput::Step(s) => s as u32,
    });
}

fn read_input(r: &mut Reader) -> Result<StepInput> {
    match r.u32()? {
        INPUT_PREV => Ok(StepInput::Prev),
        INPUT_CHAIN => Ok(StepInput::ChainInput),
        s if s < MAX_STEP_REF => Ok(StepInput::Step(s as usize)),
        s => bail!("reserved step-input sentinel {s:#010x}"),
    }
}

fn write_residual(w: &mut Writer, residual: Residual) {
    w.u32(match residual {
        Residual::None => RESID_NONE,
        Residual::ChainInput => RESID_CHAIN,
        Residual::OwnInput => RESID_OWN_INPUT,
        Residual::Step(s) => s as u32,
    });
}

fn read_residual(r: &mut Reader) -> Result<Residual> {
    match r.u32()? {
        RESID_NONE => Ok(Residual::None),
        RESID_CHAIN => Ok(Residual::ChainInput),
        RESID_OWN_INPUT => Ok(Residual::OwnInput),
        s if s < MAX_STEP_REF => Ok(Residual::Step(s as usize)),
        s => bail!("reserved residual sentinel {s:#010x}"),
    }
}

fn read_name(r: &mut Reader, what: &str) -> Result<String> {
    match String::from_utf8(r.bytes()?) {
        Ok(s) => Ok(s),
        Err(_) => bail!("chain {what} not utf8"),
    }
}

/// Serialize the chains section (shared by [`super::write_container_v3`]).
pub(super) fn write_chains(w: &mut Writer, chains: &[ChainSpec]) {
    w.u32(chains.len() as u32);
    for chain in chains {
        w.bytes(chain.model.as_bytes());
        w.u32(chain.steps.len() as u32);
        for step in &chain.steps {
            w.u8(step.kind.tag());
            write_input(w, step.input);
            write_residual(w, step.residual);
            w.u8(step.activation.code());
            match &step.kind {
                StepKind::Gemv { layer } => {
                    w.bytes(layer.as_bytes());
                }
                StepKind::Attention { q, k, v, output } => {
                    w.bytes(q.as_bytes());
                    w.bytes(k.as_bytes());
                    w.bytes(v.as_bytes());
                    w.bytes(output.as_bytes());
                }
                StepKind::Conv { layer, kh, kw, in_ch, out_ch } => {
                    w.bytes(layer.as_bytes());
                    w.u32(*kh as u32);
                    w.u32(*kw as u32);
                    w.u32(*in_ch as u32);
                    w.u32(*out_ch as u32);
                }
            }
        }
    }
}

/// Parse the chains section. Errors (never panics) on truncation,
/// absurd counts, unknown tags/codes and reserved sentinels; callers
/// run [`ChainSpec::validate`] against the layer index afterwards.
pub(super) fn read_chains(r: &mut Reader) -> Result<Vec<ChainSpec>> {
    let n_chains = r.u32()? as usize;
    if n_chains > MAX_CHAINS {
        bail!("chain count {n_chains} exceeds the {MAX_CHAINS} cap");
    }
    let mut chains = Vec::with_capacity(n_chains.min(1024));
    for ci in 0..n_chains {
        let model = read_name(r, "model id")?;
        let n_steps = r.u32()? as usize;
        if n_steps > MAX_STEPS {
            bail!(
                "chain {ci} ({model}): step count {n_steps} exceeds \
                 the {MAX_STEPS} cap"
            );
        }
        let mut steps = Vec::with_capacity(n_steps.min(1024));
        for _ in 0..n_steps {
            let tag = r.u8()?;
            let input = read_input(r)?;
            let residual = read_residual(r)?;
            let activation = Activation::from_code(r.u8()?)?;
            let kind = match tag {
                0 => StepKind::Gemv { layer: read_name(r, "layer")? },
                1 => StepKind::Attention {
                    q: read_name(r, "q layer")?,
                    k: read_name(r, "k layer")?,
                    v: read_name(r, "v layer")?,
                    output: read_name(r, "output layer")?,
                },
                2 => StepKind::Conv {
                    layer: read_name(r, "layer")?,
                    kh: r.u32()? as usize,
                    kw: r.u32()? as usize,
                    in_ch: r.u32()? as usize,
                    out_ch: r.u32()? as usize,
                },
                t => bail!("unknown chain step tag {t}"),
            };
            steps.push(ChainStep { kind, input, residual, activation });
        }
        chains.push(ChainSpec { model, steps });
    }
    Ok(chains)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_chains() -> Vec<ChainSpec> {
        vec![
            ChainSpec::uniform("mlp", &["fc0", "fc1", "fc2"]),
            ChainSpec {
                model: "tf".into(),
                steps: vec![
                    ChainStep {
                        kind: StepKind::Attention {
                            q: "b0/q".into(),
                            k: "b0/k".into(),
                            v: "b0/v".into(),
                            output: "b0/o".into(),
                        },
                        input: StepInput::ChainInput,
                        residual: Residual::OwnInput,
                        activation: Activation::None,
                    },
                    ChainStep {
                        kind: StepKind::Gemv { layer: "b0/ffn1".into() },
                        input: StepInput::Prev,
                        residual: Residual::None,
                        activation: Activation::Gelu,
                    },
                    ChainStep {
                        kind: StepKind::Gemv { layer: "b0/ffn2".into() },
                        input: StepInput::Prev,
                        residual: Residual::Step(0),
                        activation: Activation::None,
                    },
                ],
            },
            ChainSpec {
                model: "cnn".into(),
                steps: vec![
                    ChainStep {
                        kind: StepKind::Conv {
                            layer: "conv1".into(),
                            kh: 3,
                            kw: 3,
                            in_ch: 4,
                            out_ch: 8,
                        },
                        input: StepInput::ChainInput,
                        residual: Residual::None,
                        activation: Activation::Relu,
                    },
                ],
            },
        ]
    }

    fn round_trip(chains: &[ChainSpec]) -> Vec<ChainSpec> {
        let mut w = Writer::new();
        write_chains(&mut w, chains);
        let mut r = Reader::new(&w.buf);
        let back = read_chains(&mut r).unwrap();
        assert_eq!(r.pos, w.buf.len(), "chains section fully consumed");
        back
    }

    #[test]
    fn chains_round_trip_exact() {
        let chains = sample_chains();
        assert_eq!(round_trip(&chains), chains);
        assert_eq!(round_trip(&[]), Vec::<ChainSpec>::new());
    }

    #[test]
    fn uniform_reproduces_the_ladder() {
        let c = ChainSpec::uniform("", &["a", "b", "c"]);
        assert_eq!(c.steps.len(), 3);
        assert_eq!(c.steps[0].activation, Activation::Relu);
        assert_eq!(c.steps[1].activation, Activation::Relu);
        assert_eq!(c.steps[2].activation, Activation::None);
        assert!(c
            .steps
            .iter()
            .all(|s| s.input == StepInput::Prev
                && s.residual == Residual::None));
        assert_eq!(c.layer_names(), vec!["a", "b", "c"]);
    }

    #[test]
    fn attention_contributes_four_layer_names() {
        let chains = sample_chains();
        assert_eq!(
            chains[1].layer_names(),
            vec!["b0/q", "b0/k", "b0/v", "b0/o", "b0/ffn1", "b0/ffn2"]
        );
    }

    #[test]
    fn validate_rejects_missing_layers_and_forward_refs() {
        let chains = sample_chains();
        let names = ["fc0", "fc1", "fc2"];
        assert!(chains[0]
            .validate(|n| names.contains(&n))
            .is_ok());
        let err = chains[0].validate(|_| false).unwrap_err();
        assert!(format!("{err}").contains("not in the container"));

        let mut bad = chains[0].clone();
        bad.steps[0].input = StepInput::Step(2);
        let err = bad.validate(|_| true).unwrap_err();
        assert!(format!("{err}").contains("strictly earlier"), "{err}");

        let mut bad = chains[0].clone();
        bad.steps[1].residual = Residual::Step(1);
        let err = bad.validate(|_| true).unwrap_err();
        assert!(format!("{err}").contains("strictly earlier"), "{err}");

        let empty = ChainSpec { model: "e".into(), steps: vec![] };
        assert!(empty.validate(|_| true).is_err());
    }

    #[test]
    fn validate_rejects_degenerate_conv_geometry() {
        let mut c = sample_chains()[2].clone();
        if let StepKind::Conv { kh, .. } = &mut c.steps[0].kind {
            *kh = 0;
        }
        let err = c.validate(|_| true).unwrap_err();
        assert!(format!("{err}").contains("degenerate conv"), "{err}");
    }

    #[test]
    fn corrupt_chain_bytes_error_cleanly() {
        let mut w = Writer::new();
        write_chains(&mut w, &sample_chains());
        let bytes = w.buf;
        // Truncation at every prefix must error, never panic.
        for cut in 0..bytes.len().min(64) {
            let mut r = Reader::new(&bytes[..cut]);
            assert!(read_chains(&mut r).is_err(), "cut at {cut}");
        }
        // Absurd chain count.
        let mut huge = bytes.clone();
        huge[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        let mut r = Reader::new(&huge);
        assert!(read_chains(&mut r).is_err());
        // Unknown activation / tag / sentinel values.
        for pos in 4..bytes.len().min(96) {
            for val in [0x7Fu8, 0xF3, 0xFF] {
                if bytes[pos] == val {
                    continue;
                }
                let mut corrupt = bytes.clone();
                corrupt[pos] = val;
                let mut r = Reader::new(&corrupt);
                let _ = read_chains(&mut r);
            }
        }
    }

    #[test]
    fn gelu_and_relu_apply() {
        let mut xs = vec![-1.0f32, 0.0, 2.0];
        Activation::Relu.apply(&mut xs);
        assert_eq!(xs, vec![0.0, 0.0, 2.0]);
        let mut ys = vec![-1.0f32, 0.0, 2.0];
        Activation::Gelu.apply(&mut ys);
        assert!(ys[0] < 0.0 && ys[0] > -0.2);
        assert_eq!(ys[1], 0.0);
        assert!(ys[2] > 1.9 && ys[2] < 2.0);
        let mut zs = vec![-3.0f32];
        Activation::None.apply(&mut zs);
        assert_eq!(zs, vec![-3.0]);
    }
}

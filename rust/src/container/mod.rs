//! Compressed-model container: the on-disk/wire format.
//!
//! Stores, per layer: geometry, the decoder spec + `M⊕` seed (the matrix
//! is re-derived, never stored), the pruning mask, and per bit-plane the
//! encoded stream, invert flag and correction stream. All fixed-to-fixed
//! payloads are kept contiguous so a runtime can stream them at full
//! memory bandwidth (the point of the paper).
//!
//! Size accounting follows the paper: `payload_bits` (encoded streams) +
//! `correction_bits` (Eq. 7 terms 2–3) are reported against the original
//! dense size; the mask is accounted separately (§3 assumes the binary
//! mask is stored/compressed independently, citing Lee et al. 2019a).
//!
//! Three wire layouts exist: legacy v1 (`F2F1`, parse front-to-back),
//! the indexed v2 (`F2F2`, per-layer offset index for random access —
//! see [`ContainerIndex`]), and v3 (same magic, version field 3),
//! which adds a chains section recording the executable structure of
//! each model — layer-kind records ([`ChainSpec`]: gemv+activation,
//! attention Q/K/V/output groups, conv-as-GEMM, residual links).
//! [`read_container`] accepts all three; [`write_container_v2`] is the
//! default writer for plain layer tables and [`write_container_v3`]
//! for containers with chains. A v2/v3 container can additionally be
//! partitioned across N stores: the `F2F3` [`ShardMap`] sidecar
//! records the layer → shard assignment and [`split_container`] emits
//! one self-contained v2 file per shard (see [`crate::shard`] for the
//! serving side).

mod chain;
mod serde;
mod shard;
mod v2;

pub use chain::{
    Activation, ChainSpec, ChainStep, Residual, StepInput, StepKind,
};
pub use serde::{read_container, write_container};
pub use shard::{
    is_shard_map, split_container, split_with_map, write_sharded,
    ShardAssignment, ShardMap,
};
pub use v2::{
    is_v2, read_layer_at, write_container_v2, write_container_v3,
    ContainerIndex, LayerEntry,
};

use crate::decoder::DecoderSpec;

/// Weight element type of a compressed layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I8,
}

impl Dtype {
    /// Bits per weight (`n_w`).
    pub fn bits(&self) -> usize {
        match self {
            Dtype::F32 => 32,
            Dtype::I8 => 8,
        }
    }
}

/// One encoded bit-plane.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressedPlane {
    /// Whether the plane was inverted before encoding.
    pub inverted: bool,
    /// Encoded stream (`l + N_s` chunks of `N_in` bits).
    pub encoded: Vec<u32>,
    /// Correction stream for lossless reconstruction.
    pub correction: crate::correction::CorrectionStream,
}

/// One compressed layer.
#[derive(Debug, Clone)]
pub struct CompressedLayer {
    pub name: String,
    /// Row-major shape (rows, cols) of the original matrix.
    pub rows: usize,
    pub cols: usize,
    pub dtype: Dtype,
    /// INT8 dequantization scale (1.0 for F32).
    pub scale: f32,
    /// Decoder geometry shared by all planes of this layer.
    pub spec: DecoderSpec,
    /// Seed regenerating `M⊕`.
    pub m_seed: u64,
    /// Pruning mask (set = unpruned), length `rows·cols`.
    pub mask: crate::gf2::BitVecF2,
    /// `n_w` planes, MSB first.
    pub planes: Vec<CompressedPlane>,
}

impl CompressedLayer {
    /// Number of weights.
    pub fn n_weights(&self) -> usize {
        self.rows * self.cols
    }

    /// Original dense size in bits.
    pub fn original_bits(&self) -> usize {
        self.n_weights() * self.dtype.bits()
    }

    /// Encoded payload bits across planes (`(l+N_s)·N_in` each).
    pub fn payload_bits(&self) -> usize {
        self.planes.iter().map(|p| p.encoded.len() * self.spec.n_in).sum()
    }

    /// Correction bits across planes (+1 invert flag bit per plane).
    pub fn correction_bits(&self) -> usize {
        self.planes
            .iter()
            .map(|p| p.correction.size_bits() + 1)
            .sum()
    }

    /// Compressed bits as the paper accounts them (payload + correction).
    pub fn compressed_bits(&self) -> usize {
        self.payload_bits() + self.correction_bits()
    }

    /// Memory reduction percentage vs. dense (Table 1 / Table 2 metric).
    pub fn memory_reduction(&self) -> f64 {
        (1.0 - self.compressed_bits() as f64 / self.original_bits() as f64)
            * 100.0
    }
}

/// A whole compressed model.
#[derive(Debug, Clone, Default)]
pub struct Container {
    pub layers: Vec<CompressedLayer>,
}

impl Container {
    /// Aggregate original size (bits).
    pub fn original_bits(&self) -> usize {
        self.layers.iter().map(|l| l.original_bits()).sum()
    }

    /// Aggregate compressed size (bits).
    pub fn compressed_bits(&self) -> usize {
        self.layers.iter().map(|l| l.compressed_bits()).sum()
    }

    /// Aggregate memory reduction (%).
    pub fn memory_reduction(&self) -> f64 {
        (1.0
            - self.compressed_bits() as f64 / self.original_bits() as f64)
            * 100.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::correction::CorrectionStream;
    use crate::gf2::BitVecF2;

    fn tiny_layer() -> CompressedLayer {
        let spec = DecoderSpec::new(4, 10, 1);
        CompressedLayer {
            name: "test".into(),
            rows: 4,
            cols: 8,
            dtype: Dtype::I8,
            scale: 0.05,
            spec,
            m_seed: 7,
            mask: BitVecF2::zeros(32),
            planes: (0..8)
                .map(|_| CompressedPlane {
                    inverted: false,
                    encoded: vec![0, 3, 9, 1],
                    correction: CorrectionStream::build(&[], 32, 512),
                })
                .collect(),
        }
    }

    #[test]
    fn size_accounting() {
        let l = tiny_layer();
        assert_eq!(l.original_bits(), 32 * 8);
        assert_eq!(l.payload_bits(), 8 * 4 * 4);
        // Correction per plane: 1 flag vector bit + 1 invert bit = 2.
        assert_eq!(l.correction_bits(), 8 * 2);
        assert!(l.memory_reduction() > 0.0);
    }

    #[test]
    fn container_aggregates() {
        let c = Container { layers: vec![tiny_layer(), tiny_layer()] };
        assert_eq!(c.original_bits(), 2 * 256);
        assert_eq!(
            c.compressed_bits(),
            2 * tiny_layer().compressed_bits()
        );
    }
}

//! Shard map (magic `F2F3`): partitioning a v2 container across stores.
//!
//! A v2 container already makes every layer record independently
//! addressable; the shard map is the missing piece for serving one
//! compressed model from N independent stores. It is a *sidecar* record
//! rather than an embedded section, deliberately: each shard file stays
//! a plain v2 container that any [`crate::store::ModelStore`] can open
//! on its own, and the map travels next to them as a tiny directory of
//! `layer → shard` assignments in original container order (which is
//! also the forward-chain order a router executes).
//!
//! ```text
//! "F2F3" | u32 version=1 | u32 n_shards | u32 n_layers
//! n_layers × { name, u32 shard }
//! ```
//!
//! Assignment is deterministic ([`ShardAssignment`]): round-robin, or
//! greedy by-record-bytes balancing (each layer goes to the currently
//! lightest shard, measured in compressed record bytes — the quantity
//! that drives per-shard file size and mmap paging).

use super::serde::{Reader, Writer};
use super::v2::{read_layer_at, write_container_v2};
use super::{Container, ContainerIndex, LayerEntry};
use anyhow::{bail, Result};
use std::collections::HashSet;

pub(super) const MAGIC_SHARD: &[u8; 4] = b"F2F3";

/// Deterministic layer → shard assignment strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardAssignment {
    /// Layer `i` goes to shard `i % n_shards`.
    RoundRobin,
    /// Each layer (in container order) goes to the shard with the
    /// fewest assigned record bytes so far (ties break to the lowest
    /// shard id).
    ByBytes,
}

/// Which shard owns each layer, in original container (= chain) order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardMap {
    n_shards: usize,
    /// `(layer name, shard id)` in container order.
    assignments: Vec<(String, usize)>,
}

impl ShardMap {
    /// Assign every indexed layer to one of `n_shards` shards.
    pub fn assign(
        index: &ContainerIndex,
        n_shards: usize,
        strategy: ShardAssignment,
    ) -> Result<ShardMap> {
        match strategy {
            ShardAssignment::RoundRobin => {
                if n_shards == 0 {
                    bail!("shard map needs at least one shard");
                }
                let assignments = index
                    .entries()
                    .iter()
                    .enumerate()
                    .map(|(i, e)| (e.name.clone(), i % n_shards))
                    .collect();
                ShardMap::from_assignments(n_shards, assignments)
            }
            ShardAssignment::ByBytes => {
                Self::assign_by_weight(index, n_shards, |e| e.len as f64)
            }
        }
    }

    /// Greedy weighted assignment: each layer, in container (= chain)
    /// order, goes to the shard with the least accumulated `weight` so
    /// far (ties to the lowest shard id — deterministic). The single
    /// balancing loop behind both [`ShardAssignment::ByBytes`]
    /// (weight = compressed record bytes) and the observed-cost
    /// rebalancer in [`crate::shard`] (weight = measured decode ns).
    pub fn assign_by_weight<F>(
        index: &ContainerIndex,
        n_shards: usize,
        mut weight: F,
    ) -> Result<ShardMap>
    where
        F: FnMut(&LayerEntry) -> f64,
    {
        if n_shards == 0 {
            bail!("shard map needs at least one shard");
        }
        let mut load = vec![0.0f64; n_shards];
        let mut assignments = Vec::with_capacity(index.len());
        for e in index.entries() {
            // Least-loaded shard; the tuple comparison breaks load
            // ties toward the lowest shard id (deterministic).
            let shard = load
                .iter()
                .enumerate()
                .min_by(|(sa, a), (sb, b)| {
                    a.total_cmp(b).then(sa.cmp(sb))
                })
                .map(|(s, _)| s)
                .unwrap_or(0);
            if let Some(l) = load.get_mut(shard) {
                *l += weight(e);
            }
            assignments.push((e.name.clone(), shard));
        }
        // Funnel through the validating constructor so even maps built
        // from a pathological index (e.g. duplicate layer names, which
        // the v2 index does not reject) can never serialize a sidecar
        // that ShardMap::parse would refuse to load back.
        ShardMap::from_assignments(n_shards, assignments)
    }

    /// Build a map directly from `(layer name, shard id)` assignments
    /// in container (= chain) order — how externally computed
    /// partitions (e.g. the observed-cost rebalancer in
    /// [`crate::shard`]) become a validated `F2F3` sidecar. Applies
    /// the same rules as [`ShardMap::parse`]: at least one shard, no
    /// assignment to a shard that does not exist, no duplicate layers.
    pub fn from_assignments(
        n_shards: usize,
        assignments: Vec<(String, usize)>,
    ) -> Result<ShardMap> {
        if n_shards == 0 {
            bail!("shard map needs at least one shard");
        }
        let mut seen: HashSet<&str> = HashSet::new();
        for (name, shard) in &assignments {
            if *shard >= n_shards {
                bail!(
                    "layer {name:?} assigned to shard {shard} but only \
                     {n_shards} shards exist"
                );
            }
            if !seen.insert(name.as_str()) {
                bail!("layer {name:?} assigned twice");
            }
        }
        Ok(ShardMap { n_shards, assignments })
    }

    /// Serialize the map (the `F2F3` sidecar record).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.buf.extend_from_slice(MAGIC_SHARD);
        w.u32(1); // version
        w.u32(self.n_shards as u32);
        w.u32(self.assignments.len() as u32);
        for (name, shard) in &self.assignments {
            w.bytes(name.as_bytes());
            w.u32(*shard as u32);
        }
        w.buf
    }

    /// Parse a serialized shard map. Rejects — as errors, never panics —
    /// truncation, trailing bytes, a zero shard count, assignments to
    /// shards that do not exist, and duplicate layer assignments.
    pub fn parse(bytes: &[u8]) -> Result<ShardMap> {
        let mut r = Reader::new(bytes);
        if r.take(4)? != MAGIC_SHARD {
            bail!("bad magic: not an F2F shard map");
        }
        let version = r.u32()?;
        if version != 1 {
            bail!("unsupported shard-map version {version}");
        }
        let n_shards = r.u32()? as usize;
        if n_shards == 0 {
            bail!("shard map declares zero shards");
        }
        let n_layers = r.u32()? as usize;
        // Never pre-reserve attacker-controlled sizes.
        let mut assignments: Vec<(String, usize)> =
            Vec::with_capacity(n_layers.min(1024));
        for li in 0..n_layers {
            let name = match String::from_utf8(r.bytes()?) {
                Ok(n) => n,
                Err(_) => bail!("shard-map entry {li}: name not utf8"),
            };
            assignments.push((name, r.u32()? as usize));
        }
        if r.pos != bytes.len() {
            bail!(
                "{} trailing bytes after shard map",
                bytes.len() - r.pos
            );
        }
        // The semantic invariants (in-range shard ids, no duplicate
        // layers) live in exactly one place: the validating
        // constructor shared with programmatic map builders.
        ShardMap::from_assignments(n_shards, assignments)
    }

    /// Number of shards the map partitions across.
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Number of layers assigned.
    pub fn len(&self) -> usize {
        self.assignments.len()
    }

    /// True when no layers are assigned.
    pub fn is_empty(&self) -> bool {
        self.assignments.is_empty()
    }

    /// `(layer name, shard id)` pairs in container (= chain) order.
    pub fn assignments(&self) -> &[(String, usize)] {
        &self.assignments
    }

    /// The shard owning `name`, if assigned.
    pub fn shard_of(&self, name: &str) -> Option<usize> {
        self.assignments
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| *s)
    }

    /// Names of the layers assigned to `shard`, in chain order.
    pub fn layers_of(&self, shard: usize) -> impl Iterator<Item = &str> {
        self.assignments
            .iter()
            .filter(move |(_, s)| *s == shard)
            .map(|(n, _)| n.as_str())
    }
}

/// True when `bytes` carry the shard-map (`F2F3`) magic.
pub fn is_shard_map(bytes: &[u8]) -> bool {
    bytes.get(..4) == Some(MAGIC_SHARD.as_slice())
}

/// Split serialized v2 container bytes into per-shard v2 containers plus
/// the map describing the partition. Each output is a self-contained v2
/// file holding that shard's layers (in original relative order); the
/// per-layer records round-trip bit-exactly.
pub fn split_container(
    bytes: &[u8],
    n_shards: usize,
    strategy: ShardAssignment,
) -> Result<(ShardMap, Vec<Vec<u8>>)> {
    let index = ContainerIndex::parse(bytes)?;
    let map = ShardMap::assign(&index, n_shards, strategy)?;
    let shards = split_with_map(bytes, &map)?;
    Ok((map, shards))
}

/// Split serialized v2 container bytes under an externally supplied
/// map — how a cost-rebalanced [`ShardMap`] (see [`crate::shard`])
/// becomes per-shard files. The map must cover *exactly* the
/// container's indexed layers; a map naming missing or extra layers is
/// stale and rejected as an error, never a panic.
pub fn split_with_map(
    bytes: &[u8],
    map: &ShardMap,
) -> Result<Vec<Vec<u8>>> {
    let index = ContainerIndex::parse(bytes)?;
    if map.len() != index.len() {
        bail!(
            "shard map assigns {} layers but the container indexes {} \
             — stale map?",
            map.len(),
            index.len()
        );
    }
    let mut per: Vec<Container> =
        (0..map.n_shards()).map(|_| Container::default()).collect();
    for entry in index.entries() {
        let Some(shard) = map.shard_of(&entry.name) else {
            bail!(
                "layer {:?} is in the container but not the shard map \
                 — stale map?",
                entry.name
            );
        };
        let Some(c) = per.get_mut(shard) else {
            bail!(
                "layer {:?} assigned to shard {shard}, but the map has \
                 only {} shards",
                entry.name,
                map.n_shards()
            );
        };
        c.layers.push(read_layer_at(bytes, entry)?);
    }
    Ok(per.iter().map(write_container_v2).collect())
}

/// Partition an in-memory container: serialize to the indexed v2 layout
/// and [`split_container`] it.
///
/// This deliberately routes through the serialized form even though the
/// layers are already in memory: by-bytes assignment needs real record
/// sizes (known only after serialization), and funneling every split
/// through the one parse-validated path keeps CLI-split and in-memory
/// shard files byte-identical. The extra encode/parse is a one-time
/// startup cost, never on the serving path.
pub fn write_sharded(
    c: &Container,
    n_shards: usize,
    strategy: ShardAssignment,
) -> Result<(ShardMap, Vec<Vec<u8>>)> {
    split_container(&write_container_v2(c), n_shards, strategy)
}

#[cfg(test)]
mod tests {
    use super::super::read_container;
    use super::super::serde::sample_container;
    use super::*;

    fn sample_bytes(seed: u64) -> Vec<u8> {
        write_container_v2(&sample_container(seed))
    }

    /// Hand-built map bytes (for shapes `assign` can never produce).
    fn raw_map(entries: &[(&str, u32)], n_shards: u32) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(MAGIC_SHARD);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&n_shards.to_le_bytes());
        b.extend_from_slice(&(entries.len() as u32).to_le_bytes());
        for (name, shard) in entries {
            b.extend_from_slice(&(name.len() as u32).to_le_bytes());
            b.extend_from_slice(name.as_bytes());
            b.extend_from_slice(&shard.to_le_bytes());
        }
        b
    }

    #[test]
    fn round_robin_interleaves_in_order() {
        let bytes = sample_bytes(30);
        let index = ContainerIndex::parse(&bytes).unwrap();
        let map =
            ShardMap::assign(&index, 2, ShardAssignment::RoundRobin)
                .unwrap();
        assert_eq!(map.n_shards(), 2);
        assert_eq!(map.len(), 3);
        assert_eq!(map.shard_of("layer0"), Some(0));
        assert_eq!(map.shard_of("layer1"), Some(1));
        assert_eq!(map.shard_of("layer2"), Some(0));
        assert_eq!(map.shard_of("ghost"), None);
        assert_eq!(
            map.layers_of(0).collect::<Vec<_>>(),
            vec!["layer0", "layer2"]
        );
    }

    #[test]
    fn by_bytes_balances_record_sizes() {
        // sample_container's layer0 is FP32 (32 planes) — by far the
        // largest record — so greedy balancing must put layer1 on the
        // other shard instead of round-robin's blind interleave.
        let bytes = sample_bytes(31);
        let index = ContainerIndex::parse(&bytes).unwrap();
        let map = ShardMap::assign(&index, 2, ShardAssignment::ByBytes)
            .unwrap();
        assert_eq!(map.shard_of("layer0"), Some(0));
        assert_eq!(map.shard_of("layer1"), Some(1));
        // Deterministic: the same input maps identically every time.
        let again = ShardMap::assign(&index, 2, ShardAssignment::ByBytes)
            .unwrap();
        assert_eq!(map, again);
    }

    #[test]
    fn map_serialization_round_trips() {
        let bytes = sample_bytes(32);
        let index = ContainerIndex::parse(&bytes).unwrap();
        for strategy in
            [ShardAssignment::RoundRobin, ShardAssignment::ByBytes]
        {
            let map = ShardMap::assign(&index, 3, strategy).unwrap();
            let wire = map.to_bytes();
            assert!(is_shard_map(&wire));
            assert!(!is_shard_map(&bytes));
            assert_eq!(ShardMap::parse(&wire).unwrap(), map);
        }
    }

    #[test]
    fn split_produces_bit_exact_shard_records() {
        let c = sample_container(33);
        let bytes = write_container_v2(&c);
        let (map, shards) =
            split_container(&bytes, 2, ShardAssignment::RoundRobin)
                .unwrap();
        assert_eq!(shards.len(), 2);
        let index = ContainerIndex::parse(&bytes).unwrap();
        for (name, shard) in map.assignments() {
            let e = index.find(name).expect("layer indexed");
            let sidx = ContainerIndex::parse(&shards[*shard]).unwrap();
            let se = sidx.find(name).expect("layer in its shard");
            assert_eq!(
                &bytes[e.offset..e.offset + e.len],
                &shards[*shard][se.offset..se.offset + se.len],
                "record of {name} must survive the split bit-exactly"
            );
        }
        // Each shard is a self-contained, readable v2 container.
        let union: usize = shards
            .iter()
            .map(|s| read_container(s).unwrap().layers.len())
            .sum();
        assert_eq!(union, c.layers.len());
    }

    #[test]
    fn more_shards_than_layers_leaves_valid_empty_shards() {
        let c = sample_container(34);
        let (map, shards) =
            write_sharded(&c, 5, ShardAssignment::RoundRobin).unwrap();
        assert_eq!(map.n_shards(), 5);
        assert_eq!(shards.len(), 5);
        for s in &shards[3..] {
            assert!(read_container(s).unwrap().layers.is_empty());
        }
    }

    #[test]
    fn from_assignments_validates_like_parse() {
        let map = ShardMap::from_assignments(
            2,
            vec![("a".into(), 1), ("b".into(), 0)],
        )
        .unwrap();
        assert_eq!(map.n_shards(), 2);
        assert_eq!(map.shard_of("a"), Some(1));
        // And it round-trips through the wire format.
        assert_eq!(ShardMap::parse(&map.to_bytes()).unwrap(), map);
        assert!(ShardMap::from_assignments(0, vec![]).is_err());
        assert!(ShardMap::from_assignments(
            2,
            vec![("a".into(), 2)]
        )
        .is_err());
        assert!(ShardMap::from_assignments(
            2,
            vec![("a".into(), 0), ("a".into(), 1)]
        )
        .is_err());
    }

    #[test]
    fn split_with_map_honors_external_maps_and_rejects_stale_ones() {
        let c = sample_container(36);
        let bytes = write_container_v2(&c);
        // An external (hand-built) partition: everything on shard 1.
        let map = ShardMap::from_assignments(
            2,
            c.layers.iter().map(|l| (l.name.clone(), 1)).collect(),
        )
        .unwrap();
        let shards = split_with_map(&bytes, &map).unwrap();
        assert!(read_container(&shards[0]).unwrap().layers.is_empty());
        assert_eq!(
            read_container(&shards[1]).unwrap().layers.len(),
            c.layers.len()
        );
        // Stale maps error instead of panicking: wrong layer count...
        let short = ShardMap::from_assignments(
            2,
            vec![(c.layers[0].name.clone(), 0)],
        )
        .unwrap();
        assert!(split_with_map(&bytes, &short).is_err());
        // ...and right count but wrong names.
        let renamed = ShardMap::from_assignments(
            2,
            c.layers
                .iter()
                .map(|l| (format!("{}-renamed", l.name), 0))
                .collect(),
        )
        .unwrap();
        assert!(split_with_map(&bytes, &renamed).is_err());
    }

    #[test]
    fn zero_shards_is_an_error_everywhere() {
        let bytes = sample_bytes(35);
        let index = ContainerIndex::parse(&bytes).unwrap();
        assert!(ShardMap::assign(&index, 0, ShardAssignment::RoundRobin)
            .is_err());
        assert!(
            split_container(&bytes, 0, ShardAssignment::ByBytes).is_err()
        );
        let err = ShardMap::parse(&raw_map(&[], 0)).unwrap_err();
        assert!(format!("{err}").contains("zero shards"), "{err}");
    }

    #[test]
    fn parse_rejects_missing_shard_and_duplicates() {
        let err = ShardMap::parse(&raw_map(&[("a", 0), ("b", 7)], 2))
            .unwrap_err();
        assert!(
            format!("{err}").contains("only 2 shards exist"),
            "{err}"
        );
        let err = ShardMap::parse(&raw_map(&[("a", 0), ("a", 1)], 2))
            .unwrap_err();
        assert!(format!("{err}").contains("assigned twice"), "{err}");
    }

    #[test]
    fn parse_rejects_truncation_and_trailing_bytes() {
        let wire = raw_map(&[("layer0", 0), ("layer1", 1)], 2);
        for cut in 0..wire.len() {
            assert!(
                ShardMap::parse(&wire[..cut]).is_err(),
                "cut at {cut} should fail"
            );
        }
        let mut garbage = wire.clone();
        garbage.push(0);
        assert!(ShardMap::parse(&garbage).is_err());
    }

    #[test]
    fn fuzzed_shard_map_corruption_never_panics() {
        // Counterpart of the container-index fuzz sweep: every byte of
        // the map forced to adversarial values must parse cleanly or
        // reject cleanly — never panic or over-allocate.
        let wire = raw_map(&[("layer0", 0), ("layer1", 1)], 2);
        for pos in 0..wire.len() {
            for val in [0x00u8, 0x01, 0x7F, 0xFF] {
                if wire[pos] == val {
                    continue;
                }
                let mut corrupt = wire.clone();
                corrupt[pos] = val;
                let _ = ShardMap::parse(&corrupt);
            }
        }
    }
}

//! Byte-level (de)serialization of [`Container`] — no external crates.
//!
//! Layout: little-endian, length-prefixed. Two wire versions share one
//! per-layer record codec ([`write_layer`] / [`read_layer`]):
//!
//! * **v1** (magic `F2F1`): header + layer records back to back; the
//!   whole file must be parsed front-to-back.
//! * **v2** (magic `F2F2`, see [`super::v2`]): a layer-offset index up
//!   front so any record is addressable without touching the others.
//!
//! [`read_container`] accepts both.

use super::{CompressedLayer, CompressedPlane, Container, Dtype};
use crate::correction::CorrectionStream;
use crate::decoder::DecoderSpec;
use crate::gf2::BitVecF2;
use anyhow::{bail, Result};

const MAGIC: &[u8; 4] = b"F2F1";

pub(super) fn dtype_code(d: Dtype) -> u8 {
    match d {
        Dtype::F32 => 0,
        Dtype::I8 => 1,
    }
}

pub(super) fn dtype_from_code(code: u8) -> Result<Dtype> {
    match code {
        0 => Ok(Dtype::F32),
        1 => Ok(Dtype::I8),
        d => bail!("unknown dtype {d}"),
    }
}

pub(super) struct Writer {
    pub(super) buf: Vec<u8>,
}

impl Writer {
    pub(super) fn new() -> Self {
        Writer { buf: Vec::new() }
    }
    pub(super) fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    pub(super) fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub(super) fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub(super) fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub(super) fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }
    pub(super) fn u32s_vec(&mut self, v: &[u32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    pub(super) fn words(&mut self, v: &[u64]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.u64(x);
        }
    }
    pub(super) fn bitvec(&mut self, v: &BitVecF2) {
        self.u64(v.len() as u64);
        self.words(v.words());
    }
}

pub(super) struct Reader<'a> {
    pub(super) buf: &'a [u8],
    pub(super) pos: usize,
}

impl<'a> Reader<'a> {
    pub(super) fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }
    pub(super) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).ok_or_else(|| {
            anyhow::anyhow!(
                "container length overflows at offset {}",
                self.pos
            )
        })?;
        let Some(s) = self.buf.get(self.pos..end) else {
            bail!("container truncated at offset {}", self.pos);
        };
        self.pos = end;
        Ok(s)
    }
    /// Exactly `N` bytes as a fixed-size array (the `from_le_bytes`
    /// shape), so the scalar accessors below never index or unwrap.
    fn array<const N: usize>(&mut self) -> Result<[u8; N]> {
        let b = self.take(N)?;
        b.try_into().map_err(|_| {
            anyhow::anyhow!("internal: reader returned a wrong-size slice")
        })
    }
    pub(super) fn u8(&mut self) -> Result<u8> {
        let [b] = self.array()?;
        Ok(b)
    }
    pub(super) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.array()?))
    }
    pub(super) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.array()?))
    }
    pub(super) fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.array()?))
    }
    pub(super) fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }
    pub(super) fn u32s_vec(&mut self) -> Result<Vec<u32>> {
        let n = self.u32()? as usize;
        let byte_len = n.checked_mul(4).ok_or_else(|| {
            anyhow::anyhow!("u32 array length {n} overflows")
        })?;
        let raw = self.take(byte_len)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| {
                // lint: allow(no-unwrap) -- chunks_exact(4) yields exactly 4 bytes
                u32::from_le_bytes(c.try_into().unwrap())
            })
            .collect())
    }
    pub(super) fn words(&mut self) -> Result<Vec<u64>> {
        let n = self.u32()? as usize;
        let byte_len = n.checked_mul(8).ok_or_else(|| {
            anyhow::anyhow!("word array length {n} overflows")
        })?;
        let raw = self.take(byte_len)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| {
                // lint: allow(no-unwrap) -- chunks_exact(8) yields exactly 8 bytes
                u64::from_le_bytes(c.try_into().unwrap())
            })
            .collect())
    }
    pub(super) fn bitvec(&mut self) -> Result<BitVecF2> {
        let len = self.u64()? as usize;
        let words = self.words()?;
        if words.len() != len.div_ceil(64) {
            bail!("bitvec word count mismatch");
        }
        Ok(BitVecF2::from_words(words, len))
    }
}

/// Serialize one layer record (shared by the v1 body and v2 payload).
pub(super) fn write_layer(w: &mut Writer, layer: &CompressedLayer) {
    w.bytes(layer.name.as_bytes());
    w.u32(layer.rows as u32);
    w.u32(layer.cols as u32);
    w.u8(dtype_code(layer.dtype));
    w.f32(layer.scale);
    w.u32(layer.spec.n_in as u32);
    w.u32(layer.spec.n_out as u32);
    w.u32(layer.spec.n_s as u32);
    w.u64(layer.m_seed);
    w.bitvec(&layer.mask);
    w.u32(layer.planes.len() as u32);
    for p in &layer.planes {
        w.u8(p.inverted as u8);
        w.u32s_vec(&p.encoded);
        let (fw, fl, pw, pl) = p.correction.to_words();
        w.u32(p.correction.p() as u32);
        w.u64(layer.n_weights() as u64);
        w.u32(p.correction.n_errors() as u32);
        w.u64(fl as u64);
        w.words(&fw);
        w.u64(pl as u64);
        w.words(&pw);
    }
}

/// Parse one layer record (shared by the v1 body and v2 payload).
pub(super) fn read_layer(r: &mut Reader) -> Result<CompressedLayer> {
    let name = match String::from_utf8(r.bytes()?) {
        Ok(n) => n,
        Err(_) => bail!("layer name not utf8"),
    };
    let rows = r.u32()? as usize;
    let cols = r.u32()? as usize;
    // `rows`/`cols` are untrusted: reject geometry whose decoded size
    // would overflow `usize` (mirrors `ContainerIndex::parse`, but also
    // covers v1 containers, which have no index) so `n_weights()`
    // arithmetic is safe on every successfully parsed layer.
    let decoded = (rows as u64)
        .checked_mul(cols as u64)
        .and_then(|n| n.checked_mul(4));
    let sane = matches!(
        decoded,
        Some(d)
            if d <= super::v2::MAX_LAYER_DECODED_BYTES
                && usize::try_from(d).is_ok()
    );
    if !sane {
        bail!(
            "layer {name}: absurd geometry {rows}x{cols} (decoded size \
             overflows or exceeds the per-layer cap)"
        );
    }
    let dtype = dtype_from_code(r.u8()?)?;
    let scale = r.f32()?;
    let n_in = r.u32()? as usize;
    let n_out = r.u32()? as usize;
    let n_s = r.u32()? as usize;
    // `DecoderSpec::new` *asserts* these bounds; corrupt bytes must
    // surface as an error, never a panic on the serving thread.
    if !(1..=20).contains(&n_in)
        || !(1..=128).contains(&n_out)
        || n_s > 4
        || n_in * (n_s + 1) > 60
    {
        bail!(
            "layer {name}: decoder spec out of range \
             (N_in={n_in} N_out={n_out} N_s={n_s})"
        );
    }
    let m_seed = r.u64()?;
    let mask = r.bitvec()?;
    if mask.len() != rows * cols {
        bail!(
            "layer {name}: mask has {} bits but geometry {rows}x{cols} \
             needs {}",
            mask.len(),
            rows * cols
        );
    }
    let n_planes = r.u32()? as usize;
    // Never pre-reserve attacker-controlled sizes (failure_injection.rs).
    let mut planes = Vec::with_capacity(n_planes.min(1024));
    for _ in 0..n_planes {
        let inverted = r.u8()? != 0;
        let encoded = r.u32s_vec()?;
        let p = r.u32()? as usize;
        let n_bits = r.u64()? as usize;
        let n_errors = r.u32()? as usize;
        let fl = r.u64()? as usize;
        let fw = r.words()?;
        let pl = r.u64()? as usize;
        let pw = r.words()?;
        // `BitVecF2::from_words` asserts this consistency; corrupt
        // word counts must be an error, not a panic.
        if fw.len() != fl.div_ceil(64) || pw.len() != pl.div_ceil(64) {
            bail!(
                "layer {name}: correction stream word count disagrees \
                 with its bit length"
            );
        }
        planes.push(CompressedPlane {
            inverted,
            encoded,
            correction: CorrectionStream::from_words(
                (fw, fl),
                (pw, pl),
                p,
                n_bits,
                n_errors,
            ),
        });
    }
    Ok(CompressedLayer {
        name,
        rows,
        cols,
        dtype,
        scale,
        spec: DecoderSpec::new(n_in, n_out, n_s),
        m_seed,
        mask,
        planes,
    })
}

/// Serialize a container to bytes in the legacy v1 layout.
pub fn write_container(c: &Container) -> Vec<u8> {
    let mut w = Writer::new();
    w.buf.extend_from_slice(MAGIC);
    w.u32(1); // version
    w.u32(c.layers.len() as u32);
    for layer in &c.layers {
        write_layer(&mut w, layer);
    }
    w.buf
}

/// Parse a container from bytes. Accepts both the v1 (`F2F1`) and the
/// indexed v2 (`F2F2`) layouts.
pub fn read_container(bytes: &[u8]) -> Result<Container> {
    if super::v2::is_v2(bytes) {
        return super::v2::read_container_v2(bytes);
    }
    let mut r = Reader::new(bytes);
    if r.take(4)? != MAGIC {
        bail!("bad magic: not an F2F container");
    }
    let version = r.u32()?;
    if version != 1 {
        bail!("unsupported container version {version}");
    }
    let n_layers = r.u32()? as usize;
    // Never pre-reserve attacker-controlled sizes (failure_injection.rs).
    let mut layers = Vec::with_capacity(n_layers.min(1024));
    for _ in 0..n_layers {
        layers.push(read_layer(&mut r)?);
    }
    if r.pos != bytes.len() {
        bail!("{} trailing bytes after container", bytes.len() - r.pos);
    }
    Ok(Container { layers })
}

/// Deterministic multi-layer container for serialization tests (shared
/// with the v2 tests).
#[cfg(test)]
pub(super) fn sample_container(seed: u64) -> Container {
    use crate::rng::Rng;
    let mut rng = Rng::new(seed);
    let spec = DecoderSpec::new(8, 40, 2);
    let layers = (0..3)
        .map(|i| {
            let rows = 8 + i;
            let cols = 16;
            let n = rows * cols;
            CompressedLayer {
                name: format!("layer{i}"),
                rows,
                cols,
                dtype: if i == 0 { Dtype::F32 } else { Dtype::I8 },
                scale: 0.01 * (i as f32 + 1.0),
                spec,
                m_seed: rng.next_u64(),
                mask: BitVecF2::random(n, 0.3, &mut rng),
                planes: (0..if i == 0 { 32 } else { 8 })
                    .map(|_| {
                        let mism: Vec<usize> = {
                            let mut v: Vec<usize> =
                                (0..5).map(|_| rng.below(n)).collect();
                            v.sort_unstable();
                            v.dedup();
                            v
                        };
                        CompressedPlane {
                            inverted: rng.bernoulli(0.5),
                            encoded: (0..spec
                                .stream_len(spec.num_blocks(n)))
                                .map(|_| rng.below(256) as u32)
                                .collect(),
                            correction: CorrectionStream::build(
                                &mism, n, 512,
                            ),
                        }
                    })
                    .collect(),
            }
        })
        .collect();
    Container { layers }
}

/// Assert two containers hold identical layers, field by field.
#[cfg(test)]
pub(super) fn assert_layers_eq(a: &Container, b: &Container) {
    assert_eq!(a.layers.len(), b.layers.len());
    for (x, y) in a.layers.iter().zip(&b.layers) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.rows, y.rows);
        assert_eq!(x.cols, y.cols);
        assert_eq!(x.dtype, y.dtype);
        assert_eq!(x.scale, y.scale);
        assert_eq!(x.spec, y.spec);
        assert_eq!(x.m_seed, y.m_seed);
        assert_eq!(x.mask, y.mask);
        assert_eq!(x.planes, y.planes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact() {
        let c = sample_container(1);
        let bytes = write_container(&c);
        let back = read_container(&bytes).unwrap();
        assert_layers_eq(&c, &back);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = write_container(&sample_container(2));
        bytes[0] = b'X';
        assert!(read_container(&bytes).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let bytes = write_container(&sample_container(3));
        for cut in [4usize, 9, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                read_container(&bytes[..cut]).is_err(),
                "cut at {cut} should fail"
            );
        }
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut bytes = write_container(&sample_container(4));
        bytes.push(0);
        assert!(read_container(&bytes).is_err());
    }

    #[test]
    fn rejects_absurd_v1_geometry() {
        // v1 has no index, so the record reader itself must reject
        // rows/cols whose decoded size overflows (u32::MAX × u32::MAX).
        let mut bytes = write_container(&sample_container(5));
        // Layer 0's rows/cols sit after magic+version+count and the
        // name record (4-byte len + "layer0").
        let rows_pos = 4 + 4 + 4 + (4 + 6);
        bytes[rows_pos..rows_pos + 8].copy_from_slice(&[0xFF; 8]);
        let err = read_container(&bytes).unwrap_err();
        assert!(format!("{err}").contains("absurd geometry"), "{err}");
    }

    #[test]
    fn rejects_corrupt_decoder_spec_without_panicking() {
        // `DecoderSpec::new` asserts its bounds; the reader must turn a
        // corrupt spec field into an error before reaching it.
        let mut bytes = write_container(&sample_container(7));
        // Layer 0's n_in sits after the name record, rows, cols, dtype
        // and scale.
        let n_in_pos = 4 + 4 + 4 + (4 + 6) + 4 + 4 + 1 + 4;
        bytes[n_in_pos..n_in_pos + 4]
            .copy_from_slice(&0u32.to_le_bytes());
        let err = read_container(&bytes).unwrap_err();
        assert!(
            format!("{err}").contains("decoder spec out of range"),
            "{err}"
        );
    }

    #[test]
    fn rejects_mask_geometry_mismatch() {
        // Shrinking `cols` keeps the decoded size sane but makes the
        // (length-prefixed) mask disagree with the geometry — the
        // reader must reject it instead of serving out-of-bounds reads.
        let mut bytes = write_container(&sample_container(6));
        let cols_pos = 4 + 4 + 4 + (4 + 6) + 4;
        bytes[cols_pos..cols_pos + 4]
            .copy_from_slice(&1u32.to_le_bytes());
        assert!(read_container(&bytes).is_err());
    }
}

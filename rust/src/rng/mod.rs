//! Deterministic pseudo-random number generation.
//!
//! The crate builds offline (no `rand`), so we ship a small, fast,
//! well-understood generator: SplitMix64 (Steele et al., *Fast Splittable
//! Pseudorandom Number Generators*, OOPSLA 2014). It passes BigCrush when
//! used as a 64-bit stream and is more than adequate for the Monte-Carlo
//! style experiments in the paper (random `M⊕` matrices, Bernoulli masks,
//! random weight bits).
//!
//! Every experiment takes an explicit seed so all tables/figures are
//! exactly reproducible.

/// SplitMix64 PRNG. Copy-cheap; all methods are `#[inline]`.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Any seed (including 0) is fine.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Bernoulli trial with probability `p` of `true`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Uniform integer in `[0, n)`. `n` must be nonzero.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias is negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; simple, exact
    /// enough for synthetic weight generation).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Split off an independent generator (for per-thread streams).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bernoulli_mean_close_to_p() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let hits = (0..n).filter(|_| r.bernoulli(0.9)).count();
        let mean = hits as f64 / n as f64;
        assert!((mean - 0.9).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}

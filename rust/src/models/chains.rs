//! Chain builders: executable [`ChainSpec`]s for the benchmark layer
//! tables.
//!
//! The layer tables in [`super::layers`] follow the paper's naming
//! conventions (`enc0/self_att/q`, `group1_layer0_conv2`, …); these
//! builders turn a table into the chain the registry executes —
//! attention Q/K/V/output groups with sublayer residuals for the
//! Transformer, conv-as-GEMM bottlenecks with downsampled skip links
//! for ResNet. Both are name-driven, so scaled-down tables with the
//! same naming scheme ([`tiny_transformer_layers`],
//! [`tiny_resnet_layers`]) produce valid chains too.

use super::LayerSpec;
use crate::container::{
    Activation, ChainSpec, ChainStep, Residual, StepInput, StepKind,
};
use anyhow::{bail, Result};

/// Build the Transformer chain from a layer table using the
/// `{block}/self_att/{q,k,v,output}`, `{block}/enc_att/…`,
/// `{block}/ffn1`, `{block}/ffn2` naming scheme.
///
/// Semantics (documented simplifications, all dimension-honest):
/// sequence length 1, so each attention step runs all four matmuls
/// and its single score softmaxes to 1; decoder cross-attention reads
/// the running stream as its memory. Attention sublayers add their
/// own input (`x + Att(x)`); the FFN pair adds the activation that
/// entered `ffn1` after `ffn2` completes.
pub fn transformer_chain(
    model: impl Into<String>,
    specs: &[LayerSpec],
) -> Result<ChainSpec> {
    let exists =
        |name: &str| specs.iter().any(|s| s.name == name);
    let mut steps: Vec<ChainStep> = Vec::new();
    for spec in specs {
        if let Some(prefix) = spec.name.strip_suffix("/q") {
            let part = |m: &str| format!("{prefix}/{m}");
            for m in ["k", "v", "output"] {
                if !exists(&part(m)) {
                    bail!(
                        "attention group {prefix:?} is missing its \
                         {m:?} projection"
                    );
                }
            }
            steps.push(ChainStep {
                kind: StepKind::Attention {
                    q: spec.name.clone(),
                    k: part("k"),
                    v: part("v"),
                    output: part("output"),
                },
                input: StepInput::Prev,
                residual: Residual::OwnInput,
                activation: Activation::None,
            });
        } else if spec.name.ends_with("/ffn1") {
            steps.push(ChainStep {
                kind: StepKind::Gemv { layer: spec.name.clone() },
                input: StepInput::Prev,
                residual: Residual::None,
                activation: Activation::Relu,
            });
        } else if spec.name.ends_with("/ffn2") {
            // The FFN sublayer residual: add what entered ffn1 — the
            // output of the step before it (the attention sublayer).
            let Some(ffn1_idx) = steps.len().checked_sub(1) else {
                bail!("{}: ffn2 with no preceding ffn1", spec.name);
            };
            let residual = match ffn1_idx.checked_sub(1) {
                Some(att_idx) => Residual::Step(att_idx),
                None => Residual::ChainInput,
            };
            steps.push(ChainStep {
                kind: StepKind::Gemv { layer: spec.name.clone() },
                input: StepInput::Prev,
                residual,
                activation: Activation::None,
            });
        } else if spec.name.contains("_att/") {
            // k/v/output members: consumed by their group's /q entry.
            continue;
        } else {
            bail!(
                "layer {:?} does not follow the transformer naming \
                 scheme",
                spec.name
            );
        }
    }
    let chain = ChainSpec { model: model.into(), steps };
    chain.validate(exists)?;
    Ok(chain)
}

/// Build the ResNet chain from a layer table using the `conv1` stem /
/// `group{g}_layer{l}_{conv1,conv2,conv3,downsample}` / `fc` naming
/// scheme. Convs execute as GEMM over im2col patches at
/// 1×1-feature-map semantics (the incoming channel vector is tiled
/// `kh·kw` times); each bottleneck adds its block input (through the
/// 1×1 downsample conv when the block has one) before the final ReLU
/// — the post-add activation of He et al. 2016.
pub fn resnet_chain(
    model: impl Into<String>,
    specs: &[LayerSpec],
) -> Result<ChainSpec> {
    let find = |name: &str| specs.iter().find(|s| s.name == name);
    let conv = |spec: &LayerSpec, kh: usize, kw: usize| -> Result<StepKind> {
        let patch = kh * kw;
        if patch == 0 || spec.cols % patch != 0 {
            bail!(
                "{}: cols {} not divisible by the {kh}x{kw} kernel",
                spec.name,
                spec.cols
            );
        }
        Ok(StepKind::Conv {
            layer: spec.name.clone(),
            kh,
            kw,
            in_ch: spec.cols / patch,
            out_ch: spec.rows,
        })
    };
    let mut steps: Vec<ChainStep> = Vec::new();
    for spec in specs {
        if spec.name == "conv1" {
            steps.push(ChainStep {
                kind: conv(spec, 7, 7)?,
                input: StepInput::ChainInput,
                residual: Residual::None,
                activation: Activation::Relu,
            });
        } else if let Some(base) = spec.name.strip_suffix("_conv1") {
            if !base.starts_with("group") {
                bail!("layer {:?}: unexpected conv1 prefix", spec.name);
            }
            let Some(c2) = find(&format!("{base}_conv2")) else {
                bail!("block {base:?} is missing conv2");
            };
            let Some(c3) = find(&format!("{base}_conv3")) else {
                bail!("block {base:?} is missing conv3");
            };
            let ds = find(&format!("{base}_downsample"));
            // The block input is whatever the chain produced so far.
            let block_input = steps.len().checked_sub(1);
            let input_of = |idx: Option<usize>| match idx {
                Some(i) => StepInput::Step(i),
                None => StepInput::ChainInput,
            };
            // Downsample first (when present) so conv3 can reference
            // it as an earlier step; it reads the block input, not
            // the main path.
            let skip = if let Some(ds) = ds {
                steps.push(ChainStep {
                    kind: conv(ds, 1, 1)?,
                    input: input_of(block_input),
                    residual: Residual::None,
                    activation: Activation::None,
                });
                Residual::Step(steps.len() - 1)
            } else {
                match block_input {
                    Some(i) => Residual::Step(i),
                    None => Residual::ChainInput,
                }
            };
            steps.push(ChainStep {
                kind: conv(spec, 1, 1)?,
                input: input_of(block_input),
                residual: Residual::None,
                activation: Activation::Relu,
            });
            steps.push(ChainStep {
                kind: conv(c2, 3, 3)?,
                input: StepInput::Prev,
                residual: Residual::None,
                activation: Activation::Relu,
            });
            steps.push(ChainStep {
                kind: conv(c3, 1, 1)?,
                input: StepInput::Prev,
                residual: skip,
                activation: Activation::Relu,
            });
        } else if spec.name.ends_with("_conv2")
            || spec.name.ends_with("_conv3")
            || spec.name.ends_with("_downsample")
        {
            continue; // consumed by the block's conv1 entry
        } else if spec.name == "fc" {
            steps.push(ChainStep::gemv("fc", Activation::None));
        } else {
            bail!(
                "layer {:?} does not follow the resnet naming scheme",
                spec.name
            );
        }
    }
    let chain = ChainSpec { model: model.into(), steps };
    chain.validate(|name| specs.iter().any(|s| s.name == name))?;
    Ok(chain)
}

/// A scaled-down encoder-only Transformer table with the canonical
/// naming scheme — chain-valid via [`transformer_chain`], small
/// enough to compress in tests and CI.
pub fn tiny_transformer_layers(
    n_blocks: usize,
    d_model: usize,
    d_ff: usize,
) -> Vec<LayerSpec> {
    let mut layers = Vec::new();
    for i in 0..n_blocks {
        for m in ["q", "k", "v", "output"] {
            layers.push(LayerSpec {
                name: format!("enc{i}/self_att/{m}"),
                rows: d_model,
                cols: d_model,
            });
        }
        layers.push(LayerSpec {
            name: format!("enc{i}/ffn1"),
            rows: d_ff,
            cols: d_model,
        });
        layers.push(LayerSpec {
            name: format!("enc{i}/ffn2"),
            rows: d_model,
            cols: d_ff,
        });
    }
    layers
}

/// A scaled-down ResNet table (stem + one bottleneck per width stage
/// + fc) with the canonical naming scheme — chain-valid via
/// [`resnet_chain`].
pub fn tiny_resnet_layers(widths: &[(usize, usize)]) -> Vec<LayerSpec> {
    let mut layers = Vec::new();
    let stem_out = widths.first().map_or(8, |&(mid, _)| mid.max(2));
    layers.push(LayerSpec {
        name: "conv1".into(),
        rows: stem_out,
        cols: 7 * 7 * 3,
    });
    let mut in_ch = stem_out;
    for (g, &(mid, out)) in widths.iter().enumerate() {
        let g1 = g + 1;
        layers.push(LayerSpec {
            name: format!("group{g1}_layer0_conv1"),
            rows: mid,
            cols: in_ch,
        });
        layers.push(LayerSpec {
            name: format!("group{g1}_layer0_conv2"),
            rows: mid,
            cols: 3 * 3 * mid,
        });
        layers.push(LayerSpec {
            name: format!("group{g1}_layer0_conv3"),
            rows: out,
            cols: mid,
        });
        layers.push(LayerSpec {
            name: format!("group{g1}_layer0_downsample"),
            rows: out,
            cols: in_ch,
        });
        in_ch = out;
    }
    layers.push(LayerSpec { name: "fc".into(), rows: 10, cols: in_ch });
    layers
}

#[cfg(test)]
mod tests {
    use super::super::{resnet50_layers, transformer_layers};
    use super::*;

    #[test]
    fn full_transformer_table_builds_a_chain() {
        let specs = transformer_layers();
        let chain = transformer_chain("tf", &specs).unwrap();
        // 6 enc blocks × (att + ffn1 + ffn2) + 6 dec × (2 att + 2 ffn).
        assert_eq!(chain.steps.len(), 6 * 3 + 6 * 4);
        // Every layer of the table is consumed exactly once.
        let mut names = chain.layer_names();
        names.sort_unstable();
        let mut want: Vec<&str> =
            specs.iter().map(|s| s.name.as_str()).collect();
        want.sort_unstable();
        assert_eq!(names, want);
        // FFN residuals skip back to the attention sublayer output.
        let ffn2 = chain
            .steps
            .iter()
            .position(|s| {
                matches!(&s.kind, StepKind::Gemv { layer } if layer == "enc0/ffn2")
            })
            .unwrap();
        assert_eq!(chain.steps[ffn2].residual, Residual::Step(ffn2 - 2));
    }

    #[test]
    fn full_resnet_table_builds_a_chain() {
        let specs = resnet50_layers();
        let chain = resnet_chain("rn", &specs).unwrap();
        // stem + 16 blocks × 3 convs + 4 downsamples + fc = 54 steps.
        assert_eq!(chain.steps.len(), 54);
        let mut names = chain.layer_names();
        names.sort_unstable();
        let mut want: Vec<&str> =
            specs.iter().map(|s| s.name.as_str()).collect();
        want.sort_unstable();
        assert_eq!(names, want);
        // First block: downsample precedes conv1 and is the residual.
        assert!(matches!(
            &chain.steps[1].kind,
            StepKind::Conv { layer, .. } if layer == "group1_layer0_downsample"
        ));
        assert_eq!(chain.steps[4].residual, Residual::Step(1));
        // Identity blocks skip straight to the block input.
        assert!(matches!(
            &chain.steps[5].kind,
            StepKind::Conv { layer, .. } if layer == "group1_layer1_conv1"
        ));
        assert_eq!(chain.steps[7].residual, Residual::Step(4));
    }

    #[test]
    fn tiny_tables_are_chain_valid() {
        let tf = tiny_transformer_layers(2, 32, 64);
        assert_eq!(tf.len(), 12);
        let chain = transformer_chain("t", &tf).unwrap();
        assert_eq!(chain.steps.len(), 6);
        let rn = tiny_resnet_layers(&[(4, 16), (8, 32)]);
        let chain = resnet_chain("r", &rn).unwrap();
        assert_eq!(chain.steps.len(), 1 + 2 * 4 + 1);
    }

    #[test]
    fn malformed_tables_are_rejected() {
        let mut tf = tiny_transformer_layers(1, 8, 16);
        tf.retain(|s| s.name != "enc0/self_att/k");
        let err = transformer_chain("t", &tf).unwrap_err();
        assert!(format!("{err}").contains("missing"), "{err}");

        let mut rn = tiny_resnet_layers(&[(4, 16)]);
        rn.retain(|s| s.name != "group1_layer0_conv2");
        let err = resnet_chain("r", &rn).unwrap_err();
        assert!(format!("{err}").contains("missing conv2"), "{err}");
    }
}

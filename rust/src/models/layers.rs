//! Layer tables: exact shapes of the two benchmark networks.

/// One weight matrix (conv kernels flattened to `out × (k·k·in)`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerSpec {
    pub name: String,
    /// Output dimension (rows).
    pub rows: usize,
    /// Input dimension (cols; `k·k·in_ch` for convs).
    pub cols: usize,
}

impl LayerSpec {
    fn new(name: impl Into<String>, rows: usize, cols: usize) -> Self {
        LayerSpec { name: name.into(), rows, cols }
    }

    /// Weight count.
    pub fn n_weights(&self) -> usize {
        self.rows * self.cols
    }
}

/// Transformer base (Vaswani et al. 2017), WMT'14 en-de: 6 encoder and 6
/// decoder layers, `d_model = 512`, `d_ff = 2048`. Embeddings/softmax are
/// excluded (the paper prunes the attention/FFN matrices).
pub fn transformer_layers() -> Vec<LayerSpec> {
    let mut layers = Vec::new();
    let d = 512;
    let ff = 2048;
    for i in 0..6 {
        for m in ["q", "k", "v", "output"] {
            layers.push(LayerSpec::new(
                format!("enc{i}/self_att/{m}"),
                d,
                d,
            ));
        }
        layers.push(LayerSpec::new(format!("enc{i}/ffn1"), ff, d));
        layers.push(LayerSpec::new(format!("enc{i}/ffn2"), d, ff));
    }
    for i in 0..6 {
        for m in ["q", "k", "v", "output"] {
            layers.push(LayerSpec::new(
                format!("dec{i}/self_att/{m}"),
                d,
                d,
            ));
        }
        for m in ["q", "k", "v", "output"] {
            layers.push(LayerSpec::new(
                format!("dec{i}/enc_att/{m}"),
                d,
                d,
            ));
        }
        layers.push(LayerSpec::new(format!("dec{i}/ffn1"), ff, d));
        layers.push(LayerSpec::new(format!("dec{i}/ffn2"), d, ff));
    }
    layers
}

/// ResNet-50 (He et al. 2016), ImageNet: bottleneck blocks
/// `[3, 4, 6, 3]`, plus the stem conv and the final FC. Conv kernels are
/// flattened to `out_ch × (k·k·in_ch)` matrices — the layout the paper's
/// bit-plane grouping operates on. Names follow the paper's
/// `GROUPg_LAYERl_…` convention (Table S.5).
pub fn resnet50_layers() -> Vec<LayerSpec> {
    let mut layers = Vec::new();
    layers.push(LayerSpec::new("conv1", 64, 7 * 7 * 3));
    let blocks = [3usize, 4, 6, 3];
    let widths = [(64usize, 256usize), (128, 512), (256, 1024), (512, 2048)];
    let mut in_ch = 64usize;
    for (g, (&nblocks, &(mid, out))) in
        blocks.iter().zip(widths.iter()).enumerate()
    {
        for l in 0..nblocks {
            let g1 = g + 1;
            layers.push(LayerSpec::new(
                format!("group{g1}_layer{l}_conv1"),
                mid,
                in_ch,
            ));
            layers.push(LayerSpec::new(
                format!("group{g1}_layer{l}_conv2"),
                mid,
                3 * 3 * mid,
            ));
            layers.push(LayerSpec::new(
                format!("group{g1}_layer{l}_conv3"),
                out,
                mid,
            ));
            if l == 0 {
                layers.push(LayerSpec::new(
                    format!("group{g1}_layer{l}_downsample"),
                    out,
                    in_ch,
                ));
            }
            in_ch = out;
        }
    }
    layers.push(LayerSpec::new("fc", 1000, 2048));
    layers
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transformer_layer_shapes() {
        let layers = transformer_layers();
        let ffn1 = layers.iter().find(|l| l.name == "enc0/ffn1").unwrap();
        assert_eq!((ffn1.rows, ffn1.cols), (2048, 512));
        assert!(layers.iter().any(|l| l.name == "dec5/enc_att/v"));
    }

    #[test]
    fn resnet_block_structure() {
        let layers = resnet50_layers();
        // 1 stem + (3+4+6+3)·3 convs + 4 downsamples + 1 fc = 54.
        assert_eq!(layers.len(), 1 + 16 * 3 + 4 + 1);
        let c2 = layers
            .iter()
            .find(|l| l.name == "group3_layer3_conv2")
            .unwrap();
        assert_eq!((c2.rows, c2.cols), (256, 3 * 3 * 256));
        let ds = layers
            .iter()
            .find(|l| l.name == "group4_layer0_downsample")
            .unwrap();
        assert_eq!((ds.rows, ds.cols), (2048, 1024));
    }

    #[test]
    fn fc_is_1000_way() {
        let layers = resnet50_layers();
        let fc = layers.last().unwrap();
        assert_eq!((fc.rows, fc.cols), (1000, 2048));
    }
}

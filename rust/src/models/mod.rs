//! Synthetic model zoo: Transformer (WMT'14 en-de, base) and ResNet-50
//! layer tables with realistic weight statistics.
//!
//! We cannot ship the paper's pretrained checkpoints
//! (`google-research/state_of_sparsity`); the encoder, however, only
//! consumes (a) the pruning-mask block statistics and (b) per-bit-plane
//! 0/1 ratios. Both are reproduced by Gaussian weights with per-output-row
//! scale variation (real layers have per-neuron norms spread by training)
//! and weight-decay-scale magnitudes (`|w| ≪ 1`, which produces the
//! exponent-plane skew of Figure S.12). See DESIGN.md §2 for the
//! substitution argument; Table 2 of the paper itself validates that
//! random vs trained weights compress near-identically.

mod chains;
mod layers;
mod synth;

pub use chains::{
    resnet_chain, tiny_resnet_layers, tiny_transformer_layers,
    transformer_chain,
};
pub use layers::{resnet50_layers, transformer_layers, LayerSpec};
pub use synth::{
    compressed_mlp, compressed_table, quantize_i8, MlpConfig,
    SyntheticLayer, WeightGen,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transformer_has_12_blocks_of_layers() {
        let layers = transformer_layers();
        // 6 encoder × 6 matrices + 6 decoder × 10 matrices.
        assert_eq!(layers.len(), 6 * 6 + 6 * 10);
        // Named layers from Table 3 exist with the right shapes.
        let q = layers
            .iter()
            .find(|l| l.name == "dec3/self_att/q")
            .expect("dec3/self_att/q");
        assert_eq!((q.rows, q.cols), (512, 512));
        let ffn2 = layers
            .iter()
            .find(|l| l.name == "dec3/ffn2")
            .expect("dec3/ffn2");
        assert_eq!((ffn2.rows, ffn2.cols), (512, 2048));
    }

    #[test]
    fn resnet50_parameter_count_is_right_ballpark() {
        let layers = resnet50_layers();
        let params: usize =
            layers.iter().map(|l| l.rows * l.cols).sum();
        // ~25.5M params (conv + fc).
        assert!(
            (23_000_000..28_000_000).contains(&params),
            "params = {params}"
        );
    }

    #[test]
    fn total_transformer_params_match_base_model_sans_embeddings() {
        let params: usize = transformer_layers()
            .iter()
            .map(|l| l.rows * l.cols)
            .sum();
        // Transformer base: ~44M in attention + FFN matrices.
        assert!(
            (40_000_000..48_000_000).contains(&params),
            "params = {params}"
        );
    }
}

//! Synthetic weight generation with trained-network statistics.

use super::LayerSpec;
use crate::rng::Rng;

/// Weight generator parameters.
#[derive(Debug, Clone, Copy)]
pub struct WeightGen {
    /// Lognormal σ of the per-output-row scale (trained nets: ~0.2; set
    /// higher to emulate stronger structure, 0 for i.i.d.).
    pub row_scale_sigma: f64,
    /// Global magnitude multiplier on the Xavier std. Trained,
    /// weight-decayed nets sit well below 1 — this keeps `|w| ≪ 1` so
    /// FP32 exponent planes show Figure S.12's skew.
    pub gain: f64,
}

impl Default for WeightGen {
    fn default() -> Self {
        WeightGen { row_scale_sigma: 0.20, gain: 1.0 }
    }
}

/// A generated layer: spec + FP32 weights (row-major).
#[derive(Debug, Clone)]
pub struct SyntheticLayer {
    pub spec: LayerSpec,
    pub weights: Vec<f32>,
}

impl SyntheticLayer {
    /// Generate weights: `w[r][c] ~ N(0, (gain·xavier·scale_r)²)` with
    /// `scale_r ~ LogNormal(0, row_scale_sigma)`.
    pub fn generate(spec: &LayerSpec, gen: WeightGen, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let xavier = (2.0 / (spec.rows + spec.cols) as f64).sqrt();
        let std = gen.gain * xavier;
        let mut weights = Vec::with_capacity(spec.n_weights());
        for _ in 0..spec.rows {
            let scale = (gen.row_scale_sigma * rng.normal()).exp() * std;
            for _ in 0..spec.cols {
                weights.push((rng.normal() * scale) as f32);
            }
        }
        SyntheticLayer { spec: spec.clone(), weights }
    }

    /// Truncate to the first `n` weights (whole rows are kept; used to
    /// subsample very large layers for encoding-statistics runs — `E` is
    /// a ratio and converges with a few 10⁵ bits, see EXPERIMENTS.md).
    pub fn truncated(&self, n: usize) -> SyntheticLayer {
        let rows = (n / self.spec.cols).max(1).min(self.spec.rows);
        let take = rows * self.spec.cols;
        SyntheticLayer {
            spec: LayerSpec {
                name: self.spec.name.clone(),
                rows,
                cols: self.spec.cols,
            },
            weights: self.weights[..take].to_vec(),
        }
    }
}

/// Symmetric signed-INT8 quantization: `q = round(w / scale)` with
/// `scale = max|w| / 127` (Jacob et al. 2018 style, per-tensor).
pub fn quantize_i8(weights: &[f32]) -> (Vec<i8>, f32) {
    let max = weights.iter().fold(0.0f32, |m, &w| m.max(w.abs()));
    let scale = if max == 0.0 { 1.0 } else { max / 127.0 };
    let q = weights
        .iter()
        .map(|&w| (w / scale).round().clamp(-127.0, 127.0) as i8)
        .collect();
    (q, scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::weights::BitPlanes;

    fn spec(rows: usize, cols: usize) -> LayerSpec {
        LayerSpec { name: "t".into(), rows, cols }
    }

    #[test]
    fn deterministic_in_seed() {
        let s = spec(16, 32);
        let a = SyntheticLayer::generate(&s, WeightGen::default(), 1);
        let b = SyntheticLayer::generate(&s, WeightGen::default(), 1);
        assert_eq!(
            a.weights.iter().map(|w| w.to_bits()).collect::<Vec<_>>(),
            b.weights.iter().map(|w| w.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn weights_are_small_magnitude() {
        let s = spec(512, 512);
        let l = SyntheticLayer::generate(&s, WeightGen::default(), 2);
        let max = l.weights.iter().fold(0.0f32, |m, &w| m.max(w.abs()));
        assert!(max < 1.0, "max |w| = {max}: exponent skew requires |w|<1");
    }

    #[test]
    fn exponent_skew_like_fig_s12() {
        let s = spec(256, 256);
        let l = SyntheticLayer::generate(&s, WeightGen::default(), 3);
        let planes = BitPlanes::from_f32(&l.weights);
        let mask = crate::gf2::BitVecF2::from_bools(&vec![
            true;
            l.weights.len()
        ]);
        let zr = planes.zero_ratios(&mask);
        // sign ~balanced, exponent MSB all-zero, next bits ~all-one.
        assert!((zr[0] - 0.5).abs() < 0.05);
        assert!(zr[1] > 0.99);
        assert!(zr[2] < 0.05);
    }

    #[test]
    fn quantize_i8_roundtrip_error_bounded() {
        let s = spec(64, 64);
        let l = SyntheticLayer::generate(&s, WeightGen::default(), 4);
        let (q, scale) = quantize_i8(&l.weights);
        assert_eq!(q.len(), l.weights.len());
        for (&w, &qv) in l.weights.iter().zip(&q) {
            assert!((w - qv as f32 * scale).abs() <= scale * 0.5 + 1e-7);
        }
    }

    #[test]
    fn quantized_bitplanes_are_roughly_balanced() {
        // Signed INT8 of Gaussian weights: low bits ~uniform — the reason
        // Table 2's INT8 rows mark inverting "N/A".
        let s = spec(256, 256);
        let l = SyntheticLayer::generate(&s, WeightGen::default(), 5);
        let (q, _) = quantize_i8(&l.weights);
        let planes = BitPlanes::from_i8(&q);
        let mask =
            crate::gf2::BitVecF2::from_bools(&vec![true; q.len()]);
        let zr = planes.zero_ratios(&mask);
        for k in 5..8 {
            assert!(
                (zr[k] - 0.5).abs() < 0.1,
                "plane {k} zero-ratio {}",
                zr[k]
            );
        }
    }

    #[test]
    fn truncation_keeps_whole_rows() {
        let s = spec(100, 64);
        let l = SyntheticLayer::generate(&s, WeightGen::default(), 6);
        let t = l.truncated(1000);
        assert_eq!(t.spec.rows, 15);
        assert_eq!(t.weights.len(), 15 * 64);
    }
}

//! Synthetic weight generation with trained-network statistics, plus
//! the shared compressed-MLP builder every serving-path consumer
//! (benches, examples, CLI, integration tests) parameterizes instead of
//! hand-rolling.

use super::LayerSpec;
use crate::container::Container;
use crate::pipeline::{CompressionConfig, Compressor, LayerReport};
use crate::pruning::PruneMethod;
use crate::rng::Rng;

/// Weight generator parameters.
#[derive(Debug, Clone, Copy)]
pub struct WeightGen {
    /// Lognormal σ of the per-output-row scale (trained nets: ~0.2; set
    /// higher to emulate stronger structure, 0 for i.i.d.).
    pub row_scale_sigma: f64,
    /// Global magnitude multiplier on the Xavier std. Trained,
    /// weight-decayed nets sit well below 1 — this keeps `|w| ≪ 1` so
    /// FP32 exponent planes show Figure S.12's skew.
    pub gain: f64,
}

impl Default for WeightGen {
    fn default() -> Self {
        WeightGen { row_scale_sigma: 0.20, gain: 1.0 }
    }
}

/// A generated layer: spec + FP32 weights (row-major).
#[derive(Debug, Clone)]
pub struct SyntheticLayer {
    pub spec: LayerSpec,
    pub weights: Vec<f32>,
}

impl SyntheticLayer {
    /// Generate weights: `w[r][c] ~ N(0, (gain·xavier·scale_r)²)` with
    /// `scale_r ~ LogNormal(0, row_scale_sigma)`.
    pub fn generate(spec: &LayerSpec, gen: WeightGen, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let xavier = (2.0 / (spec.rows + spec.cols) as f64).sqrt();
        let std = gen.gain * xavier;
        let mut weights = Vec::with_capacity(spec.n_weights());
        for _ in 0..spec.rows {
            let scale = (gen.row_scale_sigma * rng.normal()).exp() * std;
            for _ in 0..spec.cols {
                weights.push((rng.normal() * scale) as f32);
            }
        }
        SyntheticLayer { spec: spec.clone(), weights }
    }

    /// Truncate to the first `n` weights (whole rows are kept; used to
    /// subsample very large layers for encoding-statistics runs — `E` is
    /// a ratio and converges with a few 10⁵ bits, see EXPERIMENTS.md).
    pub fn truncated(&self, n: usize) -> SyntheticLayer {
        let rows = (n / self.spec.cols).max(1).min(self.spec.rows);
        let take = rows * self.spec.cols;
        SyntheticLayer {
            spec: LayerSpec {
                name: self.spec.name.clone(),
                rows,
                cols: self.spec.cols,
            },
            weights: self.weights[..take].to_vec(),
        }
    }
}

/// Symmetric signed-INT8 quantization: `q = round(w / scale)` with
/// `scale = max|w| / 127` (Jacob et al. 2018 style, per-tensor).
pub fn quantize_i8(weights: &[f32]) -> (Vec<i8>, f32) {
    let max = weights.iter().fold(0.0f32, |m, &w| m.max(w.abs()));
    let scale = if max == 0.0 { 1.0 } else { max / 127.0 };
    let q = weights
        .iter()
        .map(|&w| (w / scale).round().clamp(-127.0, 127.0) as i8)
        .collect();
    (q, scale)
}

/// Parameters for [`compressed_mlp`]. Start from [`MlpConfig::new`] (or
/// [`MlpConfig::uniform`]) and override fields with struct-update
/// syntax: `MlpConfig { seed: 21, sparsity: 0.75, ..MlpConfig::new(&dims) }`.
#[derive(Debug, Clone)]
pub struct MlpConfig {
    /// Layer widths: layer `i` is `dims[i+1] × dims[i]` (≥ 2 entries).
    pub dims: Vec<usize>,
    /// Base seed — layer `i`'s weights use `seed + i`, and the
    /// compressor (masks, `M⊕` candidates) derives from `seed` too.
    pub seed: u64,
    /// Layer-name prefix: layer `i` is named `{name_prefix}{i}`.
    pub name_prefix: String,
    /// Pruning rate `S`.
    pub sparsity: f64,
    /// Decoder shift registers `N_s`.
    pub n_s: usize,
    /// Viterbi beam width (`None` = exact DP).
    pub beam: Option<u32>,
}

impl MlpConfig {
    /// Defaults shared by the serving demos: magnitude pruning at
    /// `S = 0.9`, `N_s = 1`, beam 8, layers named `fc0..`.
    pub fn new(dims: &[usize]) -> Self {
        MlpConfig {
            dims: dims.to_vec(),
            seed: 7,
            name_prefix: "fc".into(),
            sparsity: 0.9,
            n_s: 1,
            beam: Some(8),
        }
    }

    /// An `n_layers`-deep MLP of constant `width`.
    pub fn uniform(n_layers: usize, width: usize) -> Self {
        Self::new(&vec![width; n_layers + 1])
    }
}

/// Build a compressed synthetic INT8 MLP: generate each layer's weights
/// ([`SyntheticLayer::generate`]), quantize ([`quantize_i8`]), compress
/// with the paper's fixed-to-fixed scheme, and return the container
/// alongside the per-layer compression reports (for callers that print
/// efficiency / memory-reduction summaries).
pub fn compressed_mlp(cfg: &MlpConfig) -> (Container, Vec<LayerReport>) {
    assert!(
        cfg.dims.len() >= 2,
        "an MLP needs at least input and output dims"
    );
    let specs: Vec<LayerSpec> = cfg
        .dims
        .windows(2)
        .enumerate()
        .map(|(i, w)| LayerSpec {
            name: format!("{}{i}", cfg.name_prefix),
            rows: w[1],
            cols: w[0],
        })
        .collect();
    compressed_table(&specs, cfg)
}

/// [`compressed_mlp`] generalized to an arbitrary layer table: the
/// same synthetic-weight + INT8-quantize + fixed-to-fixed pipeline,
/// geometry and names taken from `specs` (e.g. the Transformer /
/// ResNet tables of [`super::layers`] or their `tiny_*` variants)
/// instead of a uniform ladder. `cfg.dims` and `cfg.name_prefix` are
/// ignored.
pub fn compressed_table(
    specs: &[LayerSpec],
    cfg: &MlpConfig,
) -> (Container, Vec<LayerReport>) {
    let compressor = Compressor::new(CompressionConfig {
        sparsity: cfg.sparsity,
        n_s: cfg.n_s,
        method: PruneMethod::Magnitude,
        beam: cfg.beam,
        seed: cfg.seed,
        ..Default::default()
    });
    let mut container = Container::default();
    let mut reports = Vec::with_capacity(specs.len());
    for (i, spec) in specs.iter().enumerate() {
        let layer = SyntheticLayer::generate(
            spec,
            WeightGen::default(),
            cfg.seed.wrapping_add(i as u64),
        );
        let (q, scale) = quantize_i8(&layer.weights);
        let (cl, rep) = compressor.compress_i8(
            &spec.name, spec.rows, spec.cols, &q, scale,
        );
        container.layers.push(cl);
        reports.push(rep);
    }
    (container, reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::weights::BitPlanes;

    fn spec(rows: usize, cols: usize) -> LayerSpec {
        LayerSpec { name: "t".into(), rows, cols }
    }

    #[test]
    fn deterministic_in_seed() {
        let s = spec(16, 32);
        let a = SyntheticLayer::generate(&s, WeightGen::default(), 1);
        let b = SyntheticLayer::generate(&s, WeightGen::default(), 1);
        assert_eq!(
            a.weights.iter().map(|w| w.to_bits()).collect::<Vec<_>>(),
            b.weights.iter().map(|w| w.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn weights_are_small_magnitude() {
        let s = spec(512, 512);
        let l = SyntheticLayer::generate(&s, WeightGen::default(), 2);
        let max = l.weights.iter().fold(0.0f32, |m, &w| m.max(w.abs()));
        assert!(max < 1.0, "max |w| = {max}: exponent skew requires |w|<1");
    }

    #[test]
    fn exponent_skew_like_fig_s12() {
        let s = spec(256, 256);
        let l = SyntheticLayer::generate(&s, WeightGen::default(), 3);
        let planes = BitPlanes::from_f32(&l.weights);
        let mask = crate::gf2::BitVecF2::from_bools(&vec![
            true;
            l.weights.len()
        ]);
        let zr = planes.zero_ratios(&mask);
        // sign ~balanced, exponent MSB all-zero, next bits ~all-one.
        assert!((zr[0] - 0.5).abs() < 0.05);
        assert!(zr[1] > 0.99);
        assert!(zr[2] < 0.05);
    }

    #[test]
    fn quantize_i8_roundtrip_error_bounded() {
        let s = spec(64, 64);
        let l = SyntheticLayer::generate(&s, WeightGen::default(), 4);
        let (q, scale) = quantize_i8(&l.weights);
        assert_eq!(q.len(), l.weights.len());
        for (&w, &qv) in l.weights.iter().zip(&q) {
            assert!((w - qv as f32 * scale).abs() <= scale * 0.5 + 1e-7);
        }
    }

    #[test]
    fn quantized_bitplanes_are_roughly_balanced() {
        // Signed INT8 of Gaussian weights: low bits ~uniform — the reason
        // Table 2's INT8 rows mark inverting "N/A".
        let s = spec(256, 256);
        let l = SyntheticLayer::generate(&s, WeightGen::default(), 5);
        let (q, _) = quantize_i8(&l.weights);
        let planes = BitPlanes::from_i8(&q);
        let mask =
            crate::gf2::BitVecF2::from_bools(&vec![true; q.len()]);
        let zr = planes.zero_ratios(&mask);
        for k in 5..8 {
            assert!(
                (zr[k] - 0.5).abs() < 0.1,
                "plane {k} zero-ratio {}",
                zr[k]
            );
        }
    }

    #[test]
    fn truncation_keeps_whole_rows() {
        let s = spec(100, 64);
        let l = SyntheticLayer::generate(&s, WeightGen::default(), 6);
        let t = l.truncated(1000);
        assert_eq!(t.spec.rows, 15);
        assert_eq!(t.weights.len(), 15 * 64);
    }

    #[test]
    fn compressed_mlp_builds_the_named_chain() {
        let cfg = MlpConfig {
            seed: 11,
            sparsity: 0.75,
            name_prefix: "mlp/fc".into(),
            ..MlpConfig::new(&[32, 24, 16])
        };
        let (c, reports) = compressed_mlp(&cfg);
        assert_eq!(c.layers.len(), 2);
        assert_eq!(reports.len(), 2);
        assert_eq!(c.layers[0].name, "mlp/fc0");
        assert_eq!(c.layers[1].name, "mlp/fc1");
        assert_eq!((c.layers[0].rows, c.layers[0].cols), (24, 32));
        assert_eq!((c.layers[1].rows, c.layers[1].cols), (16, 24));
        // Deterministic in the seed.
        let (again, _) = compressed_mlp(&cfg);
        for (a, b) in c.layers.iter().zip(&again.layers) {
            assert_eq!(a.planes, b.planes);
            assert_eq!(a.mask, b.mask);
        }
        // Lossless: unpruned weights round-trip through decode.
        let dec =
            crate::sparse::DecodedLayer::from_compressed(&c.layers[0]);
        assert_eq!(dec.rows * dec.cols, 24 * 32);
    }

    #[test]
    fn uniform_mlp_dims() {
        let cfg = MlpConfig::uniform(3, 16);
        assert_eq!(cfg.dims, vec![16, 16, 16, 16]);
        let (c, _) = compressed_mlp(&cfg);
        assert_eq!(c.layers.len(), 3);
    }
}

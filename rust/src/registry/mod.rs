//! Multi-tenant model registry: N models, one process, one budget.
//!
//! The paper's fixed-to-fixed format prices every layer's decoded
//! footprint up front, which is what makes *co-tenancy* tractable: a
//! zoo of compressed models can share one byte-budgeted
//! [`crate::store::ModelStore`] and its decode workers, with the LRU
//! arbitrating between tenants instead of each model reserving its
//! worst case. This module is that serving tier:
//!
//! * [`merge_zoo`] — fold per-model containers into one container
//!   whose layers are named `{model}::{layer}` ([`MODEL_SEP`]), each
//!   model keeping its own executable [`ChainSpec`] (explicit v3
//!   chains, or the implicit uniform gemv+relu ladder of a chainless
//!   container).
//! * [`CompiledChain`] — a chain validated against real layer
//!   geometry and lowered to a step program: gemv, attention at
//!   sequence length 1 (four projections, single score softmaxes
//!   to 1), conv-as-GEMM over tiled im2col patches, residual adds,
//!   activations.
//! * [`ModelRegistry`] — the multi-model
//!   [`crate::coordinator::Backend`]: requests route by model id,
//!   every tenant executes over the *shared* store(s) — one store,
//!   N in-process shards, or IPC shard workers — so a burst on model
//!   A evicts cold model B layers while pinned-while-executing layers
//!   of any tenant survive. Per-model cost tables and cache views
//!   come from filtering the shared state by the `{model}::` prefix.

mod compile;
mod zoo;

pub use compile::CompiledChain;
pub use zoo::{merge_zoo, MergedZoo, ModelRegistry, ZooModel};

use crate::container::ChainSpec;
use anyhow::{bail, Result};

/// Separator between a model id and a layer name in a merged
/// container. Model ids must not contain it (and must be non-empty),
/// so scoped names parse unambiguously.
pub const MODEL_SEP: &str = "::";

/// The merged container's name for `layer` of `model`.
pub fn scoped_name(model: &str, layer: &str) -> String {
    format!("{model}{MODEL_SEP}{layer}")
}

/// Join a wire-level model id and layer name into a store key: the
/// bare layer name when the model id is empty (the single-model wire
/// form), else the merged container's `{model}::{layer}`.
pub fn scoped_or_bare(model: &str, layer: &str) -> String {
    if model.is_empty() {
        layer.to_string()
    } else {
        scoped_name(model, layer)
    }
}

/// Reject ids that cannot name a zoo tenant: empty (reserved for the
/// unscoped single-model form) or containing the name separator.
pub fn validate_model_id(id: &str) -> Result<()> {
    if id.is_empty() {
        bail!("model id must not be empty");
    }
    if id.contains(MODEL_SEP) {
        bail!("model id {id:?} contains the reserved {MODEL_SEP:?}");
    }
    Ok(())
}

/// The chain a container serves for `id`: an explicit chain matching
/// the id, the sole chain of a single-chain container (whatever id it
/// was written under), or `None` — the caller falls back to the
/// implicit [`ChainSpec::uniform`] ladder.
pub(crate) fn select_chain<'a>(
    chains: &'a [ChainSpec],
    id: &str,
) -> Option<&'a ChainSpec> {
    match chains {
        [only] => Some(only),
        many => many.iter().find(|c| c.model == id),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scoping_and_ids() {
        assert_eq!(scoped_name("a", "fc0"), "a::fc0");
        assert_eq!(scoped_or_bare("", "fc0"), "fc0");
        assert_eq!(scoped_or_bare("a", "fc0"), "a::fc0");
        assert!(validate_model_id("a").is_ok());
        assert!(validate_model_id("").is_err());
        assert!(validate_model_id("a::b").is_err());
    }

    #[test]
    fn chain_selection_rules() {
        let one = vec![ChainSpec::uniform("whatever", &["x"])];
        assert!(select_chain(&one, "a").is_some());
        let two = vec![
            ChainSpec::uniform("a", &["x"]),
            ChainSpec::uniform("b", &["y"]),
        ];
        assert_eq!(select_chain(&two, "b").map(|c| c.model.as_str()), Some("b"));
        assert!(select_chain(&two, "c").is_none());
        assert!(select_chain(&[], "a").is_none());
    }
}

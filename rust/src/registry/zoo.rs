//! The model zoo: merged containers and the serving registry.

use super::compile::{run_step, CompiledChain};
use super::{scoped_name, select_chain, validate_model_id, MODEL_SEP};
use crate::container::{
    is_v2, read_container, read_layer_at, write_sharded, ChainSpec,
    Container, ContainerIndex, ShardAssignment, ShardMap,
};
use crate::coordinator::Backend;
use crate::ipc::{IpcCallError, IpcShardStore, Supervisor};
use crate::kernels::ExecLayer;
use crate::obs;
use crate::store::{
    planned_depth, wrapped_targets, LayerCost, LayerCosts, ModelStore,
    ReadaheadPolicy, StoreConfig, StoreMetrics,
};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

/// One tenant of a zoo: its id, compressed container, and the chain it
/// executes (explicit, or `None` for the implicit uniform ladder).
pub struct ZooModel {
    pub id: String,
    pub container: Container,
    pub chain: Option<ChainSpec>,
}

impl ZooModel {
    /// A tenant from an in-memory container with no explicit chain
    /// (serves as the uniform gemv+relu ladder).
    pub fn new(id: impl Into<String>, container: Container) -> Self {
        ZooModel { id: id.into(), container, chain: None }
    }

    /// Attach an explicit chain (builder style).
    pub fn with_chain(mut self, chain: ChainSpec) -> Self {
        self.chain = Some(chain);
        self
    }

    /// A tenant from serialized container bytes — v1, v2, or v3. A v3
    /// chains section is honored: the sole chain of a single-chain
    /// container, else the chain recorded under `id`.
    pub fn from_bytes(id: impl Into<String>, bytes: &[u8]) -> Result<Self> {
        let id = id.into();
        if !is_v2(bytes) {
            // v1: flat layer list, no chains section.
            let container = read_container(bytes)
                .with_context(|| format!("parsing model {id:?}"))?;
            return Ok(ZooModel { id, container, chain: None });
        }
        let index = ContainerIndex::parse(bytes)
            .with_context(|| format!("parsing model {id:?}"))?;
        let mut container = Container::default();
        for entry in index.entries() {
            container.layers.push(read_layer_at(bytes, entry)?);
        }
        let chain = select_chain(index.chains(), &id).cloned();
        Ok(ZooModel { id, container, chain })
    }

    /// [`ZooModel::from_bytes`] over a container file.
    pub fn from_path(
        id: impl Into<String>,
        path: impl AsRef<std::path::Path>,
    ) -> Result<Self> {
        let path = path.as_ref();
        let bytes = std::fs::read(path).with_context(|| {
            format!("reading container {}", path.display())
        })?;
        Self::from_bytes(id, &bytes)
    }
}

/// [`merge_zoo`]'s output: one container holding every tenant's layers
/// under `{model}::{layer}` names, plus one chain per tenant (in bare
/// layer names, `model` set to the tenant id).
pub struct MergedZoo {
    pub container: Container,
    pub chains: Vec<ChainSpec>,
}

/// Fold N tenants into one container: every layer renamed to
/// `{model}::{layer}`, every tenant's chain resolved (explicit or the
/// implicit uniform ladder) and validated against its own layer set.
/// One container means one [`ModelStore`] serves the whole zoo — one
/// byte budget, one LRU, one in-flight decode table, shared decode
/// workers — which is the entire point.
pub fn merge_zoo(models: &[ZooModel]) -> Result<MergedZoo> {
    if models.is_empty() {
        bail!("model zoo is empty");
    }
    let mut container = Container::default();
    let mut chains = Vec::with_capacity(models.len());
    for (i, m) in models.iter().enumerate() {
        validate_model_id(&m.id)?;
        if models.iter().take(i).any(|o| o.id == m.id) {
            bail!("duplicate model id {:?}", m.id);
        }
        if m.container.layers.is_empty() {
            bail!("model {:?} has no layers", m.id);
        }
        let names: Vec<&str> =
            m.container.layers.iter().map(|l| l.name.as_str()).collect();
        let chain = match &m.chain {
            Some(c) => {
                let mut c = c.clone();
                c.model = m.id.clone();
                c
            }
            None => ChainSpec::uniform(&m.id, &names),
        };
        chain
            .validate(|n| names.contains(&n))
            .with_context(|| format!("chain of model {:?}", m.id))?;
        for l in &m.container.layers {
            let mut l = l.clone();
            l.name = scoped_name(&m.id, &l.name);
            container.layers.push(l);
        }
        chains.push(chain);
    }
    Ok(MergedZoo { container, chains })
}

/// One tenant's compiled chain plus the source-routing it needs:
/// `owners[i]` is the store/client index holding flat layer `i`, and
/// `bare[i]` its unscoped name (what rides the wire's model-scoped
/// frames).
struct ChainEntry {
    chain: CompiledChain,
    bare: Vec<String>,
    owners: Vec<usize>,
}

/// Where the registry's layers come from.
enum Source {
    /// In-process byte-budgeted stores — one shared store, or N
    /// in-process shards of the merged container.
    Stores(Vec<Arc<ModelStore>>),
    /// Per-worker IPC stubs over shard sockets; transport failures
    /// route through the supervisor's revive path once, exactly like
    /// [`crate::ipc::ProcRouter`].
    Ipc {
        clients: Vec<Arc<IpcShardStore>>,
        supervisor: Option<Arc<Supervisor>>,
    },
}

/// N models served from one process over shared decode capacity: the
/// multi-model [`Backend`]. Every tenant's chain executes against the
/// same store set, so the byte budget, LRU, pin table and in-flight
/// dedup are all *cross-model* — a burst on one tenant evicts another
/// tenant's cold layers, never anyone's pinned ones.
pub struct ModelRegistry {
    entries: Vec<ChainEntry>,
    source: Source,
    readahead: ReadaheadPolicy,
    /// Registry-side GEMV telemetry for the IPC path (in-process
    /// stores record into their own tables instead). Shared so the
    /// serving CLI can keep reading it after the registry moves
    /// behind the inference server.
    costs: Arc<LayerCosts>,
}

impl ModelRegistry {
    /// Serve `models` from **one shared store** under `config`'s byte
    /// budget — the canonical zoo deployment.
    pub fn new(models: &[ZooModel], config: StoreConfig) -> Result<Self> {
        let merged = merge_zoo(models)?;
        let store =
            Arc::new(ModelStore::from_container(merged.container, config));
        let entries = {
            let store = &store;
            compile_entries(
                &merged.chains,
                |name| store.layer_dims(name),
                |_| Ok(0),
            )?
        };
        Ok(ModelRegistry {
            entries,
            source: Source::Stores(vec![store]),
            readahead: ReadaheadPolicy::default(),
            costs: Arc::new(LayerCosts::new()),
        })
    }

    /// Serve `models` from `n_shards` in-process shard stores: the
    /// merged container splits exactly like a single model would
    /// ([`write_sharded`]), so one shard can hold layers of several
    /// tenants and cross-model sharing still applies per shard.
    pub fn new_sharded(
        models: &[ZooModel],
        n_shards: usize,
        strategy: ShardAssignment,
        config: StoreConfig,
    ) -> Result<Self> {
        let merged = merge_zoo(models)?;
        let (map, shard_bytes) =
            write_sharded(&merged.container, n_shards, strategy)?;
        let mut stores = Vec::with_capacity(shard_bytes.len());
        for bytes in shard_bytes {
            stores.push(Arc::new(ModelStore::open_bytes(bytes, config)?));
        }
        let entries = {
            let stores = &stores;
            compile_entries(
                &merged.chains,
                |name| {
                    stores.iter().find_map(|s| s.layer_dims(name))
                },
                |name| {
                    map.shard_of(name).ok_or_else(|| {
                        anyhow!("layer {name:?} missing from shard map")
                    })
                },
            )?
        };
        Ok(ModelRegistry {
            entries,
            source: Source::Stores(stores),
            readahead: ReadaheadPolicy::default(),
            costs: Arc::new(LayerCosts::new()),
        })
    }

    /// Serve `models` over IPC worker stubs: `map` partitions the
    /// *merged* container's `{model}::{layer}` names across
    /// `clients[i]` (one per shard worker, each holding its shard of
    /// the merged container). Fetches ride model-scoped wire frames.
    pub fn over_ipc(
        models: &[ZooModel],
        map: &ShardMap,
        clients: Vec<Arc<IpcShardStore>>,
    ) -> Result<Self> {
        if map.n_shards() != clients.len() {
            bail!(
                "shard map names {} shards but {} worker clients were \
                 supplied",
                map.n_shards(),
                clients.len()
            );
        }
        let merged = merge_zoo(models)?;
        let dims: BTreeMap<String, (usize, usize)> = merged
            .container
            .layers
            .iter()
            .map(|l| (l.name.clone(), (l.rows, l.cols)))
            .collect();
        let entries = compile_entries(
            &merged.chains,
            |name| dims.get(name).copied(),
            |name| {
                map.shard_of(name).ok_or_else(|| {
                    anyhow!("layer {name:?} missing from shard map")
                })
            },
        )?;
        Ok(ModelRegistry {
            entries,
            source: Source::Ipc { clients, supervisor: None },
            readahead: ReadaheadPolicy::default(),
            costs: Arc::new(LayerCosts::new()),
        })
    }

    /// Attach the supervisor whose revive path repairs transport
    /// failures on the IPC source (no-op over in-process stores).
    pub fn with_supervisor(mut self, sup: Arc<Supervisor>) -> Self {
        if let Source::Ipc { supervisor, .. } = &mut self.source {
            *supervisor = Some(sup);
        }
        self
    }

    /// Replace the readahead policy (builder style).
    pub fn with_readahead(mut self, policy: ReadaheadPolicy) -> Self {
        self.readahead = policy;
        self
    }

    /// Replace the readahead policy in place.
    pub fn set_readahead(&mut self, policy: ReadaheadPolicy) {
        self.readahead = policy;
    }

    /// The active readahead policy.
    pub fn readahead(&self) -> ReadaheadPolicy {
        self.readahead
    }

    /// The registry-local cost table: GEMV stamps recorded on the IPC
    /// path, keyed by scoped `{model}::{layer}` name. Shared — clone
    /// the `Arc` before moving the registry behind a server to keep
    /// reading it (merge with worker tables via
    /// [`crate::ipc::ProcRouter::merged_profile`]).
    pub fn costs(&self) -> &Arc<LayerCosts> {
        &self.costs
    }

    /// Tenant ids, in registration order.
    pub fn model_ids(&self) -> Vec<String> {
        self.entries
            .iter()
            .map(|e| e.chain.model().to_string())
            .collect()
    }

    /// Number of tenants.
    pub fn n_models(&self) -> usize {
        self.entries.len()
    }

    /// The compiled chain serving `model`.
    pub fn chain(&self, model: &str) -> Option<&CompiledChain> {
        self.entries
            .iter()
            .map(|e| &e.chain)
            .find(|c| c.model() == model)
    }

    /// `model`'s layer names (bare, in fetch order) — what `f2f top`
    /// and the examples print per tenant.
    pub fn chain_layers(&self, model: &str) -> Option<Vec<String>> {
        self.entry(model).map(|e| e.bare.clone())
    }

    /// The shared in-process stores (empty slice over IPC).
    pub fn stores(&self) -> &[Arc<ModelStore>] {
        match &self.source {
            Source::Stores(stores) => stores,
            Source::Ipc { .. } => &[],
        }
    }

    /// Block until every in-process store's decode service drains
    /// (no-op over IPC).
    pub fn wait_for_idle(&self) {
        for s in self.stores() {
            s.wait_for_idle();
        }
    }

    /// Merged store metrics across the shared source — the zoo-wide
    /// cache view (`None` when a worker is unreachable over IPC).
    pub fn store_metrics(&self) -> Option<StoreMetrics> {
        let mut total = StoreMetrics::default();
        match &self.source {
            Source::Stores(stores) => {
                for s in stores {
                    total.merge(&s.metrics());
                }
            }
            Source::Ipc { clients, .. } => {
                for c in clients {
                    total.merge(&c.metrics().ok()?);
                }
            }
        }
        Some(total)
    }

    /// `model`'s observed cost table, keyed by bare layer name: the
    /// shared tables filtered to the tenant's `{model}::` prefix. Over
    /// IPC, registry-side GEMV stamps merge with whatever worker
    /// tables answer (best-effort — a dead worker just contributes
    /// nothing).
    pub fn model_costs(&self, model: &str) -> Vec<(String, LayerCost)> {
        let prefix = format!("{model}{MODEL_SEP}");
        let mut table: BTreeMap<String, LayerCost> = BTreeMap::new();
        let mut add = |name: &str, cost: LayerCost| {
            if let Some(bare) = name.strip_prefix(&prefix) {
                table
                    .entry(bare.to_string())
                    .and_modify(|c| c.merge(&cost))
                    .or_insert(cost);
            }
        };
        match &self.source {
            Source::Stores(stores) => {
                for s in stores {
                    for (name, cost) in s.costs().snapshot() {
                        add(&name, cost);
                    }
                }
            }
            Source::Ipc { clients, .. } => {
                for (name, cost) in self.costs.snapshot() {
                    add(&name, cost);
                }
                for c in clients {
                    if let Ok(profile) = c.cost_profile() {
                        for (name, cost) in profile.entries() {
                            add(&name, cost);
                        }
                    }
                }
            }
        }
        table.into_iter().collect()
    }

    /// `model`'s resident cache footprint, `(layers, bytes)`, from the
    /// shared stores' cache views (`None` over IPC — residency lives
    /// in the workers).
    pub fn model_cache(&self, model: &str) -> Option<(usize, usize)> {
        let Source::Stores(stores) = &self.source else {
            return None;
        };
        let prefix = format!("{model}{MODEL_SEP}");
        let mut layers = 0usize;
        let mut bytes = 0usize;
        for s in stores {
            for (name, b) in s.cached_entries() {
                if name.starts_with(&prefix) {
                    layers += 1;
                    bytes = bytes.saturating_add(b);
                }
            }
        }
        Some((layers, bytes))
    }

    fn entry(&self, model: &str) -> Option<&ChainEntry> {
        self.entries.iter().find(|e| e.chain.model() == model)
    }

    /// One tenant's forward pass over the shared source.
    fn forward_entry(
        &self,
        entry: &ChainEntry,
        xs: &[Vec<f32>],
    ) -> Result<Vec<Vec<f32>>> {
        for x in xs {
            if x.len() != entry.chain.input_dim() {
                bail!(
                    "model {:?} expects {} values, got {}",
                    entry.chain.model(),
                    entry.chain.input_dim(),
                    x.len()
                );
            }
        }
        match &self.source {
            Source::Stores(stores) => {
                self.forward_stores(entry, stores, xs)
            }
            Source::Ipc { clients, supervisor } => {
                self.forward_ipc(entry, clients, supervisor.as_ref(), xs)
            }
        }
    }

    /// The in-process zoo inner loop — the multi-kind generalization
    /// of [`crate::store::ModelBackend`]'s chain walk. Per step: pin
    /// every layer the step consumes (a readahead install can never
    /// evict mid-matmul, whichever tenant it belongs to), plan
    /// readahead from the step's *last* flat layer (so warming looks
    /// past the whole step, across shard stores and tenant
    /// boundaries), run the step math per batch item, stamp the GEMV
    /// phase into the owning store's cost table.
    fn forward_stores(
        &self,
        entry: &ChainEntry,
        stores: &[Arc<ModelStore>],
        xs: &[Vec<f32>],
    ) -> Result<Vec<Vec<f32>>> {
        let mut links: Vec<(&ModelStore, &str)> =
            Vec::with_capacity(entry.chain.layers().len());
        for (name, &owner) in
            entry.chain.layers().iter().zip(&entry.owners)
        {
            let Some(store) = stores.get(owner) else {
                bail!("layer {name:?} routed to missing store {owner}");
            };
            links.push((store.as_ref(), name.as_str()));
        }
        let mut outs: Vec<Vec<Vec<f32>>> = xs
            .iter()
            .map(|_| Vec::with_capacity(entry.chain.n_steps()))
            .collect();
        for step in entry.chain.steps() {
            let mut pinned = Vec::with_capacity(
                step.last_layer - step.first_layer + 1,
            );
            for li in step.first_layer..=step.last_layer {
                let Some((store, name)) = links.get(li) else {
                    bail!("step layer index {li} out of range");
                };
                pinned.push(store.get_pinned(name).with_context(
                    || format!("fetching layer {name:?}"),
                )?);
            }
            let depth = planned_depth(
                self.readahead,
                &links,
                step.last_layer,
                xs.len(),
            );
            if let Some((_, last_name)) = links.get(step.last_layer) {
                if depth > 0 {
                    obs::event(obs::SpanKind::ReadaheadPlan, last_name);
                }
            }
            for t in
                wrapped_targets(step.last_layer, links.len(), depth)
            {
                if let Some((store, name)) = links.get(t) {
                    store.prefetch_async(name);
                }
            }
            let execs: Vec<&ExecLayer> =
                pinned.iter().map(|p| p.layer().as_ref()).collect();
            let start = Instant::now();
            for (x, prior) in xs.iter().zip(outs.iter_mut()) {
                let y = run_step(step, &execs, x, prior)?;
                prior.push(y);
            }
            let took = start.elapsed();
            if let Some((store, name)) = links.get(step.last_layer) {
                obs::span(obs::SpanKind::Gemv, name, took);
                store.costs().record_gemv(name, took, xs.len());
            }
        }
        finalize(outs)
    }

    /// The IPC zoo inner loop: fetches ride model-scoped wire frames
    /// (`model` id + bare layer name — the worker joins the scoped
    /// name), warming is fixed-depth ahead of the step, and a
    /// transport failure routes through the supervisor's revive path
    /// once before giving up.
    fn forward_ipc(
        &self,
        entry: &ChainEntry,
        clients: &[Arc<IpcShardStore>],
        supervisor: Option<&Arc<Supervisor>>,
        xs: &[Vec<f32>],
    ) -> Result<Vec<Vec<f32>>> {
        let model = entry.chain.model();
        let n_layers = entry.chain.layers().len();
        let depth = self
            .readahead
            .max_depth()
            .min(n_layers.saturating_sub(1));
        let mut outs: Vec<Vec<Vec<f32>>> = xs
            .iter()
            .map(|_| Vec::with_capacity(entry.chain.n_steps()))
            .collect();
        for step in entry.chain.steps() {
            let mut layers = Vec::with_capacity(
                step.last_layer - step.first_layer + 1,
            );
            for li in step.first_layer..=step.last_layer {
                layers.push(self.ipc_fetch(
                    entry, clients, supervisor, model, li,
                )?);
            }
            // Warm ahead of the step on whichever workers own the
            // upcoming layers; admission is theirs to decline.
            for t in
                wrapped_targets(step.last_layer, n_layers, depth)
            {
                let (Some(&owner), Some(bare)) =
                    (entry.owners.get(t), entry.bare.get(t))
                else {
                    continue;
                };
                if let Some(client) = clients.get(owner) {
                    let _ = client.prefetch_model(model, bare);
                }
            }
            let execs: Vec<&ExecLayer> = layers.iter().collect();
            let start = Instant::now();
            for (x, prior) in xs.iter().zip(outs.iter_mut()) {
                let y = run_step(step, &execs, x, prior)?;
                prior.push(y);
            }
            let took = start.elapsed();
            if let Some(name) =
                entry.chain.layers().get(step.last_layer)
            {
                obs::span(obs::SpanKind::Gemv, name, took);
                self.costs.record_gemv(name, took, xs.len());
            }
        }
        finalize(outs)
    }

    /// Fetch flat layer `li` of a tenant's chain from its worker,
    /// repairing a transport failure through the supervisor once —
    /// the [`crate::ipc::ProcRouter`] contract, per tenant.
    fn ipc_fetch(
        &self,
        entry: &ChainEntry,
        clients: &[Arc<IpcShardStore>],
        supervisor: Option<&Arc<Supervisor>>,
        model: &str,
        li: usize,
    ) -> Result<ExecLayer> {
        let (Some(&owner), Some(bare)) =
            (entry.owners.get(li), entry.bare.get(li))
        else {
            bail!("chain layer index {li} out of range");
        };
        let Some(client) = clients.get(owner) else {
            bail!("layer {bare:?} routed to missing worker {owner}");
        };
        match client.fetch_model(model, bare) {
            Ok(layer) => Ok(layer),
            Err(IpcCallError::Remote(msg)) => Err(anyhow!(
                "worker {owner} rejected {model}::{bare}: {msg}"
            )),
            Err(IpcCallError::Transport(msg)) => {
                let Some(sup) = supervisor else {
                    bail!(
                        "worker {owner} unreachable fetching \
                         {model}::{bare}: {msg}"
                    );
                };
                sup.revive(owner)?;
                client.fetch_model(model, bare).map_err(|e| {
                    anyhow!(
                        "worker {owner} still failing after restart \
                         fetching {model}::{bare}: {e}"
                    )
                })
            }
        }
    }
}

/// Pop each item's final step output (every earlier output was only
/// ever scratch for step/residual references).
fn finalize(outs: Vec<Vec<Vec<f32>>>) -> Result<Vec<Vec<f32>>> {
    outs.into_iter()
        .map(|mut o| {
            o.pop().ok_or_else(|| anyhow!("chain produced no output"))
        })
        .collect()
}

/// Compile every tenant chain against the shared source: `dims` looks
/// up scoped-name geometry, `owner_of` routes a scoped name to its
/// store/client index.
fn compile_entries(
    chains: &[ChainSpec],
    mut dims: impl FnMut(&str) -> Option<(usize, usize)>,
    mut owner_of: impl FnMut(&str) -> Result<usize>,
) -> Result<Vec<ChainEntry>> {
    let mut entries = Vec::with_capacity(chains.len());
    for spec in chains {
        let chain = CompiledChain::compile(
            spec,
            |bare| scoped_name(&spec.model, bare),
            &mut dims,
        )?;
        let prefix = format!("{}{}", spec.model, MODEL_SEP);
        let mut bare = Vec::with_capacity(chain.layers().len());
        let mut owners = Vec::with_capacity(chain.layers().len());
        for scoped in chain.layers() {
            bare.push(
                scoped
                    .strip_prefix(&prefix)
                    .unwrap_or(scoped)
                    .to_string(),
            );
            owners.push(owner_of(scoped)?);
        }
        entries.push(ChainEntry { chain, bare, owners });
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::write_container_v3;
    use crate::models::{
        compressed_table, tiny_transformer_layers, transformer_chain,
        MlpConfig,
    };
    use crate::store::test_model;

    fn zoo_pair() -> (Container, Container) {
        (test_model(&[12, 10, 8], 11), test_model(&[12, 9, 6], 23))
    }

    fn big() -> StoreConfig {
        StoreConfig {
            cache_budget_bytes: usize::MAX,
            decode_workers: 2,
            ..StoreConfig::default()
        }
    }

    fn probe_batch(dim: usize) -> Vec<Vec<f32>> {
        (0..3)
            .map(|i| {
                (0..dim)
                    .map(|j| ((i * dim + j) as f32 * 0.37).sin())
                    .collect()
            })
            .collect()
    }

    #[test]
    fn merge_zoo_rejects_bad_zoos() {
        let (a, b) = zoo_pair();
        assert!(merge_zoo(&[]).is_err());
        let dup =
            [ZooModel::new("m", a.clone()), ZooModel::new("m", b)];
        assert!(merge_zoo(&dup).is_err());
        assert!(merge_zoo(&[ZooModel::new("a::b", a.clone())]).is_err());
        assert!(merge_zoo(&[ZooModel::new("", a)]).is_err());
        let hollow = [ZooModel::new("empty", Container::default())];
        assert!(merge_zoo(&hollow).is_err());
    }

    #[test]
    fn merge_scopes_layer_names_and_resolves_chains() {
        let (a, b) = zoo_pair();
        let merged = merge_zoo(&[
            ZooModel::new("chat", a),
            ZooModel::new("rank", b),
        ])
        .unwrap();
        let names: Vec<&str> = merged
            .container
            .layers
            .iter()
            .map(|l| l.name.as_str())
            .collect();
        assert!(names.contains(&"chat::fc0"));
        assert!(names.contains(&"chat::fc1"));
        assert!(names.contains(&"rank::fc1"));
        assert_eq!(merged.chains.len(), 2);
        assert_eq!(merged.chains[0].model, "chat");
        assert_eq!(merged.chains[1].model, "rank");
        // Chains stay in bare names — they are per-tenant programs,
        // scoping happens at compile time.
        assert_eq!(merged.chains[0].steps.len(), 2);
    }

    #[test]
    fn shared_budget_serves_bit_exact_with_cross_model_eviction() {
        let (a, b) = zoo_pair();
        let xs = probe_batch(12);

        // Reference: each tenant served alone, unlimited budget.
        let mut solo_a =
            ModelRegistry::new(&[ZooModel::new("a", a.clone())], big())
                .unwrap();
        let mut solo_b =
            ModelRegistry::new(&[ZooModel::new("b", b.clone())], big())
                .unwrap();
        let ra = solo_a.forward_model_batch("a", &xs).unwrap();
        let rb = solo_b.forward_model_batch("b", &xs).unwrap();

        // Shared store under a budget smaller than the combined
        // working set (a: 800 B decoded, b: 648 B): a burst on one
        // tenant must evict the other's cold layers, yet outputs stay
        // bit-identical to solo serving.
        let zoo = [ZooModel::new("a", a), ZooModel::new("b", b)];
        let mut reg = ModelRegistry::new(
            &zoo,
            StoreConfig {
                cache_budget_bytes: 700,
                decode_workers: 2,
                ..StoreConfig::default()
            },
        )
        .unwrap();
        assert_eq!(reg.model_ids(), vec!["a", "b"]);
        assert_eq!(reg.n_models(), 2);
        for _ in 0..3 {
            assert_eq!(reg.forward_model_batch("a", &xs).unwrap(), ra);
            assert_eq!(reg.forward_model_batch("b", &xs).unwrap(), rb);
        }
        reg.wait_for_idle();
        let m = reg.store_metrics().unwrap();
        assert_eq!(m.redundant_decodes, 0);
        assert!(
            m.evictions > 0,
            "budget below the combined working set must evict \
             cross-model: {m:?}"
        );

        // Per-tenant views filter the shared state by prefix.
        let (layers, bytes) = reg.model_cache("a").unwrap();
        assert!(layers <= 2, "tenant a caches at most its own chain");
        assert!(bytes <= 800);
        let costs = reg.model_costs("a");
        assert!(costs
            .iter()
            .any(|(name, c)| name == "fc0" && c.gemv_samples > 0));
        assert!(
            costs.iter().all(|(name, _)| !name.contains(MODEL_SEP)),
            "cost tables are keyed by bare layer name"
        );
    }

    #[test]
    fn sharded_zoo_matches_the_single_store() {
        let (a, b) = zoo_pair();
        let xs = probe_batch(12);
        let mut single = ModelRegistry::new(
            &[
                ZooModel::new("a", a.clone()),
                ZooModel::new("b", b.clone()),
            ],
            big(),
        )
        .unwrap();
        let mut sharded = ModelRegistry::new_sharded(
            &[ZooModel::new("a", a), ZooModel::new("b", b)],
            2,
            ShardAssignment::RoundRobin,
            big(),
        )
        .unwrap();
        assert_eq!(sharded.stores().len(), 2);
        assert_eq!(
            single.forward_model_batch("b", &xs).unwrap(),
            sharded.forward_model_batch("b", &xs).unwrap()
        );
        assert_eq!(
            single.forward_model_batch("a", &xs).unwrap(),
            sharded.forward_model_batch("a", &xs).unwrap()
        );
    }

    #[test]
    fn transformer_tenant_serves_next_to_an_mlp() {
        let specs = tiny_transformer_layers(1, 8, 16);
        let cfg = MlpConfig {
            seed: 5,
            sparsity: 0.75,
            n_s: 0,
            beam: None,
            ..MlpConfig::new(&[8, 8])
        };
        let (container, _) = compressed_table(&specs, &cfg);
        let chain = transformer_chain("tx", &specs).unwrap();
        let zoo = [
            ZooModel::new("tx", container).with_chain(chain),
            ZooModel::new("mlp", test_model(&[8, 6, 4], 3)),
        ];
        let mut reg = ModelRegistry::new(&zoo, big()).unwrap();
        assert_eq!(reg.model_input_dim("tx"), Some(8));
        assert_eq!(reg.model_output_dim("tx"), Some(8));
        assert!(reg.chain_layers("tx").unwrap().len() >= 6);
        let y = reg
            .forward_model_batch("tx", &[vec![0.3_f32; 8]])
            .unwrap();
        assert_eq!(y[0].len(), 8);
        assert!(y[0].iter().all(|v| v.is_finite()));
        let ym = reg
            .forward_model_batch("mlp", &[vec![0.1_f32; 8]])
            .unwrap();
        assert_eq!(ym[0].len(), 4);
        // Dim validation names the tenant.
        let err = reg
            .forward_model_batch("mlp", &[vec![0.0_f32; 5]])
            .unwrap_err();
        assert!(err.to_string().contains("mlp"), "{err}");
        assert!(reg
            .forward_model_batch("ghost", &[vec![0.0_f32; 8]])
            .is_err());
        // The anonymous single-model path refuses a multi-tenant zoo.
        assert!(reg.forward_batch(&[vec![0.0_f32; 8]]).is_err());
    }

    #[test]
    fn zoo_model_reads_a_v3_chain_from_bytes() {
        let specs = tiny_transformer_layers(1, 8, 16);
        let cfg = MlpConfig {
            seed: 9,
            sparsity: 0.75,
            n_s: 0,
            beam: None,
            ..MlpConfig::new(&[8, 8])
        };
        let (container, _) = compressed_table(&specs, &cfg);
        let chain = transformer_chain("orig-id", &specs).unwrap();
        let bytes = write_container_v3(&container, &[chain]);
        let m = ZooModel::from_bytes("tx", &bytes).unwrap();
        // The sole chain of a single-chain container is honored no
        // matter what id it was written under.
        assert!(m.chain.is_some());
        assert_eq!(m.container.layers.len(), specs.len());
        let mut reg = ModelRegistry::new(&[m], big()).unwrap();
        assert_eq!(reg.model_ids(), vec!["tx"]);
        let y = reg
            .forward_model_batch("tx", &[vec![0.2_f32; 8]])
            .unwrap();
        assert_eq!(y[0].len(), 8);
    }
}

impl Backend for ModelRegistry {
    /// The anonymous single-model path: only meaningful when the
    /// registry serves exactly one tenant.
    fn forward_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let _trace = obs::ensure_trace();
        match self.entries.as_slice() {
            [only] => self.forward_entry(only, xs),
            many => bail!(
                "registry serves {} models; address one by id",
                many.len()
            ),
        }
    }

    fn input_dim(&self) -> usize {
        self.entries
            .first()
            .map(|e| e.chain.input_dim())
            .unwrap_or(0)
    }

    fn output_dim(&self) -> usize {
        self.entries
            .first()
            .map(|e| e.chain.output_dim())
            .unwrap_or(0)
    }

    fn models(&self) -> Vec<String> {
        self.model_ids()
    }

    fn forward_model_batch(
        &mut self,
        model: &str,
        xs: &[Vec<f32>],
    ) -> Result<Vec<Vec<f32>>> {
        if model.is_empty() {
            return self.forward_batch(xs);
        }
        let _trace = obs::ensure_trace();
        let Some(entry) = self.entry(model) else {
            bail!("registry serves no model {model:?}");
        };
        self.forward_entry(entry, xs)
    }

    fn model_input_dim(&self, model: &str) -> Option<usize> {
        self.entry(model).map(|e| e.chain.input_dim())
    }

    fn model_output_dim(&self, model: &str) -> Option<usize> {
        self.entry(model).map(|e| e.chain.output_dim())
    }
}

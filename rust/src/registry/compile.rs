//! Chain compilation: a wire [`ChainSpec`] resolved against real layer
//! geometry into an executable step program.
//!
//! Compilation is where a chain stops being a description and starts
//! being a contract: every referenced layer must exist, every step's
//! input dimension must match what its source produces, residual adds
//! must be shape-compatible, attention groups must agree on head
//! geometry and conv steps on patch geometry. All of it is checked
//! here, once, against the container index — nothing is decoded — so
//! the serving hot path never discovers a shape bug mid-batch.

use crate::container::{
    Activation, ChainSpec, Residual, StepInput, StepKind,
};
use crate::kernels::ExecLayer;
use anyhow::{bail, Context, Result};

/// What one compiled step computes; layer references are implicit — a
/// step consumes a contiguous run of the chain's flat layer list
/// (`first_layer..=last_layer`), in [`StepKind::layer_names`] order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) enum StepOp {
    /// `y = W·x` over the step's single layer.
    Gemv,
    /// Sequence-length-1 attention over `[q, k, v, output]`.
    Attention,
    /// Conv-as-GEMM: tile the incoming channel vector `kh·kw` times
    /// into the im2col patch, then one GEMV.
    Conv { kh: usize, kw: usize },
}

/// One step of a compiled chain: the operation, resolved data flow,
/// and the flat-list span of layers it consumes.
#[derive(Debug, Clone)]
pub(crate) struct StepExec {
    pub op: StepOp,
    pub input: StepInput,
    pub residual: Residual,
    pub activation: Activation,
    pub in_dim: usize,
    pub out_dim: usize,
    /// First index into [`CompiledChain::layers`] this step consumes.
    pub first_layer: usize,
    /// Last (inclusive) index — readahead plans from here, so warming
    /// looks past the whole step instead of at its own projections.
    pub last_layer: usize,
}

/// A [`ChainSpec`] compiled against layer geometry: the flat fetch
/// list (driving pinning and readahead) plus the validated step
/// program the executor runs.
#[derive(Debug, Clone)]
pub struct CompiledChain {
    model: String,
    layers: Vec<String>,
    steps: Vec<StepExec>,
    input_dim: usize,
    output_dim: usize,
}

impl CompiledChain {
    /// Compile `spec`: resolve every layer name through `rename` (the
    /// registry scopes to `{model}::{layer}` here; identity for a
    /// plain container) and look up `(rows, cols)` through `dims`.
    /// Errors name the model and step; nothing is decoded.
    pub fn compile(
        spec: &ChainSpec,
        mut rename: impl FnMut(&str) -> String,
        mut dims: impl FnMut(&str) -> Option<(usize, usize)>,
    ) -> Result<Self> {
        if spec.steps.is_empty() {
            bail!("chain {:?} has no steps", spec.model);
        }
        let mut layers: Vec<String> = Vec::new();
        let mut steps: Vec<StepExec> = Vec::new();
        let mut out_dims: Vec<usize> = Vec::new();
        // The chain's input dim is whatever the first step that reads
        // the chain input demands; later readers must agree.
        let mut chain_input: Option<usize> = None;
        for (si, step) in spec.steps.iter().enumerate() {
            let first_layer = layers.len();
            let mut push = |name: &str| -> Result<(usize, usize)> {
                let scoped = rename(name);
                let Some(d) = dims(&scoped) else {
                    bail!(
                        "chain {:?} step {si}: layer {scoped:?} is not \
                         in the store",
                        spec.model
                    );
                };
                layers.push(scoped);
                Ok(d)
            };
            let (op, in_dim, out_dim) = match &step.kind {
                StepKind::Gemv { layer } => {
                    let (rows, cols) = push(layer)?;
                    (StepOp::Gemv, cols, rows)
                }
                StepKind::Attention { q, k, v, output } => {
                    let (qr, qc) = push(q)?;
                    let (kr, kc) = push(k)?;
                    let (vr, vc) = push(v)?;
                    let (or_, oc) = push(output)?;
                    if kc != qc || vc != qc {
                        bail!(
                            "chain {:?} step {si}: attention \
                             projections disagree on input dim \
                             (q {qc}, k {kc}, v {vc})",
                            spec.model
                        );
                    }
                    if kr != qr {
                        bail!(
                            "chain {:?} step {si}: q projects to {qr} \
                             but k to {kr}",
                            spec.model
                        );
                    }
                    if oc != vr {
                        bail!(
                            "chain {:?} step {si}: output projection \
                             expects {oc} but v produces {vr}",
                            spec.model
                        );
                    }
                    (StepOp::Attention, qc, or_)
                }
                StepKind::Conv { layer, kh, kw, in_ch, out_ch } => {
                    let (rows, cols) = push(layer)?;
                    let Some(patch) = kh
                        .checked_mul(*kw)
                        .and_then(|p| p.checked_mul(*in_ch))
                        .filter(|p| *p > 0)
                    else {
                        bail!(
                            "chain {:?} step {si}: degenerate conv \
                             geometry {kh}x{kw}x{in_ch}",
                            spec.model
                        );
                    };
                    if cols != patch {
                        bail!(
                            "chain {:?} step {si}: conv layer has \
                             {cols} cols but {kh}x{kw}x{in_ch} im2col \
                             patches are {patch} wide",
                            spec.model
                        );
                    }
                    if rows != *out_ch {
                        bail!(
                            "chain {:?} step {si}: conv layer has \
                             {rows} rows but declares {out_ch} output \
                             channels",
                            spec.model
                        );
                    }
                    (StepOp::Conv { kh: *kh, kw: *kw }, *in_ch, rows)
                }
            };
            // Bind the input dim against wherever the step reads from.
            let mut bind_chain_input = |need: usize| -> Result<()> {
                match chain_input {
                    Some(have) if have != need => bail!(
                        "chain {:?} step {si}: reads the chain input \
                         as {need} values but an earlier step reads \
                         it as {have}",
                        spec.model
                    ),
                    Some(_) => Ok(()),
                    None => {
                        chain_input = Some(need);
                        Ok(())
                    }
                }
            };
            match step.input {
                StepInput::Prev if si == 0 => bind_chain_input(in_dim)?,
                StepInput::Prev => {
                    let have = out_dims.last().copied().unwrap_or(0);
                    if have != in_dim {
                        bail!(
                            "chain {:?} step {si}: expects {in_dim} \
                             values but the previous step produces \
                             {have}",
                            spec.model
                        );
                    }
                }
                StepInput::ChainInput => bind_chain_input(in_dim)?,
                StepInput::Step(j) => {
                    let Some(have) =
                        (j < si).then(|| out_dims.get(j).copied()).flatten()
                    else {
                        bail!(
                            "chain {:?} step {si}: input references \
                             step {j} (must be strictly earlier)",
                            spec.model
                        );
                    };
                    if have != in_dim {
                        bail!(
                            "chain {:?} step {si}: expects {in_dim} \
                             values but step {j} produces {have}",
                            spec.model
                        );
                    }
                }
            }
            // The residual is added to the step output — dims must
            // match the output, not the input.
            match step.residual {
                Residual::None => {}
                Residual::ChainInput => {
                    bind_chain_input(out_dim).with_context(|| {
                        format!(
                            "chain {:?} step {si}: residual reads the \
                             chain input",
                            spec.model
                        )
                    })?;
                }
                Residual::OwnInput => {
                    if in_dim != out_dim {
                        bail!(
                            "chain {:?} step {si}: x + f(x) residual \
                             needs matching dims, got {in_dim} -> \
                             {out_dim}",
                            spec.model
                        );
                    }
                }
                Residual::Step(j) => {
                    let Some(have) =
                        (j < si).then(|| out_dims.get(j).copied()).flatten()
                    else {
                        bail!(
                            "chain {:?} step {si}: residual references \
                             step {j} (must be strictly earlier)",
                            spec.model
                        );
                    };
                    if have != out_dim {
                        bail!(
                            "chain {:?} step {si}: residual from step \
                             {j} is {have} wide but the output is \
                             {out_dim}",
                            spec.model
                        );
                    }
                }
            }
            let Some(last_layer) = layers.len().checked_sub(1) else {
                bail!(
                    "chain {:?} step {si} consumes no layers",
                    spec.model
                );
            };
            out_dims.push(out_dim);
            steps.push(StepExec {
                op,
                input: step.input,
                residual: step.residual,
                activation: step.activation,
                in_dim,
                out_dim,
                first_layer,
                last_layer,
            });
        }
        let Some(input_dim) = chain_input.or_else(|| {
            steps.first().map(|s| s.in_dim)
        }) else {
            bail!("chain {:?} never binds an input", spec.model);
        };
        let Some(output_dim) = out_dims.last().copied() else {
            bail!("chain {:?} produces no output", spec.model);
        };
        Ok(CompiledChain {
            model: spec.model.clone(),
            layers,
            steps,
            input_dim,
            output_dim,
        })
    }

    /// The model id this chain serves.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Every layer the chain fetches, in execution order, under the
    /// names the compile-time `rename` produced (scoped names when the
    /// registry compiled it against a merged store).
    pub fn layers(&self) -> &[String] {
        &self.layers
    }

    /// Number of executable steps.
    pub fn n_steps(&self) -> usize {
        self.steps.len()
    }

    pub(crate) fn steps(&self) -> &[StepExec] {
        &self.steps
    }

    /// Input vector length the chain demands.
    pub fn input_dim(&self) -> usize {
        self.input_dim
    }

    /// Output vector length the chain produces.
    pub fn output_dim(&self) -> usize {
        self.output_dim
    }
}

/// Execute one step for one batch item. `fetched` is the step's layer
/// span (in [`StepKind::layer_names`] order), `chain_x` the item's
/// chain input, `prior` its earlier step outputs (so `prior.len()` is
/// this step's index). Order is fixed: matmul(s), residual add,
/// activation.
pub(crate) fn run_step(
    step: &StepExec,
    fetched: &[&ExecLayer],
    chain_x: &[f32],
    prior: &[Vec<f32>],
) -> Result<Vec<f32>> {
    let x: &[f32] = match step.input {
        StepInput::Prev => {
            prior.last().map(Vec::as_slice).unwrap_or(chain_x)
        }
        StepInput::ChainInput => chain_x,
        StepInput::Step(j) => {
            let Some(v) = prior.get(j) else {
                bail!("step input references missing step {j}");
            };
            v.as_slice()
        }
    };
    if x.len() != step.in_dim {
        bail!(
            "step input is {} values, compiled for {}",
            x.len(),
            step.in_dim
        );
    }
    let mut y = match step.op {
        StepOp::Gemv => {
            let Some(w) = fetched.first() else {
                bail!("gemv step fetched no layer");
            };
            w.gemv(x)
        }
        StepOp::Attention => {
            let [wq, wk, wv, wo] = fetched else {
                bail!(
                    "attention step fetched {} layers, expected 4",
                    fetched.len()
                );
            };
            let q = wq.gemv(x);
            let k = wk.gemv(x);
            let v = wv.gemv(x);
            // Sequence length 1: the lone score softmaxes to exactly
            // 1, so the context *is* v — but the score is still
            // computed and sanity-checked, because a non-finite
            // q·k/√d is a model bug worth failing loudly on rather
            // than laundering through the softmax identity.
            let scale = (q.len().max(1) as f32).sqrt();
            let score = q
                .iter()
                .zip(&k)
                .map(|(a, b)| a * b)
                .sum::<f32>()
                / scale;
            if !score.is_finite() {
                bail!("attention score is not finite ({score})");
            }
            wo.gemv(&v)
        }
        StepOp::Conv { kh, kw } => {
            let Some(w) = fetched.first() else {
                bail!("conv step fetched no layer");
            };
            let tiles = kh.saturating_mul(kw);
            let mut patch =
                Vec::with_capacity(tiles.saturating_mul(x.len()));
            for _ in 0..tiles {
                patch.extend_from_slice(x);
            }
            w.gemv(&patch)
        }
    };
    let residual: Option<&[f32]> = match step.residual {
        Residual::None => None,
        Residual::ChainInput => Some(chain_x),
        Residual::OwnInput => Some(x),
        Residual::Step(j) => {
            let Some(v) = prior.get(j) else {
                bail!("residual references missing step {j}");
            };
            Some(v.as_slice())
        }
    };
    if let Some(r) = residual {
        if r.len() != y.len() {
            bail!(
                "residual is {} values but the step output is {}",
                r.len(),
                y.len()
            );
        }
        for (a, b) in y.iter_mut().zip(r) {
            *a += b;
        }
    }
    step.activation.apply(&mut y);
    Ok(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::ChainStep;

    /// Dims table: fc0 8x4, fc1 2x8; attention block 4x4 each + conv.
    fn dims_of(name: &str) -> Option<(usize, usize)> {
        match name {
            "m::fc0" => Some((8, 4)),
            "m::fc1" => Some((2, 8)),
            "m::q" | "m::k" | "m::v" | "m::o" => Some((4, 4)),
            "m::conv" => Some((6, 2 * 2 * 4)),
            _ => None,
        }
    }

    fn scoped(name: &str) -> String {
        format!("m::{name}")
    }

    #[test]
    fn uniform_chain_compiles_with_flat_layout() {
        let spec = ChainSpec::uniform("m", &["fc0", "fc1"]);
        let c =
            CompiledChain::compile(&spec, scoped, dims_of).unwrap();
        assert_eq!(c.model(), "m");
        assert_eq!(c.layers(), &["m::fc0".to_string(), "m::fc1".into()]);
        assert_eq!((c.input_dim(), c.output_dim()), (4, 2));
        assert_eq!(c.n_steps(), 2);
        assert_eq!(c.steps()[0].last_layer, 0);
        assert_eq!(c.steps()[1].first_layer, 1);
    }

    #[test]
    fn attention_and_conv_geometry_is_validated() {
        let spec = ChainSpec {
            model: "m".into(),
            steps: vec![
                ChainStep {
                    kind: StepKind::Attention {
                        q: "q".into(),
                        k: "k".into(),
                        v: "v".into(),
                        output: "o".into(),
                    },
                    input: StepInput::ChainInput,
                    residual: Residual::OwnInput,
                    activation: Activation::None,
                },
                ChainStep {
                    kind: StepKind::Conv {
                        layer: "conv".into(),
                        kh: 2,
                        kw: 2,
                        in_ch: 4,
                        out_ch: 6,
                    },
                    input: StepInput::Prev,
                    residual: Residual::None,
                    activation: Activation::Relu,
                },
            ],
        };
        let c =
            CompiledChain::compile(&spec, scoped, dims_of).unwrap();
        assert_eq!(c.layers().len(), 5);
        assert_eq!((c.input_dim(), c.output_dim()), (4, 6));
        // One attention step spans four flat layers.
        assert_eq!(c.steps()[0].first_layer, 0);
        assert_eq!(c.steps()[0].last_layer, 3);

        // Wrong out_ch declaration.
        let mut bad = spec.clone();
        if let StepKind::Conv { out_ch, .. } = &mut bad.steps[1].kind {
            *out_ch = 7;
        }
        let err = CompiledChain::compile(&bad, scoped, dims_of)
            .unwrap_err();
        assert!(format!("{err}").contains("output channels"), "{err}");

        // Patch width mismatch.
        let mut bad = spec.clone();
        if let StepKind::Conv { kh, .. } = &mut bad.steps[1].kind {
            *kh = 3;
        }
        let err = CompiledChain::compile(&bad, scoped, dims_of)
            .unwrap_err();
        assert!(format!("{err}").contains("im2col"), "{err}");
    }

    #[test]
    fn dim_mismatches_are_rejected() {
        // fc1 then fc0: fc1 outputs 2, fc0 expects 4.
        let spec = ChainSpec::uniform("m", &["fc1", "fc0"]);
        let err = CompiledChain::compile(&spec, scoped, dims_of)
            .unwrap_err();
        assert!(format!("{err}").contains("previous step"), "{err}");

        // Missing layer.
        let spec = ChainSpec::uniform("m", &["ghost"]);
        let err = CompiledChain::compile(&spec, scoped, dims_of)
            .unwrap_err();
        assert!(format!("{err}").contains("not in the store"), "{err}");

        // x + f(x) on a non-square step.
        let mut spec = ChainSpec::uniform("m", &["fc0"]);
        spec.steps[0].residual = Residual::OwnInput;
        let err = CompiledChain::compile(&spec, scoped, dims_of)
            .unwrap_err();
        assert!(format!("{err}").contains("matching dims"), "{err}");

        // Residual from a step of the wrong width.
        let mut spec = ChainSpec::uniform("m", &["fc0", "fc1"]);
        spec.steps[1].residual = Residual::Step(0);
        let err = CompiledChain::compile(&spec, scoped, dims_of)
            .unwrap_err();
        assert!(format!("{err}").contains("residual"), "{err}");

        // Conflicting chain-input readers.
        let mut spec = ChainSpec::uniform("m", &["fc0", "fc1"]);
        spec.steps[1].input = StepInput::ChainInput;
        let err = CompiledChain::compile(&spec, scoped, dims_of)
            .unwrap_err();
        assert!(format!("{err}").contains("earlier step reads"), "{err}");

        let empty = ChainSpec { model: "m".into(), steps: vec![] };
        assert!(
            CompiledChain::compile(&empty, scoped, dims_of).is_err()
        );
    }

    #[test]
    fn run_step_math_matches_hand_reference() {
        use crate::sparse::DecodedLayer;
        // A 2x3 layer with known weights via DecodedLayer.
        let w = DecodedLayer {
            rows: 2,
            cols: 3,
            weights: vec![1.0, 0.0, -1.0, 0.5, 0.5, 0.5],
        };
        let layer = ExecLayer::Materialized(w);
        let step = StepExec {
            op: StepOp::Gemv,
            input: StepInput::Prev,
            residual: Residual::None,
            activation: Activation::Relu,
            in_dim: 3,
            out_dim: 2,
            first_layer: 0,
            last_layer: 0,
        };
        let y = run_step(&step, &[&layer], &[1.0, 2.0, 4.0], &[])
            .unwrap();
        // Row 0: 1 - 4 = -3 -> relu 0; row 1: 0.5*(1+2+4) = 3.5.
        assert_eq!(y, vec![0.0, 3.5]);

        // Residual add from the chain input, then no activation.
        let step = StepExec {
            residual: Residual::ChainInput,
            activation: Activation::None,
            in_dim: 3,
            out_dim: 2,
            ..step
        };
        // chain input must be out_dim-wide for this shape to work:
        // use Step(0)-style prior instead.
        let err =
            run_step(&step, &[&layer], &[1.0, 2.0, 4.0], &[]).unwrap_err();
        assert!(format!("{err}").contains("residual"), "{err}");
    }
}

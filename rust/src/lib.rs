//! # f2f — Fixed-to-Fixed Encoding of Irregularly Sparse Weights
//!
//! Reproduction of *"Encoding Weights of Irregular Sparsity for
//! Fixed-to-Fixed Model Compression"* (Park, Kwon, Oh, Kim, Lee — ICLR 2022).
//!
//! Fine-grained (unstructured) pruning achieves high sparsity but classic
//! sparse formats (CSR) translate fixed-size weight blocks into
//! variable-size ones, wrecking memory-bandwidth utilization on parallel
//! hardware. This crate implements the paper's alternative: a **lossless
//! fixed-to-fixed encoding** where every `N_out`-bit weight block is stored
//! as exactly `N_in` encoded bits, decoded through a fixed XOR-gate network
//! (a random linear code over GF(2)) augmented with shift registers so one
//! encoded vector is reused across `N_s + 1` consecutive blocks
//! ("sequential" decoding). Encoding is a Viterbi-style dynamic program
//! that minimizes unmatched bits; residual mismatches are patched by a
//! compact correction stream, making the scheme lossless.
//!
//! ## Layout
//!
//! * [`gf2`] — bit-packed blocks and GF(2) linear algebra (the decoder is a
//!   binary matrix; decoding is a GF(2) mat-vec, table-accelerated).
//! * [`decoder`] — combinational (`N_s = 0`) and sequential XOR-gate
//!   decoders, plus the hardware cost model from the paper's Appendix G.
//! * [`encoder`] — exhaustive and Viterbi-DP encoders with encoding
//!   efficiency statistics.
//! * [`weights`] — bit-plane grouping / flattening / slicing of FP32 and
//!   INT8 tensors, and the inverting technique.
//! * [`pruning`] — random / magnitude / L0-style / variational-dropout
//!   style mask generation plus `n_u` statistics (coefficient of variation).
//! * [`entropy`] — Appendix D entropy bounds on block compression.
//! * [`correction`] — Appendix F lossless correction (patch) format.
//! * [`container`] — serialized compressed-model container with lossless
//!   round-trip; legacy v1 (`F2F1`) plus the indexed v2 (`F2F2`) layout
//!   whose layer-offset index makes any layer addressable without
//!   parsing the whole file, and the `F2F3` shard-map sidecar that
//!   partitions a v2 container into per-shard files
//!   ([`container::ShardMap`], [`container::split_container`]).
//! * [`sparse`] — CSR + SpMV baseline (Algorithm 1) and the
//!   decode-then-GEMV fixed-to-fixed path (Algorithm 2).
//! * [`kernels`] — word-parallel hot-loop kernels exploiting the
//!   format's regularity: a block writer laying decoded `N_out`-bit
//!   blocks into `u64` words, the 64×64 bit-matrix transpose behind
//!   word-level reassembly (64 weights per iteration under a
//!   word-masked prune gate), and the fused decode→GEMV
//!   [`kernels::FusedLayer`] that never materializes dense f32 —
//!   surfaced as [`kernels::DecodeMode`] on stores and `serve
//!   --decode-mode` (see *Serving a whole model*). `F2F_KERNEL=scalar`
//!   forces the portable per-bit fallback.
//! * [`store`] — model store + streaming decode engine: a persistent
//!   background decode service with async submit/wait handles and a
//!   worker-side record-parse stage ([`store::DecodeService`];
//!   [`store::DecodePool`] remains for one-shot bulk decodes), a
//!   byte-budgeted LRU of decoded layers as a concurrent subsystem —
//!   in-flight decode dedup, async `prefetch_async`,
//!   pin-while-executing ([`store::ModelStore`]) — per-layer timing
//!   telemetry (`store::timing`: [`store::LayerCosts`] EWMAs of decode
//!   submit→install and per-item GEMV, stamped at the source), a
//!   [`store::ReadaheadPolicy`] that warms layer `i+1` while layer `i`
//!   executes — fixed depth, or `Auto`: a planner sizing depth-`k`
//!   warming against the predicted GEMV window and store budget — the
//!   readahead-driven multi-layer [`store::ModelBackend`], and a
//!   [`store::RecordSource`] that holds the compressed bytes as owned
//!   memory or (with the `mmap` feature) a read-only file mapping
//!   paged in on demand.
//! * [`shard`] — horizontal scale-out: a [`shard::ShardRouter`] serving
//!   one split model from N independent stores (per-shard decode
//!   services and budgets, cross-shard readahead, aggregated metrics
//!   with a merged cost table), bit-identical to the single-store
//!   path; plus observed-cost rebalancing (`shard::rebalance`:
//!   [`shard::CostProfile`] JSON snapshots of the cost tables and
//!   [`shard::rebalance_map`] re-partitioning on measured per-layer
//!   decode time — the `f2f rebalance` CLI).
//! * `ipc` (unix) — multi-process sharded serving: a hand-rolled
//!   length-prefixed wire protocol over unix domain sockets
//!   (`ipc::wire`), the `f2f shard-worker` child-process entrypoint
//!   (one mmap-backed store behind a `UnixListener`), the
//!   reconnecting `ipc::IpcShardStore` client, an `ipc::ProcRouter`
//!   [`coordinator::Backend`] that walks the chain across worker
//!   *processes* with cross-process readahead, and an
//!   `ipc::Supervisor` that spawns, health-checks and restarts
//!   workers (shard assignment replayed) while aggregating metrics
//!   and cost tables over the wire — `f2f serve --shard-procs N`.
//! * [`obs`] — observability: a lock-cheap span recorder (fixed ring
//!   buffer, relaxed atomics, zero allocation on the hot path) with a
//!   span taxonomy covering the whole serving path — queueing
//!   (`enqueue`/`queue`/`batch_form`/`batch`), per-layer `gemv`,
//!   `decode` submit→install, `readahead_plan`/`readahead_skip`,
//!   `cache_hit`/`cache_miss`/`evict`, and `ipc_fetch`/`ipc_prefetch`
//!   round trips — plus trace-context propagation (the server mints a
//!   trace id per batch; `Fetch`/`Prefetch` frames carry it to shard
//!   workers so cross-process spans stitch into one timeline),
//!   mergeable log-bucketed latency histograms ([`obs::HdrLite`], the
//!   percentile engine under [`coordinator::MetricsSnapshot`] and
//!   [`store::StoreMetrics`]), and exporters: Chrome trace-event JSON
//!   ([`obs::chrome_trace`] — `serve --trace-out`, one pid lane per
//!   process, Perfetto-loadable) and a unified JSON metrics registry
//!   (`serve --metrics-out`, counters + histograms + cost table via
//!   [`bench_util::JsonReport`]). Recording compiles out with
//!   `--no-default-features` (the on-by-default `obs` feature) and has
//!   a runtime kill switch for overhead measurement. On top of the
//!   recorder sits the **live operations plane**: a streaming stats
//!   server answering `Metrics`/`CostProfile` wire frames on a unix
//!   socket *while serving* (`serve --stats-socket`, polled by the
//!   `f2f top` CLI), a structured rate-limited JSONL event journal
//!   ([`obs::events`], `serve --events-out`) replacing ad-hoc stderr
//!   prints, a crash flight recorder ([`obs::flight`] — workers
//!   checkpoint their span ring to a sidecar; the supervisor turns a
//!   dead worker's sidecar into a postmortem artifact with an
//!   attributed exit cause), and a latency watchdog
//!   ([`obs::watchdog`]) that emits `anomaly` events when live EWMAs
//!   regress against their rolling baseline.
//! * [`bandwidth`] — memory transaction / bandwidth-utilization simulator
//!   (Figure 1, Appendix A).
//! * [`models`] — synthetic Transformer / ResNet-50 model zoo with
//!   realistic FP32 bit-plane statistics.
//! * [`pipeline`] — end-to-end compression pipeline over whole models.
//! * [`coordinator`] — serving stack: router, dynamic batcher, workers.
//! * [`registry`] — the multi-tenant model zoo: container v3
//!   layer-kind chains compiled to executable step programs
//!   ([`registry::CompiledChain`] — gemv, attention groups,
//!   conv-as-GEMM, residual links), [`registry::merge_zoo`] folding N
//!   models into one `{model}::{layer}`-named container, and
//!   [`registry::ModelRegistry`] serving all of them from one shared
//!   store / shard set / worker fleet under one byte budget (see
//!   *Serving a model zoo* below).
//! * [`runtime`] — PJRT (XLA) runtime that loads AOT-compiled artifacts.
//! * [`report`] — textual table/figure rendering for the repro harness.
//! * [`repro`] — one entry point per paper table/figure.
//! * [`analysis`] — the `f2f lint` soundness scanner: a
//!   dependency-free token-level analyzer enforcing the repo's
//!   panic-free-serving, SAFETY-comment and lock-poisoning invariants
//!   (see *Soundness & analysis* below).
//! * [`sync`] — poison-tolerant lock/condvar helpers shared by every
//!   serving module.
//!
//! ## Serving a whole model
//!
//! A compressed multi-layer network serves end to end without ever
//! materializing all of its decoded weights at once, with decode
//! overlapping compute:
//!
//! ```no_run
//! use f2f::container::write_container_v2;
//! use f2f::coordinator::{InferenceServer, ServerConfig};
//! use f2f::kernels::DecodeMode;
//! use f2f::store::{ModelBackend, ModelStore, ReadaheadPolicy, StoreConfig};
//! use std::sync::Arc;
//!
//! # fn demo(container: f2f::container::Container) -> anyhow::Result<()> {
//! // Compress with `Compressor::compress_model`, then write the indexed
//! // v2 layout so any layer is addressable on its own.
//! let bytes = write_container_v2(&container);
//!
//! // A store with a decoded-weight budget smaller than the model:
//! // layers decode on miss (persistent workers, per bit-plane) and cold
//! // layers are evicted; in-flight dedup means a get racing a readahead
//! // never decodes twice.
//! let store = Arc::new(ModelStore::open_bytes(
//!     bytes,
//!     StoreConfig {
//!         cache_budget_bytes: 64 << 20,
//!         decode_workers: 4,
//!         decode_mode: DecodeMode::Auto,
//!     },
//! )?);
//!
//! // A multi-layer GEMV chain behind the batching inference server.
//! // While layer i executes (pinned — readahead installs cannot evict
//! // it), layer i+1 decodes in the background.
//! let backend = ModelBackend::sequential(store.clone())?
//!     .with_readahead(ReadaheadPolicy::layers(1));
//! let server = InferenceServer::start(ServerConfig::default(), move || {
//!     Box::new(backend)
//! })?;
//! let y = server.infer(vec![0.0; server.input_dim()])?;
//! # let _ = y;
//! # Ok(())
//! # }
//! ```
//!
//! ### Decode modes and word-parallel kernels
//!
//! The store's decode pipeline runs on word-parallel kernels by
//! default ([`kernels`]): decoded blocks land in `u64` words via a
//! block writer instead of per-bit stores, and reassembly transposes
//! 64 plane words at a time instead of probing every plane per weight.
//! What the decode *produces* is the store's
//! [`kernels::DecodeMode`] (`StoreConfig::decode_mode`, CLI `serve
//! --decode-mode`):
//!
//! * `materialized` (default) — the dense f32 buffer, as before.
//! * `fused` — a [`kernels::FusedLayer`]: decoded bit-planes + mask
//!   stay resident and the GEMV decodes 64 weights at a time on the
//!   fly. I8 layers shrink to ~9/32 of their dense footprint, so the
//!   same cache budget holds ~3.5× more layers, readahead admission
//!   accepts deeper warms, and shard workers ship fewer bytes per
//!   fetch.
//! * `auto` — per layer, whichever representation is smaller
//!   (fused for I8, materialized for F32), priced from the same
//!   geometry the planners use so byte accounting stays consistent.
//!
//! Every mode is bit-exact with every other (identical f32
//! accumulation order, pinned down by `rust/tests/fused_parity.rs`),
//! and flows through [`shard::ShardRouter`] and `ipc::ProcRouter`
//! unchanged — fused layers cross the IPC wire as plane words, not
//! dense f32.
//!
//! To scale out horizontally, split the same container across N shards
//! ([`container::write_sharded`] / the `f2f shard` CLI) and serve it
//! with a [`shard::ShardRouter`] — the same [`coordinator::Backend`]
//! surface and bit-identical outputs, but per-shard decode services,
//! per-shard cache budgets, cross-shard readahead, and (with the `mmap`
//! feature, on by default) per-shard container files paged in lazily.
//!
//! To scale past one address space, serve each shard from its own
//! *process*: `f2f serve --shard-procs N` spawns one `f2f
//! shard-worker` per shard file (supervised — a crashed worker is
//! restarted with its shard assignment replayed), and an
//! `ipc::ProcRouter` walks the same chain over unix-socket IPC with
//! cross-process readahead, still bit-identical to the single store.
//!
//! ## Serving a model zoo
//!
//! One process can serve *N* models from the same decode capacity and
//! byte budget. Container **v3** (same `F2F2` magic, version 3)
//! records each model's executable structure next to its weights —
//! [`container::ChainSpec`] steps for plain gemv+activation ladders,
//! attention Q/K/V/output groups (sequence length 1), conv-as-GEMM
//! with im2col geometry, and residual/skip links — so a compressed
//! Transformer or ResNet round-trips into something executable, not a
//! naming convention. [`registry::merge_zoo`] folds the tenants into
//! one container whose layers are named `{model}::{layer}`, and a
//! [`registry::ModelRegistry`] serves them concurrently:
//!
//! ```no_run
//! use f2f::coordinator::{InferenceServer, ServerConfig};
//! use f2f::registry::{ModelRegistry, ZooModel};
//! use f2f::store::StoreConfig;
//!
//! # fn demo(a: f2f::container::Container, b: f2f::container::Container) -> anyhow::Result<()> {
//! // Two models, one store: a shared byte budget, one cross-model
//! // LRU, one in-flight decode table, shared decode workers. A burst
//! // on "chat" evicts cold "rank" layers — never pinned ones.
//! let registry = ModelRegistry::new(
//!     &[ZooModel::new("chat", a), ZooModel::new("rank", b)],
//!     StoreConfig { cache_budget_bytes: 32 << 20, ..StoreConfig::default() },
//! )?;
//! let server = InferenceServer::start(ServerConfig::default(), move || {
//!     Box::new(registry)
//! })?;
//! // Requests route by model id; batches never mix models.
//! let dim = server.model_input_dim("chat").unwrap_or(0);
//! let y = server.infer_model("chat", vec![0.0; dim])?;
//! # let _ = y;
//! # Ok(())
//! # }
//! ```
//!
//! The registry is itself a [`coordinator::Backend`], so the batching
//! server, per-model [`coordinator::MetricsSnapshot`] windows, and the
//! live stats plane all apply per tenant. The same zoo serves from N
//! in-process shard stores ([`registry::ModelRegistry::new_sharded`])
//! or from `f2f shard-worker` processes
//! ([`registry::ModelRegistry::over_ipc`]) — `Fetch`/`Prefetch` wire
//! frames carry a model-id byte range, and `f2f serve --models
//! a=a.f2f,b=b.f2f` drives all three paths from the CLI. Outputs are
//! bit-identical to serving each model alone: same decode, same f32
//! accumulation order, whatever the co-tenant traffic does.
//!
//! ## Observability
//!
//! Every stage of that path is traced. The inference server mints a
//! trace id per batch; the forward chain, stores, decode service and
//! IPC client record spans under it ([`obs::SpanKind`] is the
//! taxonomy: queueing → batch → per-layer `gemv` → `decode`, plus
//! readahead/cache/IPC events), and `Fetch`/`Prefetch` wire frames
//! carry the id into `shard-worker` processes so one request's
//! timeline stitches across pid lanes. `f2f serve --trace-out t.json`
//! exports Chrome trace-event JSON (open in `chrome://tracing` or
//! Perfetto); `--metrics-out m.json` dumps the unified registry —
//! counters, mergeable [`obs::HdrLite`] latency histograms at
//! request / batch / decode / GEMV granularity, and the per-layer cost
//! table in [`shard::CostProfile`]-compatible form. Span recording is
//! governed by the on-by-default `obs` cargo feature
//! (`--no-default-features` compiles it out entirely) and a runtime
//! kill switch ([`obs::set_enabled`]).
//!
//! ### Live operations
//!
//! The exporters above are post-hoc; the live ops plane answers "what
//! is it doing *right now*" and "what killed it":
//!
//! * **Streaming stats** — `serve --stats-socket ops.sock` starts a
//!   [`obs::stats::StatsServer`] on a dedicated unix socket. Each poll
//!   merges the router-side [`coordinator::MetricsSnapshot`], every
//!   shard's / worker's [`store::StoreMetrics`] (fetched over the
//!   existing `Metrics` wire frame for worker processes), and the
//!   per-layer cost table into one JSON snapshot — on demand, off the
//!   request path, without pausing traffic. `f2f top ops.sock` renders
//!   it as a refreshing per-shard / per-layer table; `f2f top ops.sock
//!   --once` prints the raw snapshot for scripts and CI.
//! * **Event journal** — [`obs::events`] is a leveled, per-kind
//!   rate-limited, trace-id-stamped JSONL stream (always compiled in;
//!   `serve --events-out events.jsonl` adds a file sink, `--quiet`
//!   silences the stderr mirror for warn/error, and the tail is also
//!   served over the stats socket). Every ad-hoc `eprintln!` in the
//!   serving tier now routes through it. One example line per kind:
//!
//!   ```text
//!   {"ts_ns":1,"seq":0,"level":"warn","kind":"cost_sidecar_malformed","pid":7,"msg":"cost sidecar ignored","fields":{"path":"m.f2f.costs.json","error":"..."}}
//!   {"ts_ns":2,"seq":1,"level":"error","kind":"decode_worker_spawn_failed","pid":7,"msg":"decode worker thread failed to spawn","fields":{"error":"..."}}
//!   {"ts_ns":3,"seq":2,"level":"warn","kind":"decode_inline_degraded","pid":7,"msg":"no decode workers; decoding inline on callers","fields":{"requested":4}}
//!   {"ts_ns":4,"seq":3,"level":"error","kind":"worker_exit","pid":7,"msg":"shard worker exited","fields":{"shard":0,"pid":91,"cause":"signal 9","postmortem":"flight/postmortem-91.json"}}
//!   {"ts_ns":5,"seq":4,"level":"info","kind":"worker_respawn","pid":7,"msg":"shard worker revived","fields":{"shard":0,"pid":92,"restarts":1}}
//!   {"ts_ns":6,"seq":5,"level":"warn","kind":"worker_unresponsive","pid":7,"msg":"health check failed; restarting","fields":{"shard":1,"error":"..."}}
//!   {"ts_ns":7,"seq":6,"level":"warn","kind":"postmortem_failed","pid":7,"msg":"could not write postmortem","fields":{"shard":0,"error":"..."}}
//!   {"ts_ns":8,"seq":7,"level":"warn","kind":"flight_install_failed","pid":91,"msg":"flight recorder disabled","fields":{"dir":"flight","error":"..."}}
//!   {"ts_ns":9,"seq":8,"level":"info","kind":"evict","pid":7,"msg":"evicted decoded layer","fields":{"layer":"fc3","bytes":8192}}
//!   {"ts_ns":10,"seq":9,"level":"warn","kind":"request_shed","pid":7,"trace_id":"0x1a2b","msg":"queue full; request shed","fields":{"inflight":256,"capacity":256}}
//!   {"ts_ns":11,"seq":10,"level":"warn","kind":"anomaly","pid":7,"msg":"decode latency above rolling baseline","fields":{"metric":"decode_ns:fc1","ewma_ns":920000,"baseline_ns":310000,"factor":2.97}}
//!   ```
//!
//! * **Crash flight recorder** — with `--shard-procs N`, each worker
//!   installs a panic hook and checkpoints its span ring plus recent
//!   journal lines to `<workdir>/flight-<pid>.bin` every ~100 ms
//!   ([`obs::flight`]). When the supervisor reaps a dead worker it
//!   attributes the exit (wire `Shutdown` vs signal vs recorded panic
//!   message), converts the sidecar into `postmortem-<pid>.json` (span
//!   and journal summary, `"cause"`) plus a Chrome-trace fragment
//!   `postmortem-<pid>.trace.json`, emits the `worker_exit` event
//!   above, and only then revives the worker.
//! * **Watchdog** — [`obs::watchdog::Watchdog`] samples live per-layer
//!   decode / GEMV EWMAs and the request p99 on an interval, maintains
//!   rolling baselines, and emits an `anomaly` event when a signal
//!   sustains above `factor ×` baseline — the hook ROADMAP item 5's
//!   SLO tier consumes.
//!
//! ## Soundness & analysis
//!
//! The serving paths are *panic-free by policy*, and the policy is
//! machine-checked: `f2f lint` (the [`analysis`] module — a
//! dependency-free token-level scanner over `rust/src/`) forbids
//! `unwrap`/`expect`/panicking macros and unchecked indexing in the
//! serving modules (`ipc`, `container`, `store`, `shard`,
//! `coordinator`, `sparse`, `kernels`, `registry`), requires a
//! `// SAFETY:`
//! comment on every `unsafe`,
//! and flags `.lock().unwrap()` everywhere — lock poisoning must be
//! handled (see [`sync::lock_unpoisoned`]: a panicking worker must
//! degrade one request, not wedge the process). Deliberate exceptions
//! carry an inline justification
//! (`// lint: allow(<rule>) -- <reason>`), which the linter verifies
//! and CI enforces (`cargo run -- lint`). Parser/codec hot spots
//! (wire frames, container records, shard maps) additionally run
//! under Miri in CI, debug builds self-audit cache byte-accounting
//! invariants ([`store::ModelStore`]) and the trace ring ([`obs`]),
//! and a scheduled ThreadSanitizer job sweeps the concurrent decode /
//! serving tests.

pub mod analysis;
pub mod bandwidth;
pub mod bench_util;
pub mod cli;
pub mod container;
pub mod coordinator;
pub mod correction;
pub mod decoder;
pub mod encoder;
pub mod entropy;
pub mod gf2;
#[cfg(unix)]
pub mod ipc;
pub mod kernels;
pub mod models;
pub mod obs;
pub mod pipeline;
pub mod pruning;
pub mod registry;
pub mod report;
pub mod repro;
pub mod rng;
pub mod runtime;
pub mod shard;
pub mod sparse;
pub mod store;
pub mod sync;
pub mod weights;

pub use decoder::{DecoderSpec, SequentialDecoder};
pub use encoder::{EncodeResult, ViterbiEncoder};
pub use gf2::BitVecF2;
pub use kernels::{DecodeMode, ExecLayer, FusedLayer, KernelKind};
pub use pipeline::{CompressionConfig, Compressor};
pub use shard::{rebalance_map, CostProfile, ShardMetrics, ShardRouter};
pub use store::{
    DecodePool, DecodeService, LayerCost, LayerCosts, ModelBackend,
    ModelStore, ReadaheadPolicy, StoreConfig,
};

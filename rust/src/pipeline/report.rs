//! Per-layer compression report — the numbers Tables 2/3 and S.4/S.5
//! are built from.

use crate::encoder::EncodeStats;
use crate::pruning::PruneMethod;

/// Everything measured while compressing one layer.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub name: String,
    pub n_weights: usize,
    pub sparsity: f64,
    pub method: PruneMethod,
    pub n_s: usize,
    /// Aggregate encoding efficiency `E` (%) across planes (Eq. 1).
    pub efficiency: f64,
    /// Per-plane `E` (%), MSB-first (Figure S.13's series).
    pub per_plane_efficiency: Vec<f64>,
    /// Memory reduction (%) incl. correction (Table 2's metric).
    pub memory_reduction: f64,
    /// Coefficient of variation of `n_u` (Table 3's statistic).
    pub coeff_var: f64,
    /// Raw bit accounting.
    pub stats: EncodeStats,
}

impl LayerReport {
    /// Merge several layer reports into a model-level aggregate
    /// (efficiency/memory recomputed from summed bit counts, not
    /// averaged percentages).
    pub fn aggregate(name: &str, reports: &[LayerReport]) -> LayerReport {
        assert!(!reports.is_empty());
        let mut stats = EncodeStats::default();
        let mut n_weights = 0usize;
        let mut cv_weighted = 0.0f64;
        let mut original_bits = 0usize;
        let mut compressed_bits = 0usize;
        for r in reports {
            stats.merge(&r.stats);
            n_weights += r.n_weights;
            cv_weighted += r.coeff_var * r.n_weights as f64;
            let planes = r.per_plane_efficiency.len().max(1);
            original_bits += r.n_weights * planes;
            compressed_bits += (r.n_weights as f64
                * planes as f64
                * (1.0 - r.memory_reduction / 100.0))
                .round() as usize;
        }
        LayerReport {
            name: name.to_string(),
            n_weights,
            sparsity: reports[0].sparsity,
            method: reports[0].method,
            n_s: reports[0].n_s,
            efficiency: stats.efficiency(),
            per_plane_efficiency: Vec::new(),
            memory_reduction: (1.0
                - compressed_bits as f64 / original_bits as f64)
                * 100.0,
            coeff_var: cv_weighted / n_weights as f64,
            stats,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rep(e_matched: usize, e_unpruned: usize, mr: f64, n: usize) -> LayerReport {
        LayerReport {
            name: "x".into(),
            n_weights: n,
            sparsity: 0.9,
            method: PruneMethod::Random,
            n_s: 2,
            efficiency: e_matched as f64 / e_unpruned as f64 * 100.0,
            per_plane_efficiency: vec![0.0; 8],
            memory_reduction: mr,
            coeff_var: 0.3,
            stats: EncodeStats {
                total_bits: n * 8,
                unpruned_bits: e_unpruned,
                matched_bits: e_matched,
                error_bits: e_unpruned - e_matched,
                encoded_bits: n,
            },
        }
    }

    #[test]
    fn aggregate_weights_by_bits_not_percent() {
        let a = rep(90, 100, 80.0, 1000);
        let b = rep(450, 500, 88.0, 3000);
        let agg = LayerReport::aggregate("model", &[a, b]);
        // E = (90+450)/(100+500) = 90%
        assert!((agg.efficiency - 90.0).abs() < 1e-9);
        // memory reduction: (1000·8·0.2 + 3000·8·0.12) compressed
        let expect = (1.0
            - (1000.0 * 8.0 * 0.2 + 3000.0 * 8.0 * 0.12)
                / (4000.0 * 8.0))
            * 100.0;
        assert!((agg.memory_reduction - expect).abs() < 0.1);
        assert_eq!(agg.n_weights, 4000);
    }
}

//! End-to-end compression pipeline: prune → bit-planes → (invert) →
//! sequential encode → correction → container, plus lossless
//! decompression and verification.
//!
//! This is the orchestration layer every experiment and the serving
//! examples go through. One [`Compressor`] handles a layer or a whole
//! model; the decoder matrix is selected per layer (the paper picks the
//! best of several random `M⊕` candidates, §5.1 Setup).

mod compress;
mod report;

pub use compress::{CompressionConfig, Compressor};
pub use report::LayerReport;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::Dtype;
    use crate::models::{LayerSpec, SyntheticLayer, WeightGen};
    use crate::pruning::PruneMethod;
    use crate::sparse::DecodedLayer;

    fn small_layer(seed: u64) -> SyntheticLayer {
        let spec = LayerSpec { name: "t/0".into(), rows: 8, cols: 48 };
        SyntheticLayer::generate(&spec, WeightGen::default(), seed)
    }

    #[test]
    fn f32_roundtrip_is_lossless_on_unpruned_weights() {
        let cfg = CompressionConfig {
            sparsity: 0.9,
            n_s: 1,
            ..CompressionConfig::default()
        };
        let c = Compressor::new(cfg);
        let layer = small_layer(1);
        let (compressed, report) =
            c.compress_f32(&layer.spec.name, layer.spec.rows, layer.spec.cols, &layer.weights);
        assert!(report.efficiency > 50.0);
        let decoded = DecodedLayer::from_compressed(&compressed);
        let mask = &compressed.mask;
        for i in 0..layer.weights.len() {
            if mask.get(i) {
                assert_eq!(
                    decoded.weights[i].to_bits(),
                    layer.weights[i].to_bits(),
                    "weight {i} corrupted"
                );
            } else {
                assert_eq!(decoded.weights[i], 0.0);
            }
        }
    }

    #[test]
    fn i8_roundtrip_is_lossless() {
        let cfg = CompressionConfig {
            sparsity: 0.7,
            n_s: 2,
            method: PruneMethod::Magnitude,
            beam: Some(8), // keep the debug-mode DP quick
            ..CompressionConfig::default()
        };
        let c = Compressor::new(cfg);
        let layer = small_layer(2);
        let (q, scale) = crate::models::quantize_i8(&layer.weights);
        let (compressed, _) = c.compress_i8(
            &layer.spec.name,
            layer.spec.rows,
            layer.spec.cols,
            &q,
            scale,
        );
        assert_eq!(compressed.dtype, Dtype::I8);
        let decoded = DecodedLayer::from_compressed(&compressed);
        for i in 0..q.len() {
            if compressed.mask.get(i) {
                let expect = q[i] as f32 * scale;
                assert!((decoded.weights[i] - expect).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn memory_reduction_approaches_sparsity_at_high_ns() {
        let cfg = CompressionConfig {
            sparsity: 0.9,
            n_s: 2,
            method: PruneMethod::Random,
            beam: Some(8), // keep the debug-mode DP quick
            ..CompressionConfig::default()
        };
        let c = Compressor::new(cfg);
        let spec = LayerSpec { name: "big".into(), rows: 16, cols: 512 };
        let layer = SyntheticLayer::generate(&spec, WeightGen::default(), 3);
        let (q, scale) = crate::models::quantize_i8(&layer.weights);
        let (compressed, report) =
            c.compress_i8("big", 16, 512, &q, scale);
        assert!(
            report.efficiency > 95.0,
            "E = {:.1}%",
            report.efficiency
        );
        let mr = compressed.memory_reduction();
        assert!(mr > 80.0, "memory reduction {mr:.1}% should approach 90%");
    }

    #[test]
    fn container_serialization_roundtrip_through_pipeline() {
        let cfg = CompressionConfig {
            sparsity: 0.8,
            n_s: 1,
            ..CompressionConfig::default()
        };
        let c = Compressor::new(cfg);
        let layer = small_layer(4);
        let (q, scale) = crate::models::quantize_i8(&layer.weights);
        let (compressed, _) =
            c.compress_i8("l0", layer.spec.rows, layer.spec.cols, &q, scale);
        let container =
            crate::container::Container { layers: vec![compressed] };
        let bytes = crate::container::write_container(&container);
        let back = crate::container::read_container(&bytes).unwrap();
        let a = DecodedLayer::from_compressed(&container.layers[0]);
        let b = DecodedLayer::from_compressed(&back.layers[0]);
        assert_eq!(a.weights, b.weights);
    }

    #[test]
    fn compress_model_to_bytes_emits_indexed_v2() {
        let cfg = CompressionConfig {
            sparsity: 0.8,
            n_s: 1,
            beam: Some(8),
            ..CompressionConfig::default()
        };
        let c = Compressor::new(cfg);
        let layers = vec![small_layer(5), small_layer(6)];
        let (bytes, reports) =
            c.compress_model_to_bytes(&layers, Dtype::I8);
        assert_eq!(reports.len(), 2);
        assert_eq!(&bytes[..4], b"F2F2", "default layout is indexed v2");
        let index =
            crate::container::ContainerIndex::parse(&bytes).unwrap();
        assert_eq!(index.len(), 2);
        let back = crate::container::read_container(&bytes).unwrap();
        assert_eq!(back.layers.len(), 2);
    }
}

//! The compressor: configuration + per-layer compression.

use super::LayerReport;
use crate::container::{CompressedLayer, CompressedPlane, Container, Dtype};
use crate::correction::{CorrectionStream, DEFAULT_P};
use crate::decoder::{DecoderSpec, SequentialDecoder};
use crate::encoder::{Encoder, SlicedPlane, ViterbiEncoder};
use crate::gf2::BitVecF2;
use crate::models::SyntheticLayer;
use crate::pruning::{MaskStats, PruneMethod, Pruner};
use crate::weights::{maybe_invert, BitPlanes};

/// All knobs of the compression pipeline.
#[derive(Debug, Clone, Copy)]
pub struct CompressionConfig {
    /// Decoder input width `N_in` (paper: 8 — byte-fed decoders).
    pub n_in: usize,
    /// Shift registers `N_s`.
    pub n_s: usize,
    /// Pruning rate `S`; also sets `N_out = ⌊N_in/(1−S)⌋`.
    pub sparsity: f64,
    /// Mask family.
    pub method: PruneMethod,
    /// Apply the inverting technique (§5.1). The paper enables it for
    /// `N_s ∈ {0,1}` on FP32.
    pub invert: bool,
    /// Correction vector length `p` (Appendix F; paper uses 512).
    pub p: usize,
    /// Base seed (masks, M⊕ candidates, weights all derive from it).
    pub seed: u64,
    /// Number of random `M⊕` candidates to try per layer; the best (by
    /// error count on a sample) is kept. §5.1: "we try numerous random
    /// M⊕ matrices and choose a particular M⊕ of the highest E".
    pub m_candidates: usize,
    /// Optional Viterbi beam width (None = exact DP).
    pub beam: Option<u32>,
}

impl Default for CompressionConfig {
    fn default() -> Self {
        CompressionConfig {
            n_in: 8,
            n_s: 2,
            sparsity: 0.9,
            method: PruneMethod::Random,
            invert: false,
            p: DEFAULT_P,
            seed: 0xF2F0,
            m_candidates: 1,
            beam: None,
        }
    }
}

impl CompressionConfig {
    /// Decoder geometry implied by this config.
    pub fn decoder_spec(&self) -> DecoderSpec {
        DecoderSpec::for_sparsity(self.n_in, self.sparsity, self.n_s)
    }
}

/// Layer/model compressor.
#[derive(Debug, Clone)]
pub struct Compressor {
    config: CompressionConfig,
}

impl Compressor {
    /// Build from a config.
    pub fn new(config: CompressionConfig) -> Self {
        Compressor { config }
    }

    /// Access the config.
    pub fn config(&self) -> &CompressionConfig {
        &self.config
    }

    /// Compress FP32 weights (32 planes).
    pub fn compress_f32(
        &self,
        name: &str,
        rows: usize,
        cols: usize,
        weights: &[f32],
    ) -> (CompressedLayer, LayerReport) {
        assert_eq!(weights.len(), rows * cols);
        let planes = BitPlanes::from_f32(weights);
        self.compress_planes(name, rows, cols, Dtype::F32, 1.0, planes, weights)
    }

    /// Compress signed-INT8 weights (8 planes).
    pub fn compress_i8(
        &self,
        name: &str,
        rows: usize,
        cols: usize,
        q: &[i8],
        scale: f32,
    ) -> (CompressedLayer, LayerReport) {
        assert_eq!(q.len(), rows * cols);
        let planes = BitPlanes::from_i8(q);
        let weights: Vec<f32> =
            q.iter().map(|&v| v as f32 * scale).collect();
        self.compress_planes(name, rows, cols, Dtype::I8, scale, planes, &weights)
    }

    /// Compress a synthetic layer in the given dtype.
    pub fn compress_layer(
        &self,
        layer: &SyntheticLayer,
        dtype: Dtype,
    ) -> (CompressedLayer, LayerReport) {
        match dtype {
            Dtype::F32 => self.compress_f32(
                &layer.spec.name,
                layer.spec.rows,
                layer.spec.cols,
                &layer.weights,
            ),
            Dtype::I8 => {
                let (q, scale) = crate::models::quantize_i8(&layer.weights);
                self.compress_i8(
                    &layer.spec.name,
                    layer.spec.rows,
                    layer.spec.cols,
                    &q,
                    scale,
                )
            }
        }
    }

    /// Compress a whole model into a container + per-layer reports.
    pub fn compress_model(
        &self,
        layers: &[SyntheticLayer],
        dtype: Dtype,
    ) -> (Container, Vec<LayerReport>) {
        let mut container = Container::default();
        let mut reports = Vec::with_capacity(layers.len());
        for layer in layers {
            let (cl, rep) = self.compress_layer(layer, dtype);
            container.layers.push(cl);
            reports.push(rep);
        }
        (container, reports)
    }

    /// Compress a whole model and serialize it in the indexed v2
    /// container layout — the default on-disk format, ready for
    /// [`crate::store::ModelStore::open_bytes`].
    pub fn compress_model_to_bytes(
        &self,
        layers: &[SyntheticLayer],
        dtype: Dtype,
    ) -> (Vec<u8>, Vec<LayerReport>) {
        let (container, reports) = self.compress_model(layers, dtype);
        (crate::container::write_container_v2(&container), reports)
    }

    fn compress_planes(
        &self,
        name: &str,
        rows: usize,
        cols: usize,
        dtype: Dtype,
        scale: f32,
        planes: BitPlanes,
        weights_f32: &[f32],
    ) -> (CompressedLayer, LayerReport) {
        let cfg = &self.config;
        let spec = cfg.decoder_spec();
        let n = rows * cols;

        // Mask from the weights (magnitude-family pruners score |w|).
        let pruner = Pruner::new(
            cfg.method,
            cfg.sparsity,
            cfg.seed ^ hash_name(name),
        );
        let mask = pruner.mask(weights_f32, cols);
        let mask_stats = MaskStats::from_mask(&mask, spec.n_out);

        // M⊕ selection: score candidates on the first plane sample.
        let m_seed = self.pick_matrix_seed(name, &planes, &mask, spec);
        let decoder = SequentialDecoder::random(spec, m_seed);
        let encoder = match cfg.beam {
            None => ViterbiEncoder::new(decoder.clone()),
            Some(b) => ViterbiEncoder::with_beam(decoder.clone(), b),
        };

        let mut out_planes = Vec::with_capacity(planes.n_planes());
        let mut agg = crate::encoder::EncodeStats::default();
        let mut per_plane_e = Vec::with_capacity(planes.n_planes());
        for k in 0..planes.n_planes() {
            let (bits, inverted) = if cfg.invert {
                maybe_invert(planes.plane(k), &mask)
            } else {
                (planes.plane(k).clone(), false)
            };
            let sliced = SlicedPlane::new(&bits, &mask, spec.n_out);
            let res = encoder.encode(&sliced);
            agg.merge(&res.stats);
            per_plane_e.push(res.efficiency());
            out_planes.push(CompressedPlane {
                inverted,
                encoded: res.encoded,
                correction: CorrectionStream::build(
                    &res.mismatches,
                    n,
                    cfg.p,
                ),
            });
        }

        let layer = CompressedLayer {
            name: name.to_string(),
            rows,
            cols,
            dtype,
            scale,
            spec,
            m_seed,
            mask,
            planes: out_planes,
        };
        let report = LayerReport {
            name: name.to_string(),
            n_weights: n,
            sparsity: cfg.sparsity,
            method: cfg.method,
            n_s: cfg.n_s,
            efficiency: agg.efficiency(),
            per_plane_efficiency: per_plane_e,
            memory_reduction: layer.memory_reduction(),
            coeff_var: mask_stats.coeff_var,
            stats: agg,
        };
        (layer, report)
    }

    /// Paper §5.1: sample a few random `M⊕` and keep the best. We score
    /// on the sign plane truncated to ≤ 16 blocks-worth of bits with a
    /// cheap `N_s`-aware encode.
    fn pick_matrix_seed(
        &self,
        name: &str,
        planes: &BitPlanes,
        mask: &BitVecF2,
        spec: DecoderSpec,
    ) -> u64 {
        let base = self.config.seed ^ hash_name(name) ^ 0x4D58;
        if self.config.m_candidates <= 1 {
            return base;
        }
        let sample_bits = (spec.n_out * 64).min(planes.plane(0).len());
        let mut sample = BitVecF2::zeros(sample_bits);
        let mut smask = BitVecF2::zeros(sample_bits);
        for i in 0..sample_bits {
            sample.set(i, planes.plane(0).get(i));
            smask.set(i, mask.get(i));
        }
        let plane = SlicedPlane::new(&sample, &smask, spec.n_out);
        (0..self.config.m_candidates as u64)
            .map(|k| {
                let seed = base.wrapping_add(k.wrapping_mul(0x9E37));
                let dec = SequentialDecoder::random(spec, seed);
                let res = ViterbiEncoder::new(dec).encode(&plane);
                (res.stats.error_bits, seed)
            })
            .min()
            .map(|(_, seed)| seed)
            .unwrap_or(base)
    }
}

/// Stable name hash for per-layer seed derivation (FNV-1a).
fn hash_name(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_matches_paper_flagship() {
        let cfg = CompressionConfig::default();
        assert_eq!(cfg.n_in, 8);
        let spec = cfg.decoder_spec();
        assert_eq!(spec.n_out, 80); // S = 0.9
        assert_eq!(cfg.p, 512);
    }

    #[test]
    fn hash_name_distinguishes_layers() {
        assert_ne!(hash_name("a"), hash_name("b"));
        assert_eq!(hash_name("dec3/ffn2"), hash_name("dec3/ffn2"));
    }

    #[test]
    fn m_candidates_never_picks_worse_than_first() {
        // With 4 candidates the chosen seed's sample error must be ≤ the
        // base seed's sample error by construction (min over a set that
        // includes it... first candidate IS base). Just smoke-test that
        // compression still round-trips.
        let cfg = CompressionConfig {
            m_candidates: 4,
            sparsity: 0.8,
            n_s: 1,
            ..Default::default()
        };
        let c = Compressor::new(cfg);
        let spec = crate::models::LayerSpec {
            name: "m".into(),
            rows: 16,
            cols: 64,
        };
        let layer = SyntheticLayer::generate(
            &spec,
            crate::models::WeightGen::default(),
            9,
        );
        let (q, scale) = crate::models::quantize_i8(&layer.weights);
        let (cl, rep) = c.compress_i8("m", 16, 64, &q, scale);
        assert!(rep.efficiency > 80.0);
        let dec = crate::sparse::DecodedLayer::from_compressed(&cl);
        for i in 0..q.len() {
            if cl.mask.get(i) {
                assert!(
                    (dec.weights[i] - q[i] as f32 * scale).abs() < 1e-6
                );
            }
        }
    }
}

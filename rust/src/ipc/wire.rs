//! The IPC wire protocol: length-prefixed frames over a byte stream.
//!
//! Hand-rolled against `std::io` only (no serde, no tokio — the build
//! stays fully offline) and deliberately tiny: every message is one
//! frame, every frame is a fixed 11-byte header followed by a
//! length-prefixed payload:
//!
//! ```text
//! "F2FI" | u16 version=1 | u8 kind | u32 payload_len | payload
//! ```
//!
//! Request kinds are [`Request`] (`Fetch`/`Prefetch`/`Metrics`/
//! `CostProfile`/`TraceDump`/`Stats`/`Events`/`Shutdown`), response
//! kinds [`Response`]. Every
//! decoder in this module is bounds-checked and size-capped: corrupt
//! bytes — truncation, a lying length, a hostile name, an unknown kind
//! — come back as [`WireError::Corrupt`] errors, never a panic and
//! never an unbounded allocation, on *both* sides of the socket. A
//! worker that receives garbage answers with an error frame and closes
//! the connection; a client that reads garbage drops the connection
//! and reports a transport failure the supervisor can act on.
//!
//! Payload shapes (all little-endian):
//!
//! * `Fetch` / `Prefetch` — `u32 name_len | name` (utf-8), then an
//!   *optional* trailing `u64 trace_id`: current peers always append
//!   it (so worker-side spans stitch under the originating request's
//!   trace), v1 peers don't, and decoders accept both — absent means
//!   [`crate::obs::TRACE_NONE`]; any other trailing length is
//!   corruption.
//! * `Metrics` / `CostProfile` / `TraceDump` / `Shutdown` / `Stats`
//!   — empty.
//! * `Events` — `u32 max` (newest journal lines wanted).
//! * `Layer` — `u64 rows | u64 cols | rows·cols × f32` (the decoded
//!   weights, the same dense row-major layout
//!   [`crate::sparse::DecodedLayer`] holds).
//! * `FusedLayer` — `u64 rows | u64 cols | u8 dtype (0=f32, 1=i8) |
//!   f32 scale`, then `n_w · rows · ⌈cols/64⌉` plane words and
//!   `rows · ⌈cols/64⌉` mask words (all `u64`): the bit-plane-resident
//!   form [`crate::kernels::FusedLayer`] executes directly. Word
//!   counts are *derived* from the geometry, never carried, so a frame
//!   whose payload disagrees with its own geometry is corruption.
//! * `Ack` — `u8 accepted`.
//! * `Metrics` reply — `u32 field_count | field_count × u64`:
//!   version-tolerant by construction. The current field order is the
//!   12 [`StoreMetrics`] counters in declaration order, then the
//!   decode histogram and the GEMV histogram, each flattened to
//!   [`crate::obs::HDR_WIRE_FIELDS`] words
//!   ([`crate::obs::HdrLite::to_wire`]). A decoder reading a *longer*
//!   payload (newer peer) ignores the extra fields; a *shorter* one
//!   (older peer) zero-fills the missing tail — so mixed-version
//!   router/worker pairs keep exchanging metrics instead of erroring.
//! * `CostProfile` reply — `u32 json_len | json` (the exact
//!   [`crate::shard::CostProfile::to_json`] form, so the cost table
//!   crosses the process boundary through the same validated parser
//!   `f2f rebalance` uses).
//! * `Trace` reply — `u32 pid | u32 n_events`, then per event
//!   `u64 trace_id | u64 t_start_ns | u64 dur_ns | u8 kind |
//!   u32 label_len | label`. Events with an unknown kind (a newer
//!   peer's taxonomy) are dropped individually, never the whole frame.
//! * `Stats` reply — `u32 json_len | json`: the self-describing live
//!   snapshot [`crate::obs::stats`] builds (what `f2f top` renders).
//! * `Events` reply — `u32 jsonl_len | jsonl`: newline-separated
//!   journal lines, oldest first ([`crate::obs::events`]).
//! * `Err` — `u32 msg_len | msg`.

use crate::container::Dtype;
use crate::kernels::{ExecLayer, FusedLayer};
use crate::obs::{self, HdrLite, SpanEvent, SpanKind};
use crate::sparse::DecodedLayer;
use crate::store::StoreMetrics;
use anyhow::{bail, Result};
use std::io::{Read, Write};

/// Frame magic: `F2FI` (fixed-to-fixed IPC).
pub const MAGIC: &[u8; 4] = b"F2FI";

/// Protocol version carried in every frame header.
pub const VERSION: u16 = 1;

/// Hard cap on one frame's payload. Large enough for any decoded layer
/// this crate serves, small enough that a corrupt length can never ask
/// for an absurd allocation.
pub const MAX_PAYLOAD: usize = 256 << 20;

/// Hard cap on a layer-name length inside a frame.
pub const MAX_NAME: usize = 4096;

/// The most weights a layer frame can carry under [`MAX_PAYLOAD`]
/// (16 header bytes for the geometry, 4 bytes per f32). A worker
/// checks this *before* serializing, so an oversized layer becomes a
/// clear error frame at the source rather than a mid-stream
/// corrupt-frame rejection on the other side.
pub const MAX_WIRE_WEIGHTS: usize = (MAX_PAYLOAD - 16) / 4;

/// Fixed prefix of a fused-layer payload: `u64 rows | u64 cols |
/// u8 dtype | f32 scale`.
const FUSED_HEADER_BYTES: usize = 8 + 8 + 1 + 4;

/// The most `u64` words (planes + mask together) a fused-layer frame
/// can carry under [`MAX_PAYLOAD`] — the worker-side pre-check
/// mirroring [`MAX_WIRE_WEIGHTS`].
pub const MAX_WIRE_FUSED_WORDS: usize =
    (MAX_PAYLOAD - FUSED_HEADER_BYTES) / 8;

const HEADER_LEN: usize = 4 + 2 + 1 + 4;

// Request frame kinds.
const K_FETCH: u8 = 0x01;
const K_PREFETCH: u8 = 0x02;
const K_METRICS: u8 = 0x03;
const K_COST_PROFILE: u8 = 0x04;
const K_SHUTDOWN: u8 = 0x05;
const K_TRACE: u8 = 0x06;
const K_STATS: u8 = 0x07;
const K_EVENTS: u8 = 0x08;

// Response frame kinds.
const K_LAYER: u8 = 0x81;
const K_ACK: u8 = 0x82;
const K_METRICS_REPLY: u8 = 0x83;
const K_COSTS_REPLY: u8 = 0x84;
const K_BYE: u8 = 0x85;
const K_TRACE_REPLY: u8 = 0x86;
const K_STATS_REPLY: u8 = 0x87;
const K_EVENTS_REPLY: u8 = 0x88;
const K_FUSED_LAYER: u8 = 0x89;
const K_ERR: u8 = 0xFF;

/// Smallest possible wire footprint of one trace event (empty label):
/// the divisor that pre-validates a `Trace` reply's claimed event count
/// against the bytes actually present.
const TRACE_EVENT_MIN_BYTES: usize = 8 + 8 + 8 + 1 + 4;

/// Client → worker messages.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Fetch one decoded layer (blocks worker-side until decoded).
    /// `trace` is the originating request's trace id
    /// ([`crate::obs::TRACE_NONE`] outside any), which the worker pins
    /// while handling so its decode/cache spans stitch cross-process.
    /// `model` scopes the layer to one tenant of a model-zoo worker
    /// (`""` = unscoped, the single-model wire form): the worker joins
    /// `{model}::{layer}` before its store lookup. The model id rides
    /// as an optional trailing byte range, so single-model peers emit
    /// byte-identical frames to before.
    Fetch { layer: String, model: String, trace: u64 },
    /// Warm one layer asynchronously ([`accepted`](Response::Ack)
    /// mirrors [`crate::store::ModelStore::prefetch_async`]); `trace`
    /// and `model` as in [`Request::Fetch`].
    Prefetch { layer: String, model: String, trace: u64 },
    /// Snapshot the worker store's [`StoreMetrics`].
    Metrics,
    /// Snapshot the worker store's cost table as `CostProfile` JSON.
    CostProfile,
    /// Snapshot the worker's span recorder ([`Response::Trace`]).
    TraceDump,
    /// Snapshot the peer's live-stats JSON ([`Response::Stats`]) —
    /// what a [`crate::obs::stats::StatsServer`] and workers answer.
    Stats,
    /// The newest `max` event-journal lines ([`Response::Events`]).
    Events { max: u32 },
    /// Stop serving: the worker replies [`Response::Bye`] and exits.
    Shutdown,
}

/// Worker → client messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// A decoded layer (dense row-major weights).
    Layer { rows: usize, cols: usize, weights: Vec<f32> },
    /// A decoded layer in its fused (bit-plane-resident) form: the
    /// representation a fused-mode worker caches crosses the socket
    /// as-is — ~9/32 of the dense frame for I8 layers — and executes
    /// on the client without ever materializing dense f32.
    FusedLayer {
        rows: usize,
        cols: usize,
        dtype: Dtype,
        scale: f32,
        planes: Vec<u64>,
        mask: Vec<u64>,
    },
    /// Prefetch acknowledged; `accepted` is false when the readahead
    /// was declined (unknown layer, or budget admission).
    Ack { accepted: bool },
    /// Metrics snapshot.
    Metrics(StoreMetrics),
    /// Cost-table snapshot as `CostProfile` JSON.
    CostProfile { json: String },
    /// Span-recorder snapshot: the worker's pid (its Chrome-trace
    /// lane) plus every retained event.
    Trace { pid: u32, events: Vec<SpanEvent> },
    /// Live-stats snapshot as self-describing JSON
    /// ([`crate::obs::stats`]).
    Stats { json: String },
    /// Event-journal tail as JSONL (one journal line per text line,
    /// oldest first; empty when the journal is).
    Events { jsonl: String },
    /// Shutdown acknowledged; the worker is exiting.
    Bye,
    /// The request failed worker-side (unknown layer, decode error,
    /// unparseable frame). The worker stays alive.
    Err { message: String },
}

/// How a frame read fails. The worker loop branches on this: a timeout
/// polls the shutdown flag, an EOF ends the connection quietly, and a
/// corrupt frame gets an error reply before the connection closes.
#[derive(Debug)]
pub enum WireError {
    /// The stream ended cleanly before a frame started.
    Eof,
    /// The read timed out between frames (poll and retry).
    TimedOut,
    /// The bytes on the stream do not form a valid frame.
    Corrupt(String),
    /// Any other I/O failure.
    Io(std::io::Error),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Eof => write!(f, "connection closed"),
            WireError::TimedOut => write!(f, "read timed out"),
            WireError::Corrupt(msg) => write!(f, "corrupt frame: {msg}"),
            WireError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Write one frame (header + payload) and flush. An over-cap payload
/// is an error in release builds too — never a frame the receiver
/// would misdiagnose as stream corruption. Header and payload go out
/// as one buffered write: ordinary frames are small, and a single
/// syscall leaves no scheduling window between header and payload for
/// the peer's mid-frame read timeout to misread as corruption.
pub fn write_frame(
    w: &mut impl Write,
    kind: u8,
    payload: &[u8],
) -> std::io::Result<()> {
    check_payload_len(payload.len())?;
    let mut frame = Vec::with_capacity(HEADER_LEN + payload.len());
    push_header(&mut frame, kind, payload.len());
    frame.extend_from_slice(payload);
    w.write_all(&frame)?;
    w.flush()
}

fn check_payload_len(payload_len: usize) -> std::io::Result<()> {
    if payload_len > MAX_PAYLOAD {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!(
                "frame payload {payload_len} exceeds the \
                 {MAX_PAYLOAD}-byte cap"
            ),
        ));
    }
    Ok(())
}

fn push_header(frame: &mut Vec<u8>, kind: u8, payload_len: usize) {
    frame.extend_from_slice(MAGIC);
    frame.extend_from_slice(&VERSION.to_le_bytes());
    frame.push(kind);
    frame.extend_from_slice(&(payload_len as u32).to_le_bytes());
}

/// Send a layer response streamed straight from borrowed weights —
/// the worker's hot fetch path. One serialization copy into the frame
/// buffer; no intermediate owned `Vec<f32>`. Callers must pre-check
/// [`MAX_WIRE_WEIGHTS`] (an oversized layer should be an error
/// *frame*, not an I/O error here).
pub fn send_layer(
    w: &mut impl Write,
    rows: usize,
    cols: usize,
    weights: &[f32],
) -> std::io::Result<()> {
    let payload_len = 16 + weights.len() * 4;
    check_payload_len(payload_len)?;
    let mut frame = Vec::with_capacity(HEADER_LEN + payload_len);
    push_header(&mut frame, K_LAYER, payload_len);
    frame.extend_from_slice(&(rows as u64).to_le_bytes());
    frame.extend_from_slice(&(cols as u64).to_le_bytes());
    for v in weights {
        frame.extend_from_slice(&v.to_le_bytes());
    }
    w.write_all(&frame)?;
    w.flush()
}

/// Send a fused-layer response streamed straight from the layer's
/// borrowed plane/mask words — the fused counterpart of
/// [`send_layer`], one serialization copy and no intermediate owned
/// buffers. Callers must pre-check [`MAX_WIRE_FUSED_WORDS`] (an
/// oversized layer should be an error *frame*, not an I/O error
/// here).
pub fn send_fused_layer(
    w: &mut impl Write,
    layer: &FusedLayer,
) -> std::io::Result<()> {
    let planes = layer.plane_words();
    let mask = layer.mask_words();
    let payload_len =
        FUSED_HEADER_BYTES + (planes.len() + mask.len()) * 8;
    check_payload_len(payload_len)?;
    let mut frame = Vec::with_capacity(HEADER_LEN + payload_len);
    push_header(&mut frame, K_FUSED_LAYER, payload_len);
    push_fused_header(
        &mut frame,
        layer.rows(),
        layer.cols(),
        layer.dtype(),
        layer.scale(),
    );
    for v in planes {
        frame.extend_from_slice(&v.to_le_bytes());
    }
    for v in mask {
        frame.extend_from_slice(&v.to_le_bytes());
    }
    w.write_all(&frame)?;
    w.flush()
}

fn push_fused_header(
    b: &mut Vec<u8>,
    rows: usize,
    cols: usize,
    dtype: Dtype,
    scale: f32,
) {
    b.extend_from_slice(&(rows as u64).to_le_bytes());
    b.extend_from_slice(&(cols as u64).to_le_bytes());
    b.push(match dtype {
        Dtype::F32 => 0,
        Dtype::I8 => 1,
    });
    b.extend_from_slice(&scale.to_le_bytes());
}

/// Read one frame: `(kind, payload)`. Bounds-checked and size-capped;
/// a lying payload length never allocates more than the stream
/// actually delivers.
pub fn read_frame(
    r: &mut impl Read,
) -> std::result::Result<(u8, Vec<u8>), WireError> {
    // First byte read separately so a clean close (or an idle-poll
    // timeout) between frames is distinguishable from truncation
    // inside one.
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Err(WireError::Eof),
            Ok(_) => break,
            Err(e) if is_timeout(&e) => return Err(WireError::TimedOut),
            Err(e)
                if e.kind() == std::io::ErrorKind::Interrupted =>
            {
                continue
            }
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    // The header is a fixed 11-byte stack array; every offset below is
    // a compile-time constant inside it, hence the per-line lint
    // exceptions rather than a bounds-checked reader.
    let mut header = [0u8; HEADER_LEN];
    header[0] = first[0]; // lint: allow(no-index) -- constant offsets in a fixed header array
    read_exact_frame(r, &mut header[1..])?; // lint: allow(no-index) -- constant offsets in a fixed header array
    if &header[..4] != MAGIC { // lint: allow(no-index) -- constant offsets in a fixed header array
        return Err(WireError::Corrupt("bad magic".into()));
    }
    let version = u16::from_le_bytes([header[4], header[5]]); // lint: allow(no-index) -- constant offsets in a fixed header array
    if version != VERSION {
        return Err(WireError::Corrupt(format!(
            "unsupported wire version {version}"
        )));
    }
    let kind = header[6]; // lint: allow(no-index) -- constant offsets in a fixed header array
    let len = u32::from_le_bytes([
        // lint: allow(no-index) -- constant offsets in a fixed header array
        header[7], header[8], header[9], header[10],
    ]) as usize;
    if len > MAX_PAYLOAD {
        return Err(WireError::Corrupt(format!(
            "payload length {len} exceeds the {MAX_PAYLOAD}-byte cap"
        )));
    }
    // `take` bounds the allocation by what the stream really provides,
    // so a corrupt length on a short stream cannot balloon memory.
    let mut payload = Vec::new();
    match r.by_ref().take(len as u64).read_to_end(&mut payload) {
        Ok(_) => {}
        Err(e) if is_timeout(&e) => {
            return Err(WireError::Corrupt(
                "timed out mid-frame".into(),
            ))
        }
        Err(e) => return Err(WireError::Io(e)),
    }
    if payload.len() != len {
        return Err(WireError::Corrupt(format!(
            "truncated payload: {} of {len} bytes",
            payload.len()
        )));
    }
    Ok((kind, payload))
}

/// `read_exact` for the rest of a header: truncation and timeouts
/// mid-frame are corruption (the stream is desynchronized).
fn read_exact_frame(
    r: &mut impl Read,
    buf: &mut [u8],
) -> std::result::Result<(), WireError> {
    match r.read_exact(buf) {
        Ok(()) => Ok(()),
        Err(e) if is_timeout(&e) => {
            Err(WireError::Corrupt("timed out mid-frame".into()))
        }
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            Err(WireError::Corrupt("truncated frame header".into()))
        }
        Err(e) => Err(WireError::Io(e)),
    }
}

/// Serialize + send one request.
pub fn send_request(
    w: &mut impl Write,
    req: &Request,
) -> std::io::Result<()> {
    let (kind, payload) = req.encode();
    write_frame(w, kind, &payload)
}

/// Read + parse one request frame.
pub fn read_request(
    r: &mut impl Read,
) -> std::result::Result<Request, WireError> {
    let (kind, payload) = read_frame(r)?;
    Request::decode(kind, &payload)
        .map_err(|e| WireError::Corrupt(format!("{e:#}")))
}

/// Serialize + send one response.
pub fn send_response(
    w: &mut impl Write,
    resp: &Response,
) -> std::io::Result<()> {
    let (kind, payload) = resp.encode();
    write_frame(w, kind, &payload)
}

/// Read + parse one response frame.
pub fn read_response(
    r: &mut impl Read,
) -> std::result::Result<Response, WireError> {
    let (kind, payload) = read_frame(r)?;
    Response::decode(kind, &payload)
        .map_err(|e| WireError::Corrupt(format!("{e:#}")))
}

impl Request {
    fn encode(&self) -> (u8, Vec<u8>) {
        match self {
            Request::Fetch { layer, model, trace } => {
                (K_FETCH, encode_name_trace_model(layer, model, *trace))
            }
            Request::Prefetch { layer, model, trace } => {
                (
                    K_PREFETCH,
                    encode_name_trace_model(layer, model, *trace),
                )
            }
            Request::Metrics => (K_METRICS, Vec::new()),
            Request::CostProfile => (K_COST_PROFILE, Vec::new()),
            Request::TraceDump => (K_TRACE, Vec::new()),
            Request::Stats => (K_STATS, Vec::new()),
            Request::Events { max } => {
                (K_EVENTS, max.to_le_bytes().to_vec())
            }
            Request::Shutdown => (K_SHUTDOWN, Vec::new()),
        }
    }

    /// Parse a request payload. Errors (never panics) on truncation,
    /// trailing bytes, oversized names, non-utf8 names, and unknown
    /// kinds. `Fetch`/`Prefetch` accept the v1 form without the
    /// trailing trace id (absent means [`obs::TRACE_NONE`]) and the
    /// single-model form without the trailing model id (absent means
    /// `""`, unscoped).
    pub fn decode(kind: u8, payload: &[u8]) -> Result<Request> {
        let mut p = Cursor::new(payload);
        let req = match kind {
            K_FETCH => {
                let layer = p.name()?;
                let (trace, model) = p.optional_trace_model()?;
                Request::Fetch { layer, model, trace }
            }
            K_PREFETCH => {
                let layer = p.name()?;
                let (trace, model) = p.optional_trace_model()?;
                Request::Prefetch { layer, model, trace }
            }
            K_METRICS => Request::Metrics,
            K_COST_PROFILE => Request::CostProfile,
            K_TRACE => Request::TraceDump,
            K_STATS => Request::Stats,
            K_EVENTS => Request::Events { max: p.u32()? },
            K_SHUTDOWN => Request::Shutdown,
            k => bail!("unknown request kind {k:#04x}"),
        };
        p.finish()?;
        Ok(req)
    }
}

impl Response {
    fn encode(&self) -> (u8, Vec<u8>) {
        match self {
            Response::Layer { rows, cols, weights } => {
                let mut b =
                    Vec::with_capacity(16 + weights.len() * 4);
                b.extend_from_slice(&(*rows as u64).to_le_bytes());
                b.extend_from_slice(&(*cols as u64).to_le_bytes());
                for w in weights {
                    b.extend_from_slice(&w.to_le_bytes());
                }
                (K_LAYER, b)
            }
            Response::FusedLayer {
                rows,
                cols,
                dtype,
                scale,
                planes,
                mask,
            } => {
                let mut b = Vec::with_capacity(
                    FUSED_HEADER_BYTES
                        + (planes.len() + mask.len()) * 8,
                );
                push_fused_header(&mut b, *rows, *cols, *dtype, *scale);
                for w in planes {
                    b.extend_from_slice(&w.to_le_bytes());
                }
                for w in mask {
                    b.extend_from_slice(&w.to_le_bytes());
                }
                (K_FUSED_LAYER, b)
            }
            Response::Ack { accepted } => {
                (K_ACK, vec![u8::from(*accepted)])
            }
            Response::Metrics(m) => {
                let mut fields: Vec<u64> = vec![
                    m.hits,
                    m.misses,
                    m.decodes,
                    m.evictions,
                    m.prefetches,
                    m.redundant_decodes,
                    m.readahead_skips,
                    m.cached_bytes as u64,
                    m.cached_layers as u64,
                    m.pinned_bytes as u64,
                    m.decode_ns_total,
                    m.gemv_ns_total,
                ];
                fields.extend(m.decode_hist.to_wire());
                fields.extend(m.gemv_hist.to_wire());
                let mut b = Vec::with_capacity(4 + fields.len() * 8);
                b.extend_from_slice(
                    &(fields.len() as u32).to_le_bytes(),
                );
                for f in fields {
                    b.extend_from_slice(&f.to_le_bytes());
                }
                (K_METRICS_REPLY, b)
            }
            Response::CostProfile { json } => {
                (K_COSTS_REPLY, encode_name(json))
            }
            Response::Trace { pid, events } => {
                let mut b = Vec::with_capacity(
                    8 + events.len() * (TRACE_EVENT_MIN_BYTES + 16),
                );
                b.extend_from_slice(&pid.to_le_bytes());
                b.extend_from_slice(
                    &(events.len() as u32).to_le_bytes(),
                );
                for e in events {
                    b.extend_from_slice(&e.trace_id.to_le_bytes());
                    b.extend_from_slice(&e.t_start_ns.to_le_bytes());
                    b.extend_from_slice(&e.dur_ns.to_le_bytes());
                    b.push(e.kind.as_u8());
                    let label = e.label();
                    b.extend_from_slice(
                        &(label.len() as u32).to_le_bytes(),
                    );
                    b.extend_from_slice(label.as_bytes());
                }
                (K_TRACE_REPLY, b)
            }
            Response::Stats { json } => {
                (K_STATS_REPLY, encode_name(json))
            }
            Response::Events { jsonl } => {
                (K_EVENTS_REPLY, encode_name(jsonl))
            }
            Response::Bye => (K_BYE, Vec::new()),
            Response::Err { message } => {
                // Bound the message to the string cap the decoder
                // enforces, backing off to a char boundary so a
                // multibyte layer name can never panic the encoder.
                let mut message = message.clone();
                if message.len() > MAX_NAME {
                    let mut cut = MAX_NAME;
                    while !message.is_char_boundary(cut) {
                        cut -= 1;
                    }
                    message.truncate(cut);
                }
                (K_ERR, encode_name(&message))
            }
        }
    }

    /// Parse a response payload. Errors (never panics) on truncation,
    /// trailing bytes, geometry whose weight count disagrees with the
    /// payload, and unknown kinds.
    pub fn decode(kind: u8, payload: &[u8]) -> Result<Response> {
        let mut p = Cursor::new(payload);
        let resp = match kind {
            K_LAYER => {
                let rows = p.dim()?;
                let cols = p.dim()?;
                let n = rows.checked_mul(cols).ok_or_else(|| {
                    anyhow::anyhow!(
                        "layer geometry {rows}x{cols} overflows"
                    )
                })?;
                let byte_len = n.checked_mul(4).ok_or_else(|| {
                    anyhow::anyhow!(
                        "layer byte size overflows ({n} weights)"
                    )
                })?;
                let bytes = p.bytes(byte_len)?;
                let weights = bytes
                    .chunks_exact(4)
                    .map(|c| {
                        // lint: allow(no-index) -- chunks_exact(4) yields exactly 4 bytes
                        f32::from_le_bytes([c[0], c[1], c[2], c[3]])
                    })
                    .collect();
                Response::Layer { rows, cols, weights }
            }
            K_FUSED_LAYER => {
                let rows = p.dim()?;
                let cols = p.dim()?;
                let dtype = match p.u8()? {
                    0 => Dtype::F32,
                    1 => Dtype::I8,
                    d => bail!("unknown fused-layer dtype {d}"),
                };
                let scale = f32::from_le_bytes(p.array()?);
                // Word counts are derived from the geometry, with the
                // same pre-read validation as the counted frames
                // above: a lying geometry on a short payload is
                // corruption, never an absurd allocation.
                let stride = rows
                    .checked_mul(cols.div_ceil(64))
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "fused geometry {rows}x{cols} overflows"
                        )
                    })?;
                let plane_words = stride
                    .checked_mul(dtype.bits())
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "fused plane count overflows \
                             ({rows}x{cols})"
                        )
                    })?;
                let total = plane_words
                    .checked_add(stride)
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "fused word count overflows \
                             ({rows}x{cols})"
                        )
                    })?;
                if total > p.remaining() / 8 {
                    bail!(
                        "fused geometry {rows}x{cols} wants {total} \
                         words but the payload holds {} bytes",
                        p.remaining()
                    );
                }
                let planes = p.words(plane_words)?;
                let mask = p.words(stride)?;
                Response::FusedLayer {
                    rows,
                    cols,
                    dtype,
                    scale,
                    planes,
                    mask,
                }
            }
            K_ACK => Response::Ack { accepted: p.u8()? != 0 },
            K_METRICS_REPLY => {
                // Field-counted: a shorter payload (older peer)
                // zero-fills the tail, a longer one (newer peer) has
                // its extra fields read and ignored. The count is
                // validated against the bytes actually present before
                // anything is read, so a lying count is corruption,
                // never an absurd allocation.
                let count = p.u32()? as usize;
                if count > p.remaining() / 8 {
                    bail!(
                        "metrics field count {count} exceeds the \
                         {}-byte payload",
                        p.remaining()
                    );
                }
                let mut f = Vec::with_capacity(count);
                for _ in 0..count {
                    f.push(p.u64()?);
                }
                let g = |i: usize| f.get(i).copied().unwrap_or(0);
                let hist = |start: usize| {
                    HdrLite::from_wire(f.get(start..).unwrap_or(&[]))
                };
                Response::Metrics(StoreMetrics {
                    hits: g(0),
                    misses: g(1),
                    decodes: g(2),
                    evictions: g(3),
                    prefetches: g(4),
                    redundant_decodes: g(5),
                    readahead_skips: g(6),
                    cached_bytes: clamp_usize(g(7)),
                    cached_layers: clamp_usize(g(8)),
                    pinned_bytes: clamp_usize(g(9)),
                    decode_ns_total: g(10),
                    gemv_ns_total: g(11),
                    decode_hist: hist(12),
                    gemv_hist: hist(12 + obs::HDR_WIRE_FIELDS),
                })
            }
            K_COSTS_REPLY => {
                // The JSON text rides the same length-prefixed string
                // encoding as names, without the name length cap (a
                // large model's profile is legitimately long).
                Response::CostProfile { json: p.text()? }
            }
            K_STATS_REPLY => Response::Stats { json: p.text()? },
            K_EVENTS_REPLY => Response::Events { jsonl: p.text()? },
            K_TRACE_REPLY => {
                let pid = p.u32()?;
                let n = p.u32()? as usize;
                if n > p.remaining() / TRACE_EVENT_MIN_BYTES {
                    bail!(
                        "trace event count {n} exceeds the {}-byte \
                         payload",
                        p.remaining()
                    );
                }
                let mut events = Vec::with_capacity(n);
                for _ in 0..n {
                    let trace_id = p.u64()?;
                    let t_start_ns = p.u64()?;
                    let dur_ns = p.u64()?;
                    let kind = p.u8()?;
                    let label = p.name()?;
                    // A kind this build doesn't know (newer peer's
                    // taxonomy): drop the event, keep the frame.
                    if let Some(kind) = SpanKind::from_u8(kind) {
                        events.push(SpanEvent::new(
                            trace_id, kind, &label, t_start_ns,
                            dur_ns,
                        ));
                    }
                }
                Response::Trace { pid, events }
            }
            K_BYE => Response::Bye,
            K_ERR => Response::Err { message: p.name()? },
            k => bail!("unknown response kind {k:#04x}"),
        };
        p.finish()?;
        Ok(resp)
    }
}

fn clamp_usize(v: u64) -> usize {
    usize::try_from(v).unwrap_or(usize::MAX)
}

fn encode_name(s: &str) -> Vec<u8> {
    let mut b = Vec::with_capacity(4 + s.len());
    b.extend_from_slice(&(s.len() as u32).to_le_bytes());
    b.extend_from_slice(s.as_bytes());
    b
}

/// `Fetch`/`Prefetch` payload: length-prefixed name plus the trailing
/// trace id current peers always send (decoders accept its absence).
#[cfg(test)]
fn encode_name_trace(s: &str, trace: u64) -> Vec<u8> {
    let mut b = encode_name(s);
    b.extend_from_slice(&trace.to_le_bytes());
    b
}

/// `Fetch`/`Prefetch` payload with an optional model-id byte range:
/// `name | u64 trace | [u32 model_len | model]`. The model range is
/// only emitted when non-empty, so a single-model peer's frames are
/// byte-identical to the pre-zoo wire form and old decoders keep
/// accepting them.
fn encode_name_trace_model(s: &str, model: &str, trace: u64) -> Vec<u8> {
    let mut b = encode_name(s);
    b.extend_from_slice(&trace.to_le_bytes());
    if !model.is_empty() {
        b.extend_from_slice(&(model.len() as u32).to_le_bytes());
        b.extend_from_slice(model.as_bytes());
    }
    b
}

/// Bounds-checked payload reader: every accessor errors on truncation,
/// and [`Cursor::finish`] rejects trailing bytes.
struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Self {
        Cursor { b, i: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.i.checked_add(n).ok_or_else(|| {
            anyhow::anyhow!("payload offset overflows")
        })?;
        let Some(s) = self.b.get(self.i..end) else {
            bail!(
                "truncated payload: wanted {n} bytes at offset {}",
                self.i
            );
        };
        self.i = end;
        Ok(s)
    }

    /// Exactly `N` bytes as a fixed-size array (the `from_le_bytes`
    /// shape), so the integer accessors below never index a slice.
    fn array<const N: usize>(&mut self) -> Result<[u8; N]> {
        let b = self.bytes(N)?;
        b.try_into().map_err(|_| {
            anyhow::anyhow!("internal: cursor returned a wrong-size slice")
        })
    }

    fn u8(&mut self) -> Result<u8> {
        let [b] = self.array()?;
        Ok(b)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.array()?))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.array()?))
    }

    /// A layer dimension: `u64` on the wire, must fit a host `usize`.
    fn dim(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v)
            .map_err(|_| anyhow::anyhow!("dimension {v} too large"))
    }

    /// A length-prefixed utf-8 string, capped at [`MAX_NAME`].
    fn name(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        if len > MAX_NAME {
            bail!("name length {len} exceeds the {MAX_NAME}-byte cap");
        }
        let bytes = self.bytes(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| anyhow::anyhow!("name not utf8"))
    }

    /// A length-prefixed utf-8 string *without* the name cap (profile
    /// / stats / journal text is legitimately long; [`MAX_PAYLOAD`]
    /// still bounds it, and `bytes` bounds the read by what the
    /// payload actually holds).
    fn text(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let bytes = self.bytes(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| anyhow::anyhow!("text payload not utf8"))
    }

    /// Exactly `n` little-endian `u64` words. Callers pre-validate
    /// `n` against [`Cursor::remaining`]; `bytes` re-bounds the read
    /// by the payload actually present either way.
    fn words(&mut self, n: usize) -> Result<Vec<u64>> {
        let byte_len = n.checked_mul(8).ok_or_else(|| {
            anyhow::anyhow!("word count {n} overflows")
        })?;
        let bytes = self.bytes(byte_len)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| {
                let mut w = [0u8; 8];
                w.copy_from_slice(c);
                u64::from_le_bytes(w)
            })
            .collect())
    }

    /// Bytes not yet consumed.
    fn remaining(&self) -> usize {
        self.b.len().saturating_sub(self.i)
    }

    /// The optional trailing trace id and model id of
    /// `Fetch`/`Prefetch`: nothing from a v1 peer
    /// ([`obs::TRACE_NONE`], unscoped), exactly 8 bytes (trace only)
    /// from a single-model peer, or the trace followed by a
    /// length-prefixed model id (≥ 12 bytes) from a model-zoo peer;
    /// any other length is corruption. The model name shares
    /// [`MAX_NAME`] and the utf-8 requirement with layer names.
    fn optional_trace_model(&mut self) -> Result<(u64, String)> {
        match self.remaining() {
            0 => Ok((obs::TRACE_NONE, String::new())),
            8 => Ok((self.u64()?, String::new())),
            n if n >= 12 => {
                let trace = self.u64()?;
                let model = self.name()?;
                if model.is_empty() {
                    bail!("empty model id in a model-scoped frame");
                }
                Ok((trace, model))
            }
            n => bail!(
                "{n} trailing bytes where a trace id (8), a trace id \
                 plus model id (>=12), or nothing was expected"
            ),
        }
    }

    fn finish(&self) -> Result<()> {
        if self.i != self.b.len() {
            bail!(
                "{} trailing bytes after payload",
                self.b.len() - self.i
            );
        }
        Ok(())
    }
}

/// Convert a fetched wire layer into the serving-side decoded form.
pub fn layer_from_response(resp: Response) -> Result<DecodedLayer> {
    match resp {
        Response::Layer { rows, cols, weights } => {
            if rows.checked_mul(cols) != Some(weights.len()) {
                bail!(
                    "layer payload carries {} weights for a {rows}x{cols} \
                     geometry",
                    weights.len()
                );
            }
            Ok(DecodedLayer { rows, cols, weights })
        }
        other => bail!("expected a layer frame, got {other:?}"),
    }
}

/// Convert a fetched wire layer — dense or fused — into the executable
/// form the serving side runs. Both arrive through the same
/// geometry-vs-payload validation: a dense frame through
/// [`layer_from_response`], a fused one through
/// [`FusedLayer::from_raw`] (which re-checks the word counts against
/// the geometry, so a hostile frame can never build a layer whose
/// GEMV would read out of bounds).
pub fn exec_layer_from_response(resp: Response) -> Result<ExecLayer> {
    match resp {
        Response::FusedLayer {
            rows,
            cols,
            dtype,
            scale,
            planes,
            mask,
        } => FusedLayer::from_raw(rows, cols, dtype, scale, planes, mask)
            .map(ExecLayer::Fused)
            .map_err(|e| anyhow::anyhow!("fused layer frame: {e}")),
        other => {
            layer_from_response(other).map(ExecLayer::Materialized)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor as IoCursor;

    fn round_trip_request(req: Request) {
        let mut buf = Vec::new();
        send_request(&mut buf, &req).unwrap();
        let got = read_request(&mut IoCursor::new(&buf)).unwrap();
        assert_eq!(got, req);
    }

    fn round_trip_response(resp: Response) {
        let mut buf = Vec::new();
        send_response(&mut buf, &resp).unwrap();
        let got = read_response(&mut IoCursor::new(&buf)).unwrap();
        assert_eq!(got, resp);
    }

    fn sample_metrics() -> StoreMetrics {
        let mut decode_hist = HdrLite::new();
        decode_hist.record_ns(5_000);
        decode_hist.record_ns(900_000);
        let mut gemv_hist = HdrLite::new();
        gemv_hist.record_ns(250);
        StoreMetrics {
            hits: 1,
            misses: 2,
            decodes: 3,
            evictions: 4,
            prefetches: 5,
            redundant_decodes: 6,
            readahead_skips: 7,
            cached_bytes: 8,
            cached_layers: 9,
            pinned_bytes: 10,
            decode_ns_total: 11,
            gemv_ns_total: 12,
            decode_hist,
            gemv_hist,
        }
    }

    #[test]
    fn every_message_kind_round_trips() {
        round_trip_request(Request::Fetch {
            layer: "mlp/fc0".into(),
            model: String::new(),
            trace: 0xABCD_0000_0042,
        });
        round_trip_request(Request::Fetch {
            layer: "mlp/fc0".into(),
            model: "tf-base".into(),
            trace: 0xABCD_0000_0042,
        });
        round_trip_request(Request::Prefetch {
            layer: "x".into(),
            model: String::new(),
            trace: obs::TRACE_NONE,
        });
        round_trip_request(Request::Prefetch {
            layer: "x".into(),
            model: "m".into(),
            trace: obs::TRACE_NONE,
        });
        round_trip_request(Request::Metrics);
        round_trip_request(Request::CostProfile);
        round_trip_request(Request::TraceDump);
        round_trip_request(Request::Stats);
        round_trip_request(Request::Events { max: 0 });
        round_trip_request(Request::Events { max: u32::MAX });
        round_trip_request(Request::Shutdown);
        round_trip_response(Response::Layer {
            rows: 2,
            cols: 3,
            weights: vec![0.5, -1.0, 0.0, 3.25, 2.0, -0.125],
        });
        // 2×3 I8 fused: wpr = 1, 8 planes × 2 rows + 2 mask words.
        round_trip_response(Response::FusedLayer {
            rows: 2,
            cols: 3,
            dtype: Dtype::I8,
            scale: 0.125,
            planes: (0..16u64).map(|i| i.wrapping_mul(0x9E37)).collect(),
            mask: vec![0b101, 0b111],
        });
        // F32 fused: 32 planes per word-aligned row.
        round_trip_response(Response::FusedLayer {
            rows: 1,
            cols: 64,
            dtype: Dtype::F32,
            scale: 1.0,
            planes: vec![u64::MAX; 32],
            mask: vec![u64::MAX],
        });
        round_trip_response(Response::Ack { accepted: true });
        round_trip_response(Response::Ack { accepted: false });
        round_trip_response(Response::Metrics(sample_metrics()));
        round_trip_response(Response::CostProfile {
            json: "{\"title\": \"t\", \"cases\": {}}".into(),
        });
        round_trip_response(Response::Trace {
            pid: 4242,
            events: vec![
                SpanEvent::new(7, SpanKind::Decode, "fc0", 100, 50),
                SpanEvent::new(7, SpanKind::CacheMiss, "fc0", 90, 0),
                SpanEvent::new(
                    obs::TRACE_NONE,
                    SpanKind::Evict,
                    "",
                    200,
                    0,
                ),
            ],
        });
        round_trip_response(Response::Trace {
            pid: 1,
            events: Vec::new(),
        });
        round_trip_response(Response::Stats {
            json: "{\"schema\": 1, \"pid\": 7}".into(),
        });
        round_trip_response(Response::Stats { json: String::new() });
        round_trip_response(Response::Events {
            jsonl: "{\"kind\":\"a\"}\n{\"kind\":\"b\"}".into(),
        });
        round_trip_response(Response::Events { jsonl: String::new() });
        round_trip_response(Response::Bye);
        round_trip_response(Response::Err {
            message: "layer \"ghost\" not in container".into(),
        });
    }

    #[test]
    fn stats_and_events_frames_reject_corruption() {
        // Events request is exactly 4 bytes.
        assert!(Request::decode(K_EVENTS, &[]).is_err());
        assert!(Request::decode(K_EVENTS, &[1, 2, 3]).is_err());
        assert!(Request::decode(K_EVENTS, &[1, 0, 0, 0, 9]).is_err());
        // Stats request is empty.
        assert!(Request::decode(K_STATS, &[7]).is_err());
        // A text length lying past the payload is truncation.
        let mut lying = Vec::new();
        lying.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Response::decode(K_STATS_REPLY, &lying).is_err());
        assert!(Response::decode(K_EVENTS_REPLY, &lying).is_err());
        // Non-utf8 text is corruption, not a lossy parse.
        let mut bad = Vec::new();
        bad.extend_from_slice(&2u32.to_le_bytes());
        bad.extend_from_slice(&[0xFF, 0xFE]);
        assert!(Response::decode(K_STATS_REPLY, &bad).is_err());
        // Trailing bytes after the text reject.
        let mut trailing = encode_name("{}");
        trailing.push(0);
        assert!(Response::decode(K_STATS_REPLY, &trailing).is_err());
    }

    #[test]
    fn fetch_without_trailing_trace_decodes_as_v1() {
        // Satellite of the versioned-metrics work: an older peer's
        // Fetch/Prefetch carries no trace id — absent means NONE; a
        // partial trailer is corruption, not a silent zero.
        for kind in [K_FETCH, K_PREFETCH] {
            let payload = encode_name("fc0");
            let req = Request::decode(kind, &payload).unwrap();
            let (layer, model, trace) = match req {
                Request::Fetch { layer, model, trace }
                | Request::Prefetch { layer, model, trace } => {
                    (layer, model, trace)
                }
                other => panic!("wrong variant: {other:?}"),
            };
            assert_eq!(layer, "fc0");
            assert_eq!(model, "", "absent model range means unscoped");
            assert_eq!(trace, obs::TRACE_NONE);
            for extra in 1..8usize {
                let mut bad = encode_name("fc0");
                bad.extend_from_slice(&vec![0u8; extra]);
                assert!(
                    Request::decode(kind, &bad).is_err(),
                    "{extra} trailing bytes must not parse"
                );
            }
            // 9..11 trailing bytes: more than a trace, less than the
            // smallest trace+model trailer — corruption.
            for extra in 1..4usize {
                let mut bad = encode_name_trace("fc0", 9);
                bad.extend_from_slice(&vec![0u8; extra]);
                assert!(
                    Request::decode(kind, &bad).is_err(),
                    "trace + {extra} stray bytes must not parse"
                );
            }
        }
    }

    #[test]
    fn model_scoped_fetch_trailer_is_validated() {
        for kind in [K_FETCH, K_PREFETCH] {
            // A model-id length lying past the payload is truncation.
            let mut lying = encode_name_trace("fc0", 9);
            lying.extend_from_slice(&u32::MAX.to_le_bytes());
            assert!(Request::decode(kind, &lying).is_err());
            // An explicit empty model id is corruption (the encoder
            // omits the range entirely for unscoped frames).
            let mut empty = encode_name_trace("fc0", 9);
            empty.extend_from_slice(&0u32.to_le_bytes());
            assert!(Request::decode(kind, &empty).is_err());
            // Trailing bytes after the model id reject.
            let mut trailing =
                encode_name_trace_model("fc0", "zoo-a", 9);
            trailing.push(0);
            assert!(Request::decode(kind, &trailing).is_err());
            // Non-utf8 model id is corruption.
            let mut bad = encode_name_trace("fc0", 9);
            bad.extend_from_slice(&2u32.to_le_bytes());
            bad.extend_from_slice(&[0xFF, 0xFE]);
            assert!(Request::decode(kind, &bad).is_err());
            // The single-model frame is byte-identical to the pre-zoo
            // form: no model range at all.
            assert_eq!(
                encode_name_trace_model("fc0", "", 9),
                encode_name_trace("fc0", 9)
            );
        }
    }

    #[test]
    fn metrics_reply_tolerates_older_and_newer_field_counts() {
        let m = sample_metrics();
        let (kind, full) = Response::Metrics(m).encode();
        assert_eq!(kind, K_METRICS_REPLY);
        let n_fields = 12 + 2 * obs::HDR_WIRE_FIELDS;
        assert_eq!(full.len(), 4 + n_fields * 8);

        // Older peer: only the 12 counters. The histograms zero-fill.
        let mut short = Vec::new();
        short.extend_from_slice(&12u32.to_le_bytes());
        short.extend_from_slice(&full[4..4 + 12 * 8]);
        let got = Response::decode(K_METRICS_REPLY, &short).unwrap();
        let Response::Metrics(sm) = got else { panic!("not metrics") };
        assert_eq!(sm.hits, m.hits);
        assert_eq!(sm.gemv_ns_total, m.gemv_ns_total);
        assert!(sm.decode_hist.is_empty(), "missing tail zero-fills");
        assert!(sm.gemv_hist.is_empty());

        // Newer peer: four extra fields appended. Extras are ignored.
        let mut long = Vec::new();
        long.extend_from_slice(&(n_fields as u32 + 4).to_le_bytes());
        long.extend_from_slice(&full[4..]);
        for v in [101u64, 102, 103, 104] {
            long.extend_from_slice(&v.to_le_bytes());
        }
        let got = Response::decode(K_METRICS_REPLY, &long).unwrap();
        assert_eq!(got, Response::Metrics(m), "extras must be ignored");

        // A count lying past the payload is corruption, pre-read.
        let mut lying = Vec::new();
        lying.extend_from_slice(&u32::MAX.to_le_bytes());
        lying.extend_from_slice(&full[4..]);
        assert!(Response::decode(K_METRICS_REPLY, &lying).is_err());
    }

    #[test]
    fn trace_reply_drops_unknown_kinds_and_caps_counts() {
        let ev = SpanEvent::new(3, SpanKind::Gemv, "fc1", 50, 25);
        let (kind, mut payload) = Response::Trace {
            pid: 9,
            events: vec![ev],
        }
        .encode();
        assert_eq!(kind, K_TRACE_REPLY);
        // Append a second event with a future kind discriminant and
        // bump the count: the event drops, the frame survives.
        payload[4..8].copy_from_slice(&2u32.to_le_bytes());
        payload.extend_from_slice(&11u64.to_le_bytes());
        payload.extend_from_slice(&60u64.to_le_bytes());
        payload.extend_from_slice(&1u64.to_le_bytes());
        payload.push(200); // unknown kind
        payload.extend_from_slice(&0u32.to_le_bytes());
        let got = Response::decode(K_TRACE_REPLY, &payload).unwrap();
        assert_eq!(got, Response::Trace { pid: 9, events: vec![ev] });
        // An event count lying past the payload is corruption.
        let mut lying = Vec::new();
        lying.extend_from_slice(&9u32.to_le_bytes());
        lying.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Response::decode(K_TRACE_REPLY, &lying).is_err());
    }

    #[test]
    fn fused_frames_reject_corruption() {
        // A well-formed 1×3 I8 frame to mutate: 3 cols → 1 word/row,
        // 8 plane words + 1 mask word after the 21-byte prefix.
        let good = Response::FusedLayer {
            rows: 1,
            cols: 3,
            dtype: Dtype::I8,
            scale: 0.5,
            planes: vec![0b101; 8],
            mask: vec![0b111],
        };
        let (kind, payload) = good.encode();
        assert_eq!(kind, K_FUSED_LAYER);
        assert!(Response::decode(kind, &payload).is_ok());
        // Unknown dtype discriminant.
        let mut bad_dtype = payload.clone();
        bad_dtype[16] = 7;
        assert!(Response::decode(kind, &bad_dtype).is_err());
        // Geometry promising more words than the payload holds —
        // rejected before any allocation.
        let mut lying = payload.clone();
        lying[0..8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(Response::decode(kind, &lying).is_err());
        // An overflowing geometry.
        let mut overflow = payload.clone();
        overflow[0..8]
            .copy_from_slice(&(u64::MAX / 2).to_le_bytes());
        overflow[8..16]
            .copy_from_slice(&(u64::MAX / 2).to_le_bytes());
        assert!(Response::decode(kind, &overflow).is_err());
        // Trailing bytes after the mask words.
        let mut trailing = payload.clone();
        trailing.push(0);
        assert!(Response::decode(kind, &trailing).is_err());
        // Truncation at every cut errors, never panics.
        for cut in 0..payload.len() {
            assert!(
                Response::decode(kind, &payload[..cut]).is_err(),
                "cut {cut} parsed"
            );
        }
    }

    #[test]
    fn streamed_fused_frame_matches_the_owned_encoding() {
        // 2×70 I8: 2 words/row, tail bits in play.
        let planes: Vec<u64> =
            (0..32u64).map(|i| i.wrapping_mul(0x0123_4567)).collect();
        let mask = vec![u64::MAX, 0x3F, 0, 0x2A];
        let layer = FusedLayer::from_raw(
            2,
            70,
            Dtype::I8,
            0.25,
            planes.clone(),
            mask.clone(),
        )
        .unwrap();
        let mut owned = Vec::new();
        send_response(
            &mut owned,
            &Response::FusedLayer {
                rows: 2,
                cols: 70,
                dtype: Dtype::I8,
                scale: 0.25,
                planes,
                mask,
            },
        )
        .unwrap();
        let mut streamed = Vec::new();
        send_fused_layer(&mut streamed, &layer).unwrap();
        assert_eq!(streamed, owned, "one wire form, two writers");
    }

    #[test]
    fn exec_layer_from_response_converts_both_forms() {
        let dense = exec_layer_from_response(Response::Layer {
            rows: 1,
            cols: 2,
            weights: vec![1.0, 2.0],
        })
        .unwrap();
        assert!(!dense.is_fused());
        assert_eq!((dense.rows(), dense.cols()), (1, 2));
        let fused = exec_layer_from_response(Response::FusedLayer {
            rows: 1,
            cols: 3,
            dtype: Dtype::I8,
            scale: 0.5,
            planes: vec![0; 8],
            mask: vec![0b111],
        })
        .unwrap();
        assert!(fused.is_fused());
        assert_eq!((fused.rows(), fused.cols()), (1, 3));
        // Word counts disagreeing with the geometry re-reject at the
        // FusedLayer boundary (an in-process construction bug, since
        // the wire decoder derives counts from the geometry).
        assert!(exec_layer_from_response(Response::FusedLayer {
            rows: 1,
            cols: 3,
            dtype: Dtype::I8,
            scale: 0.5,
            planes: vec![0; 7],
            mask: vec![0b111],
        })
        .is_err());
        assert!(exec_layer_from_response(Response::Bye).is_err());
    }

    #[test]
    fn streamed_layer_frame_matches_the_owned_encoding() {
        let weights = vec![0.5f32, -1.0, 0.0, 3.25, 2.0, -0.125];
        let mut owned = Vec::new();
        send_response(
            &mut owned,
            &Response::Layer {
                rows: 2,
                cols: 3,
                weights: weights.clone(),
            },
        )
        .unwrap();
        let mut streamed = Vec::new();
        send_layer(&mut streamed, 2, 3, &weights).unwrap();
        assert_eq!(streamed, owned, "one wire form, two writers");
    }

    #[test]
    fn oversized_payload_is_a_send_error_in_release_too() {
        // The length check happens before any bytes move, so probing
        // it needs no giant allocation.
        let err = check_payload_len(MAX_PAYLOAD + 1).unwrap_err();
        assert!(format!("{err}").contains("cap"), "{err}");
        assert!(check_payload_len(MAX_PAYLOAD).is_ok());
        assert_eq!(MAX_WIRE_WEIGHTS, (MAX_PAYLOAD - 16) / 4);
    }

    #[test]
    fn empty_stream_is_eof_not_corrupt() {
        let err =
            read_frame(&mut IoCursor::new(Vec::new())).unwrap_err();
        assert!(matches!(err, WireError::Eof));
    }

    #[test]
    fn truncation_at_every_cut_errors_never_panics() {
        let mut buf = Vec::new();
        send_request(
            &mut buf,
            &Request::Fetch {
                layer: "layer0".into(),
                model: "zoo".into(),
                trace: 0,
            },
        )
        .unwrap();
        for cut in 1..buf.len() {
            let err = read_request(&mut IoCursor::new(&buf[..cut]))
                .unwrap_err();
            assert!(
                matches!(err, WireError::Corrupt(_)),
                "cut {cut}: {err}"
            );
        }
        let mut resp = Vec::new();
        send_response(
            &mut resp,
            &Response::Layer {
                rows: 2,
                cols: 2,
                weights: vec![1.0, 2.0, 3.0, 4.0],
            },
        )
        .unwrap();
        for cut in 1..resp.len() {
            assert!(
                read_response(&mut IoCursor::new(&resp[..cut]))
                    .is_err(),
                "cut {cut} parsed"
            );
        }
    }

    #[test]
    fn byte_flip_fuzz_never_panics() {
        let mut buf = Vec::new();
        send_response(
            &mut buf,
            &Response::Layer {
                rows: 2,
                cols: 2,
                weights: vec![1.0, 2.0, 3.0, 4.0],
            },
        )
        .unwrap();
        for pos in 0..buf.len() {
            for val in [0x00u8, 0x01, 0x7F, 0xFF] {
                if buf[pos] == val {
                    continue;
                }
                let mut corrupt = buf.clone();
                corrupt[pos] = val;
                // May parse (a flipped f32 bit is still a layer) or
                // reject — must never panic or over-allocate.
                let _ = read_response(&mut IoCursor::new(&corrupt));
            }
        }
    }

    #[test]
    fn hostile_lengths_are_capped() {
        // A header that promises more payload than the cap.
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.push(K_FETCH);
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut IoCursor::new(&buf)).unwrap_err();
        assert!(
            matches!(err, WireError::Corrupt(ref m) if m.contains("cap")),
            "{err}"
        );
        // A name length beyond the cap inside a well-formed frame.
        let mut payload = Vec::new();
        payload.extend_from_slice(&(MAX_NAME as u32 + 1).to_le_bytes());
        assert!(Request::decode(K_FETCH, &payload).is_err());
        // A layer whose geometry overflows.
        let mut payload = Vec::new();
        payload.extend_from_slice(&u64::MAX.to_le_bytes());
        payload.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(Response::decode(K_LAYER, &payload).is_err());
    }

    #[test]
    fn bad_magic_version_kind_and_trailing_bytes_error() {
        let mut buf = Vec::new();
        send_request(&mut buf, &Request::Metrics).unwrap();
        let mut bad_magic = buf.clone();
        bad_magic[0] = b'X';
        assert!(matches!(
            read_frame(&mut IoCursor::new(&bad_magic)).unwrap_err(),
            WireError::Corrupt(_)
        ));
        let mut bad_version = buf.clone();
        bad_version[4] = 9;
        assert!(read_frame(&mut IoCursor::new(&bad_version)).is_err());
        assert!(Request::decode(0x42, &[]).is_err());
        assert!(Response::decode(0x42, &[]).is_err());
        // Trailing bytes after a fixed-size payload.
        assert!(Request::decode(K_METRICS, &[0]).is_err());
        assert!(Response::decode(K_ACK, &[1, 2]).is_err());
    }

    #[test]
    fn layer_from_response_validates_shape() {
        let ok = layer_from_response(Response::Layer {
            rows: 1,
            cols: 2,
            weights: vec![1.0, 2.0],
        })
        .unwrap();
        assert_eq!((ok.rows, ok.cols), (1, 2));
        assert!(layer_from_response(Response::Bye).is_err());
    }
}

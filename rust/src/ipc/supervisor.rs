//! The worker supervisor: spawn, health-check, restart, aggregate.
//!
//! One [`Supervisor`] owns N shard-worker *processes* (spawned with
//! `std::process::Command` running `f2f shard-worker`), one per shard
//! of a split model. Its job is to keep the serving tier available:
//!
//! * **Spawn** — start every worker and block until each answers a
//!   health probe (a metrics round trip) on its socket.
//! * **Health-check / revive** — [`Supervisor::revive`] is the repair
//!   path the router calls on a transport failure: a worker that
//!   merely dropped a connection is reconnected; a dead or
//!   unresponsive one is replaced by a fresh process *with the same
//!   shard assignment and socket path* (the spec is replayed
//!   verbatim), so the router's next call lands on the new process
//!   without any re-routing.
//! * **Shutdown** — ask every worker to exit over the wire, wait
//!   briefly, and kill stragglers; `Drop` does the same so a panicked
//!   test never leaks processes.
//! * **Postmortem** — reaping a dead worker ([`Supervisor::revive`],
//!   [`Supervisor::kill_worker`]) collects its crash flight sidecar
//!   ([`crate::obs::flight`]), attributes the exit (panic message >
//!   signal > exit code; a wire shutdown is attributed as such), and
//!   emits the postmortem artifact pair plus a `worker_exit` journal
//!   event before the replacement starts.
//!
//! The supervisor also owns the per-worker [`IpcShardStore`] clients,
//! shared with the [`ProcRouter`](super::ProcRouter) by `Arc` — which
//! is what makes the restart transparent: both sides talk through the
//! same reconnecting stub.

use super::client::IpcShardStore;
use crate::obs::events::{self, Value};
use crate::obs::flight;
use crate::sync::lock_unpoisoned;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::process::{Child, Command, ExitStatus, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Everything needed to (re)start one shard worker. Replaying the
/// spec after a crash reproduces the worker's shard assignment
/// exactly.
#[derive(Debug, Clone)]
pub struct WorkerSpec {
    /// The `f2f` binary to exec.
    pub binary: PathBuf,
    /// The shard's self-contained v2 container file.
    pub shard_path: PathBuf,
    /// The unix socket the worker serves on.
    pub socket_path: PathBuf,
    /// Decoded-weight cache budget in KiB (0 = unbounded).
    pub cache_kb: usize,
    /// Decode-service width (0 = size to the host).
    pub decode_threads: usize,
    /// The worker store's [`crate::kernels::DecodeMode`] — replayed on
    /// respawn so a restarted worker caches (and ships) layers in the
    /// same representation as the incarnation it replaces.
    pub decode_mode: crate::kernels::DecodeMode,
    /// Directory for crash flight sidecars ([`crate::obs::flight`]).
    /// `None` disables flight recording and postmortems.
    pub flight_dir: Option<PathBuf>,
}

impl WorkerSpec {
    /// A spec with default store knobs.
    pub fn new(
        binary: impl Into<PathBuf>,
        shard_path: impl Into<PathBuf>,
        socket_path: impl Into<PathBuf>,
    ) -> Self {
        WorkerSpec {
            binary: binary.into(),
            shard_path: shard_path.into(),
            socket_path: socket_path.into(),
            cache_kb: 0,
            decode_threads: 0,
            decode_mode: crate::kernels::DecodeMode::default(),
            flight_dir: None,
        }
    }

    /// Enable crash flight recording under `dir`.
    pub fn with_flight_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.flight_dir = Some(dir.into());
        self
    }

    fn command(&self) -> Command {
        let mut cmd = Command::new(&self.binary);
        cmd.arg("shard-worker")
            .arg(&self.shard_path)
            .arg("--socket")
            .arg(&self.socket_path);
        if self.cache_kb > 0 {
            cmd.arg("--cache-kb").arg(self.cache_kb.to_string());
        }
        if self.decode_threads > 0 {
            cmd.arg("--decode-threads")
                .arg(self.decode_threads.to_string());
        }
        if self.decode_mode != crate::kernels::DecodeMode::default() {
            cmd.arg("--decode-mode")
                .arg(self.decode_mode.to_string());
        }
        if let Some(dir) = &self.flight_dir {
            cmd.arg("--flight-dir").arg(dir);
        }
        // Workers are silent on success; their stderr is worth seeing
        // when one dies, so it inherits the supervisor's.
        cmd.stdin(Stdio::null()).stdout(Stdio::null());
        cmd
    }
}

/// Attribute one worker death: a panic recorded in the flight sidecar
/// wins (it names the panic site), then the wait status (signal
/// number or exit code), then an honest "unknown".
fn exit_cause(
    status: Option<ExitStatus>,
    flight: Option<&flight::FlightData>,
) -> String {
    if let Some(data) = flight {
        if data.panicked {
            return format!("panic: {}", data.panic_msg);
        }
    }
    match status {
        Some(st) => {
            use std::os::unix::process::ExitStatusExt;
            if let Some(sig) = st.signal() {
                format!("signal {sig}")
            } else if let Some(code) = st.code() {
                if code == 0 {
                    "clean exit".to_string()
                } else {
                    format!("exit code {code}")
                }
            } else {
                format!("{st}")
            }
        }
        None => "unknown (no exit status)".to_string(),
    }
}

/// Reap one dead worker: collect its flight sidecar (if any),
/// attribute the exit, write the postmortem artifact pair, and emit
/// one `worker_exit` journal event carrying the attributed cause.
fn reap_worker(
    spec: &WorkerSpec,
    shard: usize,
    pid: Option<u32>,
    status: Option<ExitStatus>,
) {
    let data = match (spec.flight_dir.as_deref(), pid) {
        (Some(dir), Some(pid)) => {
            let path = flight::flight_path(dir, pid);
            let data = flight::FlightData::read(&path).ok();
            if data.is_some() {
                // The sidecar is consumed by this reap; the next
                // incarnation writes its own under its own pid.
                let _ = std::fs::remove_file(&path);
            }
            data
        }
        _ => None,
    };
    let cause = exit_cause(status, data.as_ref());
    let mut spans = 0u64;
    if let (Some(dir), Some(data)) =
        (spec.flight_dir.as_deref(), data.as_ref())
    {
        match flight::write_postmortem(dir, data, &cause) {
            Ok(pm) => spans = pm.spans as u64,
            Err(e) => {
                events::warn(
                    "postmortem_failed",
                    &format!(
                        "shard worker {shard}: postmortem write \
                         failed: {e:#}"
                    ),
                    &[("shard", Value::U64(shard as u64))],
                );
            }
        }
    }
    events::warn(
        "worker_exit",
        &format!("shard worker {shard} died: {cause}"),
        &[
            ("shard", Value::U64(shard as u64)),
            ("pid", Value::U64(u64::from(pid.unwrap_or(0)))),
            ("cause", Value::Str(cause)),
            ("flight_spans", Value::U64(spans)),
        ],
    );
}

struct Slot {
    spec: WorkerSpec,
    child: Option<Child>,
}

/// Supervises N shard-worker processes and their client stubs.
pub struct Supervisor {
    slots: Mutex<Vec<Slot>>,
    clients: Vec<Arc<IpcShardStore>>,
    restarts: AtomicU64,
    ready_timeout: Duration,
}

impl Supervisor {
    /// Spawn one worker per spec and wait until every one answers its
    /// health probe. On failure, already-started workers are torn
    /// down by `Drop`.
    pub fn spawn(specs: Vec<WorkerSpec>) -> Result<Arc<Supervisor>> {
        Self::spawn_with_timeout(specs, Duration::from_secs(20))
    }

    /// [`Supervisor::spawn`] with an explicit per-worker readiness
    /// timeout.
    pub fn spawn_with_timeout(
        specs: Vec<WorkerSpec>,
        ready_timeout: Duration,
    ) -> Result<Arc<Supervisor>> {
        if specs.is_empty() {
            bail!("supervisor needs at least one worker spec");
        }
        let clients = specs
            .iter()
            .map(|s| Arc::new(IpcShardStore::connect(&s.socket_path)))
            .collect();
        let sup = Arc::new(Supervisor {
            slots: Mutex::new(
                specs
                    .into_iter()
                    .map(|spec| Slot { spec, child: None })
                    .collect(),
            ),
            clients,
            restarts: AtomicU64::new(0),
            ready_timeout,
        });
        let n = sup.n_workers();
        for i in 0..n {
            sup.start_worker(i)?;
        }
        Ok(sup)
    }

    /// Number of supervised workers.
    pub fn n_workers(&self) -> usize {
        self.clients.len()
    }

    /// The per-worker client stubs, indexed by shard id. Shared with
    /// the router by `Arc`.
    pub fn clients(&self) -> &[Arc<IpcShardStore>] {
        &self.clients
    }

    /// How many workers have been restarted since spawn.
    pub fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::Relaxed)
    }

    /// The worker's OS pid, if it is currently running.
    pub fn worker_pid(&self, shard: usize) -> Option<u32> {
        let slots = lock_unpoisoned(&self.slots);
        slots.get(shard)?.child.as_ref().map(|c| c.id())
    }

    /// (Re)start one worker and wait for its health probe.
    fn start_worker(&self, shard: usize) -> Result<()> {
        {
            let mut slots = lock_unpoisoned(&self.slots);
            let slot = slots
                .get_mut(shard)
                .with_context(|| format!("no worker slot {shard}"))?;
            // The worker unlinks a stale socket itself, but removing
            // it here too closes the window where a probe reaches the
            // dead incarnation's socket.
            let _ = std::fs::remove_file(&slot.spec.socket_path);
            let child = slot.spec.command().spawn().with_context(
                || {
                    format!(
                        "spawning shard worker {shard} ({})",
                        slot.spec.binary.display()
                    )
                },
            )?;
            slot.child = Some(child);
        }
        self.clients[shard].disconnect();
        self.wait_ready(shard)
    }

    /// Poll the worker's health probe until it answers or the
    /// readiness timeout passes. A child that exits meanwhile fails
    /// fast with its status.
    fn wait_ready(&self, shard: usize) -> Result<()> {
        let deadline = Instant::now() + self.ready_timeout;
        loop {
            if self.clients[shard].ping() {
                return Ok(());
            }
            // Child already gone? Report the exit instead of waiting
            // out the clock.
            {
                let mut slots = lock_unpoisoned(&self.slots);
                if let Some(child) = slots[shard].child.as_mut() {
                    if let Some(status) = child.try_wait()? {
                        slots[shard].child = None;
                        bail!(
                            "shard worker {shard} exited during \
                             startup ({status})"
                        );
                    }
                }
            }
            if Instant::now() >= deadline {
                bail!(
                    "shard worker {shard} did not become ready \
                     within {:?}",
                    self.ready_timeout
                );
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// Repair one worker after a transport failure: if the process is
    /// alive and answers a probe, only the connection is refreshed;
    /// a dead or unresponsive process is replaced (same spec, same
    /// socket — the shard assignment is replayed).
    pub fn revive(&self, shard: usize) -> Result<()> {
        let needs_restart = {
            let mut slots = lock_unpoisoned(&self.slots);
            let slot = slots
                .get_mut(shard)
                .with_context(|| format!("no worker slot {shard}"))?;
            match slot.child.as_mut() {
                None => true,
                Some(child) => {
                    let pid = child.id();
                    match child.try_wait()? {
                        Some(status) => {
                            slot.child = None;
                            let spec = slot.spec.clone();
                            drop(slots);
                            reap_worker(
                                &spec,
                                shard,
                                Some(pid),
                                Some(status),
                            );
                            true
                        }
                        None => false,
                    }
                }
            }
        };
        if !needs_restart {
            // Process alive: maybe only the connection died.
            self.clients[shard].disconnect();
            if self.clients[shard].ping() {
                return Ok(());
            }
            // Alive but unresponsive: replace it.
            let mut slots = lock_unpoisoned(&self.slots);
            if let Some(mut child) = slots[shard].child.take() {
                let pid = child.id();
                let _ = child.kill();
                let status = child.wait().ok();
                let spec = slots[shard].spec.clone();
                drop(slots);
                events::warn(
                    "worker_unresponsive",
                    &format!(
                        "shard worker {shard} alive but unresponsive; \
                         replacing"
                    ),
                    &[("shard", Value::U64(shard as u64))],
                );
                reap_worker(&spec, shard, Some(pid), status);
            }
        }
        self.restarts.fetch_add(1, Ordering::Relaxed);
        self.start_worker(shard)?;
        events::info(
            "worker_respawn",
            &format!("shard worker {shard} respawned"),
            &[
                ("shard", Value::U64(shard as u64)),
                (
                    "pid",
                    Value::U64(u64::from(
                        self.worker_pid(shard).unwrap_or(0),
                    )),
                ),
                ("restarts", Value::U64(self.restarts())),
            ],
        );
        Ok(())
    }

    /// Kill one worker process outright (no restart) — the fault
    /// injection hook the kill/restart tests and chaos drills use.
    pub fn kill_worker(&self, shard: usize) -> Result<()> {
        let mut slots = lock_unpoisoned(&self.slots);
        let slot = slots
            .get_mut(shard)
            .with_context(|| format!("no worker slot {shard}"))?;
        if let Some(mut child) = slot.child.take() {
            let pid = child.id();
            let _ = child.kill();
            let status = child.wait().ok();
            let spec = slot.spec.clone();
            drop(slots);
            reap_worker(&spec, shard, Some(pid), status);
        } else {
            drop(slots);
        }
        self.clients[shard].disconnect();
        Ok(())
    }

    /// Stop every worker: a wire `Shutdown` first, then a bounded
    /// wait, then a kill for whatever is left. Socket files are
    /// cleaned up.
    pub fn shutdown(&self) {
        for client in &self.clients {
            let _ = client.shutdown();
        }
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut slots = lock_unpoisoned(&self.slots);
        for (shard, slot) in slots.iter_mut().enumerate() {
            let Some(child) = slot.child.as_mut() else { continue };
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
            slot.child = None;
            let _ = std::fs::remove_file(&slot.spec.socket_path);
            // An orderly exit: attributed to the wire request, no
            // postmortem (the worker removed its own flight sidecar).
            events::info(
                "worker_exit",
                &format!("shard worker {shard} shut down (wire)"),
                &[
                    ("shard", Value::U64(shard as u64)),
                    ("cause", Value::Str("shutdown".to_string())),
                ],
            );
        }
    }
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        // Never leak worker processes, even on a panicking path.
        let mut slots = lock_unpoisoned(&self.slots);
        for slot in slots.iter_mut() {
            if let Some(mut child) = slot.child.take() {
                match child.try_wait() {
                    Ok(Some(_)) => {}
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                    }
                }
            }
            let _ = std::fs::remove_file(&slot.spec.socket_path);
        }
    }
}

//! The shard-worker serve loop: one [`ModelStore`] behind a socket.
//!
//! A worker process owns exactly one shard of a split model: its own
//! mmap-backed store, its own decode service, its own budget, its own
//! cost table (warm-started from the `<shard>.costs.json` sidecar when
//! one sits next to the shard file — see
//! [`crate::store::ModelStore::open_path`]). It answers the wire
//! protocol over a `UnixListener`:
//!
//! * `Fetch` blocks on [`ModelStore::get`] and ships the decoded
//!   layer back *in the representation the store caches*: a
//!   materialized layer as a dense weight frame, a fused one as its
//!   bit-planes + mask (~9/32 of the dense frame for I8 layers) — the
//!   store's in-flight dedup means a fetch racing a cross-process
//!   readahead never decodes twice.
//! * `Prefetch` maps to [`ModelStore::prefetch_async`] and returns
//!   immediately, which is what lets the router warm layer `i+1` on
//!   *this* worker's decode service while layer `i`'s GEMV runs in the
//!   router process.
//! * `Metrics` / `CostProfile` snapshot the store's counters and cost
//!   table, so the supervisor aggregates `--timing` and
//!   `--profile-out` across processes unchanged.
//! * `TraceDump` snapshots this process's span recorder
//!   ([`crate::obs`]) so the router can stitch worker decode spans
//!   into one cross-process Chrome trace. `Fetch`/`Prefetch` frames
//!   carry the requester's trace id, and the handler pins it to the
//!   serving thread for the duration of the store call — every span
//!   the call records lands in the requester's timeline.
//! * `Stats` / `Events` answer the live-operations frames
//!   ([`crate::obs::stats`], [`crate::obs::events`]) with this
//!   worker's single-shard snapshot and journal tail.
//! * `Shutdown` ends the serve loop cleanly.
//!
//! When spawned with a flight directory ([`run_worker`]'s
//! `flight_dir`), the worker also installs a crash flight recorder
//! ([`crate::obs::flight`]): a panic hook plus a checkpoint thread
//! keep `<dir>/flight-<pid>.bin` current so the supervisor can write
//! a postmortem for a death that never answered `TraceDump`. A clean
//! shutdown removes the sidecar.
//!
//! Failure policy: a bad request (unknown layer, corrupt record) is an
//! error *frame*, never a worker death; a corrupt byte stream closes
//! that one connection; a panic anywhere in decode is already caught
//! store-side. The process only exits on `Shutdown` — everything else
//! is survivable, and the supervisor restarts whatever is not.

use super::wire::{self, Request, Response, WireError};
use crate::kernels::ExecLayer;
use crate::obs;
use crate::shard::CostProfile;
use crate::store::{ModelStore, StoreConfig};
use anyhow::{Context, Result};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// How often the accept loop and idle connections poll the shutdown
/// flag.
const POLL: Duration = Duration::from_millis(5);

/// Open `shard_path` as a [`ModelStore`] (mmap-backed under the `mmap`
/// feature, cost sidecar auto-loaded) and serve it on `socket_path`
/// until a `Shutdown` request arrives. The `f2f shard-worker` child
/// entrypoint is a thin wrapper over this.
pub fn run_worker(
    shard_path: &Path,
    socket_path: &Path,
    config: StoreConfig,
    flight_dir: Option<&Path>,
) -> Result<()> {
    let store = Arc::new(
        ModelStore::open_path(shard_path, config).with_context(|| {
            format!("opening shard {}", shard_path.display())
        })?,
    );
    // Flight recording is best-effort: a worker that cannot write its
    // sidecar still serves — it just dies without a postmortem.
    let recorder = flight_dir.and_then(|dir| {
        match obs::flight::FlightRecorder::install(
            dir,
            obs::flight::DEFAULT_CHECKPOINT_INTERVAL,
        ) {
            Ok(r) => Some(r),
            Err(e) => {
                obs::events::warn(
                    "flight_install_failed",
                    &format!("flight recorder disabled: {e:#}"),
                    &[],
                );
                None
            }
        }
    });
    let result = serve_store(store, socket_path);
    if let Some(rec) = recorder {
        // A clean exit removes the sidecar; a flight file left behind
        // always means an unclean death.
        rec.finish(result.is_ok());
    }
    result
}

/// Serve an already-open store on `socket_path` until `Shutdown`.
/// Restarted workers replay the same socket path, so a stale socket
/// file from a crashed incarnation is unlinked before binding.
pub fn serve_store(
    store: Arc<ModelStore>,
    socket_path: &Path,
) -> Result<()> {
    let _ = std::fs::remove_file(socket_path);
    let listener = UnixListener::bind(socket_path).with_context(|| {
        format!("binding {}", socket_path.display())
    })?;
    // Non-blocking accept so the loop can observe the shutdown flag a
    // connection handler sets.
    listener
        .set_nonblocking(true)
        .context("setting listener non-blocking")?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !shutdown.load(Ordering::SeqCst) {
        // Reap handlers whose connection already ended, so a
        // long-lived worker's handle list stays bounded by *live*
        // connections, not lifetime connection count.
        conns.retain(|h| !h.is_finished());
        match listener.accept() {
            Ok((stream, _)) => {
                let store = store.clone();
                let shutdown = shutdown.clone();
                match std::thread::Builder::new()
                    .name("f2f-ipc-conn".into())
                    .spawn(move || {
                        serve_connection(stream, &store, &shutdown)
                    }) {
                    Ok(handle) => conns.push(handle),
                    // Transient resource pressure: dropping the one
                    // connection (the closure — and the stream it
                    // owns — is dropped) beats killing a worker full
                    // of warm cache. The client sees a transport
                    // error and retries through the supervisor.
                    Err(_) => std::thread::sleep(POLL),
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                std::thread::sleep(POLL);
            }
            // A failed accept (e.g. aborted connection) is not fatal;
            // back off briefly and keep serving.
            Err(_) => std::thread::sleep(POLL),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    let _ = std::fs::remove_file(socket_path);
    Ok(())
}

/// One connection: frames in, frames out, until EOF, corruption, or
/// shutdown. Every failure mode ends at worst this connection.
fn serve_connection(
    mut stream: UnixStream,
    store: &ModelStore,
    shutdown: &AtomicBool,
) {
    // The listener is non-blocking; the conversation must not be (on
    // some platforms accepted sockets inherit the flag). A finite
    // read timeout then keeps idle connections polling the shutdown
    // flag instead of pinning their thread forever.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match wire::read_request(&mut stream) {
            Ok(req) => {
                let (reply, quit) = handle(store, req, shutdown);
                let sent = match &reply {
                    // Fetched layers stream straight from the cache's
                    // Arc — one serialization copy, no owned clone of
                    // the weights (or plane words) on the hot path.
                    Reply::Layer(l) => match l.as_ref() {
                        ExecLayer::Materialized(d) => wire::send_layer(
                            &mut stream,
                            d.rows,
                            d.cols,
                            &d.weights,
                        ),
                        ExecLayer::Fused(f) => {
                            wire::send_fused_layer(&mut stream, f)
                        }
                    },
                    Reply::Msg(resp) => {
                        wire::send_response(&mut stream, resp)
                    }
                };
                if sent.is_err() {
                    return; // client went away mid-reply
                }
                if quit {
                    return;
                }
            }
            Err(WireError::TimedOut) => continue,
            Err(WireError::Eof) => return,
            Err(WireError::Corrupt(msg)) => {
                // Tell the peer what went wrong, then drop the
                // connection: a desynchronized stream cannot be
                // re-framed. The worker itself keeps serving.
                let _ = wire::send_response(
                    &mut stream,
                    &Response::Err { message: msg },
                );
                return;
            }
            Err(WireError::Io(_)) => return,
        }
    }
}

/// What one request produces: either an ordinary response message, or
/// a fetched layer kept behind its cache `Arc` so the send path can
/// stream it without cloning the weights.
enum Reply {
    Msg(Response),
    Layer(std::sync::Arc<ExecLayer>),
}

/// Dispatch one request against the store. Returns the reply and
/// whether the connection (and, for `Shutdown`, the worker) should
/// end.
fn handle(
    store: &ModelStore,
    req: Request,
    shutdown: &AtomicBool,
) -> (Reply, bool) {
    let msg = |resp| (Reply::Msg(resp), false);
    match req {
        Request::Fetch { layer, model, trace } => {
            // Pin the requester's trace to this thread: the cache
            // hit/miss events and any decode the get() triggers stitch
            // into the caller's cross-process timeline.
            let _trace = obs::with_trace(trace);
            // A model-scoped fetch addresses a zoo worker, whose store
            // holds the merged container's `{model}::{layer}` names.
            let layer = crate::registry::scoped_or_bare(&model, &layer);
            match store.get(&layer) {
                Ok(decoded) => {
                    // Error at the source when a layer cannot fit one
                    // wire frame: sending it anyway would be rejected
                    // receiver-side as a corrupt frame and trigger a
                    // pointless worker restart.
                    let oversized = match decoded.as_ref() {
                        ExecLayer::Materialized(d) => {
                            (d.weights.len() > wire::MAX_WIRE_WEIGHTS)
                                .then(|| {
                                    format!(
                                        "{} weights (cap {})",
                                        d.weights.len(),
                                        wire::MAX_WIRE_WEIGHTS
                                    )
                                })
                        }
                        ExecLayer::Fused(f) => {
                            let words = f.plane_words().len()
                                + f.mask_words().len();
                            (words > wire::MAX_WIRE_FUSED_WORDS)
                                .then(|| {
                                    format!(
                                        "{words} fused words (cap {})",
                                        wire::MAX_WIRE_FUSED_WORDS
                                    )
                                })
                        }
                    };
                    match oversized {
                        Some(why) => msg(Response::Err {
                            message: format!(
                                "layer {layer:?} has {why} — too \
                                 large for one wire frame"
                            ),
                        }),
                        None => (Reply::Layer(decoded), false),
                    }
                }
                Err(e) => {
                    msg(Response::Err { message: format!("{e:#}") })
                }
            }
        }
        Request::Prefetch { layer, model, trace } => {
            let _trace = obs::with_trace(trace);
            let layer = crate::registry::scoped_or_bare(&model, &layer);
            msg(Response::Ack {
                accepted: store.prefetch_async(&layer),
            })
        }
        Request::Metrics => msg(Response::Metrics(store.metrics())),
        Request::CostProfile => msg(Response::CostProfile {
            json: CostProfile::from_stores([store.costs()]).to_json(),
        }),
        Request::Stats => {
            // One-shard live view: snapshot now, serve as the same
            // JSON document the router's stats socket produces.
            let m = store.metrics();
            let costs = store.costs().snapshot();
            let name = format!("pid {}", std::process::id());
            let stores: obs::stats::StoresSource =
                Arc::new(move || vec![(name.clone(), m)]);
            let costs_src: obs::stats::CostsSource =
                Arc::new(move || costs.clone());
            let sources =
                obs::stats::LiveSources::new(stores, costs_src);
            msg(Response::Stats { json: sources.stats_json() })
        }
        Request::Events { max } => {
            let max = max.min(obs::stats::MAX_EVENT_LINES) as usize;
            msg(Response::Events {
                jsonl: obs::events::recent(max).join("\n"),
            })
        }
        Request::TraceDump => {
            // Snapshot, do not clear: the recorder is process-global,
            // and a dump must never erase spans other code in this
            // process is still accumulating. The exporter dumps once
            // at end of run, so replay is not a concern.
            msg(Response::Trace {
                pid: std::process::id(),
                events: obs::snapshot(),
            })
        }
        Request::Shutdown => {
            shutdown.store(true, Ordering::SeqCst);
            (Reply::Msg(Response::Bye), true)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::write_container_v2;
    use crate::store::test_model;

    fn temp_socket(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir()
            .join(format!("f2f-ipc-{tag}-{}.sock", std::process::id()))
    }

    /// In-thread worker: the serve loop and the wire protocol without
    /// a process fork (the fork path is covered by the integration
    /// tests and the CI smoke job).
    #[test]
    fn serve_loop_answers_every_request_kind_then_shuts_down() {
        let c = test_model(&[16, 12, 8], 90);
        let want: Vec<Vec<f32>> = c
            .layers
            .iter()
            .map(|l| {
                crate::sparse::DecodedLayer::from_compressed(l).weights
            })
            .collect();
        let bytes = write_container_v2(&c);
        let store = Arc::new(
            ModelStore::open_bytes(bytes, StoreConfig::default())
                .unwrap(),
        );
        let socket = temp_socket("serve-loop");
        let worker = {
            let store = store.clone();
            let socket = socket.clone();
            std::thread::spawn(move || serve_store(store, &socket))
        };
        // Wait for the socket to come up.
        let mut stream = loop {
            match UnixStream::connect(&socket) {
                Ok(s) => break s,
                Err(_) => std::thread::sleep(Duration::from_millis(5)),
            }
        };

        // Fetch both layers: bit-exact decoded weights over the wire.
        for (i, name) in ["fc0", "fc1"].iter().enumerate() {
            wire::send_request(
                &mut stream,
                &Request::Fetch {
                    layer: name.to_string(),
                    model: String::new(),
                    trace: 7,
                },
            )
            .unwrap();
            let resp = wire::read_response(&mut stream).unwrap();
            let layer = wire::layer_from_response(resp).unwrap();
            assert_eq!(layer.weights, want[i], "{name}");
        }
        // Unknown layer: an error frame, and the connection survives.
        wire::send_request(
            &mut stream,
            &Request::Fetch {
                layer: "ghost".into(),
                model: String::new(),
                trace: 0,
            },
        )
        .unwrap();
        match wire::read_response(&mut stream).unwrap() {
            Response::Err { message } => {
                assert!(message.contains("ghost"), "{message}")
            }
            other => panic!("expected an error frame, got {other:?}"),
        }
        // Prefetch dedups against the already-cached layer.
        wire::send_request(
            &mut stream,
            &Request::Prefetch {
                layer: "fc0".into(),
                model: String::new(),
                trace: 0,
            },
        )
        .unwrap();
        assert_eq!(
            wire::read_response(&mut stream).unwrap(),
            Response::Ack { accepted: true }
        );
        // Metrics show both decodes.
        wire::send_request(&mut stream, &Request::Metrics).unwrap();
        match wire::read_response(&mut stream).unwrap() {
            Response::Metrics(m) => {
                assert_eq!(m.decodes, 2);
                assert_eq!(m.redundant_decodes, 0);
            }
            other => panic!("expected metrics, got {other:?}"),
        }
        // The cost profile crosses the wire through the validated
        // JSON parser.
        wire::send_request(&mut stream, &Request::CostProfile)
            .unwrap();
        match wire::read_response(&mut stream).unwrap() {
            Response::CostProfile { json } => {
                let profile =
                    CostProfile::parse_json(&json).unwrap();
                assert_eq!(profile.len(), 2);
                assert!(
                    profile.get("fc0").unwrap().decode_samples > 0
                );
            }
            other => panic!("expected a profile, got {other:?}"),
        }
        // The live-stats frame carries a one-shard JSON snapshot that
        // parses with the hardened reader.
        wire::send_request(&mut stream, &Request::Stats).unwrap();
        match wire::read_response(&mut stream).unwrap() {
            Response::Stats { json } => {
                let snap =
                    crate::obs::stats::StatsSnapshot::parse_json(&json)
                        .unwrap();
                assert_eq!(snap.shards.len(), 1);
                assert_eq!(
                    crate::obs::stats::field(
                        &snap.shards[0].1,
                        "decodes"
                    ),
                    2.0
                );
            }
            other => panic!("expected a stats frame, got {other:?}"),
        }
        // The journal tail rides the events frame.
        crate::obs::events::set_stderr_mirror(false);
        crate::obs::events::warn("worker_unit_probe", "probe", &[]);
        wire::send_request(
            &mut stream,
            &Request::Events { max: 4096 },
        )
        .unwrap();
        match wire::read_response(&mut stream).unwrap() {
            Response::Events { jsonl } => {
                assert!(jsonl.contains("worker_unit_probe"), "{jsonl}")
            }
            other => panic!("expected an events frame, got {other:?}"),
        }
        // A trace dump names this process; with recording compiled
        // in, the fetches above left spans under their request trace.
        wire::send_request(&mut stream, &Request::TraceDump).unwrap();
        match wire::read_response(&mut stream).unwrap() {
            Response::Trace { pid, events } => {
                assert_eq!(pid, std::process::id());
                #[cfg(feature = "obs")]
                assert!(
                    events.iter().any(|e| e.trace_id == 7),
                    "fetch spans must carry the frame's trace id"
                );
                #[cfg(not(feature = "obs"))]
                assert!(events.is_empty());
            }
            other => panic!("expected a trace dump, got {other:?}"),
        }
        // Shutdown ends the loop; the socket file is removed.
        wire::send_request(&mut stream, &Request::Shutdown).unwrap();
        assert_eq!(
            wire::read_response(&mut stream).unwrap(),
            Response::Bye
        );
        worker.join().unwrap().unwrap();
        assert!(!socket.exists(), "socket removed on clean exit");
    }

    #[test]
    fn fused_store_ships_fused_frames_bit_exact() {
        // A fused-mode worker answers Fetch with the bit-plane frame;
        // the exec-layer conversion on the receiving side reproduces
        // the materialized decode bit-for-bit.
        let c = test_model(&[64, 8], 93);
        let want =
            crate::sparse::DecodedLayer::from_compressed(&c.layers[0])
                .weights;
        let bytes = write_container_v2(&c);
        let store = Arc::new(
            ModelStore::open_bytes(
                bytes,
                StoreConfig {
                    decode_mode: crate::kernels::DecodeMode::Fused,
                    ..StoreConfig::default()
                },
            )
            .unwrap(),
        );
        let socket = temp_socket("fused-serve");
        let worker = {
            let store = store.clone();
            let socket = socket.clone();
            std::thread::spawn(move || serve_store(store, &socket))
        };
        let mut stream = loop {
            match UnixStream::connect(&socket) {
                Ok(s) => break s,
                Err(_) => std::thread::sleep(Duration::from_millis(5)),
            }
        };
        wire::send_request(
            &mut stream,
            &Request::Fetch {
                layer: "fc0".into(),
                model: String::new(),
                trace: 0,
            },
        )
        .unwrap();
        let resp = wire::read_response(&mut stream).unwrap();
        assert!(
            matches!(resp, Response::FusedLayer { .. }),
            "fused store must ship the fused frame, got {resp:?}"
        );
        // The dense form must reject the fused frame explicitly...
        let fused_err =
            wire::layer_from_response(resp.clone()).unwrap_err();
        assert!(format!("{fused_err:#}").contains("expected a layer"));
        // ...while the exec conversion executes it bit-exactly.
        let exec = wire::exec_layer_from_response(resp).unwrap();
        assert!(exec.is_fused());
        assert_eq!(exec.dense_weights(), want);
        wire::send_request(&mut stream, &Request::Shutdown).unwrap();
        let _ = wire::read_response(&mut stream);
        worker.join().unwrap().unwrap();
    }

    #[test]
    fn garbage_bytes_close_one_connection_not_the_worker() {
        let c = test_model(&[16, 12], 91);
        let bytes = write_container_v2(&c);
        let store = Arc::new(
            ModelStore::open_bytes(bytes, StoreConfig::default())
                .unwrap(),
        );
        let socket = temp_socket("garbage");
        let worker = {
            let store = store.clone();
            let socket = socket.clone();
            std::thread::spawn(move || serve_store(store, &socket))
        };
        let mut stream = loop {
            match UnixStream::connect(&socket) {
                Ok(s) => break s,
                Err(_) => std::thread::sleep(Duration::from_millis(5)),
            }
        };
        use std::io::Write;
        stream.write_all(b"this is definitely not a frame").unwrap();
        stream.flush().unwrap();
        // The worker replies with an error frame (or just closes);
        // either way the *next* connection must serve normally.
        let _ = wire::read_response(&mut stream);
        drop(stream);
        let mut fresh = UnixStream::connect(&socket).unwrap();
        wire::send_request(
            &mut fresh,
            &Request::Fetch {
                layer: "fc0".into(),
                model: String::new(),
                trace: 0,
            },
        )
        .unwrap();
        let resp = wire::read_response(&mut fresh).unwrap();
        assert!(wire::layer_from_response(resp).is_ok());
        wire::send_request(&mut fresh, &Request::Shutdown).unwrap();
        let _ = wire::read_response(&mut fresh);
        worker.join().unwrap().unwrap();
    }
}

//! `IpcShardStore`: the client side of one shard worker.
//!
//! A thin, reconnecting stub over the wire protocol. One instance per
//! worker; the connection dials lazily, survives across calls, and is
//! dropped on any transport failure so the next call redials — which
//! is exactly what makes a supervisor restart transparent: the worker
//! comes back on the same socket path, and the store's next call
//! simply connects to the new process.
//!
//! Errors are split in two ([`IpcCallError`]): a **remote** error is
//! the worker answering "no" (unknown layer, corrupt record) — the
//! worker is healthy and restarting it would not help; a **transport**
//! error means the conversation itself failed (dead socket, corrupt
//! frame, unexpected kind) — the signal the
//! [`ProcRouter`](super::ProcRouter) feeds to the supervisor's revive
//! path.

use super::wire::{self, Request, Response};
use crate::kernels::ExecLayer;
use crate::obs::{self, SpanEvent, SpanKind};
use crate::shard::CostProfile;
use crate::sync::lock_unpoisoned;
use crate::store::StoreMetrics;
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Duration;

/// Default per-call I/O timeout: generous enough for a cold decode of
/// any layer this crate serves, finite so a hung worker surfaces as a
/// transport error the supervisor can act on instead of a hang.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(30);

/// How one IPC call failed.
#[derive(Debug)]
pub enum IpcCallError {
    /// The conversation failed: dead socket, corrupt frame, timeout,
    /// or a response of the wrong kind. Worth a worker health check.
    Transport(String),
    /// The worker answered with an error frame: it is alive, the
    /// request itself was bad (unknown layer, rotten record).
    Remote(String),
}

impl IpcCallError {
    /// True for failures where restarting the worker could help.
    pub fn is_transport(&self) -> bool {
        matches!(self, IpcCallError::Transport(_))
    }
}

impl std::fmt::Display for IpcCallError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IpcCallError::Transport(m) => {
                write!(f, "ipc transport failure: {m}")
            }
            IpcCallError::Remote(m) => write!(f, "worker error: {m}"),
        }
    }
}

impl std::error::Error for IpcCallError {}

type CallResult<T> = std::result::Result<T, IpcCallError>;

/// Client stub for one shard worker's socket.
pub struct IpcShardStore {
    socket_path: PathBuf,
    conn: Mutex<Option<UnixStream>>,
    io_timeout: Duration,
}

impl IpcShardStore {
    /// A stub for `socket_path`. Dials lazily on the first call, so
    /// constructing one before the worker is up is fine.
    pub fn connect(socket_path: impl Into<PathBuf>) -> Self {
        IpcShardStore {
            socket_path: socket_path.into(),
            conn: Mutex::new(None),
            io_timeout: DEFAULT_IO_TIMEOUT,
        }
    }

    /// Override the per-call I/O timeout (builder style).
    pub fn with_io_timeout(mut self, timeout: Duration) -> Self {
        self.io_timeout = timeout;
        self
    }

    /// The worker's socket path.
    pub fn socket_path(&self) -> &Path {
        &self.socket_path
    }

    fn dial(&self) -> CallResult<UnixStream> {
        let stream =
            UnixStream::connect(&self.socket_path).map_err(|e| {
                IpcCallError::Transport(format!(
                    "connecting {}: {e}",
                    self.socket_path.display()
                ))
            })?;
        let _ = stream.set_read_timeout(Some(self.io_timeout));
        let _ = stream.set_write_timeout(Some(self.io_timeout));
        Ok(stream)
    }

    /// One request/response round trip. Holds the connection lock for
    /// the duration, so concurrent callers serialize cleanly; any
    /// transport failure drops the connection and the next call
    /// redials (the restart-transparency contract).
    fn call(&self, req: &Request) -> CallResult<Response> {
        let mut guard = lock_unpoisoned(&self.conn);
        let mut stream = match guard.take() {
            Some(s) => s,
            None => self.dial()?,
        };
        let result = wire::send_request(&mut stream, req)
            .map_err(|e| {
                IpcCallError::Transport(format!("send failed: {e}"))
            })
            .and_then(|()| {
                wire::read_response(&mut stream).map_err(|e| {
                    IpcCallError::Transport(format!("{e}"))
                })
            });
        match result {
            Ok(Response::Err { message }) => {
                // The worker is healthy; keep the connection.
                *guard = Some(stream);
                Err(IpcCallError::Remote(message))
            }
            Ok(resp) => {
                *guard = Some(stream);
                Ok(resp)
            }
            Err(e) => Err(e), // connection dropped; next call redials
        }
    }

    /// Drop the cached connection (the next call redials). The
    /// supervisor calls this after replacing a worker process.
    pub fn disconnect(&self) {
        *lock_unpoisoned(&self.conn) = None;
    }

    /// Fetch one decoded layer from the worker, in whichever
    /// representation the worker's store caches (dense or fused —
    /// both execute behind the same [`ExecLayer`] surface,
    /// bit-identically). The caller's trace id rides the frame so the
    /// worker's decode spans stitch into the same timeline; the round
    /// trip itself is recorded as an `ipc_fetch` span on this side.
    pub fn fetch(&self, layer: &str) -> CallResult<ExecLayer> {
        self.fetch_model("", layer)
    }

    /// [`fetch`](Self::fetch) scoped to one model of a zoo worker: the
    /// model id rides the frame's trailing byte range and the worker
    /// joins `{model}::{layer}` before its store lookup. `""` is the
    /// unscoped single-model form (byte-identical frames to before).
    pub fn fetch_model(
        &self,
        model: &str,
        layer: &str,
    ) -> CallResult<ExecLayer> {
        let start = std::time::Instant::now();
        let resp = self.call(&Request::Fetch {
            layer: layer.to_string(),
            model: model.to_string(),
            trace: obs::current_trace(),
        })?;
        obs::span(SpanKind::IpcFetch, layer, start.elapsed());
        wire::exec_layer_from_response(resp)
            .map_err(|e| IpcCallError::Transport(format!("{e:#}")))
    }

    /// Ask the worker to warm a layer asynchronously; returns whether
    /// the readahead was accepted.
    pub fn prefetch(&self, layer: &str) -> CallResult<bool> {
        self.prefetch_model("", layer)
    }

    /// [`prefetch`](Self::prefetch) scoped to one model of a zoo
    /// worker (`""` = unscoped).
    pub fn prefetch_model(
        &self,
        model: &str,
        layer: &str,
    ) -> CallResult<bool> {
        let start = std::time::Instant::now();
        let resp = self.call(&Request::Prefetch {
            layer: layer.to_string(),
            model: model.to_string(),
            trace: obs::current_trace(),
        })?;
        obs::span(SpanKind::IpcPrefetch, layer, start.elapsed());
        match resp {
            Response::Ack { accepted } => Ok(accepted),
            other => Err(IpcCallError::Transport(format!(
                "expected an ack, got {other:?}"
            ))),
        }
    }

    /// Snapshot the worker store's metrics.
    pub fn metrics(&self) -> CallResult<StoreMetrics> {
        match self.call(&Request::Metrics)? {
            Response::Metrics(m) => Ok(m),
            other => Err(IpcCallError::Transport(format!(
                "expected metrics, got {other:?}"
            ))),
        }
    }

    /// Snapshot the worker store's observed cost table.
    pub fn cost_profile(&self) -> CallResult<CostProfile> {
        match self.call(&Request::CostProfile)? {
            Response::CostProfile { json } => {
                CostProfile::parse_json(&json).map_err(|e| {
                    IpcCallError::Transport(format!(
                        "unparseable cost profile: {e:#}"
                    ))
                })
            }
            other => Err(IpcCallError::Transport(format!(
                "expected a cost profile, got {other:?}"
            ))),
        }
    }

    /// Snapshot the worker's span recorder: its pid plus every event
    /// it currently retains. The trace exporter stitches these into
    /// the cross-process Chrome trace, one lane per pid.
    pub fn trace_events(&self) -> CallResult<(u32, Vec<SpanEvent>)> {
        match self.call(&Request::TraceDump)? {
            Response::Trace { pid, events } => Ok((pid, events)),
            other => Err(IpcCallError::Transport(format!(
                "expected a trace dump, got {other:?}"
            ))),
        }
    }

    /// One live-stats poll: the raw JSON document described in
    /// [`crate::obs::stats`]. Workers answer with a single-shard
    /// snapshot of their own store; stats sockets answer with the
    /// merged serving-process view.
    pub fn stats_json(&self) -> CallResult<String> {
        match self.call(&Request::Stats)? {
            Response::Stats { json } => Ok(json),
            other => Err(IpcCallError::Transport(format!(
                "expected a stats frame, got {other:?}"
            ))),
        }
    }

    /// The newest `max` lines of the peer's event journal, as JSONL.
    pub fn events_tail(&self, max: u32) -> CallResult<String> {
        match self.call(&Request::Events { max })? {
            Response::Events { jsonl } => Ok(jsonl),
            other => Err(IpcCallError::Transport(format!(
                "expected an events frame, got {other:?}"
            ))),
        }
    }

    /// True when the worker answers a metrics round trip — the health
    /// probe the supervisor polls.
    pub fn ping(&self) -> bool {
        self.metrics().is_ok()
    }

    /// Ask the worker to exit cleanly.
    pub fn shutdown(&self) -> CallResult<()> {
        match self.call(&Request::Shutdown)? {
            Response::Bye => {
                self.disconnect();
                Ok(())
            }
            other => Err(IpcCallError::Transport(format!(
                "expected bye, got {other:?}"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::write_container_v2;
    use crate::store::{test_model, ModelStore, StoreConfig};
    use std::sync::Arc;

    #[test]
    fn client_round_trips_against_an_in_thread_worker() {
        let c = test_model(&[16, 12, 8], 92);
        let want =
            crate::sparse::DecodedLayer::from_compressed(&c.layers[0])
                .weights
                .clone();
        let bytes = write_container_v2(&c);
        let store = Arc::new(
            ModelStore::open_bytes(bytes, StoreConfig::default())
                .unwrap(),
        );
        let socket = std::env::temp_dir().join(format!(
            "f2f-ipc-client-{}.sock",
            std::process::id()
        ));
        let worker = {
            let store = store.clone();
            let socket = socket.clone();
            std::thread::spawn(move || {
                crate::ipc::serve_store(store, &socket)
            })
        };
        let client = IpcShardStore::connect(&socket)
            .with_io_timeout(Duration::from_secs(10));
        // Lazy dial retries (bounded) until the worker binds.
        let deadline =
            std::time::Instant::now() + Duration::from_secs(10);
        let layer = loop {
            match client.fetch("fc0") {
                Ok(l) => break l,
                Err(e) if e.is_transport() => {
                    assert!(
                        std::time::Instant::now() < deadline,
                        "worker did not come up within 10s: {e}"
                    );
                    std::thread::sleep(Duration::from_millis(5))
                }
                Err(e) => panic!("remote error: {e}"),
            }
        };
        assert_eq!(layer.dense_weights(), want);
        // A remote error keeps the connection (and is not transport).
        let err = client.fetch("ghost").unwrap_err();
        assert!(!err.is_transport(), "{err}");
        assert!(client.prefetch("fc1").unwrap());
        assert!(client.ping());
        let m = client.metrics().unwrap();
        assert!(m.decodes >= 1);
        let profile = client.cost_profile().unwrap();
        assert!(profile.get("fc0").is_some());
        // The worker runs in-thread here, so its trace dump reports
        // this very process.
        let (pid, _events) = client.trace_events().unwrap();
        assert_eq!(pid, std::process::id());
        client.shutdown().unwrap();
        worker.join().unwrap().unwrap();
        // With the worker gone, calls degrade to transport errors.
        assert!(client.fetch("fc0").unwrap_err().is_transport());
        assert!(!client.ping());
    }
}

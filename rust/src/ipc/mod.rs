//! Multi-process sharded serving: shard workers behind unix sockets.
//!
//! The paper's fixed-to-fixed encoding makes every layer's compressed
//! record a regular, independently addressable unit — which is what
//! already let [`crate::shard`] split one model across N in-process
//! stores. This module pushes the same partition past the
//! single-address-space limit: each shard is served by its **own OS
//! process** (own mmap, own decode service, own budget, own cost
//! table), and the forward chain routes over IPC.
//!
//! The pieces, bottom up:
//!
//! * [`wire`] — a hand-rolled, length-prefixed frame protocol over
//!   `std::os::unix::net` (pure std, consistent with the offline
//!   no-new-crates constraint): versioned header,
//!   `Fetch`/`Prefetch`/`Metrics`/`CostProfile`/`Shutdown` request
//!   kinds, error frames on both sides — corrupt bytes are errors,
//!   never panics, never unbounded allocations. Fetched layers cross
//!   in the representation the worker's store caches: dense weight
//!   frames, or fused bit-plane frames (`--decode-mode fused|auto`)
//!   that the router executes without materializing dense f32.
//! * [`run_worker`] / [`serve_store`] — the `f2f shard-worker`
//!   child-process entrypoint: one [`crate::store::ModelStore`]
//!   (cost-sidecar warm-started) behind a `UnixListener`.
//! * [`IpcShardStore`] — the reconnecting client stub for one worker.
//! * [`ProcRouter`] — a [`crate::coordinator::Backend`] that walks
//!   the chain across workers, bit-identical to the single-store
//!   [`crate::store::ModelBackend`], driving *cross-process*
//!   readahead: layer `i+1` warms on its worker's decode service
//!   while layer `i`'s GEMV runs in the router process.
//! * [`Supervisor`] — spawns workers via `std::process::Command`,
//!   health-checks them, restarts a crashed worker with its shard
//!   assignment replayed, and (with the router) aggregates
//!   [`crate::shard::ShardMetrics`] and
//!   [`crate::shard::CostProfile`] over the wire so `--timing`,
//!   `--profile-out` and `f2f rebalance` work unchanged in
//!   multi-process mode.
//!
//! Surface: `f2f serve --shard-procs N`. Unix-only (unix domain
//! sockets); the module is compiled out elsewhere and the CLI reports
//! that plainly.

mod client;
mod router;
mod supervisor;
pub mod wire;
mod worker;

pub use client::{IpcCallError, IpcShardStore, DEFAULT_IO_TIMEOUT};
pub use router::ProcRouter;
pub use supervisor::{Supervisor, WorkerSpec};
pub use worker::{run_worker, serve_store};

//! `ProcRouter`: the multi-process [`Backend`] for split models.
//!
//! The cross-process sibling of [`crate::shard::ShardRouter`]: the
//! same chain walk, the same GEMV inner loop, the same readahead
//! shape — but every layer fetch crosses a process boundary to the
//! worker owning that shard, and every readahead warms on the target
//! worker's *own* decode service. Layers arrive in whichever
//! representation the worker's store caches — dense weight frames, or
//! fused bit-plane frames under `--decode-mode fused`/`auto` — and
//! outputs are bit-identical to the single-store
//! [`crate::store::ModelBackend`] either way, because both
//! [`ExecLayer`] forms accumulate the same f32 terms in the same
//! order and the ReLU loop is the same code shape.
//!
//! Telemetry mirrors the in-process router: GEMV phases are stamped
//! into a router-local [`LayerCosts`] table (workers never run a
//! GEMV), decode estimates are pulled from the workers' tables over
//! the wire ([`ProcRouter::refresh_costs`], automatic after each pass
//! under the `Auto` policy), and [`ProcRouter::cost_profile`] merges
//! both — so `--timing`, `--profile-out` and `f2f rebalance` work
//! unchanged in multi-process mode. The `Auto` planner runs on those
//! estimates; per-store budget admission stays worker-side (the
//! worker's `prefetch_async` is the final gatekeeper, exactly as the
//! store is for the in-process planner). Request traces cross the
//! boundary too: every `Fetch`/`Prefetch` frame carries the current
//! trace id ([`crate::obs`]), so a worker's decode spans land in the
//! same timeline as the router's GEMV and `ipc_fetch` spans.
//!
//! Fault handling: a *remote* error (unknown layer, rotten record)
//! propagates to the batch like any backend error. A *transport*
//! error asks the [`Supervisor`] to revive the worker — reconnect if
//! it is alive, respawn with the replayed shard assignment if not —
//! and retries the fetch once against the fresh process.

use super::client::{IpcCallError, IpcShardStore};
use super::supervisor::Supervisor;
use crate::container::{ContainerIndex, ShardMap};
use crate::coordinator::Backend;
use crate::kernels::ExecLayer;
use crate::obs;
use crate::shard::{CostProfile, ShardMetrics};
use crate::store::wrapped_targets;
use crate::store::{
    LayerCost, LayerCosts, ReadaheadCandidate, ReadaheadPolicy,
    StoreMetrics,
};
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;
use std::time::Instant;

/// One step of the forward chain: the layer and the worker owning it.
struct ChainLink {
    name: String,
    shard: usize,
}

/// A sequential GEMV chain served from N shard-worker *processes*.
pub struct ProcRouter {
    clients: Vec<Arc<IpcShardStore>>,
    supervisor: Option<Arc<Supervisor>>,
    chain: Vec<ChainLink>,
    readahead: ReadaheadPolicy,
    /// Router-local cost table: GEMV EWMAs stamped here per pass,
    /// decode EWMAs seeded from the workers' tables over the wire.
    costs: Arc<LayerCosts>,
    input_dim: usize,
    output_dim: usize,
}

impl ProcRouter {
    /// Build a router over per-worker client stubs (`clients[i]`
    /// talks to the worker serving shard `i` of `map`). Chain
    /// geometry is validated against the original container's index —
    /// the map and the index travel together, exactly as they do for
    /// the in-process router's stores.
    pub fn new(
        clients: Vec<Arc<IpcShardStore>>,
        map: &ShardMap,
        index: &ContainerIndex,
    ) -> Result<Self> {
        if map.n_shards() != clients.len() {
            bail!(
                "shard map names {} shards but {} worker clients were \
                 supplied",
                map.n_shards(),
                clients.len()
            );
        }
        if map.is_empty() {
            bail!("shard map assigns no layers");
        }
        let mut chain = Vec::with_capacity(map.len());
        let mut dims = Vec::with_capacity(map.len());
        for (name, shard) in map.assignments() {
            let Some(e) = index.find(name) else {
                bail!(
                    "layer {name:?} is in the shard map but not the \
                     container index — stale map?"
                );
            };
            dims.push((e.rows, e.cols));
            chain.push(ChainLink { name: name.clone(), shard: *shard });
        }
        let names: Vec<&str> =
            chain.iter().map(|l| l.name.as_str()).collect();
        let (input_dim, output_dim) =
            crate::store::validate_chain(&names, &dims)?;
        Ok(ProcRouter {
            clients,
            supervisor: None,
            chain,
            readahead: ReadaheadPolicy::default(),
            costs: Arc::new(LayerCosts::new()),
            input_dim,
            output_dim,
        })
    }

    /// Attach the supervisor whose revive path repairs transport
    /// failures (builder style). Without one, a dead worker is a
    /// batch error instead of a restart.
    pub fn with_supervisor(mut self, sup: Arc<Supervisor>) -> Self {
        self.supervisor = Some(sup);
        self
    }

    /// Replace the readahead policy (builder style).
    pub fn with_readahead(mut self, policy: ReadaheadPolicy) -> Self {
        self.readahead = policy;
        self
    }

    /// The active readahead policy.
    pub fn readahead(&self) -> ReadaheadPolicy {
        self.readahead
    }

    /// Layer names in forward order.
    pub fn chain(&self) -> Vec<&str> {
        self.chain.iter().map(|l| l.name.as_str()).collect()
    }

    /// The router-local cost table (shareable: clone the `Arc` before
    /// moving the router behind a server to keep reading GEMV
    /// telemetry).
    pub fn costs(&self) -> &Arc<LayerCosts> {
        &self.costs
    }

    /// Pull every worker's observed decode costs into the local table
    /// (the estimates the `Auto` planner reads). Runs automatically
    /// after each pass under the `Auto` policy; errors are reported
    /// but a failed refresh only means a staler plan.
    pub fn refresh_costs(&self) -> Result<()> {
        for client in &self.clients {
            let profile = client
                .cost_profile()
                .map_err(|e| anyhow!("{e}"))?;
            for (name, cost) in profile.entries() {
                if cost.decode_samples == 0 {
                    continue;
                }
                // Seed only the decode dimension: GEMV telemetry is
                // observed locally, and worker tables never carry it.
                self.costs.seed(
                    &name,
                    LayerCost {
                        decode_ns: cost.decode_ns,
                        decode_samples: cost.decode_samples,
                        ..Default::default()
                    },
                );
            }
        }
        Ok(())
    }

    /// Merge the workers' cost tables with router-local GEMV
    /// telemetry into one model-wide [`CostProfile`] — the exact
    /// input `f2f rebalance` consumes, now gathered across processes.
    pub fn cost_profile(&self) -> Result<CostProfile> {
        Self::merged_profile(&self.clients, &self.costs)
    }

    /// The profile merge shared by [`ProcRouter::cost_profile`] and
    /// the CLI teardown path (which holds the clients and the local
    /// table after the router moved behind the server).
    pub fn merged_profile(
        clients: &[Arc<IpcShardStore>],
        local: &LayerCosts,
    ) -> Result<CostProfile> {
        let mut profile = CostProfile::new();
        for client in clients {
            let worker =
                client.cost_profile().map_err(|e| anyhow!("{e}"))?;
            for (name, cost) in worker.entries() {
                profile.record(&name, cost);
            }
        }
        for (name, cost) in local.snapshot() {
            // Only the locally observed dimension: the decode entries
            // in the local table are re-seeded copies of the worker
            // tables and would double-count.
            if cost.gemv_samples > 0 {
                profile.record(
                    &name,
                    LayerCost {
                        gemv_ns: cost.gemv_ns,
                        gemv_samples: cost.gemv_samples,
                        ..Default::default()
                    },
                );
            }
        }
        Ok(profile)
    }

    /// Aggregate metrics across every worker, over the wire — the
    /// multi-process counterpart of
    /// [`crate::shard::ShardRouter::metrics`].
    pub fn metrics(&self) -> Result<ShardMetrics> {
        let mut per_shard = Vec::with_capacity(self.clients.len());
        for client in &self.clients {
            per_shard
                .push(client.metrics().map_err(|e| anyhow!("{e}"))?);
        }
        let mut total = StoreMetrics::default();
        for m in &per_shard {
            total.merge(m);
        }
        Ok(ShardMetrics {
            per_shard,
            total,
            costs: self.cost_profile()?.entries(),
        })
    }

    /// Fetch one chain layer from its worker, repairing a transport
    /// failure through the supervisor once: revive (reconnect or
    /// respawn with the replayed shard assignment) and retry.
    fn fetch(&self, idx: usize) -> Result<ExecLayer> {
        let link = &self.chain[idx];
        let client = &self.clients[link.shard];
        match client.fetch(&link.name) {
            Ok(layer) => Ok(layer),
            Err(IpcCallError::Remote(msg)) => Err(anyhow!(
                "worker {} rejected layer {:?}: {msg}",
                link.shard,
                link.name
            )),
            Err(IpcCallError::Transport(msg)) => {
                let Some(sup) = &self.supervisor else {
                    bail!(
                        "worker {} unreachable fetching {:?}: {msg}",
                        link.shard,
                        link.name
                    );
                };
                sup.revive(link.shard)?;
                client.fetch(&link.name).map_err(|e| {
                    anyhow!(
                        "worker {} still failing after restart \
                         fetching {:?}: {e}",
                        link.shard,
                        link.name
                    )
                })
            }
        }
    }

    /// Decide how deep layer `i`'s cross-process readahead warms —
    /// the same planner as the in-process chain
    /// ([`ReadaheadPolicy::plan`]), fed from the router-local
    /// estimates. Budget admission is left to the target worker's
    /// store (its `prefetch_async` declines what cannot fit), so
    /// candidates here always claim to fit.
    fn planned_depth(&self, i: usize, batch_items: usize) -> usize {
        let len = self.chain.len();
        let cap = self.readahead.max_depth().min(len.saturating_sub(1));
        if cap == 0 {
            return 0;
        }
        if !self.readahead.is_auto() {
            return cap;
        }
        let window = self
            .costs
            .get(&self.chain[i].name)
            .and_then(|c| c.gemv_estimate())
            .map(|per_item| per_item * batch_items as f64);
        let candidates: Vec<ReadaheadCandidate> = (1..=cap)
            .map(|d| {
                let target = &self.chain[(i + d) % len];
                ReadaheadCandidate {
                    decode_ns: self
                        .costs
                        .get(&target.name)
                        .and_then(|c| c.decode_estimate()),
                    fits_budget: true,
                }
            })
            .collect();
        self.readahead.plan(window, &candidates)
    }
}

impl Backend for ProcRouter {
    fn forward_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        // Callers entering outside a server-minted trace still get a
        // connected timeline; the id rides every Fetch/Prefetch frame
        // so worker-side spans stitch into the same trace.
        let _trace = obs::ensure_trace();
        let mut acts: Vec<Vec<f32>> = xs.to_vec();
        let Some(last) = self.chain.len().checked_sub(1) else {
            return Ok(acts); // empty chain: the constructor rejects this
        };
        // One scratch output reused across every layer × batch item,
        // mirroring the in-process chain's buffer reuse.
        let mut scratch: Vec<f32> = Vec::new();
        for i in 0..self.chain.len() {
            let layer = self.fetch(i)?;
            // Warm upcoming layers on *their* worker's decode service
            // while this layer's GEMVs run here. Declined or failed
            // warms only cost overlap, never correctness.
            let depth = self.planned_depth(i, acts.len());
            if depth > 0 {
                obs::event(
                    obs::SpanKind::ReadaheadPlan,
                    &self.chain[i].name,
                );
            }
            for t in wrapped_targets(i, self.chain.len(), depth) {
                let target = &self.chain[t];
                let _ =
                    self.clients[target.shard].prefetch(&target.name);
            }
            let gemv_start = Instant::now();
            for a in acts.iter_mut() {
                layer.gemv_into(a, &mut scratch);
                if i < last {
                    for v in &mut scratch {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
                std::mem::swap(a, &mut scratch);
            }
            let gemv_took = gemv_start.elapsed();
            obs::span(
                obs::SpanKind::Gemv,
                &self.chain[i].name,
                gemv_took,
            );
            self.costs.record_gemv(
                &self.chain[i].name,
                gemv_took,
                acts.len(),
            );
        }
        if self.readahead.is_auto() {
            // Pull the workers' freshly observed decode EWMAs so the
            // next pass plans on them; a failed refresh only stales
            // the plan.
            let _ = self.refresh_costs();
        }
        Ok(acts)
    }

    fn input_dim(&self) -> usize {
        self.input_dim
    }

    fn output_dim(&self) -> usize {
        self.output_dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::{write_sharded, ShardAssignment};
    use crate::store::{test_model, ModelStore, StoreConfig};
    use std::sync::Arc;

    /// In-thread workers over real unix sockets: the full IPC path
    /// minus the process fork (covered by rust/tests/ipc_serving.rs).
    struct ThreadWorkers {
        clients: Vec<Arc<IpcShardStore>>,
        handles: Vec<std::thread::JoinHandle<Result<()>>>,
    }

    impl ThreadWorkers {
        fn start(
            tag: &str,
            shard_bytes: Vec<Vec<u8>>,
            config: StoreConfig,
        ) -> Self {
            let mut clients = Vec::new();
            let mut handles = Vec::new();
            for (i, bytes) in shard_bytes.into_iter().enumerate() {
                let socket = std::env::temp_dir().join(format!(
                    "f2f-ipc-router-{tag}-{i}-{}.sock",
                    std::process::id()
                ));
                let store = Arc::new(
                    ModelStore::open_bytes(bytes, config).unwrap(),
                );
                let s = socket.clone();
                handles.push(std::thread::spawn(move || {
                    crate::ipc::serve_store(store, &s)
                }));
                clients.push(Arc::new(
                    IpcShardStore::connect(&socket).with_io_timeout(
                        std::time::Duration::from_secs(10),
                    ),
                ));
            }
            // Bounded wait until every worker answers (a bind
            // failure must fail the test, not hang it).
            let deadline = std::time::Instant::now()
                + std::time::Duration::from_secs(10);
            for c in &clients {
                while !c.ping() {
                    assert!(
                        std::time::Instant::now() < deadline,
                        "in-thread worker did not come up within 10s"
                    );
                    std::thread::sleep(
                        std::time::Duration::from_millis(5),
                    );
                }
            }
            ThreadWorkers { clients, handles }
        }

        fn stop(self) {
            for c in &self.clients {
                let _ = c.shutdown();
            }
            for h in self.handles {
                let _ = h.join();
            }
        }
    }

    #[test]
    fn proc_router_matches_single_store_bit_exact() {
        let c = test_model(&[20, 16, 12, 8], 93);
        let bytes = crate::container::write_container_v2(&c);
        let index = ContainerIndex::parse(&bytes).unwrap();
        let xs: Vec<Vec<f32>> = (0..3)
            .map(|i| {
                (0..20).map(|j| ((i * j) as f32 * 0.1).sin()).collect()
            })
            .collect();
        let single = Arc::new(
            ModelStore::open_bytes(
                bytes.clone(),
                StoreConfig::default(),
            )
            .unwrap(),
        );
        let want = crate::store::ModelBackend::sequential(single)
            .unwrap()
            .forward_batch(&xs)
            .unwrap();

        let (map, shard_bytes) =
            write_sharded(&c, 2, ShardAssignment::ByBytes).unwrap();
        let workers = ThreadWorkers::start(
            "bitexact",
            shard_bytes,
            StoreConfig::default(),
        );
        let mut router = ProcRouter::new(
            workers.clients.clone(),
            &map,
            &index,
        )
        .unwrap()
        .with_readahead(ReadaheadPolicy::layers(1));
        assert_eq!(router.input_dim(), 20);
        assert_eq!(router.output_dim(), 8);
        assert_eq!(router.chain(), vec!["fc0", "fc1", "fc2"]);
        let got = router.forward_batch(&xs).unwrap();
        assert_eq!(got, want, "IPC serving must be bit-exact");

        // Aggregated metrics and cost profile come back over the wire.
        let m = router.metrics().unwrap();
        assert_eq!(m.per_shard.len(), 2);
        assert_eq!(m.total.decodes, 3, "each layer decodes once");
        assert_eq!(m.total.redundant_decodes, 0);
        let profile = router.cost_profile().unwrap();
        for name in ["fc0", "fc1", "fc2"] {
            let cost = profile.get(name).unwrap();
            assert!(cost.decode_samples > 0, "{name}: worker decode");
            assert!(cost.gemv_samples > 0, "{name}: local gemv");
        }
        workers.stop();
    }

    #[test]
    fn auto_policy_plans_from_refreshed_costs_and_stays_bit_exact() {
        let c = test_model(&[20, 16, 12, 8], 94);
        let bytes = crate::container::write_container_v2(&c);
        let index = ContainerIndex::parse(&bytes).unwrap();
        let xs = vec![vec![0.25f32; 20]];
        let (map, shard_bytes) =
            write_sharded(&c, 2, ShardAssignment::RoundRobin).unwrap();
        let workers = ThreadWorkers::start(
            "auto",
            shard_bytes,
            StoreConfig::default(),
        );
        let mut outs = Vec::new();
        for policy in
            [ReadaheadPolicy::off(), ReadaheadPolicy::auto()]
        {
            let mut router = ProcRouter::new(
                workers.clients.clone(),
                &map,
                &index,
            )
            .unwrap()
            .with_readahead(policy);
            // Multiple passes: the auto pass after the first runs on
            // refreshed worker decode estimates + local gemv EWMAs.
            let first = router.forward_batch(&xs).unwrap();
            let second = router.forward_batch(&xs).unwrap();
            assert_eq!(first, second, "{policy}: passes agree");
            if policy.is_auto() {
                assert!(
                    router
                        .costs()
                        .get("fc0")
                        .is_some_and(|c| c.decode_samples > 0),
                    "auto refresh must pull worker decode estimates"
                );
            }
            outs.push(first);
        }
        assert_eq!(outs[0], outs[1], "policy never changes outputs");
        workers.stop();
    }

    #[test]
    fn fused_workers_match_materialized_bit_exact() {
        // The same chain served twice over IPC — workers materialized,
        // then fused — must produce bit-identical batches: the fused
        // frame crosses the wire and executes without ever building
        // the dense buffer, yet accumulates the same f32 terms in the
        // same order.
        let c = test_model(&[64, 32, 8], 97);
        let bytes = crate::container::write_container_v2(&c);
        let index = ContainerIndex::parse(&bytes).unwrap();
        let xs: Vec<Vec<f32>> = (0..2)
            .map(|i| {
                (0..64).map(|j| ((i + j) as f32 * 0.3).cos()).collect()
            })
            .collect();
        let (map, shard_bytes) =
            write_sharded(&c, 2, ShardAssignment::RoundRobin).unwrap();
        let mut outs = Vec::new();
        for mode in [
            crate::kernels::DecodeMode::Materialized,
            crate::kernels::DecodeMode::Fused,
        ] {
            let workers = ThreadWorkers::start(
                &format!("fused-parity-{mode}"),
                shard_bytes.clone(),
                StoreConfig {
                    decode_mode: mode,
                    ..StoreConfig::default()
                },
            );
            let mut router = ProcRouter::new(
                workers.clients.clone(),
                &map,
                &index,
            )
            .unwrap();
            outs.push(router.forward_batch(&xs).unwrap());
            workers.stop();
        }
        assert_eq!(outs[0], outs[1], "fused IPC serving must be bit-exact");
    }

    #[test]
    fn constructor_rejects_mismatched_maps() {
        let c = test_model(&[16, 12, 8], 95);
        let bytes = crate::container::write_container_v2(&c);
        let index = ContainerIndex::parse(&bytes).unwrap();
        let (map, _) =
            write_sharded(&c, 2, ShardAssignment::RoundRobin).unwrap();
        // One client short of the map's shard count.
        let one = vec![Arc::new(IpcShardStore::connect("/tmp/x"))];
        let err = ProcRouter::new(one, &map, &index).unwrap_err();
        assert!(format!("{err}").contains("2 shards"), "{err}");
        // A map naming a layer the index lacks.
        let stale = ShardMap::from_assignments(
            2,
            vec![("ghost".into(), 0)],
        )
        .unwrap();
        let two = vec![
            Arc::new(IpcShardStore::connect("/tmp/x")),
            Arc::new(IpcShardStore::connect("/tmp/y")),
        ];
        let err =
            ProcRouter::new(two, &stale, &index).unwrap_err();
        assert!(format!("{err}").contains("stale map"), "{err}");
    }

    #[test]
    fn transport_failure_without_supervisor_is_a_batch_error() {
        let c = test_model(&[16, 12], 96);
        let bytes = crate::container::write_container_v2(&c);
        let index = ContainerIndex::parse(&bytes).unwrap();
        let (map, _) =
            write_sharded(&c, 1, ShardAssignment::RoundRobin).unwrap();
        // A client pointed at a socket nobody serves.
        let dead = std::env::temp_dir().join(format!(
            "f2f-ipc-dead-{}.sock",
            std::process::id()
        ));
        let clients = vec![Arc::new(IpcShardStore::connect(&dead))];
        let mut router =
            ProcRouter::new(clients, &map, &index).unwrap();
        let err =
            router.forward_batch(&[vec![0.0; 16]]).unwrap_err();
        assert!(
            format!("{err}").contains("unreachable"),
            "{err}"
        );
    }
}

//! Figure 4 — encoding efficiency of random XOR-gate decoders
//! (`N_s = 0`) under three `n_u` regimes.
//!
//! Grid: `N_in ∈ {4, 8, 12, 16, 20}` × `S ∈ {0.5 … 0.9}` with
//! `N_out = ⌊N_in/(1−S)⌋`; cells report `E%` mean (± sd) over trials,
//! each trial using a fresh random `M⊕` and fresh blocks.
//!
//! * 4a — `n_u` fixed to `N_in` per block (`Var[n_u] = 0`);
//! * 4b — Bernoulli pruning: `n_u ~ B(N_out, 1−S)`;
//! * 4c — empirical `n_u` from magnitude-pruning the first decoder FFN
//!   layer of the (synthetic) Transformer.
//!
//! Expected shape: E grows with `N_in` (4a: 90 → 98 down the rows);
//! 4b/4c sit a few points below 4a at the same `N_in` (variation hurts);
//! 4c ≈ 4b (magnitude ≈ Bernoulli — the paper's justification for
//! synthetic studies).

use super::ExpOptions;
use crate::cli::Args;
use crate::decoder::DecoderSpec;
use crate::gf2::BitVecF2;
use crate::models::{transformer_layers, SyntheticLayer, WeightGen};
use crate::pruning::{PruneMethod, Pruner};
use crate::report::{fmt_mean_sd, mean_sd, Table};
use crate::rng::Rng;
use anyhow::Result;

const N_INS: [usize; 5] = [4, 8, 12, 16, 20];
const SPARSITIES: [f64; 5] = [0.5, 0.6, 0.7, 0.8, 0.9];

enum NuRegime {
    Fixed,
    Binomial,
    Empirical,
}

pub fn fig4a(args: &Args) -> Result<()> {
    grid("Figure 4a: E%, n_u fixed = N_in (Var[n_u]=0)", args, NuRegime::Fixed)
}

pub fn fig4b(args: &Args) -> Result<()> {
    grid(
        "Figure 4b: E%, n_u ~ B(N_out, 1-S) (Bernoulli pruning)",
        args,
        NuRegime::Binomial,
    )
}

pub fn fig4c(args: &Args) -> Result<()> {
    grid(
        "Figure 4c: E%, empirical n_u (magnitude-pruned Transformer dec0/ffn1)",
        args,
        NuRegime::Empirical,
    )
}

fn grid(title: &str, args: &Args, regime: NuRegime) -> Result<()> {
    let opt = ExpOptions::from_args(args, 40_000)?;
    let mut rng = Rng::new(opt.seed);

    // Empirical masks: magnitude-prune the synthetic dec0/ffn1 layer once
    // per sparsity, reuse its mask bits across trials (fresh offsets).
    let empirical_masks: Vec<BitVecF2> = match regime {
        NuRegime::Empirical => SPARSITIES
            .iter()
            .map(|&s| {
                let spec = transformer_layers()
                    .into_iter()
                    .find(|l| l.name == "dec0/ffn1")
                    .unwrap();
                let layer = SyntheticLayer::generate(
                    &spec,
                    WeightGen::default(),
                    opt.seed ^ 0xEE,
                );
                Pruner::new(PruneMethod::Magnitude, s, opt.seed ^ 0xAA)
                    .mask(&layer.weights, layer.spec.cols)
            })
            .collect(),
        _ => Vec::new(),
    };

    let mut headers: Vec<String> = vec!["N_in".into()];
    headers.extend(SPARSITIES.iter().map(|s| format!("S={s}")));
    let mut table = Table::new(
        title,
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );

    for &n_in in &N_INS {
        let mut cells = vec![n_in.to_string()];
        for (si, &s) in SPARSITIES.iter().enumerate() {
            let n_out = ((n_in as f64) / (1.0 - s)).floor() as usize;
            // Cap per-trial bits so the 2^20-entry N_in=20 search stays
            // tractable; E converges with few blocks.
            let blocks = (opt.bits / n_out).clamp(16, 64);
            let bits = blocks * n_out;
            let mut es = Vec::with_capacity(opt.trials);
            for t in 0..opt.trials {
                let data = BitVecF2::random(bits, 0.5, &mut rng);
                let mask = match regime {
                    NuRegime::Fixed => super::fixed_nu_mask(
                        bits, n_out, n_in, &mut rng,
                    ),
                    NuRegime::Binomial => {
                        super::random_mask(bits, s, &mut rng)
                    }
                    NuRegime::Empirical => {
                        // Random window into the empirical mask.
                        let src = &empirical_masks[si];
                        let start =
                            rng.below(src.len().saturating_sub(bits).max(1));
                        let mut m = BitVecF2::zeros(bits);
                        for i in 0..bits {
                            m.set(i, src.get(start + i));
                        }
                        m
                    }
                };
                let seed = opt.seed ^ ((t as u64) << 8) ^ n_in as u64;
                let e = if n_out <= 128 {
                    let spec = DecoderSpec::new(n_in, n_out, 0);
                    super::encode_with(spec, seed, &data, &mask, None)
                        .efficiency()
                } else {
                    wide_exhaustive_e(n_in, n_out, &data, &mask, seed)
                };
                es.push(e);
            }
            let (m, sd) = mean_sd(&es);
            cells.push(fmt_mean_sd(m, sd));
        }
        table.row(cells);
    }
    print_table(&table, opt.csv);
    Ok(())
}

/// Exhaustive (`N_s = 0`) encoding efficiency for blocks wider than 128
/// bits (Figure 4's `N_in = 16, 20` × `S = 0.9` cells, `N_out` up to
/// 200). The decoder matrix is two independently-random stacked halves —
/// statistically identical to one random `N_out`-row matrix. Returns E%.
fn wide_exhaustive_e(
    n_in: usize,
    n_out: usize,
    data: &BitVecF2,
    mask: &BitVecF2,
    seed: u64,
) -> f64 {
    use crate::gf2::XorMatrix;
    assert!(n_out > 128 && n_out <= 256);
    let hi_width = n_out - 128;
    let m_lo = XorMatrix::random(128, n_in, seed);
    let m_hi = XorMatrix::random(hi_width, n_in, seed ^ 0x9E37);
    // Dynamic-expansion tables, as in ChunkTables.
    let size = 1usize << n_in;
    let mut t_lo = vec![0u128; size];
    let mut t_hi = vec![0u128; size];
    for v in 1..size {
        let low = v.trailing_zeros() as usize;
        t_lo[v] = t_lo[v & (v - 1)] ^ m_lo.col(low);
        t_hi[v] = t_hi[v & (v - 1)] ^ m_hi.col(low);
    }
    let blocks = data.len() / n_out;
    let mut matched = 0usize;
    let mut unpruned = 0usize;
    for b in 0..blocks {
        let start = b * n_out;
        let d_lo = data.block(start, 128);
        let d_hi = data.block(start + 128, hi_width);
        let k_lo = mask.block(start, 128);
        let k_hi = mask.block(start + 128, hi_width);
        let n_u = (k_lo.count_ones() + k_hi.count_ones()) as usize;
        unpruned += n_u;
        let mut best = u32::MAX;
        for v in 0..size {
            let err = ((t_lo[v] ^ d_lo) & k_lo).count_ones()
                + ((t_hi[v] ^ d_hi) & k_hi).count_ones();
            if err < best {
                best = err;
                if err == 0 {
                    break;
                }
            }
        }
        matched += n_u - best as usize;
    }
    if unpruned == 0 {
        100.0
    } else {
        matched as f64 / unpruned as f64 * 100.0
    }
}

pub(crate) fn print_table(table: &Table, csv: bool) {
    if csv {
        print!("{}", table.to_csv());
    } else {
        print!("{}", table.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smoke: the 4a grid's headline trend (E rises with N_in) holds on a
    /// tiny budget.
    #[test]
    fn efficiency_rises_with_n_in_fixed_nu() {
        let mut rng = Rng::new(3);
        let mut means = Vec::new();
        for &n_in in &[4usize, 12] {
            let spec = DecoderSpec::for_sparsity(n_in, 0.5, 0);
            let bits = spec.n_out * 32;
            let mut es = Vec::new();
            for t in 0..4 {
                let data = BitVecF2::random(bits, 0.5, &mut rng);
                let mask = crate::repro::fixed_nu_mask(
                    bits, spec.n_out, n_in, &mut rng,
                );
                es.push(
                    crate::repro::encode_with(spec, t, &data, &mask, None)
                        .efficiency(),
                );
            }
            means.push(mean_sd(&es).0);
        }
        assert!(
            means[1] > means[0],
            "E(N_in=12) {} should beat E(N_in=4) {}",
            means[1],
            means[0]
        );
    }

    /// 4b sits below 4a at the same geometry (variation hurts).
    #[test]
    fn binomial_nu_is_harder_than_fixed() {
        let mut rng = Rng::new(4);
        let spec = DecoderSpec::for_sparsity(8, 0.8, 0);
        let bits = spec.n_out * 64;
        let (mut e_fixed, mut e_binom) = (0.0, 0.0);
        for t in 0..6 {
            let data = BitVecF2::random(bits, 0.5, &mut rng);
            let fm = crate::repro::fixed_nu_mask(bits, spec.n_out, 8, &mut rng);
            let bm = crate::repro::random_mask(bits, 0.8, &mut rng);
            e_fixed += crate::repro::encode_with(spec, t, &data, &fm, None)
                .efficiency();
            e_binom += crate::repro::encode_with(spec, t, &data, &bm, None)
                .efficiency();
        }
        assert!(
            e_fixed > e_binom,
            "fixed {e_fixed} should beat binomial {e_binom}"
        );
    }
}
